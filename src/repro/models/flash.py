"""Flash attention (pure-JAX, TPU-shaped) with a custom VJP.

The naive composition (softmax(QKᵀ)·V under autodiff) saves the S×S
probability tensor for the backward pass — at 32k context that is the
memory roofline killer the dry-run flagged (112 GiB/layer residuals).
This implementation:

  forward : online-softmax over K/V chunks (scan), saving only
            (out, q, k, v, lse) — O(S·d), never O(S²);
  backward: recomputes P chunk-by-chunk exactly (via the saved LSE) and
            accumulates dQ, dK, dV — the standard flash-attention-2 split:
            dQ with a scan over KV chunks, dK/dV with a scan over Q chunks.

GQA is native: queries are grouped (B, S, KV, G, Dh) and K/V are never
repeated.  Causal masking is applied per tile; fully-masked tiles are
skipped analytically in neither pass (baseline — a §Perf lever).
"""
from __future__ import annotations

import functools
import math
from typing import Optional

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def _chunk(x: jnp.ndarray, axis: int, size: int) -> jnp.ndarray:
    """(…, S, …) -> (…, S/size, size, …) with the chunk axis moved to 0."""
    n = x.shape[axis] // size
    new_shape = x.shape[:axis] + (n, size) + x.shape[axis + 1:]
    return jnp.moveaxis(x.reshape(new_shape), axis, 0)


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5))
def flash_attention(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                    causal: bool = True, q_chunk: int = 512,
                    k_chunk: int = 1024) -> jnp.ndarray:
    """q: (B,S,H,Dh); k/v: (B,S,KV,Dh) -> (B,S,H,Dh)."""
    out, _ = _flash_fwd_inner(q, k, v, causal, q_chunk, k_chunk)
    return out


def _flash_fwd_inner(q, k, v, causal, q_chunk, k_chunk):
    b, sq, h, dh = q.shape
    sk, kvh = k.shape[1], k.shape[2]
    g = h // kvh
    q_chunk = min(q_chunk, sq)
    k_chunk = min(k_chunk, sk)
    nq, nk = sq // q_chunk, sk // k_chunk
    scale = 1.0 / math.sqrt(dh)

    qg = q.reshape(b, nq, q_chunk, kvh, g, dh)
    kc = _chunk(k, 1, k_chunk)      # (nk, b, kc, kvh, dh)
    vc = _chunk(v, 1, k_chunk)

    def one_q(qi, q_blk):
        def kv_step(carry, xs):
            m, l, acc = carry
            ki, k_blk, v_blk = xs
            s = jnp.einsum('bqkgd,bskd->bkgqs', q_blk.astype(jnp.float32),
                           k_blk.astype(jnp.float32)) * scale
            if causal:
                qp = qi * q_chunk + jnp.arange(q_chunk)
                kp = ki * k_chunk + jnp.arange(k_chunk)
                s = jnp.where((qp[:, None] >= kp[None, :])[None, None, None],
                              s, NEG_INF)
            m_new = jnp.maximum(m, jnp.max(s, axis=-1))
            p = jnp.exp(s - m_new[..., None])
            corr = jnp.exp(m - m_new)
            l_new = l * corr + jnp.sum(p, axis=-1)
            acc_new = acc * corr[..., None] + jnp.einsum(
                'bkgqs,bskd->bkgqd', p, v_blk.astype(jnp.float32))
            return (m_new, l_new, acc_new), None

        m0 = jnp.full((b, kvh, g, q_chunk), NEG_INF, jnp.float32)
        l0 = jnp.zeros((b, kvh, g, q_chunk), jnp.float32)
        a0 = jnp.zeros((b, kvh, g, q_chunk, dh), jnp.float32)
        (m, l, acc), _ = jax.lax.scan(
            kv_step, (m0, l0, a0), (jnp.arange(nk), kc, vc))
        l = jnp.maximum(l, 1e-30)
        out = acc / l[..., None]
        lse = m + jnp.log(l)                          # (b,kvh,g,qc)
        return jnp.moveaxis(out, 3, 1), lse           # (b,qc,kvh,g,dh)

    outs, lses = jax.lax.map(lambda args: one_q(*args),
                             (jnp.arange(nq), jnp.moveaxis(qg, 1, 0)))
    out = jnp.moveaxis(outs, 0, 1).reshape(b, sq, h, dh).astype(q.dtype)
    # lses: (nq, b, kvh, g, qc) -> (b, kvh, g, nq, qc) -> (b, kvh, g, sq)
    lse = jnp.moveaxis(lses, 0, 3).reshape(b, kvh, g, sq)
    return out, lse


def _flash_fwd(q, k, v, causal, q_chunk, k_chunk):
    out, lse = _flash_fwd_inner(q, k, v, causal, q_chunk, k_chunk)
    return out, (q, k, v, out, lse)


def _flash_bwd(causal, q_chunk, k_chunk, res, dout):
    q, k, v, out, lse = res
    b, sq, h, dh = q.shape
    sk, kvh = k.shape[1], k.shape[2]
    g = h // kvh
    q_chunk = min(q_chunk, sq)
    k_chunk = min(k_chunk, sk)
    nq, nk = sq // q_chunk, sk // k_chunk
    scale = 1.0 / math.sqrt(dh)

    qg = q.reshape(b, sq, kvh, g, dh)
    og = out.reshape(b, sq, kvh, g, dh)
    dog = dout.reshape(b, sq, kvh, g, dh)
    # delta = rowsum(dO ⊙ O)  (b,kvh,g,sq)
    delta = jnp.einsum('bskgd,bskgd->bkgs', dog.astype(jnp.float32),
                       og.astype(jnp.float32))

    qc = _chunk(qg, 1, q_chunk)       # (nq, b, qc, kvh, g, dh)
    doc = _chunk(dog, 1, q_chunk)
    kc = _chunk(k, 1, k_chunk)        # (nk, b, kc, kvh, dh)
    vc = _chunk(v, 1, k_chunk)
    lse_c = _chunk(lse, 3, q_chunk)   # (nq, b, kvh, g, qc)
    delta_c = _chunk(delta, 3, q_chunk)

    def p_tile(qi, ki, q_blk, k_blk, lse_blk):
        s = jnp.einsum('bqkgd,bskd->bkgqs', q_blk.astype(jnp.float32),
                       k_blk.astype(jnp.float32)) * scale
        if causal:
            qp = qi * q_chunk + jnp.arange(q_chunk)
            kp = ki * k_chunk + jnp.arange(k_chunk)
            s = jnp.where((qp[:, None] >= kp[None, :])[None, None, None],
                          s, NEG_INF)
        return jnp.exp(s - lse_blk[..., None])        # (b,kvh,g,qc,kc)

    # --- dQ: for each q chunk, scan kv chunks ---
    def dq_one(qi, q_blk, do_blk, lse_blk, delta_blk):
        def step(dq_acc, xs):
            ki, k_blk, v_blk = xs
            p = p_tile(qi, ki, q_blk, k_blk, lse_blk)
            dp = jnp.einsum('bqkgd,bskd->bkgqs', do_blk.astype(jnp.float32),
                            v_blk.astype(jnp.float32))
            ds = p * (dp - delta_blk[..., None])
            dq_acc = dq_acc + jnp.einsum('bkgqs,bskd->bqkgd', ds,
                                         k_blk.astype(jnp.float32)) * scale
            return dq_acc, None

        dq0 = jnp.zeros((b, q_chunk, kvh, g, dh), jnp.float32)
        dq, _ = jax.lax.scan(step, dq0, (jnp.arange(nk), kc, vc))
        return dq

    dqs = jax.lax.map(lambda a: dq_one(*a),
                      (jnp.arange(nq), qc, doc, lse_c, delta_c))
    dq = jnp.moveaxis(dqs, 0, 1).reshape(b, sq, h, dh).astype(q.dtype)

    # --- dK/dV: for each kv chunk, scan q chunks ---
    def dkv_one(ki, k_blk, v_blk):
        def step(carry, xs):
            dk_acc, dv_acc = carry
            qi, q_blk, do_blk, lse_blk, delta_blk = xs
            p = p_tile(qi, ki, q_blk, k_blk, lse_blk)
            dv_acc = dv_acc + jnp.einsum('bkgqs,bqkgd->bskd', p,
                                         do_blk.astype(jnp.float32))
            dp = jnp.einsum('bqkgd,bskd->bkgqs', do_blk.astype(jnp.float32),
                            v_blk.astype(jnp.float32))
            ds = p * (dp - delta_blk[..., None])
            dk_acc = dk_acc + jnp.einsum('bkgqs,bqkgd->bskd', ds,
                                         q_blk.astype(jnp.float32)) * scale
            return (dk_acc, dv_acc), None

        z = jnp.zeros((b, k_chunk, kvh, dh), jnp.float32)
        (dk, dv), _ = jax.lax.scan(
            step, (z, z), (jnp.arange(nq), qc, doc, lse_c, delta_c))
        return dk, dv

    dks, dvs = jax.lax.map(lambda a: dkv_one(*a), (jnp.arange(nk), kc, vc))
    dk = jnp.moveaxis(dks, 0, 1).reshape(b, sk, kvh, dh).astype(k.dtype)
    dv = jnp.moveaxis(dvs, 0, 1).reshape(b, sk, kvh, dh).astype(v.dtype)
    return dq, dk, dv


flash_attention.defvjp(_flash_fwd, _flash_bwd)
