"""Whisper-style encoder-decoder backbone (``encdec`` family).

The conv audio frontend is a STUB per assignment: ``input_specs`` provides
precomputed frame embeddings (B, S_enc, d_model).  Sinusoidal positions,
LayerNorm, GELU MLPs, bias on QKV — decoder adds causal self-attention +
cross-attention; decode serves from self- and cross-caches.
``dec_len = seq_len // dec_ratio`` (≈ Whisper's 1500:448 enc:dec ratio).
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.core import kv as kvlib
from repro.models import module as M
from repro.models.attention import attention_block, attention_spec
from repro.models.layers import (embed, embed_spec, gelu_mlp, gelu_mlp_spec,
                                 linear, linear_spec, make_norm,
                                 sinusoidal_positions)
from repro.models.transformer import _remat_policy, cross_entropy
from repro.sharding.constraints import shard_activations


class EncDecLM:
    def __init__(self, cfg: ArchConfig):
        self.cfg = cfg
        self.n_enc = cfg.n_enc_layers or cfg.n_layers
        self.n_dec = cfg.n_dec_layers or cfg.n_layers

    # -- specs --------------------------------------------------------------

    def _enc_block_spec(self) -> dict:
        cfg = self.cfg
        norm_spec, _ = make_norm(cfg.norm)
        return {
            'norm1': norm_spec(cfg.d_model, cfg.pdtype),
            'attn': attention_spec(cfg.d_model, cfg.n_heads, cfg.n_kv_heads,
                                   cfg.head_dim, cfg.pdtype, cfg.qkv_bias),
            'norm2': norm_spec(cfg.d_model, cfg.pdtype),
            'mlp': gelu_mlp_spec(cfg.d_model, cfg.d_ff, cfg.pdtype),
        }

    def _dec_block_spec(self) -> dict:
        cfg = self.cfg
        spec = dict(self._enc_block_spec())
        norm_spec, _ = make_norm(cfg.norm)
        spec['norm_x'] = norm_spec(cfg.d_model, cfg.pdtype)
        spec['xattn'] = attention_spec(cfg.d_model, cfg.n_heads, cfg.n_kv_heads,
                                       cfg.head_dim, cfg.pdtype, cfg.qkv_bias)
        return spec

    def param_specs(self) -> dict:
        cfg = self.cfg
        norm_spec, _ = make_norm(cfg.norm)
        return {
            'embed': embed_spec(cfg.vocab, cfg.d_model, cfg.pdtype),
            'enc_blocks': M.stack_specs(self._enc_block_spec(), self.n_enc),
            'enc_norm_f': norm_spec(cfg.d_model, cfg.pdtype),
            'dec_blocks': M.stack_specs(self._dec_block_spec(), self.n_dec),
            'dec_norm_f': norm_spec(cfg.d_model, cfg.pdtype),
            'lm_head': linear_spec(cfg.d_model, cfg.vocab, ('embed', 'vocab'),
                                   cfg.pdtype),
        }

    def precon_paths(self) -> set[str]:
        paths = set()
        for stack, subs in (('enc_blocks', ('attn',)), ('dec_blocks', ('attn', 'xattn'))):
            for sub in subs:
                paths |= {f'{stack}/{sub}/{s}/w' for s in ('q', 'k', 'v', 'o')}
            paths |= {f'{stack}/mlp/fc1/w', f'{stack}/mlp/fc2/w'}
        paths.add('lm_head/w')
        return paths

    # -- encoder ------------------------------------------------------------

    def _encode(self, params, embeds, *, taps=None, capture=None):
        cfg = self.cfg
        _, norm = make_norm(cfg.norm)
        x = embeds.astype(cfg.cdtype)
        x = x + sinusoidal_positions(x.shape[1], cfg.d_model).astype(x.dtype)
        block_taps = M.subtree(taps, 'enc_blocks') or {}
        positions = jnp.broadcast_to(jnp.arange(x.shape[1]), x.shape[:2])

        def body(h, xs):
            h = shard_activations(h)
            bp, bt = xs
            bcol: dict = {}
            kw = dict(col=bcol, taps=bt or None, capture=capture,
                      compute_dtype=cfg.cdtype)
            a, _ = attention_block(bp['attn'], norm(bp['norm1'], h),
                                   n_heads=cfg.n_heads, n_kv_heads=cfg.n_kv_heads,
                                   head_dim=cfg.head_dim, positions=positions,
                                   causal=False, rope=False, path='attn', **kw)
            h = h + a
            h = h + gelu_mlp(bp['mlp'], norm(bp['norm2'], h), path='mlp', **kw)
            return h, bcol

        policy = _remat_policy(cfg.remat)
        if policy is not None or cfg.remat == 'full':
            body = jax.checkpoint(body, policy=policy)
        x, cols = jax.lax.scan(body, x, (params['enc_blocks'], block_taps))
        x = norm(params['enc_norm_f'], x)
        return x, M.add_prefix(cols, 'enc_blocks')

    # -- decoder ------------------------------------------------------------

    def _decode_stack(self, params, x, enc_out, *, taps=None, capture=None,
                      cache=None, cache_pos=None, prefill: bool = False):
        cfg = self.cfg
        _, norm = make_norm(cfg.norm)
        block_taps = M.subtree(taps, 'dec_blocks') or {}
        has_cache = cache is not None
        b, s = x.shape[:2]
        if cache_pos is not None and s == 1:
            positions = jnp.full((b, 1), cache_pos)
        else:
            positions = jnp.broadcast_to(jnp.arange(s), (b, s))
        if s == 1 and cache_pos is not None:
            # decode: table sized to the cache's max sequence length
            max_seq = cache['dec']['self']['k'].shape[2] if has_cache else 4096
            pe = sinusoidal_positions(max_seq, cfg.d_model)
            x = x + jax.lax.dynamic_slice_in_dim(pe, cache_pos, 1)[None].astype(x.dtype)
        else:
            x = x + sinusoidal_positions(s, cfg.d_model)[None].astype(x.dtype)

        def body(h, xs):
            h = shard_activations(h)
            if has_cache:
                bp, bt, bc = xs
            else:
                bp, bt = xs
                bc = None
            bcol: dict = {}
            kw = dict(col=bcol, taps=bt or None, capture=capture,
                      compute_dtype=cfg.cdtype)
            a, self_c = attention_block(
                bp['attn'], norm(bp['norm1'], h), n_heads=cfg.n_heads,
                n_kv_heads=cfg.n_kv_heads, head_dim=cfg.head_dim,
                positions=positions, causal=True, rope=False,
                cache=bc.get('self') if bc else None, cache_pos=cache_pos,
                path='attn', **kw)
            h = h + a
            # cross-attention: train/prefill kv from enc_out (prefill writes
            # the cross cache); decode reads the cached cross K/V.
            xa, cross_c = attention_block(
                bp['xattn'], norm(bp['norm_x'], h), n_heads=cfg.n_heads,
                n_kv_heads=cfg.n_kv_heads, head_dim=cfg.head_dim,
                positions=positions, causal=False, rope=False,
                kv_x=enc_out, is_cross=True,
                cache=bc.get('cross') if bc else None,
                cross_prefill=prefill, path='xattn', **kw)
            h = h + xa
            h = h + gelu_mlp(bp['mlp'], norm(bp['norm2'], h), path='mlp', **kw)
            ys = (bcol, {'self': self_c, 'cross': cross_c}) if has_cache else (bcol,)
            return h, ys

        policy = _remat_policy(cfg.remat)
        if policy is not None or cfg.remat == 'full':
            body = jax.checkpoint(body, policy=policy)

        if has_cache:
            x, (cols, new_caches) = jax.lax.scan(
                body, x, (params['dec_blocks'], block_taps, cache['dec']))
            new_cache = {'dec': new_caches}
        else:
            x, (cols,) = jax.lax.scan(body, x, (params['dec_blocks'], block_taps))
            new_cache = None
        x = norm(params['dec_norm_f'], x)
        return x, M.add_prefix(cols, 'dec_blocks'), new_cache

    # -- entry points ---------------------------------------------------------

    def loss_fn(self, params, taps, batch, capture: Optional[kvlib.CaptureConfig]):
        cfg = self.cfg
        enc_out, col_e = self._encode(params, batch['embeds'], taps=taps,
                                      capture=capture)
        x = embed(params['embed'], batch['tokens'], cfg.cdtype)
        b, s = x.shape[:2]
        x, col_d, _ = self._decode_stack(params, x, enc_out, taps=taps,
                                         capture=capture)
        col = {**col_e, **col_d}
        logits = linear(params['lm_head'], x, path='lm_head', col=col,
                        taps=taps, capture=capture, compute_dtype=cfg.cdtype)
        n = b * s + batch['embeds'].shape[0] * batch['embeds'].shape[1]
        return cross_entropy(logits, batch['labels']), {'stats': col, 'n_tokens': n}

    def init_cache(self, batch_size: int, max_seq: int, abstract: bool = False,
                   enc_len: Optional[int] = None):
        cfg = self.cfg
        enc_len = enc_len if enc_len is not None else max_seq * cfg.dec_ratio
        mk = (lambda shp, dt: jax.ShapeDtypeStruct(shp, dt)) if abstract else \
             (lambda shp, dt: jnp.zeros(shp, dt))
        cdt = jnp.dtype(cfg.cache_dtype)
        kv = lambda seq: {'k': mk((self.n_dec, batch_size, seq, cfg.n_kv_heads,
                                   cfg.head_dim), cdt),
                          'v': mk((self.n_dec, batch_size, seq, cfg.n_kv_heads,
                                   cfg.head_dim), cdt)}
        return {'dec': {'self': kv(max_seq), 'cross': kv(enc_len)}}

    def prefill_fn(self, params, batch):
        """Encode + decoder prefill over the prompt tokens."""
        cfg = self.cfg
        enc_out, _ = self._encode(params, batch['embeds'])
        x = embed(params['embed'], batch['tokens'], cfg.cdtype)
        b, s = x.shape[:2]
        cache = self.init_cache(b, s, enc_len=enc_out.shape[1])
        x, col, new_cache = self._decode_stack(params, x, enc_out, cache=cache,
                                               prefill=True)
        logits = linear(params['lm_head'], x[:, -1:, :], path='lm_head',
                        col=col, compute_dtype=cfg.cdtype)
        return logits[:, 0], new_cache

    def decode_fn(self, params, cache, tokens, pos):
        cfg = self.cfg
        x = embed(params['embed'], tokens[:, None], cfg.cdtype)
        x, col, new_cache = self._decode_stack(params, x, None, cache=cache,
                                               cache_pos=pos)
        logits = linear(params['lm_head'], x, path='lm_head', col=col,
                        compute_dtype=cfg.cdtype)
        return logits[:, 0], new_cache
