"""Building-block layers: capture-aware Linear, norms, embeddings, RoPE, MLP.

Every preconditionable linear goes through ``linear()`` which
  * emits input-activation statistics (``repro.core.kv.fwd_stats``) and
  * adds the zero *tap* whose gradient is the paper's ``b̄``
when capture is active.  Stats/taps are keyed by the weight's parameter path
so the optimizer can align them with gradients.
"""
from __future__ import annotations

import math
from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.core import kv as kvlib
from repro.models.module import ParamSpec

Collector = dict  # path -> LayerStats


# ---------------------------------------------------------------------------
# Linear


def linear_spec(d_in: int, d_out: int, axes: tuple[str | None, str | None],
                dtype=jnp.float32, bias: bool = False,
                bias_axis: str | None = None) -> dict:
    spec = {'w': ParamSpec((d_in, d_out), dtype, axes, init='scaled')}
    if bias:
        spec['b'] = ParamSpec((d_out,), dtype, (bias_axis if bias_axis is not None
                                                else axes[1],), init='zeros')
    return spec


def linear(p: dict, x: jnp.ndarray, *, path: str, col: Collector,
           taps: Optional[dict] = None,
           capture: Optional[kvlib.CaptureConfig] = None,
           compute_dtype=None) -> jnp.ndarray:
    """y = x @ w (+ b) (+ tap).  x: (..., d_in), w: (d_in, d_out)."""
    w = p['w']
    if compute_dtype is not None:
        x = x.astype(compute_dtype)
        w = w.astype(compute_dtype)
    wpath = f'{path}/w'
    if capture is not None and capture.a is not None:
        col[wpath] = kvlib.fwd_stats(x, capture)
    y = x @ w
    if 'b' in p:
        y = y + p['b'].astype(y.dtype)
    if taps is not None and wpath in taps:
        y = y + taps[wpath].astype(y.dtype)
    return y


# ---------------------------------------------------------------------------
# Norms


def rmsnorm_spec(d: int, dtype=jnp.float32) -> dict:
    return {'scale': ParamSpec((d,), dtype, ('embed',), init='ones')}


def rmsnorm(p: dict, x: jnp.ndarray, eps: float = 1e-6) -> jnp.ndarray:
    x32 = x.astype(jnp.float32)
    var = jnp.mean(x32 * x32, axis=-1, keepdims=True)
    y = x32 * jax.lax.rsqrt(var + eps)
    return (y * p['scale'].astype(jnp.float32)).astype(x.dtype)


def layernorm_spec(d: int, dtype=jnp.float32) -> dict:
    return {'scale': ParamSpec((d,), dtype, ('embed',), init='ones'),
            'bias': ParamSpec((d,), dtype, ('embed',), init='zeros')}


def layernorm(p: dict, x: jnp.ndarray, eps: float = 1e-5) -> jnp.ndarray:
    x32 = x.astype(jnp.float32)
    mu = jnp.mean(x32, axis=-1, keepdims=True)
    var = jnp.var(x32, axis=-1, keepdims=True)
    y = (x32 - mu) * jax.lax.rsqrt(var + eps)
    return (y * p['scale'].astype(jnp.float32) + p['bias'].astype(jnp.float32)).astype(x.dtype)


def make_norm(kind: str):
    if kind == 'rms':
        return rmsnorm_spec, rmsnorm
    if kind == 'layer':
        return layernorm_spec, layernorm
    raise ValueError(kind)


# ---------------------------------------------------------------------------
# Embedding


def embed_spec(vocab: int, d: int, dtype=jnp.float32) -> dict:
    return {'table': ParamSpec((vocab, d), dtype, ('vocab', 'embed'),
                               init='normal', scale=0.02)}


def embed(p: dict, ids: jnp.ndarray, compute_dtype=None) -> jnp.ndarray:
    t = p['table']
    if compute_dtype is not None:
        t = t.astype(compute_dtype)
    return jnp.take(t, ids, axis=0)


# ---------------------------------------------------------------------------
# RoPE


def rope_freqs(head_dim: int, theta: float = 10000.0) -> jnp.ndarray:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def apply_rope(x: jnp.ndarray, positions: jnp.ndarray,
               theta: float = 10000.0) -> jnp.ndarray:
    """x: (B, S, H, Dh); positions: (B, S) or (S,)."""
    dh = x.shape[-1]
    freqs = rope_freqs(dh, theta)                      # (Dh/2,)
    if positions.ndim == 1:
        positions = positions[None, :]
    ang = positions[..., None].astype(jnp.float32) * freqs  # (B, S, Dh/2)
    cos = jnp.cos(ang)[:, :, None, :]
    sin = jnp.sin(ang)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


def sinusoidal_positions(seq: int, d: int) -> jnp.ndarray:
    pos = jnp.arange(seq, dtype=jnp.float32)[:, None]
    div = jnp.exp(jnp.arange(0, d, 2, dtype=jnp.float32) * (-math.log(10000.0) / d))
    pe = jnp.zeros((seq, d), jnp.float32)
    pe = pe.at[:, 0::2].set(jnp.sin(pos * div))
    pe = pe.at[:, 1::2].set(jnp.cos(pos * div))
    return pe


# ---------------------------------------------------------------------------
# Gated MLP (SwiGLU) — the dense FFN used by all LM archs


def mlp_spec(d: int, d_ff: int, dtype=jnp.float32, bias: bool = False) -> dict:
    return {
        'gate': linear_spec(d, d_ff, ('embed', 'mlp'), dtype, bias),
        'up': linear_spec(d, d_ff, ('embed', 'mlp'), dtype, bias),
        'down': linear_spec(d_ff, d, ('mlp', 'embed'), dtype, bias),
    }


def mlp(p: dict, x: jnp.ndarray, *, path: str, col: Collector,
        taps=None, capture=None, compute_dtype=None) -> jnp.ndarray:
    kw = dict(col=col, taps=taps, capture=capture, compute_dtype=compute_dtype)
    g = linear(p['gate'], x, path=f'{path}/gate', **kw)
    u = linear(p['up'], x, path=f'{path}/up', **kw)
    h = jax.nn.silu(g) * u
    return linear(p['down'], h, path=f'{path}/down', **kw)


def gelu_mlp_spec(d: int, d_ff: int, dtype=jnp.float32, bias: bool = True) -> dict:
    """Whisper-style 2-layer GELU MLP."""
    return {
        'fc1': linear_spec(d, d_ff, ('embed', 'mlp'), dtype, bias),
        'fc2': linear_spec(d_ff, d, ('mlp', 'embed'), dtype, bias),
    }


def gelu_mlp(p: dict, x: jnp.ndarray, *, path: str, col: Collector,
             taps=None, capture=None, compute_dtype=None) -> jnp.ndarray:
    kw = dict(col=col, taps=taps, capture=capture, compute_dtype=compute_dtype)
    h = jax.nn.gelu(linear(p['fc1'], x, path=f'{path}/fc1', **kw))
    return linear(p['fc2'], h, path=f'{path}/fc2', **kw)
