"""Model zoo: dense/MoE/VLM transformer, Mamba2 SSD, Jamba hybrid,
whisper-style enc-dec, and the paper's autoencoder/MLP."""
from repro.models.registry import (build_model, decode_specs,
                                   prefill_batch_specs, train_batch_specs)
from repro.models.simple import MLP, autoencoder, ae_loss_fn, classifier_loss_fn

__all__ = ['build_model', 'decode_specs', 'prefill_batch_specs',
           'train_batch_specs', 'MLP', 'autoencoder', 'ae_loss_fn',
           'classifier_loss_fn']
