"""GQA attention: naive and chunked (flash-style, online-softmax) paths,
plus KV-cache decode.  KV heads are never materialized ``G`` times — queries
are grouped ``(B, S, KV, G, Dh)`` and contracted against un-repeated K/V.

``impl='naive'`` materializes (B,KV,G,Sq,Sk) scores — simplest HLO, highest
HBM traffic.  ``impl='chunked'`` scans over K/V chunks with an online softmax
(the TPU-friendly flash adaptation: block sizes are chosen so the working set
sits in VMEM and the MXU sees [q_chunk × Dh] × [Dh × k_chunk] matmuls); this
is one of the §Perf hillclimb levers.
"""
from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp

from repro.models.layers import apply_rope, linear, linear_spec
from repro.sharding.constraints import constrain

NEG_INF = -1e30


def _shard_heads(x: jnp.ndarray) -> jnp.ndarray:
    """(B, S, H, Dh): batch -> data axes, heads -> model when divisible,
    head_dim NEVER sharded (a sharded contraction dim would psum every
    attention score tile — the §Perf collective-bound fix)."""
    return constrain(x, 'data', None, 'model', None)


def attention_spec(d_model: int, n_heads: int, n_kv_heads: int, head_dim: int,
                   dtype=jnp.float32, qkv_bias: bool = False) -> dict:
    return {
        'q': linear_spec(d_model, n_heads * head_dim, ('embed', 'heads'), dtype, qkv_bias),
        'k': linear_spec(d_model, n_kv_heads * head_dim, ('embed', 'kv_heads'), dtype, qkv_bias),
        'v': linear_spec(d_model, n_kv_heads * head_dim, ('embed', 'kv_heads'), dtype, qkv_bias),
        'o': linear_spec(n_heads * head_dim, d_model, ('heads', 'embed'), dtype, False),
    }


# ---------------------------------------------------------------------------
# Core attends (q: (B,Sq,H,Dh), k/v: (B,Sk,KV,Dh))


def _group(q: jnp.ndarray, n_kv: int) -> jnp.ndarray:
    b, s, h, dh = q.shape
    return q.reshape(b, s, n_kv, h // n_kv, dh)


def attend_naive(q, k, v, *, causal: bool,
                 q_positions=None, k_positions=None) -> jnp.ndarray:
    b, sq, h, dh = q.shape
    kvh = k.shape[2]
    qg = _group(q, kvh)
    scale = 1.0 / math.sqrt(dh)
    scores = jnp.einsum('bqkgd,bskd->bkgqs', qg.astype(jnp.float32),
                        k.astype(jnp.float32)) * scale
    if causal:
        qp = q_positions if q_positions is not None else jnp.arange(sq)
        kp = k_positions if k_positions is not None else jnp.arange(k.shape[1])
        mask = qp[:, None] >= kp[None, :]
        scores = jnp.where(mask[None, None, None], scores, NEG_INF)
    w = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum('bkgqs,bskd->bqkgd', w, v.astype(jnp.float32))
    return out.reshape(b, sq, h, dh).astype(q.dtype)


def attend_chunked(q, k, v, *, causal: bool, q_chunk: int = 512,
                   k_chunk: int = 1024) -> jnp.ndarray:
    """Flash-style: map over query chunks, scan over key chunks with an
    online softmax.  Causal masking is applied per (q_chunk × k_chunk) tile;
    fully-masked tiles still compute (baseline; see §Perf for the
    block-skipping iteration)."""
    b, sq, h, dh = q.shape
    sk = k.shape[1]
    kvh = k.shape[2]
    g = h // kvh
    q_chunk = min(q_chunk, sq)
    k_chunk = min(k_chunk, sk)
    nq, nk = sq // q_chunk, sk // k_chunk
    assert sq % q_chunk == 0 and sk % k_chunk == 0, (sq, q_chunk, sk, k_chunk)
    scale = 1.0 / math.sqrt(dh)

    qg = _group(q, kvh).reshape(b, nq, q_chunk, kvh, g, dh)
    kc = k.reshape(b, nk, k_chunk, kvh, dh)
    vc = v.reshape(b, nk, k_chunk, kvh, dh)

    def one_q_chunk(qi, q_blk):
        # q_blk: (b, q_chunk, kvh, g, dh)
        def kv_step(carry, xs):
            m, l, acc = carry
            ki, k_blk, v_blk = xs
            s = jnp.einsum('bqkgd,bskd->bkgqs', q_blk.astype(jnp.float32),
                           k_blk.astype(jnp.float32)) * scale
            if causal:
                qp = qi * q_chunk + jnp.arange(q_chunk)
                kp = ki * k_chunk + jnp.arange(k_chunk)
                mask = qp[:, None] >= kp[None, :]
                s = jnp.where(mask[None, None, None], s, NEG_INF)
            m_new = jnp.maximum(m, jnp.max(s, axis=-1))
            p = jnp.exp(s - m_new[..., None])
            corr = jnp.exp(m - m_new)
            l_new = l * corr + jnp.sum(p, axis=-1)
            acc_new = acc * corr[..., None] + jnp.einsum(
                'bkgqs,bskd->bkgqd', p, v_blk.astype(jnp.float32))
            return (m_new, l_new, acc_new), None

        m0 = jnp.full((b, kvh, g, q_chunk), NEG_INF, jnp.float32)
        l0 = jnp.zeros((b, kvh, g, q_chunk), jnp.float32)
        a0 = jnp.zeros((b, kvh, g, q_chunk, dh), jnp.float32)
        ks = jnp.arange(nk)
        (m, l, acc), _ = jax.lax.scan(
            kv_step, (m0, l0, a0),
            (ks, jnp.moveaxis(kc, 1, 0), jnp.moveaxis(vc, 1, 0)))
        out = acc / jnp.maximum(l, 1e-30)[..., None]      # (b,kvh,g,qc,dh)
        return jnp.moveaxis(out, 3, 1)                    # (b,qc,kvh,g,dh)

    outs = jax.lax.map(lambda args: one_q_chunk(*args),
                       (jnp.arange(nq), jnp.moveaxis(qg, 1, 0)))
    out = jnp.moveaxis(outs, 0, 1).reshape(b, sq, h, dh)  # (b,nq,qc,...)->(b,sq,h,dh)
    return out.astype(q.dtype)


def attend_decode(q, cache_k, cache_v, pos) -> jnp.ndarray:
    """Single-token decode: q (B,1,H,Dh) against the full cache, masked to
    positions <= pos.  O(S) — this is the sub-quadratic decode path."""
    b, _, h, dh = q.shape
    kvh = cache_k.shape[2]
    qg = _group(q, kvh)
    scale = 1.0 / math.sqrt(dh)
    s = jnp.einsum('bqkgd,bskd->bkgqs', qg.astype(jnp.float32),
                   cache_k.astype(jnp.float32)) * scale
    valid = jnp.arange(cache_k.shape[1]) <= pos
    s = jnp.where(valid[None, None, None, None, :], s, NEG_INF)
    w = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum('bkgqs,bskd->bqkgd', w, cache_v.astype(jnp.float32))
    return out.reshape(b, 1, h, dh).astype(q.dtype)


def attend(q, k, v, *, causal: bool, impl: str = 'naive',
           q_chunk: int = 512, k_chunk: int = 1024) -> jnp.ndarray:
    if impl == 'flash':
        from repro.models.flash import flash_attention
        return flash_attention(q, k, v, causal, q_chunk, k_chunk)
    if impl == 'chunked':
        return attend_chunked(q, k, v, causal=causal,
                              q_chunk=q_chunk, k_chunk=k_chunk)
    return attend_naive(q, k, v, causal=causal)


# ---------------------------------------------------------------------------
# Full attention block (projections + rope + attend)


def attention_block(p, x, *, n_heads: int, n_kv_heads: int, head_dim: int,
                    positions, causal: bool = True, rope: bool = True,
                    rope_theta: float = 10000.0, impl: str = 'naive',
                    q_chunk: int = 512, k_chunk: int = 1024,
                    kv_x: Optional[jnp.ndarray] = None, is_cross: bool = False,
                    cache: Optional[dict] = None, cache_pos=None,
                    cross_prefill: bool = False,
                    path: str = '', col=None, taps=None, capture=None,
                    compute_dtype=None):
    """Returns (out, new_cache).  ``is_cross`` marks cross-attention (K/V
    from ``kv_x`` at train/prefill, from ``cache`` at decode);
    ``cross_prefill`` computes cross K/V from ``kv_x`` and writes the cache."""
    b = x.shape[0]
    kw = dict(col=col if col is not None else {}, taps=taps, capture=capture,
              compute_dtype=compute_dtype)
    q = linear(p['q'], x, path=f'{path}/q', **kw)
    q = q.reshape(b, x.shape[1], n_heads, head_dim)
    if rope:
        q = apply_rope(q, positions, rope_theta)
    q = _shard_heads(q)

    if is_cross:
        if cache is not None and not cross_prefill:
            # decode: read-only cached encoder keys/values
            out = attend_naive(q, cache['k'], cache['v'], causal=False)
            new_cache = cache
        else:
            assert kv_x is not None, 'cross-attention needs kv_x at train/prefill'
            k = linear(p['k'], kv_x, path=f'{path}/k', **kw)
            v = linear(p['v'], kv_x, path=f'{path}/v', **kw)
            k = _shard_heads(k.reshape(b, kv_x.shape[1], n_kv_heads, head_dim))
            v = _shard_heads(v.reshape(b, kv_x.shape[1], n_kv_heads, head_dim))
            if cache is not None:  # cross prefill: populate the cache
                ck = jax.lax.dynamic_update_slice(
                    cache['k'], k.astype(cache['k'].dtype), (0, 0, 0, 0))
                cv = jax.lax.dynamic_update_slice(
                    cache['v'], v.astype(cache['v'].dtype), (0, 0, 0, 0))
                new_cache = {'k': ck, 'v': cv}
            else:
                new_cache = None
            out = attend_naive(q, k, v, causal=False)
    else:
        k = linear(p['k'], x, path=f'{path}/k', **kw)
        v = linear(p['v'], x, path=f'{path}/v', **kw)
        k = _shard_heads(k.reshape(b, x.shape[1], n_kv_heads, head_dim))
        v = _shard_heads(v.reshape(b, x.shape[1], n_kv_heads, head_dim))
        if rope:
            if cache is not None and q.shape[1] == 1:  # decode: key at cache_pos
                k = apply_rope(k, jnp.full((b, 1), cache_pos), rope_theta)
            else:
                k = apply_rope(k, positions, rope_theta)

        if cache is not None:
            start = cache_pos if q.shape[1] == 1 else 0
            ck = jax.lax.dynamic_update_slice(
                cache['k'], k.astype(cache['k'].dtype), (0, start, 0, 0))
            cv = jax.lax.dynamic_update_slice(
                cache['v'], v.astype(cache['v'].dtype), (0, start, 0, 0))
            new_cache = {'k': ck, 'v': cv}
            if q.shape[1] == 1:
                out = attend_decode(q, ck, cv, cache_pos)
            else:
                out = attend(q, k, v, causal=causal, impl=impl,
                             q_chunk=q_chunk, k_chunk=k_chunk)
        else:
            new_cache = None
            out = attend(q, k, v, causal=causal, impl=impl,
                         q_chunk=q_chunk, k_chunk=k_chunk)

    out = out.reshape(b, x.shape[1], n_heads * head_dim)
    y = linear(p['o'], out, path=f'{path}/o', **kw)
    return y, new_cache
