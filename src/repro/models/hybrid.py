"""Jamba-style hybrid: interleaved attention/Mamba mixers with periodic MoE.

Layer ``i`` uses an attention mixer iff ``i % attn_period == attn_offset``
(Jamba: 1 attention per 8 layers) and a MoE FFN iff
``i % expert_period == expert_offset`` (Jamba: every other layer); all other
FFNs are dense.  We scan over *super-blocks* of ``attn_period`` sublayers
(each sublayer type is static inside the super-block), which keeps the HLO
compact while allowing the heterogeneous caches.

Adaptation note (DESIGN.md): Jamba's mixer is Mamba-1; we use our Mamba-2
SSD block as the state-space mixer (same interface, MXU-friendly).
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.core import kv as kvlib
from repro.models import module as M
from repro.models.attention import attention_block, attention_spec
from repro.models.layers import embed, embed_spec, linear, linear_spec, make_norm, mlp, mlp_spec
from repro.models.moe import moe_apply, moe_spec
from repro.models.ssm import mamba_block, mamba_spec, ssm_dims
from repro.models.transformer import _remat_policy, cross_entropy
from repro.sharding.constraints import shard_activations


class JambaLM:
    def __init__(self, cfg: ArchConfig):
        self.cfg = cfg
        assert cfg.attn_period > 0 and cfg.n_layers % cfg.attn_period == 0
        self.n_super = cfg.n_layers // cfg.attn_period

    def _sub_is_attn(self, i: int) -> bool:
        return i % self.cfg.attn_period == self.cfg.attn_offset

    def _sub_is_moe(self, i: int) -> bool:
        cfg = self.cfg
        return cfg.expert_period > 0 and i % cfg.expert_period == cfg.expert_offset

    def sub_spec(self, i: int) -> dict:
        cfg = self.cfg
        norm_spec, _ = make_norm(cfg.norm)
        spec = {'norm1': norm_spec(cfg.d_model, cfg.pdtype),
                'norm2': norm_spec(cfg.d_model, cfg.pdtype)}
        if self._sub_is_attn(i):
            spec['attn'] = attention_spec(cfg.d_model, cfg.n_heads, cfg.n_kv_heads,
                                          cfg.head_dim, cfg.pdtype, cfg.qkv_bias)
        else:
            spec['mixer'] = mamba_spec(cfg.d_model, expand=cfg.ssm_expand,
                                       headdim=cfg.ssm_headdim, d_state=cfg.ssm_state,
                                       d_conv=cfg.ssm_conv, dtype=cfg.pdtype)
        if self._sub_is_moe(i):
            spec['moe'] = moe_spec(cfg.d_model, cfg.d_ff, cfg.n_experts, cfg.pdtype)
        else:
            spec['mlp'] = mlp_spec(cfg.d_model, cfg.d_ff, cfg.pdtype)
        return spec

    def param_specs(self) -> dict:
        cfg = self.cfg
        norm_spec, _ = make_norm(cfg.norm)
        super_spec = {f'sub_{i}': self.sub_spec(i) for i in range(cfg.attn_period)}
        specs = {
            'embed': embed_spec(cfg.vocab, cfg.d_model, cfg.pdtype),
            'blocks': M.stack_specs(super_spec, self.n_super),
            'norm_f': norm_spec(cfg.d_model, cfg.pdtype),
        }
        if not cfg.tie_embeddings:
            specs['lm_head'] = linear_spec(cfg.d_model, cfg.vocab,
                                           ('embed', 'vocab'), cfg.pdtype)
        return specs

    def precon_paths(self) -> set[str]:
        cfg = self.cfg
        paths = set()
        for i in range(cfg.attn_period):
            base = f'blocks/sub_{i}'
            if self._sub_is_attn(i):
                paths |= {f'{base}/attn/{s}/w' for s in ('q', 'k', 'v', 'o')}
            else:
                paths |= {f'{base}/mixer/in_proj/w', f'{base}/mixer/out_proj/w'}
            if self._sub_is_moe(i):
                paths |= {f'{base}/moe/router/w', f'{base}/moe/gate/w',
                          f'{base}/moe/up/w', f'{base}/moe/down/w'}
            else:
                paths |= {f'{base}/mlp/{s}/w' for s in ('gate', 'up', 'down')}
        if not cfg.tie_embeddings:
            paths.add('lm_head/w')
        return paths

    # -- sublayer ---------------------------------------------------------

    def _sublayer(self, i, p, x, *, positions, col, taps, capture,
                  cache=None, cache_pos=None, prefill: bool = False):
        cfg = self.cfg
        _, norm = make_norm(cfg.norm)
        kw = dict(col=col, taps=M.subtree(taps, f'sub_{i}') if taps else None,
                  capture=capture, compute_dtype=cfg.cdtype)
        sub_col: dict = {}
        kw['col'] = sub_col
        h = norm(p['norm1'], x)
        new_cache = None
        if self._sub_is_attn(i):
            out, new_cache = attention_block(
                p['attn'], h, n_heads=cfg.n_heads, n_kv_heads=cfg.n_kv_heads,
                head_dim=cfg.head_dim, positions=positions, causal=True,
                rope=True, rope_theta=cfg.rope_theta, impl=cfg.attn_impl,
                q_chunk=cfg.q_chunk, k_chunk=cfg.k_chunk, cache=cache,
                cache_pos=cache_pos, path='attn', **kw)
        else:
            # prefill: ignore the preallocated (zero) cache, emit a fresh one
            out, new_cache = mamba_block(
                p['mixer'], h, headdim=cfg.ssm_headdim, d_state=cfg.ssm_state,
                d_conv=cfg.ssm_conv, chunk=cfg.ssm_chunk,
                cache=None if prefill else cache,
                return_cache=prefill, path='mixer', **kw)
        x = x + out
        h2 = norm(p['norm2'], x)
        if self._sub_is_moe(i):
            ff, aux = moe_apply(p['moe'], h2, top_k=cfg.top_k,
                                capacity_factor=cfg.capacity_factor,
                                norm_topk=cfg.norm_topk, path='moe',
                                aux_coef=cfg.moe_aux_coef, **kw)
        else:
            ff, aux = mlp(p['mlp'], h2, path='mlp', **kw), jnp.zeros((), jnp.float32)
        col.update(M.add_prefix(sub_col, f'sub_{i}'))
        return x + ff, new_cache, aux

    # -- forward ------------------------------------------------------------

    def _forward(self, params, x, positions, *, taps=None, capture=None,
                 cache=None, cache_pos=None, prefill: bool = False):
        cfg = self.cfg
        block_taps = M.subtree(taps, 'blocks') or {}
        has_cache = cache is not None
        emits_cache = has_cache or prefill

        def body(carry, xs):
            h = shard_activations(carry)
            if has_cache:
                bp, bt, bc = xs
            else:
                bp, bt = xs
                bc = None
            bcol: dict = {}
            caches, auxs = {}, []
            for i in range(cfg.attn_period):
                sub_cache = bc.get(f'sub_{i}') if bc else None
                h, nc, aux = self._sublayer(
                    i, bp[f'sub_{i}'], h, positions=positions, col=bcol,
                    taps=bt or None, capture=capture, cache=sub_cache,
                    cache_pos=cache_pos, prefill=prefill)
                if emits_cache and nc is not None:
                    caches[f'sub_{i}'] = nc
                auxs.append(aux)
            ys = (bcol, caches, sum(auxs)) if emits_cache else (bcol, sum(auxs))
            return h, ys

        policy = _remat_policy(cfg.remat)
        if policy is not None or cfg.remat == 'full':
            body = jax.checkpoint(body, policy=policy)

        if has_cache:
            x, (cols, new_caches, auxs) = jax.lax.scan(
                body, x, (params['blocks'], block_taps, cache['blocks']))
            new_cache = {'blocks': new_caches}
        elif prefill:
            x, (cols, new_caches, auxs) = jax.lax.scan(
                body, x, (params['blocks'], block_taps))
            new_cache = {'blocks': new_caches}
        else:
            x, (cols, auxs) = jax.lax.scan(body, x, (params['blocks'], block_taps))
            new_cache = None
        return x, M.add_prefix(cols, 'blocks'), jnp.sum(auxs), new_cache

    def _logits(self, params, x, col, taps, capture):
        cfg = self.cfg
        _, norm = make_norm(cfg.norm)
        x = norm(params['norm_f'], x)
        if cfg.tie_embeddings:
            return x.astype(cfg.cdtype) @ params['embed']['table'].T.astype(cfg.cdtype)
        return linear(params['lm_head'], x, path='lm_head', col=col,
                      taps=taps, capture=capture, compute_dtype=cfg.cdtype)

    def loss_fn(self, params, taps, batch, capture: Optional[kvlib.CaptureConfig]):
        cfg = self.cfg
        x = embed(params['embed'], batch['tokens'], cfg.cdtype)
        b, s = x.shape[:2]
        positions = jnp.broadcast_to(jnp.arange(s), (b, s))
        x, col, aux, _ = self._forward(params, x, positions, taps=taps,
                                       capture=capture)
        logits = self._logits(params, x, col, taps, capture)
        return cross_entropy(logits, batch['labels']) + aux, \
            {'stats': col, 'n_tokens': b * s}

    def init_cache(self, batch_size: int, max_seq: int, abstract: bool = False):
        cfg = self.cfg
        d_inner, nheads, conv_ch = ssm_dims(cfg.d_model, cfg.ssm_expand,
                                            cfg.ssm_headdim, cfg.ssm_state,
                                            cfg.ssm_conv)
        mk = (lambda shp, dt: jax.ShapeDtypeStruct(shp, dt)) if abstract else \
             (lambda shp, dt: jnp.zeros(shp, dt))
        cdt = jnp.dtype(cfg.cache_dtype)
        blocks = {}
        for i in range(cfg.attn_period):
            if self._sub_is_attn(i):
                blocks[f'sub_{i}'] = {
                    'k': mk((self.n_super, batch_size, max_seq, cfg.n_kv_heads,
                             cfg.head_dim), cdt),
                    'v': mk((self.n_super, batch_size, max_seq, cfg.n_kv_heads,
                             cfg.head_dim), cdt)}
            else:
                blocks[f'sub_{i}'] = {
                    'conv': mk((self.n_super, batch_size, cfg.ssm_conv - 1,
                                conv_ch), cdt),
                    'ssm': mk((self.n_super, batch_size, nheads, cfg.ssm_state,
                               cfg.ssm_headdim), jnp.float32)}
        return {'blocks': blocks}

    def prefill_fn(self, params, batch):
        cfg = self.cfg
        x = embed(params['embed'], batch['tokens'], cfg.cdtype)
        b, s = x.shape[:2]
        positions = jnp.broadcast_to(jnp.arange(s), (b, s))
        # attention sublayers need a cache buffer to fill during prefill
        cache = self.init_cache(b, s)
        # mamba sublayers build their cache from the forward; attention
        # sublayers write into the preallocated one.
        x, col, _, new_cache = self._forward(params, x, positions,
                                             cache=cache, prefill=True)
        logits = self._logits(params, x[:, -1:, :], col, None, None)
        return logits[:, 0], new_cache

    def decode_fn(self, params, cache, tokens, pos):
        cfg = self.cfg
        x = embed(params['embed'], tokens[:, None], cfg.cdtype)
        positions = jnp.full((tokens.shape[0], 1), pos)
        x, col, _, new_cache = self._forward(params, x, positions,
                                             cache=cache, cache_pos=pos)
        logits = self._logits(params, x, col, None, None)
        return logits[:, 0], new_cache
