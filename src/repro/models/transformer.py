"""Decoder-only transformer LM covering the dense / MoE / VLM families.

Layer-stacked params under ``jax.lax.scan`` (compact HLO even at 61 layers /
1T params), capture-aware linears everywhere, three entry points:

  * ``loss_fn``     — next-token CE (+ MoE aux), returns KV-capture stats
  * ``prefill_fn``  — populate a KV cache, return last-position logits
  * ``decode_fn``   — one token in, logits + updated cache out

VLM/audio archs (``input_is_embeds``) take precomputed frontend embeddings
for train/prefill (the modality frontend is a stub per assignment) and fall
back to the token embedding table for decode.
"""
from __future__ import annotations

import functools
from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.core import kv as kvlib
from repro.models import module as M
from repro.models.attention import attention_block, attention_spec
from repro.models.layers import embed, embed_spec, linear, linear_spec, make_norm, mlp, mlp_spec
from repro.models.moe import moe_apply, moe_spec
from repro.sharding.constraints import shard_activations


def _remat_policy(name: str):
    if name == 'full':
        return jax.checkpoint_policies.nothing_saveable
    if name == 'dots':
        return jax.checkpoint_policies.dots_with_no_batch_dims_saveable
    return None


def cross_entropy(logits: jnp.ndarray, labels: jnp.ndarray) -> jnp.ndarray:
    """Mean next-token CE in f32 without materializing one-hots."""
    logits = logits.astype(jnp.float32)
    lse = jax.scipy.special.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    return jnp.mean(lse - gold)


class TransformerLM:
    """Families: dense, moe, vlm."""

    def __init__(self, cfg: ArchConfig):
        self.cfg = cfg

    # -- specs ------------------------------------------------------------

    def block_spec(self) -> dict:
        cfg = self.cfg
        norm_spec, _ = make_norm(cfg.norm)
        spec = {
            'norm1': norm_spec(cfg.d_model, cfg.pdtype),
            'attn': attention_spec(cfg.d_model, cfg.n_heads, cfg.n_kv_heads,
                                   cfg.head_dim, cfg.pdtype, cfg.qkv_bias),
            'norm2': norm_spec(cfg.d_model, cfg.pdtype),
        }
        if cfg.n_experts:
            spec['moe'] = moe_spec(cfg.d_model, cfg.d_ff, cfg.n_experts, cfg.pdtype)
            if cfg.n_shared_experts:
                spec['shared_mlp'] = mlp_spec(cfg.d_model,
                                              cfg.d_ff * cfg.n_shared_experts,
                                              cfg.pdtype)
        else:
            spec['mlp'] = mlp_spec(cfg.d_model, cfg.d_ff, cfg.pdtype)
        return spec

    def param_specs(self) -> dict:
        cfg = self.cfg
        norm_spec, _ = make_norm(cfg.norm)
        specs = {
            'embed': embed_spec(cfg.vocab, cfg.d_model, cfg.pdtype),
            'blocks': M.stack_specs(self.block_spec(), cfg.n_layers),
            'norm_f': norm_spec(cfg.d_model, cfg.pdtype),
        }
        if not cfg.tie_embeddings:
            specs['lm_head'] = linear_spec(cfg.d_model, cfg.vocab,
                                           ('embed', 'vocab'), cfg.pdtype)
        return specs

    def precon_paths(self) -> set[str]:
        cfg = self.cfg
        paths = set()
        for sub in ('q', 'k', 'v', 'o'):
            paths.add(f'blocks/attn/{sub}/w')
        if cfg.n_experts:
            paths |= {'blocks/moe/router/w', 'blocks/moe/gate/w',
                      'blocks/moe/up/w', 'blocks/moe/down/w'}
            if cfg.n_shared_experts:
                paths |= {f'blocks/shared_mlp/{s}/w' for s in ('gate', 'up', 'down')}
        else:
            paths |= {f'blocks/mlp/{s}/w' for s in ('gate', 'up', 'down')}
        if not cfg.tie_embeddings:
            paths.add('lm_head/w')
        return paths

    # -- block ------------------------------------------------------------

    def _block(self, p, x, *, positions, col, taps, capture,
               cache=None, cache_pos=None):
        cfg = self.cfg
        _, norm = make_norm(cfg.norm)
        kw = dict(col=col, taps=taps, capture=capture, compute_dtype=cfg.cdtype)
        h = norm(p['norm1'], x)
        att, new_cache = attention_block(
            p['attn'], h, n_heads=cfg.n_heads, n_kv_heads=cfg.n_kv_heads,
            head_dim=cfg.head_dim, positions=positions, causal=True,
            rope=True, rope_theta=cfg.rope_theta, impl=cfg.attn_impl,
            q_chunk=cfg.q_chunk, k_chunk=cfg.k_chunk,
            cache=cache, cache_pos=cache_pos, path='attn', **kw)
        x = x + att
        h2 = norm(p['norm2'], x)
        if cfg.n_experts:
            ff, aux = moe_apply(p['moe'], h2, top_k=cfg.top_k,
                                capacity_factor=cfg.capacity_factor,
                                norm_topk=cfg.norm_topk, path='moe',
                                aux_coef=cfg.moe_aux_coef, **kw)
            if cfg.n_shared_experts:
                ff = ff + mlp(p['shared_mlp'], h2, path='shared_mlp', **kw)
        else:
            ff, aux = mlp(p['mlp'], h2, path='mlp', **kw), jnp.zeros((), jnp.float32)
        return x + ff, new_cache, aux

    # -- forward (train / prefill share the stacked scan) ------------------

    def _forward(self, params, x, positions, *, taps=None, capture=None,
                 cache=None, cache_pos=None):
        cfg = self.cfg
        block_taps = M.subtree(taps, 'blocks') or {}
        has_cache = cache is not None

        def body(carry, xs):
            h = shard_activations(carry)
            if has_cache:
                bp, bt, bc = xs
            else:
                bp, bt = xs
                bc = None
            bcol: dict = {}
            h, new_bc, aux = self._block(
                bp, h, positions=positions, col=bcol, taps=bt or None,
                capture=capture, cache=bc, cache_pos=cache_pos)
            ys = (bcol, new_bc, aux) if has_cache else (bcol, aux)
            return h, ys

        policy = _remat_policy(cfg.remat)
        if policy is not None or cfg.remat == 'full':
            body = jax.checkpoint(body, policy=policy)

        if has_cache:
            xs = (params['blocks'], block_taps, cache['blocks'])
            x, (cols, new_caches, auxs) = jax.lax.scan(
                body, x, xs, unroll=cfg.scan_unroll)
            new_cache = dict(cache)
            new_cache['blocks'] = new_caches
        else:
            xs = (params['blocks'], block_taps)
            x, (cols, auxs) = jax.lax.scan(body, x, xs, unroll=cfg.scan_unroll)
            new_cache = None
        col = M.add_prefix(cols, 'blocks')
        return x, col, jnp.sum(auxs), new_cache

    def _logits(self, params, x, col, taps, capture):
        cfg = self.cfg
        _, norm = make_norm(cfg.norm)
        x = norm(params['norm_f'], x)
        if cfg.tie_embeddings:
            return x.astype(cfg.cdtype) @ params['embed']['table'].T.astype(cfg.cdtype)
        return linear(params['lm_head'], x, path='lm_head', col=col,
                      taps=taps, capture=capture, compute_dtype=cfg.cdtype)

    def _embed_in(self, params, batch):
        cfg = self.cfg
        if cfg.input_is_embeds and 'embeds' in batch:
            return batch['embeds'].astype(cfg.cdtype)
        return embed(params['embed'], batch['tokens'], cfg.cdtype)

    # -- entry points -------------------------------------------------------

    def loss_fn(self, params, taps, batch, capture: Optional[kvlib.CaptureConfig]):
        cfg = self.cfg
        x = self._embed_in(params, batch)
        b, s = x.shape[:2]
        positions = jnp.broadcast_to(jnp.arange(s), (b, s))
        x, col, aux, _ = self._forward(x=x, params=params, positions=positions,
                                       taps=taps, capture=capture)
        logits = self._logits(params, x, col, taps, capture)
        loss = cross_entropy(logits, batch['labels']) + aux
        return loss, {'stats': col, 'n_tokens': b * s}

    def init_cache(self, batch_size: int, max_seq: int, abstract: bool = False):
        cfg = self.cfg
        mk = (lambda shp, dt: jax.ShapeDtypeStruct(shp, dt)) if abstract else \
             (lambda shp, dt: jnp.zeros(shp, dt))
        dt = jnp.dtype(cfg.cache_dtype)
        blocks = {'k': mk((cfg.n_layers, batch_size, max_seq, cfg.n_kv_heads,
                           cfg.head_dim), dt),
                  'v': mk((cfg.n_layers, batch_size, max_seq, cfg.n_kv_heads,
                           cfg.head_dim), dt)}
        return {'blocks': blocks}

    def prefill_fn(self, params, batch):
        cfg = self.cfg
        x = self._embed_in(params, batch)
        b, s = x.shape[:2]
        positions = jnp.broadcast_to(jnp.arange(s), (b, s))
        cache = self.init_cache(b, s)
        x, col, _, cache = self._forward(x=x, params=params, positions=positions,
                                         cache=cache)
        logits = self._logits(params, x[:, -1:, :], col, None, None)
        return logits[:, 0], cache

    def decode_fn(self, params, cache, tokens, pos):
        """tokens: (B,) int32; pos: scalar int32 — write position."""
        cfg = self.cfg
        x = embed(params['embed'], tokens[:, None], cfg.cdtype)
        positions = jnp.full((tokens.shape[0], 1), pos)
        x, col, _, new_cache = self._forward(x=x, params=params,
                                             positions=positions,
                                             cache=cache, cache_pos=pos)
        logits = self._logits(params, x, col, None, None)
        return logits[:, 0], new_cache
