"""Mamba2 LM (pure SSM stack — the ``ssm`` family, attention-free)."""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.core import kv as kvlib
from repro.models import module as M
from repro.models.layers import embed, embed_spec, linear, linear_spec, make_norm
from repro.models.ssm import mamba_block, mamba_spec, ssm_dims
from repro.models.transformer import _remat_policy, cross_entropy
from repro.sharding.constraints import shard_activations


class MambaLM:
    def __init__(self, cfg: ArchConfig):
        self.cfg = cfg

    def block_spec(self) -> dict:
        cfg = self.cfg
        norm_spec, _ = make_norm(cfg.norm)
        return {
            'norm': norm_spec(cfg.d_model, cfg.pdtype),
            'mixer': mamba_spec(cfg.d_model, expand=cfg.ssm_expand,
                                headdim=cfg.ssm_headdim, d_state=cfg.ssm_state,
                                d_conv=cfg.ssm_conv, dtype=cfg.pdtype),
        }

    def param_specs(self) -> dict:
        cfg = self.cfg
        norm_spec, _ = make_norm(cfg.norm)
        specs = {
            'embed': embed_spec(cfg.vocab, cfg.d_model, cfg.pdtype),
            'blocks': M.stack_specs(self.block_spec(), cfg.n_layers),
            'norm_f': norm_spec(cfg.d_model, cfg.pdtype),
        }
        if not cfg.tie_embeddings:
            specs['lm_head'] = linear_spec(cfg.d_model, cfg.vocab,
                                           ('embed', 'vocab'), cfg.pdtype)
        return specs

    def precon_paths(self) -> set[str]:
        paths = {'blocks/mixer/in_proj/w', 'blocks/mixer/out_proj/w'}
        if not self.cfg.tie_embeddings:
            paths.add('lm_head/w')
        return paths

    def _forward(self, params, x, *, taps=None, capture=None, cache=None,
                 return_cache: bool = False):
        cfg = self.cfg
        _, norm = make_norm(cfg.norm)
        block_taps = M.subtree(taps, 'blocks') or {}
        has_cache = cache is not None
        emits_cache = has_cache or return_cache

        def body(carry, xs):
            h = shard_activations(carry)
            if has_cache:
                bp, bt, bc = xs
            else:
                bp, bt = xs
                bc = None
            bcol: dict = {}
            out, new_bc = mamba_block(
                bp['mixer'], norm(bp['norm'], h), headdim=cfg.ssm_headdim,
                d_state=cfg.ssm_state, d_conv=cfg.ssm_conv, chunk=cfg.ssm_chunk,
                cache=bc, return_cache=return_cache, path='mixer', col=bcol,
                taps=bt or None, capture=capture, compute_dtype=cfg.cdtype)
            h = h + out
            return h, ((bcol, new_bc) if emits_cache else (bcol,))

        policy = _remat_policy(cfg.remat)
        if policy is not None or cfg.remat == 'full':
            body = jax.checkpoint(body, policy=policy)

        if has_cache:
            x, (cols, new_caches) = jax.lax.scan(
                body, x, (params['blocks'], block_taps, cache['blocks']),
                unroll=cfg.scan_unroll)
            new_cache = {'blocks': new_caches}
        elif return_cache:
            x, (cols, new_caches) = jax.lax.scan(
                body, x, (params['blocks'], block_taps), unroll=cfg.scan_unroll)
            new_cache = {'blocks': new_caches}
        else:
            x, (cols,) = jax.lax.scan(body, x, (params['blocks'], block_taps),
                                      unroll=cfg.scan_unroll)
            new_cache = None
        return x, M.add_prefix(cols, 'blocks'), new_cache

    def _logits(self, params, x, col, taps, capture):
        cfg = self.cfg
        _, norm = make_norm(cfg.norm)
        x = norm(params['norm_f'], x)
        if cfg.tie_embeddings:
            return x.astype(cfg.cdtype) @ params['embed']['table'].T.astype(cfg.cdtype)
        return linear(params['lm_head'], x, path='lm_head', col=col,
                      taps=taps, capture=capture, compute_dtype=cfg.cdtype)

    def loss_fn(self, params, taps, batch, capture: Optional[kvlib.CaptureConfig]):
        cfg = self.cfg
        x = embed(params['embed'], batch['tokens'], cfg.cdtype)
        b, s = x.shape[:2]
        x, col, _ = self._forward(params, x, taps=taps, capture=capture)
        logits = self._logits(params, x, col, taps, capture)
        return cross_entropy(logits, batch['labels']), {'stats': col, 'n_tokens': b * s}

    def init_cache(self, batch_size: int, max_seq: int, abstract: bool = False):
        """SSM cache is O(1) in context length — max_seq is irrelevant."""
        cfg = self.cfg
        d_inner, nheads, conv_ch = ssm_dims(cfg.d_model, cfg.ssm_expand,
                                            cfg.ssm_headdim, cfg.ssm_state,
                                            cfg.ssm_conv)
        mk = (lambda shp, dt: jax.ShapeDtypeStruct(shp, dt)) if abstract else \
             (lambda shp, dt: jnp.zeros(shp, dt))
        dt = jnp.dtype(cfg.cache_dtype)
        return {'blocks': {
            'conv': mk((cfg.n_layers, batch_size, cfg.ssm_conv - 1, conv_ch), dt),
            'ssm': mk((cfg.n_layers, batch_size, nheads, cfg.ssm_state,
                       cfg.ssm_headdim), jnp.float32),
        }}

    def prefill_fn(self, params, batch):
        """Chunked-SSD prefill; decode cache = per-layer final state + conv tail."""
        cfg = self.cfg
        x = embed(params['embed'], batch['tokens'], cfg.cdtype)
        x, col, cache = self._forward(params, x, return_cache=True)
        logits = self._logits(params, x[:, -1:, :], col, None, None)
        return logits[:, 0], cache

    def decode_fn(self, params, cache, tokens, pos):
        cfg = self.cfg
        del pos  # state-space decode is position-free
        x = embed(params['embed'], tokens[:, None], cfg.cdtype)
        x, col, new_cache = self._forward(params, x, cache=cache)
        logits = self._logits(params, x, col, None, None)
        return logits[:, 0], new_cache
