"""Mixture-of-Experts with sort-based capacity dispatch (MaxText-style).

Tokens' top-k expert assignments are sorted by expert id, positioned within
each expert's segment, and scattered into a dense ``(E, C, D)`` buffer
(capacity ``C = ceil(T·k·cf / E)``); overflow drops.  Expert FFNs are a
single stacked einsum — with the expert axis sharded over the 'model' mesh
axis this is expert parallelism, and XLA inserts the dispatch/combine
all-to-alls from the sharding constraints.

Eva-for-MoE (beyond-paper): each expert weight gets a per-expert tap
``(E, d_out)`` and masked per-expert input means, so the rank-one
preconditioner applies vmapped over experts.  The router is an ordinary
preconditioned linear.
"""
from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp

from repro.core import kv as kvlib
from repro.models.layers import linear, linear_spec
from repro.sharding.constraints import constrain
from repro.models.module import ParamSpec


def moe_spec(d: int, d_ff: int, n_experts: int, dtype=jnp.float32) -> dict:
    def w(shape, axes):
        return ParamSpec(shape, dtype, axes, init='scaled')
    return {
        'router': linear_spec(d, n_experts, ('embed', None), dtype, bias=False),
        'gate': {'w': w((n_experts, d, d_ff), ('expert', 'embed', 'mlp'))},
        'up': {'w': w((n_experts, d, d_ff), ('expert', 'embed', 'mlp'))},
        'down': {'w': w((n_experts, d_ff, d), ('expert', 'mlp', 'embed'))},
    }


def capacity(n_tokens: int, top_k: int, n_experts: int, factor: float) -> int:
    c = int(math.ceil(n_tokens * top_k * factor / n_experts))
    return max(8, -(-c // 8) * 8)  # round up to a multiple of 8


def _expert_linear(w: jnp.ndarray, x: jnp.ndarray, *, wpath: str, col,
                   taps, capture, mask) -> jnp.ndarray:
    """x: (E, ..., d_in) @ w: (E, d_in, d_out) with per-expert stats/taps.
    mask: (E, ...) slot validity."""
    if capture is not None and capture.a is not None:
        xf = x.reshape(x.shape[0], -1, x.shape[-1])
        mf = mask.reshape(mask.shape[0], -1)
        col[wpath] = kvlib.fwd_stats_masked(xf, mf, capture)
    y = jnp.einsum('e...d,edf->e...f', x, w)
    if taps is not None and wpath in taps:
        tap = taps[wpath].reshape((taps[wpath].shape[0],) + (1,) * (y.ndim - 2)
                                  + (taps[wpath].shape[-1],))
        y = y + tap.astype(y.dtype)
    return y


def _n_data_shards() -> int:
    """Data-axis size of the current mesh (1 outside a mesh context)."""
    from repro.sharding.constraints import _current_mesh
    m = _current_mesh()
    if m is None:
        return 1
    n = 1
    for a in ('pod', 'data'):
        if a in m.shape:
            n *= m.shape[a]
    return n


def moe_apply(p: dict, x: jnp.ndarray, *, top_k: int, capacity_factor: float,
              norm_topk: bool = True, path: str = '', col=None,
              taps=None, capture=None, compute_dtype=None,
              aux_coef: float = 0.0):
    """x: (B, S, D) -> (y, aux_loss).  Dropless up to capacity; overflow drops."""
    col = col if col is not None else {}
    b, s, d = x.shape
    t = b * s
    xt = x.reshape(t, d)
    n_experts = p['gate']['w'].shape[0]

    logits = linear(p['router'], xt, path=f'{path}/router', col=col,
                    taps=taps, capture=capture, compute_dtype=compute_dtype)
    probs = jax.nn.softmax(logits.astype(jnp.float32), axis=-1)     # (T, E)
    gate_vals, expert_ids = jax.lax.top_k(probs, top_k)             # (T, k)
    if norm_topk:
        gate_vals = gate_vals / jnp.sum(gate_vals, axis=-1, keepdims=True)

    # --- load-balancing auxiliary loss (Switch-style) ---
    if aux_coef:
        me = jnp.mean(probs, axis=0)                                 # (E,)
        ce = jnp.mean(jax.nn.one_hot(expert_ids[:, 0], n_experts), axis=0)
        aux = aux_coef * n_experts * jnp.sum(me * ce)
    else:
        aux = jnp.zeros((), jnp.float32)

    # --- group-local sort-based dispatch (hierarchical all-to-all) ---
    # Tokens are routed *within their data shard's group* (G = number of
    # data shards; per-group capacity C_l).  Dispatch/combine gathers are
    # then shard-local, and the only cross-device movement is resharding
    # the (E, G, C_l, D) slot tensor from token-major (G over data axes) to
    # expert-major (E over model axis) — a clean all-to-all of slot volume,
    # instead of the (T, D)-sized all-reduce per layer SPMD emits for
    # global gathers/scatters (§Perf iterations 2–3, EXPERIMENTS.md).
    # Only int32 index tables go through scatters.
    groups = _n_data_shards()
    if t % groups or (t // groups) < top_k:
        groups = 1
    tg = t // groups
    cap = capacity(tg, top_k, n_experts, capacity_factor)

    ids_g = expert_ids.reshape(groups, tg * top_k)                   # (G,T_l*k)
    ok_shape = (groups, tg, top_k)

    def route(flat_e):
        """Per-group slot assignment from (T_l*k,) expert ids."""
        sort_idx = jnp.argsort(flat_e)
        counts = jnp.zeros((n_experts,), jnp.int32).at[flat_e].add(1)
        seg_start = jnp.cumsum(counts) - counts
        inv_rank = jnp.zeros((tg * top_k,), jnp.int32).at[sort_idx].set(
            jnp.arange(tg * top_k, dtype=jnp.int32))
        pos_tk = inv_rank - seg_start[flat_e]
        ok_tk = pos_tk < cap
        safe_pos = jnp.where(ok_tk, pos_tk, cap)
        tk_token = jnp.arange(tg * top_k, dtype=jnp.int32) // top_k
        slot_token = jnp.zeros((n_experts, cap + 1), jnp.int32).at[
            flat_e, safe_pos].set(tk_token)[:, :cap]
        slot_mask = jnp.zeros((n_experts, cap + 1), jnp.float32).at[
            flat_e, safe_pos].set(ok_tk.astype(jnp.float32))[:, :cap]
        flat_slot = flat_e * cap + jnp.minimum(pos_tk, cap - 1)
        return slot_token, slot_mask, flat_slot, ok_tk

    slot_token, slot_mask, flat_slot, ok_tk = jax.vmap(route)(ids_g)
    slot_mask = jnp.moveaxis(slot_mask, 0, 1)                        # (E,G,C)

    xd = xt.astype(compute_dtype) if compute_dtype is not None else xt
    xg = constrain(xd.reshape(groups, tg, d), 'data', None, None)
    disp = jax.vmap(lambda xs, idx: jnp.take(xs, idx, axis=0))(
        xg, slot_token)                                              # (G,E,C,D)
    disp = jnp.moveaxis(disp, 0, 1)                                  # (E,G,C,D)
    disp = disp * slot_mask[..., None].astype(disp.dtype)
    disp = constrain(disp, 'model', 'data', None, None)

    # --- expert FFN (E = expert parallelism, G = data parallelism) ---
    wd = (lambda w: w.astype(compute_dtype)) if compute_dtype is not None else (lambda w: w)
    kw = dict(col=col, taps=taps, capture=capture, mask=slot_mask)
    g = _expert_linear(wd(p['gate']['w']), disp, wpath=f'{path}/gate/w', **kw)
    u = _expert_linear(wd(p['up']['w']), disp, wpath=f'{path}/up/w', **kw)
    h = jax.nn.silu(g) * u
    out_e = _expert_linear(wd(p['down']['w']), h, wpath=f'{path}/down/w', **kw)
    out_e = constrain(out_e, 'model', 'data', None, None)

    # --- combine: gather + weighted top-k sum, all group-local ---
    out_g = jnp.moveaxis(out_e, 1, 0).reshape(groups, n_experts * cap, d)
    out_g = constrain(out_g, 'data', None, None)
    w_g = (gate_vals.reshape(groups, tg, top_k)
           * ok_tk.reshape(groups, tg, top_k)).astype(jnp.float32)

    def combine(os, idx, wg):
        y_tk = jnp.take(os, idx, axis=0).reshape(tg, top_k, d)
        return jnp.einsum('tkd,tk->td', y_tk.astype(jnp.float32), wg)

    y_g = jax.vmap(combine)(out_g, flat_slot, w_g)                   # (G,T_l,D)
    y_g = constrain(y_g, 'data', None, None)
    return y_g.reshape(b, s, d).astype(x.dtype), aux
