"""Mamba2 (SSD — state-space duality) block, TPU-adapted.

The SSD algorithm is implemented in its *chunked matmul* form (intra-chunk
attention-like matmuls + inter-chunk state recurrence via ``lax.scan``) —
exactly the decomposition that maps the recurrence onto the MXU instead of a
long sequential scan; chunk length is a config knob (§Perf lever).

Preconditioning: ``in_proj`` / ``out_proj`` are capture-aware linears (Eva
applies); conv/A_log/D/dt_bias are SSM-internal → first-order fall-through
(paper's rule for non-linear-layer params).

Decode is O(1) in context length: the entire 500k-token history lives in the
(H, N, P) state + (k-1)-deep conv buffer — this is why mamba2/jamba are the
``long_500k`` archs.
"""
from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp

from repro.models.layers import linear, linear_spec, rmsnorm
from repro.models.module import ParamSpec
from repro.sharding.constraints import constrain


def ssm_dims(d_model: int, expand: int = 2, headdim: int = 64,
             d_state: int = 128, d_conv: int = 4):
    d_inner = expand * d_model
    nheads = d_inner // headdim
    conv_ch = d_inner + 2 * d_state  # x + B + C (ngroups=1)
    return d_inner, nheads, conv_ch


def mamba_spec(d_model: int, *, expand: int = 2, headdim: int = 64,
               d_state: int = 128, d_conv: int = 4, dtype=jnp.float32) -> dict:
    d_inner, nheads, conv_ch = ssm_dims(d_model, expand, headdim, d_state, d_conv)
    d_in_proj = 2 * d_inner + 2 * d_state + nheads  # z, x, B, C, dt
    return {
        'in_proj': linear_spec(d_model, d_in_proj, ('embed', 'inner'), dtype),
        'conv_w': ParamSpec((d_conv, conv_ch), dtype, (None, 'inner'), init='scaled'),
        'conv_b': ParamSpec((conv_ch,), dtype, ('inner',), init='zeros'),
        'A_log': ParamSpec((nheads,), jnp.float32, ('heads',), init='ones'),
        'dt_bias': ParamSpec((nheads,), jnp.float32, ('heads',), init='zeros'),
        'D': ParamSpec((nheads,), jnp.float32, ('heads',), init='ones'),
        'norm': {'scale': ParamSpec((d_inner,), dtype, ('inner',), init='ones')},
        'out_proj': linear_spec(d_inner, d_model, ('inner', 'embed'), dtype),
    }


def _causal_conv(x: jnp.ndarray, w: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    """Depthwise causal conv1d.  x: (B, S, Ch); w: (K, Ch)."""
    k, ch = w.shape
    xp = jnp.pad(x, ((0, 0), (k - 1, 0), (0, 0)))
    out = jax.lax.conv_general_dilated(
        xp.astype(jnp.float32),
        w[:, None, :].astype(jnp.float32),   # (K, 1, Ch) HIO for depthwise
        window_strides=(1,), padding='VALID',
        dimension_numbers=('NHC', 'HIO', 'NHC'),
        feature_group_count=ch)
    return (out + b.astype(jnp.float32)).astype(x.dtype)


def ssd_chunked(x, dt, a, bmat, cmat, d_skip, chunk: int = 128):
    """SSD forward.  x: (B,S,H,P); dt: (B,S,H); a: (H,) (negative);
    bmat/cmat: (B,S,N); d_skip: (H,).  Returns (y, final_state (B,H,N,P))."""
    bsz, s, h, p = x.shape
    n = bmat.shape[-1]
    chunk = min(chunk, s)
    pad = (-s) % chunk
    if pad:
        # right-pad with dt=0 steps: exp(dt·A)=1 and dt·B·x=0, so padded
        # positions are identities on the carried state (outputs sliced off)
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        bmat = jnp.pad(bmat, ((0, 0), (0, pad), (0, 0)))
        cmat = jnp.pad(cmat, ((0, 0), (0, pad), (0, 0)))
    s_padded = s + pad
    nc = s_padded // chunk

    xc = x.reshape(bsz, nc, chunk, h, p).astype(jnp.float32)
    dtc = dt.reshape(bsz, nc, chunk, h).astype(jnp.float32)
    bc = bmat.reshape(bsz, nc, chunk, n).astype(jnp.float32)
    cc = cmat.reshape(bsz, nc, chunk, n).astype(jnp.float32)

    dta = dtc * a[None, None, None, :]                       # (b,c,q,h) ≤ 0
    seg = jnp.cumsum(dta, axis=2)                            # within-chunk cumsum
    total = seg[:, :, -1, :]                                 # (b,c,h)

    # intra-chunk (attention-like): L[q,k] = exp(seg_q - seg_k) for q >= k
    rel = seg[:, :, :, None, :] - seg[:, :, None, :, :]      # (b,c,q,k,h)
    causal = jnp.tril(jnp.ones((chunk, chunk), bool))
    l_mat = jnp.where(causal[None, None, :, :, None], jnp.exp(rel), 0.0)
    cb = jnp.einsum('bcqn,bckn->bcqk', cc, bc)
    m = cb[..., None] * l_mat * dtc[:, :, None, :, :]        # (b,c,q,k,h)
    y_intra = jnp.einsum('bcqkh,bckhp->bcqhp', m, xc)

    # chunk -> carried state:  S_c = Σ_k exp(total - seg_k)·dt_k·B_k ⊗ x_k
    decay_out = jnp.exp(total[:, :, None, :] - seg)          # (b,c,q,h)
    s_chunk = jnp.einsum('bckn,bckh,bckhp->bchnp', bc, decay_out * dtc, xc)

    # inter-chunk recurrence
    def step(state, xs):
        s_c, tot_c = xs                                      # (b,h,n,p), (b,h)
        new = state * jnp.exp(tot_c)[:, :, None, None] + s_c
        return new, state                                    # emit state *entering* chunk

    init = jnp.zeros((bsz, h, n, p), jnp.float32)
    final, states_in = jax.lax.scan(
        step, init, (jnp.moveaxis(s_chunk, 1, 0), jnp.moveaxis(total, 1, 0)))
    states_in = jnp.moveaxis(states_in, 0, 1)                # (b,c,h,n,p)

    y_inter = jnp.einsum('bcqn,bchnp,bcqh->bcqhp', cc, states_in, jnp.exp(seg))
    y = (y_intra + y_inter).reshape(bsz, s_padded, h, p)
    y = y + x.astype(jnp.float32) * d_skip[None, None, :, None]
    if pad:
        y = y[:, :s]
    return y.astype(x.dtype), final


def mamba_block(p, x, *, headdim: int = 64, d_state: int = 128,
                d_conv: int = 4, chunk: int = 128,
                cache: Optional[dict] = None, return_cache: bool = False,
                path: str = '', col=None,
                taps=None, capture=None, compute_dtype=None):
    """Returns (y, new_cache).  cache = {'conv': (B,K-1,Ch), 'ssm': (B,H,N,P)}.
    ``return_cache=True`` (prefill) emits the cache from a cache-free forward:
    final SSD state + last (K-1) pre-conv inputs."""
    col = col if col is not None else {}
    bsz, s, d_model = x.shape
    d_inner = p['norm']['scale'].shape[0]
    nheads = p['A_log'].shape[0]
    kw = dict(col=col, taps=taps, capture=capture, compute_dtype=compute_dtype)

    zxbcdt = linear(p['in_proj'], x, path=f'{path}/in_proj', **kw)
    z, xbc, dt = jnp.split(zxbcdt, [d_inner, 2 * d_inner + 2 * d_state], axis=-1)

    if cache is None:
        xbc_raw = xbc
        xbc = jax.nn.silu(_causal_conv(xbc, p['conv_w'], p['conv_b']))
    else:
        # decode: roll the conv buffer (S == 1)
        buf = jnp.concatenate([cache['conv'], xbc.astype(cache['conv'].dtype)], axis=1)
        w = p['conv_w'].astype(jnp.float32)
        conv_out = jnp.einsum('bkc,kc->bc', buf.astype(jnp.float32), w) + p['conv_b']
        xbc = jax.nn.silu(conv_out)[:, None, :].astype(x.dtype)
        new_conv = buf[:, 1:, :]

    xs, bmat, cmat = jnp.split(xbc, [d_inner, d_inner + d_state], axis=-1)
    xh = xs.reshape(bsz, s, nheads, headdim)
    # SSD heads are a pure batch dim of the chunk einsums: pin them to the
    # model axis so the intra-chunk matmuls shard instead of replicating
    # (§Perf: jamba's compute term was 16× inflated without this anchor)
    xh = constrain(xh, 'data', None, 'model', None)
    a = -jnp.exp(p['A_log'].astype(jnp.float32))
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p['dt_bias'][None, None, :])
    dt = constrain(dt, 'data', None, 'model')

    if cache is None:
        y, final_state = ssd_chunked(xh, dt, a, bmat, cmat,
                                     p['D'].astype(jnp.float32), chunk=chunk)
        new_cache = None
        if return_cache:
            pad = d_conv - 1
            tail = xbc_raw[:, -pad:, :] if s >= pad else jnp.pad(
                xbc_raw, ((0, 0), (pad - s, 0), (0, 0)))
            new_cache = {'conv': tail, 'ssm': final_state}
    else:
        # recurrent single-step update
        da = jnp.exp(dt[:, 0, :] * a[None, :])               # (B,H)
        dbx = jnp.einsum('bn,bh,bhp->bhnp', bmat[:, 0].astype(jnp.float32),
                         dt[:, 0], xh[:, 0].astype(jnp.float32))
        state = cache['ssm'] * da[:, :, None, None] + dbx
        y0 = jnp.einsum('bn,bhnp->bhp', cmat[:, 0].astype(jnp.float32), state)
        y0 = y0 + xh[:, 0].astype(jnp.float32) * p['D'][None, :, None]
        y = y0[:, None].astype(x.dtype)
        new_cache = {'conv': new_conv, 'ssm': state.astype(cache['ssm'].dtype)}

    y = y.reshape(bsz, s, d_inner)
    y = rmsnorm(p['norm'], y.astype(x.dtype) * jax.nn.silu(z).astype(x.dtype))
    return linear(p['out_proj'], y, path=f'{path}/out_proj', **kw), new_cache
