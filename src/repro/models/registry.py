"""Model registry + per-cell input specs.

``build_model(cfg)`` maps config family -> model class (duck-typed:
param_specs / precon_paths / loss_fn / prefill_fn / decode_fn / init_cache).

``input_specs(cfg, shape)`` returns ShapeDtypeStruct stand-ins for every
model input of that (arch × shape) cell — weak-type-correct, shardable, no
device allocation — exactly what ``jit(...).lower()`` consumes in the
dry-run.
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig, ShapeCell
from repro.models.encdec import EncDecLM
from repro.models.hybrid import JambaLM
from repro.models.mamba_lm import MambaLM
from repro.models.transformer import TransformerLM


def build_model(cfg: ArchConfig):
    if cfg.family in ('dense', 'moe', 'vlm'):
        return TransformerLM(cfg)
    if cfg.family == 'ssm':
        return MambaLM(cfg)
    if cfg.family == 'hybrid':
        return JambaLM(cfg)
    if cfg.family == 'encdec':
        return EncDecLM(cfg)
    raise ValueError(f'unknown family {cfg.family!r}')


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def train_batch_specs(cfg: ArchConfig, shape: ShapeCell) -> dict[str, Any]:
    b, s = shape.global_batch, shape.seq_len
    if cfg.family == 'encdec':
        dec = s // cfg.dec_ratio
        return {'embeds': _sds((b, s, cfg.d_model), cfg.cdtype),
                'tokens': _sds((b, dec), jnp.int32),
                'labels': _sds((b, dec), jnp.int32)}
    if cfg.input_is_embeds:
        return {'embeds': _sds((b, s, cfg.d_model), cfg.cdtype),
                'labels': _sds((b, s), jnp.int32)}
    return {'tokens': _sds((b, s), jnp.int32),
            'labels': _sds((b, s), jnp.int32)}


def prefill_batch_specs(cfg: ArchConfig, shape: ShapeCell) -> dict[str, Any]:
    b, s = shape.global_batch, shape.seq_len
    if cfg.family == 'encdec':
        dec = s // cfg.dec_ratio
        return {'embeds': _sds((b, s, cfg.d_model), cfg.cdtype),
                'tokens': _sds((b, dec), jnp.int32)}
    if cfg.input_is_embeds:
        return {'embeds': _sds((b, s, cfg.d_model), cfg.cdtype)}
    return {'tokens': _sds((b, s), jnp.int32)}


def decode_specs(cfg: ArchConfig, shape: ShapeCell):
    """Returns (cache_specs, tokens_spec, pos_spec)."""
    b, s = shape.global_batch, shape.seq_len
    model = build_model(cfg)
    if cfg.family == 'encdec':
        cache = model.init_cache(b, s // cfg.dec_ratio, abstract=True, enc_len=s)
    else:
        cache = model.init_cache(b, s, abstract=True)
    return cache, _sds((b,), jnp.int32), _sds((), jnp.int32)
