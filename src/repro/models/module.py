"""Minimal functional module system with logical-axis metadata.

No flax on this box, and the dry-run needs shape-only initialization of
trillion-parameter models — so params are plain nested dicts described by a
parallel tree of ``ParamSpec`` (shape, dtype, logical axes, initializer).

* ``init_params``      materializes arrays (smoke tests, examples).
* ``abstract_params``  returns ShapeDtypeStructs (dry-run, no allocation).
* logical axes feed the sharding resolver (``repro.sharding``).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class ParamSpec:
    shape: tuple[int, ...]
    dtype: Any = jnp.float32
    axes: tuple[Optional[str], ...] = ()
    init: str = 'normal'          # normal | zeros | ones | scaled
    scale: Optional[float] = None  # stddev override

    def __post_init__(self):
        if len(self.axes) != len(self.shape):
            raise ValueError(f'axes {self.axes} do not match shape {self.shape}')


def is_spec(x) -> bool:
    return isinstance(x, ParamSpec)


def spec_tree_map(fn: Callable[[ParamSpec], Any], specs: Any) -> Any:
    """Map over a nested dict of ParamSpec."""
    if isinstance(specs, dict):
        return {k: spec_tree_map(fn, v) for k, v in specs.items()}
    return fn(specs)


def abstract_params(specs: Any) -> Any:
    return spec_tree_map(lambda s: jax.ShapeDtypeStruct(s.shape, s.dtype), specs)


def _init_one(spec: ParamSpec, key) -> jnp.ndarray:
    if spec.init == 'zeros':
        return jnp.zeros(spec.shape, spec.dtype)
    if spec.init == 'ones':
        return jnp.ones(spec.shape, spec.dtype)
    if spec.init == 'normal':
        std = spec.scale if spec.scale is not None else 0.02
        return (jax.random.normal(key, spec.shape, jnp.float32) * std).astype(spec.dtype)
    if spec.init == 'scaled':  # fan-in scaled (1/sqrt(d_in) over dim -2)
        fan_in = spec.shape[-2] if len(spec.shape) >= 2 else spec.shape[-1]
        std = spec.scale if spec.scale is not None else 1.0 / math.sqrt(fan_in)
        return (jax.random.normal(key, spec.shape, jnp.float32) * std).astype(spec.dtype)
    raise ValueError(f'unknown init {spec.init!r}')


def init_params(specs: Any, key) -> Any:
    """Materialize a spec tree into arrays with split PRNG keys."""
    flat = _flatten_specs(specs)
    keys = jax.random.split(key, max(len(flat), 1))
    leaves = {p: _init_one(s, k) for (p, s), k in zip(sorted(flat.items()), keys)}
    return _unflatten(leaves)


def _flatten_specs(specs: Any, prefix: str = '') -> dict[str, ParamSpec]:
    out = {}
    if isinstance(specs, dict):
        for k, v in specs.items():
            key = f'{prefix}/{k}' if prefix else str(k)
            out.update(_flatten_specs(v, key))
    else:
        out[prefix] = specs
    return out


def _unflatten(flat: dict[str, Any]) -> Any:
    out: dict[str, Any] = {}
    for path, leaf in flat.items():
        keys = path.split('/')
        d = out
        for k in keys[:-1]:
            d = d.setdefault(k, {})
        d[keys[-1]] = leaf
    return out


def flatten_specs(specs: Any) -> dict[str, ParamSpec]:
    return _flatten_specs(specs)


def stack_specs(specs: Any, n: int, axis_name: str = 'layer') -> Any:
    """Add a leading stacked dim (for lax.scan over layers)."""
    return spec_tree_map(
        lambda s: ParamSpec((n,) + s.shape, s.dtype, (axis_name,) + s.axes,
                            s.init, s.scale),
        specs)


def count_params(specs: Any) -> int:
    return sum(math.prod(s.shape) for s in _flatten_specs(specs).values())


def subtree(tree: Optional[dict], prefix: str) -> Optional[dict]:
    """Select entries of a flat '/'-keyed dict under ``prefix`` (relative keys)."""
    if tree is None:
        return None
    pfx = prefix + '/'
    out = {k[len(pfx):]: v for k, v in tree.items() if k.startswith(pfx)}
    return out or None


def add_prefix(tree: Optional[dict], prefix: str) -> dict:
    if not tree:
        return {}
    return {f'{prefix}/{k}': v for k, v in tree.items()}
