"""Simple fully-connected models: the paper's 8-layer autoencoder (§5.1,
Fig. 4) and an MLP classifier (stand-in for the paper's CNN benchmarks —
DESIGN.md §8).  These are the only models supporting *full* taps
(K-FAC/FOOF's ``b_outer``/``a_outer`` capture), since the cost of
materializing per-token cotangents is K-FAC's own baseline cost.
"""
from __future__ import annotations

from typing import Optional, Sequence

import jax
import jax.numpy as jnp

from repro.core import kv as kvlib
from repro.models import module as M
from repro.models.layers import linear, linear_spec


class MLP:
    """dims = [in, h1, ..., out]; relu hidden activations."""

    def __init__(self, dims: Sequence[int], final_activation: Optional[str] = None,
                 dtype=jnp.float32):
        self.dims = tuple(dims)
        self.final_activation = final_activation
        self.dtype = dtype

    def param_specs(self) -> dict:
        return {f'fc{i}': linear_spec(self.dims[i], self.dims[i + 1],
                                      (None, None), self.dtype, bias=True)
                for i in range(len(self.dims) - 1)}

    def precon_paths(self) -> set[str]:
        return {f'fc{i}/w' for i in range(len(self.dims) - 1)}

    def make_taps(self, batch_size: int,
                  capture: kvlib.CaptureConfig) -> Optional[dict]:
        """Vector taps (d_out,) or full taps (batch, d_out) per layer."""
        if not capture.needs_taps:
            return None
        taps = {}
        for i in range(len(self.dims) - 1):
            d_out = self.dims[i + 1]
            shape = (d_out,) if capture.b == 'mean' else (batch_size, d_out)
            taps[f'fc{i}/w'] = jnp.zeros(shape, jnp.float32)
        return taps

    def apply(self, params, x, taps=None, capture=None):
        col: dict = {}
        n = len(self.dims) - 1
        for i in range(n):
            x = linear(params[f'fc{i}'], x, path=f'fc{i}', col=col,
                       taps=taps, capture=capture)
            if i < n - 1:
                x = jax.nn.relu(x)
        if self.final_activation == 'sigmoid':
            x = jax.nn.sigmoid(x)
        return x, col


def autoencoder(hidden: Sequence[int] = (1000, 500, 250, 30, 250, 500, 1000),
                d_in: int = 784) -> MLP:
    """The paper's 8-layer autoencoder (§5.1)."""
    return MLP([d_in, *hidden, d_in], final_activation='sigmoid')


def ae_loss_fn(model: MLP):
    def loss_fn(params, taps, batch, capture):
        recon, col = model.apply(params, batch['x'], taps=taps, capture=capture)
        x = batch['x']
        # binary cross-entropy (x in [0,1]) as in deep-AE benchmarks
        eps = 1e-6
        r = jnp.clip(recon.astype(jnp.float32), eps, 1 - eps)
        loss = -jnp.mean(x * jnp.log(r) + (1 - x) * jnp.log(1 - r))
        return loss, {'stats': col, 'n_tokens': x.shape[0]}
    return loss_fn


def classifier_loss_fn(model: MLP):
    def loss_fn(params, taps, batch, capture):
        logits, col = model.apply(params, batch['x'], taps=taps, capture=capture)
        logits = logits.astype(jnp.float32)
        lse = jax.scipy.special.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, batch['y'][:, None], axis=-1)[:, 0]
        return jnp.mean(lse - gold), {'stats': col, 'n_tokens': logits.shape[0]}
    return loss_fn
