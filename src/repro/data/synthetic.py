"""Deterministic synthetic datasets (no external data offline — DESIGN.md §8).

All streams are *seekable*: ``batch_at(step)`` is a pure function of
(seed, step), which makes checkpoint-resume bit-exact and lets the trainer
skip to any step after an elastic restart.

* ``LMStream``   — token sequences from a fixed random bigram chain: enough
  learnable structure that CE drops well below the uniform entropy, so
  optimizer comparisons (Fig. 4 / Table 4 analogues) are meaningful.
* ``AEStream``   — MNIST-like [0,1] images: smooth random low-rank blobs.
* ``ClassStream``— gaussian-blob classification.
"""
from __future__ import annotations

import dataclasses
from typing import Iterator, Optional

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass
class LMStream:
    vocab: int
    seq_len: int
    batch: int
    seed: int = 0
    concentration: float = 0.3   # lower = peakier bigrams = more learnable

    def __post_init__(self):
        rng = np.random.default_rng(self.seed)
        logits = rng.gumbel(size=(self.vocab, self.vocab)) / self.concentration
        self._probs = np.exp(logits - logits.max(-1, keepdims=True))
        self._probs /= self._probs.sum(-1, keepdims=True)
        self._cum = np.cumsum(self._probs, axis=-1)

    def batch_at(self, step: int) -> dict:
        rng = np.random.default_rng((self.seed, step))
        toks = np.empty((self.batch, self.seq_len + 1), np.int32)
        toks[:, 0] = rng.integers(0, self.vocab, self.batch)
        u = rng.random((self.batch, self.seq_len))
        # vectorized bigram sampling: invert the per-row CDF
        for t in range(self.seq_len):
            rows = self._cum[toks[:, t]]                   # (B, V)
            toks[:, t + 1] = (rows < u[:, t:t + 1]).sum(-1)
        return {'tokens': jnp.asarray(toks[:, :-1]),
                'labels': jnp.asarray(toks[:, 1:])}

    def __iter__(self) -> Iterator[dict]:
        step = 0
        while True:
            yield self.batch_at(step)
            step += 1

    @property
    def uniform_ce(self) -> float:
        return float(np.log(self.vocab))

    @property
    def bigram_ce(self) -> float:
        """Entropy of the generating chain — the achievable CE floor."""
        p = self._probs
        h = -(p * np.log(np.maximum(p, 1e-12))).sum(-1)
        return float(h.mean())


@dataclasses.dataclass
class AEStream:
    """Smooth blob images in [0,1], shape (batch, d) with d = side*side."""
    batch: int
    side: int = 28
    rank: int = 6
    seed: int = 0

    def batch_at(self, step: int) -> dict:
        rng = np.random.default_rng((self.seed, step))
        g = np.linspace(-1, 1, self.side)
        basis = np.stack([np.exp(-((g[:, None] - rng.uniform(-1, 1)) ** 2 +
                                   (g[None, :] - rng.uniform(-1, 1)) ** 2)
                                 / rng.uniform(0.05, 0.4))
                          for _ in range(self.rank)])
        w = rng.random((self.batch, self.rank)).astype(np.float32)
        img = np.einsum('br,rhw->bhw', w, basis)
        img = img / np.maximum(img.max(axis=(1, 2), keepdims=True), 1e-6)
        return {'x': jnp.asarray(img.reshape(self.batch, -1), jnp.float32)}

    def __iter__(self):
        step = 0
        while True:
            yield self.batch_at(step)
            step += 1


@dataclasses.dataclass
class ClassStream:
    """Gaussian blobs: (batch, dim) -> labels in [0, classes)."""
    batch: int
    dim: int = 64
    classes: int = 10
    seed: int = 0
    spread: float = 3.0

    def __post_init__(self):
        rng = np.random.default_rng(self.seed)
        self._centers = rng.normal(size=(self.classes, self.dim)) * self.spread

    def batch_at(self, step: int) -> dict:
        rng = np.random.default_rng((self.seed, step))
        y = rng.integers(0, self.classes, self.batch)
        x = self._centers[y] + rng.normal(size=(self.batch, self.dim))
        return {'x': jnp.asarray(x, jnp.float32), 'y': jnp.asarray(y, jnp.int32)}

    def __iter__(self):
        step = 0
        while True:
            yield self.batch_at(step)
            step += 1
