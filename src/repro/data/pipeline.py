"""Background prefetch: overlap host-side data generation with device steps.

``Prefetcher`` wraps any seekable stream (``batch_at(step)``) and keeps a
bounded queue filled from a worker thread — on a real pod this is where
per-host input pipelines (and their sharded ``jax.device_put``) live.
It remains seekable: ``seek(step)`` drains and restarts the worker, so
checkpoint-resume composes with prefetching.
"""
from __future__ import annotations

import queue
import threading
from typing import Any, Optional


class Prefetcher:
    def __init__(self, stream: Any, depth: int = 2, start_step: int = 0):
        self.stream = stream
        self.depth = depth
        self._q: queue.Queue = queue.Queue(maxsize=depth)
        self._stop = threading.Event()
        self._thread: Optional[threading.Thread] = None
        self._next_produce = start_step
        self._next_consume = start_step
        self._start()

    def _start(self):
        self._stop.clear()

        def worker():
            while not self._stop.is_set():
                step = self._next_produce
                batch = self.stream.batch_at(step)
                while not self._stop.is_set():
                    try:
                        self._q.put((step, batch), timeout=0.1)
                        self._next_produce = step + 1
                        break
                    except queue.Full:
                        continue

        self._thread = threading.Thread(target=worker, daemon=True)
        self._thread.start()

    def batch_at(self, step: int):
        """Seekable interface; sequential access is served from the queue."""
        if step != self._next_consume:
            self.seek(step)
        s, batch = self._q.get()
        assert s == step, (s, step)
        self._next_consume = step + 1
        return batch

    def seek(self, step: int):
        self._stop.set()
        if self._thread is not None:
            self._thread.join()
        while not self._q.empty():
            self._q.get_nowait()
        self._next_produce = step
        self._next_consume = step
        self._start()

    def close(self):
        self._stop.set()
        if self._thread is not None:
            self._thread.join()
