"""Memory-mapped token-file dataset (the production data path).

Format: ``<path>.bin`` is a flat little-endian token array; ``<path>.json``
holds {"dtype": "uint16"|"int32", "n_tokens": N}.  ``write_tokens`` creates
both.  ``MemmapLM`` yields fixed-length (tokens, labels) windows:

  * deterministic: window index = f(epoch_perm(seed), step, rank),
  * disjoint across data-parallel ranks (rank r of W takes every W-th
    window of a seeded per-epoch permutation),
  * seekable: ``batch_at(step)`` — resume/elastic-restart safe.  When the
    world size changes on restart, pass the *same* seed and the stream
    stays a permutation of the corpus (windows shift ranks, never repeat
    within an epoch).
"""
from __future__ import annotations

import dataclasses
import json
from pathlib import Path
from typing import Iterator

import jax.numpy as jnp
import numpy as np


def write_tokens(path: str | Path, tokens: np.ndarray) -> None:
    path = Path(path)
    tokens = np.asarray(tokens)
    assert tokens.ndim == 1
    dtype = 'uint16' if tokens.max() < 2 ** 16 else 'int32'
    tokens.astype(dtype).tofile(path.with_suffix('.bin'))
    path.with_suffix('.json').write_text(json.dumps(
        {'dtype': dtype, 'n_tokens': int(tokens.size)}))


@dataclasses.dataclass
class MemmapLM:
    path: str
    seq_len: int
    batch: int                      # per-rank batch
    rank: int = 0
    world: int = 1
    seed: int = 0

    def __post_init__(self):
        meta = json.loads(Path(self.path).with_suffix('.json').read_text())
        self._data = np.memmap(Path(self.path).with_suffix('.bin'),
                               dtype=meta['dtype'], mode='r')
        self.n_tokens = meta['n_tokens']
        self.n_windows = (self.n_tokens - 1) // self.seq_len
        if self.n_windows < self.batch * self.world:
            raise ValueError('corpus too small for batch × world')
        self._windows_per_epoch = self.n_windows - self.n_windows % (
            self.batch * self.world)

    def _epoch_perm(self, epoch: int) -> np.ndarray:
        rng = np.random.default_rng((self.seed, epoch))
        return rng.permutation(self.n_windows)[:self._windows_per_epoch]

    def batch_at(self, step: int) -> dict:
        steps_per_epoch = self._windows_per_epoch // (self.batch * self.world)
        epoch, within = divmod(step, steps_per_epoch)
        perm = self._epoch_perm(epoch)
        base = within * self.batch * self.world + self.rank
        idx = perm[base: base + self.batch * self.world: self.world]
        toks = np.stack([
            self._data[i * self.seq_len: i * self.seq_len + self.seq_len + 1]
            for i in idx]).astype(np.int32)
        return {'tokens': jnp.asarray(toks[:, :-1]),
                'labels': jnp.asarray(toks[:, 1:])}

    def __iter__(self) -> Iterator[dict]:
        step = 0
        while True:
            yield self.batch_at(step)
            step += 1
