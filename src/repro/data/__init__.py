from repro.data.memmap_loader import MemmapLM, write_tokens
from repro.data.pipeline import Prefetcher
from repro.data.synthetic import AEStream, ClassStream, LMStream

__all__ = ['MemmapLM', 'write_tokens', 'Prefetcher', 'AEStream',
           'ClassStream', 'LMStream']
