"""jamba-v0.1-52b [hybrid]: 32L d_model=4096 32H (GQA kv=8) d_ff=14336,
Mamba+attn 1:7 interleave (attention at layer i%8==4), MoE 16 experts top-2
every other layer.  Sub-quadratic -> runs long_500k.
Adaptation: mixer is our Mamba-2 SSD block (Jamba uses Mamba-1); d_state=16
per Jamba.  [arXiv:2403.19887; hf]"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name='jamba-v0.1-52b', family='hybrid',
    n_layers=32, d_model=4096, n_heads=32, n_kv_heads=8, d_ff=14336,
    vocab=65536,
    n_experts=16, top_k=2, norm_topk=True,
    attn_period=8, attn_offset=4, expert_period=2, expert_offset=1,
    ssm_state=16, ssm_headdim=64, ssm_expand=2, ssm_conv=4, ssm_chunk=256,
    sub_quadratic=True,
    param_dtype='bfloat16', compute_dtype='bfloat16', cache_dtype='bfloat16',
    remat='dots', attn_impl='flash', microbatches=4,
    source='arXiv:2403.19887; hf',
)

REDUCED = CONFIG.replace(
    n_layers=8, d_model=64, n_heads=4, n_kv_heads=2, d_ff=96, vocab=512,
    n_experts=4, top_k=2, ssm_state=16, ssm_headdim=16, ssm_chunk=8,
    param_dtype='float32', compute_dtype='float32', cache_dtype='float32',
    remat='none', attn_impl='naive')
