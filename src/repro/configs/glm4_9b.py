"""glm4-9b [dense]: 40L d_model=4096 32H (GQA kv=2) d_ff=13696,
vocab=151552, RoPE.  [hf:THUDM/glm-4-9b; hf]"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name='glm4-9b', family='dense',
    n_layers=40, d_model=4096, n_heads=32, n_kv_heads=2, d_ff=13696,
    vocab=151552,
    param_dtype='bfloat16', compute_dtype='bfloat16', cache_dtype='bfloat16',
    remat='dots', attn_impl='flash', microbatches=4,
    source='hf:THUDM/glm-4-9b; hf',
)

REDUCED = CONFIG.replace(
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_ff=160, vocab=512,
    param_dtype='float32', compute_dtype='float32', cache_dtype='float32',
    remat='none', attn_impl='naive')
