"""codeqwen1.5-7b [dense]: 32L d_model=4096 32H (MHA kv=32) d_ff=13440,
vocab=92416, QKV bias (qwen1.5 arch).  [hf:Qwen/CodeQwen1.5-7B; hf]"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name='codeqwen1.5-7b', family='dense',
    n_layers=32, d_model=4096, n_heads=32, n_kv_heads=32, d_ff=13440,
    vocab=92416, qkv_bias=True,
    rope_theta=1e6,
    param_dtype='bfloat16', compute_dtype='bfloat16', cache_dtype='bfloat16',
    remat='dots', attn_impl='flash', microbatches=4,
    source='hf:Qwen/CodeQwen1.5-7B; hf',
)

REDUCED = CONFIG.replace(
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=4, d_ff=160, vocab=512,
    param_dtype='float32', compute_dtype='float32', cache_dtype='float32',
    remat='none', attn_impl='naive')
