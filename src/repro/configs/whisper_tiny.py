"""whisper-tiny [audio]: enc-dec, conv frontend stubbed (frame embeddings).
4L enc + 4L dec, d_model=384, 6H (kv=6), d_ff=1536, vocab=51865.
[arXiv:2212.04356; unverified]"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name='whisper-tiny', family='encdec',
    n_layers=4, n_enc_layers=4, n_dec_layers=4,
    d_model=384, n_heads=6, n_kv_heads=6, d_ff=1536, vocab=51865,
    qkv_bias=True, norm='layer', dec_ratio=4,
    param_dtype='bfloat16', compute_dtype='bfloat16', cache_dtype='bfloat16',
    remat='dots', attn_impl='flash',
    source='arXiv:2212.04356; unverified',
)

REDUCED = CONFIG.replace(
    n_layers=2, n_enc_layers=2, n_dec_layers=2, d_model=64, n_heads=4,
    n_kv_heads=4, d_ff=128, vocab=512,
    param_dtype='float32', compute_dtype='float32', cache_dtype='float32',
    remat='none', attn_impl='naive')
