"""mamba2-780m [ssm]: 48L d_model=1536, attention-free, SSD state=128.
Sub-quadratic -> runs long_500k.  [arXiv:2405.21060; unverified]"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name='mamba2-780m', family='ssm',
    n_layers=48, d_model=1536, vocab=50280,
    ssm_state=128, ssm_headdim=64, ssm_expand=2, ssm_conv=4, ssm_chunk=256,
    tie_embeddings=True, sub_quadratic=True,
    param_dtype='bfloat16', compute_dtype='bfloat16', cache_dtype='bfloat16',
    remat='dots',
    source='arXiv:2405.21060; unverified',
)

REDUCED = CONFIG.replace(
    n_layers=2, d_model=64, vocab=512, ssm_state=16, ssm_headdim=16,
    ssm_chunk=8,
    param_dtype='float32', compute_dtype='float32', cache_dtype='float32',
    remat='none')
