"""Arch-config registry: ``get_config('<id>')`` / ``get_reduced('<id>')``.

The 10 assigned architectures (+ demo configs for examples/benchmarks).
"""
from __future__ import annotations

import importlib

from repro.configs.base import ArchConfig

_MODULES = {
    'whisper-tiny': 'repro.configs.whisper_tiny',
    'qwen3-moe-30b-a3b': 'repro.configs.qwen3_moe_30b_a3b',
    'kimi-k2-1t-a32b': 'repro.configs.kimi_k2_1t_a32b',
    'mamba2-780m': 'repro.configs.mamba2_780m',
    'qwen2-0.5b': 'repro.configs.qwen2_0_5b',
    'codeqwen1.5-7b': 'repro.configs.codeqwen1_5_7b',
    'glm4-9b': 'repro.configs.glm4_9b',
    'command-r-35b': 'repro.configs.command_r_35b',
    'llava-next-34b': 'repro.configs.llava_next_34b',
    'jamba-v0.1-52b': 'repro.configs.jamba_v0_1_52b',
}

ARCH_IDS = tuple(_MODULES)


def get_config(arch_id: str) -> ArchConfig:
    if arch_id not in _MODULES:
        raise KeyError(f'unknown arch {arch_id!r}; have {sorted(_MODULES)}')
    return importlib.import_module(_MODULES[arch_id]).CONFIG


def get_reduced(arch_id: str) -> ArchConfig:
    if arch_id not in _MODULES:
        raise KeyError(f'unknown arch {arch_id!r}; have {sorted(_MODULES)}')
    return importlib.import_module(_MODULES[arch_id]).REDUCED


# demo configs for examples / CPU end-to-end runs
def demo_lm(scale: str = 'small') -> ArchConfig:
    """Decoder-only demo LM.  'small' ~1.5M params trains in seconds on CPU;
    'base' ~10M; '100m' ~100M params (the end-to-end driver config)."""
    if scale == 'small':
        return ArchConfig(name='demo-small', family='dense', n_layers=2,
                          d_model=128, n_heads=4, n_kv_heads=2, d_ff=256,
                          vocab=512)
    if scale == 'base':
        return ArchConfig(name='demo-base', family='dense', n_layers=4,
                          d_model=256, n_heads=8, n_kv_heads=4, d_ff=1024,
                          vocab=2048)
    if scale == '100m':
        return ArchConfig(name='demo-100m', family='dense', n_layers=12,
                          d_model=768, n_heads=12, n_kv_heads=4, d_ff=2048,
                          vocab=32768, remat='dots')
    raise KeyError(scale)
