"""qwen3-moe-30b-a3b [moe]: 48L d_model=2048 32H (GQA kv=4) expert d_ff=768,
vocab=151936, MoE 128 experts top-8.  [hf:Qwen/Qwen3-30B-A3B; hf]"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name='qwen3-moe-30b-a3b', family='moe',
    n_layers=48, d_model=2048, n_heads=32, n_kv_heads=4, d_ff=768,
    vocab=151936, head_dim=128,
    n_experts=128, top_k=8, norm_topk=True,
    rope_theta=1e6,
    param_dtype='bfloat16', compute_dtype='bfloat16', cache_dtype='bfloat16',
    remat='dots', attn_impl='flash', microbatches=4,
    source='hf:Qwen/Qwen3-30B-A3B; hf',
)

REDUCED = CONFIG.replace(
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_ff=32, head_dim=16,
    vocab=512, n_experts=8, top_k=2,
    param_dtype='float32', compute_dtype='float32', cache_dtype='float32',
    remat='none', attn_impl='naive')
