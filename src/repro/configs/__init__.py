from repro.configs.base import (SHAPE_BY_NAME, SHAPES, ArchConfig, ShapeCell,
                                cell_skip_reason, cells_for)
from repro.configs.registry import ARCH_IDS, demo_lm, get_config, get_reduced
