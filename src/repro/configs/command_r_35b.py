"""command-r-35b [dense]: 40L d_model=8192 64H (GQA kv=8) d_ff=22528,
vocab=256000, no biases.  [hf:CohereForAI/c4ai-command-r-v01; unverified]"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name='command-r-35b', family='dense',
    n_layers=40, d_model=8192, n_heads=64, n_kv_heads=8, d_ff=22528,
    vocab=256000,
    rope_theta=8e6,
    param_dtype='bfloat16', compute_dtype='bfloat16', cache_dtype='bfloat16',
    remat='dots', attn_impl='flash', microbatches=4,
    source='hf:CohereForAI/c4ai-command-r-v01; unverified',
)

REDUCED = CONFIG.replace(
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_ff=160, vocab=512,
    param_dtype='float32', compute_dtype='float32', cache_dtype='float32',
    remat='none', attn_impl='naive')
