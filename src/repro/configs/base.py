"""Architecture + shape configuration.

One ``ArchConfig`` instance per assigned architecture (``repro/configs/<id>.py``),
plus reduced variants for CPU smoke tests (``.reduced()``).

``ShapeCell`` encodes the four assigned input shapes; ``cells_for(arch)``
yields the (arch × shape) grid with spec-mandated skips applied.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Optional

import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                      # dense | moe | ssm | hybrid | encdec | vlm
    n_layers: int
    d_model: int
    n_heads: int = 0                 # 0 for attention-free
    n_kv_heads: int = 0
    d_ff: int = 0
    vocab: int = 0
    head_dim: Optional[int] = None   # default d_model // n_heads

    # MoE
    n_experts: int = 0
    top_k: int = 0
    n_shared_experts: int = 0
    capacity_factor: float = 1.25
    norm_topk: bool = True
    moe_aux_coef: float = 1e-3

    # SSM
    ssm_state: int = 0
    ssm_headdim: int = 64
    ssm_expand: int = 2
    ssm_conv: int = 4
    ssm_chunk: int = 128

    # hybrid (jamba): layer i is attention iff i % attn_period == attn_offset,
    # MoE iff i % expert_period == expert_offset
    attn_period: int = 0
    attn_offset: int = 0
    expert_period: int = 0
    expert_offset: int = 0

    # enc-dec (whisper)
    n_enc_layers: int = 0
    n_dec_layers: int = 0
    dec_ratio: int = 4               # dec_len = seq_len // dec_ratio

    # flags
    qkv_bias: bool = False
    tie_embeddings: bool = False
    norm: str = 'rms'                # rms | layer
    rope_theta: float = 10000.0
    input_is_embeds: bool = False    # vlm / audio stub frontends
    sub_quadratic: bool = False      # eligible for long_500k

    # numerics / impl
    param_dtype: str = 'float32'
    compute_dtype: str = 'float32'
    cache_dtype: str = 'float32'
    attn_impl: str = 'naive'         # naive | chunked
    q_chunk: int = 512
    k_chunk: int = 1024
    remat: str = 'none'              # none | full | dots
    scan_unroll: int = 1
    microbatches: int = 1            # grad-accumulation splits of train_4k

    source: str = ''                 # provenance note

    def __post_init__(self):
        if self.n_heads and self.head_dim is None:
            object.__setattr__(self, 'head_dim', self.d_model // self.n_heads)

    @property
    def pdtype(self):
        return jnp.dtype(self.param_dtype)

    @property
    def cdtype(self):
        return jnp.dtype(self.compute_dtype)

    def replace(self, **kw) -> 'ArchConfig':
        return dataclasses.replace(self, **kw)


@dataclasses.dataclass(frozen=True)
class ShapeCell:
    name: str
    seq_len: int
    global_batch: int
    kind: str                        # train | prefill | decode


SHAPES = (
    ShapeCell('train_4k', 4096, 256, 'train'),
    ShapeCell('prefill_32k', 32768, 32, 'prefill'),
    ShapeCell('decode_32k', 32768, 128, 'decode'),
    ShapeCell('long_500k', 524288, 1, 'decode'),
)

SHAPE_BY_NAME = {s.name: s for s in SHAPES}


def cell_skip_reason(arch: ArchConfig, shape: ShapeCell) -> Optional[str]:
    """Spec-mandated skips; None = run the cell."""
    if shape.name == 'long_500k' and not arch.sub_quadratic:
        return ('full-attention arch: 524k context needs sub-quadratic '
                'attention (run only for SSM/hybrid per spec)')
    return None


def cells_for(arch: ArchConfig):
    for shape in SHAPES:
        yield shape, cell_skip_reason(arch, shape)
