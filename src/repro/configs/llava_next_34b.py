"""llava-next-34b [vlm]: 60L d_model=7168 56H (GQA kv=8) d_ff=20480,
vocab=64000.  Backbone only; the anyres vision tower is a STUB —
input_specs provides precomputed patch embeddings.
[hf:llava-hf/llava-v1.6-mistral-7b-hf; unverified]"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name='llava-next-34b', family='vlm',
    n_layers=60, d_model=7168, n_heads=56, n_kv_heads=8, d_ff=20480,
    vocab=64000, input_is_embeds=True,
    param_dtype='bfloat16', compute_dtype='bfloat16', cache_dtype='bfloat16',
    remat='dots', attn_impl='flash', microbatches=4,
    source='hf:llava-hf/llava-v1.6-mistral-7b-hf; unverified',
)

REDUCED = CONFIG.replace(
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_ff=160, vocab=512,
    param_dtype='float32', compute_dtype='float32', cache_dtype='float32',
    remat='none', attn_impl='naive')
