"""qwen2-0.5b [dense]: 24L d_model=896 14H (GQA kv=2) d_ff=4864,
vocab=151936, QKV bias, tied embeddings.  [arXiv:2407.10671; hf]"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name='qwen2-0.5b', family='dense',
    n_layers=24, d_model=896, n_heads=14, n_kv_heads=2, d_ff=4864,
    vocab=151936, qkv_bias=True, tie_embeddings=True,
    rope_theta=1e6,
    param_dtype='bfloat16', compute_dtype='bfloat16', cache_dtype='bfloat16',
    remat='dots', attn_impl='flash',
    source='arXiv:2407.10671; hf',
)

REDUCED = CONFIG.replace(
    n_layers=2, d_model=56, n_heads=7, n_kv_heads=1, d_ff=128, vocab=512,
    param_dtype='float32', compute_dtype='float32', cache_dtype='float32',
    remat='none', attn_impl='naive')
