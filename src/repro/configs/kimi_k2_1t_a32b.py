"""kimi-k2-1t-a32b [moe]: 61L d_model=7168 64H (GQA kv=8) expert d_ff=2048,
vocab=163840, MoE 384 experts top-8, 1 shared expert (DeepSeek-V3-family).
Trillion-parameter MoE.  [arXiv:2501.kimi2; unverified]"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name='kimi-k2-1t-a32b', family='moe',
    n_layers=61, d_model=7168, n_heads=64, n_kv_heads=8, d_ff=2048,
    vocab=163840, head_dim=112,
    n_experts=384, top_k=8, n_shared_experts=1, norm_topk=True,
    capacity_factor=1.0,
    rope_theta=5e4,
    param_dtype='bfloat16', compute_dtype='bfloat16', cache_dtype='bfloat16',
    remat='full', attn_impl='flash', microbatches=4,
    source='arXiv:2501.kimi2; unverified',
)

REDUCED = CONFIG.replace(
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_ff=32, head_dim=16,
    vocab=512, n_experts=8, top_k=2, n_shared_experts=1,
    param_dtype='float32', compute_dtype='float32', cache_dtype='float32',
    remat='none', attn_impl='naive')
