"""Per-call-site logical exchange-byte accounting.

Every exchange primitive in ``repro.comm.exchange`` records, at trace time,
the logical payload bytes ONE worker contributes to the collective per call
(wire bits × elements + scale side-channel).  Shapes are static under jit,
so the numbers are exact and cost nothing at run time; the trainer logs a
snapshot once the step is traced and ``benchmarks/roofline.py`` uses the
same counters for the §3.3 table.

"Logical" means payload bytes handed to the collective, before any
transport-level factor (ring all-reduce moves ~2× the payload; all-gather
receives W−1 peers' payloads) — the codec/mode win shows up identically in
either convention.
"""
from __future__ import annotations

import threading
from typing import Any, Optional

_LOCK = threading.Lock()
_SITES: dict[str, dict[str, Any]] = {}


def record(site: str, *, bytes_per_call: int, codec: str, mode: str,
           extra: Optional[dict] = None) -> None:
    """Record one call-site's per-call contributed bytes (trace time)."""
    with _LOCK:
        rec = _SITES.setdefault(site, {'traces': 0})
        rec['traces'] += 1
        rec['bytes_per_call'] = int(bytes_per_call)
        rec['codec'] = codec
        rec['mode'] = mode
        if extra:
            rec.update(extra)


def snapshot() -> dict[str, dict[str, Any]]:
    """{site: {bytes_per_call, codec, mode, traces, ...}} — copy, safe to
    mutate/serialize."""
    with _LOCK:
        return {k: dict(v) for k, v in _SITES.items()}


def reset() -> None:
    with _LOCK:
        _SITES.clear()


def leaf_elements(leaf) -> int:
    """Element count of an array / ShapeDtypeStruct / tracer."""
    n = 1
    for d in leaf.shape:
        n *= int(d)
    return n
