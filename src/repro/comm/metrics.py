"""Per-call-site logical exchange-byte accounting.

Every exchange primitive in ``repro.comm.exchange`` records, at trace time,
the logical payload bytes ONE worker contributes to the collective per call
(wire bits × elements + scale side-channel).  Shapes are static under jit,
so the numbers are exact and cost nothing at run time; the trainer logs a
snapshot once the step is traced and ``benchmarks/roofline.py`` uses the
same counters for the §3.3 table.

"Logical" means payload bytes handed to the collective, before any
transport-level factor (ring all-reduce moves ~2× the payload; all-gather
receives W−1 peers' payloads) — the codec/mode win shows up identically in
either convention.
"""
from __future__ import annotations

import contextlib
import threading
from typing import Any, Iterator, Optional

_LOCK = threading.Lock()
_SITES: dict[str, dict[str, Any]] = {}
_SCOPES: list['Scope'] = []


class Scope:
    """A run-scoped view of the counters (see ``scope()``).

    While active, every ``record()`` lands here *in addition to* the
    process-global table, so one trainer/benchmark can attribute sites to
    itself without resetting (and thus destroying) another run's records —
    this replaces the trainer's old trace-count-baselining workaround.
    """

    def __init__(self) -> None:
        self.sites: dict[str, dict[str, Any]] = {}

    def snapshot(self) -> dict[str, dict[str, Any]]:
        with _LOCK:
            return {k: dict(v) for k, v in self.sites.items()}


def push_scope() -> Scope:
    """Activate a new scope (caller must ``pop_scope`` it)."""
    s = Scope()
    with _LOCK:
        _SCOPES.append(s)
    return s


def pop_scope(s: Scope) -> None:
    with _LOCK:
        if s in _SCOPES:
            _SCOPES.remove(s)


@contextlib.contextmanager
def scope() -> Iterator[Scope]:
    """Context-managed run-scoped counter view: sites recorded (= traced)
    while the scope is active."""
    s = push_scope()
    try:
        yield s
    finally:
        pop_scope(s)


def record(site: str, *, bytes_per_call: int, codec: str, mode: str,
           extra: Optional[dict] = None) -> None:
    """Record one call-site's per-call contributed bytes (trace time)."""
    with _LOCK:
        for table in [_SITES] + [s.sites for s in _SCOPES]:
            rec = table.setdefault(site, {'traces': 0})
            rec['traces'] += 1
            rec['bytes_per_call'] = int(bytes_per_call)
            rec['codec'] = codec
            rec['mode'] = mode
            if extra:
                rec.update(extra)


def snapshot() -> dict[str, dict[str, Any]]:
    """{site: {bytes_per_call, codec, mode, traces, ...}} — copy, safe to
    mutate/serialize."""
    with _LOCK:
        return {k: dict(v) for k, v in _SITES.items()}


def reset() -> None:
    with _LOCK:
        _SITES.clear()


def leaf_elements(leaf) -> int:
    """Element count of an array / ShapeDtypeStruct / tracer."""
    n = 1
    for d in leaf.shape:
        n *= int(d)
    return n
