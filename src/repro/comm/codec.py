"""Pluggable pytree codecs for cross-device exchange.

Every cross-device exchange in this repo (gradient all-reduce, KV/KF
statistics reduction, owned-slice curvature refresh) moves f32 pytrees.
A :class:`Codec` is a pure encode/decode pair over single leaves that the
exchange primitives (``repro.comm.exchange``) lift to pytrees and wire into
the collectives — safe under ``jit`` and ``shard_map`` because every method
is a pure jax function of its inputs.

Three codecs ship:

* ``f32`` (alias ``identity``) — pass-through (the exact legacy wire
  format; reductions stay the historical ``lax.pmean``/``lax.psum`` ops so
  atol=0 contracts hold);
* ``bf16`` — truncate to bfloat16 on the wire, accumulate in f32 (2× less
  traffic; round-trips exactly where the value is bf16-representable;
  carries the truncation residual as error feedback on the gradient
  all-reduce, like int8);
* ``int8`` — symmetric max-scale int8 quantization (8× less traffic) with
  an optional carried error-feedback residual (Karimireddy et al.-style
  EF-SGD, used by the gradient all-reduce so convergence is intact) and a
  saturation-count diagnostic (elements that would exceed ±127 before
  clipping — zero by construction when the scale is derived from the true
  global max, nonzero only if a caller feeds a stale/underestimated max).

MKOR (PAPERS.md) is the precedent for Kronecker-factor state tolerating
reduced-precision communication; Eva §3.3 is the sublinear-traffic story
this layer generalizes.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional

import jax
import jax.numpy as jnp

# The int8 scale clamp: keeps all-zero (or denormal) tensors from dividing
# by zero; because the clamp only ever *raises* the scale above |x|max/127,
# it can never introduce saturation.
SCALE_FLOOR = 1e-12


@dataclasses.dataclass(frozen=True)
class Codec:
    """A leaf-wise wire format.

    Attributes:
      name: registry key ('f32' | 'bf16' | 'int8').
      wire_bits: logical payload bits per element on the wire (the byte
        accounting in ``repro.comm.metrics`` is derived from this).
      error_feedback: whether the exchange should carry the quantization
        residual between calls (gradient all-reduce); codecs without it
        leave the caller's residual tree untouched.
      passthrough: the encoded payload *is* the value — exchanges may keep
        their exact legacy reduction ops (bit-identity contracts).
      sum_dtype: accumulate psums of the payload in this dtype (int8 sums
        exactly in int32, like the historical ``quantize_allreduce``);
        None sums decoded f32 values.
    """

    name: str
    wire_bits: int
    error_feedback: bool = False
    passthrough: bool = False
    sum_dtype: Optional[Any] = None

    @property
    def has_scale(self) -> bool:
        return self.name == 'int8'

    # -- leaf ops (pure; shapes broadcast: amax/scale may be scalar or
    #    per-item keepdims) ---------------------------------------------------

    def encode(self, x: jnp.ndarray, amax: jnp.ndarray
               ) -> tuple[jnp.ndarray, Optional[jnp.ndarray], jnp.ndarray]:
        """``x (f32, residual already folded in) -> (payload, scale, n_sat)``.

        ``amax`` is max|x| over whatever scope the scale is shared across
        (globally pmax'd for all-reduce, per stack item for owned-slice
        gather).  ``n_sat`` counts elements whose quantized magnitude
        exceeded the representable range before clipping (f32 scalar).
        """
        if self.name == 'f32':
            return x, None, jnp.zeros((), jnp.float32)
        if self.name == 'bf16':
            return x.astype(jnp.bfloat16), None, jnp.zeros((), jnp.float32)
        scale = jnp.maximum(amax / 127.0, SCALE_FLOOR)
        r = jnp.round(x / scale)
        n_sat = jnp.sum(jnp.abs(r) > 127.0).astype(jnp.float32)
        q = jnp.clip(r, -127, 127).astype(jnp.int8)
        return q, scale, n_sat

    def decode(self, payload: jnp.ndarray,
               scale: Optional[jnp.ndarray]) -> jnp.ndarray:
        """Wire payload (or its exact integer sum) back to f32."""
        if self.name == 'int8':
            return payload.astype(jnp.float32) * scale
        return payload.astype(jnp.float32)

    def init_err(self, tree: Any) -> Optional[Any]:
        """Zero residual tree for error-feedback codecs, else None."""
        if not self.error_feedback:
            return None
        return jax.tree_util.tree_map(
            lambda x: jnp.zeros(jnp.shape(x), jnp.float32), tree)


F32 = Codec(name='f32', wire_bits=32, passthrough=True)
BF16 = Codec(name='bf16', wire_bits=16, error_feedback=True)
INT8_EF = Codec(name='int8', wire_bits=8, error_feedback=True,
                sum_dtype=jnp.int32)

CODECS: dict[str, Codec] = {c.name: c for c in (F32, BF16, INT8_EF)}
CODECS['identity'] = F32          # the ISSUE-facing name for pass-through


def get_codec(spec: Any) -> Codec:
    """Resolve a codec name or instance; ``None`` means pass-through f32."""
    if spec is None:
        return F32
    if isinstance(spec, Codec):
        return spec
    if spec not in CODECS:
        raise KeyError(f'unknown codec {spec!r}; have {sorted(CODECS)}')
    return CODECS[spec]
