"""Unified communication codec layer (Eva §3.3 distributed story).

One exchange path for gradients, KV/KF statistics, and owned-slice
curvature refresh: pluggable pytree codecs (``codec``), the collective
primitives that wire them into shard_map bodies (``exchange``), and
per-call-site logical byte accounting (``metrics``).
"""
from repro.comm import metrics
from repro.comm.codec import BF16, CODECS, F32, INT8_EF, Codec, get_codec
from repro.comm.exchange import (ExchangeConfig, InFlightMean,
                                 InFlightSlices, allgather_owned_slices,
                                 allreduce_mean_leaf, allreduce_mean_tree,
                                 collect_allgather_owned_slices,
                                 collect_allreduce_mean_tree, from_extras,
                                 issue_allgather_owned_slices,
                                 issue_allreduce_mean_tree,
                                 refresh_exchange_bytes, slice_stack_specs,
                                 tree_payload_bytes)

__all__ = [
    'BF16', 'CODECS', 'F32', 'INT8_EF', 'Codec', 'get_codec',
    'ExchangeConfig', 'InFlightMean', 'InFlightSlices',
    'allgather_owned_slices', 'allreduce_mean_leaf', 'allreduce_mean_tree',
    'collect_allgather_owned_slices', 'collect_allreduce_mean_tree',
    'from_extras', 'issue_allgather_owned_slices',
    'issue_allreduce_mean_tree', 'refresh_exchange_bytes',
    'slice_stack_specs', 'tree_payload_bytes', 'metrics',
]
