"""Codec-aware exchange primitives: the ONE place collectives happen.

Before this module the repo had three independent cross-device exchange
paths — the int8+EF gradient all-reduce in ``train/compression.py``, the
uncompressed KV/KF ``pmean_stats`` in ``sharding/constraints.py``, and the
full-stack zero-padded psum inverse exchange in
``schedule/runtime.sharded_refresh`` — each reimplementing quantize /
reduce / dequantize or padding logic.  Both generic primitives here are
pure, jit- and shard_map-safe, codec-pluggable (``repro.comm.codec``) and
account their logical traffic per call site (``repro.comm.metrics``):

* :func:`allreduce_mean_tree` — mean all-reduce of a pytree over the live
  data-parallel axes, optionally quantized with a carried error-feedback
  residual.  With the int8 codec it reproduces the historical
  ``quantize_allreduce`` op sequence exactly (global pmax scale, int32
  exact-sum, shared-scale dequant).

* :func:`allgather_owned_slices` — the owned-slice curvature-refresh
  exchange.  Each worker contributes only the stack rows it owns (a padded
  static-shape all-gather keyed off the deterministic
  ``ownership.assign_slice_owners`` map) instead of psum-ing the whole
  zero-padded stack, so per-worker refresh traffic scales ~1/W with world
  size.  With the f32 codec the reconstruction is bit-exact: every row is
  an exact copy of its owner's computed value — the same value the psum of
  zero-padded slices reconstructs.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.comm import metrics
from repro.comm.codec import Codec, get_codec
# safe at top level: constraints imports repro.comm only lazily (inside
# pmean_stats), so there is no import cycle
from repro.sharding.constraints import data_axes_in_scope


# ---------------------------------------------------------------------------
# Train-level exchange configuration (threaded through ``Extras.comm``)


@dataclasses.dataclass(frozen=True)
class ExchangeConfig:
    """Which codec each call-site family uses (static, not a pytree).

    Attributes:
      grads: gradient all-reduce codec (the explicit-DP engine; error
        feedback applies here).
      stats: KV/KF statistics reduction codec (``pmean_stats`` — consumed
        by the K-FAC/FOOF ``a_outer``/``b_outer`` reduction; 'f32' keeps
        the exact legacy ``lax.pmean``).
      codec: owned-slice curvature-refresh exchange codec
        ('identity'/'f32' | 'bf16' | 'int8').
      exchange: 'gather' (owned slices, ~1/W traffic, the default) or
        'psum' (the legacy full-stack zero-padded exchange, kept for A/B
        benchmarks and equivalence tests).
      topology: 'flat' treats the data-parallel axes as one world;
        'pod' keeps every bucket's slices inside ONE pod (ownership
        pod-local), gathers them over the intra-pod axis (ICI) and crosses
        the pod axis (DCN) once with the reconstructed bucket — only
        meaningful when both ('pod','data') axes are live, silently flat
        otherwise.
    """

    grads: Any = 'int8'
    stats: Any = 'f32'
    codec: Any = 'f32'
    exchange: str = 'gather'
    topology: str = 'flat'

    def __post_init__(self):
        if self.exchange not in ('gather', 'psum'):
            raise ValueError("exchange must be 'gather' or 'psum', "
                             f'got {self.exchange!r}')
        if self.topology not in ('flat', 'pod'):
            raise ValueError("topology must be 'flat' or 'pod', "
                             f'got {self.topology!r}')


_DEFAULT = ExchangeConfig()


def from_extras(extras) -> ExchangeConfig:
    """The exchange config threaded through ``Extras.comm`` (next to the
    bucket plan and the refresh runtime), or the default config."""
    cfg = getattr(extras, 'comm', None) if extras is not None else None
    return cfg if cfg is not None else _DEFAULT


# ---------------------------------------------------------------------------
# Axis helpers


def _axis_arg(axes: Sequence[str]):
    return tuple(axes) if len(axes) > 1 else axes[0]


def _all_gather(x: jnp.ndarray, axes: Sequence[str], world: int) -> jnp.ndarray:
    """Gather ``x`` from every worker: (world, *x.shape), leading index =
    the row-major rank over ``axes`` (matching ``ownership.world_and_rank``).
    Gathering the minor axis first makes the reshape row-major."""
    g = x
    for ax in reversed(tuple(axes)):
        g = jax.lax.all_gather(g, ax)
    return g.reshape((world,) + x.shape)


# ---------------------------------------------------------------------------
# Byte accounting (shapes are static under jit — exact and free at run time)


def leaf_payload_bytes(leaf, codec: Codec, scale_elems: int = 1) -> int:
    """Logical bytes one worker contributes for one leaf: payload +
    the f32 scale side-channel for scaled codecs."""
    n = metrics.leaf_elements(leaf)
    payload = (n * codec.wire_bits + 7) // 8
    return payload + (4 * scale_elems if codec.has_scale else 0)


def tree_payload_bytes(tree, codec: Codec, scale_elems: int = 1) -> int:
    return sum(leaf_payload_bytes(l, codec, scale_elems)
               for l in jax.tree_util.tree_leaves(tree))


# ---------------------------------------------------------------------------
# Mean all-reduce


def allreduce_mean_leaf(g: jnp.ndarray, err: Optional[jnp.ndarray], *,
                        codec: Any, axes: Sequence[str]
                        ) -> tuple[jnp.ndarray, Optional[jnp.ndarray],
                                   jnp.ndarray]:
    """Codec'd mean all-reduce of one leaf over ``axes``.

    Returns ``(mean, new_err, n_sat)``.  With the int8 codec this is the
    exact historical ``quantize_allreduce`` op sequence: fold in the
    residual, one scalar pmax for the shared scale, int8 quantize, exact
    int32-accumulate psum, shared-scale dequantize, divide by world size.
    Non-error-feedback codecs return ``err`` unchanged.  With no live axes
    the leaf still round-trips through the codec (a W=1 collective), so
    single-device behavior is the W=1 special case of the same path.
    """
    c = get_codec(codec)
    axes = tuple(axes)
    x = g.astype(jnp.float32)
    if c.error_feedback and err is not None:
        x = x + err
    if c.passthrough:
        mean = jax.lax.pmean(x, _axis_arg(axes)) if axes else x
        return mean, err, jnp.zeros((), jnp.float32)
    amax = None
    if c.has_scale:
        # only scaled codecs consume the max; bf16 must not pay the O(n)
        # reduction + blocking pmax it would then ignore
        amax = jnp.max(jnp.abs(x))
        if axes:
            amax = jax.lax.pmax(amax, _axis_arg(axes))
    payload, scale, n_sat = c.encode(x, amax)
    new_err = err
    if c.error_feedback:
        new_err = x - c.decode(payload, scale)
    if not axes:
        return c.decode(payload, scale), new_err, n_sat
    # divisor is a runtime psum-of-ones, NOT the trace-time axis-env probe
    # (compat.bound_axis_sizes): the probe is best-effort and a
    # false-negative there must not silently turn the mean into a
    # W×-too-large sum (the historical quantize_allreduce computed n
    # exactly this way)
    n = jax.lax.psum(jnp.ones((), jnp.float32), _axis_arg(axes))
    if c.sum_dtype is not None:
        total = jax.lax.psum(payload.astype(c.sum_dtype), _axis_arg(axes))
        mean = c.decode(total, scale) / n
    else:
        total = jax.lax.psum(c.decode(payload, scale), _axis_arg(axes))
        mean = total / n
    return mean, new_err, n_sat


def allreduce_mean_tree(tree: Any, err: Optional[Any] = None, *,
                        codec: Any = 'f32',
                        axes: Optional[Sequence[str]] = None,
                        site: Optional[str] = None
                        ) -> tuple[Any, Optional[Any], dict]:
    """Mean all-reduce of a pytree; see :func:`allreduce_mean_leaf`.

    Returns ``(mean_tree, new_err_tree, info)`` where ``info['saturation']``
    is the global fraction of saturated elements (psum'd over workers so
    any worker's overflow is visible everywhere; 0.0 by construction when
    the scale comes from the true global max).
    """
    c = get_codec(codec)
    if axes is None:
        axes = data_axes_in_scope()
    axes = tuple(axes)
    zero = jnp.zeros((), jnp.float32)
    if tree is None:
        return None, err, {'saturation': zero}
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    err_leaves = (jax.tree_util.tree_leaves(err) if err is not None
                  else [None] * len(leaves))
    means, new_errs, sat, elems = [], [], zero, 0
    for g, e in zip(leaves, err_leaves):
        m, ne, ns = allreduce_mean_leaf(g, e, codec=c, axes=axes)
        means.append(m)
        new_errs.append(ne)
        sat = sat + ns
        elems += metrics.leaf_elements(g)
    if axes:
        # both the saturation count and the worker count come from runtime
        # psums — NOT the best-effort axis-env probe, whose false-negative
        # would inflate the reported fraction W×
        sat = jax.lax.psum(sat, _axis_arg(axes))
        n_workers = jax.lax.psum(jnp.ones((), jnp.float32), _axis_arg(axes))
    else:
        n_workers = jnp.ones((), jnp.float32)
    sat_frac = sat / (max(elems, 1) * n_workers)
    if site is not None:
        metrics.record(site, bytes_per_call=tree_payload_bytes(leaves, c),
                       codec=c.name, mode='allreduce')
    new_err = (jax.tree_util.tree_unflatten(treedef, new_errs)
               if err is not None else None)
    return (jax.tree_util.tree_unflatten(treedef, means), new_err,
            {'saturation': sat_frac})


# ---------------------------------------------------------------------------
# Owned-slice refresh exchange


@functools.lru_cache(maxsize=1024)
def _gather_maps(owner: tuple, world: int) -> tuple:
    """Static index maps for one bucket's owned-slice exchange.

    Returns ``(send_idx (world, M), src_idx (N,), M)``: worker ``w`` sends
    the stack rows ``send_idx[w]`` (its owned items, padded by repetition
    to the max per-worker count M so the all-gather is static-shape), and
    row ``i`` of the full stack is recovered from flat gather position
    ``src_idx[i] = owner_i * M + rank_of_i_within_owner``.
    """
    n = len(owner)
    mine = {w: [i for i in range(n) if owner[i] == w] for w in range(world)}
    m = max(1, max(len(v) for v in mine.values()))
    send = np.zeros((world, m), np.int32)
    for w in range(world):
        for j in range(m):
            send[w, j] = mine[w][j % len(mine[w])] if mine[w] else 0
    src = np.zeros(n, np.int32)
    for w in range(world):
        for j, i in enumerate(mine[w]):
            src[i] = w * m + j
    return send, src, m


def owned_slice_bytes(stack_tree: Any, owner, world: int,
                      codec: Codec) -> int:
    """Logical bytes one worker contributes to the owned-slice all-gather
    of one bucket's stacked tree (leaves shaped (N, ...)): only its padded
    M owned rows travel, plus a per-row f32 scale for scaled codecs."""
    _, _, m = _gather_maps(tuple(int(w) for w in owner), world)
    total = 0
    for leaf in jax.tree_util.tree_leaves(stack_tree):
        n_items = int(leaf.shape[0])
        per_row = metrics.leaf_elements(leaf) // max(n_items, 1)
        total += (m * per_row * codec.wire_bits + 7) // 8
        if codec.has_scale:
            total += 4 * m
    return total


def allgather_owned_slices(plan, owners: dict, world: int, rank,
                           stacks: dict, *, codec: Any = 'f32',
                           axes: Optional[Sequence[str]] = None,
                           site: Optional[str] = None,
                           pods: Optional[tuple[int, int]] = None) -> dict:
    """Reconstruct full bucket stacks from per-owner slices.

    Args:
      plan: the ``BucketPlan`` whose stacked values are being exchanged.
      owners: ``{bucket_key: (N,) owner ranks}`` from
        ``ownership.assign_slice_owners`` (or ``assign_pod_slice_owners``
        with ``pods=``) — static numpy, deterministic on every host, which
        is what makes the index maps SPMD-consistent; N must match the
        stacks' leading axis.
      world / rank: from ``ownership.world_and_rank`` (world static, rank a
        traced scalar).
      stacks: ``{bucket_key: pytree of (N, *item) arrays}`` where each
        worker holds real values at its owned rows (anything elsewhere —
        the cond-gated zeros are never read).
      codec: wire format; int8 uses one symmetric max-scale per stack row
        (each row has exactly one producer, so no global pmax is needed).
      pods: ``(n_pods, per_pod)`` for the topology-aware two-stage
        exchange: ``owners`` must be pod-local
        (``ownership.assign_pod_slice_owners``) and ``axes`` must be the
        ('pod', intra-pod) pair.  The slice gather then runs over the
        intra-pod axis only (ICI); the owning pod's reconstructed bucket
        crosses the pod axis (DCN) once as a zero-padded psum (exact, like
        the legacy exchange — but coarse-grained and pod-axis-only).

    Returns stacks of identical structure with every row holding its
    owner's value on every worker.
    """
    c = get_codec(codec)
    if axes is None:
        axes = data_axes_in_scope()
    axes = tuple(axes)
    two_stage = (pods is not None and len(axes) == 2 and pods[0] > 1
                 and pods[0] * pods[1] == world)
    out = {}
    nbytes = ici = dcn = 0
    for b in plan.buckets:
        owner = tuple(int(w) for w in owners[b.key])
        if two_stage:
            n_pods, per_pod = pods
            bucket_pod = owner[0] // per_pod
            assert all(w // per_pod == bucket_pod for w in owner), \
                f'bucket {b.key}: owners {owner} span pods (need pod-local)'
            send_np, src_np, _ = _gather_maps(
                tuple(w - bucket_pod * per_pod for w in owner), per_pod)
            rows = jnp.take(jnp.asarray(send_np), rank % per_pod, axis=0)
        else:
            send_np, src_np, _ = _gather_maps(owner, world)
            rows = jnp.take(jnp.asarray(send_np), rank, axis=0)   # (M,)
        src = jnp.asarray(src_np)                                 # (N,)

        def leaf(x, rows=rows, src=src, owner=owner):
            local = jnp.take(x, rows, axis=0).astype(jnp.float32)
            red = tuple(range(1, local.ndim))
            amax = jnp.max(jnp.abs(local), axis=red, keepdims=True) \
                if red else jnp.abs(local)
            payload, scale, _ = c.encode(local, amax)
            if two_stage:
                n_pods, per_pod = pods
                g_p = _all_gather(payload, axes[1:], per_pod)
                g_s = (_all_gather(scale, axes[1:], per_pod)
                       if scale is not None else None)
                vals = c.decode(g_p, g_s)
                flat = vals.reshape((per_pod * local.shape[0],) + x.shape[1:])
                recon = jnp.take(flat, src, axis=0)
                # stage 2: only the owning pod's reconstruction is real;
                # zero elsewhere and psum over the pod axis (x+0 exact)
                my_pod = rank // per_pod
                recon = jnp.where(my_pod == owner[0] // per_pod, recon,
                                  jnp.zeros_like(recon))
                return jax.lax.psum(recon, axes[0]).astype(x.dtype)
            g_p = _all_gather(payload, axes, world)               # (W, M, ...)
            g_s = _all_gather(scale, axes, world) if scale is not None else None
            vals = c.decode(g_p, g_s)
            flat = vals.reshape((world * local.shape[0],) + x.shape[1:])
            return jnp.take(flat, src, axis=0).astype(x.dtype)

        out[b.key] = jax.tree_util.tree_map(leaf, stacks[b.key])
        if two_stage:
            local_owner = np.asarray(owner) % pods[1]
            ici += owned_slice_bytes(stacks[b.key], local_owner, pods[1], c)
            # the pod-axis psum carries the full reconstructed bucket in f32
            dcn += sum(4 * metrics.leaf_elements(l)
                       for l in jax.tree_util.tree_leaves(stacks[b.key]))
        else:
            nbytes += owned_slice_bytes(stacks[b.key], owners[b.key], world, c)
    if site is not None:
        if two_stage:
            metrics.record(site, bytes_per_call=ici + dcn, codec=c.name,
                           mode='gather-pod',
                           extra={'world': world, 'pods': list(pods),
                                  'ici_bytes': ici, 'dcn_bytes': dcn})
        else:
            metrics.record(site, bytes_per_call=nbytes, codec=c.name,
                           mode='gather', extra={'world': world})
    return out


def refresh_exchange_bytes(plan, owners: dict, stacks: Any, world: int, *,
                           codec: Any = 'f32', mode: str = 'gather') -> int:
    """Logical per-worker bytes of ONE refresh exchange — the accounting
    the runtime records, callable on ShapeDtypeStructs (roofline §3.3).

    'psum' contributes the whole zero-padded stack at f32 regardless of
    codec (the legacy exchange is uncompressed); 'gather' contributes
    only the padded owned rows under ``codec``.
    """
    if mode == 'psum':
        return sum(4 * metrics.leaf_elements(l)
                   for k in stacks
                   for l in jax.tree_util.tree_leaves(stacks[k]))
    c = get_codec(codec)
    return sum(owned_slice_bytes(stacks[b.key], owners[b.key], world, c)
               for b in plan.buckets)


def slice_stack_specs(plan, sides: str = 'both') -> dict:
    """ShapeDtypeStruct stacks mirroring what ``sharded_refresh`` exchanges
    for a dense-factor method: per bucket a (N·lead, d_in, d_in) cached
    inverse (plus the (N·lead, d_out, d_out) pair for ``sides='both'``) in
    f32.  This encodes the runtime's slice-flattening convention (stack ×
    leading scan/expert dims → one slice axis) in ONE place for the
    byte-accounting callers (roofline §3.3, ``table5 --refresh-sharding``,
    tests) — change it here when the layout in
    ``schedule/runtime.recompute_sharded`` changes.
    """
    # lazy: repro.schedule's package __init__ imports this module, so a
    # top-level import here would be circular
    from repro.schedule.ownership import lead_size

    if sides not in ('left', 'both'):
        raise ValueError(f"sides must be 'left' or 'both', got {sides!r}")
    out = {}
    for b in plan.buckets:
        s = len(b.paths) * lead_size(b)
        d_in, d_out = b.shape[-2], b.shape[-1]
        specs = (jax.ShapeDtypeStruct((s, d_in, d_in), jnp.float32),)
        if sides == 'both':
            specs += (jax.ShapeDtypeStruct((s, d_out, d_out), jnp.float32),)
        out[b.key] = specs
    return out
