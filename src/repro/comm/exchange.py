"""Codec-aware exchange primitives: the ONE place collectives happen.

Before this module the repo had three independent cross-device exchange
paths — the int8+EF gradient all-reduce in ``train/compression.py``, the
uncompressed KV/KF ``pmean_stats`` in ``sharding/constraints.py``, and the
full-stack zero-padded psum inverse exchange in
``schedule/runtime.sharded_refresh`` — each reimplementing quantize /
reduce / dequantize or padding logic.  Both generic primitives here are
pure, jit- and shard_map-safe, codec-pluggable (``repro.comm.codec``) and
account their logical traffic per call site (``repro.comm.metrics``):

* :func:`allreduce_mean_tree` — mean all-reduce of a pytree over the live
  data-parallel axes, optionally quantized with a carried error-feedback
  residual.  With the int8 codec it reproduces the historical
  ``quantize_allreduce`` op sequence exactly (global pmax scale, int32
  exact-sum, shared-scale dequant).

* :func:`allgather_owned_slices` — the owned-slice curvature-refresh
  exchange.  Each worker contributes only the stack rows it owns (a padded
  static-shape all-gather keyed off the deterministic
  ``ownership.assign_slice_owners`` map) instead of psum-ing the whole
  zero-padded stack, so per-worker refresh traffic scales ~1/W with world
  size.  With the f32 codec the reconstruction is bit-exact: every row is
  an exact copy of its owner's computed value — the same value the psum of
  zero-padded slices reconstructs.

Both primitives are split into an ``issue_*`` half (encode + every
collective + byte accounting) and a ``collect_*`` half (decode / divide /
reconstruct — pure local math).  The synchronous names compose the halves
back bit-exactly; the one-step pipeline (``repro.schedule.pipeline``)
issues at step *t* and applies at *t+1* so the collectives can overlap
compute.  One exception: the pod two-stage gather's final pod-axis psum
consumes the reconstruction, so its issue half carries the exchange to
completion and collect is the identity.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, NamedTuple, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.comm import metrics
from repro.comm.codec import Codec, get_codec
# safe at top level: constraints imports repro.comm only lazily (inside
# pmean_stats), so there is no import cycle
from repro.sharding.constraints import data_axes_in_scope


# ---------------------------------------------------------------------------
# Train-level exchange configuration (threaded through ``Extras.comm``)


@dataclasses.dataclass(frozen=True)
class ExchangeConfig:
    """Which codec each call-site family uses (static, not a pytree).

    Attributes:
      grads: gradient all-reduce codec (the explicit-DP engine; error
        feedback applies here).
      stats: KV/KF statistics reduction codec (``pmean_stats`` — consumed
        by the K-FAC/FOOF ``a_outer``/``b_outer`` reduction; 'f32' keeps
        the exact legacy ``lax.pmean``).
      codec: owned-slice curvature-refresh exchange codec
        ('identity'/'f32' | 'bf16' | 'int8').
      exchange: 'gather' (owned slices, ~1/W traffic, the default) or
        'psum' (the legacy full-stack zero-padded exchange, kept for A/B
        benchmarks and equivalence tests).
      topology: 'flat' treats the data-parallel axes as one world;
        'pod' keeps every bucket's slices inside ONE pod (ownership
        pod-local), gathers them over the intra-pod axis (ICI) and crosses
        the pod axis (DCN) once with the reconstructed bucket — only
        meaningful when both ('pod','data') axes are live, silently flat
        otherwise.
    """

    grads: Any = 'int8'
    stats: Any = 'f32'
    codec: Any = 'f32'
    exchange: str = 'gather'
    topology: str = 'flat'

    def __post_init__(self):
        if self.exchange not in ('gather', 'psum'):
            raise ValueError("exchange must be 'gather' or 'psum', "
                             f'got {self.exchange!r}')
        if self.topology not in ('flat', 'pod'):
            raise ValueError("topology must be 'flat' or 'pod', "
                             f'got {self.topology!r}')


_DEFAULT = ExchangeConfig()


def from_extras(extras) -> ExchangeConfig:
    """The exchange config threaded through ``Extras.comm`` (next to the
    bucket plan and the refresh runtime), or the default config."""
    cfg = getattr(extras, 'comm', None) if extras is not None else None
    return cfg if cfg is not None else _DEFAULT


# ---------------------------------------------------------------------------
# Axis helpers


def _axis_arg(axes: Sequence[str]):
    return tuple(axes) if len(axes) > 1 else axes[0]


def _all_gather(x: jnp.ndarray, axes: Sequence[str], world: int) -> jnp.ndarray:
    """Gather ``x`` from every worker: (world, *x.shape), leading index =
    the row-major rank over ``axes`` (matching ``ownership.world_and_rank``).
    Gathering the minor axis first makes the reshape row-major."""
    g = x
    for ax in reversed(tuple(axes)):
        g = jax.lax.all_gather(g, ax)
    return g.reshape((world,) + x.shape)


# ---------------------------------------------------------------------------
# Byte accounting (shapes are static under jit — exact and free at run time)


def leaf_payload_bytes(leaf, codec: Codec, scale_elems: int = 1) -> int:
    """Logical bytes one worker contributes for one leaf: payload +
    the f32 scale side-channel for scaled codecs."""
    n = metrics.leaf_elements(leaf)
    payload = (n * codec.wire_bits + 7) // 8
    return payload + (4 * scale_elems if codec.has_scale else 0)


def tree_payload_bytes(tree, codec: Codec, scale_elems: int = 1) -> int:
    return sum(leaf_payload_bytes(l, codec, scale_elems)
               for l in jax.tree_util.tree_leaves(tree))


# ---------------------------------------------------------------------------
# Mean all-reduce — split into an issue half (encode + every collective)
# and a collect half (decode + divide: pure local math).  The synchronous
# entry points compose collect(issue(...)) and are op-for-op the sequence
# they always were; the one-step pipeline (schedule/pipeline.py) keeps the
# same split but feeds the collected value to the NEXT step, so the
# collectives issued here never enter the current step's compute cone.


def _mean_divisor(c: Codec, axes: Sequence[str]):
    """The divisor the collect half applies.  Passthrough codecs divide by
    the trace-time axis size (exactly what ``lax.pmean`` does internally:
    ``psum`` of a non-traced 1 folds to the axis size with no collective);
    lossy codecs keep the historical runtime psum-of-ones — NOT the
    best-effort axis-env probe, whose false-negative must not silently turn
    the mean into a W×-too-large sum."""
    if not axes:
        return None
    if c.passthrough:
        return jax.lax.psum(1, _axis_arg(axes))
    return jax.lax.psum(jnp.ones((), jnp.float32), _axis_arg(axes))


def _issue_mean_leaf(g: jnp.ndarray, err: Optional[jnp.ndarray], *,
                     c: Codec, axes: tuple):
    """Collective half for one leaf: fold the EF residual, encode, fire the
    pmax/psum.  Returns ``(payload, scale, new_err, n_sat)`` where
    ``payload`` is the psum'd wire total (or the local encode when no axes
    are live) and ``scale`` survives only when collect still needs it."""
    x = g.astype(jnp.float32)
    if c.error_feedback and err is not None:
        x = x + err
    if c.passthrough:
        p = jax.lax.psum(x, _axis_arg(axes)) if axes else x
        return p, None, err, jnp.zeros((), jnp.float32)
    amax = None
    if c.has_scale:
        # only scaled codecs consume the max; bf16 must not pay the O(n)
        # reduction + blocking pmax it would then ignore
        amax = jnp.max(jnp.abs(x))
        if axes:
            amax = jax.lax.pmax(amax, _axis_arg(axes))
    payload, scale, n_sat = c.encode(x, amax)
    new_err = err
    if c.error_feedback:
        new_err = x - c.decode(payload, scale)
    if not axes:
        return payload, scale, new_err, n_sat
    if c.sum_dtype is not None:
        total = jax.lax.psum(payload.astype(c.sum_dtype), _axis_arg(axes))
        return total, scale, new_err, n_sat
    # no exact-sum wire dtype: decode locally, psum the decoded values
    total = jax.lax.psum(c.decode(payload, scale), _axis_arg(axes))
    return total, None, new_err, n_sat


def _collect_mean_leaf(payload, scale, n, *, c: Codec, axes: tuple):
    """Local finishing math for one leaf: decode and/or divide."""
    if c.passthrough:
        return payload / n if axes else payload
    if not axes:
        return c.decode(payload, scale)
    if c.sum_dtype is not None:
        return c.decode(payload, scale) / n
    return payload / n


def allreduce_mean_leaf(g: jnp.ndarray, err: Optional[jnp.ndarray], *,
                        codec: Any, axes: Sequence[str]
                        ) -> tuple[jnp.ndarray, Optional[jnp.ndarray],
                                   jnp.ndarray]:
    """Codec'd mean all-reduce of one leaf over ``axes``.

    Returns ``(mean, new_err, n_sat)``.  With the int8 codec this is the
    exact historical ``quantize_allreduce`` op sequence: fold in the
    residual, one scalar pmax for the shared scale, int8 quantize, exact
    int32-accumulate psum, shared-scale dequantize, divide by world size.
    Non-error-feedback codecs return ``err`` unchanged.  With no live axes
    the leaf still round-trips through the codec (a W=1 collective), so
    single-device behavior is the W=1 special case of the same path.
    """
    c = get_codec(codec)
    axes = tuple(axes)
    payload, scale, new_err, n_sat = _issue_mean_leaf(g, err, c=c, axes=axes)
    n = _mean_divisor(c, axes)
    return _collect_mean_leaf(payload, scale, n, c=c, axes=axes), new_err, n_sat


class InFlightMean(NamedTuple):
    """An issued-but-not-collected mean all-reduce.  Lives inside one trace
    (it is never checkpointed — the pipeline stores the *collected* tree);
    ``collect_allreduce_mean_tree`` turns it into the final mean with local
    math only."""
    payloads: Optional[list]
    scales: Optional[list]
    n: Any
    new_err: Any
    info: dict
    treedef: Any
    codec: Codec
    axes: tuple


def issue_allreduce_mean_tree(tree: Any, err: Optional[Any] = None, *,
                              codec: Any = 'f32',
                              axes: Optional[Sequence[str]] = None,
                              site: Optional[str] = None) -> InFlightMean:
    """Collective half of :func:`allreduce_mean_tree`: every pmax/psum (and
    the byte accounting, and the EF residual update) happens here; decode +
    divide wait for :func:`collect_allreduce_mean_tree`."""
    c = get_codec(codec)
    if axes is None:
        axes = data_axes_in_scope()
    axes = tuple(axes)
    zero = jnp.zeros((), jnp.float32)
    if tree is None:
        return InFlightMean(None, None, None, err, {'saturation': zero},
                            None, c, axes)
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    err_leaves = (jax.tree_util.tree_leaves(err) if err is not None
                  else [None] * len(leaves))
    payloads, scales, new_errs, sat, elems = [], [], [], zero, 0
    for g, e in zip(leaves, err_leaves):
        p, s, ne, ns = _issue_mean_leaf(g, e, c=c, axes=axes)
        payloads.append(p)
        scales.append(s)
        new_errs.append(ne)
        sat = sat + ns
        elems += metrics.leaf_elements(g)
    if axes:
        # both the saturation count and the worker count come from runtime
        # psums — NOT the best-effort axis-env probe, whose false-negative
        # would inflate the reported fraction W×
        sat = jax.lax.psum(sat, _axis_arg(axes))
        n_workers = jax.lax.psum(jnp.ones((), jnp.float32), _axis_arg(axes))
    else:
        n_workers = jnp.ones((), jnp.float32)
    sat_frac = sat / (max(elems, 1) * n_workers)
    if site is not None:
        metrics.record(site, bytes_per_call=tree_payload_bytes(leaves, c),
                       codec=c.name, mode='allreduce')
    new_err = (jax.tree_util.tree_unflatten(treedef, new_errs)
               if err is not None else None)
    return InFlightMean(payloads, scales, _mean_divisor(c, axes), new_err,
                        {'saturation': sat_frac}, treedef, c, axes)


def collect_allreduce_mean_tree(fl: InFlightMean
                                ) -> tuple[Any, Optional[Any], dict]:
    """Local finishing half: decode + divide the in-flight totals.  Returns
    the same ``(mean_tree, new_err_tree, info)`` as the composed call."""
    if fl.treedef is None:
        return None, fl.new_err, fl.info
    means = [_collect_mean_leaf(p, s, fl.n, c=fl.codec, axes=fl.axes)
             for p, s in zip(fl.payloads, fl.scales)]
    return (jax.tree_util.tree_unflatten(fl.treedef, means), fl.new_err,
            fl.info)


def allreduce_mean_tree(tree: Any, err: Optional[Any] = None, *,
                        codec: Any = 'f32',
                        axes: Optional[Sequence[str]] = None,
                        site: Optional[str] = None
                        ) -> tuple[Any, Optional[Any], dict]:
    """Mean all-reduce of a pytree; see :func:`allreduce_mean_leaf`.

    Returns ``(mean_tree, new_err_tree, info)`` where ``info['saturation']``
    is the global fraction of saturated elements (psum'd over workers so
    any worker's overflow is visible everywhere; 0.0 by construction when
    the scale comes from the true global max).

    Composes the staged halves synchronously — the issue/collect split is
    value-preserving (collect is decode + divide on the identical psum'd
    totals), so this stays bit-exact with the pre-split implementation.
    """
    return collect_allreduce_mean_tree(issue_allreduce_mean_tree(
        tree, err, codec=codec, axes=axes, site=site))


# ---------------------------------------------------------------------------
# Owned-slice refresh exchange


@functools.lru_cache(maxsize=1024)
def _gather_maps(owner: tuple, world: int) -> tuple:
    """Static index maps for one bucket's owned-slice exchange.

    Returns ``(send_idx (world, M), src_idx (N,), M)``: worker ``w`` sends
    the stack rows ``send_idx[w]`` (its owned items, padded by repetition
    to the max per-worker count M so the all-gather is static-shape), and
    row ``i`` of the full stack is recovered from flat gather position
    ``src_idx[i] = owner_i * M + rank_of_i_within_owner``.
    """
    n = len(owner)
    mine = {w: [i for i in range(n) if owner[i] == w] for w in range(world)}
    m = max(1, max(len(v) for v in mine.values()))
    send = np.zeros((world, m), np.int32)
    for w in range(world):
        for j in range(m):
            send[w, j] = mine[w][j % len(mine[w])] if mine[w] else 0
    src = np.zeros(n, np.int32)
    for w in range(world):
        for j, i in enumerate(mine[w]):
            src[i] = w * m + j
    return send, src, m


def owned_slice_bytes(stack_tree: Any, owner, world: int,
                      codec: Codec) -> int:
    """Logical bytes one worker contributes to the owned-slice all-gather
    of one bucket's stacked tree (leaves shaped (N, ...)): only its padded
    M owned rows travel, plus a per-row f32 scale for scaled codecs."""
    _, _, m = _gather_maps(tuple(int(w) for w in owner), world)
    total = 0
    for leaf in jax.tree_util.tree_leaves(stack_tree):
        n_items = int(leaf.shape[0])
        per_row = metrics.leaf_elements(leaf) // max(n_items, 1)
        total += (m * per_row * codec.wire_bits + 7) // 8
        if codec.has_scale:
            total += 4 * m
    return total


class _GatheredLeaf(NamedTuple):
    """One leaf's in-flight owned-slice gather: the wire payload (and scale)
    as gathered from every worker, plus the static reconstruction recipe.
    ``collect_allgather_owned_slices`` finishes with local math only."""
    payload: Any       # (world, M, *item) gathered wire values
    scale: Any         # (world, M, 1…) per-row scales, or None
    src: Any           # (N,) flat gather position of each stack row
    out_dtype: Any


class InFlightSlices(NamedTuple):
    """An issued-but-not-collected owned-slice exchange.  ``done=True``
    marks the pod two-stage path, whose final pod-axis psum *consumes* the
    reconstruction — there the issue half carries the exchange to
    completion and collect is the identity."""
    stacks: dict       # {bucket_key: tree of _GatheredLeaf} (or final stacks)
    done: bool
    codec: Codec


def issue_allgather_owned_slices(plan, owners: dict, world: int, rank,
                                 stacks: dict, *, codec: Any = 'f32',
                                 axes: Optional[Sequence[str]] = None,
                                 site: Optional[str] = None,
                                 pods: Optional[tuple[int, int]] = None
                                 ) -> InFlightSlices:
    """Collective half of :func:`allgather_owned_slices`: take the owned
    rows, encode, all-gather payload + scales (and record bytes).  The
    decode / reshape / reconstruction take are deferred to
    :func:`collect_allgather_owned_slices` — pure local math, so a pipelined
    caller keeps the gather itself out of the consuming compute's cone."""
    c = get_codec(codec)
    if axes is None:
        axes = data_axes_in_scope()
    axes = tuple(axes)
    two_stage = (pods is not None and len(axes) == 2 and pods[0] > 1
                 and pods[0] * pods[1] == world)
    out = {}
    nbytes = ici = dcn = 0
    for b in plan.buckets:
        owner = tuple(int(w) for w in owners[b.key])
        if two_stage:
            n_pods, per_pod = pods
            bucket_pod = owner[0] // per_pod
            assert all(w // per_pod == bucket_pod for w in owner), \
                f'bucket {b.key}: owners {owner} span pods (need pod-local)'
            send_np, src_np, _ = _gather_maps(
                tuple(w - bucket_pod * per_pod for w in owner), per_pod)
            rows = jnp.take(jnp.asarray(send_np), rank % per_pod, axis=0)
        else:
            send_np, src_np, _ = _gather_maps(owner, world)
            rows = jnp.take(jnp.asarray(send_np), rank, axis=0)   # (M,)
        src = jnp.asarray(src_np)                                 # (N,)

        def leaf(x, rows=rows, src=src, owner=owner):
            local = jnp.take(x, rows, axis=0).astype(jnp.float32)
            red = tuple(range(1, local.ndim))
            amax = jnp.max(jnp.abs(local), axis=red, keepdims=True) \
                if red else jnp.abs(local)
            payload, scale, _ = c.encode(local, amax)
            if two_stage:
                n_pods, per_pod = pods
                g_p = _all_gather(payload, axes[1:], per_pod)
                g_s = (_all_gather(scale, axes[1:], per_pod)
                       if scale is not None else None)
                vals = c.decode(g_p, g_s)
                flat = vals.reshape((per_pod * local.shape[0],) + x.shape[1:])
                recon = jnp.take(flat, src, axis=0)
                # stage 2: only the owning pod's reconstruction is real;
                # zero elsewhere and psum over the pod axis (x+0 exact).
                # This psum CONSUMES the intra-pod reconstruction, so the
                # pod path cannot defer it — issue carries it to the end.
                my_pod = rank // per_pod
                recon = jnp.where(my_pod == owner[0] // per_pod, recon,
                                  jnp.zeros_like(recon))
                return jax.lax.psum(recon, axes[0]).astype(x.dtype)
            g_p = _all_gather(payload, axes, world)               # (W, M, ...)
            g_s = _all_gather(scale, axes, world) if scale is not None else None
            return _GatheredLeaf(payload=g_p, scale=g_s, src=src,
                                 out_dtype=x.dtype)

        out[b.key] = jax.tree_util.tree_map(leaf, stacks[b.key])
        if two_stage:
            local_owner = np.asarray(owner) % pods[1]
            ici += owned_slice_bytes(stacks[b.key], local_owner, pods[1], c)
            # the pod-axis psum carries the full reconstructed bucket in f32
            dcn += sum(4 * metrics.leaf_elements(l)
                       for l in jax.tree_util.tree_leaves(stacks[b.key]))
        else:
            nbytes += owned_slice_bytes(stacks[b.key], owners[b.key], world, c)
    if site is not None:
        if two_stage:
            metrics.record(site, bytes_per_call=ici + dcn, codec=c.name,
                           mode='gather-pod',
                           extra={'world': world, 'pods': list(pods),
                                  'ici_bytes': ici, 'dcn_bytes': dcn})
        else:
            metrics.record(site, bytes_per_call=nbytes, codec=c.name,
                           mode='gather', extra={'world': world})
    return InFlightSlices(stacks=out, done=two_stage, codec=c)


def collect_allgather_owned_slices(fl: InFlightSlices) -> dict:
    """Local finishing half: decode the gathered wire rows, flatten the
    (world, M) gather layout and take each stack row from its owner's
    position.  Identity for the pod two-stage path (see
    :class:`InFlightSlices`)."""
    if fl.done:
        return fl.stacks
    c = fl.codec

    def leaf(gl: _GatheredLeaf):
        vals = c.decode(gl.payload, gl.scale)
        flat = vals.reshape((vals.shape[0] * vals.shape[1],) + vals.shape[2:])
        return jnp.take(flat, gl.src, axis=0).astype(gl.out_dtype)

    return {k: jax.tree_util.tree_map(
        leaf, v, is_leaf=lambda x: isinstance(x, _GatheredLeaf))
        for k, v in fl.stacks.items()}


def allgather_owned_slices(plan, owners: dict, world: int, rank,
                           stacks: dict, *, codec: Any = 'f32',
                           axes: Optional[Sequence[str]] = None,
                           site: Optional[str] = None,
                           pods: Optional[tuple[int, int]] = None) -> dict:
    """Reconstruct full bucket stacks from per-owner slices.

    Args:
      plan: the ``BucketPlan`` whose stacked values are being exchanged.
      owners: ``{bucket_key: (N,) owner ranks}`` from
        ``ownership.assign_slice_owners`` (or ``assign_pod_slice_owners``
        with ``pods=``) — static numpy, deterministic on every host, which
        is what makes the index maps SPMD-consistent; N must match the
        stacks' leading axis.
      world / rank: from ``ownership.world_and_rank`` (world static, rank a
        traced scalar).
      stacks: ``{bucket_key: pytree of (N, *item) arrays}`` where each
        worker holds real values at its owned rows (anything elsewhere —
        the cond-gated zeros are never read).
      codec: wire format; int8 uses one symmetric max-scale per stack row
        (each row has exactly one producer, so no global pmax is needed).
      pods: ``(n_pods, per_pod)`` for the topology-aware two-stage
        exchange: ``owners`` must be pod-local
        (``ownership.assign_pod_slice_owners``) and ``axes`` must be the
        ('pod', intra-pod) pair.  The slice gather then runs over the
        intra-pod axis only (ICI); the owning pod's reconstructed bucket
        crosses the pod axis (DCN) once as a zero-padded psum (exact, like
        the legacy exchange — but coarse-grained and pod-axis-only).

    Returns stacks of identical structure with every row holding its
    owner's value on every worker.

    Composes the staged halves synchronously (issue the gathers, then
    decode/reconstruct locally) — value-preserving, so bit-exact with the
    pre-split implementation.
    """
    return collect_allgather_owned_slices(issue_allgather_owned_slices(
        plan, owners, world, rank, stacks, codec=codec, axes=axes,
        site=site, pods=pods))


def refresh_exchange_bytes(plan, owners: dict, stacks: Any, world: int, *,
                           codec: Any = 'f32', mode: str = 'gather') -> int:
    """Logical per-worker bytes of ONE refresh exchange — the accounting
    the runtime records, callable on ShapeDtypeStructs (roofline §3.3).

    'psum' contributes the whole zero-padded stack at f32 regardless of
    codec (the legacy exchange is uncompressed); 'gather' contributes
    only the padded owned rows under ``codec``.
    """
    if mode == 'psum':
        return sum(4 * metrics.leaf_elements(l)
                   for k in stacks
                   for l in jax.tree_util.tree_leaves(stacks[k]))
    c = get_codec(codec)
    return sum(owned_slice_bytes(stacks[b.key], owners[b.key], world, c)
               for b in plan.buckets)


def psum_partials(tree: Any, axes: Optional[Sequence[str]], world: int, *,
                  site: str = 'factor', calls: int = 1,
                  extra: Optional[dict] = None) -> Any:
    """Sum full-width per-worker matvec partials — the ONE collective of the
    matrix-free sharded-factor apply path (``repro.core.factor_sharded``).

    Each worker contributes a full-width f32 partial computed from its owned
    row band of the factor (``ownership.factor_block``); the factor's zero
    pad rows contribute zero, so the sum reconstructs the unsharded matvec
    exactly.  Nothing (d, d)-shaped ever crosses the wire — per-call traffic
    is gradient-shaped, which is what moves the oversized-factor exchange
    off the refresh roofline entirely.

    ``calls`` scales the recorded bytes to one full iterative solve: the
    psum sits inside a ``lax.scan`` body, so this trace-time record fires
    once per solve, not once per iteration.  W=1 (or no bound axes) is the
    usual degenerate case: same code path, no collective, mode='local'.
    """
    nbytes = tree_payload_bytes(tree, get_codec('f32')) * max(1, int(calls))
    info = {'world': int(world)}
    if extra:
        info.update(extra)
    collective = world > 1 and bool(axes)
    if site:
        metrics.record(site, bytes_per_call=nbytes, codec='f32',
                       mode='psum-partial' if collective else 'local',
                       extra=info)
    if not collective:
        return tree
    return jax.tree_util.tree_map(
        lambda x: jax.lax.psum(x.astype(jnp.float32), _axis_arg(axes)), tree)


def slice_stack_specs(plan, sides: str = 'both') -> dict:
    """ShapeDtypeStruct stacks mirroring what ``sharded_refresh`` exchanges
    for a dense-factor method: per bucket a (N·lead, d_in, d_in) cached
    inverse (plus the (N·lead, d_out, d_out) pair for ``sides='both'``) in
    f32.  This encodes the runtime's slice-flattening convention (stack ×
    leading scan/expert dims → one slice axis) in ONE place for the
    byte-accounting callers (roofline §3.3, ``table5 --refresh-sharding``,
    tests) — change it here when the layout in
    ``schedule/runtime.recompute_sharded`` changes.
    """
    # lazy: repro.schedule's package __init__ imports this module, so a
    # top-level import here would be circular
    from repro.schedule.ownership import lead_size

    if sides not in ('left', 'both'):
        raise ValueError(f"sides must be 'left' or 'both', got {sides!r}")
    out = {}
    for b in plan.buckets:
        s = len(b.paths) * lead_size(b)
        d_in, d_out = b.shape[-2], b.shape[-1]
        specs = (jax.ShapeDtypeStruct((s, d_in, d_in), jnp.float32),)
        if sides == 'both':
            specs += (jax.ShapeDtypeStruct((s, d_out, d_out), jnp.float32),)
        out[b.key] = specs
    return out
