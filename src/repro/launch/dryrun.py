import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run (deliverable e).

For every (architecture × input shape × mesh) cell:
  lower the step function with production shardings on 256-chip single-pod
  and 512-chip multi-pod meshes, ``.compile()`` it, and record
  ``memory_analysis()`` / ``cost_analysis()`` / trip-count-corrected HLO
  costs (FLOPs, HBM traffic, collective bytes) into results/dryrun/*.json.

The first two lines of this file force 512 host platform devices BEFORE any
jax import — nothing else in the repo sets this flag (smoke tests and
benchmarks see the real single CPU device).

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun                 # all cells
  ... --arch kimi-k2-1t-a32b --shape train_4k --mesh multi     # one cell
  ... --list                                                   # show plan
"""
import argparse
import json
import time
import traceback
from pathlib import Path

import jax
import jax.numpy as jnp

from repro.configs import SHAPES, cell_skip_reason, get_config
from repro.configs.registry import ARCH_IDS
from repro.core.registry import make_optimizer
from repro.launch import hlo_analysis
from repro.launch.mesh import make_production_mesh
from repro.sharding import compat
from repro.models import build_model, decode_specs, prefill_batch_specs, train_batch_specs
from repro.models import module as M
from repro.sharding import (cache_shardings, input_shardings,
                            opt_state_shardings, param_shardings)
from repro.train.step import abstract_opt_state, make_train_step

V5E = dict(peak_flops=197e12, hbm_bw=819e9, ici_bw=50e9)


def active_param_counts(specs) -> tuple[int, int]:
    """(total, active) params; MoE expert weights count at top_k/n_experts."""
    flat = M.flatten_specs(specs)
    total = sum(int(jnp.prod(jnp.array(s.shape))) for s in flat.values())
    return total, total  # corrected by caller for MoE


def model_flop_params(cfg, specs) -> tuple[int, int]:
    import math
    flat = M.flatten_specs(specs)
    total = sum(math.prod(s.shape) for s in flat.values())
    expert = sum(math.prod(s.shape) for p, s in flat.items()
                 if '/moe/' in f'/{p}' and not p.endswith('router/w'))
    if cfg.n_experts:
        active = total - expert + expert * (cfg.top_k / cfg.n_experts)
    else:
        active = total
    return int(total), int(active)


def build_cell(cfg, shape, mesh, fallback_log):
    """Returns (fn, args, in_shardings, donate, tokens_processed)."""
    model = build_model(cfg)
    specs = model.param_specs()
    params_sds = M.abstract_params(specs)
    p_shard = param_shardings(specs, mesh, fallback_log)

    if shape.kind == 'train':
        opt, capture = make_optimizer('eva', lr=0.01)
        batch = train_batch_specs(cfg, shape)
        opt_sds = abstract_opt_state(model, opt, capture, params_sds, batch)
        o_shard = opt_state_shardings(opt_sds, specs, mesh)
        b_shard = input_shardings(batch, mesh)
        fn = make_train_step(model, opt, capture,
                             microbatches=cfg.microbatches)
        tokens = shape.global_batch * shape.seq_len
        return (fn, (params_sds, opt_sds, batch),
                (p_shard, o_shard, b_shard), (0, 1), tokens, 'train')
    if shape.kind == 'prefill':
        batch = prefill_batch_specs(cfg, shape)
        b_shard = input_shardings(batch, mesh)
        fn = model.prefill_fn
        tokens = shape.global_batch * shape.seq_len
        return fn, (params_sds, batch), (p_shard, b_shard), (), tokens, 'prefill'
    # decode
    cache_sds, tok_sds, pos_sds = decode_specs(cfg, shape)
    c_shard = cache_shardings(cache_sds, mesh)
    t_shard = input_shardings(tok_sds, mesh, seq_dim=None)
    pos_shard = input_shardings(pos_sds, mesh, seq_dim=None)
    fn = model.decode_fn
    tokens = shape.global_batch  # one new token per sequence
    return (fn, (params_sds, cache_sds, tok_sds, pos_sds),
            (p_shard, c_shard, t_shard, pos_shard), (1,), tokens, 'decode')


def run_cell(arch_id: str, shape, multi_pod: bool, out_dir: Path,
             force: bool = False) -> dict:
    mesh_name = 'multi' if multi_pod else 'single'
    out_path = out_dir / f'{arch_id}__{shape.name}__{mesh_name}.json'
    if out_path.exists() and not force:
        return json.loads(out_path.read_text())

    cfg = get_config(arch_id)
    skip = cell_skip_reason(cfg, shape)
    rec = {'arch': arch_id, 'shape': shape.name, 'mesh': mesh_name,
           'seq_len': shape.seq_len, 'global_batch': shape.global_batch,
           'kind': shape.kind}
    if skip:
        rec['skipped'] = skip
        out_path.write_text(json.dumps(rec, indent=1))
        return rec

    mesh = make_production_mesh(multi_pod=multi_pod)
    n_chips = len(mesh.devices.reshape(-1))
    fallback_log: list = []
    t0 = time.time()
    fn, args, shardings, donate, tokens, kind = build_cell(cfg, shape, mesh,
                                                           fallback_log)
    with compat.set_mesh(mesh):
        lowered = jax.jit(fn, in_shardings=shardings,
                          donate_argnums=donate).lower(*args)
        t_lower = time.time() - t0
        t0 = time.time()
        compiled = lowered.compile()
        t_compile = time.time() - t0

    mem = compiled.memory_analysis()
    ca = compat.cost_analysis(compiled)
    hlo = hlo_analysis.analyze(compiled.as_text())

    specs = build_model(cfg).param_specs()
    total_p, active_p = model_flop_params(cfg, specs)
    if kind == 'train':
        model_flops = 6.0 * active_p * tokens
    else:
        model_flops = 2.0 * active_p * tokens

    per_dev = dict(
        hlo_flops=hlo.flops,
        hbm_traffic_bytes=hlo.traffic_bytes,
        collective_bytes=hlo.collective_bytes,
        cost_analysis_flops=float(ca.get('flops', 0.0)),
        cost_analysis_bytes=float(ca.get('bytes accessed', 0.0)),
    )
    roofline = dict(
        compute_s=hlo.flops / V5E['peak_flops'],
        memory_s=hlo.traffic_bytes / V5E['hbm_bw'],
        collective_s=hlo.collective_bytes / V5E['ici_bw'],
    )
    dominant = max(roofline, key=roofline.get)
    rec.update(
        n_chips=n_chips,
        params_total=total_p, params_active=active_p,
        tokens_per_step=tokens,
        model_flops_total=model_flops,
        model_flops_per_chip=model_flops / n_chips,
        useful_flop_ratio=(model_flops / n_chips) / max(hlo.flops, 1.0),
        per_device=per_dev,
        roofline_s=roofline,
        dominant=dominant,
        collective_by_op=hlo.collective_by_op,
        collective_count=hlo.collective_count,
        memory=dict(
            argument_bytes=mem.argument_size_in_bytes,
            output_bytes=mem.output_size_in_bytes,
            temp_bytes=mem.temp_size_in_bytes,
            alias_bytes=mem.alias_size_in_bytes,
            total_bytes=(mem.argument_size_in_bytes + mem.temp_size_in_bytes
                         + mem.output_size_in_bytes - mem.alias_size_in_bytes),
        ),
        lower_s=round(t_lower, 2), compile_s=round(t_compile, 2),
        sharding_fallbacks=sorted(set(fallback_log)),
    )
    out_path.write_text(json.dumps(rec, indent=1))
    return rec


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument('--arch', default=None)
    ap.add_argument('--shape', default=None)
    ap.add_argument('--mesh', default='both', choices=['single', 'multi', 'both'])
    ap.add_argument('--out', default='results/dryrun')
    ap.add_argument('--force', action='store_true')
    ap.add_argument('--list', action='store_true')
    args = ap.parse_args()

    out_dir = Path(args.out)
    out_dir.mkdir(parents=True, exist_ok=True)
    archs = [args.arch] if args.arch else list(ARCH_IDS)
    shapes = [s for s in SHAPES if args.shape in (None, s.name)]
    meshes = {'single': [False], 'multi': [True], 'both': [False, True]}[args.mesh]

    failures = []
    for arch in archs:
        for shape in shapes:
            for mp in meshes:
                tag = f'{arch} × {shape.name} × {"multi" if mp else "single"}'
                if args.list:
                    print(tag)
                    continue
                try:
                    rec = run_cell(arch, shape, mp, out_dir, force=args.force)
                    if 'skipped' in rec:
                        print(f'SKIP  {tag}: {rec["skipped"]}')
                    else:
                        r = rec['roofline_s']
                        print(f'OK    {tag}: compile={rec["compile_s"]}s '
                              f'mem={rec["memory"]["total_bytes"]/2**30:.2f}GiB/dev '
                              f'compute={r["compute_s"]*1e3:.1f}ms '
                              f'mem_t={r["memory_s"]*1e3:.1f}ms '
                              f'coll={r["collective_s"]*1e3:.1f}ms '
                              f'dom={rec["dominant"]}')
                except Exception as e:  # noqa: BLE001 — record and continue
                    failures.append((tag, repr(e)))
                    print(f'FAIL  {tag}: {e!r}')
                    traceback.print_exc()
    if failures:
        raise SystemExit(f'{len(failures)} cells failed: '
                         + '; '.join(t for t, _ in failures))


if __name__ == '__main__':
    main()
