"""Post-SPMD HLO analysis: trip-count-aware FLOPs / HBM traffic / collective
bytes (the three roofline terms).

Why not ``compiled.cost_analysis()`` alone?  XLA's HloCostAnalysis visits a
``while`` body ONCE — a 61-layer scanned model under-counts 61×.  (Verified
on this jax build: scan(8 matmuls) reports 1/8 the flops of the unrolled
version.)  This module parses ``compiled.as_text()``, builds the call graph
(fusions / while bodies / conditionals), extracts while trip counts from the
loop-condition constants, and multiplies costs through.

Models (documented approximations):
  * FLOPs: 2·prod(out)·K per dot (K = contraction size from operand shapes);
    convolutions counted as 2·prod(out)·K·prod(window); elementwise ignored
    (sub-1% for these models).
  * HBM traffic: at fusion/op boundaries in non-fusion computations —
    sum of unique operand bytes + output bytes (XLA materializes buffers at
    fusion boundaries).  parameter/constant/tuple/gte/bitcast excluded.
  * Collective bytes moved per device (ring conventions):
      all-reduce 2×size, all-gather size, reduce-scatter size×(g-1),
      all-to-all size, collective-permute size.
"""
from __future__ import annotations

import dataclasses
import math
import re
from typing import Optional

_SHAPE_RE = re.compile(r'([a-z][a-z0-9]*)\[([0-9,]*)\]')
_OP_RE = re.compile(
    r'^\s*(?:ROOT\s+)?%([\w.\-]+)\s*=\s*(\(?[^=]*?)\s*([\w\-]+)\((.*)$')
_COMP_RE = re.compile(r'^(?:ENTRY\s+)?%?([\w.\-]+)\s*\(.*\)\s*->\s*.*\{\s*$')
_CALLED_RE = re.compile(r'(?:calls|to_apply|condition|body|branch_computations)='
                        r'(?:\{([^}]*)\}|%?([\w.\-]+))')
_OPERAND_RE = re.compile(r'%([\w.\-]+)')
_CONST_RE = re.compile(r'constant\((\d+)\)')
_GROUPS_RE = re.compile(r'replica_groups=\[(\d+),(\d+)\]')

_DTYPE_BYTES = {
    'pred': 1, 's4': 1, 'u4': 1, 's8': 1, 'u8': 1, 'f8e4m3fn': 1, 'f8e5m2': 1,
    's16': 2, 'u16': 2, 'f16': 2, 'bf16': 2,
    's32': 4, 'u32': 4, 'f32': 4,
    's64': 8, 'u64': 8, 'f64': 8, 'c64': 8, 'c128': 16,
}

COLLECTIVE_OPS = ('all-reduce', 'all-gather', 'reduce-scatter', 'all-to-all',
                  'collective-permute')

_SKIP_TRAFFIC = {'parameter', 'constant', 'tuple', 'get-tuple-element',
                 'bitcast', 'iota', 'after-all', 'partition-id', 'replica-id',
                 # control/structural ops: loop state stays in place; the
                 # body's real reads/writes are counted inside the body
                 'while', 'conditional', 'call', 'optimization-barrier'}

# windowed-access ops: traffic ≈ the slice moved, NOT the full operand
_SLICED_READ = {'dynamic-slice', 'gather'}
_SLICED_WRITE = {'dynamic-update-slice', 'scatter', 'scatter-add'}


def shape_bytes(type_str: str) -> int:
    """Total bytes of an HLO type string (handles tuples)."""
    total = 0
    for m in _SHAPE_RE.finditer(type_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(','):
                if d:
                    n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def shape_elems(type_str: str) -> int:
    m = _SHAPE_RE.search(type_str)
    if not m:
        return 0
    n = 1
    if m.group(2):
        for d in m.group(2).split(','):
            if d:
                n *= int(d)
    return n


@dataclasses.dataclass
class Op:
    name: str
    out_type: str
    opcode: str
    rest: str          # everything after the opening paren
    operands: list
    called: list


@dataclasses.dataclass
class Computation:
    name: str
    ops: dict          # name -> Op
    order: list
    root: Optional[str] = None   # ROOT op name (falls back to last op)

    def root_op(self) -> Optional[str]:
        return self.root if self.root is not None else (
            self.order[-1] if self.order else None)


def parse_hlo(text: str) -> dict[str, Computation]:
    comps: dict[str, Computation] = {}
    cur: Optional[Computation] = None
    for raw in text.splitlines():
        line = re.sub(r'/\*.*?\*/', '', raw).rstrip()
        mc = _COMP_RE.match(line.strip()) if line.strip().endswith('{') else None
        if mc and ('->' in line):
            cur = Computation(mc.group(1), {}, [])
            comps[cur.name] = cur
            continue
        if line.strip() == '}':
            continue
        if cur is None:
            continue
        mo = _OP_RE.match(line)
        if not mo:
            continue
        name, out_type, opcode, rest = mo.groups()
        # operand names: up to the closing paren of the op call
        depth, end = 1, 0
        for i, ch in enumerate(rest):
            if ch == '(':
                depth += 1
            elif ch == ')':
                depth -= 1
                if depth == 0:
                    end = i
                    break
        arg_str = rest[:end]
        attr_str = rest[end:]
        operands = _OPERAND_RE.findall(arg_str)
        called = []
        for m in _CALLED_RE.finditer(attr_str):
            if m.group(1) is not None:
                called.extend(x.strip().lstrip('%') for x in m.group(1).split(','))
            else:
                called.append(m.group(2))
        op = Op(name, out_type.strip(), opcode, rest, operands, called)
        cur.ops[name] = op
        cur.order.append(name)
        if re.match(r'\s*ROOT\s', line):
            cur.root = name
    return comps


def _entry_name(comps: dict[str, Computation], text: str) -> str:
    m = re.search(r'^ENTRY\s+%?([\w.\-]+)', text, re.M)
    if m and m.group(1) in comps:
        return m.group(1)
    # fallback: computation never called by others
    called = {c for comp in comps.values() for op in comp.ops.values()
              for c in op.called}
    for name in comps:
        if name not in called:
            return name
    return next(iter(comps))


def _while_trip_count(comps, cond_name: str) -> int:
    cond = comps.get(cond_name)
    if cond is None:
        return 1
    consts = []
    for op in cond.ops.values():
        for m in _CONST_RE.finditer(op.rest):
            consts.append(int(m.group(1)))
        if op.opcode == 'constant':
            m = _CONST_RE.search(op.out_type + '(' + op.rest)
            if m:
                consts.append(int(m.group(1)))
    consts = [c for c in consts if c > 0]
    return max(consts) if consts else 1


def computation_multipliers(comps: dict[str, Computation],
                            entry: str) -> dict[str, float]:
    """Execution-count multiplier per computation (while bodies × trip)."""
    mult: dict[str, float] = {}

    def visit(name: str, m: float):
        if name not in comps:
            return
        mult[name] = mult.get(name, 0.0) + m
        comp = comps[name]
        for op in comp.ops.values():
            if op.opcode == 'while':
                body = cond = None
                mb = re.search(r'body=%?([\w.\-]+)', op.rest)
                mcnd = re.search(r'condition=%?([\w.\-]+)', op.rest)
                if mb:
                    body = mb.group(1)
                if mcnd:
                    cond = mcnd.group(1)
                # XLA records the statically-known trip count
                mt = re.search(r'"known_trip_count":\{"n":"(\d+)"\}', op.rest)
                if mt:
                    trip = int(mt.group(1))
                else:
                    trip = _while_trip_count(comps, cond) if cond else 1
                if body:
                    visit(body, m * trip)
                if cond:
                    visit(cond, m * (trip + 1))
            else:
                for c in op.called:
                    visit(c, m)

    visit(entry, 1.0)
    return mult


# ---------------------------------------------------------------------------
# Cost models


def _dot_flops(comp: Computation, op: Op) -> float:
    out_elems = shape_elems(op.out_type)
    lhs = comp.ops.get(op.operands[0]) if op.operands else None
    k = 1
    m = re.search(r'lhs_contracting_dims=\{([0-9,]*)\}', op.rest)
    if lhs is not None and m:
        sm = _SHAPE_RE.search(lhs.out_type)
        if sm and sm.group(2):
            dims = [int(d) for d in sm.group(2).split(',') if d]
            for ci in m.group(1).split(','):
                if ci and int(ci) < len(dims):
                    k *= dims[int(ci)]
    return 2.0 * out_elems * k


def _conv_flops(comp: Computation, op: Op) -> float:
    """2·out·window — correct for the depthwise convs in this repo (mamba's
    causal conv1d and its weight-grad, both of which contract only the
    window); a dense multi-channel conv would need × input-features, but
    none exist here and the blind heuristic inflated mamba's weight-grad
    conv (window=seq_len) by the channel count."""
    out_elems = shape_elems(op.out_type)
    m = re.search(r'window=\{size=([0-9x]+)', op.rest)
    win = 1
    if m:
        for d in m.group(1).split('x'):
            win *= int(d)
    return 2.0 * out_elems * win


def _collective_bytes(op: Op) -> float:
    size = shape_bytes(op.out_type)
    groups = _GROUPS_RE.search(op.rest)
    g = int(groups.group(2)) if groups else 2
    if op.opcode.startswith('all-reduce'):
        return 2.0 * size * (g - 1) / max(g, 1)
    if op.opcode.startswith('all-gather'):
        return size * (g - 1) / max(g, 1)
    if op.opcode.startswith('reduce-scatter'):
        return float(size * max(g - 1, 1))
    return float(size)  # all-to-all / collective-permute


@dataclasses.dataclass
class HloCosts:
    flops: float = 0.0
    traffic_bytes: float = 0.0
    collective_bytes: float = 0.0
    collective_count: int = 0
    collective_by_op: dict = dataclasses.field(default_factory=dict)
    dot_flops_by_comp: dict = dataclasses.field(default_factory=dict)


def analyze(text: str) -> HloCosts:
    comps = parse_hlo(text)
    entry = _entry_name(comps, text)
    mult = computation_multipliers(comps, entry)
    costs = HloCosts()
    fusion_comps = set()
    for comp in comps.values():
        for op in comp.ops.values():
            if op.opcode == 'fusion':
                fusion_comps.update(op.called)

    for cname, m in mult.items():
        comp = comps[cname]
        in_fusion = cname in fusion_comps
        comp_flops = 0.0
        for opn in comp.order:
            op = comp.ops[opn]
            if op.opcode == 'dot':
                comp_flops += _dot_flops(comp, op) * m
            elif op.opcode == 'convolution':
                comp_flops += _conv_flops(comp, op) * m
            if in_fusion:
                continue  # traffic counted at the fusion boundary
            if op.opcode in _SKIP_TRAFFIC:
                continue
            if any(op.opcode.startswith(c) for c in COLLECTIVE_OPS):
                b = _collective_bytes(op) * m
                costs.collective_bytes += b
                costs.collective_count += int(m)
                key = op.opcode.split('-start')[0]
                costs.collective_by_op[key] = costs.collective_by_op.get(key, 0.0) + b
            # HBM traffic: output + operands (windowed ops move ~the slice)
            out_b = shape_bytes(op.out_type)
            if op.opcode in _SLICED_READ:
                traffic = 2.0 * out_b
            elif op.opcode in _SLICED_WRITE:
                # in-place update: read+write of the update region; the
                # update operand is usually operand 1
                upd = comp.ops.get(op.operands[1]) if len(op.operands) > 1 else None
                upd_b = shape_bytes(upd.out_type) if upd is not None else out_b
                traffic = 2.0 * min(upd_b, out_b)
            else:
                traffic = out_b
                for o in op.operands:
                    src = comp.ops.get(o)
                    if src is not None:
                        traffic += shape_bytes(src.out_type)
            costs.traffic_bytes += traffic * m
        if comp_flops:
            costs.dot_flops_by_comp[cname] = comp_flops
        costs.flops += comp_flops
    return costs


# ---------------------------------------------------------------------------
# Collective/compute overlap (async-pipeline structural check)
#
# Whether a collective can overlap compute is a DEPENDENCE question, not a
# scheduling one: XLA's latency-hiding scheduler (and, on CPU, the thunk
# runtime) may or may not emit -start/-done async pairs, but a dot that
# transitively consumes a collective's output can never run before it on any
# backend.  So the backend-independent check is: forward-reach every dot from
# every collective output and classify dot FLOPs as dependent (must wait) vs
# independent (free to overlap).  A synchronous curvature exchange puts the
# preconditioning contractions squarely in the dependent set; the onestep
# pipeline's collectives feed only optimizer-state outputs, so its dependent
# dot FLOPs collapse to ~0 — that collapse is what CI asserts.


@dataclasses.dataclass
class OverlapReport:
    collective_count: int          # static collective op count (all comps)
    blocking_collectives: int      # collectives with ≥1 dot in their cone
    total_dots: int
    dependent_dots: int
    dot_flops_total: float         # trip-count-weighted
    dot_flops_dependent: float

    @property
    def dot_flops_independent(self) -> float:
        return self.dot_flops_total - self.dot_flops_dependent

    @property
    def dependent_fraction(self) -> float:
        return (self.dot_flops_dependent / self.dot_flops_total
                if self.dot_flops_total else 0.0)


def _param_ops(comp: Computation) -> list:
    """Parameter op names of a computation, in parameter-index order."""
    idx = {}
    for opn, op in comp.ops.items():
        if op.opcode == 'parameter':
            m = re.match(r'\s*(\d+)\s*\)', op.rest)
            if m:
                idx[int(m.group(1))] = opn
    return [idx[i] for i in sorted(idx)]


def _forward_edges(comps: dict[str, Computation]) -> dict:
    """Global forward dataflow edges over (comp, op) nodes: within-comp
    operand→consumer, caller-operand→callee-parameter, callee-root→caller.
    while loops additionally route the body root back into the body/cond
    parameters (loop carry).  When a call's operand↔parameter arity doesn't
    line up (map/reduce/scatter reducers, conditionals), every operand feeds
    every parameter — an over-approximation, which only ever *overstates*
    dependence, so an 'independent' verdict stays safe."""
    edges: dict = {}

    def add(src, dst):
        edges.setdefault(src, []).append(dst)

    for cname, comp in comps.items():
        for opn in comp.order:
            op = comp.ops[opn]
            for o in op.operands:
                if o in comp.ops and o != opn:
                    add((cname, o), (cname, opn))
            if not op.called:
                continue
            callees = [c for c in op.called if c in comps]
            if op.opcode == 'while':
                for c in callees:
                    params = _param_ops(comps[c])
                    for o in op.operands:
                        if o in comp.ops:
                            for p in params:
                                add((cname, o), (c, p))
                # loop carry: the body root re-enters every iteration
                body = next((c for c in callees
                             if re.search(r'body=%?' + re.escape(c), op.rest)),
                            None)
                for c in callees:
                    root = comps[c].root_op()
                    if root is not None:
                        add((c, root), (cname, opn))
                if body is not None:
                    broot = comps[body].root_op()
                    if broot is not None:
                        for c in callees:
                            for p in _param_ops(comps[c]):
                                add((body, broot), (c, p))
            else:
                for c in callees:
                    params = _param_ops(comps[c])
                    srcs = [o for o in op.operands if o in comp.ops]
                    if len(callees) == 1 and len(srcs) == len(params):
                        for o, p in zip(srcs, params):
                            add((cname, o), (c, p))
                    else:
                        for o in srcs:
                            for p in params:
                                add((cname, o), (c, p))
                    root = comps[c].root_op()
                    if root is not None:
                        add((c, root), (cname, opn))
    return edges


def _is_collective(op: Op) -> bool:
    # matches the async variants too ('all-reduce-start', '-done')
    return any(op.opcode.startswith(c) for c in COLLECTIVE_OPS)


def _reach(edges: dict, sources) -> set:
    reached = set(sources)
    frontier = list(sources)
    while frontier:
        node = frontier.pop()
        for nxt in edges.get(node, ()):
            if nxt not in reached:
                reached.add(nxt)
                frontier.append(nxt)
    return reached


def collective_overlap(text: str) -> OverlapReport:
    """Classify the module's dot FLOPs by whether they transitively depend
    on any collective's output (see module note above).

    ``blocking_collectives`` additionally counts, per collective, whether
    ANY dot sits in that collective's own forward cone.  The aggregate
    dependent fraction cannot separate a gradient all-reduce (whose
    downstream dots are the whole update — unavoidable in data parallelism)
    from the curvature exchanges this check targets; the per-collective
    count can: pipelining the curvature exchange moves exactly those
    collectives out of the blocking set while the gradient reduction stays
    in it."""
    comps = parse_hlo(text)
    entry = _entry_name(comps, text)
    mult = computation_multipliers(comps, entry)
    edges = _forward_edges(comps)

    sources = [(cname, opn) for cname, comp in comps.items()
               for opn, op in comp.ops.items() if _is_collective(op)]
    reached = _reach(edges, sources)

    dots = {}
    for cname, m in mult.items():
        comp = comps[cname]
        for opn in comp.order:
            op = comp.ops[opn]
            if op.opcode == 'dot':
                dots[(cname, opn)] = _dot_flops(comp, op) * m

    # a collective blocks iff some dot is forward-reachable from it ⇔ it is
    # backward-reachable from some dot: one reverse BFS instead of |sources|
    rev: dict = {}
    for src, dsts in edges.items():
        for d in dsts:
            rev.setdefault(d, []).append(src)
    reaches_dot = _reach(rev, list(dots))
    blocking = sum(1 for s in sources if s in reaches_dot)

    rep = OverlapReport(collective_count=len(sources),
                        blocking_collectives=blocking,
                        total_dots=len(dots), dependent_dots=0,
                        dot_flops_total=sum(dots.values()),
                        dot_flops_dependent=0.0)
    for d, fl in dots.items():
        if d in reached:
            rep.dependent_dots += 1
            rep.dot_flops_dependent += fl
    return rep
