"""Production training launcher.

Single-host execution of the full stack (config → model → Eva → trainer with
checkpointing/preemption).  On a real multi-pod deployment the same entry
point runs under ``jax.distributed.initialize()`` (one process per host —
see ``launch/run_multipod.sh``); the step function, shardings and
checkpoint protocol are host-count-agnostic.

    PYTHONPATH=src python -m repro.launch.train --arch qwen2-0.5b --reduced \\
        --steps 50 --opt eva
"""
from __future__ import annotations

import argparse

import jax

from repro.configs import get_config, get_reduced
from repro.configs.registry import ARCH_IDS, demo_lm
from repro.core import kv as kvlib
from repro.core import make_optimizer
from repro.data import LMStream, Prefetcher
from repro.models import build_model
from repro.models import module as M
from repro.train import Trainer, TrainerConfig


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument('--arch', default='demo',
                    help=f'demo|demo-base|demo-100m|{"|".join(ARCH_IDS)}')
    ap.add_argument('--reduced', action='store_true',
                    help='use the reduced config (CPU-runnable)')
    ap.add_argument('--opt', default='eva')
    ap.add_argument('--lr', type=float, default=0.05)
    ap.add_argument('--steps', type=int, default=100)
    ap.add_argument('--batch', type=int, default=8)
    ap.add_argument('--seq-len', type=int, default=64)
    ap.add_argument('--ckpt-every', type=int, default=25)
    ap.add_argument('--log-every', type=int, default=10)
    ap.add_argument('--profile', action='store_true',
                    help='span-fenced phased step + memory/HLO telemetry '
                         '(repro.obs; slight overhead, donation off)')
    ap.add_argument('--head-policy', default='dense',
                    choices=['dense', 'exclude', 'shard'],
                    help='oversized-factor policy (core.factor_sharded): '
                         'dense = legacy, exclude = MKOR-style identity '
                         'guard, shard = matrix-free distributed solve')
    ap.add_argument('--head-threshold', type=int, default=65536,
                    help='factor dim at/above which --head-policy applies '
                         '(vocab-scale factors by default)')
    ap.add_argument('--solve-iters', type=int, default=32,
                    help="iterations of the head-policy='shard' solve")
    ap.add_argument('--kernel-impl', default=None,
                    choices=['auto', 'pallas', 'pallas_interpret', 'xla'],
                    help='kernel dispatch impl for the Eva hot-path ops '
                         '(kernels.dispatch); default: leave the optimizer '
                         'on its own use_pallas behavior')
    ap.add_argument('--autotune', action='store_true',
                    help='benchmark tile/impl candidates for this model\'s '
                         'preconditioned shapes, write the winner cache to '
                         'the run dir and dispatch through it')
    ap.add_argument('--fused', action='store_true',
                    help='fused precondition→update epilogue: one kernel '
                         'launch per bucket for eva/eva_f/eva_s, single-'
                         'traversal elementwise tail for kfac/foof/shampoo')
    ap.add_argument('--out-dir', default='runs/launch')
    ap.add_argument('--no-prefetch', action='store_true')
    ap.add_argument('--distributed', action='store_true',
                    help='call jax.distributed.initialize() (multi-host pods)')
    ap.add_argument('--elastic', action='store_true',
                    help='elastic outer loop (Trainer.fit_elastic): explicit '
                         'DP over --world local devices; checkpoints reshard '
                         'across world sizes (docs/CHECKPOINT_FORMAT.md)')
    ap.add_argument('--world', type=int, default=0,
                    help='data-parallel worker count for --elastic '
                         '(0 = every local device)')
    args = ap.parse_args()

    if args.distributed:
        jax.distributed.initialize()

    if args.arch == 'demo':
        cfg = demo_lm('small')
    elif args.arch.startswith('demo-'):
        cfg = demo_lm(args.arch.split('-', 1)[1])
    else:
        cfg = get_reduced(args.arch) if args.reduced else get_config(args.arch)
    if cfg.family in ('encdec', 'vlm') or cfg.input_is_embeds:
        raise SystemExit(f'{cfg.name}: use the dry-run/examples for stub-'
                         'frontend archs; the LM trainer needs token input')

    model = build_model(cfg)
    params = M.init_params(model.param_specs(), jax.random.PRNGKey(0))
    print(f'{cfg.name}: {M.count_params(model.param_specs())/1e6:.2f}M params')
    stream = LMStream(vocab=cfg.vocab, seq_len=args.seq_len, batch=args.batch,
                      seed=0)
    data = stream if args.no_prefetch else Prefetcher(stream)
    opt_kwargs = {}
    if args.fused:
        opt_kwargs['fused'] = True
    opt, capture = make_optimizer(args.opt, lr=args.lr, **opt_kwargs)
    taps_fn = None
    if capture.b == 'outer':
        # K-FAC-style capture needs full z-shaped taps (kv.make_full_taps);
        # batch-aware so the elastic DP step sizes them to batch/W rows
        paths = set(model.precon_paths()) & set(kvlib.flatten_params(params))
        taps_fn = lambda p, b: kvlib.make_full_taps(p, paths,
                                                    b['tokens'].shape)
    factor = None
    if args.head_policy != 'dense':
        from repro.core.factor_sharded import FactorShardConfig
        factor = FactorShardConfig(head_policy=args.head_policy,
                                   shard_threshold=args.head_threshold,
                                   solve_iters=args.solve_iters)
    tc = TrainerConfig(total_steps=args.steps, log_every=args.log_every,
                       ckpt_every=args.ckpt_every, profile=args.profile,
                       out_dir=f'{args.out_dir}/{cfg.name}-{args.opt}')
    kernel = None
    if args.kernel_impl or args.autotune:
        from repro.kernels import autotune as ktune
        from repro.kernels.dispatch import KernelConfig
        cache_path = None
        if args.autotune:
            # tune the distinct 2-D trailing shapes the preconditioner will
            # actually dispatch (bucketed layers share a shape = one entry)
            flat = kvlib.flatten_params(params)
            shapes = sorted({tuple(int(d) for d in flat[p].shape[-2:])
                             for p in model.precon_paths()
                             if p in flat and flat[p].ndim >= 2})
            print(f'[launch] autotuning {len(shapes)} shapes: {shapes}')
            cache = ktune.tune(shapes)
            cache_path = str(ktune.write(
                cache, f'{tc.out_dir}/tile_cache.json'))
            print(f'[launch] autotune cache -> {cache_path}')
        kernel = KernelConfig(impl=args.kernel_impl or 'auto',
                              autotune_cache=cache_path,
                              autotune=args.autotune)
    trainer = Trainer(model, opt, capture, tc, taps_fn=taps_fn,
                      factor=factor, kernel=kernel)
    if args.elastic:
        trainer.fit_elastic(params, data, world=args.world or None)
    else:
        trainer.fit(params, data)


if __name__ == '__main__':
    main()
