"""Production meshes.

``make_production_mesh()`` is a FUNCTION (importing this module never
touches jax device state):
  single-pod:  (16, 16)      axes ('data', 'model')   — 256 chips
  multi-pod:   (2, 16, 16)   axes ('pod', 'data', 'model') — 512 chips

Design: TP/EP inside the 'model' axis (highest-bandwidth ICI dimension),
FSDP over 'data' (intra-pod ICI), pure DP over 'pod' (inter-pod DCN —
only gradient all-reduces cross it).
"""
from __future__ import annotations

import jax
import numpy as np

from repro.sharding import compat


def make_production_mesh(*, multi_pod: bool = False) -> jax.sharding.Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return compat.make_mesh(shape, axes)


def make_host_mesh() -> jax.sharding.Mesh:
    """Whatever this host actually has (tests / examples): (n_dev, 1)."""
    n = jax.device_count()
    return compat.make_mesh((n, 1), ("data", "model"))


def make_data_mesh(world: int | None = None) -> jax.sharding.Mesh:
    """A 1-D pure-DP ``('data',)`` mesh over the first ``world`` local
    devices — the elastic trainer's mesh (``Trainer.fit_elastic``).

    ``world`` may be *smaller* than the host's device count: an elastic
    resize that drops workers keeps running on the surviving device prefix
    (the extra devices just idle), which is how the chaos tests model a
    W=4 → W=2 shrink inside one host.  Built directly from a device subset
    rather than ``compat.make_mesh`` (``jax.make_mesh`` always spans every
    addressable device)."""
    devices = jax.devices()
    world = len(devices) if world is None else int(world)
    if not 1 <= world <= len(devices):
        raise ValueError(f'world must be in [1, {len(devices)}] '
                         f'(local devices), got {world}')
    return jax.sharding.Mesh(np.asarray(devices[:world]), ("data",))
