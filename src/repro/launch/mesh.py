"""Production meshes.

``make_production_mesh()`` is a FUNCTION (importing this module never
touches jax device state):
  single-pod:  (16, 16)      axes ('data', 'model')   — 256 chips
  multi-pod:   (2, 16, 16)   axes ('pod', 'data', 'model') — 512 chips

Design: TP/EP inside the 'model' axis (highest-bandwidth ICI dimension),
FSDP over 'data' (intra-pod ICI), pure DP over 'pod' (inter-pod DCN —
only gradient all-reduces cross it).
"""
from __future__ import annotations

import jax

from repro.sharding import compat


def make_production_mesh(*, multi_pod: bool = False) -> jax.sharding.Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return compat.make_mesh(shape, axes)


def make_host_mesh() -> jax.sharding.Mesh:
    """Whatever this host actually has (tests / examples): (n_dev, 1)."""
    n = jax.device_count()
    return compat.make_mesh((n, 1), ("data", "model"))
