"""Serving launcher: batched prefill + decode for any registry arch.

    PYTHONPATH=src python -m repro.launch.serve --arch qwen2-0.5b --reduced \\
        --batch 4 --prompt-len 32 --gen 16
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs import get_config, get_reduced
from repro.configs.registry import ARCH_IDS
from repro.models import build_model
from repro.models import module as M


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument('--arch', required=True, choices=list(ARCH_IDS))
    ap.add_argument('--reduced', action='store_true')
    ap.add_argument('--batch', type=int, default=4)
    ap.add_argument('--prompt-len', type=int, default=32)
    ap.add_argument('--gen', type=int, default=16)
    args = ap.parse_args()

    cfg = get_reduced(args.arch) if args.reduced else get_config(args.arch)
    model = build_model(cfg)
    params = M.init_params(model.param_specs(), jax.random.PRNGKey(0))

    b, s = args.batch, args.prompt_len
    key = jax.random.PRNGKey(1)
    batch = {}
    if cfg.family == 'encdec':
        batch['embeds'] = jax.random.normal(key, (b, s, cfg.d_model),
                                            dtype=cfg.cdtype)
        batch['tokens'] = jax.random.randint(key, (b, max(s // cfg.dec_ratio, 4)),
                                             0, cfg.vocab)
        plen = batch['tokens'].shape[1]
    elif cfg.input_is_embeds:
        batch['embeds'] = jax.random.normal(key, (b, s, cfg.d_model),
                                            dtype=cfg.cdtype)
        plen = s
    else:
        batch['tokens'] = jax.random.randint(key, (b, s), 0, cfg.vocab)
        plen = s

    prefill = jax.jit(model.prefill_fn)
    decode = jax.jit(model.decode_fn)
    t0 = time.time()
    logits, cache = prefill(params, batch)
    tok = jnp.argmax(logits, -1).astype(jnp.int32)
    gen = [tok]
    # note: demo keeps the prefill-sized cache; production sizing is
    # prompt+gen (see examples/serve_lm.py for the cache-growth pattern)
    for i in range(min(args.gen, plen) - 1):
        logits, cache = decode(params, cache, tok,
                               jnp.asarray(min(plen + i, plen - 1), jnp.int32))
        tok = jnp.argmax(logits, -1).astype(jnp.int32)
        gen.append(tok)
    jax.block_until_ready(tok)
    out = jnp.stack(gen, 1)
    print(f'{cfg.name}: {b}×{len(gen)} tokens in {time.time()-t0:.2f}s')
    print('first row:', list(map(int, out[0][:12])))


if __name__ == '__main__':
    main()
