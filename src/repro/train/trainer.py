"""Training loop with fault tolerance (deliverable: large-scale runnability).

Features:
  * jit'd train step with donated params/opt-state,
  * deterministic seekable data (resume is bit-exact),
  * async checkpointing every ``ckpt_every`` steps + keep-K GC,
  * preemption handling: SIGTERM/SIGINT → synchronous checkpoint → clean
    exit (the standard TPU-pod eviction contract),
  * straggler watchdog: per-step wall-time EMA; steps slower than
    ``straggler_factor``× the running median are logged (on a real pod this
    feeds the controller that evicts/replaces the slow host),
  * metrics JSONL + stdout.

Elasticity: restore() accepts any mesh — a run checkpointed on N hosts
resumes on M (resharding happens on load, data skips to the saved step).
"""
from __future__ import annotations

import dataclasses
import json
import signal
import statistics
import time
from pathlib import Path
from typing import Any, Callable, Iterable, Optional

import jax
import numpy as np

from repro.comm import metrics as comm_metrics
from repro.core import kv as kvlib
from repro.core.transform import GradientTransformation
from repro.schedule import ownership
from repro.schedule import runtime as schedrt
from repro.train import checkpoint as ckpt
from repro.train.step import init_opt_state, make_train_step, stats_plan_of


@dataclasses.dataclass
class TrainerConfig:
    total_steps: int = 100
    log_every: int = 10
    ckpt_every: int = 0            # 0 = no checkpointing
    keep_ckpts: int = 3
    out_dir: str = 'runs/default'
    straggler_factor: float = 3.0
    donate: bool = True


class Trainer:
    def __init__(self, model, opt: GradientTransformation,
                 capture: kvlib.CaptureConfig, cfg: TrainerConfig,
                 taps_fn: Optional[Callable] = None,
                 sched: Optional[schedrt.RefreshRuntime] = None,
                 comm=None):
        self.model = model
        self.opt = opt
        self.capture = capture
        self.cfg = cfg
        self.taps_fn = taps_fn
        self.sched = sched if sched is not None else schedrt.RefreshRuntime()
        self.comm = comm
        self.out_dir = Path(cfg.out_dir)
        self.out_dir.mkdir(parents=True, exist_ok=True)
        self.ckpt_dir = self.out_dir / 'ckpt'
        self._ckptr = ckpt.AsyncCheckpointer(self.ckpt_dir, cfg.keep_ckpts)
        step_fn = make_train_step(model, opt, capture, taps_fn=taps_fn,
                                  sched=self.sched, comm=comm)
        self.step_fn = jax.jit(step_fn,
                               donate_argnums=(0, 1) if cfg.donate else ())
        self._preempted = False
        self._step_times: list[float] = []
        self.metrics_path = self.out_dir / 'metrics.jsonl'

    # -- refresh-runtime observability ---------------------------------------

    def _log_ownership(self, log_f, params, batch) -> None:
        """One startup record: the per-bucket refresh-owner map a W-worker
        data-parallel run of this model would use (W = local device count).
        Purely informational — cheap (eval_shape only), never fatal."""
        try:
            plan = stats_plan_of(self.model, self.capture, params, batch,
                                 taps_fn=self.taps_fn)
        except Exception:
            plan = None
        if plan is None or not plan.buckets:
            return
        world = max(1, jax.device_count())
        owners = ownership.describe_ownership(plan, world)
        rec = {'event': 'refresh_ownership', 'world': world, 'owners': owners}
        log_f.write(json.dumps(rec) + '\n')
        log_f.flush()
        print(f'[trainer] refresh ownership over W={world}: '
              + ' '.join(f'{k}:{v}' for k, v in owners.items()), flush=True)

    def _log_comm(self, log_f, sites) -> None:
        """One record after the step is traced: the per-call-site logical
        exchange bytes the ``repro.comm`` layer counted for THIS trainer's
        step (empty when nothing in this run exchanges — e.g. single-host
        pjit)."""
        if not sites:
            return
        rec = {'event': 'comm_exchange', 'sites': sites}
        log_f.write(json.dumps(rec) + '\n')
        log_f.flush()
        print('[trainer] comm exchange: ' + ' '.join(
            f"{s}:{v['bytes_per_call']}B/{v['codec']}/{v['mode']}"
            for s, v in sorted(sites.items())), flush=True)

    # -- preemption ---------------------------------------------------------

    def _install_signal_handlers(self):
        def handler(signum, frame):
            del frame
            print(f'[trainer] caught signal {signum}: checkpoint-and-exit '
                  f'requested', flush=True)
            self._preempted = True

        for sig in (signal.SIGTERM, signal.SIGINT):
            try:
                signal.signal(sig, handler)
            except ValueError:
                pass  # not in main thread (tests)

    # -- main loop ------------------------------------------------------------

    def fit(self, params, data: Any, start_step: int = 0,
            opt_state=None, resume: bool = True):
        """``data`` must expose ``batch_at(step)`` (seekable)."""
        cfg = self.cfg
        self._install_signal_handlers()

        if resume and cfg.ckpt_every:
            latest = ckpt.latest_step(self.ckpt_dir)
            if latest is not None:
                template = {'params': params,
                            'opt_state': opt_state if opt_state is not None
                            else init_opt_state(self.model, self.opt,
                                                self.capture, params,
                                                data.batch_at(0),
                                                taps_fn=self.taps_fn,
                                                sched=self.sched,
                                                comm=self.comm)}
                state, meta = ckpt.restore(self.ckpt_dir, latest, template)
                params, opt_state = state['params'], state['opt_state']
                start_step = meta.get('next_step', latest)
                print(f'[trainer] resumed from step {latest}', flush=True)

        if opt_state is None:
            opt_state = init_opt_state(self.model, self.opt, self.capture,
                                       params, data.batch_at(start_step),
                                       taps_fn=self.taps_fn, sched=self.sched,
                                       comm=self.comm)

        # The comm byte counters are process-global and fill at TRACE time.
        # To attribute sites to this trainer without destroying another
        # run's records (no reset), baseline the per-site trace counts now:
        # sites whose count grows during this fit's first step belong to
        # this trainer; a warm-jit second fit() re-traces nothing, so fall
        # back to the sites remembered from this trainer's previous fit.
        base_traces = {k: v.get('traces', 0)
                       for k, v in comm_metrics.snapshot().items()}

        # refresh count already in the (possibly restored) state — the
        # cumulative exchanged-bytes estimate below must count only THIS
        # run's refreshes, like it counts only this run's steps
        base_sched = schedrt.schedule_metrics(opt_state)
        ref_base = int(base_sched['refreshes']) if base_sched else 0

        if self.cfg.donate:
            # the jitted step donates its inputs; don't delete caller-owned
            # buffers (callers may reuse the initial params across runs)
            params = jax.tree_util.tree_map(lambda x: x + 0 if hasattr(x, 'dtype') else x, params)
            opt_state = jax.tree_util.tree_map(lambda x: x + 0 if hasattr(x, 'dtype') else x, opt_state)

        log_f = self.metrics_path.open('a')
        self._log_ownership(log_f, params, data.batch_at(start_step))
        history = []
        step = start_step
        try:
            for step in range(start_step, cfg.total_steps):
                batch = data.batch_at(step)
                t0 = time.perf_counter()
                params, opt_state, metrics = self.step_fn(params, opt_state,
                                                          batch)
                loss = float(metrics['loss'])  # sync point
                dt = time.perf_counter() - t0
                if step == start_step:
                    fresh = {k: v for k, v in comm_metrics.snapshot().items()
                             if v.get('traces', 0) > base_traces.get(k, 0)}
                    if fresh:
                        self._run_sites = fresh
                    self._log_comm(log_f, getattr(self, '_run_sites', {}))
                self._watch_straggler(step, dt)
                history.append(loss)
                if step % cfg.log_every == 0 or step == cfg.total_steps - 1:
                    rec = {'step': step, 'loss': loss,
                           'grad_norm': float(metrics['grad_norm']),
                           'step_time_s': round(dt, 4)}
                    sched_line = ''
                    if 'refreshes' in metrics:
                        rec['refreshes'] = int(metrics['refreshes'])
                        rec['staleness'] = float(metrics['staleness'])
                        rec['refresh_since'] = int(metrics['refresh_since'])
                        sched_line = (f" refreshes {rec['refreshes']}"
                                      f" staleness {rec['staleness']:.3g}")
                    if 'pipeline_lag' in metrics:
                        # realized double-buffer staleness (steps since the
                        # applied buffer was exchanged) — overall + per site
                        for k, v in metrics.items():
                            if k.startswith('pipeline_lag'):
                                rec[k] = int(v)
                        sched_line += f" lag {rec['pipeline_lag']}"
                    # cumulative exchanged bytes, from THIS trainer's comm
                    # sites: per-step sites (grads/stats) fire every
                    # step, refresh sites once per realized refresh
                    sites = getattr(self, '_run_sites', {})
                    if sites:
                        step_b = sum(v['bytes_per_call']
                                     for s, v in sites.items()
                                     if not s.startswith('refresh/'))
                        refresh_b = sum(v['bytes_per_call']
                                        for s, v in sites.items()
                                        if s.startswith('refresh/'))
                        rec['exchanged_mb_cum'] = round(
                            (step_b * (step + 1 - start_step)
                             + refresh_b * (rec.get('refreshes', ref_base)
                                            - ref_base))
                            / 2 ** 20, 3)
                    log_f.write(json.dumps(rec) + '\n')
                    log_f.flush()
                    print(f'[trainer] step {step:6d} loss {loss:.4f} '
                          f'({dt*1e3:.0f} ms){sched_line}', flush=True)
                if cfg.ckpt_every and (step + 1) % cfg.ckpt_every == 0:
                    self._ckptr.save(step + 1,
                                     {'params': params, 'opt_state': opt_state},
                                     {'next_step': step + 1})
                if self._preempted:
                    print('[trainer] preemption: synchronous checkpoint at '
                          f'step {step + 1}', flush=True)
                    self._ckptr.wait()
                    ckpt.save(self.ckpt_dir, step + 1,
                              {'params': params, 'opt_state': opt_state},
                              {'next_step': step + 1, 'preempted': True})
                    break
        finally:
            self._ckptr.wait()
            log_f.close()
        return params, opt_state, history

    # -- straggler watchdog ---------------------------------------------------

    def _watch_straggler(self, step: int, dt: float) -> None:
        self._step_times.append(dt)
        if len(self._step_times) < 8:
            return
        window = self._step_times[-64:]
        med = statistics.median(window)
        if dt > self.cfg.straggler_factor * med:
            print(f'[trainer] STRAGGLER step {step}: {dt*1e3:.0f} ms vs '
                  f'median {med*1e3:.0f} ms — flagged for controller',
                  flush=True)
