"""Training loop with fault tolerance (deliverable: large-scale runnability).

Features:
  * jit'd train step with donated params/opt-state,
  * deterministic seekable data (resume is bit-exact),
  * async checkpointing every ``ckpt_every`` steps + keep-K GC,
  * preemption handling: SIGTERM/SIGINT → synchronous checkpoint → clean
    exit (the standard TPU-pod eviction contract),
  * straggler watchdog (``repro.obs.spans.StragglerWatchdog``): steps
    slower than ``straggler_factor``× the running median emit a typed
    ``straggler`` record (on a real pod this feeds the controller that
    evicts/replaces the slow host),
  * unified telemetry (``repro.obs``): every record in ``metrics.jsonl``
    is schema-typed and versioned; comm-site attribution uses the
    recorder's run-scoped counter context instead of baselining the
    process-global table,
  * ``profile`` mode: the step runs as phased jitted fns
    (grad/precondition/apply) under ``block_until_ready``-fenced spans,
    with per-step live-buffer samples and a one-shot HLO cost record per
    fn.  Off by default — fencing serializes phases (see README
    "Observability" for the measured overhead) and disables donation.

Elasticity: checkpoints are world-agnostic (full logical arrays + the
``elastic`` metadata block — see docs/CHECKPOINT_FORMAT.md for the on-disk
contract and the W-resharding semantics).  ``fit_elastic`` is the elastic
outer loop: it restores a checkpoint written at any world size, reshards
it through ``repro.schedule.reshard`` (re-derives ownership for the new W,
drains in-flight pipeline buffers), rebuilds the data mesh, re-jits and
continues — and tolerates live worker-count changes *between* steps the
same way, emitting a typed ``reshard`` event per resize.
"""
from __future__ import annotations

import dataclasses
import time
from pathlib import Path
from typing import Any, Callable, Optional

import jax

from repro.core import kv as kvlib
from repro.core.transform import GradientTransformation
from repro.kernels import dispatch as kdispatch
from repro.obs import events as obs_events
from repro.obs import spans as obs_spans
from repro.schedule import reshard as reshard_mod
from repro.schedule import runtime as schedrt
from repro.train import checkpoint as ckpt
from repro.train.step import (init_opt_state, make_dp_step,
                              make_phased_step, make_train_step,
                              stats_plan_of)


@dataclasses.dataclass
class TrainerConfig:
    total_steps: int = 100
    log_every: int = 10
    ckpt_every: int = 0            # 0 = no checkpointing
    keep_ckpts: int = 3
    out_dir: str = 'runs/default'
    straggler_factor: float = 3.0
    donate: bool = True
    profile: bool = False          # span-fenced phased step + memory/HLO
                                   # records (forces donation off)


class Trainer:
    def __init__(self, model, opt: GradientTransformation,
                 capture: kvlib.CaptureConfig, cfg: TrainerConfig,
                 taps_fn: Optional[Callable] = None,
                 sched: Optional[schedrt.RefreshRuntime] = None,
                 comm=None, factor=None, kernel=None):
        self.model = model
        self.opt = opt
        self.capture = capture
        self.cfg = cfg
        self.taps_fn = taps_fn
        self.sched = sched if sched is not None else schedrt.RefreshRuntime()
        self.comm = comm
        # per-factor oversized-Kronecker policy (core.factor_sharded);
        # None = every factor dense, the bit-exact legacy path
        self.factor = factor
        # kernel dispatch request (kernels.dispatch.KernelConfig); a cache
        # path installs its autotuned tiles before anything traces
        self.kernel = kernel
        if kernel is not None and kernel.autotune_cache:
            from repro.kernels import dispatch as _dispatch
            _dispatch.install_cache(kernel.autotune_cache)
        self.out_dir = Path(cfg.out_dir)
        self.out_dir.mkdir(parents=True, exist_ok=True)
        self.ckpt_dir = self.out_dir / 'ckpt'
        self._ckptr = ckpt.AsyncCheckpointer(self.ckpt_dir, cfg.keep_ckpts)
        step_fn = make_train_step(model, opt, capture, taps_fn=taps_fn,
                                  sched=self.sched, comm=comm, factor=factor,
                                  kernel=kernel)
        self.step_fn = jax.jit(step_fn,
                               donate_argnums=(0, 1)
                               if cfg.donate and not cfg.profile else ())
        self._phases = None
        if cfg.profile:
            # span timing needs phase boundaries; fences read nothing back
            # but donation is off so a fenced phase's inputs stay alive
            self._phases = tuple(jax.jit(f) for f in make_phased_step(
                model, opt, capture, taps_fn=taps_fn, sched=self.sched,
                comm=comm, factor=factor, kernel=kernel))
        self._watchdog = obs_spans.StragglerWatchdog(cfg.straggler_factor)
        self._preempted = False
        self.metrics_path = self.out_dir / 'metrics.jsonl'

    # -- refresh-runtime observability ---------------------------------------

    def _log_ownership(self, recorder, params, batch) -> None:
        """One startup record: the per-bucket refresh-owner map a W-worker
        data-parallel run of this model would use (W = local device count).
        Purely informational — cheap (eval_shape only), never fatal."""
        try:
            plan = stats_plan_of(self.model, self.capture, params, batch,
                                 taps_fn=self.taps_fn)
        except Exception:
            plan = None
        body = schedrt.ownership_event(plan)
        if body is None:
            return
        recorder.emit('refresh_ownership', **body)
        print(f"[trainer] refresh ownership over W={body['world']}: "
              + ' '.join(f'{k}:{v}' for k, v in body['owners'].items()),
              flush=True)

    def _log_comm(self, recorder, sites) -> None:
        """One record after the step is traced: the per-call-site logical
        exchange bytes the ``repro.comm`` layer counted for THIS trainer's
        step (empty when nothing in this run exchanges — e.g. single-host
        pjit)."""
        if not sites:
            return
        recorder.emit('comm_exchange', sites=sites)
        print('[trainer] comm exchange: ' + ' '.join(
            f"{s}:{v['bytes_per_call']}B/{v['codec']}/{v['mode']}"
            for s, v in sorted(sites.items())), flush=True)

    # -- preemption ---------------------------------------------------------

    def _install_signal_handlers(self):
        import signal

        def handler(signum, frame):
            del frame
            print(f'[trainer] caught signal {signum}: checkpoint-and-exit '
                  f'requested', flush=True)
            self._preempted = True

        for sig in (signal.SIGTERM, signal.SIGINT):
            try:
                signal.signal(sig, handler)
            except ValueError:
                pass  # not in main thread (tests)

    # -- profile-mode step ----------------------------------------------------

    def _profiled_step(self, tracker, step, data, params, opt_state):
        """One step through the phased fns under fenced spans.  Returns the
        same (params, opt_state, metrics) as the fused step, plus the
        intermediates the one-shot HLO record needs."""
        grad_fn, update_fn, apply_fn = self._phases
        with tracker.span('step', step=step) as sp_all:
            with tracker.span('data', step=step):
                batch = data.batch_at(step)
            with tracker.span('grad', step=step) as sp:
                loss, grads, stats = grad_fn(params, batch)
                sp.fence((loss, grads))
            with tracker.span('precondition', step=step) as sp:
                updates, opt_state, metrics = update_fn(grads, stats, loss,
                                                        opt_state, params)
                sp.fence(updates)
            with tracker.span('apply', step=step) as sp:
                params = apply_fn(params, updates)
                sp.fence(params)
            sp_all.fence(params)
        phase_args = {'grad': (grad_fn, (params, batch)),
                      'precondition': (update_fn, (grads, stats, loss,
                                                   opt_state, params)),
                      'apply': (apply_fn, (params, updates))}
        return params, opt_state, metrics, phase_args

    def _emit_profile(self, recorder, step, phase_args, one_shot_hlo):
        rec: dict[str, Any] = {'step': step,
                               'live_buffer_mb': obs_spans.live_buffer_mb()}
        dev = obs_spans.device_bytes_in_use()
        if dev is not None:
            rec['device_bytes_in_use'] = dev
        if one_shot_hlo:
            try:
                rec['fns'] = {
                    name: obs_spans.compiled_fn_costs(fn, *args)
                    for name, (fn, args) in phase_args.items()}
            except Exception as e:  # never fatal: HLO text formats drift
                print(f'[trainer] profile: HLO cost pass skipped ({e})',
                      flush=True)
        recorder.emit('profile', **rec)

    # -- main loop ------------------------------------------------------------

    def fit(self, params, data: Any, start_step: int = 0,
            opt_state=None, resume: bool = True):
        """``data`` must expose ``batch_at(step)`` (seekable)."""
        cfg = self.cfg
        self._install_signal_handlers()

        if resume and cfg.ckpt_every:
            latest = ckpt.latest_step(self.ckpt_dir)
            if latest is not None:
                template = {'params': params,
                            'opt_state': opt_state if opt_state is not None
                            else init_opt_state(self.model, self.opt,
                                                self.capture, params,
                                                data.batch_at(0),
                                                taps_fn=self.taps_fn,
                                                sched=self.sched,
                                                comm=self.comm,
                                                factor=self.factor,
                                                kernel=self.kernel)}
                state, meta = ckpt.restore(self.ckpt_dir, latest, template)
                params, opt_state = state['params'], state['opt_state']
                start_step = meta.get('next_step', latest)
                print(f'[trainer] resumed from step {latest}', flush=True)

        if opt_state is None:
            opt_state = init_opt_state(self.model, self.opt, self.capture,
                                       params, data.batch_at(start_step),
                                       taps_fn=self.taps_fn, sched=self.sched,
                                       comm=self.comm, factor=self.factor,
                                       kernel=self.kernel)

        # refresh count already in the (possibly restored) state — the
        # cumulative exchanged-bytes estimate below must count only THIS
        # run's refreshes, like it counts only this run's steps
        base_sched = schedrt.schedule_metrics(opt_state)
        ref_base = int(base_sched['refreshes']) if base_sched else 0

        if cfg.donate and not cfg.profile:
            # the jitted step donates its inputs; don't delete caller-owned
            # buffers (callers may reuse the initial params across runs)
            params = jax.tree_util.tree_map(
                lambda x: x + 0 if hasattr(x, 'dtype') else x, params)
            opt_state = jax.tree_util.tree_map(
                lambda x: x + 0 if hasattr(x, 'dtype') else x, opt_state)

        # The recorder owns this run's comm-counter scope: sites traced
        # while it is open belong to THIS fit (a warm-jit second fit
        # re-traces nothing → fall back to the previous fit's sites).
        recorder = obs_events.Recorder(self.metrics_path)
        self._watchdog.recorder = recorder
        tracker = obs_spans.SpanTracker(recorder)
        self._log_ownership(recorder, params, data.batch_at(start_step))
        history = []
        prev_ref = ref_base
        step = start_step
        try:
            for step in range(start_step, cfg.total_steps):
                if self._phases is not None:
                    t0 = time.perf_counter()
                    params, opt_state, metrics, phase_args = \
                        self._profiled_step(tracker, step, data, params,
                                            opt_state)
                    loss = float(metrics['loss'])
                    dt = time.perf_counter() - t0
                else:
                    batch = data.batch_at(step)
                    t0 = time.perf_counter()
                    params, opt_state, metrics = self.step_fn(params,
                                                              opt_state,
                                                              batch)
                    loss = float(metrics['loss'])  # sync point
                    dt = time.perf_counter() - t0
                if step == start_step:
                    fresh = recorder.comm_sites()
                    if fresh:
                        self._run_sites = fresh
                    self._log_comm(recorder, getattr(self, '_run_sites', {}))
                self._watchdog.observe(step, dt)
                history.append(loss)
                sched_fields = obs_events.step_fields(metrics)
                if 'refreshes' in sched_fields:
                    cur_ref = sched_fields['refreshes']
                    if cur_ref > prev_ref:
                        recorder.emit('refresh', step=step,
                                      refreshes=cur_ref,
                                      step_time_s=round(dt, 6))
                    prev_ref = cur_ref
                if step % cfg.log_every == 0 or step == cfg.total_steps - 1:
                    rec = {'step': step, 'loss': loss,
                           'grad_norm': float(metrics['grad_norm']),
                           'step_time_s': round(dt, 4), **sched_fields}
                    sched_line = ''
                    if 'refreshes' in rec:
                        sched_line = (f" refreshes {rec['refreshes']}"
                                      f" staleness {rec['staleness']:.3g}")
                    if 'pipeline_lag' in rec:
                        sched_line += f" lag {rec['pipeline_lag']}"
                    # cumulative exchanged bytes, from THIS trainer's comm
                    # sites: per-step sites (grads/stats) fire every
                    # step, refresh sites once per realized refresh
                    sites = getattr(self, '_run_sites', {})
                    if sites:
                        step_b = sum(v['bytes_per_call']
                                     for s, v in sites.items()
                                     if not s.startswith('refresh/'))
                        refresh_b = sum(v['bytes_per_call']
                                        for s, v in sites.items()
                                        if s.startswith('refresh/'))
                        rec['exchanged_mb_cum'] = round(
                            (step_b * (step + 1 - start_step)
                             + refresh_b * (rec.get('refreshes', ref_base)
                                            - ref_base))
                            / 2 ** 20, 3)
                    if self.kernel is not None:
                        rec['kernel_impl'] = self.kernel.impl
                        tiles = kdispatch.choices_snapshot()
                        if tiles:
                            rec['kernel_tiles'] = tiles
                    recorder.emit('step', **rec)
                    if self._phases is not None:
                        self._emit_profile(recorder, step, phase_args,
                                           one_shot_hlo=(step == start_step))
                    print(f'[trainer] step {step:6d} loss {loss:.4f} '
                          f'({dt*1e3:.0f} ms){sched_line}', flush=True)
                if cfg.ckpt_every and (step + 1) % cfg.ckpt_every == 0:
                    self._ckptr.save(step + 1,
                                     {'params': params, 'opt_state': opt_state},
                                     {'next_step': step + 1})
                if self._preempted:
                    print('[trainer] preemption: synchronous checkpoint at '
                          f'step {step + 1}', flush=True)
                    self._ckptr.wait()
                    ckpt.save(self.ckpt_dir, step + 1,
                              {'params': params, 'opt_state': opt_state},
                              {'next_step': step + 1, 'preempted': True})
                    break
        finally:
            self._ckptr.wait()
            self._watchdog.recorder = None
            recorder.close()
        return params, opt_state, history

    # -- elastic outer loop ---------------------------------------------------

    def fit_elastic(self, params, data: Any, world: Optional[int] = None,
                    world_fn: Optional[Callable[[int], Optional[int]]] = None,
                    start_step: int = 0, resume: bool = True):
        """Elastic training: tolerate worker-count changes *between* steps.

        The run executes as a sequence of constant-W data-parallel phases
        over a ``('data',)`` mesh of the first W local devices
        (``launch.mesh.make_data_mesh``), stepping through the explicit-DP
        ``make_dp_step``.  W starts at ``world`` (default: every local
        device) and may change two ways:

        * **restore** — a checkpoint written at a different W (its
          ``elastic`` metadata block says which, docs/CHECKPOINT_FORMAT.md)
          is restored leaf-for-leaf, then resharded;
        * **live** — ``world_fn(step)`` (None = keep current) requests a
          new W between steps, modeling workers being killed or re-added.

        Either way the loop runs restore → reshard
        (``schedule.reshard.reshard_state``: ownership re-derives from the
        new (plan, W) at trace time, in-flight pipeline buffers drain to
        the documented cold start) → rebuild mesh → re-jit → continue, and
        emits a typed ``reshard`` event plus a fresh ``refresh_ownership``
        map through ``repro.obs``.  Checkpoints written by this loop carry
        the elastic metadata block, and the preemption contract (SIGTERM →
        synchronous checkpoint → clean exit) is inherited from :meth:`fit`.

        At W=1 the trajectory is bit-identical to :meth:`fit` (size-1
        collectives are exact); across W the global batch mean is the same
        up to float reduction order.  ``profile`` mode is not supported
        here (phased spans assume the single-device step).

        Returns ``(params, opt_state, history)`` with ``history`` a list of
        ``(step, loss)`` pairs (steps matter: a resumed run starts mid-way).
        """
        from repro.launch.mesh import make_data_mesh

        cfg = self.cfg
        if cfg.profile:
            raise ValueError('profile mode is not supported by fit_elastic '
                             '(use fit for span-fenced phase profiling)')
        self._install_signal_handlers()
        world = int(world) if world else jax.device_count()

        # the bucket plan is the reshard key: ownership maps and the
        # checkpoint fingerprint both derive from it (None = first-order)
        try:
            plan = stats_plan_of(self.model, self.capture, params,
                                 data.batch_at(start_step),
                                 taps_fn=self.taps_fn)
        except Exception:
            plan = None

        def _init_state(step):
            return init_opt_state(self.model, self.opt, self.capture, params,
                                  data.batch_at(step), taps_fn=self.taps_fn,
                                  sched=self.sched, comm=self.comm,
                                  factor=self.factor, kernel=self.kernel)

        opt_state = None
        world_from = world
        source = 'init'
        if resume and cfg.ckpt_every:
            latest = ckpt.latest_step(self.ckpt_dir)
            if latest is not None:
                template = {'params': params, 'opt_state': _init_state(0)}
                state, meta = ckpt.restore(self.ckpt_dir, latest, template)
                params, opt_state = state['params'], state['opt_state']
                start_step = meta.get('next_step', latest)
                ck_world = reshard_mod.check_metadata(
                    meta.get(reshard_mod.ELASTIC_KEY),
                    plan=plan, pipeline=self.sched.pipeline)
                world_from = ck_world if ck_world else world
                source = 'checkpoint'
                print(f'[trainer] resumed from step {latest} '
                      f'(checkpoint W={world_from})', flush=True)
        if opt_state is None:
            opt_state = _init_state(start_step)

        if cfg.donate:
            # same caller-owned-buffer guard as fit: the jitted step
            # donates its inputs
            params = jax.tree_util.tree_map(
                lambda x: x + 0 if hasattr(x, 'dtype') else x, params)
            opt_state = jax.tree_util.tree_map(
                lambda x: x + 0 if hasattr(x, 'dtype') else x, opt_state)

        base_sched = schedrt.schedule_metrics(opt_state)
        ref_base = int(base_sched['refreshes']) if base_sched else 0

        recorder = obs_events.Recorder(self.metrics_path)
        self._watchdog.recorder = recorder
        step_fns: dict[int, Callable] = {}  # W -> compiled step (re-expand
                                            # to a previous W reuses it)
        step_fn = None

        check_batch_next = True  # re-validated at start and on every resize

        def _resize(w_from, w_to, at_step, src):
            nonlocal params, opt_state, step_fn, world, check_batch_next
            check_batch_next = True
            opt_state, body = reshard_mod.reshard_state(
                opt_state, world_from=w_from, world_to=w_to, plan=plan,
                step=at_step, source=src)
            mesh = make_data_mesh(w_to)
            replicated = jax.sharding.NamedSharding(
                mesh, jax.sharding.PartitionSpec())
            # explicit placement: a live shrink/grow leaves the old arrays
            # committed to the previous mesh's devices
            params = jax.device_put(params, replicated)
            opt_state = jax.device_put(opt_state, replicated)
            if w_to not in step_fns:
                dp = make_dp_step(self.model, self.opt, self.capture, mesh,
                                  taps_fn=self.taps_fn, sched=self.sched,
                                  comm=self.comm, factor=self.factor,
                                  kernel=self.kernel)
                step_fns[w_to] = jax.jit(
                    dp, donate_argnums=(0, 1) if cfg.donate else ())
            step_fn = step_fns[w_to]
            world = w_to
            if w_from != w_to:
                recorder.emit('reshard', **body)
                print(f"[trainer] reshard W={w_from} -> W={w_to} at step "
                      f"{at_step} (pipeline buffers: {body['pipeline']}, "
                      f"owners moved: {body.get('slices_moved', 0)}/"
                      f"{body.get('slices_total', 0)})", flush=True)
            own = schedrt.ownership_event(plan, world=w_to)
            if own is not None:
                recorder.emit('refresh_ownership', **own)

        _resize(world_from, world, start_step, source)

        def _meta(next_step, **extra):
            return {'next_step': next_step,
                    reshard_mod.ELASTIC_KEY: reshard_mod.elastic_metadata(
                        world, plan=plan, pipeline=self.sched.pipeline),
                    **extra}

        history: list[tuple[int, float]] = []
        prev_ref = ref_base
        first_step = True
        try:
            for step in range(start_step, cfg.total_steps):
                if world_fn is not None:
                    want = world_fn(step)
                    if want and int(want) != world:
                        _resize(world, int(want), step, 'live')
                batch = data.batch_at(step)
                if check_batch_next:
                    reshard_mod.check_batch_divisible(batch, world)
                    check_batch_next = False
                t0 = time.perf_counter()
                params, opt_state, metrics = step_fn(params, opt_state, batch)
                loss = float(metrics['loss'])  # sync point
                dt = time.perf_counter() - t0
                if first_step:
                    fresh = recorder.comm_sites()
                    if fresh:
                        self._run_sites = fresh
                    self._log_comm(recorder, getattr(self, '_run_sites', {}))
                    first_step = False
                self._watchdog.observe(step, dt)
                history.append((step, loss))
                sched_fields = obs_events.step_fields(metrics)
                if 'refreshes' in sched_fields:
                    cur_ref = sched_fields['refreshes']
                    if cur_ref > prev_ref:
                        recorder.emit('refresh', step=step, refreshes=cur_ref,
                                      step_time_s=round(dt, 6))
                    prev_ref = cur_ref
                if step % cfg.log_every == 0 or step == cfg.total_steps - 1:
                    kfields = {}
                    if self.kernel is not None:
                        kfields['kernel_impl'] = self.kernel.impl
                        tiles = kdispatch.choices_snapshot()
                        if tiles:
                            kfields['kernel_tiles'] = tiles
                    recorder.emit('step', step=step, loss=loss,
                                  grad_norm=float(metrics['grad_norm']),
                                  step_time_s=round(dt, 4), **sched_fields,
                                  **kfields)
                    print(f'[trainer] step {step:6d} loss {loss:.4f} '
                          f'({dt*1e3:.0f} ms) W={world}', flush=True)
                if cfg.ckpt_every and (step + 1) % cfg.ckpt_every == 0:
                    self._ckptr.save(step + 1,
                                     {'params': params,
                                      'opt_state': opt_state},
                                     _meta(step + 1))
                if self._preempted:
                    print('[trainer] preemption: synchronous checkpoint at '
                          f'step {step + 1}', flush=True)
                    self._ckptr.wait()
                    ckpt.save(self.ckpt_dir, step + 1,
                              {'params': params, 'opt_state': opt_state},
                              _meta(step + 1, preempted=True))
                    break
        finally:
            self._ckptr.wait()
            self._watchdog.recorder = None
            recorder.close()
        return params, opt_state, history
