"""Checkpointing: atomic, async, keep-K, elastic-reshard on restore.

Layout:
  <dir>/step_<N>/manifest.json     — leaf paths, shapes, dtypes, user metadata
  <dir>/step_<N>/<leaf-id>.npy     — one array per leaf (full logical array)
  <dir>/step_<N>/.complete         — commit marker (written last)

Writes go to ``step_<N>.tmp`` then ``os.rename`` — a crash mid-save never
corrupts the latest checkpoint.  ``AsyncCheckpointer`` snapshots to host
memory synchronously (cheap) and writes in a background thread so the train
loop is not blocked; ``wait()`` before exit.

Elastic restore: arrays are saved as full logical values and ``restore``
takes target shardings — a checkpoint written on a (16,16) mesh restores
onto (2,16,16) or a single CPU device unchanged (resharding happens in
``jax.device_put``).  On a real multi-host pod this single-file strategy
would be replaced by per-shard TensorStore writes; the manifest/commit
protocol is unchanged (noted in DESIGN.md §8).

Pytree handling: leaves are addressed by their flattened key-path string, so
any mix of dicts / NamedTuple optimizer states round-trips; ``restore``
fills a template pytree (from ``init``) leaf-by-leaf.
"""
from __future__ import annotations

import json
import os
import re
import shutil
import threading
import time
from pathlib import Path
from typing import Any, Optional

import jax
import numpy as np


def _leaf_paths(tree: Any) -> list[tuple[str, Any]]:
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    out = []
    for path, leaf in flat:
        out.append((jax.tree_util.keystr(path), leaf))
    return out


def _leaf_id(i: int) -> str:
    return f'leaf_{i:05d}'


def save(ckpt_dir: str | Path, step: int, tree: Any,
         metadata: Optional[dict] = None) -> Path:
    """Synchronous atomic save of a pytree."""
    ckpt_dir = Path(ckpt_dir)
    final = ckpt_dir / f'step_{step:08d}'
    tmp = ckpt_dir / f'step_{step:08d}.tmp'
    if tmp.exists():
        shutil.rmtree(tmp)
    tmp.mkdir(parents=True)
    leaves = _leaf_paths(tree)
    manifest = {'step': step, 'metadata': metadata or {},
                'time': time.time(), 'leaves': []}
    for i, (path, leaf) in enumerate(leaves):
        arr = np.asarray(jax.device_get(leaf))
        np.save(tmp / f'{_leaf_id(i)}.npy', arr)
        manifest['leaves'].append({'id': _leaf_id(i), 'path': path,
                                   'shape': list(arr.shape),
                                   'dtype': str(arr.dtype)})
    (tmp / 'manifest.json').write_text(json.dumps(manifest, indent=1))
    (tmp / '.complete').write_text('ok')
    if final.exists():
        shutil.rmtree(final)
    os.rename(tmp, final)
    return final


def available_steps(ckpt_dir: str | Path) -> list[int]:
    ckpt_dir = Path(ckpt_dir)
    if not ckpt_dir.exists():
        return []
    steps = []
    for d in ckpt_dir.iterdir():
        m = re.fullmatch(r'step_(\d+)', d.name)
        if m and (d / '.complete').exists():
            steps.append(int(m.group(1)))
    return sorted(steps)


def latest_step(ckpt_dir: str | Path) -> Optional[int]:
    steps = available_steps(ckpt_dir)
    return steps[-1] if steps else None


def restore(ckpt_dir: str | Path, step: int, template: Any,
            shardings: Any = None) -> tuple[Any, dict]:
    """Restore into the structure of ``template``; optional target shardings
    (same tree structure or a single sharding) reshard on load."""
    d = Path(ckpt_dir) / f'step_{step:08d}'
    manifest = json.loads((d / 'manifest.json').read_text())
    by_path = {l['path']: l for l in manifest['leaves']}

    flat, treedef = jax.tree_util.tree_flatten_with_path(template)
    if shardings is not None and not isinstance(shardings, jax.sharding.Sharding):
        shard_flat = [s for _, s in
                      jax.tree_util.tree_flatten_with_path(shardings)[0]]
    else:
        shard_flat = [shardings] * len(flat)

    leaves = []
    for (path, leaf), shard in zip(flat, shard_flat):
        key = jax.tree_util.keystr(path)
        if key not in by_path:
            raise KeyError(f'checkpoint missing leaf {key}')
        arr = np.load(d / f'{by_path[key]["id"]}.npy')
        if tuple(arr.shape) != tuple(leaf.shape):
            raise ValueError(f'{key}: shape {arr.shape} != template {leaf.shape}')
        if shard is not None:
            leaves.append(jax.device_put(arr, shard))
        else:
            leaves.append(jax.device_put(arr))
    return jax.tree_util.tree_unflatten(treedef, leaves), manifest['metadata']


def gc_old(ckpt_dir: str | Path, keep: int) -> None:
    steps = available_steps(ckpt_dir)
    for s in steps[:-keep] if keep > 0 else []:
        shutil.rmtree(Path(ckpt_dir) / f'step_{s:08d}', ignore_errors=True)


class AsyncCheckpointer:
    """Snapshot-to-host synchronously, write in a background thread."""

    def __init__(self, ckpt_dir: str | Path, keep: int = 3):
        self.ckpt_dir = Path(ckpt_dir)
        self.keep = keep
        self._thread: Optional[threading.Thread] = None
        self.last_error: Optional[BaseException] = None

    def save(self, step: int, tree: Any, metadata: Optional[dict] = None) -> None:
        self.wait()
        host_tree = jax.tree_util.tree_map(
            lambda x: np.asarray(jax.device_get(x)), tree)

        def _write():
            try:
                save(self.ckpt_dir, step, host_tree, metadata)
                gc_old(self.ckpt_dir, self.keep)
            except BaseException as e:  # noqa: BLE001
                self.last_error = e

        self._thread = threading.Thread(target=_write, daemon=True)
        self._thread.start()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self.last_error is not None:
            err, self.last_error = self.last_error, None
            raise err
