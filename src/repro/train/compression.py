"""Error-feedback int8 gradient compression for the explicit-DP engine.

The pjit path leaves gradient reduction to XLA (recorded in the roofline).
This engine makes the data-parallel collective explicit via ``shard_map``
over the 'data' axis so it can be compressed: per-tensor global max-scale
(one scalar all-reduce), int8 quantize, int32-accumulate all-reduce, then
dequantize — with the quantization residual carried as local error feedback
(Karimireddy et al.-style EF-SGD), which keeps convergence intact.

8× less gradient traffic than f32 / 2× less than bf16 all-reduce; combined
with Eva's sublinear KV all-reduce this is the paper's distributed story
(§3.3) plus a beyond-paper compression layer.
"""
from __future__ import annotations

import functools
from typing import Any, Optional

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.core import kv as kvlib
from repro.core.transform import Extras, apply_updates
from repro.sharding import compat
from repro.train.step import _plan_for_stats, compute_grads_and_stats


def quantize_allreduce(g: jnp.ndarray, err: jnp.ndarray,
                       axis: str) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Mean-all-reduce of ``g`` over ``axis`` with int8 error feedback.

    Returns (averaged dequantized gradient, new local error)."""
    x = g.astype(jnp.float32) + err
    scale = jax.lax.pmax(jnp.max(jnp.abs(x)), axis) / 127.0
    scale = jnp.maximum(scale, 1e-12)
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    deq_local = q.astype(jnp.float32) * scale
    new_err = x - deq_local
    total = jax.lax.psum(q.astype(jnp.int32), axis)
    n = jax.lax.psum(jnp.ones((), jnp.int32), axis)
    return (total.astype(jnp.float32) * scale) / n.astype(jnp.float32), new_err


def make_dp_train_step(model, opt, capture: kvlib.CaptureConfig, mesh,
                       compress: bool = True, taps_fn=None):
    """Explicit data-parallel train step via shard_map over 'data'.

    Params/opt-state replicated; the batch is split over 'data'; gradients
    are explicitly all-reduced (int8+EF when ``compress``).  KV statistics
    are mean-all-reduced uncompressed — they are sublinear (the paper's
    point).  Returns (step_fn, init_error_fn)."""

    def local_step(params, opt_state, err, batch):
        loss, grads, stats = compute_grads_and_stats(
            model, params, batch, capture,
            taps_fn(params) if taps_fn else None)
        loss = jax.lax.pmean(loss, 'data')
        if compress:
            pairs = jax.tree_util.tree_map(
                lambda g, e: quantize_allreduce(g, e, 'data'), grads, err,
                is_leaf=lambda x: isinstance(x, jnp.ndarray))
            grads = jax.tree_util.tree_map(lambda p: p[0], pairs,
                                           is_leaf=lambda x: isinstance(x, tuple))
            new_err = jax.tree_util.tree_map(lambda p: p[1], pairs,
                                             is_leaf=lambda x: isinstance(x, tuple))
        else:
            grads = jax.tree_util.tree_map(
                lambda g: jax.lax.pmean(g.astype(jnp.float32), 'data'), grads)
            new_err = err
        if stats is not None:
            stats = jax.tree_util.tree_map(
                lambda s: jax.lax.pmean(s, 'data'), stats)
        updates, new_opt = opt.update(
            grads, opt_state, params=params,
            extras=Extras(stats=stats, loss=loss,
                          plan=_plan_for_stats(grads, stats)))
        new_params = apply_updates(params, updates)
        return new_params, new_opt, new_err, {'loss': loss}

    in_specs = (P(), P(), P(), P('data'))
    out_specs = (P(), P(), P(), P())
    smapped = compat.shard_map(local_step, mesh=mesh, in_specs=in_specs,
                               out_specs=out_specs, check=False)

    def init_error(params):
        return jax.tree_util.tree_map(
            lambda p: jnp.zeros(p.shape, jnp.float32), params)

    return jax.jit(smapped), init_error
