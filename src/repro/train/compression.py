"""Error-feedback compressed gradient exchange for the explicit-DP engine.

The pjit path leaves gradient reduction to XLA (recorded in the roofline).
This engine makes the data-parallel collective explicit via ``shard_map``
over the 'data' axis so it can be codec'd: since the unified communication
layer landed, both the gradient all-reduce and the KV/KF statistics
reduction route through ``repro.comm`` — this module is the thin
train-level wrapper that picks codecs and threads the error-feedback
residual state.

Default is the int8 symmetric max-scale codec with carried error feedback
(Karimireddy et al.-style EF-SGD, which keeps convergence intact): 8× less
gradient traffic than f32 / 2× less than bf16.  Combined with Eva's
sublinear KV all-reduce this is the paper's distributed story (§3.3) plus
a beyond-paper compression layer.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Optional

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.comm import exchange
from repro.core import kv as kvlib
from repro.core.transform import Extras, apply_updates
from repro.schedule import pipeline as pipemod
from repro.sharding import compat
from repro.train.step import _plan_for_stats, compute_grads_and_stats


def quantize_allreduce(g: jnp.ndarray, err: jnp.ndarray,
                       axis: str) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Mean-all-reduce of ``g`` over ``axis`` with int8 error feedback.

    Thin wrapper over the int8+EF codec's all-reduce
    (``repro.comm.exchange.allreduce_mean_leaf``) — same op sequence as the
    historical inline implementation: global pmax scale, int8 quantize,
    exact int32-accumulate psum, shared-scale dequantize.

    Returns (averaged dequantized gradient, new local error)."""
    mean, new_err, _ = exchange.allreduce_mean_leaf(
        g, err, codec='int8', axes=(axis,))
    return mean, new_err


def make_dp_train_step(model, opt, capture: kvlib.CaptureConfig, mesh,
                       compress: bool = True, taps_fn=None,
                       comm: Optional[exchange.ExchangeConfig] = None,
                       sched=None):
    """Explicit data-parallel train step via shard_map over 'data'.

    Params/opt-state replicated; the batch is split over 'data'; gradients
    are explicitly all-reduced through ``comm.grads`` (int8+EF by default —
    the legacy ``compress`` flag maps onto the f32/int8 codecs) and the KV
    statistics through ``comm.stats`` (f32 by default — they are sublinear,
    the paper's point).  The same config threads to the optimizer through
    ``Extras.comm`` so the refresh exchange uses it too.  The step's
    metrics include ``comm_saturation`` — the int8 codec's overflow
    fraction, 0.0 by construction under the global max scale.

    ``sched`` (a ``RefreshRuntime``) threads through ``Extras.sched`` —
    pass the same one given to ``init_opt_state``; with
    ``pipeline='onestep'`` the optimizer's curvature exchanges double-buffer
    and the metrics gain the realized ``pipeline_lag`` per site.

    Returns (step_fn, init_error_fn)."""
    if comm is not None:
        from repro.comm import get_codec
        if not compress and get_codec(comm.grads).name != 'f32':
            raise ValueError(
                "conflicting arguments: compress=False but comm.grads="
                f"{comm.grads!r}; pass ExchangeConfig(grads='f32') (or drop "
                "compress=False) to say which you mean")
        cfg = comm
    else:
        cfg = exchange.ExchangeConfig(grads='int8' if compress else 'f32')

    def local_step(params, opt_state, err, batch):
        loss, grads, stats = compute_grads_and_stats(
            model, params, batch, capture,
            taps_fn(params) if taps_fn else None)
        loss = jax.lax.pmean(loss, 'data')
        grads, new_err, info = exchange.allreduce_mean_tree(
            grads, err, codec=cfg.grads, axes=('data',), site='grads/dp')
        new_err = new_err if new_err is not None else err
        # axes passed explicitly — the 'data' axis is statically known here,
        # so the reduction must not depend on the best-effort axis-env probe
        # behind pmean_stats (a false-negative there would silently leave
        # per-worker stats unreduced and desync the replicated opt state)
        stats, _, _ = exchange.allreduce_mean_tree(
            stats, codec=cfg.stats, axes=('data',), site='stats/dp')
        # stats were just reduced; lossy codecs must quantize exactly once,
        # so the optimizer's own pmean_stats call (same shard_map scope)
        # gets the idempotent f32 path
        inner = dataclasses.replace(cfg, stats='f32')
        updates, new_opt = opt.update(
            grads, opt_state, params=params,
            extras=Extras(stats=stats, loss=loss,
                          plan=_plan_for_stats(grads, stats), comm=inner,
                          sched=sched))
        new_params = apply_updates(params, updates)
        metrics = {'loss': loss, 'comm_saturation': info['saturation']}
        metrics.update(pipemod.pipeline_metrics(new_opt))
        return new_params, new_opt, new_err, metrics

    in_specs = (P(), P(), P(), P('data'))
    out_specs = (P(), P(), P(), P())
    smapped = compat.shard_map(local_step, mesh=mesh, in_specs=in_specs,
                               out_specs=out_specs, check=False)

    def init_error(params):
        return jax.tree_util.tree_map(
            lambda p: jnp.zeros(p.shape, jnp.float32), params)

    return jax.jit(smapped), init_error
