from repro.train.checkpoint import (AsyncCheckpointer, available_steps, gc_old,
                                    latest_step, restore, save)
from repro.train.step import (abstract_opt_state, compute_grads_and_stats,
                              init_opt_state, make_train_step, stats_plan_of)
from repro.train.trainer import Trainer, TrainerConfig

__all__ = ['AsyncCheckpointer', 'available_steps', 'gc_old', 'latest_step',
           'restore', 'save', 'abstract_opt_state', 'compute_grads_and_stats',
           'init_opt_state', 'make_train_step', 'stats_plan_of', 'Trainer',
           'TrainerConfig']
