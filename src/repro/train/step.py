"""Train-step factory: loss → (grads, tap-grads) → KV stats → optimizer.

The returned ``train_step(params, opt_state, batch)`` is a pure function —
jit/pjit it, donate params/opt_state, shard it with the production mesh.
``abstract_opt_state`` mirrors the same wiring under ``eval_shape`` so the
dry-run can lower a 1T-param step without allocating anything.
"""
from __future__ import annotations

import functools
import inspect
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp

from repro.core import bucketing
from repro.core import kv as kvlib
from repro.core import factor_sharded as fsh
from repro.core.transform import Extras, GradientTransformation, apply_updates
from repro.schedule import pipeline as pipemod, runtime as schedrt


def _plan_for_stats(params_or_grads, stats) -> Optional[bucketing.BucketPlan]:
    """The bucket plan over captured (= preconditioned) paths — built once
    here at init time and threaded to the optimizer through ``Extras.plan``
    (re-derivations inside jitted updates hit the memo cache)."""
    if stats is None:
        return None
    flat = kvlib.flatten_params(params_or_grads)
    return bucketing.build_plan({p: flat[p] for p in stats if p in flat})


def taps_caller(taps_fn: Optional[Callable]) -> Callable:
    """Normalize a taps factory to ``(params, batch) -> taps``.

    Legacy callers close over the global batch size
    (``lambda p: model.make_taps(32, capture)``), which breaks under the
    explicit-DP step where each worker sees ``batch/W`` rows — a
    batch-aware ``taps_fn(params, batch)`` sizes the taps from the batch it
    is actually handed (global under ``make_train_step``, the local shard
    under ``make_dp_step``).  Arity is inspected once at factory time, not
    per trace."""
    if taps_fn is None:
        return lambda params, batch: None
    try:
        n_args = len(inspect.signature(taps_fn).parameters)
    except (TypeError, ValueError):
        n_args = 1
    if n_args >= 2:
        return taps_fn
    return lambda params, batch: taps_fn(params)


def _default_make_taps(model, params, capture: kvlib.CaptureConfig):
    if not capture.needs_taps:
        return None
    if hasattr(model, 'make_taps'):
        # simple models: batch-size-dependent full taps are bound later
        raise ValueError('models with custom make_taps need explicit taps '
                         '(use make_train_step(..., taps_fn=...))')
    if capture.b == 'outer':
        # K-FAC needs the z-shaped cotangent; a silent vector-tap fallback
        # here folded the scan path dim into the token axis (wrong stats
        # AND shape-mismatched lax.cond branches in sharded_refresh)
        raise ValueError("capture.b='outer' needs full z-shaped taps — "
                         "pass taps_fn (see kv.make_full_taps)")
    flat = kvlib.flatten_params(params)
    return kvlib.make_vector_taps(params, set(model.precon_paths()) & set(flat))


def compute_grads_and_stats(model, params, batch,
                            capture: kvlib.CaptureConfig,
                            taps: Optional[dict] = None):
    """Shared by train_step and abstract shape derivation."""
    if capture.needs_taps:
        if taps is None:
            taps = _default_make_taps(model, params, capture)

        def lf(p, t):
            return model.loss_fn(p, t, batch, capture)

        (loss, aux), (grads, tap_grads) = jax.value_and_grad(
            lf, argnums=(0, 1), has_aux=True)(params, taps)
    else:
        def lf(p):
            return model.loss_fn(p, None, batch, capture)

        (loss, aux), grads = jax.value_and_grad(lf, has_aux=True)(params)
        tap_grads = None

    stats = None
    if capture.active:
        stats = kvlib.finalize_stats(aux['stats'], tap_grads, capture,
                                     n_tokens=jnp.asarray(aux['n_tokens'],
                                                          jnp.float32))
    return loss, grads, stats


def make_train_step(model, opt: GradientTransformation,
                    capture: kvlib.CaptureConfig,
                    taps_fn: Optional[Callable] = None,
                    donate: bool = True,
                    microbatches: int = 1,
                    sched: Optional[schedrt.RefreshRuntime] = None,
                    comm: Optional[Any] = None,
                    factor: Optional[Any] = None,
                    kernel: Optional[Any] = None) -> Callable:
    """Build the pure train step.  ``taps_fn(params)`` overrides tap creation
    (needed for full-tap K-FAC on the simple models).

    ``sched`` is the curvature refresh runtime threaded through ``Extras``
    next to the bucket plan (train-level default policy + worker-sharded
    refresh switch); pass the same runtime to ``init_opt_state`` so the
    scheduling state is allocated for the policy that will actually run.

    ``comm`` is the train-level ``repro.comm.ExchangeConfig`` threaded
    through ``Extras.comm``: which codec the statistics reduction and the
    owned-slice curvature-refresh exchange use under a live data-parallel
    mesh (None = defaults: f32 wire, owned-slice all-gather refresh).

    ``factor`` is the ``repro.core.factor_sharded.FactorShardConfig``
    threaded through ``Extras.factor``: the per-factor oversized-Kronecker
    policy (``head_policy='shard'|'exclude'|'dense'``).  None keeps every
    factor on the dense legacy path, bit-exactly.

    ``kernel`` is a ``repro.kernels.dispatch.KernelConfig`` threaded
    through ``Extras.kernel``: the per-step kernel impl request
    (auto/pallas/xla dispatch + autotune-cache tiles).  None keeps the
    optimizers on their own ``use_pallas``/``kernel_impl`` defaults.

    ``microbatches > 1`` runs gradient accumulation: the global batch is
    split on dim 0 and scanned, summing grads (f32) and averaging KV stats.
    This is what bounds activation memory at the 1T-param shape cells —
    saved-residual and MoE-dispatch peaks shrink by the microbatch factor
    (§Perf memory iteration)."""
    sched = sched if sched is not None else schedrt.RefreshRuntime()
    make_taps = taps_caller(taps_fn)

    def grads_of(params, batch):
        return compute_grads_and_stats(model, params, batch, capture,
                                       make_taps(params, batch))

    def train_step(params, opt_state, batch):
        if microbatches > 1:
            split = jax.tree_util.tree_map(
                lambda x: x.reshape((microbatches, x.shape[0] // microbatches)
                                    + x.shape[1:]), batch)

            def acc(carry, mb):
                g_acc, s_acc, l_acc = carry
                loss, grads, stats = grads_of(params, mb)
                g_acc = jax.tree_util.tree_map(
                    lambda a, g: a + g.astype(a.dtype), g_acc, grads)
                if stats is not None:
                    s_acc = jax.tree_util.tree_map(
                        lambda a, s: a + s.astype(jnp.float32), s_acc, stats)
                return (g_acc, s_acc, l_acc + loss), None

            g0 = jax.tree_util.tree_map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)
            s_shapes = jax.eval_shape(
                lambda p, b: grads_of(p, b)[2], params,
                jax.tree_util.tree_map(lambda x: x[0], split))
            s0 = (jax.tree_util.tree_map(
                lambda s: jnp.zeros(s.shape, jnp.float32), s_shapes)
                if capture.active else None)
            (g_sum, s_sum, l_sum), _ = jax.lax.scan(
                acc, (g0, s0, jnp.zeros((), jnp.float32)), split)
            inv = 1.0 / microbatches
            grads = jax.tree_util.tree_map(lambda g: g * inv, g_sum)
            stats = (jax.tree_util.tree_map(lambda s: s * inv, s_sum)
                     if s_sum is not None else None)
            loss = l_sum * inv
        else:
            loss, grads, stats = grads_of(params, batch)

        updates, new_opt_state = opt.update(
            grads, opt_state, params=params,
            extras=Extras(stats=stats, loss=loss,
                          plan=_plan_for_stats(grads, stats), sched=sched,
                          comm=comm, factor=factor, kernel=kernel))
        new_params = apply_updates(params, updates)
        grad_norm = jnp.sqrt(sum(
            jnp.sum(jnp.square(g.astype(jnp.float32)))
            for g in jax.tree_util.tree_leaves(grads)))
        metrics = {'loss': loss, 'grad_norm': grad_norm}
        # refresh-runtime observability: cumulative refreshes / staleness of
        # every scheduled transform in the state ({} for unscheduled opts)
        metrics.update(schedrt.schedule_metrics(new_opt_state))
        # realized pipeline staleness per exchange site ({} in sync mode)
        metrics.update(pipemod.pipeline_metrics(new_opt_state))
        # sharded-factor telemetry ({} unless a factor policy tripped)
        metrics.update(fsh.step_metrics(new_opt_state))
        return new_params, new_opt_state, metrics

    return train_step


def make_dp_step(model, opt: GradientTransformation,
                 capture: kvlib.CaptureConfig, mesh,
                 taps_fn: Optional[Callable] = None,
                 sched: Optional[schedrt.RefreshRuntime] = None,
                 comm: Optional[Any] = None,
                 factor: Optional[Any] = None,
                 kernel: Optional[Any] = None) -> Callable:
    """Explicit data-parallel train step over ``mesh``'s ``'data'`` axis —
    the elastic trainer's engine (``train/trainer.py::Trainer.fit_elastic``).

    Params/opt-state replicated, the global batch split over ``'data'``:
    the loss is ``pmean``'d and the gradients mean-all-reduced in f32
    (site ``grads/dp``), KV statistics likewise (site ``stats/dp``, axes
    passed explicitly for the same false-negative-probe reason as
    ``train/compression.py`` — the optimizer's own ``staged_pmean`` over
    already-identical values is then exact and idempotent).  The
    optimizer's update runs with the ``'data'`` axis bound, so
    worker-sharded refresh and the owned-slice exchange see
    ``world = mesh 'data' size`` — re-jitting this step under a resized
    mesh *is* the ownership reshard (``schedule/reshard.py``).

    At W=1 every collective reduces over a size-1 axis (``psum`` of one
    shard, divide by 1 — exact), so the trajectory is bit-identical to
    ``make_train_step``: the non-elastic trainer is the W=1 special case,
    not a separate code path.  Same metrics contract as
    ``make_train_step``."""
    sched = sched if sched is not None else schedrt.RefreshRuntime()
    make_taps = taps_caller(taps_fn)
    from jax.sharding import PartitionSpec as P

    from repro.comm import exchange
    from repro.sharding import compat

    def local_step(params, opt_state, batch):
        # NOTE: batch here is the per-worker shard — a batch-aware taps_fn
        # (see taps_caller) sizes full taps to batch/W rows
        loss, grads, stats = compute_grads_and_stats(
            model, params, batch, capture, make_taps(params, batch))
        loss = jax.lax.pmean(loss, 'data')
        grads, _, _ = exchange.allreduce_mean_tree(
            grads, codec='f32', axes=('data',), site='grads/dp')
        if stats is not None:
            stats, _, _ = exchange.allreduce_mean_tree(
                stats, codec='f32', axes=('data',), site='stats/dp')
        updates, new_opt_state = opt.update(
            grads, opt_state, params=params,
            extras=Extras(stats=stats, loss=loss,
                          plan=_plan_for_stats(grads, stats), sched=sched,
                          comm=comm, factor=factor, kernel=kernel))
        new_params = apply_updates(params, updates)
        grad_norm = jnp.sqrt(sum(
            jnp.sum(jnp.square(g.astype(jnp.float32)))
            for g in jax.tree_util.tree_leaves(grads)))
        metrics = {'loss': loss, 'grad_norm': grad_norm}
        metrics.update(schedrt.schedule_metrics(new_opt_state))
        metrics.update(pipemod.pipeline_metrics(new_opt_state))
        metrics.update(fsh.step_metrics(new_opt_state))
        return new_params, new_opt_state, metrics

    return compat.shard_map(local_step, mesh=mesh,
                            in_specs=(P(), P(), P('data')),
                            out_specs=(P(), P(), P()), check=False)


def make_phased_step(model, opt: GradientTransformation,
                     capture: kvlib.CaptureConfig,
                     taps_fn: Optional[Callable] = None,
                     sched: Optional[schedrt.RefreshRuntime] = None,
                     comm: Optional[Any] = None,
                     factor: Optional[Any] = None,
                     kernel: Optional[Any] = None
                     ) -> tuple[Callable, Callable, Callable]:
    """The train step split at phase boundaries for span-level timing
    (``repro.obs``): grad → precondition (= optimizer update, where the
    curvature refresh/exchange live) → apply.

    Returns ``(grad_fn, update_fn, apply_fn)`` with
      ``grad_fn(params, batch) -> (loss, grads, stats)``
      ``update_fn(grads, stats, loss, opt_state, params)
          -> (updates, new_opt_state, metrics)``
      ``apply_fn(params, updates) -> new_params``
    whose composition is semantically identical to
    ``make_train_step(microbatches=1)``.  Each piece jits separately so a
    host-side span with a ``block_until_ready`` fence can attribute wall
    time per phase; nothing is donated (profile mode trades the in-place
    update for measurability — see the README overhead caveats).
    """
    sched = sched if sched is not None else schedrt.RefreshRuntime()
    make_taps = taps_caller(taps_fn)

    def grad_fn(params, batch):
        return compute_grads_and_stats(model, params, batch, capture,
                                       make_taps(params, batch))

    def update_fn(grads, stats, loss, opt_state, params):
        updates, new_opt_state = opt.update(
            grads, opt_state, params=params,
            extras=Extras(stats=stats, loss=loss,
                          plan=_plan_for_stats(grads, stats), sched=sched,
                          comm=comm, factor=factor, kernel=kernel))
        grad_norm = jnp.sqrt(sum(
            jnp.sum(jnp.square(g.astype(jnp.float32)))
            for g in jax.tree_util.tree_leaves(grads)))
        metrics = {'loss': loss, 'grad_norm': grad_norm}
        metrics.update(schedrt.schedule_metrics(new_opt_state))
        metrics.update(pipemod.pipeline_metrics(new_opt_state))
        # sharded-factor telemetry ({} unless a factor policy tripped)
        metrics.update(fsh.step_metrics(new_opt_state))
        return updates, new_opt_state, metrics

    def apply_fn(params, updates):
        return apply_updates(params, updates)

    return grad_fn, update_fn, apply_fn


def init_opt_state(model, opt: GradientTransformation,
                   capture: kvlib.CaptureConfig, params, batch,
                   taps_fn: Optional[Callable] = None,
                   sched: Optional[schedrt.RefreshRuntime] = None,
                   comm: Optional[Any] = None,
                   factor: Optional[Any] = None,
                   kernel: Optional[Any] = None):
    """Materialized optimizer state (examples/trainer).  ``batch`` may be
    arrays or ShapeDtypeStructs — stats shapes come from eval_shape."""
    sched = sched if sched is not None else schedrt.RefreshRuntime()
    if not capture.active:
        return opt.init(params, Extras(sched=sched, comm=comm,
                                       factor=factor, kernel=kernel))
    make_taps = taps_caller(taps_fn)

    def stats_of(p, b):
        _, _, stats = compute_grads_and_stats(model, p, b, capture,
                                              make_taps(p, b))
        return stats

    stats_shapes = jax.eval_shape(stats_of, params, batch)
    zero_stats = jax.tree_util.tree_map(
        lambda s: jnp.zeros(s.shape, s.dtype), stats_shapes)
    return opt.init(params, Extras(stats=zero_stats,
                                   plan=_plan_for_stats(params, zero_stats),
                                   sched=sched, comm=comm, factor=factor,
                                   kernel=kernel))


def stats_plan_of(model, capture: kvlib.CaptureConfig, params, batch,
                  taps_fn: Optional[Callable] = None
                  ) -> Optional[bucketing.BucketPlan]:
    """The bucket plan over preconditioned paths, without materializing any
    state (trainer logging: the refresh-ownership map is keyed by it)."""
    if not capture.active:
        return None
    make_taps = taps_caller(taps_fn)

    def stats_of(p, b):
        return compute_grads_and_stats(model, p, b, capture,
                                       make_taps(p, b))[2]

    stats_shapes = jax.eval_shape(stats_of, params, batch)
    return _plan_for_stats(params, stats_shapes)


def abstract_opt_state(model, opt: GradientTransformation,
                       capture: kvlib.CaptureConfig, params_abstract, batch_specs,
                       taps_fn: Optional[Callable] = None,
                       sched: Optional[schedrt.RefreshRuntime] = None,
                       comm: Optional[Any] = None,
                       factor: Optional[Any] = None,
                       kernel: Optional[Any] = None):
    """ShapeDtypeStruct pytree of the optimizer state (dry-run path)."""
    def init_fn(p, b):
        return init_opt_state(model, opt, capture, p, b, taps_fn, sched=sched,
                              comm=comm, factor=factor, kernel=kernel)
    return jax.eval_shape(init_fn, params_abstract, batch_specs)
