"""M-FAC baseline [Frantar et al. 2021]: matrix-free FIM from a sliding
window of m gradient copies.

We implement the mathematically-equivalent Woodbury form
``F^{-1}v = (1/λ)[v − Bᵀ((mλ)I + BBᵀ)^{-1} B v]`` with ``B (m, P)`` the
gradient history — O(mP) memory, exactly the cost the paper's Table 1/§5.3
charges M-FAC with (we default m=32; the suggested m=1024 is the
out-of-memory case the paper cites).
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core import kv as kvlib
from repro.core.transform import (Extras, GradientTransformation, chain,
                                  scale_by_schedule, trace)


class MfacState(NamedTuple):
    buffer: jnp.ndarray   # (m, P) gradient history
    filled: jnp.ndarray   # number of valid rows
    head: jnp.ndarray     # ring-buffer write index


def _flatten_all(tree) -> jnp.ndarray:
    leaves = jax.tree_util.tree_leaves(tree)
    return jnp.concatenate([l.reshape(-1).astype(jnp.float32) for l in leaves])


def _unflatten_all(vec: jnp.ndarray, like) -> dict:
    leaves, treedef = jax.tree_util.tree_flatten(like)
    out, off = [], 0
    for l in leaves:
        n = l.size
        out.append(vec[off:off + n].reshape(l.shape).astype(l.dtype))
        off += n
    return jax.tree_util.tree_unflatten(treedef, out)


def mfac_preconditioner(m: int = 32, lam: float = 1e-3) -> GradientTransformation:

    def init(params, extras: Extras | None = None):
        del extras
        p_total = sum(l.size for l in jax.tree_util.tree_leaves(params))
        return MfacState(buffer=jnp.zeros((m, p_total), jnp.float32),
                         filled=jnp.zeros((), jnp.int32),
                         head=jnp.zeros((), jnp.int32))

    def update(updates, state: MfacState, params=None, extras: Extras | None = None):
        del params, extras
        g = _flatten_all(updates)
        buf = jax.lax.dynamic_update_slice(state.buffer, g[None, :], (state.head, 0))
        filled = jnp.minimum(state.filled + 1, m)
        head = (state.head + 1) % m
        # mask out unfilled rows
        row_ids = jnp.arange(m)
        valid = (row_ids < filled).astype(jnp.float32)
        b = buf * valid[:, None]
        # F = λI + (1/m')ΣggT ; Woodbury with m' = filled
        mp = jnp.maximum(filled.astype(jnp.float32), 1.0)
        gram = (b @ b.T) / mp                       # (m, m)
        core = gram + lam * jnp.eye(m) + (1 - valid)[:, None] * jnp.eye(m)
        bv = b @ g / mp
        x = jnp.linalg.solve(core, bv)
        pvec = (g - b.T @ x) / lam
        return _unflatten_all(pvec, updates), MfacState(buffer=buf, filled=filled, head=head)

    return GradientTransformation(init, update)


def mfac(lr=0.1, m: int = 32, lam: float = 1e-3,
         momentum: float = 0.9) -> GradientTransformation:
    return chain(
        mfac_preconditioner(m, lam),
        trace(momentum),
        scale_by_schedule(lr if callable(lr) else (lambda _: lr)),
    )


CAPTURE = kvlib.NO_CAPTURE
