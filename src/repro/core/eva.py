"""Eva (paper §3): rank-one Kronecker-vector preconditioning.

``eva_preconditioner`` is the composable transform (running-average KVs +
Sherman–Morrison update, Eq. 13-15); ``eva`` is the full paper optimizer:
``precondition → KL clip → momentum → (weight decay) → -lr``.

Preconditioning is *bucketed* (``core/bucketing``): parameter paths group by
(shape, dtype) and each bucket runs ONE broadcast/grid-folded call through
``precondition.precondition_tree`` — no per-path Python loop.  KV running
stats live bucket-stacked in state and EMA at bucket level; when a
data-parallel mesh axis is live (shard_map/pmap), fresh statistics are
psum-averaged across ('pod','data') first, making them batch-global as in
the paper's multi-GPU setup.
"""
from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.core import bucketing
from repro.core import kv as kvlib
from repro.core import precondition as pre
from repro.core.clipping import kl_clip_trace
from repro.core.transform import (Extras, GradientTransformation, chain,
                                  add_decayed_weights, ema_trace,
                                  scale_by_schedule)
from repro.sharding.constraints import pmean_stats


class EvaState(NamedTuple):
    running: kvlib.RunningStats


def _zeros_like_spec(tree):
    return jax.tree_util.tree_map(
        lambda x: jnp.zeros(x.shape, jnp.float32), tree)


def _extract(stats: dict, fields: tuple[str, ...]) -> dict:
    """Keep only the requested LayerStats fields (None elsewhere)."""
    out = {}
    for path, st in stats.items():
        out[path] = kvlib.LayerStats(**{f: getattr(st, f) for f in fields})
    return out


def _stats_plan(flat_updates: dict, stats: dict,
                extras: Optional[Extras]) -> bucketing.BucketPlan:
    """The bucket plan over the preconditioned (= captured) paths; uses the
    plan built at init_opt_state time when threaded through Extras, else
    re-derives it (memoized on the shape signature)."""
    if extras is not None and extras.plan is not None:
        return extras.plan
    return bucketing.build_plan({p: flat_updates[p] for p in stats
                                 if p in flat_updates})


def eva_preconditioner(gamma: float = 0.03, kv_decay: float = 0.95,
                       use_pallas: bool = False) -> GradientTransformation:
    """Bucketed P = (G − (b̄ᵀGā)/(γ+‖ā‖²‖b̄‖²)·āb̄ᵀ)/γ with EMA'd KVs."""

    fields = ('a_mean', 'b_mean')

    def init(params, extras: Extras | None = None):
        if extras is None or extras.stats is None:
            raise ValueError('eva_preconditioner.init needs example stats '
                             '(pass Extras(stats=...) — see train.make_train_step)')
        flat = kvlib.flatten_params(params)
        plan = _stats_plan(flat, extras.stats, extras)
        zeros = _zeros_like_spec(_extract(extras.stats, fields))
        return EvaState(running=kvlib.init_running(
            bucketing.gather_tree(plan, zeros)))

    def update(updates, state: EvaState, params=None, extras: Extras | None = None):
        del params
        flat = kvlib.flatten_params(updates)
        fresh_flat = _extract(extras.stats, fields)
        plan = _stats_plan(flat, fresh_flat, extras)
        fresh = pmean_stats(bucketing.gather_tree(plan, fresh_flat))
        stats, running = kvlib.update_running(state.running, fresh, kv_decay)
        out = pre.precondition_tree(flat, stats, 'eva', gamma, plan=plan,
                                    use_pallas=use_pallas)
        return kvlib.unflatten_params(out), EvaState(running=running)

    return GradientTransformation(init, update)


def eva(lr=0.1, gamma: float = 0.03, kv_decay: float = 0.95,
        kl_kappa: float = 1e-3, momentum: float = 0.9,
        weight_decay: float = 0.0, nesterov: bool = False,
        use_pallas: bool = False) -> GradientTransformation:
    """The full Eva optimizer as evaluated in the paper (§5)."""
    parts = []
    if weight_decay:
        # L2 regularization enters the gradient *before* preconditioning,
        # matching the reference implementation (grad += wd * w pre-hook).
        parts.append(add_decayed_weights(weight_decay))
    parts.append(eva_preconditioner(gamma, kv_decay, use_pallas=use_pallas))
    if kl_kappa is not None:
        # momentum lives INSIDE the trust region (see clipping.kl_clip_trace)
        parts.append(kl_clip_trace(kl_kappa, lr, momentum, nesterov=nesterov))
    else:
        # unit-gain momentum: same equal-lr step-scale convention as every
        # other chain in the registry (see transform.ema_trace)
        parts.append(ema_trace(momentum, nesterov=nesterov))
    parts.append(scale_by_schedule(lr if callable(lr) else (lambda _: lr)))
    return chain(*parts)


CAPTURE = kvlib.EVA_CAPTURE
