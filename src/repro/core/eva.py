"""Eva (paper §3): rank-one Kronecker-vector preconditioning.

``eva_preconditioner`` is the composable transform (running-average KVs +
Sherman–Morrison update, Eq. 13-15); ``eva`` is the full paper optimizer:
``precondition → KL clip → momentum → (weight decay) → -lr``.

Preconditioning is *bucketed* (``core/bucketing``): parameter paths group by
(shape, dtype) and each bucket runs ONE broadcast/grid-folded call through
``precondition.precondition_tree`` — no per-path Python loop.  KV running
stats live bucket-stacked in state and EMA at bucket level; when a
data-parallel mesh axis is live (shard_map/pmap), fresh statistics are
psum-averaged across ('pod','data') first, making them batch-global as in
the paper's multi-GPU setup.
"""
from __future__ import annotations

from typing import Any, NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.core import bucketing
from repro.core import kv as kvlib
from repro.core import precondition as pre
from repro.core.clipping import finish_kl_clip, kl_clip_trace
from repro.core.transform import (Extras, GradientTransformation, chain,
                                  add_decayed_weights, ema_trace,
                                  scale_by_schedule, tree_vdot)
from repro.kernels import dispatch
from repro.schedule import (pipeline as pipemod, policy as schedpol,
                            runtime as schedrt)


class EvaState(NamedTuple):
    running: kvlib.RunningStats
    cached: Any                   # KV snapshot applied at the last refresh
    sched: schedpol.SchedState
    # pipeline='onestep': {'stats': PipelineState} — the reduced fresh-KV
    # tree exchanged this step, applied (fed to the EMA) next step.  None
    # in sync mode (no extra leaves, same checkpoints as before).
    pipe: Any = None
    # fused path only (``eva(fused=True)``): the f32 heavy-ball buffer that
    # the composed chain keeps in kl_clip_trace's TraceState.  None for the
    # composed path — state layout/checkpoints there are unchanged.
    trace: Any = None


def _zeros_like_spec(tree):
    return jax.tree_util.tree_map(
        lambda x: jnp.zeros(x.shape, jnp.float32), tree)


def _extract(stats: dict, fields: tuple[str, ...]) -> dict:
    """Keep only the requested LayerStats fields (None elsewhere)."""
    out = {}
    for path, st in stats.items():
        out[path] = kvlib.LayerStats(**{f: getattr(st, f) for f in fields})
    return out


def _stats_plan(flat_updates: dict, stats: dict,
                extras: Optional[Extras]) -> bucketing.BucketPlan:
    """The bucket plan over the preconditioned (= captured) paths; uses the
    plan built at init_opt_state time when threaded through Extras, else
    re-derives it (memoized on the shape signature)."""
    if extras is not None and extras.plan is not None:
        return extras.plan
    return bucketing.build_plan({p: flat_updates[p] for p in stats
                                 if p in flat_updates})


def _eva_cached_init(pol, zeros):
    """The eva-family applied-snapshot slot: None when the policy itself
    keeps a snapshot (adaptive) — both follow the identical
    where(refresh, fresh, old) update from identical zeros, so storing the
    tree twice would double the KV bytes in state and every checkpoint."""
    return None if pol.wants_snapshot else zeros


def _refresh_snapshot(pol, sched, stats, cached):
    """Shared eva-family refresh: the KV snapshot actually *applied* is the
    bias-corrected EMA at the last refresh.  With ``every_k(1)`` the
    ``jnp.where`` selects the fresh stats every step — bit-identical to the
    historical always-fresh behavior (the select copies values exactly).
    The EMA itself still advances every step, mirroring how K-FAC refreshes
    factors every step but inverses on the interval.

    Returns ``(applied stats, new SchedState, new cached slot)``; snapshot
    policies read/maintain the applied tree inside SchedState instead of a
    duplicate ``cached`` (see ``_eva_cached_init``)."""
    refresh, staleness = pol.decide(sched, stats)
    base = sched.snapshot if pol.wants_snapshot else cached
    used = jax.tree_util.tree_map(
        lambda f, c: jnp.where(refresh, f, c), stats, base)
    new_sched = schedpol.commit(pol, sched, stats, refresh, staleness)
    return used, new_sched, (None if pol.wants_snapshot else used)


def _kv_init(params, extras, fields, policy, interval):
    """Shared eva-family init: bucket plan + zeroed running stats + sched."""
    if extras is None or extras.stats is None:
        raise ValueError('eva-family preconditioner init needs example stats '
                         '(pass Extras(stats=...) — see train.make_train_step)')
    flat = kvlib.flatten_params(params)
    plan = _stats_plan(flat, extras.stats, extras)
    zeros = bucketing.gather_tree(
        plan, _zeros_like_spec(_extract(extras.stats, fields)))
    rt = schedrt.from_extras(extras)
    pol = rt.resolve(policy, interval)
    pipe = ({'stats': pipemod.init_state(zeros)}
            if rt.pipeline == 'onestep' else None)
    return dict(running=kvlib.init_running(zeros),
                cached=_eva_cached_init(pol, zeros),
                sched=schedpol.init_state(pol, zeros), pipe=pipe)


def _kv_step(state, updates, extras, *, fields, site, policy, interval,
             kv_decay):
    """Shared eva-family per-step stats plumbing: EMA the fresh KVs (with
    the staged cross-replica mean) and pick the applied snapshot.

    Returns ``(flat updates, plan, applied stats, new-state field dict)``.
    """
    rt = schedrt.from_extras(extras)
    pol = rt.resolve(policy, interval)
    pipe = schedrt.resolve_pipe(rt, state.pipe)
    flat = kvlib.flatten_params(updates)
    fresh_flat = _extract(extras.stats, fields)
    plan = _stats_plan(flat, fresh_flat, extras)
    fresh, pipe_stats = pipemod.staged_pmean(
        bucketing.gather_tree(plan, fresh_flat),
        None if pipe is None else pipe['stats'], site=site)
    stats, running = kvlib.update_running(state.running, fresh, kv_decay)
    used, sched, cached = _refresh_snapshot(pol, state.sched, stats,
                                            state.cached)
    return flat, plan, used, dict(
        running=running, cached=cached, sched=sched,
        pipe=None if pipe is None else {'stats': pipe_stats})


def eva_preconditioner(gamma: float = 0.03, kv_decay: float = 0.95,
                       use_pallas: bool = False, interval: int = 1,
                       policy: Optional[schedpol.RefreshPolicy] = None,
                       impl: Optional[str] = None
                       ) -> GradientTransformation:
    """Bucketed P = (G − (b̄ᵀGā)/(γ+‖ā‖²‖b̄‖²)·āb̄ᵀ)/γ with EMA'd KVs.

    Eva is cheap enough to refresh every step (the paper's argument), but
    the refresh runtime gives it the same policy knob as the baselines —
    the Fig. 6 grid needs eva × {every_k, adaptive} cells too.
    """

    fields = ('a_mean', 'b_mean')

    def init(params, extras: Extras | None = None):
        return EvaState(**_kv_init(params, extras, fields, policy, interval))

    def update(updates, state: EvaState, params=None, extras: Extras | None = None):
        del params
        flat, plan, used, parts = _kv_step(
            state, updates, extras, fields=fields, site='stats/eva',
            policy=policy, interval=interval, kv_decay=kv_decay)
        k_impl = dispatch.impl_from_extras(
            extras, pre._kernel_impl(use_pallas, impl))
        out = pre.precondition_tree(flat, used, 'eva', gamma, plan=plan,
                                    impl=k_impl)
        return kvlib.unflatten_params(out), EvaState(**parts)

    return GradientTransformation(init, update)


def eva_fused_update(lr=0.1, gamma: float = 0.03, kv_decay: float = 0.95,
                     kl_kappa: float = 1e-3, momentum: float = 0.9,
                     fold_kl: bool = True, impl: Optional[str] = None,
                     interval: int = 1,
                     policy: Optional[schedpol.RefreshPolicy] = None
                     ) -> GradientTransformation:
    """Preconditioner + KL trust region + heavy-ball as ONE transform.

    Each bucket runs a single ``eva_fused`` dispatch (``kernels/fused.py``)
    that preconditions, folds ``m ← μ·m + P``, and emits the ⟨u,g⟩ partials
    the Eq. 16 clip needs — the separate kl_clip_trace tree passes
    disappear.  ``fold_kl=False`` (set when weight decay runs before the
    preconditioner, making the kernel's g ≠ raw_grads) keeps the kernel
    fusion but recomputes the global uᵀg against ``extras.raw_grads``.
    Math matches ``eva_preconditioner + kl_clip_trace`` (non-nesterov) to
    f32 reduction tolerance; the momentum buffer lives in
    ``EvaState.trace`` instead of a chained TraceState.
    """
    fields = ('a_mean', 'b_mean')

    def init(params, extras: Extras | None = None):
        return EvaState(**_kv_init(params, extras, fields, policy, interval),
                        trace=_zeros_like_spec(params))

    def update(updates, state: EvaState, params=None, extras: Extras | None = None):
        del params
        flat, plan, used, parts = _kv_step(
            state, updates, extras, fields=fields, site='stats/eva',
            policy=policy, interval=interval, kv_decay=kv_decay)
        k_impl = dispatch.impl_from_extras(extras, impl)
        out_flat, partials = pre.precondition_tree_fused(
            flat, used, 'eva', gamma, plan=plan,
            trace=kvlib.flatten_params(state.trace), momentum=momentum,
            fold_momentum=True, impl=k_impl)
        u = kvlib.unflatten_params(out_flat)
        if fold_kl:
            kl = sum(partials[p][0] for p in sorted(partials))
        else:
            kl = tree_vdot(u, extras.raw_grads)
        out, stored = finish_kl_clip(u, kl, extras.step, kl_kappa, lr)
        return out, EvaState(**parts, trace=stored)

    return GradientTransformation(init, update)


def eva(lr=0.1, gamma: float = 0.03, kv_decay: float = 0.95,
        kl_kappa: float = 1e-3, momentum: float = 0.9,
        weight_decay: float = 0.0, nesterov: bool = False,
        use_pallas: bool = False, interval: int = 1,
        policy: Optional[schedpol.RefreshPolicy] = None,
        fused: bool = False,
        kernel_impl: Optional[str] = None) -> GradientTransformation:
    """The full Eva optimizer as evaluated in the paper (§5).

    ``fused=True`` collapses preconditioner + KL clip + momentum into one
    kernel launch per bucket (``eva_fused_update``); it requires the
    non-nesterov trust-region tail, so nesterov / ``kl_kappa=None`` configs
    fall back to the composed chain.  ``kernel_impl`` is the dispatch
    request for the kernel ops (overridable per step via
    ``Extras.kernel``).
    """
    parts = []
    if weight_decay:
        # L2 regularization enters the gradient *before* preconditioning,
        # matching the reference implementation (grad += wd * w pre-hook).
        parts.append(add_decayed_weights(weight_decay))
    if fused and kl_kappa is not None and not nesterov:
        parts.append(eva_fused_update(
            lr, gamma, kv_decay, kl_kappa, momentum,
            fold_kl=(weight_decay == 0.0),
            impl=kernel_impl or pre._kernel_impl(use_pallas, None),
            interval=interval, policy=policy))
        parts.append(scale_by_schedule(lr if callable(lr) else (lambda _: lr)))
        return chain(*parts)
    parts.append(eva_preconditioner(gamma, kv_decay, use_pallas=use_pallas,
                                    interval=interval, policy=policy,
                                    impl=kernel_impl))
    if kl_kappa is not None:
        # momentum lives INSIDE the trust region (see clipping.kl_clip_trace)
        parts.append(kl_clip_trace(kl_kappa, lr, momentum, nesterov=nesterov))
    else:
        # unit-gain momentum: same equal-lr step-scale convention as every
        # other chain in the registry (see transform.ema_trace)
        parts.append(ema_trace(momentum, nesterov=nesterov))
    parts.append(scale_by_schedule(lr if callable(lr) else (lambda _: lr)))
    return chain(*parts)


CAPTURE = kvlib.EVA_CAPTURE
