"""Eva (paper §3): rank-one Kronecker-vector preconditioning.

``eva_preconditioner`` is the composable transform (running-average KVs +
Sherman–Morrison update, Eq. 13-15); ``eva`` is the full paper optimizer:
``precondition → KL clip → momentum → (weight decay) → -lr``.
"""
from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.core import kv as kvlib
from repro.core import precondition as pre
from repro.core.clipping import kl_clip
from repro.core.transform import (Extras, GradientTransformation, chain,
                                  add_decayed_weights, scale_by_schedule, trace)


class EvaState(NamedTuple):
    running: kvlib.RunningStats


def _zeros_like_spec(tree):
    return jax.tree_util.tree_map(
        lambda x: jnp.zeros(x.shape, jnp.float32), tree)


def _extract(stats: dict, fields: tuple[str, ...]) -> dict:
    """Keep only the requested LayerStats fields (None elsewhere)."""
    out = {}
    for path, st in stats.items():
        out[path] = kvlib.LayerStats(**{f: getattr(st, f) for f in fields})
    return out


def eva_preconditioner(gamma: float = 0.03, kv_decay: float = 0.95,
                       use_pallas: bool = False) -> GradientTransformation:
    """Per-layer P = (G − (b̄ᵀGā)/(γ+‖ā‖²‖b̄‖²)·āb̄ᵀ)/γ with EMA'd KVs."""

    fields = ('a_mean', 'b_mean')

    def init(params, extras: Extras | None = None):
        del params
        if extras is None or extras.stats is None:
            raise ValueError('eva_preconditioner.init needs example stats '
                             '(pass Extras(stats=...) — see train.make_train_step)')
        return EvaState(running=kvlib.init_running(
            _zeros_like_spec(_extract(extras.stats, fields))))

    def update(updates, state: EvaState, params=None, extras: Extras | None = None):
        del params
        fresh = _extract(extras.stats, fields)
        stats, running = kvlib.update_running(state.running, fresh, kv_decay)
        flat = kvlib.flatten_params(updates)
        for path, st in stats.items():
            g = flat[path]
            flat[path] = pre.eva_precondition(
                g, st.a_mean, st.b_mean, gamma, use_pallas=use_pallas)
        return kvlib.unflatten_params(flat), EvaState(running=running)

    return GradientTransformation(init, update)


def eva(lr=0.1, gamma: float = 0.03, kv_decay: float = 0.95,
        kl_kappa: float = 1e-3, momentum: float = 0.9,
        weight_decay: float = 0.0, nesterov: bool = False,
        use_pallas: bool = False) -> GradientTransformation:
    """The full Eva optimizer as evaluated in the paper (§5)."""
    parts = []
    if weight_decay:
        # L2 regularization enters the gradient *before* preconditioning,
        # matching the reference implementation (grad += wd * w pre-hook).
        parts.append(add_decayed_weights(weight_decay))
    parts.append(eva_preconditioner(gamma, kv_decay, use_pallas=use_pallas))
    if kl_kappa is not None:
        parts.append(kl_clip(kl_kappa, lr))
    parts.append(trace(momentum, nesterov=nesterov))
    parts.append(scale_by_schedule(lr if callable(lr) else (lambda _: lr)))
    return chain(*parts)


CAPTURE = kvlib.EVA_CAPTURE
