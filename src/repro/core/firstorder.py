"""First-order baselines: SGD(+momentum), Adagrad, AdamW."""
from __future__ import annotations

from repro.core import kv as kvlib
from repro.core.transform import (GradientTransformation, chain,
                                  add_decayed_weights, clip_by_global_norm,
                                  ema_trace, scale_by_adagrad, scale_by_adam,
                                  scale_by_schedule, trace)


def _sched(lr):
    return lr if callable(lr) else (lambda _: lr)


def sgd(lr=0.1, momentum: float = 0.9, weight_decay: float = 0.0,
        nesterov: bool = False, grad_clip: float | None = None) -> GradientTransformation:
    parts = []
    if weight_decay:
        parts.append(add_decayed_weights(weight_decay))
    if grad_clip:
        parts.append(clip_by_global_norm(grad_clip))
    if momentum:
        # bias-corrected EMA momentum (unit steady-state gain) — the same
        # convention as the second-order chains, so a given lr means the
        # same step scale across every optimizer in the registry
        parts.append(ema_trace(momentum, nesterov=nesterov))
    parts.append(scale_by_schedule(_sched(lr)))
    return chain(*parts)


def adagrad(lr=0.01, weight_decay: float = 0.0) -> GradientTransformation:
    parts = []
    if weight_decay:
        parts.append(add_decayed_weights(weight_decay))
    parts.append(scale_by_adagrad())
    parts.append(scale_by_schedule(_sched(lr)))
    return chain(*parts)


def adamw(lr=1e-3, b1: float = 0.9, b2: float = 0.999, eps: float = 1e-8,
          weight_decay: float = 0.01, grad_clip: float | None = None) -> GradientTransformation:
    parts = []
    if grad_clip:
        parts.append(clip_by_global_norm(grad_clip))
    parts.append(scale_by_adam(b1, b2, eps))
    if weight_decay:
        parts.append(add_decayed_weights(weight_decay))  # decoupled
    parts.append(scale_by_schedule(_sched(lr)))
    return chain(*parts)


CAPTURE = kvlib.NO_CAPTURE
