"""K-FAC baseline (paper Eq. 5) with update-interval support.

KF EMAs are refreshed every step (cheap relative to the inverses); the
explicit damped inverses are recomputed every ``interval`` steps under a
``lax.cond`` and cached in state — exactly the staleness trade-off the paper
studies in Fig. 6.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core import kv as kvlib
from repro.core import precondition as pre
from repro.core.clipping import kl_clip
from repro.core.eva import _extract, _zeros_like_spec
from repro.core.transform import (Extras, GradientTransformation, chain,
                                  add_decayed_weights, scale_by_schedule, trace)


class KfacState(NamedTuple):
    running: kvlib.RunningStats
    a_inv: dict
    b_inv: dict
    count: jnp.ndarray


def _damped_inv(m: jnp.ndarray, gamma) -> jnp.ndarray:
    d = m.shape[-1]
    eye = jnp.eye(d, dtype=jnp.float32)
    gam = jnp.asarray(gamma, jnp.float32)[..., None, None]
    return jnp.linalg.inv(m.astype(jnp.float32) + gam * eye)


def kfac_preconditioner(gamma: float = 0.03, kf_decay: float = 0.95,
                        interval: int = 1) -> GradientTransformation:
    fields = ('a_outer', 'b_outer')

    def init(params, extras: Extras | None = None):
        del params
        if extras is None or extras.stats is None:
            raise ValueError('kfac_preconditioner.init needs example stats')
        run = kvlib.init_running(_zeros_like_spec(_extract(extras.stats, fields)))
        a_inv = {p: jnp.zeros_like(st.a_outer) for p, st in run.stats.items()}
        b_inv = {p: jnp.zeros_like(st.b_outer) for p, st in run.stats.items()}
        return KfacState(running=run, a_inv=a_inv, b_inv=b_inv,
                         count=jnp.zeros((), jnp.int32))

    def update(updates, state: KfacState, params=None, extras: Extras | None = None):
        del params
        fresh = _extract(extras.stats, fields)
        stats, running = kvlib.update_running(state.running, fresh, kf_decay)

        def recompute(_):
            a_inv, b_inv = {}, {}
            for p, st in stats.items():
                gamma_r, gamma_q = pre.kfac_pi_damping(st.a_outer, st.b_outer, gamma)
                a_inv[p] = _damped_inv(st.a_outer, gamma_r)
                b_inv[p] = _damped_inv(st.b_outer, gamma_q)
            return a_inv, b_inv

        def keep(_):
            return state.a_inv, state.b_inv

        refresh = (state.count % interval) == 0
        a_inv, b_inv = jax.lax.cond(refresh, recompute, keep, operand=None)

        flat = kvlib.flatten_params(updates)
        for p in stats:
            g = flat[p].astype(jnp.float32)
            out = jnp.einsum('...ij,...jo->...io', a_inv[p], g)
            out = jnp.einsum('...io,...oj->...ij', out, b_inv[p])
            flat[p] = out.astype(flat[p].dtype)
        return kvlib.unflatten_params(flat), KfacState(
            running=running, a_inv=a_inv, b_inv=b_inv, count=state.count + 1)

    return GradientTransformation(init, update)


def kfac(lr=0.1, gamma: float = 0.03, kf_decay: float = 0.95,
         interval: int = 1, kl_kappa: float = 1e-3, momentum: float = 0.9,
         weight_decay: float = 0.0) -> GradientTransformation:
    parts = []
    if weight_decay:
        parts.append(add_decayed_weights(weight_decay))
    parts.append(kfac_preconditioner(gamma, kf_decay, interval))
    if kl_kappa is not None:
        parts.append(kl_clip(kl_kappa, lr))
    parts.append(trace(momentum))
    parts.append(scale_by_schedule(lr if callable(lr) else (lambda _: lr)))
    return chain(*parts)


CAPTURE = kvlib.KFAC_CAPTURE
