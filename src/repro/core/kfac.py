"""K-FAC baseline (paper Eq. 5), scheduled through the refresh runtime.

KF EMAs are refreshed every step (cheap relative to the inverses); the
explicit damped inverses are recomputed when the refresh policy fires
(``every_k(interval)`` reproduces the legacy ``count % interval`` branch
bit-exactly) — exactly the staleness trade-off the paper studies in Fig. 6.
Under a live data-parallel mesh each worker inverts only its owned bucket
slices and the results are psum-exchanged (``repro.schedule``).

Bucketed: Kronecker factors, cached inverses and the EMA all live
bucket-stacked; recomputation is one fused ``lax.map`` per bucket and the
inverse application is one batched two-sided contraction per bucket via
``precondition_tree`` — no per-path Python loops.
"""
from __future__ import annotations

from typing import Any, NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.core import bucketing
from repro.core import kv as kvlib
from repro.core import precondition as pre
from repro.core.clipping import Epilogue, fused_tail, kl_clip_trace
from repro.comm import exchange as comm_exchange
from repro.core.eva import _extract, _stats_plan, _zeros_like_spec
from repro.core.transform import (Extras, GradientTransformation, chain,
                                  add_decayed_weights, ema_trace,
                                  scale_by_schedule)
from repro.schedule import (ownership, pipeline as pipemod,
                            policy as schedpol, runtime as schedrt)
from repro.core import factor_sharded as fsh


class KfacState(NamedTuple):
    running: kvlib.RunningStats
    a_inv: dict
    b_inv: dict
    sched: schedpol.SchedState
    # pipeline='onestep': {'stats': PipelineState (reduced factor buffer),
    # 'refresh': PipelineState (age only — a_inv/b_inv double as the
    # in-flight inverse buffer)}.  None in sync mode.
    pipe: Any = None
    # sharded-factor head buckets (Extras.factor tripped): cached dense-side
    # operators + frozen dampings.  None on the all-dense legacy path.
    head: Any = None


def _damped_inv(m: jnp.ndarray, gamma) -> jnp.ndarray:
    d = m.shape[-1]
    eye = jnp.eye(d, dtype=jnp.float32)
    gam = jnp.asarray(gamma, jnp.float32)[..., None, None]
    return jnp.linalg.inv(m.astype(jnp.float32) + gam * eye)


def kfac_preconditioner(gamma: float = 0.03, kf_decay: float = 0.95,
                        interval: int = 1,
                        policy: Optional[schedpol.RefreshPolicy] = None
                        ) -> GradientTransformation:
    fields = ('a_outer', 'b_outer')

    def init(params, extras: Extras | None = None):
        if extras is None or extras.stats is None:
            raise ValueError('kfac_preconditioner.init needs example stats')
        flat = kvlib.flatten_params(params)
        plan = _stats_plan(flat, extras.stats, extras)
        zeros = bucketing.gather_tree(
            plan, _zeros_like_spec(_extract(extras.stats, fields)))
        run = kvlib.init_running(zeros)
        fcfg = fsh.from_extras(extras)
        _, head_pol = fsh.split_plan(plan, fcfg)
        a_inv = {k: jnp.zeros_like(st.a_outer)
                 for k, st in run.stats.items() if k not in head_pol}
        b_inv = {k: jnp.zeros_like(st.b_outer)
                 for k, st in run.stats.items() if k not in head_pol}
        head = fsh.init_head(
            {k: (run.stats[k].a_outer, run.stats[k].b_outer)
             for k in head_pol}, head_pol, fcfg, plan, 'kfac')
        rt = schedrt.from_extras(extras)
        pol = rt.resolve(policy, interval)
        pipe = ({'stats': pipemod.init_state(zeros),
                 'refresh': pipemod.init_state()}
                if rt.pipeline == 'onestep' else None)
        return KfacState(running=run, a_inv=a_inv, b_inv=b_inv,
                         sched=schedpol.init_state(pol, run.stats), pipe=pipe,
                         head=head)

    def update(updates, state: KfacState, params=None, extras: Extras | None = None):
        del params
        rt = schedrt.from_extras(extras)
        comm = comm_exchange.from_extras(extras)
        pol = rt.resolve(policy, interval)
        pipe = schedrt.resolve_pipe(rt, state.pipe)
        flat = kvlib.flatten_params(updates)
        fresh_flat = _extract(extras.stats, fields)
        plan = _stats_plan(flat, fresh_flat, extras)
        # the O(d²) KF factor reduction is the one stats exchange worth
        # compressing (4-5× gradient volume on the roofline) — codec'd
        fresh, pipe_stats = pipemod.staged_pmean(
            bucketing.gather_tree(plan, fresh_flat),
            None if pipe is None else pipe['stats'],
            codec=comm.stats, site='stats/kfac')
        stats, running = kvlib.update_running(state.running, fresh, kf_decay)

        def one(b, args):
            del b
            ao, bo = args
            gamma_r, gamma_q = pre.kfac_pi_damping(ao, bo, gamma)
            return _damped_inv(ao, gamma_r), _damped_inv(bo, gamma_q)

        fcfg = fsh.from_extras(extras)
        dense_plan, head_pol = fsh.split_plan(plan, fcfg)
        refresh, staleness = pol.decide(state.sched, stats)
        staged = schedrt.sharded_refresh(
            dense_plan, refresh, one,
            {k: (st.a_outer, st.b_outer) for k, st in stats.items()
             if k not in head_pol},
            {k: (state.a_inv[k], state.b_inv[k]) for k in state.a_inv},
            cost=ownership.inverse_cost('both'), shard=rt.shard_refresh,
            comm=comm, site='refresh/kfac',
            pipe=None if pipe is None else pipe['refresh'])
        if pipe is None:
            used = new = staged
            new_pipe = None
        else:
            used, new, pipe_ref = staged
            new_pipe = {'stats': pipe_stats, 'refresh': pipe_ref}
        a_inv = {k: v[0] for k, v in new.items()}
        b_inv = {k: v[1] for k, v in new.items()}
        # head buckets never enter the refresh exchange: the small dense
        # side is recomputed replicated under the same gate, the oversized
        # side is applied matrix-free from the live EMA (factor_sharded)
        head_factors = {k: (stats[k].a_outer, stats[k].b_outer)
                        for k in head_pol}
        head = fsh.refresh_head(refresh, head_factors, state.head, head_pol,
                                gamma, cfg=fcfg, plan=plan, method='kfac')
        sched = schedpol.commit(pol, state.sched, stats, refresh, staleness)

        ops = {k: kvlib.LayerStats(a_outer=used[k][0], b_outer=used[k][1])
               for k in used}
        out = pre.precondition_tree(flat, ops, 'kfac_cached', gamma,
                                    plan=dense_plan)
        if head_pol:
            out = fsh.apply_tree(out, plan, head_pol, head, head_factors,
                                 power=1.0, cfg=fcfg, site='factor/kfac')
        return kvlib.unflatten_params(out), KfacState(
            running=running, a_inv=a_inv, b_inv=b_inv, sched=sched,
            pipe=new_pipe, head=head)

    return GradientTransformation(init, update)


def kfac(lr=0.1, gamma: float = 0.03, kf_decay: float = 0.95,
         interval: int = 1, kl_kappa: float = 1e-3, momentum: float = 0.9,
         weight_decay: float = 0.0,
         policy: Optional[schedpol.RefreshPolicy] = None,
         fused: bool = False) -> GradientTransformation:
    """``fused=True`` routes the trust-region + momentum tail through the
    single-traversal ``clipping.fused_tail`` — K-FAC's preconditioner is a
    damped solve (nothing kernel-side to fuse), so the fusion here is the
    elementwise epilogue pass only; math is unchanged."""
    parts = []
    if weight_decay:
        parts.append(add_decayed_weights(weight_decay))
    parts.append(kfac_preconditioner(gamma, kf_decay, interval, policy=policy))
    if kl_kappa is not None and fused:
        parts.append(fused_tail(Epilogue(kind='kl_clip', kappa=kl_kappa,
                                         lr=lr, momentum=momentum)))
    elif kl_kappa is not None:
        # momentum lives INSIDE the trust region (see clipping.kl_clip_trace)
        parts.append(kl_clip_trace(kl_kappa, lr, momentum))
    else:
        # unit-gain momentum: same equal-lr step-scale convention as every
        # other chain in the registry (see transform.ema_trace)
        parts.append(ema_trace(momentum))
    parts.append(scale_by_schedule(lr if callable(lr) else (lambda _: lr)))
    return chain(*parts)


CAPTURE = kvlib.KFAC_CAPTURE
