"""KL clipping (Eq. 16), KL normalization (§4.1) and grafting (§4.2).

All three consume both the preconditioned updates (the incoming ``updates``)
and the raw gradients (``extras.raw_grads``) threaded by ``chain``.

``kl_clip_trace`` fuses the KL trust region with heavy-ball momentum: the
reference implementation clips the preconditioned gradient and *then* feeds
a torch-SGD momentum buffer, whose 1/(1-μ) steady-state gain re-amplifies
the clipped update up to 10× outside the trust region — on quadratic-ish
tasks this produced a limit cycle where momentum *hurt* (the seed's failing
§5 momentum ablation).  Fusing the two — accumulate first, clip the
momentum-included update, store the clipped buffer — keeps every applied
step inside the region while preserving heavy-ball smoothing, and reduces
exactly to ``kl_clip`` at momentum = 0.
"""
from __future__ import annotations

from typing import Callable, Union

import jax
import jax.numpy as jnp

from repro.core.transform import (Extras, GradientTransformation, TraceState,
                                  _unit_init, tree_vdot)

Schedule = Union[float, Callable]


def _lr_at(lr: Schedule, step) -> jnp.ndarray:
    if callable(lr):
        return jnp.asarray(lr(step), jnp.float32)
    return jnp.asarray(lr, jnp.float32)


def kl_clip(kappa: float = 1e-3, lr: Schedule = 0.1) -> GradientTransformation:
    """ν = min(1, sqrt(κ / (α² Σ_l p_lᵀ g_l))); scales all updates by ν.

    ``p`` are the (preconditioned) incoming updates, ``g`` the raw gradients.
    (C+γI)^{-1} is PD so pᵀg ≥ 0; we clamp for numerical safety.
    """

    def update(updates, state, params=None, extras: Extras | None = None):
        del params
        alpha = _lr_at(lr, extras.step)
        kl = jnp.maximum(tree_vdot(updates, extras.raw_grads), 0.0)
        nu = jnp.minimum(1.0, jnp.sqrt(kappa / jnp.maximum(alpha * alpha * kl, 1e-20)))
        return jax.tree_util.tree_map(lambda u: u * nu, updates), state

    return GradientTransformation(_unit_init, update)


def kl_clip_trace(kappa: float = 1e-3, lr: Schedule = 0.1,
                  momentum: float = 0.9,
                  nesterov: bool = False) -> GradientTransformation:
    """Momentum-aware KL trust region (see module docstring).

    m ← μ·m + p;  u = p + μ·m if nesterov else m;
    ν = min(1, √(κ / (α² uᵀg)));  output = ν·u;  store = ν·m.

    Storing the clipped buffer is what makes the transform self-stabilizing:
    in the clipped regime the buffer cannot accumulate past the trust
    region; once ν = 1 it is plain heavy-ball, and any incipient overshoot
    grows uᵀg until the clip re-engages.
    """

    def init(params):
        return TraceState(trace=jax.tree_util.tree_map(
            lambda p: jnp.zeros(p.shape, jnp.float32), params))

    def update(updates, state, params=None, extras: Extras | None = None):
        del params
        m = jax.tree_util.tree_map(
            lambda mm, g: momentum * mm + g.astype(jnp.float32),
            state.trace, updates)
        if nesterov:
            u = jax.tree_util.tree_map(
                lambda g, mm: g.astype(jnp.float32) + momentum * mm,
                updates, m)
        else:
            u = m
        alpha = _lr_at(lr, extras.step)
        kl = jnp.maximum(tree_vdot(u, extras.raw_grads), 0.0)
        nu = jnp.minimum(1.0, jnp.sqrt(
            kappa / jnp.maximum(alpha * alpha * kl, 1e-20)))
        out = jax.tree_util.tree_map(lambda x: x * nu, u)
        stored = out if not nesterov else jax.tree_util.tree_map(
            lambda x: x * nu, m)
        return out, TraceState(trace=stored)

    return GradientTransformation(init, update)


def kl_normalize(eps: float = 1e-12) -> GradientTransformation:
    """p / sqrt(Σ_l p_lᵀ g_l) — the hyper-parameter-free Eva-f stabilizer."""

    def update(updates, state, params=None, extras: Extras | None = None):
        del params
        kl = jnp.maximum(tree_vdot(updates, extras.raw_grads), eps)
        s = jax.lax.rsqrt(kl)
        return jax.tree_util.tree_map(lambda u: u * s, updates), state

    return GradientTransformation(_unit_init, update)


def graft_to_grad_magnitude(eps: float = 1e-12) -> GradientTransformation:
    """Per-layer scale sqrt(gᵀg / pᵀp): preconditioned *direction* with SGD
    *magnitude* (the Eva-s stabilizer, after [Anil et al. 2021])."""

    def update(updates, state, params=None, extras: Extras | None = None):
        del params

        def leaf(u, g):
            u32 = u.astype(jnp.float32)
            g32 = g.astype(jnp.float32)
            s = jnp.sqrt(jnp.sum(g32 * g32) / jnp.maximum(jnp.sum(u32 * u32), eps))
            return (u32 * s).astype(u.dtype)

        return jax.tree_util.tree_map(leaf, updates, extras.raw_grads), state

    return GradientTransformation(_unit_init, update)
