"""KL clipping (Eq. 16), KL normalization (§4.1) and grafting (§4.2).

All three consume both the preconditioned updates (the incoming ``updates``)
and the raw gradients (``extras.raw_grads``) threaded by ``chain``.

``kl_clip_trace`` fuses the KL trust region with heavy-ball momentum: the
reference implementation clips the preconditioned gradient and *then* feeds
a torch-SGD momentum buffer, whose 1/(1-μ) steady-state gain re-amplifies
the clipped update up to 10× outside the trust region — on quadratic-ish
tasks this produced a limit cycle where momentum *hurt* (the seed's failing
§5 momentum ablation).  Fusing the two — accumulate first, clip the
momentum-included update, store the clipped buffer — keeps every applied
step inside the region while preserving heavy-ball smoothing, and reduces
exactly to ``kl_clip`` at momentum = 0.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Union

import jax
import jax.numpy as jnp

from repro.core.transform import (Extras, GradientTransformation, TraceState,
                                  _unit_init, tree_vdot)

Schedule = Union[float, Callable]
_tree_map = jax.tree_util.tree_map


def _lr_at(lr: Schedule, step) -> jnp.ndarray:
    if callable(lr):
        return jnp.asarray(lr(step), jnp.float32)
    return jnp.asarray(lr, jnp.float32)


def kl_clip(kappa: float = 1e-3, lr: Schedule = 0.1) -> GradientTransformation:
    """ν = min(1, sqrt(κ / (α² Σ_l p_lᵀ g_l))); scales all updates by ν.

    ``p`` are the (preconditioned) incoming updates, ``g`` the raw gradients.
    (C+γI)^{-1} is PD so pᵀg ≥ 0; we clamp for numerical safety.
    """

    def update(updates, state, params=None, extras: Extras | None = None):
        del params
        alpha = _lr_at(lr, extras.step)
        kl = jnp.maximum(tree_vdot(updates, extras.raw_grads), 0.0)
        nu = jnp.minimum(1.0, jnp.sqrt(kappa / jnp.maximum(alpha * alpha * kl, 1e-20)))
        return jax.tree_util.tree_map(lambda u: u * nu, updates), state

    return GradientTransformation(_unit_init, update)


def kl_clip_trace(kappa: float = 1e-3, lr: Schedule = 0.1,
                  momentum: float = 0.9,
                  nesterov: bool = False) -> GradientTransformation:
    """Momentum-aware KL trust region (see module docstring).

    m ← μ·m + p;  u = p + μ·m if nesterov else m;
    ν = min(1, √(κ / (α² uᵀg)));  output = ν·u;  store = ν·m.

    Storing the clipped buffer is what makes the transform self-stabilizing:
    in the clipped regime the buffer cannot accumulate past the trust
    region; once ν = 1 it is plain heavy-ball, and any incipient overshoot
    grows uᵀg until the clip re-engages.
    """

    def init(params):
        return TraceState(trace=jax.tree_util.tree_map(
            lambda p: jnp.zeros(p.shape, jnp.float32), params))

    def update(updates, state, params=None, extras: Extras | None = None):
        del params
        m = jax.tree_util.tree_map(
            lambda mm, g: momentum * mm + g.astype(jnp.float32),
            state.trace, updates)
        if nesterov:
            u = jax.tree_util.tree_map(
                lambda g, mm: g.astype(jnp.float32) + momentum * mm,
                updates, m)
        else:
            u = m
        alpha = _lr_at(lr, extras.step)
        kl = jnp.maximum(tree_vdot(u, extras.raw_grads), 0.0)
        nu = jnp.minimum(1.0, jnp.sqrt(
            kappa / jnp.maximum(alpha * alpha * kl, 1e-20)))
        out = jax.tree_util.tree_map(lambda x: x * nu, u)
        stored = out if not nesterov else jax.tree_util.tree_map(
            lambda x: x * nu, m)
        return out, TraceState(trace=stored)

    return GradientTransformation(init, update)


def kl_normalize(eps: float = 1e-12) -> GradientTransformation:
    """p / sqrt(Σ_l p_lᵀ g_l) — the hyper-parameter-free Eva-f stabilizer."""

    def update(updates, state, params=None, extras: Extras | None = None):
        del params
        kl = jnp.maximum(tree_vdot(updates, extras.raw_grads), eps)
        s = jax.lax.rsqrt(kl)
        return jax.tree_util.tree_map(lambda u: u * s, updates), state

    return GradientTransformation(_unit_init, update)


# ---------------------------------------------------------------------------
# Fused update tails.  The finish helpers below are the SINGLE source of the
# scalar epilogues shared by (a) the fused-kernel optimizer paths, which get
# the inner products as per-bucket kernel partials (``kernels/fused.py``),
# and (b) ``fused_tail``, the one-transform jnp replacement for the composed
# [clip/normalize/graft] + [momentum] tail of the solve-based optimizers.
# The math is identical to the composed transforms above; only the number of
# tree traversals changes.


def finish_kl_clip(u, kl, step, kappa: float, lr: Schedule, m=None):
    """The Eq. 16 trust-region scale given a precomputed uᵀg.

    ``u`` is the momentum-included update tree (f32); ``kl`` the global
    ⟨u, raw_grads⟩ scalar.  Returns ``(out, stored)`` = (ν·u, ν·(m or u))
    — exactly ``kl_clip_trace``'s tail (``m`` only differs under nesterov).
    """
    alpha = _lr_at(lr, step)
    kl = jnp.maximum(kl, 0.0)
    nu = jnp.minimum(1.0, jnp.sqrt(
        kappa / jnp.maximum(alpha * alpha * kl, 1e-20)))
    out = _tree_map(lambda x: x * nu, u)
    stored = out if m is None else _tree_map(lambda x: x * nu, m)
    return out, stored


def ema_finish(x, trace, momentum: float, step):
    """``ema_trace`` semantics on an already-built tree: m ← μ·m + (1−μ)·x;
    out = m / (1−μ^(t+1)).  Returns ``(out, new trace)`` (trace kept f32)."""
    gain = 1.0 - momentum
    m = _tree_map(lambda mm, xx: momentum * mm.astype(jnp.float32)
                  + gain * xx.astype(jnp.float32), trace, x)
    if momentum:
        corr = 1.0 - jnp.asarray(momentum, jnp.float32) \
            ** (jnp.asarray(step).astype(jnp.float32) + 1.0)
        return _tree_map(lambda mm: mm / corr, m), m
    return m, m


def finish_normalized_ema(p, pg, trace, momentum: float, step,
                          eps: float = 1e-12):
    """``kl_normalize`` + ``ema_trace`` tail given a precomputed ⟨p, g⟩."""
    s = jax.lax.rsqrt(jnp.maximum(pg, eps))
    return ema_finish(_tree_map(lambda u: u * s, p), trace, momentum, step)


def finish_graft_ema(p, pp, gg, trace, momentum: float, step,
                     eps: float = 1e-12):
    """``graft_to_grad_magnitude`` + ``ema_trace`` tail given per-leaf
    ⟨p,p⟩ / ⟨g,g⟩ trees of scalars."""
    scaled = _tree_map(
        lambda u, a, b: u * jnp.sqrt(b / jnp.maximum(a, eps)), p, pp, gg)
    return ema_finish(scaled, trace, momentum, step)


@dataclasses.dataclass(frozen=True)
class Epilogue:
    """Declarative description of an optimizer's update tail.

    kind: 'kl_clip' (trust region + heavy-ball, the eva/kfac tail) |
    'kl_normalize' (global rescale + EMA momentum, eva_f/foof) |
    'graft' (per-leaf SGD-magnitude graft + EMA momentum, eva_s/shampoo).
    """
    kind: str
    kappa: float = 1e-3
    lr: Schedule = 0.1
    momentum: float = 0.9
    nesterov: bool = False
    eps: float = 1e-12


def fused_tail(epi: Epilogue) -> GradientTransformation:
    """One-transform (single-traversal) replacement for the composed
    [kl_clip_trace] / [kl_normalize + ema_trace] / [graft + ema_trace]
    chain tails — same math, same state shape (one f32 ``TraceState``)."""
    if epi.kind not in ('kl_clip', 'kl_normalize', 'graft'):
        raise ValueError(f'unknown epilogue kind {epi.kind!r}')

    def init(params):
        return TraceState(trace=_tree_map(
            lambda p: jnp.zeros(p.shape, jnp.float32), params))

    def update(updates, state, params=None, extras: Extras | None = None):
        del params
        p32 = _tree_map(lambda u: u.astype(jnp.float32), updates)
        if epi.kind == 'kl_clip':
            m = _tree_map(lambda mm, g: epi.momentum * mm + g,
                          state.trace, p32)
            u = _tree_map(lambda g, mm: g + epi.momentum * mm, p32, m) \
                if epi.nesterov else m
            out, stored = finish_kl_clip(
                u, tree_vdot(u, extras.raw_grads), extras.step,
                epi.kappa, epi.lr, m=m if epi.nesterov else None)
        elif epi.kind == 'kl_normalize':
            out, stored = finish_normalized_ema(
                p32, tree_vdot(p32, extras.raw_grads), state.trace,
                epi.momentum, extras.step, epi.eps)
        else:  # graft
            pp = _tree_map(lambda u: jnp.sum(u * u), p32)
            gg = _tree_map(
                lambda g: jnp.sum(jnp.square(g.astype(jnp.float32))),
                extras.raw_grads)
            out, stored = finish_graft_ema(p32, pp, gg, state.trace,
                                           epi.momentum, extras.step, epi.eps)
        return out, TraceState(trace=stored)

    return GradientTransformation(init, update)


def graft_to_grad_magnitude(eps: float = 1e-12) -> GradientTransformation:
    """Per-layer scale sqrt(gᵀg / pᵀp): preconditioned *direction* with SGD
    *magnitude* (the Eva-s stabilizer, after [Anil et al. 2021])."""

    def update(updates, state, params=None, extras: Extras | None = None):
        del params

        def leaf(u, g):
            u32 = u.astype(jnp.float32)
            g32 = g.astype(jnp.float32)
            s = jnp.sqrt(jnp.sum(g32 * g32) / jnp.maximum(jnp.sum(u32 * u32), eps))
            return (u32 * s).astype(u.dtype)

        return jax.tree_util.tree_map(leaf, updates, extras.raw_grads), state

    return GradientTransformation(_unit_init, update)
