"""KL clipping (Eq. 16), KL normalization (§4.1) and grafting (§4.2).

All three consume both the preconditioned updates (the incoming ``updates``)
and the raw gradients (``extras.raw_grads``) threaded by ``chain``.
"""
from __future__ import annotations

from typing import Callable, Union

import jax
import jax.numpy as jnp

from repro.core.transform import (Extras, GradientTransformation, _unit_init,
                                  tree_vdot)

Schedule = Union[float, Callable]


def _lr_at(lr: Schedule, step) -> jnp.ndarray:
    if callable(lr):
        return jnp.asarray(lr(step), jnp.float32)
    return jnp.asarray(lr, jnp.float32)


def kl_clip(kappa: float = 1e-3, lr: Schedule = 0.1) -> GradientTransformation:
    """ν = min(1, sqrt(κ / (α² Σ_l p_lᵀ g_l))); scales all updates by ν.

    ``p`` are the (preconditioned) incoming updates, ``g`` the raw gradients.
    (C+γI)^{-1} is PD so pᵀg ≥ 0; we clamp for numerical safety.
    """

    def update(updates, state, params=None, extras: Extras | None = None):
        del params
        alpha = _lr_at(lr, extras.step)
        kl = jnp.maximum(tree_vdot(updates, extras.raw_grads), 0.0)
        nu = jnp.minimum(1.0, jnp.sqrt(kappa / jnp.maximum(alpha * alpha * kl, 1e-20)))
        return jax.tree_util.tree_map(lambda u: u * nu, updates), state

    return GradientTransformation(_unit_init, update)


def kl_normalize(eps: float = 1e-12) -> GradientTransformation:
    """p / sqrt(Σ_l p_lᵀ g_l) — the hyper-parameter-free Eva-f stabilizer."""

    def update(updates, state, params=None, extras: Extras | None = None):
        del params
        kl = jnp.maximum(tree_vdot(updates, extras.raw_grads), eps)
        s = jax.lax.rsqrt(kl)
        return jax.tree_util.tree_map(lambda u: u * s, updates), state

    return GradientTransformation(_unit_init, update)


def graft_to_grad_magnitude(eps: float = 1e-12) -> GradientTransformation:
    """Per-layer scale sqrt(gᵀg / pᵀp): preconditioned *direction* with SGD
    *magnitude* (the Eva-s stabilizer, after [Anil et al. 2021])."""

    def update(updates, state, params=None, extras: Extras | None = None):
        del params

        def leaf(u, g):
            u32 = u.astype(jnp.float32)
            g32 = g.astype(jnp.float32)
            s = jnp.sqrt(jnp.sum(g32 * g32) / jnp.maximum(jnp.sum(u32 * u32), eps))
            return (u32 * s).astype(u.dtype)

        return jax.tree_util.tree_map(leaf, updates, extras.raw_grads), state

    return GradientTransformation(_unit_init, update)
