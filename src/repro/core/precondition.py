"""Sherman–Morrison rank-one preconditioning math (paper Eq. 13/21/23).

All weights use the (..., d_in, d_out) layout (einsum '...i,...io->...o');
leading dims are layer stacks / experts and every formula broadcasts over
them, which is what lets a whole ``lax.scan``-stacked model be preconditioned
in one fused XLA region instead of a per-layer Python loop.

Kernel routing: ``impl=`` hands the two hot operations (bilinear form +
rank-1 update) to the dispatch layer (``repro.kernels.dispatch``), which
picks compiled Pallas / interpret Pallas / the pure-XLA ``ref.py`` path per
(op, backend, shape, dtype).  ``use_pallas=True`` is the historical alias
for ``impl='pallas'``.  ``impl=None`` keeps the inline broadcast-jnp path
below — mathematically identical (the kernels are asserted against these
functions in tests).
"""
from __future__ import annotations

from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp


def _f32(x):
    # promote low-precision grads to f32 for the math; keep f64 under x64
    return x.astype(jnp.promote_types(x.dtype, jnp.float32))


# ---------------------------------------------------------------------------
# Eva (Eq. 13): P = (G - (b̄ᵀGā)/(γ + ‖ā‖²‖b̄‖²) · ā b̄ᵀ) / γ
# (paper layout ΔW ∝ b̄ āᵀ is for (d_out,d_in) weights; ours is transposed)


def _kernel_impl(use_pallas: bool, impl: Optional[str]) -> Optional[str]:
    """Back-compat shim: ``use_pallas=True`` is ``impl='pallas'``."""
    return impl or ('pallas' if use_pallas else None)


def eva_precondition(g: jnp.ndarray, a: jnp.ndarray, b: jnp.ndarray,
                     gamma: float, use_pallas: bool = False,
                     impl: Optional[str] = None) -> jnp.ndarray:
    """g: (..., d_in, d_out); a: (..., d_in); b: (..., d_out)."""
    impl = _kernel_impl(use_pallas, impl)
    if impl:
        from repro.kernels import ops as kops
        return kops.eva_precondition(g, a, b, gamma, impl=impl)
    g32, a32, b32 = _f32(g), _f32(a), _f32(b)
    dot = jnp.einsum('...io,...i,...o->...', g32, a32, b32)
    denom = gamma + jnp.sum(a32 * a32, -1) * jnp.sum(b32 * b32, -1)
    coeff = dot / denom
    p = (g32 - coeff[..., None, None] * (a32[..., :, None] * b32[..., None, :])) / gamma
    return p.astype(g.dtype)


# ---------------------------------------------------------------------------
# Eva-f (Eq. 21): P = (G - ā (āᵀ G) / (γ + ‖ā‖²)) / γ


def eva_f_precondition(g: jnp.ndarray, a: jnp.ndarray, gamma: float,
                       use_pallas: bool = False,
                       impl: Optional[str] = None) -> jnp.ndarray:
    """g: (..., d_in, d_out); a: (..., d_in)."""
    impl = _kernel_impl(use_pallas, impl)
    if impl:
        from repro.kernels import ops as kops
        return kops.eva_f_precondition(g, a, gamma, impl=impl)
    g32, a32 = _f32(g), _f32(a)
    u = jnp.einsum('...io,...i->...o', g32, a32)          # āᵀG  (..., d_out)
    denom = gamma + jnp.sum(a32 * a32, -1)
    p = (g32 - (a32[..., :, None] * u[..., None, :]) / denom[..., None, None]) / gamma
    return p.astype(g.dtype)


# ---------------------------------------------------------------------------
# Eva-s (Eq. 23, k=2): KVs are the gradient's own row/col means


def grad_kvs(g: jnp.ndarray) -> tuple[jnp.ndarray, jnp.ndarray]:
    """v_in = mean over d_out of G; v_out = mean over d_in of G."""
    g32 = _f32(g)
    return jnp.mean(g32, axis=-1), jnp.mean(g32, axis=-2)


def eva_s_precondition(g: jnp.ndarray, v_in: jnp.ndarray, v_out: jnp.ndarray,
                       gamma: float, use_pallas: bool = False,
                       impl: Optional[str] = None) -> jnp.ndarray:
    """Same rank-one form as Eva with (v_in, v_out) in place of (ā, b̄)."""
    impl = _kernel_impl(use_pallas, impl)
    if impl:
        from repro.kernels import ops as kops
        return kops.eva_precondition(g, v_in, v_out, gamma, impl=impl)
    g32, vi, vo = _f32(g), _f32(v_in), _f32(v_out)
    dot = jnp.einsum('...io,...i,...o->...', g32, vi, vo)
    denom = gamma + jnp.sum(vi * vi, -1) * jnp.sum(vo * vo, -1)
    coeff = dot / denom
    p = (g32 - coeff[..., None, None] * (vi[..., :, None] * vo[..., None, :])) / gamma
    return p.astype(g.dtype)


# ---------------------------------------------------------------------------
# Explicit-inverse baselines (K-FAC Eq. 5, FOOF Eq. 6, Shampoo Eq. 8)


def _damped_solve(m: jnp.ndarray, rhs: jnp.ndarray, gamma) -> jnp.ndarray:
    """(M + γI)^{-1} rhs for PSD M (..., d, d); batched over leading dims."""
    d = m.shape[-1]
    eye = jnp.eye(d, dtype=m.dtype)
    gam = jnp.asarray(gamma, m.dtype)[..., None, None]   # scalar -> (1,1)
    return jnp.linalg.solve(m + gam * eye, rhs)


def kfac_pi_damping(a_outer: jnp.ndarray, b_outer: jnp.ndarray,
                    gamma: float) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Martens-Grosse π-scaled split damping: γ_R = π√γ, γ_Q = √γ/π."""
    d_in = a_outer.shape[-1]
    d_out = b_outer.shape[-1]
    tr_a = jnp.trace(a_outer, axis1=-2, axis2=-1) / d_in
    tr_b = jnp.trace(b_outer, axis1=-2, axis2=-1) / d_out
    pi = jnp.sqrt(jnp.maximum(tr_a, 1e-12) / jnp.maximum(tr_b, 1e-12))
    root = jnp.sqrt(jnp.asarray(gamma, jnp.float32))
    return pi * root, root / pi  # (γ_R for A-side, γ_Q for B-side)


def kfac_precondition(g: jnp.ndarray, a_outer: jnp.ndarray, b_outer: jnp.ndarray,
                      gamma: float) -> jnp.ndarray:
    """(R+γ_R I)^{-1} G (Q+γ_Q I)^{-1} in our (d_in, d_out) layout."""
    g32 = _f32(g)
    gamma_r, gamma_q = kfac_pi_damping(a_outer, b_outer, gamma)
    left = _damped_solve(_f32(a_outer), g32, gamma_r)
    # right-side solve: X (Q+γI)^{-1}  ==  solve((Q+γI)ᵀ, Xᵀ)ᵀ ; Q symmetric.
    right = _damped_solve(_f32(b_outer), jnp.swapaxes(left, -1, -2), gamma_q)
    return jnp.swapaxes(right, -1, -2).astype(g.dtype)


def foof_precondition(g: jnp.ndarray, a_outer: jnp.ndarray, gamma: float) -> jnp.ndarray:
    """(R + γI)^{-1} G — FOOF preconditions the input side only."""
    return _damped_solve(_f32(a_outer), _f32(g), gamma).astype(g.dtype)


def _inv_proot_psd(m: jnp.ndarray, gamma: float, power: float) -> jnp.ndarray:
    """(M + γI)^{-power} for PSD M via eigh; batched."""
    w, v = jnp.linalg.eigh(_f32(m))
    w = jnp.maximum(w, 0.0) + gamma
    return jnp.einsum('...ij,...j,...kj->...ik', v, w ** (-power), v)


def shampoo_precondition(g: jnp.ndarray, m_in: jnp.ndarray, m_out: jnp.ndarray,
                         gamma: float) -> jnp.ndarray:
    """G ×_in (M_in+γI)^{-1/4} ×_out (M_out+γI)^{-1/4} (k=2 modes)."""
    g32 = _f32(g)
    p_in = _inv_proot_psd(m_in, gamma, 0.25)
    p_out = _inv_proot_psd(m_out, gamma, 0.25)
    out = jnp.einsum('...ij,...jo->...io', p_in, g32)
    out = jnp.einsum('...io,...oj->...ij', out, p_out)
    return out.astype(g.dtype)


# ---------------------------------------------------------------------------
# Bucketed tree preconditioning — the vectorized engine entry point


def precondition_tree(updates: dict, aux: dict, method: str, gamma: float, *,
                      plan=None, use_pallas: bool = False,
                      impl: Optional[str] = None) -> dict:
    """Precondition a flat ``{path: grad}`` tree with ONE vectorized call
    per parameter bucket (paper §3-§4: the formulas broadcast, so same-shape
    layers batch into a single launch instead of a per-path Python loop).

    Args:
      updates: flat ``{path: (..., d_in, d_out)}`` gradient dict (paths
        absent from ``aux``/``plan`` pass through untouched).
      aux: per-path ``kv.LayerStats`` (``{path: LayerStats}``) **or** the
        already-bucketed form (``{bucket_key: LayerStats}`` with stacked
        fields, as stored in optimizer state — detected via ``plan``).
        Field conventions per method:
          eva      — a_mean=ā, b_mean=b̄            (Eq. 13)
          eva_f    — a_mean=ā                       (Eq. 21)
          eva_s    — a_mean=v_in, b_mean=v_out      (Eq. 23)
          foof     — a_outer=AAᵀ  [or a_outer=(AAᵀ+γI)^{-1} for foof_cached]
          kfac     — a_outer, b_outer  [kfac_cached: the damped inverses]
          shampoo  — a_outer=M_in, b_outer=M_out  [shampoo_cached: the
                     cached inverse 4th roots]
      method: one of eva | eva_f | eva_s | foof | kfac | shampoo, or the
        ``*_cached`` variant applying precomputed operators.
      plan: ``bucketing.BucketPlan`` built at ``init_opt_state`` time;
        derived (memoized) from ``aux``'s paths when omitted.
      use_pallas: route the rank-one methods through the grid-folded Pallas
        kernels (one launch per bucket, ``kernels/ops.py``) — alias for
        ``impl='pallas'``.
      impl: kernel dispatch request for the rank-one methods
        (``kernels/dispatch.py``: 'auto' | 'pallas' | 'pallas_interpret' |
        'xla'); ``None`` keeps the inline broadcast-jnp formulas above.

    Bucket layout & version support: buckets group paths by (shape, dtype)
    with a new stacking axis 0 (``bucketing.build_plan``); scan-stacked
    leaves keep their leading layer/expert dims inside the bucket shape.
    Small buckets (``Bucket.stacked == False``, below the plan's
    min-bucket-size) skip the stack/unstack copies entirely and run the
    same formulas per path — on CPU the gather/scatter for an N<=2 bucket
    costs more than the single launch it saves.  For the rank-one methods
    and the ``*_cached`` operator application (everything the optimizers
    run) outputs are bit-identical to the per-path loop over the formulas
    above at ANY threshold: broadcast batching is used exactly where XLA
    guarantees per-item reduction order.  The direct solve/eigh methods
    (foof/kfac/shampoo) use one fused ``lax.map`` per stacked bucket —
    bit-identical to per-item calls of the same form, but the stacked
    (compiled scan body) and unstacked (eager) paths may differ in the
    last ulp, so across *different* thresholds they only agree to float
    tolerance (see tests/test_bucketing.py).  Runs on jax 0.4.37 through
    current jax — mesh interaction goes through ``repro.sharding.compat``.
    """
    from repro.core import bucketing

    if plan is None:
        sel = {p: updates[p] for p in aux if p in updates}
        if aux and not sel:
            # bucket keys ('float32_16x32') never match gradient paths; a
            # silent empty plan would return the gradients unpreconditioned
            raise ValueError(
                'precondition_tree: no aux key matches an update path — '
                'bucket-keyed aux requires an explicit plan=')
        plan = bucketing.build_plan(sel)
    aux_is_bucketed = bucketing.is_bucketed(plan, aux)

    def one_bucket(bucket, g, st, stacked):
        """g/st carry a leading stack axis when ``stacked``; the rank-one
        and cached-operator formulas broadcast over it, the LAPACK methods
        fuse it with one ``lax.map`` (or apply directly per item)."""
        if method == 'eva':
            return eva_precondition(g, st.a_mean, st.b_mean, gamma,
                                    use_pallas=use_pallas, impl=impl)
        if method == 'eva_f':
            return eva_f_precondition(g, st.a_mean, gamma,
                                      use_pallas=use_pallas, impl=impl)
        if method == 'eva_s':
            return eva_s_precondition(g, st.a_mean, st.b_mean, gamma,
                                      use_pallas=use_pallas, impl=impl)
        if method == 'foof':
            if not stacked:
                return foof_precondition(g, st.a_outer, gamma)
            return jax.lax.map(
                lambda t: foof_precondition(t[0], t[1], gamma),
                (g, st.a_outer))
        if method == 'kfac':
            if not stacked:
                return kfac_precondition(g, st.a_outer, st.b_outer, gamma)
            return jax.lax.map(
                lambda t: kfac_precondition(t[0], t[1], t[2], gamma),
                (g, st.a_outer, st.b_outer))
        if method == 'shampoo':
            if not stacked:
                return shampoo_precondition(g, st.a_outer, st.b_outer, gamma)
            return jax.lax.map(
                lambda t: shampoo_precondition(t[0], t[1], t[2], gamma),
                (g, st.a_outer, st.b_outer))
        if method == 'foof_cached':
            return apply_left(g, st.a_outer)
        if method in ('kfac_cached', 'shampoo_cached'):
            return apply_two_sided(g, st.a_outer, st.b_outer)
        raise ValueError(f'unknown method {method!r}')

    out = dict(updates)
    big = [b for b in plan.buckets if b.stacked]
    if big:
        sub = bucketing.BucketPlan(buckets=tuple(big))
        aux_b = {b.key: aux[b.key] for b in big} if aux_is_bucketed \
            else bucketing.gather_tree(sub, aux)
        g_b = bucketing.gather(sub, {p: updates[p] for p in sub.paths})
        out_b = {b.key: one_bucket(b, g_b[b.key], aux_b[b.key], True)
                 for b in big}
        out.update(bucketing.scatter(sub, out_b))
    for b in plan.buckets:
        if b.stacked:
            continue
        for i, p in enumerate(b.paths):
            st = jax.tree_util.tree_map(lambda x, i=i: x[i], aux[b.key]) \
                if aux_is_bucketed else aux[p]
            out[p] = one_bucket(b, updates[p], st, False)
    return out


def precondition_tree_fused(updates: dict, aux: dict, method: str,
                            gamma: float, *, plan=None, trace=None,
                            momentum: float = 0.0,
                            fold_momentum: bool = False,
                            impl: Optional[str] = None):
    """Fused precondition → update-epilogue over a flat gradient tree.

    One ``eva_fused``/``eva_f_fused`` dispatch per bucket instead of the
    bilinear + rank1_update pair plus separate momentum/inner-product tree
    passes (``kernels/fused.py``).  Rank-one methods only ('eva' | 'eva_f' |
    'eva_s'); paths outside the plan pass through with the same epilogue
    applied in jnp.

    Args:
      trace: flat ``{path: f32 momentum buffer}`` matching ``updates``
        (missing paths get zeros); only read when ``fold_momentum``.
      momentum: heavy-ball μ folded into the output when ``fold_momentum``.
      fold_momentum: emit ``out = μ·trace + P`` (the kl_clip_trace
        accumulate step) instead of the bare preconditioned ``P``.

    Returns ``(out, partials)``: ``out`` — flat ``{path: f32 array}``;
    ``partials`` — flat ``{path: (3,) f32}`` per-leaf epilogue sums
    ``[⟨out,g⟩, ⟨out,out⟩, ⟨g,g⟩]`` (``g`` = the *incoming* updates, i.e.
    the preconditioner input — equal to the raw gradients only when no
    transform ran before the preconditioner; callers gate the KL fold on
    that, see ``core/eva.py``).
    """
    from repro.core import bucketing
    from repro.kernels import ops as kops

    if method not in ('eva', 'eva_f', 'eva_s'):
        raise ValueError(f'precondition_tree_fused: rank-one methods only, '
                         f'got {method!r}')
    if plan is None:
        sel = {p: updates[p] for p in aux if p in updates}
        if aux and not sel:
            raise ValueError(
                'precondition_tree_fused: no aux key matches an update path '
                '— bucket-keyed aux requires an explicit plan=')
        plan = bucketing.build_plan(sel)
    aux_is_bucketed = bucketing.is_bucketed(plan, aux)
    trace = trace or {}
    mu = momentum if fold_momentum else 0.0

    def m_for(p):
        m = trace.get(p)
        return jnp.zeros(updates[p].shape, jnp.float32) if m is None \
            else m.astype(jnp.float32)

    def run(g, st, m):
        if method == 'eva_f':
            return kops.eva_f_fused(g, st.a_mean, gamma, m, mu,
                                    fold_momentum=fold_momentum, impl=impl)
        return kops.eva_fused(g, st.a_mean, st.b_mean, gamma, m, mu,
                              fold_momentum=fold_momentum, impl=impl)

    out, partials = {}, {}
    big = [b for b in plan.buckets if b.stacked]
    if big:
        sub = bucketing.BucketPlan(buckets=tuple(big))
        aux_b = {b.key: aux[b.key] for b in big} if aux_is_bucketed \
            else bucketing.gather_tree(sub, aux)
        g_b = bucketing.gather(sub, {p: updates[p] for p in sub.paths})
        m_b = bucketing.gather(sub, {p: m_for(p) for p in sub.paths})
        for b in big:
            o, ax = run(g_b[b.key], aux_b[b.key], m_b[b.key])
            for i, p in enumerate(b.paths):
                out[p] = o[i]
                # scan-stacked leaves carry (S, 3) partials; the epilogue
                # scalars are per *tree leaf*, so sum the item dims away
                partials[p] = ax[i].reshape(-1, 3).sum(axis=0)
    for b in plan.buckets:
        if b.stacked:
            continue
        for i, p in enumerate(b.paths):
            st = jax.tree_util.tree_map(lambda x, i=i: x[i], aux[b.key]) \
                if aux_is_bucketed else aux[p]
            o, ax = run(updates[p], st, m_for(p))
            out[p] = o
            partials[p] = ax.reshape(-1, 3).sum(axis=0)
    pre_paths = set(plan.paths)
    for p, g in updates.items():
        if p in pre_paths:
            continue
        g32 = g.astype(jnp.float32)
        o = mu * m_for(p) + g32 if fold_momentum else g32
        out[p] = o
        partials[p] = jnp.stack([jnp.sum(o * g32), jnp.sum(o * o),
                                 jnp.sum(g32 * g32)])
    return out, partials


def apply_left(g: jnp.ndarray, op_in: jnp.ndarray) -> jnp.ndarray:
    """op_in @ G — batched application of a cached input-side operator."""
    out = jnp.einsum('...ij,...jo->...io', op_in, _f32(g))
    return out.astype(g.dtype)


def apply_two_sided(g: jnp.ndarray, op_in: jnp.ndarray,
                    op_out: jnp.ndarray) -> jnp.ndarray:
    """op_in @ G @ op_out — batched two-sided cached-operator application."""
    out = jnp.einsum('...ij,...jo->...io', op_in, _f32(g))
    out = jnp.einsum('...io,...oj->...ij', out, op_out)
    return out.astype(g.dtype)


def map_bucket(fn, *args):
    """One fused ``lax.map`` over a bucket's stack axis — used where the
    batched LAPACK path (solve/inv/eigh) would change per-item numerics."""
    return jax.lax.map(lambda t: fn(*t), tuple(args))


# ---------------------------------------------------------------------------
# Reference dense forms (tests only): build the full (C + γI)^{-1} g


def eva_explicit(g: jnp.ndarray, a: jnp.ndarray, b: jnp.ndarray,
                 gamma: float) -> jnp.ndarray:
    """Literal (C+γI)^{-1} vec(G) with C = (b̄b̄ᵀ)⊗(āāᵀ) — O(d⁴), tests only.

    vec() follows the paper: row-major flatten of the (d_out, d_in) weight;
    with our (d_in, d_out) layout that is ``g.T.reshape(-1)`` and
    ``C = kron(b̄b̄ᵀ, āāᵀ)``.
    """
    d_in, d_out = g.shape[-2], g.shape[-1]
    vec = g.astype(jnp.float64 if jax.config.jax_enable_x64 else jnp.float32)
    vec = jnp.swapaxes(vec, -1, -2).reshape(d_out * d_in)
    c = jnp.kron(jnp.outer(b, b), jnp.outer(a, a))
    p = jnp.linalg.solve(c + gamma * jnp.eye(d_out * d_in, dtype=c.dtype), vec)
    return jnp.swapaxes(p.reshape(d_out, d_in), -1, -2).astype(g.dtype)
