"""Eva-s (paper §4.2): vectorized Shampoo — per-mode gradient-mean KVs +
grafting to the SGD magnitude.

Needs **no** capture: the KVs are the gradient's own row/col means
(v_i = mean_{-i}(G)), EMA'd over steps (the vectorized analogue of Shampoo's
statistic accumulation; documented deviation — the paper does not specify the
temporal treatment of v, we mirror Eq. 14-15).

Bucketed: the (v_in, v_out) running means live bucket-stacked (in the
``a_mean``/``b_mean`` LayerStats slots) and both the EMA and the rank-one
update run once per (shape, dtype) bucket via ``precondition_tree``.

Pipelining: eva_s performs **no curvature collective** (its KVs are local
gradient means and data-parallel gradient averaging already happened in the
grad psum), so ``RefreshRuntime(pipeline='onestep')`` is an exact no-op here
— the state carries no ``pipe`` buffers and sync/onestep are bit-identical.
"""
from __future__ import annotations

from typing import Any, NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.core import bucketing
from repro.core import kv as kvlib
from repro.core import precondition as pre
from repro.core.clipping import graft_to_grad_magnitude
from repro.core.eva import _eva_cached_init, _refresh_snapshot
from repro.core.transform import (Extras, GradientTransformation, chain,
                                  add_decayed_weights, ema_trace,
                                  scale_by_schedule)
from repro.schedule import policy as schedpol, runtime as schedrt


def default_precon_predicate(path: str, leaf) -> bool:
    """Precondition every >=2-D weight; skip biases/norms/scalars."""
    return hasattr(leaf, 'ndim') and leaf.ndim >= 2


class EvaSState(NamedTuple):
    running: kvlib.RunningStats
    cached: Any
    sched: schedpol.SchedState


def eva_s_preconditioner(gamma: float = 0.03, kv_decay: float = 0.95,
                         use_pallas: bool = False, interval: int = 1,
                         policy: Optional[schedpol.RefreshPolicy] = None,
                         predicate=default_precon_predicate) -> GradientTransformation:

    def init(params, extras: Extras | None = None):
        flat = kvlib.flatten_params(params)
        plan = bucketing.build_plan(flat, predicate)
        zeros = {
            b.key: kvlib.LayerStats(
                a_mean=jnp.zeros((len(b.paths),) + b.shape[:-1], jnp.float32),
                b_mean=jnp.zeros((len(b.paths),) + b.shape[:-2] + b.shape[-1:],
                                 jnp.float32))
            for b in plan.buckets}
        pol = schedrt.from_extras(extras).resolve(policy, interval)
        return EvaSState(running=kvlib.init_running(zeros),
                         cached=_eva_cached_init(pol, zeros),
                         sched=schedpol.init_state(pol, zeros))

    def update(updates, state: EvaSState, params=None, extras: Extras | None = None):
        del params
        pol = schedrt.from_extras(extras).resolve(policy, interval)
        flat = kvlib.flatten_params(updates)
        plan = bucketing.build_plan(flat, predicate)
        g_b = bucketing.gather(plan, {p: flat[p] for p in plan.paths})
        fresh = {}
        for b in plan.buckets:
            vi, vo = pre.grad_kvs(g_b[b.key])
            fresh[b.key] = kvlib.LayerStats(a_mean=vi, b_mean=vo)
        stats, running = kvlib.update_running(state.running, fresh, kv_decay)
        used, sched, cached = _refresh_snapshot(pol, state.sched, stats,
                                                state.cached)
        out = pre.precondition_tree(flat, used, 'eva_s', gamma, plan=plan,
                                    use_pallas=use_pallas)
        return kvlib.unflatten_params(out), EvaSState(
            running=running, cached=cached, sched=sched)

    return GradientTransformation(init, update)


def eva_s(lr=0.1, gamma: float = 0.03, kv_decay: float = 0.95,
          momentum: float = 0.9, weight_decay: float = 0.0,
          use_pallas: bool = False, interval: int = 1,
          policy: Optional[schedpol.RefreshPolicy] = None) -> GradientTransformation:
    parts = []
    if weight_decay:
        parts.append(add_decayed_weights(weight_decay))
    parts.append(eva_s_preconditioner(gamma, kv_decay, use_pallas=use_pallas,
                                      interval=interval, policy=policy))
    parts.append(graft_to_grad_magnitude())
    parts.append(ema_trace(momentum))
    parts.append(scale_by_schedule(lr if callable(lr) else (lambda _: lr)))
    return chain(*parts)


CAPTURE = kvlib.NO_CAPTURE
