"""Eva-s (paper §4.2): vectorized Shampoo — per-mode gradient-mean KVs +
grafting to the SGD magnitude.

Needs **no** capture: the KVs are the gradient's own row/col means
(v_i = mean_{-i}(G)), EMA'd over steps (the vectorized analogue of Shampoo's
statistic accumulation; documented deviation — the paper does not specify the
temporal treatment of v, we mirror Eq. 14-15).

Bucketed: the (v_in, v_out) running means live bucket-stacked (in the
``a_mean``/``b_mean`` LayerStats slots) and both the EMA and the rank-one
update run once per (shape, dtype) bucket via ``precondition_tree``.

Pipelining: eva_s performs **no curvature collective** (its KVs are local
gradient means and data-parallel gradient averaging already happened in the
grad psum), so ``RefreshRuntime(pipeline='onestep')`` is an exact no-op here
— the state carries no ``pipe`` buffers and sync/onestep are bit-identical.
"""
from __future__ import annotations

from typing import Any, NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.core import bucketing
from repro.core import kv as kvlib
from repro.core import precondition as pre
from repro.core.clipping import finish_graft_ema, graft_to_grad_magnitude
from repro.core.eva import (_eva_cached_init, _refresh_snapshot,
                            _zeros_like_spec)
from repro.core.transform import (Extras, GradientTransformation, chain,
                                  add_decayed_weights, ema_trace,
                                  scale_by_schedule)
from repro.kernels import dispatch
from repro.schedule import policy as schedpol, runtime as schedrt


def default_precon_predicate(path: str, leaf) -> bool:
    """Precondition every >=2-D weight; skip biases/norms/scalars."""
    return hasattr(leaf, 'ndim') and leaf.ndim >= 2


class EvaSState(NamedTuple):
    running: kvlib.RunningStats
    cached: Any
    sched: schedpol.SchedState
    # fused path only: the f32 EMA momentum buffer (else in ema_trace state)
    trace: Any = None


def _kv_init_s(params, extras, policy, interval, predicate):
    flat = kvlib.flatten_params(params)
    plan = bucketing.build_plan(flat, predicate)
    zeros = {
        b.key: kvlib.LayerStats(
            a_mean=jnp.zeros((len(b.paths),) + b.shape[:-1], jnp.float32),
            b_mean=jnp.zeros((len(b.paths),) + b.shape[:-2] + b.shape[-1:],
                             jnp.float32))
        for b in plan.buckets}
    pol = schedrt.from_extras(extras).resolve(policy, interval)
    return dict(running=kvlib.init_running(zeros),
                cached=_eva_cached_init(pol, zeros),
                sched=schedpol.init_state(pol, zeros))


def _kv_step_s(state, updates, extras, *, policy, interval, kv_decay,
               predicate):
    """eva_s per-step stats: fresh (v_in, v_out) from the gradients' own
    means, bucket-level EMA, snapshot refresh."""
    pol = schedrt.from_extras(extras).resolve(policy, interval)
    flat = kvlib.flatten_params(updates)
    plan = bucketing.build_plan(flat, predicate)
    g_b = bucketing.gather(plan, {p: flat[p] for p in plan.paths})
    fresh = {}
    for b in plan.buckets:
        vi, vo = pre.grad_kvs(g_b[b.key])
        fresh[b.key] = kvlib.LayerStats(a_mean=vi, b_mean=vo)
    stats, running = kvlib.update_running(state.running, fresh, kv_decay)
    used, sched, cached = _refresh_snapshot(pol, state.sched, stats,
                                            state.cached)
    return flat, plan, used, dict(running=running, cached=cached, sched=sched)


def eva_s_preconditioner(gamma: float = 0.03, kv_decay: float = 0.95,
                         use_pallas: bool = False, interval: int = 1,
                         policy: Optional[schedpol.RefreshPolicy] = None,
                         predicate=default_precon_predicate,
                         impl: Optional[str] = None) -> GradientTransformation:

    def init(params, extras: Extras | None = None):
        return EvaSState(**_kv_init_s(params, extras, policy, interval,
                                      predicate))

    def update(updates, state: EvaSState, params=None, extras: Extras | None = None):
        del params
        flat, plan, used, parts = _kv_step_s(
            state, updates, extras, policy=policy, interval=interval,
            kv_decay=kv_decay, predicate=predicate)
        k_impl = dispatch.impl_from_extras(
            extras, pre._kernel_impl(use_pallas, impl))
        out = pre.precondition_tree(flat, used, 'eva_s', gamma, plan=plan,
                                    impl=k_impl)
        return kvlib.unflatten_params(out), EvaSState(**parts)

    return GradientTransformation(init, update)


def eva_s_fused_update(gamma: float = 0.03, kv_decay: float = 0.95,
                       momentum: float = 0.9, fold_graft: bool = True,
                       impl: Optional[str] = None, interval: int = 1,
                       policy: Optional[schedpol.RefreshPolicy] = None,
                       predicate=default_precon_predicate
                       ) -> GradientTransformation:
    """Preconditioner + SGD-magnitude graft + EMA momentum as ONE transform.

    The ``eva_fused`` kernel emits P and the per-leaf [⟨p,g⟩, ⟨p,p⟩, ⟨g,g⟩]
    partials in a single launch per bucket; the graft scale is exactly
    √(⟨g,g⟩/⟨p,p⟩) from those partials, so the separate per-leaf reduction
    pass of ``graft_to_grad_magnitude`` disappears.  ``fold_graft=False``
    (weight decay upstream — kernel g ≠ raw_grads) recomputes the ⟨g,g⟩
    side from ``extras.raw_grads``.
    """

    def init(params, extras: Extras | None = None):
        return EvaSState(**_kv_init_s(params, extras, policy, interval,
                                      predicate),
                         trace=_zeros_like_spec(params))

    def update(updates, state: EvaSState, params=None, extras: Extras | None = None):
        del params
        flat, plan, used, parts = _kv_step_s(
            state, updates, extras, policy=policy, interval=interval,
            kv_decay=kv_decay, predicate=predicate)
        k_impl = dispatch.impl_from_extras(extras, impl)
        out_flat, partials = pre.precondition_tree_fused(
            flat, used, 'eva_s', gamma, plan=plan, fold_momentum=False,
            impl=k_impl)
        pp = {p: partials[p][1] for p in partials}
        if fold_graft:
            gg = {p: partials[p][2] for p in partials}
        else:
            raw = kvlib.flatten_params(extras.raw_grads)
            gg = {p: jnp.sum(jnp.square(raw[p].astype(jnp.float32)))
                  for p in partials}
        out_flat, stored_flat = finish_graft_ema(
            out_flat, pp, gg, kvlib.flatten_params(state.trace), momentum,
            extras.step)
        return kvlib.unflatten_params(out_flat), EvaSState(
            **parts, trace=kvlib.unflatten_params(stored_flat))

    return GradientTransformation(init, update)


def eva_s(lr=0.1, gamma: float = 0.03, kv_decay: float = 0.95,
          momentum: float = 0.9, weight_decay: float = 0.0,
          use_pallas: bool = False, interval: int = 1,
          policy: Optional[schedpol.RefreshPolicy] = None,
          fused: bool = False,
          kernel_impl: Optional[str] = None) -> GradientTransformation:
    parts = []
    if weight_decay:
        parts.append(add_decayed_weights(weight_decay))
    if fused:
        parts.append(eva_s_fused_update(
            gamma, kv_decay, momentum, fold_graft=(weight_decay == 0.0),
            impl=kernel_impl or pre._kernel_impl(use_pallas, None),
            interval=interval, policy=policy))
    else:
        parts.append(eva_s_preconditioner(gamma, kv_decay,
                                          use_pallas=use_pallas,
                                          interval=interval, policy=policy,
                                          impl=kernel_impl))
        parts.append(graft_to_grad_magnitude())
        parts.append(ema_trace(momentum))
    parts.append(scale_by_schedule(lr if callable(lr) else (lambda _: lr)))
    return chain(*parts)


CAPTURE = kvlib.NO_CAPTURE
