"""Eva-s (paper §4.2): vectorized Shampoo — per-mode gradient-mean KVs +
grafting to the SGD magnitude.

Needs **no** capture: the KVs are the gradient's own row/col means
(v_i = mean_{-i}(G)), EMA'd over steps (the vectorized analogue of Shampoo's
statistic accumulation; documented deviation — the paper does not specify the
temporal treatment of v, we mirror Eq. 14-15).
"""
from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.core import kv as kvlib
from repro.core import precondition as pre
from repro.core.clipping import graft_to_grad_magnitude
from repro.core.transform import (Extras, GradientTransformation, chain,
                                  add_decayed_weights, scale_by_schedule, trace)


def default_precon_predicate(path: str, leaf) -> bool:
    """Precondition every >=2-D weight; skip biases/norms/scalars."""
    return hasattr(leaf, 'ndim') and leaf.ndim >= 2


class EvaSState(NamedTuple):
    v_in: dict
    v_out: dict
    count: jnp.ndarray


def eva_s_preconditioner(gamma: float = 0.03, kv_decay: float = 0.95,
                         use_pallas: bool = False,
                         predicate=default_precon_predicate) -> GradientTransformation:

    def init(params, extras: Extras | None = None):
        del extras
        flat = kvlib.flatten_params(params)
        v_in = {p: jnp.zeros(w.shape[:-1], jnp.float32)
                for p, w in flat.items() if predicate(p, w)}
        v_out = {p: jnp.zeros(w.shape[:-2] + w.shape[-1:], jnp.float32)
                 for p, w in flat.items() if predicate(p, w)}
        return EvaSState(v_in=v_in, v_out=v_out, count=jnp.zeros((), jnp.int32))

    def update(updates, state: EvaSState, params=None, extras: Extras | None = None):
        del params, extras
        flat = kvlib.flatten_params(updates)
        count = state.count + 1
        corr = 1.0 - jnp.asarray(kv_decay, jnp.float32) ** count.astype(jnp.float32)
        new_vi, new_vo = dict(state.v_in), dict(state.v_out)
        for path in state.v_in:
            g = flat[path]
            vi, vo = pre.grad_kvs(g)
            new_vi[path] = kv_decay * state.v_in[path] + (1 - kv_decay) * vi
            new_vo[path] = kv_decay * state.v_out[path] + (1 - kv_decay) * vo
            flat[path] = pre.eva_s_precondition(
                g, new_vi[path] / corr, new_vo[path] / corr, gamma,
                use_pallas=use_pallas)
        return (kvlib.unflatten_params(flat),
                EvaSState(v_in=new_vi, v_out=new_vo, count=count))

    return GradientTransformation(init, update)


def eva_s(lr=0.1, gamma: float = 0.03, kv_decay: float = 0.95,
          momentum: float = 0.9, weight_decay: float = 0.0,
          use_pallas: bool = False) -> GradientTransformation:
    parts = []
    if weight_decay:
        parts.append(add_decayed_weights(weight_decay))
    parts.append(eva_s_preconditioner(gamma, kv_decay, use_pallas=use_pallas))
    parts.append(graft_to_grad_magnitude())
    parts.append(trace(momentum))
    parts.append(scale_by_schedule(lr if callable(lr) else (lambda _: lr)))
    return chain(*parts)


CAPTURE = kvlib.NO_CAPTURE
