"""Eva-f (paper §4.1): vectorized FOOF — input-side-only rank-one
preconditioning + hyper-parameter-free KL normalization.

Bucketed like ``eva``: one ``precondition_tree`` call per (shape, dtype)
bucket, bucket-level KV EMA, distributed psum hook.  KV-snapshot refresh is
scheduled through ``repro.schedule`` (same knob as the baselines)."""
from __future__ import annotations

from typing import Any, NamedTuple, Optional

from repro.core import bucketing
from repro.core import kv as kvlib
from repro.core import precondition as pre
from repro.core.clipping import kl_normalize
from repro.core.eva import (_eva_cached_init, _extract, _refresh_snapshot,
                            _stats_plan, _zeros_like_spec)
from repro.core.transform import (Extras, GradientTransformation, chain,
                                  add_decayed_weights, ema_trace,
                                  scale_by_schedule)
from repro.schedule import (pipeline as pipemod, policy as schedpol,
                            runtime as schedrt)


class EvaFState(NamedTuple):
    running: kvlib.RunningStats
    cached: Any
    sched: schedpol.SchedState
    # pipeline='onestep': {'stats': PipelineState}; None in sync mode
    pipe: Any = None


def eva_f_preconditioner(gamma: float = 0.03, kv_decay: float = 0.95,
                         use_pallas: bool = False, interval: int = 1,
                         policy: Optional[schedpol.RefreshPolicy] = None
                         ) -> GradientTransformation:
    fields = ('a_mean',)

    def init(params, extras: Extras | None = None):
        if extras is None or extras.stats is None:
            raise ValueError('eva_f_preconditioner.init needs example stats')
        flat = kvlib.flatten_params(params)
        plan = _stats_plan(flat, extras.stats, extras)
        zeros = bucketing.gather_tree(
            plan, _zeros_like_spec(_extract(extras.stats, fields)))
        rt = schedrt.from_extras(extras)
        pol = rt.resolve(policy, interval)
        pipe = ({'stats': pipemod.init_state(zeros)}
                if rt.pipeline == 'onestep' else None)
        return EvaFState(running=kvlib.init_running(zeros),
                         cached=_eva_cached_init(pol, zeros),
                         sched=schedpol.init_state(pol, zeros), pipe=pipe)

    def update(updates, state: EvaFState, params=None, extras: Extras | None = None):
        del params
        rt = schedrt.from_extras(extras)
        pol = rt.resolve(policy, interval)
        pipe = schedrt.resolve_pipe(rt, state.pipe)
        flat = kvlib.flatten_params(updates)
        fresh_flat = _extract(extras.stats, fields)
        plan = _stats_plan(flat, fresh_flat, extras)
        fresh, pipe_stats = pipemod.staged_pmean(
            bucketing.gather_tree(plan, fresh_flat),
            None if pipe is None else pipe['stats'], site='stats/eva_f')
        stats, running = kvlib.update_running(state.running, fresh, kv_decay)
        used, sched, cached = _refresh_snapshot(pol, state.sched, stats,
                                                state.cached)
        out = pre.precondition_tree(flat, used, 'eva_f', gamma, plan=plan,
                                    use_pallas=use_pallas)
        return kvlib.unflatten_params(out), EvaFState(
            running=running, cached=cached, sched=sched,
            pipe=None if pipe is None else {'stats': pipe_stats})

    return GradientTransformation(init, update)


def eva_f(lr=0.1, gamma: float = 0.03, kv_decay: float = 0.95,
          momentum: float = 0.9, weight_decay: float = 0.0,
          use_pallas: bool = False, interval: int = 1,
          policy: Optional[schedpol.RefreshPolicy] = None) -> GradientTransformation:
    parts = []
    if weight_decay:
        parts.append(add_decayed_weights(weight_decay))
    parts.append(eva_f_preconditioner(gamma, kv_decay, use_pallas=use_pallas,
                                      interval=interval, policy=policy))
    parts.append(kl_normalize())
    parts.append(ema_trace(momentum))
    parts.append(scale_by_schedule(lr if callable(lr) else (lambda _: lr)))
    return chain(*parts)


CAPTURE = kvlib.EVA_F_CAPTURE
