"""Eva-f (paper §4.1): vectorized FOOF — input-side-only rank-one
preconditioning + hyper-parameter-free KL normalization.

Bucketed like ``eva``: one ``precondition_tree`` call per (shape, dtype)
bucket, bucket-level KV EMA, distributed psum hook.  KV-snapshot refresh is
scheduled through ``repro.schedule`` (same knob as the baselines).

``eva_f(fused=True)`` runs the preconditioner as one ``eva_f_fused``
dispatch per bucket, folding the ⟨p,g⟩ inner product the KL normalizer
needs into the kernel epilogue; the normalize + EMA tail itself stays a
single jnp pass (its global scale depends on every bucket, so it cannot
live inside a per-bucket launch — see ``kernels/fused.py``)."""
from __future__ import annotations

from typing import Any, NamedTuple, Optional

from repro.core import kv as kvlib
from repro.core import precondition as pre
from repro.core.clipping import finish_normalized_ema, kl_normalize
from repro.core.eva import _kv_init, _kv_step, _zeros_like_spec
from repro.core.transform import (Extras, GradientTransformation, chain,
                                  add_decayed_weights, ema_trace,
                                  scale_by_schedule, tree_vdot)
from repro.kernels import dispatch
from repro.schedule import policy as schedpol


class EvaFState(NamedTuple):
    running: kvlib.RunningStats
    cached: Any
    sched: schedpol.SchedState
    # pipeline='onestep': {'stats': PipelineState}; None in sync mode
    pipe: Any = None
    # fused path only: the f32 EMA momentum buffer (else in ema_trace state)
    trace: Any = None


_FIELDS = ('a_mean',)


def eva_f_preconditioner(gamma: float = 0.03, kv_decay: float = 0.95,
                         use_pallas: bool = False, interval: int = 1,
                         policy: Optional[schedpol.RefreshPolicy] = None,
                         impl: Optional[str] = None
                         ) -> GradientTransformation:

    def init(params, extras: Extras | None = None):
        return EvaFState(**_kv_init(params, extras, _FIELDS, policy,
                                    interval))

    def update(updates, state: EvaFState, params=None, extras: Extras | None = None):
        del params
        flat, plan, used, parts = _kv_step(
            state, updates, extras, fields=_FIELDS, site='stats/eva_f',
            policy=policy, interval=interval, kv_decay=kv_decay)
        k_impl = dispatch.impl_from_extras(
            extras, pre._kernel_impl(use_pallas, impl))
        out = pre.precondition_tree(flat, used, 'eva_f', gamma, plan=plan,
                                    impl=k_impl)
        return kvlib.unflatten_params(out), EvaFState(**parts)

    return GradientTransformation(init, update)


def eva_f_fused_update(gamma: float = 0.03, kv_decay: float = 0.95,
                       momentum: float = 0.9, fold_kl: bool = True,
                       impl: Optional[str] = None, interval: int = 1,
                       policy: Optional[schedpol.RefreshPolicy] = None
                       ) -> GradientTransformation:
    """Preconditioner + KL normalize + EMA momentum as ONE transform.

    The kernel emits P and the per-bucket ⟨p,g⟩ partials in a single
    launch; the tail is the shared ``finish_normalized_ema``.  Momentum
    cannot fold into the kernel here (normalization precedes the EMA and
    its scale is global), so ``fold_momentum`` stays off — the win is the
    merged launch and the folded inner product.  ``fold_kl=False`` (weight
    decay upstream) recomputes ⟨p, raw_grads⟩ instead of trusting the
    kernel partials.
    """

    def init(params, extras: Extras | None = None):
        return EvaFState(**_kv_init(params, extras, _FIELDS, policy,
                                    interval),
                         trace=_zeros_like_spec(params))

    def update(updates, state: EvaFState, params=None, extras: Extras | None = None):
        del params
        flat, plan, used, parts = _kv_step(
            state, updates, extras, fields=_FIELDS, site='stats/eva_f',
            policy=policy, interval=interval, kv_decay=kv_decay)
        k_impl = dispatch.impl_from_extras(extras, impl)
        out_flat, partials = pre.precondition_tree_fused(
            flat, used, 'eva_f', gamma, plan=plan, fold_momentum=False,
            impl=k_impl)
        p = kvlib.unflatten_params(out_flat)
        if fold_kl:
            pg = sum(partials[k][0] for k in sorted(partials))
        else:
            pg = tree_vdot(p, extras.raw_grads)
        out, stored = finish_normalized_ema(p, pg, state.trace, momentum,
                                            extras.step)
        return out, EvaFState(**parts, trace=stored)

    return GradientTransformation(init, update)


def eva_f(lr=0.1, gamma: float = 0.03, kv_decay: float = 0.95,
          momentum: float = 0.9, weight_decay: float = 0.0,
          use_pallas: bool = False, interval: int = 1,
          policy: Optional[schedpol.RefreshPolicy] = None,
          fused: bool = False,
          kernel_impl: Optional[str] = None) -> GradientTransformation:
    parts = []
    if weight_decay:
        parts.append(add_decayed_weights(weight_decay))
    if fused:
        parts.append(eva_f_fused_update(
            gamma, kv_decay, momentum, fold_kl=(weight_decay == 0.0),
            impl=kernel_impl or pre._kernel_impl(use_pallas, None),
            interval=interval, policy=policy))
    else:
        parts.append(eva_f_preconditioner(gamma, kv_decay,
                                          use_pallas=use_pallas,
                                          interval=interval, policy=policy,
                                          impl=kernel_impl))
        parts.append(kl_normalize())
        parts.append(ema_trace(momentum))
    parts.append(scale_by_schedule(lr if callable(lr) else (lambda _: lr)))
    return chain(*parts)


CAPTURE = kvlib.EVA_F_CAPTURE
