"""Eva-f (paper §4.1): vectorized FOOF — input-side-only rank-one
preconditioning + hyper-parameter-free KL normalization."""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core import kv as kvlib
from repro.core import precondition as pre
from repro.core.clipping import kl_normalize
from repro.core.eva import _extract, _zeros_like_spec
from repro.core.transform import (Extras, GradientTransformation, chain,
                                  add_decayed_weights, scale_by_schedule, trace)


class EvaFState(NamedTuple):
    running: kvlib.RunningStats


def eva_f_preconditioner(gamma: float = 0.03, kv_decay: float = 0.95,
                         use_pallas: bool = False) -> GradientTransformation:
    fields = ('a_mean',)

    def init(params, extras: Extras | None = None):
        del params
        if extras is None or extras.stats is None:
            raise ValueError('eva_f_preconditioner.init needs example stats')
        return EvaFState(running=kvlib.init_running(
            _zeros_like_spec(_extract(extras.stats, fields))))

    def update(updates, state: EvaFState, params=None, extras: Extras | None = None):
        del params
        fresh = _extract(extras.stats, fields)
        stats, running = kvlib.update_running(state.running, fresh, kv_decay)
        flat = kvlib.flatten_params(updates)
        for path, st in stats.items():
            flat[path] = pre.eva_f_precondition(
                flat[path], st.a_mean, gamma, use_pallas=use_pallas)
        return kvlib.unflatten_params(flat), EvaFState(running=running)

    return GradientTransformation(init, update)


def eva_f(lr=0.1, gamma: float = 0.03, kv_decay: float = 0.95,
          momentum: float = 0.9, weight_decay: float = 0.0,
          use_pallas: bool = False) -> GradientTransformation:
    parts = []
    if weight_decay:
        parts.append(add_decayed_weights(weight_decay))
    parts.append(eva_f_preconditioner(gamma, kv_decay, use_pallas=use_pallas))
    parts.append(kl_normalize())
    parts.append(trace(momentum))
    parts.append(scale_by_schedule(lr if callable(lr) else (lambda _: lr)))
    return chain(*parts)


CAPTURE = kvlib.EVA_F_CAPTURE
