"""Eva core: vectorized second-order optimization (the paper's contribution).

Public surface:
  make_optimizer(name, **kw) -> (GradientTransformation, CaptureConfig)
  eva / eva_f / eva_s / kfac / foof / shampoo / mfac / sgd / adagrad / adamw
  kv: capture machinery;  precondition: Sherman-Morrison math
"""
from repro.core import bucketing, kv, precondition, transform
from repro.core.bucketing import BucketPlan, build_plan
from repro.core.clipping import (graft_to_grad_magnitude, kl_clip,
                                 kl_clip_trace, kl_normalize)
from repro.core.precondition import precondition_tree
from repro.core.eva import eva, eva_preconditioner
from repro.core.eva_f import eva_f, eva_f_preconditioner
from repro.core.eva_s import eva_s, eva_s_preconditioner
from repro.core.firstorder import adagrad, adamw, sgd
from repro.core.foof import foof, foof_preconditioner
from repro.core.kfac import kfac, kfac_preconditioner
from repro.core.mfac import mfac, mfac_preconditioner
from repro.core.registry import capture_for, make_optimizer, optimizer_names
from repro.core.shampoo import shampoo, shampoo_preconditioner
from repro.core.transform import Extras, GradientTransformation, apply_updates, chain

__all__ = [
    'bucketing', 'BucketPlan', 'build_plan', 'precondition_tree',
    'kv', 'precondition', 'transform', 'Extras', 'GradientTransformation',
    'apply_updates', 'chain', 'make_optimizer', 'optimizer_names', 'capture_for',
    'eva', 'eva_f', 'eva_s', 'kfac', 'foof', 'shampoo', 'mfac',
    'sgd', 'adagrad', 'adamw', 'kl_clip', 'kl_clip_trace', 'kl_normalize',
    'graft_to_grad_magnitude',
    'eva_preconditioner', 'eva_f_preconditioner', 'eva_s_preconditioner',
    'kfac_preconditioner', 'foof_preconditioner', 'shampoo_preconditioner',
    'mfac_preconditioner',
]
