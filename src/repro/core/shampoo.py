"""Shampoo baseline (paper Eq. 8, k=2) with update-interval + grafting.

Statistics are accumulated Adagrad-style (M += mat_i(G) mat_i(G)ᵀ, ε-init);
inverse 4th roots are recomputed every ``interval`` steps (Fig. 6 style) and
cached.  Grafting to the gradient magnitude follows [Anil et al. 2021] as the
paper's §4.2 does for Eva-s.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core import kv as kvlib
from repro.core import precondition as pre
from repro.core.clipping import graft_to_grad_magnitude
from repro.core.eva_s import default_precon_predicate
from repro.core.transform import (Extras, GradientTransformation, chain,
                                  add_decayed_weights, scale_by_schedule, trace)


class ShampooState(NamedTuple):
    m_in: dict    # (..., d_in, d_in)
    m_out: dict   # (..., d_out, d_out)
    p_in: dict    # cached (M+γI)^{-1/4}
    p_out: dict
    count: jnp.ndarray


def shampoo_preconditioner(gamma: float = 1e-4, eps_init: float = 1e-6,
                           interval: int = 1,
                           predicate=default_precon_predicate) -> GradientTransformation:

    def init(params, extras: Extras | None = None):
        del extras
        flat = kvlib.flatten_params(params)
        sel = {p: w for p, w in flat.items() if predicate(p, w)}
        m_in = {p: eps_init * jnp.broadcast_to(
                    jnp.eye(w.shape[-2], dtype=jnp.float32),
                    w.shape[:-2] + (w.shape[-2], w.shape[-2]))
                for p, w in sel.items()}
        m_out = {p: eps_init * jnp.broadcast_to(
                     jnp.eye(w.shape[-1], dtype=jnp.float32),
                     w.shape[:-2] + (w.shape[-1], w.shape[-1]))
                 for p, w in sel.items()}
        return ShampooState(
            m_in=m_in, m_out=m_out,
            p_in=jax.tree_util.tree_map(jnp.zeros_like, m_in),
            p_out=jax.tree_util.tree_map(jnp.zeros_like, m_out),
            count=jnp.zeros((), jnp.int32))

    def update(updates, state: ShampooState, params=None, extras: Extras | None = None):
        del params, extras
        flat = kvlib.flatten_params(updates)
        m_in, m_out = {}, {}
        for p in state.m_in:
            g = flat[p].astype(jnp.float32)
            m_in[p] = state.m_in[p] + jnp.einsum('...io,...jo->...ij', g, g)
            m_out[p] = state.m_out[p] + jnp.einsum('...io,...ij->...oj', g, g)

        def recompute(_):
            return ({p: pre._inv_proot_psd(m_in[p], gamma, 0.25) for p in m_in},
                    {p: pre._inv_proot_psd(m_out[p], gamma, 0.25) for p in m_out})

        refresh = (state.count % interval) == 0
        p_in, p_out = jax.lax.cond(
            refresh, recompute, lambda _: (state.p_in, state.p_out), operand=None)

        for p in state.m_in:
            g = flat[p].astype(jnp.float32)
            out = jnp.einsum('...ij,...jo->...io', p_in[p], g)
            out = jnp.einsum('...io,...oj->...ij', out, p_out[p])
            flat[p] = out.astype(flat[p].dtype)
        return kvlib.unflatten_params(flat), ShampooState(
            m_in=m_in, m_out=m_out, p_in=p_in, p_out=p_out, count=state.count + 1)

    return GradientTransformation(init, update)


def shampoo(lr=0.1, gamma: float = 1e-4, interval: int = 1,
            momentum: float = 0.9, weight_decay: float = 0.0,
            graft: bool = True) -> GradientTransformation:
    parts = []
    if weight_decay:
        parts.append(add_decayed_weights(weight_decay))
    parts.append(shampoo_preconditioner(gamma, interval=interval))
    if graft:
        parts.append(graft_to_grad_magnitude())
    parts.append(trace(momentum))
    parts.append(scale_by_schedule(lr if callable(lr) else (lambda _: lr)))
    return chain(*parts)


CAPTURE = kvlib.NO_CAPTURE
