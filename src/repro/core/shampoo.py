"""Shampoo baseline (paper Eq. 8, k=2) with update-interval + grafting.

Statistics are accumulated Adagrad-style (M += mat_i(G) mat_i(G)ᵀ, ε-init);
inverse 4th roots are recomputed every ``interval`` steps (Fig. 6 style) and
cached.  Grafting to the gradient magnitude follows [Anil et al. 2021] as the
paper's §4.2 does for Eva-s.

Bucketed: the M_in/M_out accumulators and cached roots live bucket-stacked;
accumulation is one batched contraction per bucket, root recomputation one
fused ``lax.map`` per bucket, application one batched two-sided contraction
per bucket via ``precondition_tree``.
"""
from __future__ import annotations

from typing import Any, NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.comm import exchange as comm_exchange
from repro.core import bucketing
from repro.core import kv as kvlib
from repro.core import precondition as pre
from repro.core.clipping import (Epilogue, fused_tail,
                                 graft_to_grad_magnitude)
from repro.core.eva_s import default_precon_predicate
from repro.core.transform import (Extras, GradientTransformation, chain,
                                  add_decayed_weights, ema_trace,
                                  scale_by_schedule)
from repro.schedule import (ownership, pipeline as pipemod,
                            policy as schedpol, runtime as schedrt)
from repro.core import factor_sharded as fsh


class ShampooState(NamedTuple):
    m_in: dict    # {bucket: (N, ..., d_in, d_in)}
    m_out: dict   # {bucket: (N, ..., d_out, d_out)}
    p_in: dict    # cached (M+γI)^{-1/4}
    p_out: dict
    sched: schedpol.SchedState
    # pipeline='onestep': {'refresh': PipelineState (age only — p_in/p_out
    # double as the in-flight root buffer)}.  Shampoo accumulates from local
    # grads (no stats collective), so only the refresh exchange is staged.
    pipe: Any = None
    # sharded-factor head buckets (Extras.factor tripped): cached dense-side
    # roots + frozen dampings.  None on the all-dense legacy path.
    head: Any = None


def shampoo_preconditioner(gamma: float = 1e-4, eps_init: float = 1e-6,
                           interval: int = 1,
                           policy: Optional[schedpol.RefreshPolicy] = None,
                           predicate=default_precon_predicate) -> GradientTransformation:

    def init(params, extras: Extras | None = None):
        flat = kvlib.flatten_params(params)
        plan = bucketing.build_plan(flat, predicate)
        m_in, m_out = {}, {}
        for b in plan.buckets:
            lead = (len(b.paths),) + b.shape[:-2]
            d_in, d_out = b.shape[-2], b.shape[-1]
            m_in[b.key] = eps_init * jnp.broadcast_to(
                jnp.eye(d_in, dtype=jnp.float32), lead + (d_in, d_in))
            m_out[b.key] = eps_init * jnp.broadcast_to(
                jnp.eye(d_out, dtype=jnp.float32), lead + (d_out, d_out))
        rt = schedrt.from_extras(extras)
        pol = rt.resolve(policy, interval)
        pipe = ({'refresh': pipemod.init_state()}
                if rt.pipeline == 'onestep' else None)
        fcfg = fsh.from_extras(extras)
        _, head_pol = fsh.split_plan(plan, fcfg)
        head = fsh.init_head({k: (m_in[k], m_out[k]) for k in head_pol},
                             head_pol, fcfg, plan, 'shampoo')
        return ShampooState(
            m_in=m_in, m_out=m_out,
            p_in={k: jnp.zeros_like(v) for k, v in m_in.items()
                  if k not in head_pol},
            p_out={k: jnp.zeros_like(v) for k, v in m_out.items()
                   if k not in head_pol},
            sched=schedpol.init_state(pol, {'m_in': m_in, 'm_out': m_out}),
            pipe=pipe, head=head)

    def update(updates, state: ShampooState, params=None, extras: Extras | None = None):
        del params
        rt = schedrt.from_extras(extras)
        pol = rt.resolve(policy, interval)
        pipe = schedrt.resolve_pipe(rt, state.pipe)
        flat = kvlib.flatten_params(updates)
        plan = bucketing.build_plan(flat, predicate)
        g_b = bucketing.gather(plan, {p: flat[p] for p in plan.paths})
        m_in, m_out = {}, {}
        for b in plan.buckets:
            g = g_b[b.key].astype(jnp.float32)
            m_in[b.key] = state.m_in[b.key] + jnp.einsum('...io,...jo->...ij', g, g)
            m_out[b.key] = state.m_out[b.key] + jnp.einsum('...io,...ij->...oj', g, g)

        accum = {'m_in': m_in, 'm_out': m_out}
        refresh, staleness = pol.decide(state.sched, accum)

        def one(b, args):
            del b
            mi, mo = args
            return (pre._inv_proot_psd(mi, gamma, 0.25),
                    pre._inv_proot_psd(mo, gamma, 0.25))

        fcfg = fsh.from_extras(extras)
        dense_plan, head_pol = fsh.split_plan(plan, fcfg)
        staged = schedrt.sharded_refresh(
            dense_plan, refresh, one,
            {k: (m_in[k], m_out[k]) for k in m_in if k not in head_pol},
            {k: (state.p_in[k], state.p_out[k]) for k in state.p_in},
            cost=ownership.inverse_cost('both'), shard=rt.shard_refresh,
            comm=comm_exchange.from_extras(extras), site='refresh/shampoo',
            pipe=None if pipe is None else pipe['refresh'])
        if pipe is None:
            used = new = staged
            new_pipe = None
        else:
            used, new, pipe_ref = staged
            new_pipe = {'refresh': pipe_ref}
        p_in = {k: v[0] for k, v in new.items()}
        p_out = {k: v[1] for k, v in new.items()}
        # head buckets skip the root refresh + exchange entirely: the
        # oversized side is applied matrix-free (binomial series for the
        # −1/4 root) from the live accumulator in factor_sharded
        head_factors = {k: (m_in[k], m_out[k]) for k in head_pol}
        head = fsh.refresh_head(refresh, head_factors, state.head, head_pol,
                                gamma, cfg=fcfg, plan=plan, method='shampoo')
        sched = schedpol.commit(pol, state.sched, accum, refresh, staleness)

        ops = {k: kvlib.LayerStats(a_outer=used[k][0], b_outer=used[k][1])
               for k in used}
        out = pre.precondition_tree(flat, ops, 'shampoo_cached', gamma,
                                    plan=dense_plan)
        if head_pol:
            out = fsh.apply_tree(out, plan, head_pol, head, head_factors,
                                 power=0.25, cfg=fcfg, site='factor/shampoo')
        return kvlib.unflatten_params(out), ShampooState(
            m_in=m_in, m_out=m_out, p_in=p_in, p_out=p_out, sched=sched,
            pipe=new_pipe, head=head)

    return GradientTransformation(init, update)


def shampoo(lr=0.1, gamma: float = 1e-4, interval: int = 1,
            momentum: float = 0.9, weight_decay: float = 0.0,
            graft: bool = True,
            policy: Optional[schedpol.RefreshPolicy] = None,
            fused: bool = False) -> GradientTransformation:
    """``fused=True`` collapses graft + EMA momentum into the
    single-traversal ``clipping.fused_tail`` (the eigh-based preconditioner
    itself has nothing kernel-side to fuse); math is unchanged."""
    parts = []
    if weight_decay:
        parts.append(add_decayed_weights(weight_decay))
    parts.append(shampoo_preconditioner(gamma, interval=interval, policy=policy))
    if graft and fused:
        parts.append(fused_tail(Epilogue(kind='graft', momentum=momentum)))
    else:
        if graft:
            parts.append(graft_to_grad_magnitude())
        parts.append(ema_trace(momentum))
    parts.append(scale_by_schedule(lr if callable(lr) else (lambda _: lr)))
    return chain(*parts)


CAPTURE = kvlib.NO_CAPTURE
