"""Optimizer registry: name -> (factory, CaptureConfig).

``make_optimizer('eva', lr=0.1)`` is the single entry point used by the
trainer, launcher, benchmarks and examples.
"""
from __future__ import annotations

from typing import Any

from repro.core import kv as kvlib
from repro.core.eva import CAPTURE as _EVA_CAP
from repro.core.eva import eva as _eva_fn
from repro.core.eva_f import CAPTURE as _EVA_F_CAP
from repro.core.eva_f import eva_f as _eva_f_fn
from repro.core.eva_s import CAPTURE as _EVA_S_CAP
from repro.core.eva_s import eva_s as _eva_s_fn
from repro.core.firstorder import adagrad as _adagrad_fn
from repro.core.firstorder import adamw as _adamw_fn
from repro.core.firstorder import sgd as _sgd_fn
from repro.core.foof import CAPTURE as _FOOF_CAP
from repro.core.foof import foof as _foof_fn
from repro.core.kfac import CAPTURE as _KFAC_CAP
from repro.core.kfac import kfac as _kfac_fn
from repro.core.mfac import mfac as _mfac_fn
from repro.core.shampoo import shampoo as _shampoo_fn
from repro.core.transform import GradientTransformation

_REGISTRY: dict[str, tuple[Any, kvlib.CaptureConfig]] = {
    'eva': (_eva_fn, _EVA_CAP),
    'eva_f': (_eva_f_fn, _EVA_F_CAP),
    'eva_s': (_eva_s_fn, _EVA_S_CAP),
    'kfac': (_kfac_fn, _KFAC_CAP),
    'foof': (_foof_fn, _FOOF_CAP),
    'shampoo': (_shampoo_fn, kvlib.NO_CAPTURE),
    'mfac': (_mfac_fn, kvlib.NO_CAPTURE),
    'sgd': (_sgd_fn, kvlib.NO_CAPTURE),
    'adagrad': (_adagrad_fn, kvlib.NO_CAPTURE),
    'adamw': (_adamw_fn, kvlib.NO_CAPTURE),
}


def optimizer_names() -> list[str]:
    return sorted(_REGISTRY)


def capture_for(name: str) -> kvlib.CaptureConfig:
    return _REGISTRY[name][1]


def make_optimizer(name: str, **kwargs) -> tuple[GradientTransformation, kvlib.CaptureConfig]:
    if name not in _REGISTRY:
        raise KeyError(f'unknown optimizer {name!r}; have {optimizer_names()}')
    factory, capture = _REGISTRY[name]
    return factory(**kwargs), capture
