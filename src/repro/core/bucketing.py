"""Bucketed parameter grouping for vectorized preconditioning.

The paper's central claim (§3, §4) is that second-order updates become
*vectorizable*: the Sherman–Morrison/Kronecker-vector formulas broadcast
over any leading dims.  A per-path Python dict loop throws that away — a
40-layer model pays 40 kernel launches per step.  This module groups
parameter paths by ``(shape, dtype)`` into **buckets**, stacks each bucket
into one ``(N, *shape)`` array, and lets the caller run ONE broadcast (or
grid-folded Pallas) preconditioning call per bucket before scattering the
results back.

Layout contract
---------------
* A plan is a deterministic pure function of the flat ``{path: leaf}``
  mapping's shapes/dtypes: paths are sorted, buckets are keyed
  ``"<dtype>_<d0>x<d1>..."`` and emitted in sorted-key order.  Determinism
  is what lets optimizer *state* (EMA'd statistics, cached inverses) live
  bucketed: the plan rebuilt from the same tree always aligns with it.
* Stacking axis is a NEW leading axis 0; entry ``i`` of a bucket is
  ``bucket.paths[i]``.  Scan-stacked leaves (leading layer/expert dims) keep
  those dims *inside* the bucket shape — a bucket of ``(L, d_in, d_out)``
  leaves stacks to ``(N, L, d_in, d_out)``, which the broadcast formulas
  and the grid-folded kernels handle unchanged.
* ``build_plan`` is memoized on the shape signature, so deriving the plan
  at ``init_opt_state`` time and re-deriving it inside a jitted ``update``
  costs one dict walk, not a recomputation.
"""
from __future__ import annotations

import functools
from typing import Any, Callable, Mapping, NamedTuple, Optional

import jax
import jax.numpy as jnp


# Buckets with fewer than this many members skip the stack/unstack copies in
# ``precondition.precondition_tree`` and take the broadcast per-path calls
# instead: the table5 CPU numbers showed gather/scatter copies for N<=2
# buckets costing more than the one launch they save (ROADMAP "bucket gather
# cost").  State layout is unaffected — optimizer state stays bucket-stacked
# for every bucket (``gather_tree``/``gather`` ignore the flag), so the
# threshold is purely an execution-path choice and outputs stay bit-identical
# either way (proven in tests/test_bucketing.py).
DEFAULT_MIN_BUCKET_SIZE = 3


class Bucket(NamedTuple):
    key: str                    # "<dtype>_<d0>x<d1>..."
    paths: tuple[str, ...]      # sorted; index in this tuple == stack index
    shape: tuple[int, ...]      # per-leaf shape (without the stack axis)
    dtype: Any                  # jnp dtype
    stacked: bool = True        # False: small bucket, broadcast path


class BucketPlan(NamedTuple):
    buckets: tuple[Bucket, ...]

    @property
    def paths(self) -> tuple[str, ...]:
        return tuple(p for b in self.buckets for p in b.paths)

    def __len__(self) -> int:
        return len(self.buckets)


def bucket_key(shape: tuple[int, ...], dtype) -> str:
    return f"{jnp.dtype(dtype).name}_{'x'.join(map(str, shape))}"


@functools.lru_cache(maxsize=512)
def _plan_from_sig(sig: tuple, min_bucket_size: int) -> BucketPlan:
    groups: dict[str, list] = {}
    meta: dict[str, tuple] = {}
    for path, shape, dtype_name in sig:
        key = bucket_key(shape, dtype_name)
        groups.setdefault(key, []).append(path)
        meta[key] = (shape, dtype_name)
    buckets = tuple(
        Bucket(key=k, paths=tuple(sorted(groups[k])),
               shape=meta[k][0], dtype=jnp.dtype(meta[k][1]),
               stacked=len(groups[k]) >= min_bucket_size)
        for k in sorted(groups))
    return BucketPlan(buckets=buckets)


def build_plan(flat: Mapping[str, Any],
               predicate: Optional[Callable[[str, Any], bool]] = None,
               min_bucket_size: Optional[int] = None) -> BucketPlan:
    """Group ``{path: leaf}`` (arrays / ShapeDtypeStructs / tracers) into a
    deterministic BucketPlan; ``predicate(path, leaf)`` filters paths.
    Buckets smaller than ``min_bucket_size`` (default
    ``DEFAULT_MIN_BUCKET_SIZE``) are marked unstacked — same grouping and
    state layout, but ``precondition_tree`` skips their gather/scatter."""
    if min_bucket_size is None:
        min_bucket_size = DEFAULT_MIN_BUCKET_SIZE
    sig = tuple(sorted(
        (p, tuple(x.shape), jnp.dtype(x.dtype).name)
        for p, x in flat.items()
        if predicate is None or predicate(p, x)))
    return _plan_from_sig(sig, min_bucket_size)


def gather(plan: BucketPlan, flat: Mapping[str, Any]) -> dict[str, jnp.ndarray]:
    """Stack each bucket's leaves along a new axis 0: {key: (N, *shape)}."""
    return {b.key: jnp.stack([flat[p] for p in b.paths]) for b in plan.buckets}


def scatter(plan: BucketPlan, bucketed: Mapping[str, jnp.ndarray]) -> dict[str, Any]:
    """Inverse of ``gather``: {path: (*shape)} in plan order."""
    out = {}
    for b in plan.buckets:
        stacked = bucketed[b.key]
        for i, p in enumerate(b.paths):
            out[p] = stacked[i]
    return out


def gather_tree(plan: BucketPlan, flat: Mapping[str, Any]) -> dict[str, Any]:
    """``gather`` for per-path *pytrees* (e.g. ``kv.LayerStats``): each leaf
    position is stacked across the bucket's paths; None leaves stay None.

    All paths in a bucket must share the pytree structure (true by
    construction: one capture config per optimizer)."""
    out = {}
    for b in plan.buckets:
        trees = [flat[p] for p in b.paths]
        out[b.key] = jax.tree_util.tree_map(lambda *ls: jnp.stack(ls), *trees)
    return out


def is_bucketed(plan: BucketPlan, mapping: Mapping[str, Any]) -> bool:
    """True when ``mapping`` is keyed by this plan's bucket keys (already
    gathered) rather than by parameter paths."""
    keys = {b.key for b in plan.buckets}
    return bool(mapping) and set(mapping) <= keys
