"""FOOF baseline (paper Eq. 6): right-side K-FAC, C = I ⊗ AAᵀ."""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core import kv as kvlib
from repro.core import precondition as pre
from repro.core.clipping import kl_normalize
from repro.core.eva import _extract, _zeros_like_spec
from repro.core.kfac import _damped_inv
from repro.core.transform import (Extras, GradientTransformation, chain,
                                  add_decayed_weights, scale_by_schedule, trace)


class FoofState(NamedTuple):
    running: kvlib.RunningStats
    a_inv: dict
    count: jnp.ndarray


def foof_preconditioner(gamma: float = 0.03, kf_decay: float = 0.95,
                        interval: int = 1) -> GradientTransformation:
    fields = ('a_outer',)

    def init(params, extras: Extras | None = None):
        del params
        if extras is None or extras.stats is None:
            raise ValueError('foof_preconditioner.init needs example stats')
        run = kvlib.init_running(_zeros_like_spec(_extract(extras.stats, fields)))
        a_inv = {p: jnp.zeros_like(st.a_outer) for p, st in run.stats.items()}
        return FoofState(running=run, a_inv=a_inv, count=jnp.zeros((), jnp.int32))

    def update(updates, state: FoofState, params=None, extras: Extras | None = None):
        del params
        fresh = _extract(extras.stats, fields)
        stats, running = kvlib.update_running(state.running, fresh, kf_decay)

        def recompute(_):
            return {p: _damped_inv(st.a_outer, gamma) for p, st in stats.items()}

        refresh = (state.count % interval) == 0
        a_inv = jax.lax.cond(refresh, recompute, lambda _: state.a_inv, operand=None)

        flat = kvlib.flatten_params(updates)
        for p in stats:
            g = flat[p].astype(jnp.float32)
            flat[p] = jnp.einsum('...ij,...jo->...io', a_inv[p], g).astype(flat[p].dtype)
        return kvlib.unflatten_params(flat), FoofState(
            running=running, a_inv=a_inv, count=state.count + 1)

    return GradientTransformation(init, update)


def foof(lr=0.1, gamma: float = 0.03, kf_decay: float = 0.95, interval: int = 1,
         momentum: float = 0.9, weight_decay: float = 0.0) -> GradientTransformation:
    parts = []
    if weight_decay:
        parts.append(add_decayed_weights(weight_decay))
    parts.append(foof_preconditioner(gamma, kf_decay, interval))
    parts.append(kl_normalize())
    parts.append(trace(momentum))
    parts.append(scale_by_schedule(lr if callable(lr) else (lambda _: lr)))
    return chain(*parts)


CAPTURE = kvlib.FOOF_CAPTURE
