"""FOOF baseline (paper Eq. 6): right-side K-FAC, C = I ⊗ AAᵀ.

Bucketed: the AAᵀ EMA and the cached damped inverses live bucket-stacked;
recomputation is one fused ``lax.map`` per bucket and application one
batched contraction per bucket via ``precondition_tree``.  Inverse refresh
is scheduled/worker-sharded through ``repro.schedule`` (input factor only,
so ownership weighting uses the 'left' cost model).
"""
from __future__ import annotations

from typing import Any, NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.comm import exchange as comm_exchange
from repro.core import bucketing
from repro.core import kv as kvlib
from repro.core import precondition as pre
from repro.core.clipping import Epilogue, fused_tail, kl_normalize
from repro.core.eva import _extract, _stats_plan, _zeros_like_spec
from repro.core.kfac import _damped_inv
from repro.core.transform import (Extras, GradientTransformation, chain,
                                  add_decayed_weights, ema_trace,
                                  scale_by_schedule)
from repro.schedule import (ownership, pipeline as pipemod,
                            policy as schedpol, runtime as schedrt)


class FoofState(NamedTuple):
    running: kvlib.RunningStats
    a_inv: dict
    sched: schedpol.SchedState
    # pipeline='onestep': {'stats': PipelineState (reduced AAᵀ buffer),
    # 'refresh': PipelineState (age only — a_inv doubles as the in-flight
    # inverse buffer)}.  None in sync mode.
    pipe: Any = None


def foof_preconditioner(gamma: float = 0.03, kf_decay: float = 0.95,
                        interval: int = 1,
                        policy: Optional[schedpol.RefreshPolicy] = None
                        ) -> GradientTransformation:
    fields = ('a_outer',)

    def init(params, extras: Extras | None = None):
        if extras is None or extras.stats is None:
            raise ValueError('foof_preconditioner.init needs example stats')
        flat = kvlib.flatten_params(params)
        plan = _stats_plan(flat, extras.stats, extras)
        zeros = bucketing.gather_tree(
            plan, _zeros_like_spec(_extract(extras.stats, fields)))
        run = kvlib.init_running(zeros)
        a_inv = {k: jnp.zeros_like(st.a_outer) for k, st in run.stats.items()}
        rt = schedrt.from_extras(extras)
        pol = rt.resolve(policy, interval)
        pipe = ({'stats': pipemod.init_state(zeros),
                 'refresh': pipemod.init_state()}
                if rt.pipeline == 'onestep' else None)
        return FoofState(running=run, a_inv=a_inv,
                         sched=schedpol.init_state(pol, run.stats), pipe=pipe)

    def update(updates, state: FoofState, params=None, extras: Extras | None = None):
        del params
        rt = schedrt.from_extras(extras)
        comm = comm_exchange.from_extras(extras)
        pol = rt.resolve(policy, interval)
        pipe = schedrt.resolve_pipe(rt, state.pipe)
        flat = kvlib.flatten_params(updates)
        fresh_flat = _extract(extras.stats, fields)
        plan = _stats_plan(flat, fresh_flat, extras)
        fresh, pipe_stats = pipemod.staged_pmean(
            bucketing.gather_tree(plan, fresh_flat),
            None if pipe is None else pipe['stats'],
            codec=comm.stats, site='stats/foof')
        stats, running = kvlib.update_running(state.running, fresh, kf_decay)

        refresh, staleness = pol.decide(state.sched, stats)
        staged = schedrt.sharded_refresh(
            plan, refresh, lambda b, m: _damped_inv(m, gamma),
            {k: st.a_outer for k, st in stats.items()},
            dict(state.a_inv),
            cost=ownership.inverse_cost('left'), shard=rt.shard_refresh,
            comm=comm, site='refresh/foof',
            pipe=None if pipe is None else pipe['refresh'])
        if pipe is None:
            used = a_inv = staged
            new_pipe = None
        else:
            used, a_inv, pipe_ref = staged
            new_pipe = {'stats': pipe_stats, 'refresh': pipe_ref}
        sched = schedpol.commit(pol, state.sched, stats, refresh, staleness)

        ops = {k: kvlib.LayerStats(a_outer=used[k]) for k in used}
        out = pre.precondition_tree(flat, ops, 'foof_cached', gamma, plan=plan)
        return kvlib.unflatten_params(out), FoofState(
            running=running, a_inv=a_inv, sched=sched, pipe=new_pipe)

    return GradientTransformation(init, update)


def foof(lr=0.1, gamma: float = 0.03, kf_decay: float = 0.95, interval: int = 1,
         momentum: float = 0.9, weight_decay: float = 0.0,
         policy: Optional[schedpol.RefreshPolicy] = None,
         fused: bool = False) -> GradientTransformation:
    """``fused=True`` collapses KL normalize + EMA momentum into the
    single-traversal ``clipping.fused_tail`` (the solve-based
    preconditioner itself has nothing kernel-side to fuse); math is
    unchanged."""
    parts = []
    if weight_decay:
        parts.append(add_decayed_weights(weight_decay))
    parts.append(foof_preconditioner(gamma, kf_decay, interval, policy=policy))
    if fused:
        parts.append(fused_tail(Epilogue(kind='kl_normalize',
                                         momentum=momentum)))
    else:
        parts.append(kl_normalize())
        parts.append(ema_trace(momentum))
    parts.append(scale_by_schedule(lr if callable(lr) else (lambda _: lr)))
    return chain(*parts)


CAPTURE = kvlib.FOOF_CAPTURE
