"""Kronecker-vector / Kronecker-factor capture — the JAX answer to hooks.

The paper's PyTorch implementation captures layer inputs ``A`` and
pre-activation output gradients ``B`` with forward-pre-hooks and
backward-hooks.  JAX is functional, so we use two mechanisms instead:

* **forward stats**: every preconditioned linear emits the statistics of its
  *input* (``a_mean`` and/or ``a_outer``) as auxiliary outputs threaded
  through the model's apply function (and stacked by ``lax.scan`` for
  layer-stacked models).

* **taps** for the backward side: the layer computes ``z = x @ W + t`` where
  ``t`` is a zero *tap*.  For a vector tap of shape ``(d_out,)`` broadcast
  over tokens, ``∂loss/∂t = Σ_tokens ∂loss/∂z`` — exactly the batch-summed
  pre-activation gradient, i.e. the paper's ``b̄`` (with mean-loss convention,
  ``b̄ = Σ_t cotangent_t``).  The backward of a broadcast-add is a reduce-sum
  that XLA fuses into the existing backprop, so this costs **no extra
  activation memory** — which is the whole point of Eva vs K-FAC.  For the
  K-FAC baseline a *full* tap (``z``-shaped) materializes the cotangent so
  ``BBᵀ`` can be formed; that expense is intrinsic to K-FAC, not the capture
  mechanism.

Scaling conventions (all with ``loss = mean over tokens`` and ``n`` tokens):
  ``ā      = (1/n) Σ a_t``                    (paper's mean-col(A))
  ``b̄      = (1/n) Σ ∂ℓ_t/∂z_t = Σ_t z̃_t``    (z̃ = cotangent of the mean loss)
  ``A_kf   = (1/n) Σ a_t a_tᵀ``               (normalized K-FAC factor)
  ``B_kf   = n · Σ z̃_t z̃_tᵀ``                 (= (1/n) Σ (∂ℓ_t/∂z_t)(·)ᵀ)
Normalized KFs deviate from the paper's unnormalized Eq. 4 by a factor of n
absorbed into the damping γ; Eq. 19's trust-region ordering
``A_kf ⪰ ā āᵀ`` holds exactly in this convention.
"""
from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple, Optional

import jax
import jax.numpy as jnp


# ---------------------------------------------------------------------------
# Capture configuration


@dataclasses.dataclass(frozen=True)
class CaptureConfig:
    """What statistics the optimizer wants per preconditioned layer.

    a: None | 'mean' | 'outer'   — input-activation side (forward).
    b: None | 'mean' | 'outer'   — pre-activation-gradient side (backward).
        'mean'  -> vector taps (d_out,)        [Eva]
        'outer' -> full taps (tokens, d_out)   [K-FAC baseline only]
    """

    a: Optional[str] = None
    b: Optional[str] = None

    @property
    def needs_taps(self) -> bool:
        return self.b is not None

    @property
    def active(self) -> bool:
        return self.a is not None or self.b is not None


NO_CAPTURE = CaptureConfig(None, None)
EVA_CAPTURE = CaptureConfig('mean', 'mean')
EVA_F_CAPTURE = CaptureConfig('mean', None)
FOOF_CAPTURE = CaptureConfig('outer', None)
KFAC_CAPTURE = CaptureConfig('outer', 'outer')


class LayerStats(NamedTuple):
    """Per-layer captured statistics (leading dims = layer-stack / experts).

    Any field may be None.  ``count`` is the number of tokens that
    contributed (scalar, or per-expert ``(E,)`` for MoE layers).
    """

    a_mean: Any = None   # (..., d_in)
    b_mean: Any = None   # (..., d_out)
    a_outer: Any = None  # (..., d_in, d_in)
    b_outer: Any = None  # (..., d_out, d_out)
    count: Any = None


# ---------------------------------------------------------------------------
# Forward-side statistics helpers (used inside model code)


def _flatten_tokens(x: jnp.ndarray) -> jnp.ndarray:
    """(batch..., d) -> (tokens, d)."""
    return x.reshape(-1, x.shape[-1])


def fwd_stats(x: jnp.ndarray, capture: CaptureConfig) -> LayerStats:
    """Input-side statistics of a linear layer's input ``x (..., d_in)``.

    Reductions use ``preferred_element_type=f32`` instead of materializing
    an f32 copy of the activation (at MoE scale that copy was one of the
    largest HBM-traffic terms in the profile — §Perf)."""
    if capture is None or capture.a is None:
        return LayerStats()
    xt = _flatten_tokens(x)
    n = xt.shape[0]
    ones = jnp.ones((n,), xt.dtype)
    a_mean = jnp.einsum('ni,n->i', xt, ones,
                        preferred_element_type=jnp.float32) / n
    if capture.a == 'outer':
        a_outer = jnp.einsum('ni,nj->ij', xt, xt,
                             preferred_element_type=jnp.float32) / n
        return LayerStats(a_mean=a_mean, a_outer=a_outer,
                          count=jnp.asarray(n, jnp.float32))
    return LayerStats(a_mean=a_mean, count=jnp.asarray(n, jnp.float32))


def fwd_stats_masked(x: jnp.ndarray, mask: jnp.ndarray,
                     capture: CaptureConfig) -> LayerStats:
    """Masked input stats for MoE expert layers (fused reductions, no f32
    activation copy).

    x: (E, C, d_in) dispatched tokens; mask: (E, C) validity in {0,1}.
    Returns per-expert stats with leading dim E.
    """
    if capture is None or capture.a is None:
        return LayerStats()
    cnt = jnp.sum(mask, axis=-1)                       # (E,)
    denom = jnp.maximum(cnt, 1.0)[..., None]
    a_mean = jnp.einsum('eci,ec->ei', x, mask.astype(x.dtype),
                        preferred_element_type=jnp.float32) / denom
    if capture.a == 'outer':
        xm = x * mask[..., None].astype(x.dtype)
        a_outer = jnp.einsum('eci,ecj->eij', xm, xm,
                             preferred_element_type=jnp.float32) / denom[..., None]
        return LayerStats(a_mean=a_mean, a_outer=a_outer, count=cnt)
    return LayerStats(a_mean=a_mean, count=cnt)


# ---------------------------------------------------------------------------
# Taps


def vector_tap_shape(w_shape: tuple[int, ...]) -> tuple[int, ...]:
    """Weights are laid out (..., d_in, d_out); the tap is (..., d_out)."""
    return tuple(w_shape[:-2]) + (w_shape[-1],)


def make_vector_taps(params: Any, precon_paths: set[str]) -> dict[str, jnp.ndarray]:
    """Zero vector taps for every preconditioned weight path.

    ``params`` is a nested dict; ``precon_paths`` are '/'-joined key paths of
    weight leaves (shape (..., d_in, d_out)).
    """
    flat = flatten_params(params)
    taps = {}
    for path in precon_paths:
        w = flat[path]
        taps[path] = jnp.zeros(vector_tap_shape(w.shape), jnp.float32)
    return taps


def full_tap_shape(w_shape: tuple[int, ...],
                   token_shape: tuple[int, ...]) -> tuple[int, ...]:
    """Full (z-shaped) tap for a weight (lead..., d_in, d_out): the tap is
    (lead..., *token_shape, d_out) — the lead dims line up with the layer
    stack so ``lax.scan`` slices the tap alongside the weight."""
    return tuple(w_shape[:-2]) + tuple(token_shape) + (w_shape[-1],)


def make_full_taps(params: Any, precon_paths: set[str],
                   token_shape: tuple[int, ...]) -> dict[str, jnp.ndarray]:
    """Zero full taps (K-FAC's ``b='outer'`` capture) for every
    preconditioned weight path.

    Unlike vector taps, a full tap materializes the per-token cotangent so
    ``BBᵀ`` can be formed — that cost is intrinsic to K-FAC.
    ``token_shape`` is the broadcastable token layout of the layer outputs,
    e.g. ``(batch, seq_len)`` for the LM or ``(batch,)`` for the MLPs.
    """
    flat = flatten_params(params)
    return {path: jnp.zeros(full_tap_shape(flat[path].shape, token_shape),
                            jnp.float32)
            for path in precon_paths}


def flatten_params(params: Any, prefix: str = '') -> dict[str, Any]:
    """Nested-dict params -> {'a/b/c': leaf}."""
    out = {}
    if isinstance(params, dict):
        for k, v in params.items():
            key = f'{prefix}/{k}' if prefix else str(k)
            out.update(flatten_params(v, key))
    else:
        out[prefix] = params
    return out


def unflatten_params(flat: dict[str, Any]) -> Any:
    out: dict[str, Any] = {}
    for path, leaf in flat.items():
        keys = path.split('/')
        d = out
        for k in keys[:-1]:
            d = d.setdefault(k, {})
        d[keys[-1]] = leaf
    return out


# ---------------------------------------------------------------------------
# Finalization: merge forward stats and tap gradients


def finalize_stats(forward: dict[str, LayerStats],
                   tap_grads: Optional[dict[str, jnp.ndarray]],
                   capture: CaptureConfig,
                   n_tokens: Optional[jnp.ndarray] = None) -> dict[str, LayerStats]:
    """Combine forward-side stats with tap gradients into optimizer stats.

    For vector taps the gradient *is* ``b̄`` (see module docstring).  For MoE
    layers (per-expert counts), ``b̄_e`` is rescaled to a per-routed-token
    mean-consistent value: ``b̄_e = tap_grad_e * n / count_e``.
    """
    out = {}
    for path, st in forward.items():
        b_mean = None
        b_outer = None
        if tap_grads is not None and path in tap_grads:
            tg = tap_grads[path]
            if capture.b == 'mean':
                b_mean = tg.astype(jnp.float32)
                if st.count is not None and st.count.ndim >= 1 and n_tokens is not None:
                    # per-expert rescale: tap sums cotangents of routed tokens
                    scale = n_tokens / jnp.maximum(st.count, 1.0)
                    b_mean = b_mean * scale[..., None]
            elif capture.b == 'outer':
                # tg is the full cotangent (lead..., tokens..., d_out);
                # B_kf = n * Σ z̃ z̃ᵀ, reduced over token axes ONLY.  The
                # leading stack dims (scan layers / experts) must survive
                # — flattening them into the token axis dropped the scan
                # path dim from b_outer while the forward-side a_outer
                # kept it, so `sharded_refresh`'s cached and recomputed
                # branches disagreed on bucket shapes and lowering failed
                # on stacked models (the kfac demo-LM bug).  The lead-dim
                # count comes from the forward stats of the same layer.
                nlead = 0
                if st.a_outer is not None:
                    nlead = st.a_outer.ndim - 2
                elif st.a_mean is not None:
                    nlead = st.a_mean.ndim - 1
                zt = tg.reshape(tg.shape[:nlead] + (-1, tg.shape[-1]))
                zt = zt.astype(jnp.float32)
                n = n_tokens if n_tokens is not None else zt.shape[-2]
                b_outer = n * jnp.einsum('...ti,...tj->...ij', zt, zt)
                b_mean = jnp.sum(zt, axis=-2)
        out[path] = LayerStats(a_mean=st.a_mean, b_mean=b_mean,
                               a_outer=st.a_outer, b_outer=b_outer,
                               count=st.count)
    return out


# ---------------------------------------------------------------------------
# Running averages of stats (paper Eq. 14-15, bias-corrected)
#
# The tree under ``stats`` may be keyed per-path ({'layer/w': LayerStats})
# or — as the bucketed optimizers store it — per-bucket
# ({'f32_16x32': LayerStats(stacked fields)}, see ``core/bucketing``).  The
# EMA below is a tree_map, so the bucketed form turns per-path scalar-decay
# ops into ONE fused op per bucket field: bucket-level updates for free.


class RunningStats(NamedTuple):
    stats: dict[str, LayerStats]
    count: jnp.ndarray  # step counter for bias correction


def init_running(stats_shapes: dict[str, LayerStats]) -> RunningStats:
    zeros = jax.tree_util.tree_map(
        lambda x: jnp.zeros(x.shape, jnp.float32), stats_shapes)
    return RunningStats(stats=zeros, count=jnp.zeros((), jnp.int32))


def update_running(run: RunningStats, new: dict[str, LayerStats],
                   decay: float) -> tuple[dict[str, LayerStats], RunningStats]:
    """EMA with weight ``decay`` on the old value (paper's ξ = 1-decay).

    Returns (bias-corrected stats to use this step, new running state).
    Bias correction makes step 1 equal to the fresh batch stats — matching
    the reference implementation's "initialize from first batch" behavior.
    """
    count = run.count + 1
    ema = jax.tree_util.tree_map(
        lambda o, s: decay * o + (1.0 - decay) * s.astype(jnp.float32),
        run.stats, new)
    corr = 1.0 - jnp.asarray(decay, jnp.float32) ** count.astype(jnp.float32)
    corrected = jax.tree_util.tree_map(lambda x: x / corr, ema)
    return corrected, RunningStats(stats=ema, count=count)
