"""Minimal optax-like gradient-transformation algebra with a side-channel.

Second-order methods need more than (grads, state, params): Eva needs the
Kronecker-vector statistics captured during the forward/backward pass, KL
clipping needs the *raw* gradients alongside the preconditioned ones, and
grafting needs both magnitudes.  We thread all of that through an ``Extras``
record so individual transforms stay tiny and composable.

Every transform is a pair of pure functions ``(init, update)`` over pytrees,
which makes the whole optimizer state shardable, checkpointable and donatable
under ``pjit``.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple, Optional, Sequence

import jax
import jax.numpy as jnp


# ---------------------------------------------------------------------------
# Types


@dataclasses.dataclass(frozen=True)
class Extras:
    """Side-channel values available to every transform in a chain.

    Attributes:
      raw_grads: the unmodified gradients (before any preconditioning).
      stats: KV/KF statistics captured by the model forward/backward
        (see ``repro.core.kv``); a dict keyed by parameter path.
      loss: scalar loss value for logging-style transforms.
      step: current step (filled in by ``chain``).
      plan: optional ``repro.core.bucketing.BucketPlan`` built once at
        ``init_opt_state`` time; bucketed preconditioners use it instead of
        re-deriving the grouping (the fallback is a memoized re-derivation,
        so omitting it is always correct, just redundant work at trace time).
      sched: optional ``repro.schedule.RefreshRuntime`` — the curvature
        refresh runtime threaded next to the plan: default refresh policy
        and the worker-sharded-ownership switch.  Omitting it leaves each
        preconditioner on its own ``policy``/``interval`` arguments.
      comm: optional ``repro.comm.ExchangeConfig`` — which codec each
        cross-device exchange family (gradients / statistics / refresh)
        uses and whether the refresh exchange is the owned-slice
        all-gather or the legacy full-stack psum.  Omitting it means the
        defaults (f32 stats/refresh, owned-slice refresh exchange).
      factor: optional ``repro.core.factor_sharded.FactorShardConfig`` —
        the per-factor execution policy for oversized Kronecker factors
        (``head_policy='shard'|'exclude'|'dense'``, the sub-slice
        ``shard_threshold`` and the iterative-solver knobs).  Omitting it
        keeps every factor on the dense legacy path, bit-exactly.
      kernel: optional ``repro.kernels.dispatch.KernelConfig`` — the
        launcher-level kernel knobs (impl request 'auto' | 'pallas' |
        'pallas_interpret' | 'xla', autotune-cache path).  Omitting it
        leaves each preconditioner on its own ``impl``/``use_pallas``
        arguments (the historical behavior).
    """

    raw_grads: Any = None
    stats: Any = None
    loss: Any = None
    step: Any = None
    plan: Any = None
    sched: Any = None
    comm: Any = None
    factor: Any = None
    kernel: Any = None


class GradientTransformation(NamedTuple):
    init: Callable[[Any], Any]
    update: Callable[..., tuple[Any, Any]]  # (updates, state, params, extras)


class EmptyState(NamedTuple):
    pass


def _unit_init(params, extras=None):
    del params, extras
    return EmptyState()


def stateless(fn: Callable[[Any, Any, Extras], Any]) -> GradientTransformation:
    """Build a stateless transform from ``fn(updates, params, extras)``."""

    def update(updates, state, params=None, extras: Extras | None = None):
        return fn(updates, params, extras), state

    return GradientTransformation(_unit_init, update)


# ---------------------------------------------------------------------------
# Tree utilities


def tree_map(fn, *trees):
    return jax.tree_util.tree_map(fn, *trees)


def tree_vdot(a, b):
    """Global inner product <a, b> over two pytrees.

    Elementwise multiply + full reduce (NOT jnp.vdot: its 1-D flatten breaks
    sharding and forces a full all-gather of every gradient under SPMD).
    """
    leaves = jax.tree_util.tree_map(
        lambda x, y: jnp.sum(x.astype(jnp.float32) * y.astype(jnp.float32)), a, b
    )
    return jax.tree_util.tree_reduce(jnp.add, leaves, jnp.zeros((), jnp.float32))


def tree_norm_sq(a):
    return tree_vdot(a, a)


def tree_scale(tree, s):
    return jax.tree_util.tree_map(lambda x: (x.astype(jnp.float32) * s).astype(x.dtype), tree)


def tree_zeros_like(tree, dtype=None):
    return jax.tree_util.tree_map(
        lambda x: jnp.zeros(x.shape, dtype or x.dtype), tree
    )


# ---------------------------------------------------------------------------
# Chain


class ChainState(NamedTuple):
    step: jnp.ndarray
    inner: tuple


def chain(*transforms: GradientTransformation) -> GradientTransformation:
    """Compose transforms left-to-right; maintains a shared step counter.

    The ``Extras`` record is augmented with ``raw_grads`` (the incoming
    updates) and ``step`` before the first transform runs.
    """

    def init(params, extras: Extras | None = None):
        inner = []
        for t in transforms:
            try:
                inner.append(t.init(params, extras))
            except TypeError:
                inner.append(t.init(params))
        return ChainState(step=jnp.zeros((), jnp.int32), inner=tuple(inner))

    def update(updates, state: ChainState, params=None, extras: Extras | None = None):
        extras = extras or Extras()
        extras = dataclasses.replace(extras, raw_grads=updates, step=state.step)
        new_inner = []
        for t, s in zip(transforms, state.inner):
            updates, s = t.update(updates, s, params=params, extras=extras)
            new_inner.append(s)
        return updates, ChainState(step=state.step + 1, inner=tuple(new_inner))

    return GradientTransformation(init, update)


def apply_updates(params, updates):
    """``w <- w + Δw`` preserving dtypes (master math in f32)."""
    return jax.tree_util.tree_map(
        lambda p, u: (p.astype(jnp.float32) + u.astype(jnp.float32)).astype(p.dtype),
        params,
        updates,
    )


# ---------------------------------------------------------------------------
# First-order building blocks


class TraceState(NamedTuple):
    trace: Any


def trace(momentum: float = 0.9, nesterov: bool = False,
          dtype: Optional[jnp.dtype] = None,
          dampening: float = 0.0,
          bias_correction: bool = False) -> GradientTransformation:
    """Heavy-ball momentum (torch-SGD convention: m <- mu*m + (1-dampening)·g).

    ``dampening=momentum`` + ``bias_correction=True`` gives the EMA form
    ``m̂ = (mu·m + (1-mu)·g) / (1-mu^t)``: same smoothing direction as
    heavy-ball but unit steady-state gain instead of 1/(1-mu).  The
    second-order optimizers use this form so that momentum composes with the
    KL trust region — undamped heavy-ball multiplies the clipped update by
    up to 1/(1-mu) (10× at mu=0.9), stepping far outside the region the clip
    just enforced (the paper's §5 momentum ablation regressed without this).
    ``momentum=0`` reduces to the identity in both conventions.
    """

    def init(params):
        return TraceState(trace=jax.tree_util.tree_map(
            lambda p: jnp.zeros(p.shape, dtype or p.dtype), params))

    def update(updates, state, params=None, extras=None):
        del params
        gain = 1.0 - dampening
        new_trace = jax.tree_util.tree_map(
            lambda m, g: momentum * m.astype(jnp.float32)
            + gain * g.astype(jnp.float32),
            state.trace, updates)
        out = new_trace
        if bias_correction and momentum:
            step = extras.step if extras is not None and extras.step is not None \
                else jnp.zeros((), jnp.int32)
            corr = 1.0 - jnp.asarray(momentum, jnp.float32) \
                ** (step.astype(jnp.float32) + 1.0)
            out = jax.tree_util.tree_map(lambda m: m / corr, new_trace)
        if nesterov:
            out = jax.tree_util.tree_map(
                lambda g, m: gain * g.astype(jnp.float32) + momentum * m,
                updates, out)
        stored = jax.tree_util.tree_map(
            lambda m, old: m.astype(old.dtype), new_trace, state.trace)
        return out, TraceState(trace=stored)

    return GradientTransformation(init, update)


def ema_trace(momentum: float = 0.9, nesterov: bool = False) -> GradientTransformation:
    """Bias-corrected EMA momentum — the trust-region-compatible form used by
    the second-order optimizer chains (see ``trace``)."""
    return trace(momentum, nesterov=nesterov, dampening=momentum,
                 bias_correction=True)


def scale(factor) -> GradientTransformation:
    return stateless(lambda u, p, e: tree_map(lambda x: x * factor, u))


class ScheduleState(NamedTuple):
    pass


def scale_by_schedule(schedule: Callable[[jnp.ndarray], jnp.ndarray],
                      negate: bool = True) -> GradientTransformation:
    """Multiply updates by ``-schedule(step)`` (learning-rate schedule)."""

    def update(updates, state, params=None, extras: Extras | None = None):
        lr = schedule(extras.step if extras is not None else 0)
        s = -lr if negate else lr
        return tree_map(lambda x: x * s, updates), state

    return GradientTransformation(_unit_init, update)


def add_decayed_weights(weight_decay: float,
                        mask: Callable[[Any], Any] | None = None) -> GradientTransformation:
    def fn(updates, params, extras):
        if weight_decay == 0.0 or params is None:
            return updates
        wd = jax.tree_util.tree_map(
            lambda g, p: g + weight_decay * p.astype(g.dtype), updates, params)
        if mask is not None:
            m = mask(params)
            wd = jax.tree_util.tree_map(
                lambda use, a, b: a if use else b, m, wd, updates)
        return wd

    return stateless(fn)


def clip_by_global_norm(max_norm: float) -> GradientTransformation:
    def fn(updates, params, extras):
        gn = jnp.sqrt(tree_norm_sq(updates) + 1e-16)
        s = jnp.minimum(1.0, max_norm / gn)
        return tree_map(lambda x: x * s, updates)

    return stateless(fn)


class AdamState(NamedTuple):
    mu: Any
    nu: Any
    count: jnp.ndarray


def scale_by_adam(b1: float = 0.9, b2: float = 0.999, eps: float = 1e-8) -> GradientTransformation:
    def init(params):
        z = lambda: jax.tree_util.tree_map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
        return AdamState(mu=z(), nu=z(), count=jnp.zeros((), jnp.int32))

    def update(updates, state, params=None, extras=None):
        del params, extras
        count = state.count + 1
        mu = jax.tree_util.tree_map(
            lambda m, g: b1 * m + (1 - b1) * g.astype(jnp.float32), state.mu, updates)
        nu = jax.tree_util.tree_map(
            lambda v, g: b2 * v + (1 - b2) * jnp.square(g.astype(jnp.float32)),
            state.nu, updates)
        mu_hat = tree_scale(mu, 1.0 / (1 - b1 ** count.astype(jnp.float32)))
        nu_hat = tree_scale(nu, 1.0 / (1 - b2 ** count.astype(jnp.float32)))
        out = jax.tree_util.tree_map(
            lambda m, v: m / (jnp.sqrt(v) + eps), mu_hat, nu_hat)
        return out, AdamState(mu=mu, nu=nu, count=count)

    return GradientTransformation(init, update)


class AdagradState(NamedTuple):
    accum: Any


def scale_by_adagrad(eps: float = 1e-10, initial_accum: float = 0.1) -> GradientTransformation:
    def init(params):
        return AdagradState(accum=jax.tree_util.tree_map(
            lambda p: jnp.full(p.shape, initial_accum, jnp.float32), params))

    def update(updates, state, params=None, extras=None):
        del params, extras
        accum = jax.tree_util.tree_map(
            lambda a, g: a + jnp.square(g.astype(jnp.float32)), state.accum, updates)
        out = jax.tree_util.tree_map(
            lambda g, a: g.astype(jnp.float32) / (jnp.sqrt(a) + eps), updates, accum)
        return out, AdagradState(accum=accum)

    return GradientTransformation(init, update)
