"""Matrix-free sharded application of oversized Kronecker factors.

The owned-slice refresh exchange bottoms out at ONE owner per factor: a
single un-stackable oversized factor (glm4-9b's 151552-wide vocab-head
K-FAC/Shampoo factor) then caps the W=4 exchange reduction at 1.71x vs the
4.00x the rest of the model achieves.  MKOR ducks the problem with
``exclude_vocabulary_size``; the paper's Sherman–Morrison identity (Eq. 13)
makes the better fix obvious — the *inverse never needs materializing*.
This module extends that matrix-free view from rank-one Eva updates to
dense Kronecker factors: the damped inverse (or inverse 4th root) is
*applied* to the gradient through an iterative solve whose only primitive
is ``Y @ M`` — and that matvec distributes perfectly over row bands of the
factor (``ownership.factor_block``), each worker contributing a full-width
partial completed by one gradient-shaped psum
(``exchange.psum_partials``).  Nothing (d, d)-sized is ever inverted,
eigendecomposed, or exchanged.

Per-factor policy knob (threaded via ``Extras.factor``):

  'dense'    — legacy path, bit-exact (the module is a structural no-op).
  'exclude'  — MKOR-style guard: the oversized side becomes the identity,
               the remaining side keeps plain-γ damping (π-split damping
               needs both factors).  Zero cost, zero exchange.
  'shard'    — matrix-free: per-worker band matvecs (FLOPs 1/W) + one psum
               per solve iteration.  The factor EMA stays replicated (state
               layout unchanged); only the *work* and the refresh exchange
               shrink — the oversized factor leaves the refresh roofline
               entirely and its per-step traffic is gradient-shaped.

Solvers: 'binomial' — the generalized binomial (Neumann) series for
(M+γI)^{-p} after a Gershgorin rescale, valid for any p>0 (K-FAC p=1,
Shampoo p=1/4); 'cg' — conjugate gradients, p=1 only (exact in ≤ d
iterations on a small factor, which is what the equivalence tests use).
Small factors below ``shard_threshold`` keep the dense cached-inverse
fallback (``_damped_inv`` / ``_inv_proot_psd``) recomputed replicated under
the same refresh schedule.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.comm import exchange
from repro.core import bucketing
from repro.core import precondition as pre
from repro.schedule import ownership


POLICIES = ('dense', 'exclude', 'shard')
SOLVERS = ('binomial', 'cg')


@dataclasses.dataclass(frozen=True)
class FactorShardConfig:
    """Per-factor execution policy for oversized Kronecker factors.

    head_policy: what to do with a factor side whose dim trips
      ``shard_threshold`` — 'dense' (legacy, default), 'exclude' (identity
      guard) or 'shard' (matrix-free distributed solve).
    shard_threshold: factor dim at/above which a side trips
      (``ownership.subslice_trips``).  The 65536 default targets
      vocab-scale factors only: glm4-9b's 151552 head trips, its 13696
      d_ff (the largest block side) does not.  Callers size it to their
      arch; the launcher exposes ``--head-threshold``.
    solver / solve_iters: iterative scheme for 'shard' ('cg' valid for
      power −1 only; Shampoo's −1/4 root always takes the binomial series).
    use_pallas: route band partials through the column-blocked Pallas
      matvec kernels (``kernels/matvec.py``); default is the identical
      einsum form.
    """
    head_policy: str = 'dense'
    shard_threshold: int = 65536
    solver: str = 'cg'
    solve_iters: int = 32
    use_pallas: bool = False

    def __post_init__(self):
        if self.head_policy not in POLICIES:
            raise ValueError(f'head_policy must be one of {POLICIES}, '
                             f'got {self.head_policy!r}')
        if self.solver not in SOLVERS:
            raise ValueError(f'solver must be one of {SOLVERS}, '
                             f'got {self.solver!r}')
        if self.shard_threshold < 2:
            raise ValueError('shard_threshold must be >= 2')
        if self.solve_iters < 1:
            raise ValueError('solve_iters must be >= 1')


def from_extras(extras) -> FactorShardConfig:
    """The factor policy threaded through ``Extras.factor`` (a
    FactorShardConfig or a kwargs mapping); default keeps every factor
    dense — the exact legacy path."""
    f = getattr(extras, 'factor', None) if extras is not None else None
    if f is None:
        return FactorShardConfig()
    if isinstance(f, FactorShardConfig):
        return f
    return FactorShardConfig(**dict(f))


# ---------------------------------------------------------------------------
# Plan split: which buckets leave the dense refresh path


@functools.lru_cache(maxsize=256)
def _split_cached(plan: bucketing.BucketPlan, policy: str,
                  threshold: int):
    head: dict[str, tuple[str, str]] = {}
    dense = []
    for b in plan.buckets:
        t_in, t_out = ownership.subslice_trips(b, threshold)
        if policy != 'dense' and (t_in or t_out):
            head[b.key] = (policy if t_in else 'dense',
                           policy if t_out else 'dense')
        else:
            dense.append(b)
    if not head:
        # return the ORIGINAL plan object: callers hit the legacy code path
        # with the same lru-cached ownership maps — bit-exact by identity
        return plan, head
    return bucketing.BucketPlan(buckets=tuple(dense)), head


def split_plan(plan: bucketing.BucketPlan, cfg: FactorShardConfig):
    """(dense_plan, {bucket_key: (in_policy, out_policy)}).

    Buckets with a tripped side are removed from the dense plan — and with
    it from ``sharded_refresh`` and the owned-slice exchange; sides below
    the threshold inside a head bucket stay 'dense' (cached small inverse,
    recomputed replicated).  When nothing trips (or head_policy='dense')
    the original plan object is returned with an empty policy map: the
    optimizer takes the legacy path unchanged."""
    return _split_cached(plan, cfg.head_policy, int(cfg.shard_threshold))


# ---------------------------------------------------------------------------
# Distributed band matvec: the ONE primitive of the matrix-free path


def _band(m: jnp.ndarray, world: int, rank) -> jnp.ndarray:
    """This worker's contiguous row band of factor ``m`` (..., d, d) ->
    (..., B, d) with B = ceil(d/world); rows past d are zero (padding), so
    band partials sum exactly to the unsharded matvec."""
    if world <= 1 or rank is None:
        return m
    d = m.shape[-2]
    blk = ownership.factor_block(d, world)
    pad = world * blk - d
    if pad:
        width = [(0, 0)] * (m.ndim - 2) + [(0, pad), (0, 0)]
        m = jnp.pad(m, width)
    return jax.lax.dynamic_slice_in_dim(m, rank * blk, blk, axis=-2)


def _matvec_partial(band: jnp.ndarray, y: jnp.ndarray, world: int, rank,
                    use_pallas: bool = False) -> jnp.ndarray:
    """Partial of ``y @ M`` from this worker's row band (M symmetric, so
    the row band is the transposed column block): contracts only the owned
    columns of ``y`` — FLOPs 1/W — and returns a full-width (..., R, d)
    partial that ``exchange.psum_partials`` completes."""
    if world <= 1 or rank is None:
        return jnp.einsum('...ri,...ij->...rj', y, band)
    blk = band.shape[-2]
    d = y.shape[-1]
    pad = world * blk - d
    if pad:
        width = [(0, 0)] * (y.ndim - 1) + [(0, pad)]
        y = jnp.pad(y, width)
    y_blk = jax.lax.dynamic_slice_in_dim(y, rank * blk, blk, axis=-1)
    if use_pallas:
        from repro.kernels import matvec as kmv
        if band.ndim == 2 and y_blk.ndim == 2:
            return kmv.matvec_cols(band, y_blk)
        if band.ndim == 3 and y_blk.ndim == 3:
            return kmv.matvec_cols_stacked(band, y_blk)
    return jnp.einsum('...ri,...ij->...rj', y_blk, band)


# ---------------------------------------------------------------------------
# Iterative damped-inverse application:  Y (M + γI)^{-power}


@functools.lru_cache(maxsize=64)
def _binomial_coeffs(power: float, iters: int) -> tuple[float, ...]:
    """Series coefficients of (1-x)^{-power} = Σ a_k x^k:
    a_0 = 1, a_{k+1} = a_k (k + power) / (k + 1)."""
    a = [1.0]
    for k in range(iters):
        a.append(a[-1] * (k + power) / (k + 1))
    return tuple(a)


def solve_damped_power(m: jnp.ndarray, y: jnp.ndarray, gamma, power: float,
                       *, cfg: FactorShardConfig, axes, world: int, rank,
                       site: Optional[str] = None) -> jnp.ndarray:
    """Matrix-free ``Y (M + γI)^{-power}`` for PSD ``m`` (..., d, d) and
    ``y`` (..., R, d); ``gamma`` broadcasts over the leading batch dims.

    Every ``Y @ M`` is a per-worker band partial + one psum; the factor is
    never inverted.  'binomial': Gershgorin-rescaled generalized binomial
    series, any power > 0 — convergence rate (1 - γ/c)^k with
    c = max_j Σ_i |M_ij| + γ, so heavier damping converges faster.
    'cg': conjugate gradients on the SPD system, power −1 only (Shampoo's
    −1/4 root silently takes the series).  W=1 runs the identical code
    minus the collective.
    """
    f32 = jnp.float32
    m = m.astype(f32)
    y = y.astype(f32)
    gam = jnp.asarray(gamma, f32)
    band = _band(m, world, rank)
    iters = int(cfg.solve_iters)
    shard_bytes = float(int(np.prod(band.shape)) * 4)
    extra = {'solve_iters': iters, 'factor_shard_bytes': int(shard_bytes)}

    def mv(v):
        part = _matvec_partial(band, v, world, rank,
                               use_pallas=cfg.use_pallas)
        return exchange.psum_partials(part, axes, world, site=site,
                                      calls=iters, extra=extra)

    if cfg.solver == 'cg' and power == 1.0:
        # CG on (M + γI) xᵀ = yᵀ, vectorized over the R rows of y (each row
        # an independent RHS; α/β are per-row scalars).
        def dot(u, v):
            return jnp.sum(u * v, axis=-1)

        x = jnp.zeros_like(y)
        r = y
        p = r
        rs = dot(r, r)

        def body(carry, _):
            x, r, p, rs = carry
            ap = mv(p) + gam[..., None, None] * p
            denom = dot(p, ap)
            alpha = jnp.where(denom > 0, rs / jnp.maximum(denom, 1e-30), 0.0)
            x = x + alpha[..., None] * p
            r = r - alpha[..., None] * ap
            rs_new = dot(r, r)
            beta = jnp.where(rs > 0, rs_new / jnp.maximum(rs, 1e-30), 0.0)
            p = r + beta[..., None] * p
            return (x, r, p, rs_new), None

        (x, _, _, _), _ = jax.lax.scan(body, (x, r, p, rs), None,
                                       length=iters)
        return x

    # Generalized binomial series.  Scale c ≥ λmax(M) + γ via the
    # Gershgorin column-abs-sum bound — itself assembled from band partials
    # with one small psum (the bands partition the rows exactly).
    col_part = jnp.sum(jnp.abs(band), axis=-2)
    col = exchange.psum_partials(col_part, axes, world, site=None)
    c = jnp.max(col, axis=-1) + gam                       # (...,) per item
    coeffs = _binomial_coeffs(float(power), iters)

    def t_step(v):
        # V ← V T  with  T = I - (M + γI)/c   (spectral radius < 1)
        return v - (mv(v) + gam[..., None, None] * v) / c[..., None, None]

    def body(carry, a_k):
        v, acc = carry
        v = t_step(v)
        return (v, acc + a_k * v), None

    acc = coeffs[0] * y
    (_, acc), _ = jax.lax.scan(body, (y, acc),
                               jnp.asarray(coeffs[1:], f32))
    return acc * (c ** (-float(power)))[..., None, None]


# ---------------------------------------------------------------------------
# Head state: cached dense-side operators + refresh-time dampings


class HeadState(NamedTuple):
    """Sharded-factor bucket state.  ``buckets`` maps bucket key ->
    {'inv_in', 'inv_out' (cached dense-side operator, or () when that side
    is excluded/sharded), 'gam_in', 'gam_out' (refresh-time dampings — the
    sharded side solves against the LIVE factor EMA but keeps the legacy
    frozen-γ staleness semantics)}.  The two scalars are static-valued
    telemetry surfaced as step metrics."""
    buckets: dict
    solve_iters: jnp.ndarray    # () int32
    shard_bytes: jnp.ndarray    # () float32 — per-step partial-psum bytes


def _plain_gamma(m: jnp.ndarray, gamma) -> jnp.ndarray:
    batch = m.shape[:-2]
    return jnp.broadcast_to(jnp.asarray(gamma, jnp.float32), batch)


def _entry_shapes(policies: tuple[str, str], m_in, m_out, gamma,
                  dense_op, method: str) -> dict:
    p_in, p_out = policies
    if method == 'kfac' and 'exclude' not in policies:
        gam_in, gam_out = pre.kfac_pi_damping(m_in, m_out, gamma)
    else:
        # identity on one side makes the π trace split meaningless (and
        # Shampoo never π-splits): plain γ on whatever sides remain
        gam_in, gam_out = _plain_gamma(m_in, gamma), _plain_gamma(m_out, gamma)
    return {
        'inv_in': dense_op(m_in, gam_in) if p_in == 'dense' else (),
        'inv_out': dense_op(m_out, gam_out) if p_out == 'dense' else (),
        'gam_in': gam_in, 'gam_out': gam_out,
    }


def _damped_inv(m: jnp.ndarray, gamma) -> jnp.ndarray:
    d = m.shape[-1]
    eye = jnp.eye(d, dtype=jnp.float32)
    gam = jnp.asarray(gamma, jnp.float32)[..., None, None]
    return jnp.linalg.inv(m.astype(jnp.float32) + gam * eye)


def _dense_op(method: str):
    if method == 'kfac':
        return _damped_inv
    # _inv_proot_psd adds gamma to the (..., d) eigenvalues — broadcast the
    # (batch,) damping to (batch, 1)
    return lambda m, gam: pre._inv_proot_psd(m.astype(jnp.float32),
                                             gam[..., None], 0.25)


def shard_psum_bytes(plan: bucketing.BucketPlan, policies: dict,
                     cfg: FactorShardConfig) -> float:
    """Static per-step f32 partial-psum bytes of the sharded-factor apply
    (one worker's contribution): ``solve_iters`` gradient-shaped psums per
    sharded side of every head bucket.  Callable on specs — this is the
    figure roofline reports next to the refresh-exchange reduction."""
    total = 0.0
    for b in plan.buckets:
        pol = policies.get(b.key)
        if pol is None:
            continue
        n = len(b.paths) * ownership.lead_size(b)
        d_in, d_out = int(b.shape[-2]), int(b.shape[-1])
        elems = n * d_in * d_out
        for p in pol:
            if p == 'shard':
                total += 4.0 * elems * cfg.solve_iters
    return total


def init_head(stats: dict, policies: dict,
              cfg: FactorShardConfig, plan: bucketing.BucketPlan,
              method: str) -> Optional[HeadState]:
    """Zero-initialized HeadState matching what ``refresh_head`` produces;
    None when no bucket tripped — state layout stays bit-identical to
    legacy (``pipe``-field precedent)."""
    if not policies:
        return None
    buckets = {}
    for k, (p_in, p_out) in policies.items():
        m_in, m_out = stats[k]
        batch = m_in.shape[:-2]
        buckets[k] = {
            'inv_in': (jnp.zeros_like(m_in, dtype=jnp.float32)
                       if p_in == 'dense' else ()),
            'inv_out': (jnp.zeros_like(m_out, dtype=jnp.float32)
                        if p_out == 'dense' else ()),
            'gam_in': jnp.zeros(batch, jnp.float32),
            'gam_out': jnp.zeros(batch, jnp.float32),
        }
    sharded = any(p == 'shard' for pol in policies.values() for p in pol)
    return HeadState(
        buckets=buckets,
        solve_iters=jnp.asarray(cfg.solve_iters if sharded else 0, jnp.int32),
        shard_bytes=jnp.asarray(shard_psum_bytes(plan, policies, cfg),
                                jnp.float32))


def refresh_head(refresh, stats: dict, head: Optional[HeadState],
                 policies: dict, gamma: float, *, cfg: FactorShardConfig,
                 plan: bucketing.BucketPlan, method: str
                 ) -> Optional[HeadState]:
    """Recompute head-bucket operators under the same refresh gate as the
    dense plan: dense-side damped inverses (replicated — the side is small
    by construction, so no exchange) + the frozen dampings.  ``stats``:
    {bucket_key: (m_in, m_out)} live factor EMAs."""
    if not policies:
        return None
    dense_op = _dense_op(method)

    def fresh():
        return {k: _entry_shapes(policies[k], stats[k][0], stats[k][1],
                                 gamma, dense_op, method)
                for k in policies}

    buckets = jax.lax.cond(refresh, fresh, lambda: head.buckets)
    return HeadState(buckets=buckets, solve_iters=head.solve_iters,
                     shard_bytes=head.shard_bytes)


# ---------------------------------------------------------------------------
# Apply: the per-step matrix-free preconditioning of head buckets


def _apply_one(g: jnp.ndarray, entry: dict, policies: tuple[str, str],
               m_in: jnp.ndarray, m_out: jnp.ndarray, *, power: float,
               cfg: FactorShardConfig, axes, world: int, rank,
               site: Optional[str]) -> jnp.ndarray:
    p_in, p_out = policies
    g32 = g.astype(jnp.float32)
    if p_in == 'dense':
        g32 = jnp.einsum('...ij,...jo->...io', entry['inv_in'], g32)
    elif p_in == 'shard':
        gt = jnp.swapaxes(g32, -1, -2)
        gt = solve_damped_power(m_in, gt, entry['gam_in'], power, cfg=cfg,
                                axes=axes, world=world, rank=rank, site=site)
        g32 = jnp.swapaxes(gt, -1, -2)
    # 'exclude': identity — the guard costs nothing
    if p_out == 'dense':
        g32 = jnp.einsum('...io,...oj->...ij', g32, entry['inv_out'])
    elif p_out == 'shard':
        g32 = solve_damped_power(m_out, g32, entry['gam_out'], power,
                                 cfg=cfg, axes=axes, world=world, rank=rank,
                                 site=site)
    return g32.astype(g.dtype)


def apply_tree(flat: dict, plan: bucketing.BucketPlan, policies: dict,
               head: HeadState, factors: dict, *, power: float,
               cfg: FactorShardConfig, site: str) -> dict:
    """Precondition the head buckets of ``flat`` ({path: grad}) in place of
    the dense cached-operator path.  ``factors``: {bucket_key: (m_in,
    m_out)} live EMAs (bucket-stacked); dense buckets pass through
    untouched.  One vectorized apply per stacked bucket, mirroring
    ``precondition_tree``'s engine contract."""
    if not policies:
        return flat
    from repro.sharding.constraints import data_axes_in_scope
    axes = data_axes_in_scope()
    world, rank = ownership.world_and_rank(axes)
    out = dict(flat)
    for b in plan.buckets:
        if b.key not in policies:
            continue
        entry = head.buckets[b.key]
        m_in, m_out = factors[b.key]
        kw = dict(power=power, cfg=cfg, axes=axes, world=world, rank=rank,
                  site=site)
        if b.stacked:
            g = jnp.stack([flat[p] for p in b.paths])
            res = _apply_one(g, entry, policies[b.key], m_in, m_out, **kw)
            for i, p in enumerate(b.paths):
                out[p] = res[i]
        else:
            for i, p in enumerate(b.paths):
                e_i = jax.tree_util.tree_map(lambda x, i=i: x[i], entry)
                out[p] = _apply_one(flat[p], e_i, policies[b.key],
                                    m_in[i], m_out[i], **kw)
    return out


# ---------------------------------------------------------------------------
# Step metrics (repro.obs contract: declared fields, walked from opt state)


METRIC_FIELDS = {
    'factor_solve_iters': ('int', 'iterations of one sharded-factor solve'),
    'factor_shard_bytes': ('num', 'per-step sharded-factor partial-psum B'),
}


def head_states(opt_state):
    """Every HeadState in an optimizer state tree (chains nest states in
    tuples/dicts — mirror ``schedule.runtime.sched_states``)."""
    found = []

    def walk(x):
        if isinstance(x, HeadState):
            found.append(x)
        elif isinstance(x, dict):
            for v in x.values():
                walk(v)
        elif isinstance(x, (list, tuple)):
            for v in x:
                walk(v)

    walk(opt_state)
    return found


def step_metrics(opt_state) -> dict:
    """{declared field: scalar} for the step event — empty when no factor
    is sharded (fields are optional; no schema bump)."""
    out = {}
    for hs in head_states(opt_state):
        out['factor_solve_iters'] = hs.solve_iters
        out['factor_shard_bytes'] = hs.shard_bytes
    return out
