"""Checkpoint resharding across world sizes (elastic training).

A checkpoint written at W=4 restores at W=2 or W=8 because the state the
optimizers carry is deliberately world-agnostic:

* every checkpoint leaf is the full *logical* array (``train/checkpoint``
  saves replicated values, not per-worker shards), so KV EMAs, cached
  inverses, ``SchedState`` counters and factor-head state load unchanged
  at any W;
* refresh ownership is never stored — ``assign_slice_owners`` /
  ``assign_subslice_owners`` are deterministic lru-cached functions of
  ``(BucketPlan, world)`` recomputed at trace time, so re-jitting under
  the new mesh *is* the reshard of the work assignment;
* sharded factor-head row bands (``core.factor_sharded``) are computed
  on the fly from ``factor_block(d, world)`` at apply time — the
  persisted ``HeadState`` holds replicated EMAs only.

What is left for this module is the part that is genuinely W-dependent:

1. the **elastic metadata block** stamped into every checkpoint
   (:func:`elastic_metadata`) so a restore knows what world wrote it and
   whether the bucket plan still matches (:func:`check_metadata`);
2. the **pipeline drain rule** — in ``pipeline='onestep'`` mode the
   in-flight :class:`~repro.schedule.pipeline.PipelineState` buffers were
   reduced over the *old* world's workers.  Their content is replicated
   and world-agnostic in value, but their staleness bookkeeping refers to
   an exchange epoch that no longer exists; on a resize the default
   ``'drain'`` rule zeroes the buffers and resets ``age`` to 0, which is
   exactly the documented cold-start state (``pipeline.init_state``), so
   the first post-resize step behaves like step 0 of a fresh pipeline.
   ``'keep'`` passes the buffers through unchanged (their values are
   fully-reduced means, valid at any W) for runs that prefer one stale
   application over one cold step;
3. the **ownership delta** (:func:`ownership_delta`) — how many owned
   slices move to a new worker when the maps are re-run at the new W —
   which feeds the typed ``reshard`` event the trainer emits through
   ``repro.obs``.

The trainer-side composition (restore → :func:`reshard_state` → rebuild
mesh → re-jit → continue) lives in ``train/trainer.py::Trainer.fit_elastic``;
the on-disk contract is documented in docs/CHECKPOINT_FORMAT.md.
"""
from __future__ import annotations

import hashlib
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.bucketing import BucketPlan
from repro.schedule import ownership
from repro.schedule import pipeline as pipeline_mod

# key of the elastic block inside checkpoint metadata (manifest.json)
ELASTIC_KEY = 'elastic'

PIPELINE_RULES = ('drain', 'keep')


class ReshardError(ValueError):
    """A checkpoint cannot be resharded into this run's configuration."""


# ---------------------------------------------------------------------------
# Metadata contract


def plan_fingerprint(plan: Optional[BucketPlan]) -> str:
    """Stable digest of a bucket plan's structure (keys, shapes, dtypes,
    member paths).  Two runs whose plans fingerprint equal produce the same
    ownership maps at every W — the precondition for resharding being pure
    metadata.  '' when nothing is preconditioned (first-order runs)."""
    if plan is None or not plan.buckets:
        return ''
    h = hashlib.sha256()
    for b in plan.buckets:
        h.update(repr((b.key, tuple(int(d) for d in b.shape),
                       str(jnp.dtype(b.dtype).name), b.paths,
                       bool(b.stacked))).encode())
    return h.hexdigest()[:16]


def elastic_metadata(world: int, plan: Optional[BucketPlan] = None,
                     pipeline: str = 'sync') -> dict:
    """The JSON block a checkpoint's metadata carries under
    :data:`ELASTIC_KEY` — everything a restore at a different W needs to
    validate and reshard (docs/CHECKPOINT_FORMAT.md)."""
    return {'world': int(world),
            'pipeline': str(pipeline),
            'plan': plan_fingerprint(plan)}


def check_metadata(meta: Optional[dict], plan: Optional[BucketPlan] = None,
                   pipeline: str = 'sync') -> int:
    """Validate a checkpoint's elastic block against this run's
    configuration and return the world size that wrote it.

    A missing block (pre-elastic checkpoint) is accepted and reported as
    world 0 — the caller treats it as "same world as now".  A bucket-plan
    fingerprint mismatch is fatal: the ownership maps of the two runs
    disagree, which means the model/capture/factor configuration changed,
    not just W.  A pipeline-mode mismatch is fatal for the same reason
    restore would fail structurally (the state template differs).
    """
    if not meta:
        return 0
    want = plan_fingerprint(plan)
    got = meta.get('plan', '')
    if got != want:
        raise ReshardError(
            f'checkpoint bucket plan {got!r} != this run {want!r} — the '
            'model/capture/factor configuration changed; elastic restore '
            'only reshards across world sizes (docs/CHECKPOINT_FORMAT.md)')
    ck_pipe = meta.get('pipeline', 'sync')
    if ck_pipe != pipeline:
        raise ReshardError(
            f'checkpoint pipeline mode {ck_pipe!r} != this run '
            f'{pipeline!r} — pipeline buffers are part of the state '
            'structure; restore with the same RefreshRuntime(pipeline=...)')
    return int(meta.get('world', 0))


def check_batch_divisible(batch: Any, world: int) -> None:
    """Every batch leaf's leading dim must split evenly over the ``'data'``
    axis — an elastic resize that breaks ``batch % W == 0`` is a
    configuration error, raised before tracing (shard_map's own error
    names the spec, not the fix)."""
    flat, _ = jax.tree_util.tree_flatten_with_path(batch)
    for path, x in flat:
        dim0 = int(jnp.shape(x)[0]) if jnp.ndim(x) else 0
        if dim0 % int(world):
            key = jax.tree_util.keystr(path)
            raise ReshardError(
                f'global batch dim {dim0} of {key!r} does not divide over '
                f'world={world} — elastic resizes must keep batch % W == 0 '
                '(docs/CHECKPOINT_FORMAT.md)')


# ---------------------------------------------------------------------------
# Ownership delta (telemetry for the typed `reshard` event)


def ownership_delta(plan: Optional[BucketPlan], world_from: int,
                    world_to: int, sides: str = 'both') -> dict:
    """How the refresh-owner maps move when re-run at the new world size:
    ``{'slices_total', 'slices_moved'}`` over every bucket's (row ×
    lead-slice) grid.  Slices whose owner rank changes are the refreshes
    that warm up on a different worker after the resize — purely
    informational (ownership is recomputed, never migrated), but exactly
    the number an operator staring at a post-resize refresh-latency blip
    wants to see.  {} when nothing is preconditioned."""
    if plan is None or not plan.buckets:
        return {}
    cost = ownership.inverse_cost(sides)
    a = ownership.assign_slice_owners(plan, cost, max(1, int(world_from)))
    b = ownership.assign_slice_owners(plan, cost, max(1, int(world_to)))
    total = moved = 0
    for key in a:
        total += int(a[key].size)
        moved += int(np.sum(a[key] != b[key]))
    return {'slices_total': total, 'slices_moved': moved}


# ---------------------------------------------------------------------------
# Pipeline drain rule


def map_pipeline_states(tree: Any,
                        fn: Callable[[pipeline_mod.PipelineState],
                                     pipeline_mod.PipelineState]) -> Any:
    """Structurally rebuild an optimizer-state pytree with ``fn`` applied
    to every :class:`PipelineState` (dicts / lists / tuples / NamedTuples
    preserved; everything else passed through untouched)."""
    if isinstance(tree, pipeline_mod.PipelineState):
        return fn(tree)
    if isinstance(tree, dict):
        return {k: map_pipeline_states(v, fn) for k, v in tree.items()}
    if isinstance(tree, tuple):
        vals = [map_pipeline_states(v, fn) for v in tree]
        return type(tree)(*vals) if hasattr(tree, '_fields') \
            else tuple(vals)
    if isinstance(tree, list):
        return [map_pipeline_states(v, fn) for v in tree]
    return tree


def _drain_one(pipe: pipeline_mod.PipelineState) -> pipeline_mod.PipelineState:
    """One slot back to the documented cold start: zeros buffer, age 0 —
    identical to ``pipeline.init_state(template)``."""
    buf = None
    if pipe.inflight is not None:
        buf = jax.tree_util.tree_map(
            lambda x: jnp.zeros(jnp.shape(x), jnp.asarray(x).dtype),
            pipe.inflight)
    return pipeline_mod.PipelineState(inflight=buf,
                                      age=jnp.zeros((), jnp.int32))


def reshard_state(opt_state: Any, *, world_from: int, world_to: int,
                  plan: Optional[BucketPlan] = None,
                  step: Optional[int] = None,
                  pipeline_rule: str = 'drain',
                  source: str = 'checkpoint') -> tuple[Any, dict]:
    """Reshard a restored (or live) optimizer state from ``world_from`` to
    ``world_to`` workers.  Returns ``(opt_state, event_body)`` where the
    body is a valid ``reshard`` record for ``repro.obs``.

    Leaves are full logical arrays, so the only state transformation is
    the pipeline rule on a genuine resize: ``'drain'`` (default) resets
    every in-flight buffer to the cold-start zeros/age-0 state;
    ``'keep'`` passes them through (values are fully-reduced replicated
    means, valid at any W).  When ``world_from == world_to`` the state
    passes through untouched under either rule — the bit-exact resume
    contract of the non-elastic trainer is preserved.
    """
    if pipeline_rule not in PIPELINE_RULES:
        raise ValueError(f'pipeline_rule must be one of {PIPELINE_RULES}, '
                         f'got {pipeline_rule!r}')
    world_from, world_to = int(world_from), int(world_to)
    resized = world_from != world_to
    n_pipes = len(pipeline_mod.pipe_entries(opt_state))
    pipes = 'none'
    if n_pipes:
        if resized and pipeline_rule == 'drain':
            opt_state = map_pipeline_states(opt_state, _drain_one)
            pipes = 'drained'
        else:
            pipes = 'kept'
    body: dict[str, Any] = {'world_from': world_from, 'world_to': world_to,
                            'pipeline': pipes, 'source': str(source)}
    if step is not None:
        body['step'] = int(step)
    body.update(ownership_delta(plan, world_from, world_to))
    return opt_state, body
