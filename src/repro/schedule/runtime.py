"""RefreshRuntime: the façade the optimizers and the train step talk to.

One object owns the three scheduling concerns the optimizers used to
re-implement ad hoc:

* **policy resolution** — an optimizer's explicit ``policy=`` wins, else a
  train-level default threaded through ``Extras.sched``, else the legacy
  ``interval`` kwarg as ``every_k(interval)``;
* **gated, worker-sharded recomputation** — :func:`sharded_refresh` wraps
  the whole refresh in one ``lax.cond`` (skipped steps cost nothing) and,
  under a live data-parallel mesh, flattens each bucket's stack × leading
  scan dims into slices and gates each slice on ownership with an inner
  ``lax.cond`` inside the ``lax.map`` (``lax.map`` lowers to ``scan``, so
  non-owned slices really skip the inverse) before the codec-aware
  owned-slice exchange (``repro.comm.exchange``, per-worker traffic ~1/W;
  the legacy full-stack psum stays available via
  ``ExchangeConfig(exchange='psum')``);
* **observability** — :func:`schedule_metrics` pulls refresh counts /
  staleness out of any optimizer state so the trainer can log them without
  knowing optimizer internals; the comm layer counts exchange bytes per
  call-site.

Bit-identity contract: with ``every_k(1)`` and/or a single worker, outputs
are bit-identical (atol=0) to always-fresh recomputation.  With W workers
the two exchange modes are bit-identical to each other under the f32 codec
(owned-slice copies / x+0 psums are both exact); vs a single worker only
the LAPACK batching of the slice-granular inverses can move the last float
ulp (see ``recompute_sharded``).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Mapping, Optional

import jax
import jax.numpy as jnp

from repro.comm import exchange, metrics
from repro.comm import codec as exchange_codec
from repro.core.bucketing import Bucket, BucketPlan
from repro.schedule import ownership
from repro.schedule import pipeline as pipeline_mod
from repro.schedule import policy as policy_mod
from repro.sharding import compat
from repro.sharding.constraints import psum_tree


@dataclasses.dataclass(frozen=True)
class RefreshRuntime:
    """Train-level refresh configuration (static, not a pytree).

    Attributes:
      policy: default policy for optimizers built without an explicit one
        (their legacy ``interval`` kwarg still wins over this default only
        when it was explicitly set ≠ 1 — see :meth:`resolve`).
      shard_refresh: gate worker-sharded ownership; turning it off makes
        every worker recompute everything (the redundant pre-runtime
        behavior, kept for A/B benchmarks).
      pipeline: 'sync' (default — every exchange result is applied in the
        step that issued it, the exact legacy behavior) or 'onestep' (the
        double-buffered pipeline: step t applies the stats / refreshed
        inverses exchanged at t−1 so step t's collectives can overlap its
        compute; see ``repro.schedule.pipeline``).  Must match between
        ``init_opt_state`` and the train step — 'onestep' allocates
        pipeline buffers in optimizer state.
    """

    policy: Optional[policy_mod.RefreshPolicy] = None
    shard_refresh: bool = True
    pipeline: str = 'sync'

    def __post_init__(self):
        if self.pipeline not in ('sync', 'onestep'):
            raise ValueError("pipeline must be 'sync' or 'onestep', "
                             f'got {self.pipeline!r}')

    def resolve(self, local: Optional[policy_mod.RefreshPolicy],
                interval: int = 1) -> policy_mod.RefreshPolicy:
        if local is not None:
            return local
        if interval != 1:
            # an explicitly-tuned legacy interval beats a train-level default
            return policy_mod.every_k(interval)
        return self.policy if self.policy is not None \
            else policy_mod.every_k(1)


_DEFAULT = RefreshRuntime()


def from_extras(extras) -> RefreshRuntime:
    """The runtime threaded through ``Extras.sched`` (next to the bucket
    plan), or the default runtime when the caller drives the transform
    directly."""
    rt = getattr(extras, 'sched', None) if extras is not None else None
    return rt if rt is not None else _DEFAULT


def resolve_pipe(rt: RefreshRuntime, state_pipe):
    """The pipe dict an optimizer update should thread this step (None in
    sync mode), with a static consistency check: the pipeline mode is baked
    into the state structure at init, so init and update must agree."""
    if rt.pipeline == 'onestep':
        if state_pipe is None:
            raise ValueError(
                "pipeline='onestep' but the optimizer state has no pipeline "
                'buffers — pass the same RefreshRuntime(pipeline=...) to '
                'init_opt_state and the train step')
        return state_pipe
    if state_pipe is not None:
        raise ValueError(
            "pipeline='sync' but the optimizer state carries pipeline "
            'buffers — pass the same RefreshRuntime(pipeline=...) to '
            'init_opt_state and the train step')
    return None


# ---------------------------------------------------------------------------
# Gated, worker-sharded refresh


def sharded_refresh(plan: BucketPlan, refresh: jnp.ndarray,
                    item_fn: Callable[[Bucket, Any], Any],
                    args_b: Mapping[str, Any], old_b: Mapping[str, Any],
                    *, cost: Callable[[Bucket], float],
                    shard: bool = True,
                    comm: Optional[exchange.ExchangeConfig] = None,
                    site: str = 'refresh',
                    pipe: Optional[pipeline_mod.PipelineState] = None):
    """Recompute cached per-bucket values under a refresh decision.

    Args:
      plan: the bucket plan whose stacked state is being refreshed.
      refresh: traced scalar bool — the policy decision (replicated across
        workers, so every worker takes the same cond branch).
      item_fn: ``(bucket, per_item_args) -> per_item_out`` — the expensive
        recomputation for ONE stack item (e.g. a damped-inverse pair).
        Must broadcast over leading dims: single-worker it receives a whole
        stack row (with any scan/expert lead dims), under a W>1 mesh one
        (lead-flattened) slice at a time.
      args_b: {bucket_key: stacked-args pytree} (leading axis = stack).
      old_b: {bucket_key: stacked cached values} returned unchanged on
        non-refresh steps; also supplies output shapes/dtypes.
      cost: per-item FLOP estimate for ownership weighting.
      shard: disable to force every worker to recompute everything.
      comm: exchange config (``Extras.comm``): which codec the refreshed
        slices travel in and whether the exchange is the owned-slice
        all-gather (default; per-worker traffic ~1/W of the stack) or the
        legacy full-stack zero-padded psum.
      site: call-site label for the ``repro.comm.metrics`` byte counters.
      pipe: ``None`` (sync — the refreshed values are applied in this step,
        the legacy behavior and return shape) or this site's
        ``PipelineState`` (one-step pipeline).  The cond/exchange graph is
        IDENTICAL in both modes; what changes is the consumer: pipelined
        callers precondition with the returned ``applied`` caches (the
        values refreshed in an earlier step — ``old_b``, which doubles as
        the in-flight buffer, so no second cache copy exists) and store the
        fresh result, keeping this step's exchange out of this step's
        compute cone.

    Returns {bucket_key: refreshed stacked values} with ``old_b``'s
    structure when ``pipe is None``; otherwise the staged triple
    ``(applied, fresh, new_pipe)`` where ``applied`` is ``old_b`` (what
    this step preconditions with) and ``fresh`` is the cond output (what
    the caller must store for the next step).
    """
    axes = ownership.data_axes_in_scope() if shard else ()
    world, rank = ownership.world_and_rank(axes) if shard else (1, None)
    cfg = exchange.from_extras(None) if comm is None else comm

    def recompute_single(_):
        # the exact legacy single-worker structure: one fused lax.map per
        # bucket over stack ROWS, item_fn broadcasting over any leading
        # scan/expert dims — this is the path the atol=0 every_k(1)-vs-
        # legacy contracts compare (tests/test_schedule.py)
        out = {}
        for b in plan.buckets:
            out[b.key] = jax.lax.map(lambda a, b=b: item_fn(b, a),
                                     args_b[b.key])
        # W=1: nothing moves, but the site still reports the stack's
        # logical payload so telemetry breakdowns compare across worlds
        metrics.record(site, bytes_per_call=sum(
            exchange.tree_payload_bytes(v, exchange_codec.F32)
            for v in out.values()), codec='f32', mode='local')
        return out

    def recompute_sharded(_):
        # W > 1: ownership at SLICE granularity — the stack axis and the
        # leading scan/expert dims flatten into one (N·lead) slice axis, so
        # refresh FLOPs and exchange traffic both scale ~1/W even when the
        # model has few (huge, scan-stacked) parameter paths.  Caveat: a
        # slice inverse runs LAPACK on one (d, d) matrix where the
        # single-worker path batches (lead, d, d), which can move the last
        # float ulp (~1e-6; batched-vs-single getrf) — the two exchange
        # MODES below stay bit-identical to each other because they share
        # this compute.
        # topology='pod': pod-local ownership so the slice gather stays on
        # the intra-pod (ICI) axis; needs both ('pod','data') axes live and
        # the gather exchange (the full-stack psum has no gather stage)
        pods = None
        if cfg.topology == 'pod' and cfg.exchange == 'gather' \
                and len(axes) == 2:
            sizes = compat.bound_axis_sizes()
            pods = (int(sizes.get(axes[0], 1)), int(sizes.get(axes[1], 1)))
            if pods[0] <= 1 or pods[0] * pods[1] != world:
                pods = None
        owners = (ownership.assign_pod_slice_owners(plan, cost, pods)
                  if pods is not None
                  else ownership.assign_slice_owners(plan, cost, world))
        out = {}
        for b in plan.buckets:
            nlead = len(b.shape) - 2
            n_slices = len(b.paths) * ownership.lead_size(b)

            def flat(x, nlead=nlead, n_slices=n_slices):
                return x.reshape((n_slices,) + x.shape[1 + nlead:])

            fargs = jax.tree_util.tree_map(flat, args_b[b.key])
            fold = jax.tree_util.tree_map(flat, old_b[b.key])
            own = jnp.asarray(owners[b.key])

            def one(t, b=b, own=own, fold=fold):
                idx, a = t
                zeros = jax.tree_util.tree_map(
                    lambda x: jnp.zeros(x.shape[1:], x.dtype), fold)
                return jax.lax.cond(own[idx] == rank,
                                    lambda a: item_fn(b, a),
                                    lambda a: zeros, a)

            idx = jnp.arange(n_slices, dtype=jnp.int32)
            out[b.key] = jax.lax.map(one, (idx, fargs))
        # exchange: owners computed real slices, everyone else zeros.
        # 'gather' ships only each worker's owned slices (static-shape
        # padded gather, per-worker traffic ~1/W of the stack) and
        # reconstructs every slice as an exact copy of its owner's value;
        # 'psum' is the legacy full-stack zero-padded sum (x+0 exact).
        # Exact copies and x+0 sums are both bit-exact, so the two modes
        # agree atol=0 under the f32 codec.
        if cfg.exchange == 'psum':
            out = psum_tree(out, axes)
            metrics.record(site, bytes_per_call=sum(
                exchange.tree_payload_bytes(v, exchange_codec.F32)
                for v in out.values()), codec='f32', mode='psum')
        else:
            out = exchange.allgather_owned_slices(
                plan, owners, world, rank, out, codec=cfg.codec,
                axes=axes, site=site, pods=pods)
        return {k: jax.tree_util.tree_map(
            lambda y, o: y.reshape(o.shape), out[k], old_b[k])
            for k in out}

    recompute = recompute_single if world == 1 else recompute_sharded

    def keep(_):
        return {b.key: old_b[b.key] for b in plan.buckets}

    fresh = jax.lax.cond(refresh, recompute, keep, operand=None)
    if pipe is None:
        return fresh
    applied = {b.key: old_b[b.key] for b in plan.buckets}
    return applied, fresh, pipeline_mod.tick(pipe, refresh)


# ---------------------------------------------------------------------------
# Observability


def sched_states(opt_state: Any) -> list[policy_mod.SchedState]:
    """All SchedState nodes in an optimizer-state pytree (works on traced
    and concrete states — the walk is over static Python structure)."""
    found: list[policy_mod.SchedState] = []

    def walk(x):
        if isinstance(x, policy_mod.SchedState):
            found.append(x)
            return
        if isinstance(x, dict):
            for v in x.values():
                walk(v)
        elif isinstance(x, (list, tuple)):
            for v in x:
                walk(v)

    walk(opt_state)
    return found


# Step-metric fields this module contributes, declared next to their
# producer so the telemetry schema (repro.obs.events) stays in sync with
# the code that emits them: name -> (kind in {'int','num'}, unit).
METRIC_FIELDS = {
    'refreshes': ('int', 'cumulative refreshes'),
    'refresh_since': ('int', 'steps since last refresh'),
    'staleness': ('num', 'policy staleness proxy'),
}


def ownership_event(plan: Optional[BucketPlan],
                    world: Optional[int] = None) -> Optional[dict]:
    """Typed ``refresh_ownership`` record body ({'world','owners'}) for a
    bucket plan under a ``world``-worker mesh — what the trainer emits at
    startup through ``repro.obs`` (None when nothing is preconditioned)."""
    if plan is None or not plan.buckets:
        return None
    world = world if world is not None else max(1, jax.device_count())
    return {'world': int(world),
            'owners': ownership.describe_ownership(plan, world)}


def schedule_metrics(opt_state: Any) -> dict[str, jnp.ndarray]:
    """{'refreshes', 'refresh_since', 'staleness'} aggregated over every
    scheduled transform in the state; {} when nothing is scheduled.  Usable
    inside jit (returns traced scalars) and on concrete states."""
    sts = sched_states(opt_state)
    if not sts:
        return {}
    return {
        'refreshes': sum((s.n_refresh for s in sts),
                         jnp.zeros((), jnp.int32)),
        'refresh_since': jnp.max(jnp.stack([s.since for s in sts])),
        'staleness': jnp.max(jnp.stack([s.staleness for s in sts])),
    }
