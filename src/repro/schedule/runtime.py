"""RefreshRuntime: the façade the optimizers and the train step talk to.

One object owns the three scheduling concerns the optimizers used to
re-implement ad hoc:

* **policy resolution** — an optimizer's explicit ``policy=`` wins, else a
  train-level default threaded through ``Extras.sched``, else the legacy
  ``interval`` kwarg as ``every_k(interval)``;
* **gated, worker-sharded recomputation** — :func:`sharded_refresh` wraps
  the whole refresh in one ``lax.cond`` (skipped steps cost nothing) and,
  under a live data-parallel mesh, gates each bucket item on ownership with
  an inner ``lax.cond`` inside the stacked ``lax.map`` (``lax.map`` lowers
  to ``scan``, so non-owned items really skip the inverse) before a
  bucket-stacked psum exchange;
* **observability** — :func:`schedule_metrics` pulls refresh counts /
  staleness out of any optimizer state so the trainer can log them without
  knowing optimizer internals.

Bit-identity contract: with ``every_k(1)`` and/or a single worker, outputs
are bit-identical (atol=0) to always-fresh recomputation; with W workers the
psum-of-zero-padded-slices exchange preserves that bit-identity (see
``repro.schedule.ownership``).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Mapping, Optional

import jax
import jax.numpy as jnp

from repro.core.bucketing import Bucket, BucketPlan
from repro.schedule import ownership
from repro.schedule import policy as policy_mod
from repro.sharding.constraints import psum_tree


@dataclasses.dataclass(frozen=True)
class RefreshRuntime:
    """Train-level refresh configuration (static, not a pytree).

    Attributes:
      policy: default policy for optimizers built without an explicit one
        (their legacy ``interval`` kwarg still wins over this default only
        when it was explicitly set ≠ 1 — see :meth:`resolve`).
      shard_refresh: gate worker-sharded ownership; turning it off makes
        every worker recompute everything (the redundant pre-runtime
        behavior, kept for A/B benchmarks).
    """

    policy: Optional[policy_mod.RefreshPolicy] = None
    shard_refresh: bool = True

    def resolve(self, local: Optional[policy_mod.RefreshPolicy],
                interval: int = 1) -> policy_mod.RefreshPolicy:
        if local is not None:
            return local
        if interval != 1:
            # an explicitly-tuned legacy interval beats a train-level default
            return policy_mod.every_k(interval)
        return self.policy if self.policy is not None \
            else policy_mod.every_k(1)


_DEFAULT = RefreshRuntime()


def from_extras(extras) -> RefreshRuntime:
    """The runtime threaded through ``Extras.sched`` (next to the bucket
    plan), or the default runtime when the caller drives the transform
    directly."""
    rt = getattr(extras, 'sched', None) if extras is not None else None
    return rt if rt is not None else _DEFAULT


# ---------------------------------------------------------------------------
# Gated, worker-sharded refresh


def sharded_refresh(plan: BucketPlan, refresh: jnp.ndarray,
                    item_fn: Callable[[Bucket, Any], Any],
                    args_b: Mapping[str, Any], old_b: Mapping[str, Any],
                    *, cost: Callable[[Bucket], float],
                    shard: bool = True) -> dict[str, Any]:
    """Recompute cached per-bucket values under a refresh decision.

    Args:
      plan: the bucket plan whose stacked state is being refreshed.
      refresh: traced scalar bool — the policy decision (replicated across
        workers, so every worker takes the same cond branch).
      item_fn: ``(bucket, per_item_args) -> per_item_out`` — the expensive
        recomputation for ONE stack item (e.g. a damped-inverse pair).
      args_b: {bucket_key: stacked-args pytree} (leading axis = stack).
      old_b: {bucket_key: stacked cached values} returned unchanged on
        non-refresh steps; also supplies output shapes/dtypes.
      cost: per-item FLOP estimate for ownership weighting.
      shard: disable to force every worker to recompute everything.

    Returns {bucket_key: refreshed stacked values} with ``old_b``'s
    structure.
    """
    world, rank = ownership.world_and_rank() if shard else (1, None)
    owners = ownership.assign_owners(plan, cost, world)

    def recompute(_):
        out = {}
        for b in plan.buckets:
            args = args_b[b.key]
            old = old_b[b.key]

            def one(t, b=b, old=old):
                idx, a = t
                if world == 1:
                    return item_fn(b, a)
                own = jnp.asarray(owners[b.key])[idx]
                zeros = jax.tree_util.tree_map(
                    lambda x: jnp.zeros(x.shape[1:], x.dtype), old)
                return jax.lax.cond(own == rank,
                                    lambda a: item_fn(b, a),
                                    lambda a: zeros, a)

            idx = jnp.arange(len(b.paths), dtype=jnp.int32)
            out[b.key] = jax.lax.map(one, (idx, args))
        if world > 1:
            # exchange: owners contributed real slices, everyone else zeros;
            # the psum reconstructs the full stack bit-exactly on all workers
            out = psum_tree(out)
        return out

    def keep(_):
        return {b.key: old_b[b.key] for b in plan.buckets}

    return jax.lax.cond(refresh, recompute, keep, operand=None)


# ---------------------------------------------------------------------------
# Observability


def sched_states(opt_state: Any) -> list[policy_mod.SchedState]:
    """All SchedState nodes in an optimizer-state pytree (works on traced
    and concrete states — the walk is over static Python structure)."""
    found: list[policy_mod.SchedState] = []

    def walk(x):
        if isinstance(x, policy_mod.SchedState):
            found.append(x)
            return
        if isinstance(x, dict):
            for v in x.values():
                walk(v)
        elif isinstance(x, (list, tuple)):
            for v in x:
                walk(v)

    walk(opt_state)
    return found


def schedule_metrics(opt_state: Any) -> dict[str, jnp.ndarray]:
    """{'refreshes', 'refresh_since', 'staleness'} aggregated over every
    scheduled transform in the state; {} when nothing is scheduled.  Usable
    inside jit (returns traced scalars) and on concrete states."""
    sts = sched_states(opt_state)
    if not sts:
        return {}
    return {
        'refreshes': sum((s.n_refresh for s in sts),
                         jnp.zeros((), jnp.int32)),
        'refresh_since': jnp.max(jnp.stack([s.since for s in sts])),
        'staleness': jnp.max(jnp.stack([s.staleness for s in sts])),
    }
