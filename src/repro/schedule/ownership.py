"""Worker-sharded refresh ownership: which data-parallel worker recomputes
which bucket item.

Every worker holding identical (psum-averaged) curvature statistics and
redundantly inverting every bucket item is exactly the waste distributed
K-FAC-style layer assignment eliminates (cf. MKOR's distributed factor
maintenance).  This module assigns each (bucket, item) to one worker of the
live ``('pod','data')`` mesh — a deterministic, cost-weighted round-robin
(longest-processing-time greedy over the per-item inverse FLOP estimate
from the bucket plan) — so refresh FLOPs scale 1/W with world size.  The
refreshed slices are then exchanged with one bucket-stacked ``psum`` (each
non-owner contributes zeros, so the sum reconstructs every item bit-exactly:
``x + 0 == x`` in IEEE arithmetic, which is what makes W-worker refresh
bit-identical to single-host refresh).
"""
from __future__ import annotations

import functools
from typing import Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.bucketing import Bucket, BucketPlan
from repro.sharding import compat
from repro.sharding.constraints import data_axes_in_scope


# ---------------------------------------------------------------------------
# Per-item cost model


def inverse_cost(sides: str = 'both') -> Callable[[Bucket], float]:
    """FLOP estimate for refreshing ONE item of a bucket: dense
    factorizations are cubic in the factor dim, and scan-stacked leading
    dims multiply (an item of a ``(L, d_in, d_out)`` bucket refreshes L
    factor pairs).

    sides: 'left' (FOOF: input factor only) or 'both' (K-FAC / Shampoo).
    """
    if sides not in ('left', 'both'):
        raise ValueError(f"sides must be 'left' or 'both', got {sides!r}")

    def cost(bucket: Bucket) -> float:
        d_in, d_out = bucket.shape[-2], bucket.shape[-1]
        lead = 1
        for d in bucket.shape[:-2]:
            lead *= d
        c = float(d_in) ** 3
        if sides == 'both':
            c += float(d_out) ** 3
        return lead * c

    return cost


# ---------------------------------------------------------------------------
# Assignment


@functools.lru_cache(maxsize=256)
def _assign_cached(plan: BucketPlan, costs: tuple, world: int) -> dict:
    owners = {b.key: np.zeros(len(b.paths), np.int64) for b in plan.buckets}
    if world > 1:
        items = [(costs[bi], b.key, i)
                 for bi, b in enumerate(plan.buckets)
                 for i in range(len(b.paths))]
        # LPT greedy = weighted round-robin: biggest items first, each to the
        # least-loaded worker; ties broken by (key, item) so the map is a
        # pure function of (plan, cost, world) on every host.
        items.sort(key=lambda t: (-t[0], t[1], t[2]))
        loads = np.zeros(world, np.float64)
        for c, key, i in items:
            w = int(np.argmin(loads))
            owners[key][i] = w
            loads[w] += c
    return owners


def assign_owners(plan: BucketPlan, cost: Callable[[Bucket], float],
                  world: int) -> dict[str, np.ndarray]:
    """{bucket_key: (N,) int array of owner ranks in [0, world)} — static
    (numpy) metadata, deterministic across hosts."""
    costs = tuple(cost(b) for b in plan.buckets)
    return _assign_cached(plan, costs, world)


def describe_ownership(plan: BucketPlan, world: int,
                       sides: str = 'both') -> dict[str, list[int]]:
    """JSON-able owner map (trainer logging)."""
    owners = assign_owners(plan, inverse_cost(sides), world)
    return {k: [int(w) for w in v] for k, v in owners.items()}


# ---------------------------------------------------------------------------
# Mesh introspection (trace-time)


def world_and_rank(axes: Optional[tuple[str, ...]] = None):
    """(world, rank) over the data-parallel axes bound in the current
    tracing scope.  ``world`` is a static int; ``rank`` is a traced scalar
    (row-major over the bound axes), or None when single-worker.

    Outside any shard_map/pmap body this is (1, None): refresh sharding
    quietly disables itself and every worker (the only worker) owns
    everything — which is what makes single-host behavior the W=1 special
    case of the same code path rather than a separate branch.
    """
    if axes is None:
        axes = data_axes_in_scope()
    if not axes:
        return 1, None
    sizes = compat.bound_axis_sizes()
    world = 1
    for a in axes:
        world *= int(sizes.get(a, 1))
    if world <= 1:
        return 1, None
    rank = jnp.zeros((), jnp.int32)
    for a in axes:
        rank = rank * int(sizes.get(a, 1)) + jax.lax.axis_index(a)
    return world, rank
