"""Worker-sharded refresh ownership: which data-parallel worker recomputes
which bucket item.

Every worker holding identical (psum-averaged) curvature statistics and
redundantly inverting every bucket item is exactly the waste distributed
K-FAC-style layer assignment eliminates (cf. MKOR's distributed factor
maintenance).  This module assigns work to the workers of the live
``('pod','data')`` mesh deterministically at two granularities: per stack
row (:func:`assign_owners`, the original cost-weighted LPT greedy — no
production caller since the runtime went slice-granular; kept as the
simple reference the ownership tests compare against) and per
(row × lead-dim) slice (:func:`assign_slice_owners`, what the refresh
runtime shards at; :func:`assign_pod_slice_owners` for pod-local
topology), so
refresh FLOPs scale 1/W with world size even on scan-stacked models with
few parameter paths.  The refreshed slices are then exchanged through
``repro.comm.exchange`` — by default an owned-slice all-gather whose
per-worker traffic also scales ~1/W (each slice arrives as an exact copy
of its owner's value), or the legacy bucket-stacked zero-padded ``psum``
(``x + 0 == x`` is exact) — both bit-identical reconstructions.
"""
from __future__ import annotations

import functools
from typing import Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.bucketing import Bucket, BucketPlan
from repro.sharding import compat
from repro.sharding.constraints import data_axes_in_scope


# ---------------------------------------------------------------------------
# Per-item cost model


def inverse_cost(sides: str = 'both') -> Callable[[Bucket], float]:
    """FLOP estimate for refreshing ONE item of a bucket: dense
    factorizations are cubic in the factor dim, and scan-stacked leading
    dims multiply (an item of a ``(L, d_in, d_out)`` bucket refreshes L
    factor pairs).

    sides: 'left' (FOOF: input factor only) or 'both' (K-FAC / Shampoo).
    """
    if sides not in ('left', 'both'):
        raise ValueError(f"sides must be 'left' or 'both', got {sides!r}")

    def cost(bucket: Bucket) -> float:
        d_in, d_out = bucket.shape[-2], bucket.shape[-1]
        lead = 1
        for d in bucket.shape[:-2]:
            lead *= d
        c = float(d_in) ** 3
        if sides == 'both':
            c += float(d_out) ** 3
        return lead * c

    return cost


# ---------------------------------------------------------------------------
# Assignment


@functools.lru_cache(maxsize=256)
def _assign_cached(plan: BucketPlan, costs: tuple, world: int,
                   counts: tuple) -> dict:
    owners = {b.key: np.zeros(n, np.int64)
              for b, n in zip(plan.buckets, counts)}
    if world > 1:
        items = [(costs[bi], b.key, i)
                 for bi, b in enumerate(plan.buckets)
                 for i in range(counts[bi])]
        # LPT greedy = weighted round-robin: biggest items first, each to the
        # least-loaded worker; ties broken by (key, item) so the map is a
        # pure function of (plan, cost, world) on every host.
        items.sort(key=lambda t: (-t[0], t[1], t[2]))
        loads = np.zeros(world, np.float64)
        for c, key, i in items:
            w = int(np.argmin(loads))
            owners[key][i] = w
            loads[w] += c
    return owners


def assign_owners(plan: BucketPlan, cost: Callable[[Bucket], float],
                  world: int) -> dict[str, np.ndarray]:
    """{bucket_key: (N,) int array of owner ranks in [0, world)} — static
    (numpy) metadata, deterministic across hosts.  One entry per stack ROW
    (parameter path); the refresh runtime and the exchange accounting use
    the finer :func:`assign_slice_owners` — this row-level form has no
    production caller and survives as the reference in the tests."""
    costs = tuple(cost(b) for b in plan.buckets)
    counts = tuple(len(b.paths) for b in plan.buckets)
    return _assign_cached(plan, costs, world, counts)


def lead_size(bucket: Bucket) -> int:
    """Product of a bucket's leading (scan/expert-stack) dims — the number
    of factor pairs one stack row carries."""
    lead = 1
    for d in bucket.shape[:-2]:
        lead *= int(d)
    return lead


@functools.lru_cache(maxsize=256)
def _assign_slices_cached(plan: BucketPlan, costs: tuple, world: int,
                          counts: tuple) -> dict:
    owners = {b.key: np.zeros(n, np.int64)
              for b, n in zip(plan.buckets, counts)}
    if world > 1:
        order = sorted(range(len(plan.buckets)),
                       key=lambda bi: (-costs[bi], plan.buckets[bi].key))
        loads = np.zeros(world, np.float64)
        for bi in order:
            key = plan.buckets[bi].key
            per = np.zeros(world, np.int64)
            for i in range(counts[bi]):
                # per-bucket balance first (counts differ by <= 1, which is
                # what minimizes the padded all-gather), global cost load as
                # the tie-break; first-min ties keep the map deterministic
                cand = np.flatnonzero(per == per.min())
                w = int(cand[np.argmin(loads[cand])])
                owners[key][i] = w
                per[w] += 1
                loads[w] += costs[bi]
    return owners


def assign_slice_owners(plan: BucketPlan, cost: Callable[[Bucket], float],
                        world: int) -> dict[str, np.ndarray]:
    """{bucket_key: (N·lead,) owner ranks} — ownership at the finest stack
    granularity: (row, lead-slice), row-major.

    Row-level assignment caps parallelism at the path count, which on
    scan-stacked models is tiny (qwen2-0.5b: 7 paths for 168 layer-factor
    pairs) — one 2 GB row then has a single owner and the exchange can't
    shrink.  Slicing the leading dims makes refresh FLOPs *and* the
    owned-slice exchange genuinely scale ~1/W.

    Within a bucket every slice costs the same (``cost(bucket)/lead``), so
    the assignment balances each bucket's slice COUNT across workers first
    (per-worker counts differ by at most 1 — exactly what minimizes the
    padded all-gather size, since the exchange pads every worker to the
    bucket max) and breaks count ties by global cost load (the LPT
    objective; buckets are visited biggest-slice-first).  Deterministic on
    every host, like :func:`assign_owners`.
    """
    costs = tuple(cost(b) / lead_size(b) for b in plan.buckets)
    counts = tuple(len(b.paths) * lead_size(b) for b in plan.buckets)
    return _assign_slices_cached(plan, costs, world, counts)


@functools.lru_cache(maxsize=256)
def _assign_pod_cached(plan: BucketPlan, costs: tuple, pods: tuple,
                       counts: tuple) -> dict:
    n_pods, per_pod = pods
    owners = {b.key: np.zeros(n, np.int64)
              for b, n in zip(plan.buckets, counts)}
    if n_pods * per_pod > 1:
        # LPT of whole buckets over pods: biggest total first to the
        # least-loaded pod — every slice of a bucket lands in ONE pod, so
        # the slice-granular gather stays on that pod's ICI links.
        order = sorted(range(len(plan.buckets)),
                       key=lambda bi: (-costs[bi] * counts[bi],
                                       plan.buckets[bi].key))
        pod_loads = np.zeros(n_pods, np.float64)
        for bi in order:
            key = plan.buckets[bi].key
            pod = int(np.argmin(pod_loads))
            pod_loads[pod] += costs[bi] * counts[bi]
            # within the pod: balance slice counts over its workers (the
            # same objective as the flat assignment — per-worker counts
            # differ by <= 1, minimizing the padded gather)
            for i in range(counts[bi]):
                owners[key][i] = pod * per_pod + i % per_pod
    return owners


def assign_pod_slice_owners(plan: BucketPlan, cost: Callable[[Bucket], float],
                            pods: tuple[int, int]) -> dict[str, np.ndarray]:
    """Slice owners under a ``(n_pods, per_pod)`` topology: every bucket's
    slices are owned by workers of a single pod (buckets LPT-balanced over
    pods by total inverse cost, slices count-balanced within the pod).

    Global ranks are row-major over ('pod', intra-pod) — matching
    ``world_and_rank`` over the ('pod','data') axes — so the same owner
    map drives both the cond-gated recompute and the two-stage exchange
    (``repro.comm.exchange.allgather_owned_slices(pods=...)``).
    """
    costs = tuple(cost(b) / lead_size(b) for b in plan.buckets)
    counts = tuple(len(b.paths) * lead_size(b) for b in plan.buckets)
    return _assign_pod_cached(plan, costs, tuple(pods), counts)


def describe_ownership(plan: BucketPlan, world: int,
                       sides: str = 'both') -> dict[str, list[int]]:
    """JSON-able per-worker owned-slice counts per bucket (trainer
    logging): {bucket_key: [slices owned by worker 0, 1, ...]}."""
    owners = assign_slice_owners(plan, inverse_cost(sides), world)
    return {k: np.bincount(v, minlength=world).tolist()
            for k, v in owners.items()}


# ---------------------------------------------------------------------------
# Sub-slice (column-block) ownership — granularity BELOW one slice
#
# Slice-granular ownership bottoms out at one (lead-slice, d, d) factor per
# owner: a single un-stackable oversized factor (glm4-9b's 151552-wide vocab
# head) is then owned whole by ONE worker and caps the W=4 exchange
# reduction at 1.71x.  These helpers partition the rows/columns of one such
# factor across ALL workers as contiguous row bands, which the matrix-free
# apply path (repro.core.factor_sharded) turns into per-worker partial
# matvecs completed by a single zero-padded psum.


def factor_block(d: int, world: int) -> int:
    """Rows per worker when one (d, d) factor is column-block partitioned:
    ``ceil(d / world)``.  Worker ``w`` holds the contiguous row band
    ``[w*B, (w+1)*B)`` of the zero-padded ``(world*B, d)`` factor.  Every
    row of a single symmetric factor costs the same, so the uniform
    contiguous split IS the LPT partition at this granularity (per-worker
    loads differ by at most one row) — no greedy pass needed."""
    return -(-int(d) // int(world))


def assign_subslice_owners(d: int, world: int) -> np.ndarray:
    """(world,) int64: row band ``b`` of the factor is owned by worker
    ``b`` — the uniform LPT map below slice granularity, returned as an
    explicit owner array so describe/logging paths treat factor bands like
    any other ownership map."""
    return np.arange(int(world), dtype=np.int64)


def subslice_trips(bucket: Bucket, threshold: int) -> tuple[bool, bool]:
    """(in_side, out_side): which factor sides of ``bucket`` exceed the
    sub-slice ``shard_threshold`` (factor dim >= threshold).  The policy
    knob (``repro.core.factor_sharded.FactorShardConfig``) decides WHAT to
    do with a tripped side ('shard' | 'exclude' | keep 'dense'); this is
    only the structural trigger."""
    d_in, d_out = int(bucket.shape[-2]), int(bucket.shape[-1])
    return d_in >= int(threshold), d_out >= int(threshold)


def describe_subslices(plan: BucketPlan, world: int,
                       threshold: int) -> dict[str, list[int]]:
    """JSON-able per-worker row-band sizes for every tripped factor side
    (trainer logging, alongside :func:`describe_ownership`):
    ``{'<bucket_key>/<in|out>': [rows owned by worker 0, 1, ...]}``."""
    out: dict[str, list[int]] = {}
    for b in plan.buckets:
        trips = subslice_trips(b, threshold)
        for side, tripped, d in (('in', trips[0], int(b.shape[-2])),
                                 ('out', trips[1], int(b.shape[-1]))):
            if tripped:
                blk = factor_block(d, world)
                out[f'{b.key}/{side}'] = [
                    max(0, min(blk, d - w * blk)) for w in range(world)]
    return out


# ---------------------------------------------------------------------------
# Mesh introspection (trace-time)


def world_and_rank(axes: Optional[tuple[str, ...]] = None):
    """(world, rank) over the data-parallel axes bound in the current
    tracing scope.  ``world`` is a static int; ``rank`` is a traced scalar
    (row-major over the bound axes), or None when single-worker.

    Outside any shard_map/pmap body this is (1, None): refresh sharding
    quietly disables itself and every worker (the only worker) owns
    everything — which is what makes single-host behavior the W=1 special
    case of the same code path rather than a separate branch.
    """
    if axes is None:
        axes = data_axes_in_scope()
    if not axes:
        return 1, None
    sizes = compat.bound_axis_sizes()
    world = 1
    for a in axes:
        world *= int(sizes.get(a, 1))
    if world <= 1:
        return 1, None
    rank = jnp.zeros((), jnp.int32)
    for a in axes:
        rank = rank * int(sizes.get(a, 1)) + jax.lax.axis_index(a)
    return world, rank
