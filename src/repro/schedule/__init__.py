"""Curvature refresh runtime (paper Fig. 6, §3.3).

Owns every decision about *when* cached curvature (factor inverses, KV
snapshots) is recomputed and *where* (which data-parallel worker) the
recomputation runs.  Three pieces:

* ``policy``    — refresh policies as pure pytree-state objects
                  (``every_k`` / ``warmup_then_k`` / ``adaptive``),
* ``ownership`` — deterministic worker-sharded bucket-item assignment
                  (inverse FLOPs scale 1/W with world size),
* ``pipeline``  — the double-buffered one-step-stale exchange pipeline
                  (``PipelineState`` buffers, ``pipeline='onestep'``),
* ``runtime``   — the ``RefreshRuntime`` façade the optimizers and the
                  train step talk to,
* ``reshard``   — elastic checkpoint resharding across world sizes (the
                  metadata contract, the pipeline drain rule, and the
                  ownership delta behind the typed ``reshard`` event).
"""
from repro.schedule.policy import (SchedState, RefreshPolicy, adaptive,
                                   every_k, init_state, commit, named_policy,
                                   warmup_then_k)
from repro.schedule.ownership import (assign_owners, describe_ownership,
                                      inverse_cost, world_and_rank)
from repro.schedule.pipeline import (PipelineState, pipe_entries,
                                     pipeline_metrics, staged_pmean)
from repro.schedule.runtime import (RefreshRuntime, from_extras,
                                    ownership_event, resolve_pipe,
                                    sched_states, schedule_metrics,
                                    sharded_refresh)
from repro.schedule.reshard import (ELASTIC_KEY, ReshardError,
                                    check_metadata, elastic_metadata,
                                    ownership_delta, plan_fingerprint,
                                    reshard_state)

__all__ = [
    'SchedState', 'RefreshPolicy', 'every_k', 'warmup_then_k', 'adaptive',
    'named_policy', 'init_state', 'commit',
    'assign_owners', 'describe_ownership', 'inverse_cost', 'world_and_rank',
    'PipelineState', 'pipe_entries', 'pipeline_metrics', 'staged_pmean',
    'RefreshRuntime', 'from_extras', 'ownership_event', 'resolve_pipe',
    'sched_states', 'schedule_metrics', 'sharded_refresh',
    'ELASTIC_KEY', 'ReshardError', 'check_metadata', 'elastic_metadata',
    'ownership_delta', 'plan_fingerprint', 'reshard_state',
]
