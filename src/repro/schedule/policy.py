"""Refresh policies: pure pytree-state decisions about curvature staleness.

The paper's Fig. 6 argument is that second-order cost is dominated by *when*
curvature is refreshed: K-FAC amortizes factor inversions over an update
interval while Eva's vectorized form is cheap enough to refresh every step.
Before this module each optimizer carried its own ``count % interval``
branch; now the decision is a :class:`RefreshPolicy` — a named pair of pure
functions over a shared :class:`SchedState` pytree — so every method (the
explicit-inverse baselines *and* the eva family) gets the same knob, the
state checkpoints with the optimizer, and new policies need no optimizer
changes.

Contract: with ``every_k(1)`` the scheduled path is bit-identical (atol=0)
to always-fresh recomputation — ``tests/test_schedule.py`` proves it for all
six methods.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp


class SchedState(NamedTuple):
    """Refresh bookkeeping carried inside optimizer state (checkpointable).

    Attributes:
      count: int32 — update steps observed (the decide for step t sees t).
      since: int32 — steps since the last refresh (0 right after one).
      n_refresh: int32 — cumulative refreshes (trainer logging).
      staleness: f32 — last value of the policy's staleness proxy.
      snapshot: stats pytree at the last refresh (adaptive policies), or
        None for counter-only policies so checkpoints stay small.
    """

    count: jnp.ndarray
    since: jnp.ndarray
    n_refresh: jnp.ndarray
    staleness: jnp.ndarray
    snapshot: Any = None


@dataclasses.dataclass(frozen=True)
class RefreshPolicy:
    """A refresh decision: ``decide(state, stats) -> (refresh, staleness)``.

    ``decide`` is pure and jit-traceable; ``refresh`` is a scalar bool array
    (replicated across workers — every worker must agree so the gated
    recompute branches stay SPMD-consistent) and ``staleness`` a scalar f32
    proxy recorded for logging.  ``wants_snapshot`` policies get a stats
    snapshot maintained for them by :func:`commit`.
    """

    name: str
    decide: Callable[[SchedState, Any], tuple[jnp.ndarray, jnp.ndarray]]
    wants_snapshot: bool = False


def init_state(policy: RefreshPolicy, stats_template: Any) -> SchedState:
    """Zero-initialized SchedState; snapshot allocated only when needed."""
    snap = None
    if policy.wants_snapshot and stats_template is not None:
        snap = jax.tree_util.tree_map(
            lambda x: jnp.zeros(jnp.shape(x), jnp.float32), stats_template)
    z = jnp.zeros((), jnp.int32)
    return SchedState(count=z, since=z, n_refresh=z,
                      staleness=jnp.zeros((), jnp.float32), snapshot=snap)


def commit(policy: RefreshPolicy, state: SchedState, stats: Any,
           refresh: jnp.ndarray, staleness: jnp.ndarray) -> SchedState:
    """Advance counters after a decided step; snapshot updates where
    refreshed (``jnp.where`` keeps it jit-safe under a traced decision)."""
    snap = state.snapshot
    if policy.wants_snapshot and snap is not None:
        snap = jax.tree_util.tree_map(
            lambda s, f: jnp.where(refresh, f.astype(s.dtype), s),
            snap, stats)
    one = jnp.ones((), jnp.int32)
    return SchedState(
        count=state.count + one,
        since=jnp.where(refresh, jnp.zeros((), jnp.int32), state.since + one),
        n_refresh=state.n_refresh + refresh.astype(jnp.int32),
        staleness=jnp.asarray(staleness, jnp.float32),
        snapshot=snap)


# ---------------------------------------------------------------------------
# Policies


def every_k(k: int = 1) -> RefreshPolicy:
    """Refresh every ``k`` steps — reproduces the historical per-optimizer
    ``count % interval == 0`` branch exactly (count starts at 0, so step 0
    always refreshes)."""
    if k < 1:
        raise ValueError(f'every_k needs k >= 1, got {k}')

    def decide(state: SchedState, stats):
        del stats
        refresh = (state.count % k) == 0
        return refresh, state.since.astype(jnp.float32)

    return RefreshPolicy(name=f'every_k({k})', decide=decide)


def warmup_then_k(warmup: int, k: int) -> RefreshPolicy:
    """Refresh every step for the first ``warmup`` steps (while curvature
    EMAs are still moving fast), then every ``k`` — the standard production
    K-FAC schedule (cf. MKOR's fac/kfac update-freq split)."""
    if warmup < 0 or k < 1:
        raise ValueError(f'warmup_then_k needs warmup >= 0, k >= 1; '
                         f'got ({warmup}, {k})')

    def decide(state: SchedState, stats):
        del stats
        in_warmup = state.count < warmup
        periodic = ((state.count - warmup) % k) == 0
        return in_warmup | periodic, state.since.astype(jnp.float32)

    return RefreshPolicy(name=f'warmup_then_k({warmup},{k})', decide=decide)


def drift(snapshot: Any, stats: Any) -> jnp.ndarray:
    """Relative L2 drift of the bucket-stacked statistics since the last
    refresh: ``‖stats − snapshot‖ / (‖snapshot‖ + ε)`` over all leaves —
    the cheap staleness proxy (a handful of reductions over arrays the
    optimizer already holds; no inverse is touched)."""
    def sq(t):
        leaves = [jnp.sum(jnp.square(x.astype(jnp.float32)))
                  for x in jax.tree_util.tree_leaves(t)]
        return sum(leaves, jnp.zeros((), jnp.float32))

    diff = jax.tree_util.tree_map(
        lambda s, f: f.astype(jnp.float32) - s.astype(jnp.float32),
        snapshot, stats)
    return jnp.sqrt(sq(diff)) / (jnp.sqrt(sq(snapshot)) + 1e-12)


def adaptive(threshold: float = 0.05,
             max_interval: Optional[int] = None) -> RefreshPolicy:
    """Staleness-aware: refresh when the relative drift of the curvature
    statistics since the last refresh exceeds ``threshold`` (always at step
    0, and at least every ``max_interval`` steps when given).  Early in
    training the stats move fast and refreshes are frequent; near
    convergence they plateau and the inverse cost amortizes itself."""
    if threshold <= 0:
        raise ValueError(f'adaptive needs threshold > 0, got {threshold}')

    def decide(state: SchedState, stats):
        if state.snapshot is None:
            raise ValueError(
                'adaptive policy found no drift snapshot in SchedState — '
                'the optimizer state was initialized under a different '
                'policy.  Pass the same policy (or the same Extras.sched '
                'runtime) to init and update.')
        d = drift(state.snapshot, stats)
        refresh = (state.count == 0) | (d > threshold)
        if max_interval is not None:
            refresh = refresh | (state.since >= (max_interval - 1))
        # step 0 drifts from the zero snapshot — the forced refresh makes
        # the decision right, but don't log that ratio as staleness
        return refresh, jnp.where(state.count == 0, 0.0, d)

    return RefreshPolicy(name=f'adaptive({threshold})', decide=decide,
                         wants_snapshot=True)


_NAMED: dict[str, Callable[..., RefreshPolicy]] = {
    'every_k': every_k,
    'warmup_then_k': warmup_then_k,
    'adaptive': adaptive,
}


def named_policy(name: str, **kwargs) -> RefreshPolicy:
    """Registry entry point for benchmarks/launchers: ``named_policy(
    'every_k', k=5)``."""
    if name not in _NAMED:
        raise KeyError(f'unknown policy {name!r}; have {sorted(_NAMED)}')
    return _NAMED[name](**kwargs)


def resolve(policy: Optional[RefreshPolicy], interval: int = 1) -> RefreshPolicy:
    """An explicit policy wins; otherwise the optimizer's legacy ``interval``
    kwarg maps onto ``every_k`` so existing call sites keep their exact
    behavior."""
    return policy if policy is not None else every_k(interval)
