"""Double-buffered one-step-stale curvature pipeline (MKOR-style async Eva).

The synchronous exchange data flow is "call collective, block, use the
result this step".  That keeps every ``pmean_stats`` factor reduction and
every ``sharded_refresh`` owned-slice gather inside the critical path of
the step that produced it — the roofline's 3-5.5× gradient-volume factor
traffic all sits between the backward matmuls and the parameter update.

``pipeline='onestep'`` (a knob on ``schedule.runtime.RefreshRuntime``)
rewires the optimizers through the staged issue/collect API
(``repro.comm.exchange`` / ``sharding.constraints``) so step *t* **applies**
the statistics / refreshed inverses exchanged during step *t−1* while step
*t*'s own exchange is merely *issued*: its result feeds only the optimizer
STATE outputs, never this step's preconditioning contractions, so XLA's
async collectives / latency-hiding scheduler are free to overlap it with
compute (``launch/hlo_analysis.collective_overlap`` checks exactly this
dependence structure).  The price is one step of staleness — the same
quantity the refresh policies already model and the trainer already logs.

State carried per pipelined exchange site is one :class:`PipelineState`:

* ``inflight`` — the value exchanged this step, applied next step.  For the
  statistics sites this is the reduced fresh-stat tree (one extra stats
  copy in optimizer state); for the refresh sites it is ``None`` — the
  optimizer's own cache fields (``a_inv`` …) double as the buffer because
  "apply the old cache, then store the refreshed one" needs no second copy.
* ``age`` — staleness (in steps) the buffer will have when applied.  0 at
  init (cold zeros; the eva-family snapshot and the inverse caches already
  start from zeros, so step 0 just preconditions with the same zeros the
  sync path would have produced pre-refresh).

Cold start is *zeros*, deliberately: a ``where(primed, buffered, fresh)``
fallback would keep the fresh collective inside the preconditioning
dependence cone on EVERY step (both select arms are materialized) and kill
the overlap this module exists to create.

Exact semantics (tested atol=0 in ``tests/test_pipeline.py``): for the
stats-only optimizers (eva, eva_f) a ``onestep`` run is bit-identical to a
``sync`` run fed the one-step-shifted stats stream ``[0, s_0, s_1, …]``;
for the interval methods (kfac, foof, shampoo) the reference is the
hand-rolled double-buffered loop.  eva_s performs no exchange at all, so
for it ``onestep`` ≡ ``sync`` trivially (documented no-op).
"""
from __future__ import annotations

from typing import Any, NamedTuple, Optional

import jax
import jax.numpy as jnp


class PipelineState(NamedTuple):
    """One pipelined exchange site's carried buffer (a pytree in optimizer
    state; ``inflight=None`` for sites whose buffer is the optimizer's own
    cache fields)."""
    inflight: Any
    age: jnp.ndarray


def init_state(template: Any = None) -> PipelineState:
    """Cold pipeline slot: a zeros buffer shaped like ``template`` (or no
    buffer at all for refresh sites) at age 0."""
    buf = (jax.tree_util.tree_map(lambda x: jnp.zeros(x.shape, x.dtype),
                                  template)
           if template is not None else None)
    return PipelineState(inflight=buf, age=jnp.zeros((), jnp.int32))


def stage(pipe: PipelineState, fresh: Any) -> tuple[Any, PipelineState]:
    """Swap buffers at an every-step exchange site: apply what was exchanged
    last step, put this step's ``fresh`` in flight (applied next step at
    age 1)."""
    return pipe.inflight, PipelineState(inflight=fresh,
                                        age=jnp.ones((), jnp.int32))


def tick(pipe: PipelineState, refresh: jnp.ndarray) -> PipelineState:
    """Advance a refresh-site slot whose buffer lives in the optimizer's
    cache fields: age resets to 1 when the gated recompute fired (fresh
    inverses now in flight), otherwise the in-flight value just got one
    step older."""
    return PipelineState(
        inflight=pipe.inflight,
        age=jnp.where(refresh, jnp.ones((), jnp.int32), pipe.age + 1))


def staged_pmean(tree: Any, pipe: Optional[PipelineState], codec=None,
                 site: Optional[str] = None
                 ) -> tuple[Any, Optional[PipelineState]]:
    """The staged statistics reduction every optimizer calls.

    Issues this step's mean all-reduce and collects it (decode + divide are
    local math — the collective output itself stays out of any downstream
    compute the caller does with the *applied* tree).  ``pipe=None`` is the
    sync path: the freshly reduced tree is applied immediately —
    bit-identical to the legacy ``sharding.constraints.pmean_stats`` (the
    issue/collect composition is op-for-op the same sequence).
    """
    from repro.sharding import constraints

    fresh = constraints.collect_pmean_stats(
        constraints.issue_pmean_stats(tree, codec=codec, site=site))
    if pipe is None:
        return fresh, None
    return stage(pipe, fresh)


# ---------------------------------------------------------------------------
# Observability


def pipe_entries(opt_state: Any) -> list[tuple[str, PipelineState]]:
    """All (site_key, PipelineState) pairs in an optimizer-state pytree —
    static Python walk, usable on traced and concrete states.  The site key
    is the nearest enclosing dict key ('stats' / 'refresh' by convention)."""
    found: list[tuple[str, PipelineState]] = []

    def walk(x, key=''):
        if isinstance(x, PipelineState):
            found.append((key, x))
            return
        if isinstance(x, dict):
            for k, v in x.items():
                walk(v, k if isinstance(k, str) else key)
        elif isinstance(x, (list, tuple)):
            for v in x:
                walk(v, key)

    walk(opt_state)
    return found


# Step-metric fields this module contributes (see the matching block in
# schedule/runtime.py): a trailing '/*' marks a per-site key family.
METRIC_FIELDS = {
    'pipeline_lag': ('int', 'steps of realized double-buffer staleness'),
    'pipeline_lag/*': ('int', 'per-site realized staleness'),
}


def pipeline_metrics(opt_state: Any) -> dict[str, jnp.ndarray]:
    """{'pipeline_lag', 'pipeline_lag/<site>'} — realized staleness (steps)
    of the buffer each pipelined exchange site will apply next; {} when the
    state carries no pipeline (sync mode)."""
    entries = pipe_entries(opt_state)
    if not entries:
        return {}
    out = {'pipeline_lag': jnp.max(jnp.stack([p.age for _, p in entries]))}
    for key in sorted({k for k, _ in entries if k}):
        out[f'pipeline_lag/{key}'] = jnp.max(
            jnp.stack([p.age for k2, p in entries if k2 == key]))
    return out
