from repro.sharding import compat
from repro.sharding.logical import (RULES, batch_pspec, cache_shardings,
                                    input_shardings, mirror_pspec,
                                    opt_state_shardings, param_shardings,
                                    resolve_pspec)

__all__ = ['RULES', 'batch_pspec', 'cache_shardings', 'compat',
           'input_shardings', 'mirror_pspec', 'opt_state_shardings',
           'param_shardings', 'resolve_pspec']
