"""Version-tolerant wrappers around jax.sharding mesh APIs.

The repo targets two jax generations:

* **new** (>= 0.5-era): ``jax.sharding.get_abstract_mesh()`` returns the
  mesh of the current sharding context and ``jax.sharding.AxisType``
  distinguishes Auto/Explicit/Manual axes; ``jax.make_mesh`` accepts an
  ``axis_types=`` keyword.
* **old** (0.4.x, what this container ships): none of those exist.  The
  current mesh lives at ``jax.interpreters.pxla.thread_resources.env
  .physical_mesh`` and every axis behaves as Auto.

Everything below probes the new API first and falls back, so callers never
touch ``jax.sharding`` attributes directly.  ``tests/test_jax_compat.py``
exercises both branches.
"""
from __future__ import annotations

from typing import Optional, Sequence

import jax

# AxisType.Auto, or None when the installed jax predates axis types (in which
# case every mesh axis is implicitly Auto).
AXIS_TYPE_AUTO = getattr(getattr(jax.sharding, 'AxisType', None), 'Auto', None)


def current_mesh() -> Optional[jax.sharding.Mesh]:
    """The mesh of the enclosing ``with mesh:`` context, or None.

    Uses ``jax.sharding.get_abstract_mesh`` when available; otherwise reads
    the thread-resources physical mesh (the only context mechanism on
    jax 0.4.x).  Returns None outside any mesh context.
    """
    get_abstract = getattr(jax.sharding, 'get_abstract_mesh', None)
    if get_abstract is not None:
        m = get_abstract()
    else:
        m = jax.interpreters.pxla.thread_resources.env.physical_mesh
    if m is None or m.empty:
        return None
    return m


def axes_all_auto(mesh) -> bool:
    """True when every mesh axis is Auto (constraints are legal).

    Meshes without axis-type metadata (old jax) are all-Auto by definition.
    """
    axis_types = getattr(mesh, 'axis_types', None)
    if axis_types is None or AXIS_TYPE_AUTO is None:
        return True
    try:
        types = tuple(axis_types)
    except TypeError:
        return True
    return all(t == AXIS_TYPE_AUTO for t in types)


def shard_map(f, *, mesh, in_specs, out_specs, check: bool = True):
    """``shard_map`` across jax generations: new jax exposes
    ``jax.shard_map(..., check_vma=)``, 0.4.x has
    ``jax.experimental.shard_map.shard_map(..., check_rep=)``."""
    sm = getattr(jax, 'shard_map', None)
    if sm is not None:
        return sm(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                  check_vma=check)
    from jax.experimental.shard_map import shard_map as sm_old
    return sm_old(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                  check_rep=check)


def bound_axis_names() -> tuple[str, ...]:
    """Mesh axis names bound in the current tracing scope (inside
    ``shard_map``/``pmap`` bodies); () at top level.

    Probes the axis env (moved between jax versions), falling back to () —
    a false-negative only disables the optional distributed stats reduction,
    never breaks tracing.
    """
    for mod in (getattr(jax, 'core', None),
                getattr(getattr(jax, '_src', None), 'core', None)):
        get_env = getattr(mod, 'get_axis_env', None)
        if get_env is None:
            continue
        try:
            env = get_env()
            sizes = getattr(env, 'axis_sizes', None)
            if sizes is not None:
                return tuple(sizes)
        except Exception:
            pass
    return ()


def bound_axis_sizes() -> dict:
    """{axis name: size} for mesh axes bound in the current tracing scope
    (inside ``shard_map``/``pmap`` bodies); {} at top level.

    Same env probe as ``bound_axis_names`` — a false-negative only disables
    the optional worker-sharded refresh (every worker recomputes everything,
    the always-correct fallback), never breaks tracing.
    """
    for mod in (getattr(jax, 'core', None),
                getattr(getattr(jax, '_src', None), 'core', None)):
        get_env = getattr(mod, 'get_axis_env', None)
        if get_env is None:
            continue
        try:
            env = get_env()
            sizes = getattr(env, 'axis_sizes', None)
            if sizes is not None:
                return {str(k): int(v) for k, v in dict(sizes).items()}
        except Exception:
            pass
    return {}


def cost_analysis(compiled) -> dict:
    """``Compiled.cost_analysis()`` normalized across jax versions: 0.4.x
    returns a one-element list of per-program dicts, newer jax a dict."""
    ca = compiled.cost_analysis() or {}
    if isinstance(ca, (list, tuple)):
        ca = ca[0] if ca else {}
    return ca


def set_mesh(mesh: jax.sharding.Mesh):
    """Context manager entering ``mesh``: ``jax.set_mesh`` on new jax, the
    mesh's own context manager (thread-resources) on 0.4.x."""
    setter = getattr(jax, 'set_mesh', None)
    if setter is not None:
        return setter(mesh)
    return mesh


def make_mesh(axis_shapes: Sequence[int], axis_names: Sequence[str],
              **kwargs) -> jax.sharding.Mesh:
    """``jax.make_mesh`` with all axes Auto, on any supported jax.

    Old jax has no ``axis_types=`` keyword; Auto is its only behavior, so
    dropping the argument is exact.
    """
    if AXIS_TYPE_AUTO is not None:
        kwargs.setdefault('axis_types', (AXIS_TYPE_AUTO,) * len(axis_names))
    return jax.make_mesh(tuple(axis_shapes), tuple(axis_names), **kwargs)
