"""Activation sharding constraints, mesh-aware but model-agnostic.

XLA's sharding propagation is weak through ``while`` loops: without anchors,
loop carries (the residual stream, flash-attention accumulators) silently
replicate — the dry-run showed 112 GiB/device attention residuals on qwen2.
``shard_activations(x)`` pins the batch dim of (B, S, D)-like activations to
the data axes of whatever mesh is current (no-op outside a mesh context or
when batch doesn't divide), which is enough of an anchor for propagation to
shard the loops.  Sequence parallelism (seq → 'model' in the norm/elementwise
regions) is available as ``shard_activations(x, seq='model')`` — a §Perf lever.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.sharding import compat


def _current_mesh():
    m = compat.current_mesh()
    if m is None:
        return None
    # inside shard_map axes are Manual: constraints are illegal there.  New
    # jax marks this via axis_types; old jax has no axis metadata, so detect
    # the shard_map body by its bound axis names instead.
    if not compat.axes_all_auto(m):
        return None
    if compat.bound_axis_names():
        return None
    return m


def constrain(x: jnp.ndarray, *axes: Optional[str]) -> jnp.ndarray:
    """with_sharding_constraint by logical role per dim: each entry is
    'data' (→ (pod,data)), 'model', or None; silently dropped when the axis
    is missing, doesn't divide, or we're inside shard_map."""
    mesh = _current_mesh()
    if mesh is None:
        return x
    daxes = tuple(a for a in ('pod', 'data') if a in mesh.shape)
    dsize = 1
    for a in daxes:
        dsize *= mesh.shape[a]
    spec: list = [None] * x.ndim
    for i, role in enumerate(axes[:x.ndim]):
        if role == 'data' and daxes and x.shape[i] % dsize == 0 and x.shape[i] > 0:
            spec[i] = daxes if len(daxes) > 1 else daxes[0]
        elif role == 'model' and 'model' in mesh.shape and \
                x.shape[i] % mesh.shape['model'] == 0:
            spec[i] = 'model'
    if all(s is None for s in spec):
        return x
    return jax.lax.with_sharding_constraint(x, P(*spec))


def data_axes_in_scope() -> tuple[str, ...]:
    """The subset of the data-parallel axes ('pod', 'data') bound in the
    current tracing scope (inside shard_map/pmap bodies); () elsewhere."""
    bound = compat.bound_axis_names()
    return tuple(a for a in ('pod', 'data') if a in bound)


class _InFlightPmean:
    """An issued-but-not-collected statistics reduction (one of: the raw
    tree when no data axis is bound, a dtype-preserving psum'd tree plus
    its static divisor, or a ``repro.comm`` :class:`InFlightMean`).  Lives
    within one trace — the pipeline stores the *collected* tree."""

    __slots__ = ('tree', 'n', 'kind')

    def __init__(self, tree, n, kind):
        self.tree = tree
        self.n = n
        self.kind = kind   # 'raw' | 'passthrough' | 'codec'


def issue_pmean_stats(tree, codec=None, site: Optional[str] = None
                      ) -> _InFlightPmean:
    """Collective half of :func:`pmean_stats`: fire the psums (or the
    codec'd all-reduce issue) over the live data-parallel axes.  The
    passthrough divisor is the trace-time axis size — exactly what
    ``lax.pmean`` divides by internally (``psum`` of a non-traced 1), so
    composing with :func:`collect_pmean_stats` stays bit-exact and
    dtype-preserving."""
    axes = data_axes_in_scope()
    if not axes or tree is None:
        if site is not None and tree is not None:
            from repro.comm import exchange, get_codec, metrics
            c = get_codec(codec)
            # No data axis bound (single-host pjit): nothing moves on the
            # wire, but the site still carries its logical payload so the
            # telemetry breakdown stays comparable across world sizes.
            metrics.record(site, bytes_per_call=exchange.tree_payload_bytes(
                tree, c), codec=c.name, mode='local')
        return _InFlightPmean(tree, None, 'raw')
    from repro.comm import exchange, get_codec, metrics
    arg = axes if len(axes) > 1 else axes[0]
    if get_codec(codec).passthrough:
        if site is not None:
            c = get_codec(codec)
            metrics.record(site, bytes_per_call=exchange.tree_payload_bytes(
                tree, c), codec=c.name, mode='psum')
        return _InFlightPmean(
            jax.tree_util.tree_map(lambda x: jax.lax.psum(x, arg), tree),
            jax.lax.psum(1, arg), 'passthrough')
    return _InFlightPmean(
        exchange.issue_allreduce_mean_tree(tree, codec=codec, axes=axes,
                                           site=site), None, 'codec')


def collect_pmean_stats(fl: _InFlightPmean):
    """Local finishing half of :func:`pmean_stats` (divide / decode)."""
    if fl.kind == 'raw':
        return fl.tree
    if fl.kind == 'passthrough':
        return jax.tree_util.tree_map(lambda v: v / fl.n, fl.tree)
    from repro.comm import exchange
    return exchange.collect_allreduce_mean_tree(fl.tree)[0]


def pmean_stats(tree, codec=None, site: Optional[str] = None):
    """psum-average a pytree of per-bucket KV/KF statistics across the live
    data-parallel axes, making Eva's statistics batch-global as in the
    paper's multi-GPU setup (§3.3).

    ``codec`` ('f32' | 'bf16' | 'int8' | a ``repro.comm.Codec``) selects
    the wire format — the K-FAC/FOOF ``a_outer``/``b_outer`` factor
    reduction moves O(d²) per layer (4-5× the gradient volume on the
    roofline), so compressing it matters where Eva's O(d) KVs don't.
    ``codec=None`` or 'f32' keeps the exact legacy ``lax.pmean`` ops, which
    is what the atol=0 scheduling contracts compare against.

    No-op when no data axis is bound (single-host pjit path — there XLA's
    sharding propagation already reduces the stats with the gradients).
    The f32/None path is idempotent under repetition (pmean of
    already-averaged replicated values returns them unchanged), so
    composing it with an outer explicit reduction (e.g.
    ``train/compression.py``) is safe; the bf16/int8 paths re-quantize on
    every application and must run exactly once per fresh statistic.

    Synchronous composition of the staged halves (issue the collectives,
    finish locally) — see ``repro.schedule.pipeline`` for the one-step
    staged caller.
    """
    return collect_pmean_stats(issue_pmean_stats(tree, codec=codec,
                                                 site=site))


def psum_tree(tree, axes: Optional[tuple[str, ...]] = None):
    """psum a pytree across the live data-parallel axes — the exchange step
    of worker-sharded curvature refresh (``repro.schedule.ownership``): each
    worker contributes its owned, zero-padded slices and the sum
    reconstructs the full bucket stack on every worker (adding zeros is
    exact in IEEE arithmetic, so the exchange preserves bit-identity with a
    single-host refresh).  No-op when no data axis is bound.
    """
    if axes is None:
        axes = data_axes_in_scope()
    if not axes or tree is None:
        return tree
    return jax.tree_util.tree_map(
        lambda x: jax.lax.psum(x, axes if len(axes) > 1 else axes[0]), tree)


def shard_activations(x: jnp.ndarray, seq: Optional[str] = None) -> jnp.ndarray:
    """Constrain dim0 (batch) to (pod,data); optionally dim1 (seq) to model.
    Falls back to sharding the sequence dim over 'data' for batch=1 cells."""
    mesh = _current_mesh()
    if mesh is None or x.ndim < 2:
        return x
    daxes = tuple(a for a in ('pod', 'data') if a in mesh.shape)
    if not daxes:
        return x
    dsize = 1
    for a in daxes:
        dsize *= mesh.shape[a]
    spec: list = [None] * x.ndim
    if x.shape[0] % dsize == 0 and x.shape[0] >= dsize:
        spec[0] = daxes if len(daxes) > 1 else daxes[0]
        if seq and seq in mesh.shape and x.ndim >= 3 and \
                x.shape[1] % mesh.shape[seq] == 0:
            spec[1] = seq
    elif x.ndim >= 2 and 'data' in mesh.shape and \
            x.shape[1] % mesh.shape['data'] == 0:
        spec[1] = 'data'
    else:
        return x
    return jax.lax.with_sharding_constraint(x, P(*spec))
