"""Logical-axis → mesh-axis resolution with divisibility fallback.

Every ParamSpec carries logical axis names; RULES lists candidate mesh axes
per logical axis in priority order.  The resolver takes the first candidate
that (a) exists in the mesh, (b) divides the dimension, and (c) doesn't
reuse a mesh axis already consumed by another dim of the same tensor.
Indivisible dims fall back to the next candidate or replication — this is
what lets qwen2's 14 heads, whisper's 51865 vocab or jamba's kv=8 coexist
with a 16-way model axis (decisions are recorded; the dry-run prints them).

Design: FSDP over 'data', TP/EP over 'model', pure DP across 'pod' (no
parameter sharding over the cross-pod DCN axis).

Optimizer state is sharded by *mirroring*: momentum/Adam moments match the
param spec exactly; KV stats (ā: drop-last-dim, b̄: drop-second-last) and
KF outers inherit the matching weight-dim assignment by shape inference.
"""
from __future__ import annotations

from typing import Any, Optional

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.core import kv as kvlib
from repro.models import module as M

# logical axis -> mesh-axis candidates, in priority order
RULES: dict[Optional[str], tuple[str, ...]] = {
    'vocab': ('model',),
    'embed': ('data',),     # FSDP
    'mlp': ('model',),
    'heads': ('model',),
    'kv_heads': ('model',),
    'expert': ('model',),
    'inner': ('model',),    # mamba d_inner / in_proj fused dim
    'state': (),
    'layer': (),            # scan axis: never shard
    'conv': (),
    None: (),
}


def resolve_pspec(shape: tuple[int, ...], axes: tuple[Optional[str], ...],
                  mesh: Mesh, log: Optional[list] = None) -> P:
    assert len(shape) == len(axes), (shape, axes)
    used: set[str] = set()
    out = []
    for dim, ax in zip(shape, axes):
        assigned = None
        for cand in RULES.get(ax, ()):
            if cand not in mesh.shape:
                continue
            if cand in used:
                continue
            if dim % mesh.shape[cand] != 0:
                if log is not None:
                    log.append(f'  fallback: dim {dim} (axis {ax!r}) not '
                               f'divisible by {cand}={mesh.shape[cand]}')
                continue
            assigned = cand
            used.add(cand)
            break
        out.append(assigned)
    return P(*out)


def param_shardings(specs: Any, mesh: Mesh, log: Optional[list] = None) -> Any:
    """ParamSpec tree -> NamedSharding tree (same structure)."""
    return M.spec_tree_map(
        lambda s: NamedSharding(mesh, resolve_pspec(s.shape, s.axes, mesh, log)),
        specs)


# ---------------------------------------------------------------------------
# Inputs


def _data_axes(mesh: Mesh) -> tuple[str, ...]:
    return tuple(a for a in ('pod', 'data') if a in mesh.shape)


def batch_pspec(shape: tuple[int, ...], mesh: Mesh,
                seq_dim: Optional[int] = 1) -> P:
    """Shard dim 0 over (pod, data) when divisible; else (for batch=1
    long-context cells) shard the sequence dim over 'data'."""
    daxes = _data_axes(mesh)
    dsize = 1
    for a in daxes:
        dsize *= mesh.shape[a]
    specs: list = [None] * len(shape)
    if shape and shape[0] % dsize == 0 and shape[0] > 0:
        specs[0] = daxes if len(daxes) > 1 else daxes[0]
    elif (seq_dim is not None and len(shape) > seq_dim
          and shape[seq_dim] % mesh.shape.get('data', 1) == 0):
        specs[seq_dim] = 'data'
    return P(*specs)


def input_shardings(tree: Any, mesh: Mesh, seq_dim: Optional[int] = 1) -> Any:
    return jax.tree_util.tree_map(
        lambda x: NamedSharding(mesh, batch_pspec(x.shape, mesh, seq_dim)),
        tree)


def cache_shardings(cache: Any, mesh: Mesh) -> Any:
    """KV/SSM cache leaves: (L, B, S, KV, Dh) / (L, B, H, N, P) / (L, B, K, Ch).
    Batch -> (pod,data) when divisible, else seq -> data; one model-axis dim
    among the trailing dims when divisible."""
    daxes = _data_axes(mesh)
    dsize = 1
    for a in daxes:
        dsize *= mesh.shape[a]
    msize = mesh.shape.get('model', 1)

    def one(x):
        shape = x.shape
        specs: list = [None] * len(shape)
        if len(shape) >= 2 and shape[1] % dsize == 0:
            specs[1] = daxes if len(daxes) > 1 else daxes[0]
        elif len(shape) >= 3 and shape[2] % mesh.shape.get('data', 1) == 0:
            specs[2] = 'data'   # batch=1: shard the sequence/state dim
        # model axis preference: dim 2 (attn seq / ssm heads — decode
        # attention then psums one small partial per layer), then the
        # KV-heads dim, then the last dim.  Never a contraction-heavy dim
        # first: a model-sharded head_dim would psum every score tile.
        if msize > 1 and len(shape) >= 3:
            for i in (2, len(shape) - 2, len(shape) - 1):
                if i >= len(shape) or i < 2:
                    continue
                if specs[i] is None and shape[i] % msize == 0:
                    specs[i] = 'model'
                    break
        return NamedSharding(mesh, P(*specs))

    return jax.tree_util.tree_map(one, cache)


# ---------------------------------------------------------------------------
# Optimizer state mirroring


def mirror_pspec(param_spec: P, param_shape: tuple[int, ...],
                 leaf_shape: tuple[int, ...]) -> P:
    ps = tuple(param_spec) + (None,) * (len(param_shape) - len(tuple(param_spec)))
    if leaf_shape == param_shape:
        return P(*ps)
    if len(param_shape) >= 2:
        stack, d_in, d_out = param_shape[:-2], param_shape[-2], param_shape[-1]
        s_stack, s_in, s_out = ps[:-2], ps[-2], ps[-1]
        if leaf_shape == stack + (d_in,):           # a_mean / v_in
            return P(*s_stack, s_in)
        if leaf_shape == stack + (d_out,):          # b_mean / v_out
            return P(*s_stack, s_out)
        if leaf_shape == stack + (d_in, d_in):      # a_outer / m_in / p_in
            return P(*s_stack, s_in, None)
        if leaf_shape == stack + (d_out, d_out):    # b_outer / m_out / p_out
            return P(*s_stack, s_out, None)
        if leaf_shape == stack:                     # count
            return P(*s_stack)
    return P()


def _path_parts(path) -> list[str]:
    parts = []
    for k in path:
        if isinstance(k, jax.tree_util.DictKey):
            parts.append(str(k.key))
        elif isinstance(k, jax.tree_util.GetAttrKey):
            parts.append(k.name)
        elif isinstance(k, jax.tree_util.SequenceKey):
            parts.append(str(k.idx))
    return parts


def opt_state_shardings(opt_state_shapes: Any, param_specs: Any,
                        mesh: Mesh) -> Any:
    """NamedSharding tree for the optimizer state (same structure).

    Each leaf is matched to a parameter by the longest '/'-joined suffix of
    its key path that names a parameter (momentum subtrees end in the param
    path; KV-stat dicts key by the full weight path), then sharded by shape
    mirroring.  Unmatched leaves (step counters, M-FAC buffers) replicate.
    """
    flat_specs = M.flatten_specs(param_specs)
    spec_by_path = {p: (resolve_pspec(s.shape, s.axes, mesh), s.shape)
                    for p, s in flat_specs.items()}

    def one(path, leaf):
        parts = _path_parts(path)
        # try joined suffixes, longest first, and each single part (dict keys
        # in stats trees are full 'a/b/c/w' paths already)
        candidates = ['/'.join(parts[i:]) for i in range(len(parts))]
        candidates += [p for p in parts if '/' in p]
        best = None
        for cand in sorted(set(candidates), key=len, reverse=True):
            if cand in spec_by_path:
                best = cand
                break
        if best is None:
            return NamedSharding(mesh, P())
        pspec, pshape = spec_by_path[best]
        return NamedSharding(mesh, mirror_pspec(pspec, pshape, leaf.shape))

    return jax.tree_util.tree_map_with_path(one, opt_state_shapes)
