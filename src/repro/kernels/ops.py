"""Composed Eva ops on top of the kernel dispatch layer.

Each op routes every primitive (bilinear / matvec / rank1_update) through
``kernels/dispatch.py``, which picks compiled Pallas, interpret Pallas, or
the pure-XLA ``ref.py`` path per (op, backend, shape, dtype) — see that
module for the resolution rules.  The historical import-time ``INTERPRET``
constant is gone; backend selection is a runtime setting
(``dispatch.set_default_impl`` / ``dispatch.impl_override``) plus the
per-call ``impl=`` argument threaded down from ``core/precondition.py``.

Leading stack dims (scan-stacked layers, experts, bucket stacks — see
``core/bucketing``) are flattened into one leading axis and folded into the
pallas grid via the ``*_stacked`` kernels: ONE kernel launch regardless of
stack depth, with per-item numerics bit-identical to unstacked calls (the
kernels iterate each item's tiles in the same order — no vmap, whose
batched lowering changes accumulation order).
"""
from __future__ import annotations

from typing import Optional

import jax.numpy as jnp

from repro.kernels import dispatch


def _fold(x, n_lead):
    """Collapse the leading ``n_lead`` dims into one stack axis."""
    return x.reshape((-1,) + x.shape[n_lead:])


def eva_precondition(g: jnp.ndarray, a: jnp.ndarray, b: jnp.ndarray,
                     gamma: float, impl: Optional[str] = None) -> jnp.ndarray:
    """Eq. 13 via dispatched bilinear + rank1_update.

    g: (..., d_in, d_out); a: (..., d_in); b: (..., d_out); any leading
    stack dims run in a single grid-folded launch.
    """
    if g.ndim == 2:
        dot = dispatch.bilinear(g, a, b, impl=impl)
        a32, b32 = a.astype(jnp.float32), b.astype(jnp.float32)
        denom = gamma + jnp.sum(a32 * a32) * jnp.sum(b32 * b32)
        return dispatch.rank1_update(g, a, b, dot / denom, 1.0 / gamma,
                                     impl=impl)
    lead = g.shape[:-2]
    gs, as_, bs = _fold(g, g.ndim - 2), _fold(a, a.ndim - 1), _fold(b, b.ndim - 1)
    dot = dispatch.bilinear_stacked(gs, as_, bs, impl=impl)            # (L,)
    a32, b32 = as_.astype(jnp.float32), bs.astype(jnp.float32)
    denom = gamma + jnp.sum(a32 * a32, -1) * jnp.sum(b32 * b32, -1)
    scale = jnp.full_like(denom, 1.0 / gamma)
    out = dispatch.rank1_update_stacked(gs, as_, bs, dot / denom, scale,
                                        impl=impl)
    return out.reshape(lead + out.shape[1:])


def eva_f_precondition(g: jnp.ndarray, a: jnp.ndarray, gamma: float,
                       impl: Optional[str] = None) -> jnp.ndarray:
    """Eq. 21 via dispatched matvec + rank1_update (stack grid-folded)."""
    if g.ndim == 2:
        u = dispatch.matvec(g, a, impl=impl)
        a32 = a.astype(jnp.float32)
        denom = gamma + jnp.sum(a32 * a32)
        return dispatch.rank1_update(g, a, u, 1.0 / denom, 1.0 / gamma,
                                     impl=impl)
    lead = g.shape[:-2]
    gs, as_ = _fold(g, g.ndim - 2), _fold(a, a.ndim - 1)
    u = dispatch.matvec_stacked(gs, as_, impl=impl)                    # (L, d_out)
    a32 = as_.astype(jnp.float32)
    denom = gamma + jnp.sum(a32 * a32, -1)
    scale = jnp.full_like(denom, 1.0 / gamma)
    out = dispatch.rank1_update_stacked(gs, as_, u, 1.0 / denom, scale,
                                        impl=impl)
    return out.reshape(lead + out.shape[1:])


def eva_fused(g: jnp.ndarray, a: jnp.ndarray, b: jnp.ndarray, gamma: float,
              m: jnp.ndarray, mu: float, fold_momentum: bool = True,
              impl: Optional[str] = None):
    """Eq. 13 + momentum/epilogue in one dispatched launch.

    Accepts arbitrary leading stack dims like :func:`eva_precondition`;
    returns ``(out, aux)`` with out f32 shaped like g and aux (..., 3)
    per-item epilogue partials [⟨out,g⟩, ⟨out,out⟩, ⟨g,g⟩].
    """
    lead = g.shape[:-2]
    n = g.ndim - 2
    gs, as_, bs, ms = (_fold(g, n), _fold(a, a.ndim - 1),
                       _fold(b, b.ndim - 1), _fold(m, n))
    out, aux = dispatch.eva_fused_stacked(gs, as_, bs, gamma, ms, mu,
                                          fold_momentum=fold_momentum,
                                          impl=impl)
    return out.reshape(lead + out.shape[1:]), aux.reshape(lead + (3,))


def eva_f_fused(g: jnp.ndarray, a: jnp.ndarray, gamma: float,
                m: jnp.ndarray, mu: float, fold_momentum: bool = True,
                impl: Optional[str] = None):
    """Eq. 21 + momentum/epilogue in one dispatched launch; same contract
    as :func:`eva_fused`."""
    lead = g.shape[:-2]
    n = g.ndim - 2
    gs, as_, ms = _fold(g, n), _fold(a, a.ndim - 1), _fold(m, n)
    out, aux = dispatch.eva_f_fused_stacked(gs, as_, gamma, ms, mu,
                                            fold_momentum=fold_momentum,
                                            impl=impl)
    return out.reshape(lead + out.shape[1:]), aux.reshape(lead + (3,))
