"""Jit'd wrappers composing the Pallas kernels into the Eva ops.

On TPU these run compiled (``interpret=False``); on this CPU container the
same kernel bodies execute under ``interpret=True`` (Python semantics) —
identical math, validated against ``ref.py`` in tests/test_kernels.py.

Leading stack dims (layers/experts) are handled by vmapping the pallas_call
— on TPU that folds the stack into the grid.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.bilinear import bilinear
from repro.kernels.matvec import matvec
from repro.kernels.rank1_update import rank1_update

# flipped to False on real TPU backends
INTERPRET = jax.default_backend() != 'tpu'


def _vmap_to_2d(fn, *args):
    """Apply fn over leading stack dims (all args share them)."""
    g = args[0]
    if g.ndim == 2:
        return fn(*args)
    return jax.vmap(lambda *a: _vmap_to_2d(fn, *a))(*args)


def eva_precondition(g: jnp.ndarray, a: jnp.ndarray, b: jnp.ndarray,
                     gamma: float) -> jnp.ndarray:
    """Fused Eq. 13 via bilinear + rank1_update kernels."""

    def one(g2, a1, b1):
        dot = bilinear(g2, a1, b1, interpret=INTERPRET)
        a32, b32 = a1.astype(jnp.float32), b1.astype(jnp.float32)
        denom = gamma + jnp.sum(a32 * a32) * jnp.sum(b32 * b32)
        return rank1_update(g2, a1, b1, dot / denom, 1.0 / gamma,
                            interpret=INTERPRET)

    return _vmap_to_2d(one, g, a, b)


def eva_f_precondition(g: jnp.ndarray, a: jnp.ndarray, gamma: float) -> jnp.ndarray:
    """Fused Eq. 21 via matvec + rank1_update kernels."""

    def one(g2, a1):
        u = matvec(g2, a1, interpret=INTERPRET)
        a32 = a1.astype(jnp.float32)
        denom = gamma + jnp.sum(a32 * a32)
        return rank1_update(g2, a1, u, 1.0 / denom, 1.0 / gamma,
                            interpret=INTERPRET)

    return _vmap_to_2d(one, g, a)
