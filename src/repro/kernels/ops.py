"""Jit'd wrappers composing the Pallas kernels into the Eva ops.

On TPU these run compiled (``interpret=False``); on this CPU container the
same kernel bodies execute under ``interpret=True`` (Python semantics) —
identical math, validated against ``ref.py`` in tests/test_kernels.py.

Leading stack dims (scan-stacked layers, experts, bucket stacks — see
``core/bucketing``) are flattened into one leading axis and folded into the
pallas grid via the ``*_stacked`` kernels: ONE kernel launch regardless of
stack depth, with per-item numerics bit-identical to unstacked calls (the
kernels iterate each item's tiles in the same order — no vmap, whose
batched lowering changes accumulation order).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.bilinear import bilinear, bilinear_stacked
from repro.kernels.matvec import matvec, matvec_stacked
from repro.kernels.rank1_update import rank1_update, rank1_update_stacked

# flipped to False on real TPU backends
INTERPRET = jax.default_backend() != 'tpu'


def _fold(x, n_lead):
    """Collapse the leading ``n_lead`` dims into one stack axis."""
    return x.reshape((-1,) + x.shape[n_lead:])


def eva_precondition(g: jnp.ndarray, a: jnp.ndarray, b: jnp.ndarray,
                     gamma: float) -> jnp.ndarray:
    """Fused Eq. 13 via bilinear + rank1_update kernels.

    g: (..., d_in, d_out); a: (..., d_in); b: (..., d_out); any leading
    stack dims run in a single grid-folded launch.
    """
    if g.ndim == 2:
        dot = bilinear(g, a, b, interpret=INTERPRET)
        a32, b32 = a.astype(jnp.float32), b.astype(jnp.float32)
        denom = gamma + jnp.sum(a32 * a32) * jnp.sum(b32 * b32)
        return rank1_update(g, a, b, dot / denom, 1.0 / gamma,
                            interpret=INTERPRET)
    lead = g.shape[:-2]
    gs, as_, bs = _fold(g, g.ndim - 2), _fold(a, a.ndim - 1), _fold(b, b.ndim - 1)
    dot = bilinear_stacked(gs, as_, bs, interpret=INTERPRET)          # (L,)
    a32, b32 = as_.astype(jnp.float32), bs.astype(jnp.float32)
    denom = gamma + jnp.sum(a32 * a32, -1) * jnp.sum(b32 * b32, -1)
    scale = jnp.full_like(denom, 1.0 / gamma)
    out = rank1_update_stacked(gs, as_, bs, dot / denom, scale,
                               interpret=INTERPRET)
    return out.reshape(lead + out.shape[1:])


def eva_f_precondition(g: jnp.ndarray, a: jnp.ndarray, gamma: float) -> jnp.ndarray:
    """Fused Eq. 21 via matvec + rank1_update kernels (stack grid-folded)."""
    if g.ndim == 2:
        u = matvec(g, a, interpret=INTERPRET)
        a32 = a.astype(jnp.float32)
        denom = gamma + jnp.sum(a32 * a32)
        return rank1_update(g, a, u, 1.0 / denom, 1.0 / gamma,
                            interpret=INTERPRET)
    lead = g.shape[:-2]
    gs, as_ = _fold(g, g.ndim - 2), _fold(a, a.ndim - 1)
    u = matvec_stacked(gs, as_, interpret=INTERPRET)                  # (L, d_out)
    a32 = as_.astype(jnp.float32)
    denom = gamma + jnp.sum(a32 * a32, -1)
    scale = jnp.full_like(denom, 1.0 / gamma)
    out = rank1_update_stacked(gs, as_, u, 1.0 / denom, scale,
                               interpret=INTERPRET)
    return out.reshape(lead + out.shape[1:])
