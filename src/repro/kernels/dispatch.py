"""Backend-aware kernel dispatch: per-(op, backend, shape, dtype) impl
selection + tile lookup for the Eva hot-path kernels.

Three implementations per op:

  * ``'pallas'``           — the Pallas kernels; compiled on TPU, interpret
                             (Python semantics) everywhere else.  This is
                             the historical ``use_pallas=True`` behavior.
  * ``'pallas_interpret'`` — Pallas forced into interpret mode on every
                             backend (tests pin this to exercise the kernel
                             bodies deterministically).
  * ``'xla'``              — the pure-jnp ``ref.py`` path, one fused XLA
                             region.  On CPU this is orders of magnitude
                             faster than interpret-mode Pallas (see
                             ``benchmarks/table5_itertime.py --kernels``).
  * ``'auto'``             — resolve per call site: an autotune-cache entry
                             for (backend, op, shape, dtype) wins if
                             present; otherwise ``'pallas'`` on TPU and
                             ``'xla'`` everywhere else.

The default impl is a **runtime** setting (``set_default_impl`` /
``impl_override``), replacing the old import-time ``ops.INTERPRET``
constant — tests and benchmarks flip backends without module reloads.
Per-call overrides thread through ``Extras.kernel`` (a ``KernelConfig``)
or the explicit ``impl=`` argument on each wrapper.

Tile sizes come from the autotune cache (``kernels/autotune.py``; shipped
defaults in ``tile_defaults.json`` warm-start it), falling back to the
waste-aware ``tiles.fit_block`` clamp of the 512-tile default.  Every
resolution is recorded and exposed via ``choices_snapshot()`` so the
trainer can emit the chosen impl + tiles as optional obs fields.
"""
from __future__ import annotations

import contextlib
import dataclasses
import json
from pathlib import Path
from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.kernels import bilinear as _bil
from repro.kernels import matvec as _mv
from repro.kernels import rank1_update as _r1
from repro.kernels import ref
from repro.kernels import tiles

IMPLS = ('auto', 'pallas', 'pallas_interpret', 'xla')
DEFAULT_BLOCK = 512
_DEFAULTS_FILE = Path(__file__).with_name('tile_defaults.json')


@dataclasses.dataclass(frozen=True)
class KernelConfig:
    """The launcher/trainer-level kernel knobs, threaded via ``Extras``.

    ``impl`` overrides the process default for every dispatch inside the
    step; ``autotune_cache`` is a JSON cache path installed at step-build
    time (``install_cache``); ``autotune`` marks that the launcher ran the
    tuner this session (informational, for obs).
    """
    impl: str = 'auto'
    autotune_cache: Optional[str] = None
    autotune: bool = False


@dataclasses.dataclass(frozen=True)
class Choice:
    """One resolved dispatch decision."""
    impl: str            # 'pallas' | 'xla'
    interpret: bool      # meaningful only for impl='pallas'
    block_in: int
    block_out: int


_state: dict[str, Any] = {'impl': 'auto', 'cache': None}
_choices: dict[str, str] = {}


def backend() -> str:
    return jax.default_backend()


def default_impl() -> str:
    return _state['impl']


def set_default_impl(impl: str) -> None:
    """Set the process-wide default impl at runtime (no reload needed)."""
    _check_impl(impl)
    _state['impl'] = impl


@contextlib.contextmanager
def impl_override(impl: str):
    """Temporarily force an impl (tests/benchmarks)."""
    _check_impl(impl)
    prev = _state['impl']
    _state['impl'] = impl
    try:
        yield
    finally:
        _state['impl'] = prev


def _check_impl(impl: str) -> None:
    if impl not in IMPLS:
        raise ValueError(f'unknown kernel impl {impl!r}; have {IMPLS}')


def impl_from_extras(extras, default: Optional[str] = None) -> Optional[str]:
    """The per-step impl request threaded through ``Extras.kernel``.

    A present ``KernelConfig`` wins over the preconditioner's own default —
    including ``'auto'``, which engages the dispatch layer's cache/backend
    resolution.  No config -> ``default`` (``None`` keeps callers on their
    historical inline-jnp path)."""
    cfg = getattr(extras, 'kernel', None) if extras is not None else None
    if cfg is not None:
        return cfg.impl
    return default


# ---------------------------------------------------------------------------
# Autotune-cache plumbing


def cache_key(op: str, d_in: int, d_out: int, dtype,
              backend_name: Optional[str] = None) -> str:
    return (f'{backend_name or backend()}/{op}/'
            f'{jnp.dtype(dtype).name}/{d_in}x{d_out}')


def _shipped_defaults() -> dict:
    if _DEFAULTS_FILE.exists():
        return dict(json.loads(_DEFAULTS_FILE.read_text()).get('entries', {}))
    return {}


def _cache() -> dict:
    if _state['cache'] is None:
        _state['cache'] = _shipped_defaults()
    return _state['cache']


def install_cache(cache) -> int:
    """Install autotune winners on top of the shipped defaults.

    ``cache`` is a path to an ``autotune.py`` JSON file or an already-loaded
    ``{'entries': {...}}``/plain-entries mapping.  Returns the entry count.
    """
    if isinstance(cache, (str, Path)):
        cache = json.loads(Path(cache).read_text())
    entries = cache.get('entries', cache) if isinstance(cache, dict) else {}
    base = _shipped_defaults()
    base.update(entries)
    _state['cache'] = base
    return len(base)


def reset_cache() -> None:
    _state['cache'] = None


# ---------------------------------------------------------------------------
# Resolution


def resolve(op: str, d_in: int, d_out: int, dtype,
            impl: Optional[str] = None) -> Choice:
    """Pick (impl, tiles) for one op instance.

    Order: explicit ``impl`` arg > process default; ``'auto'`` consults the
    autotune cache for this (backend, op, shape, dtype) and falls back to
    the backend rule (TPU -> pallas, else xla).  Tiles: cache entry, else
    the waste-aware clamp of the 512 default.
    """
    req = impl or _state['impl']
    _check_impl(req)
    entry = _cache().get(cache_key(op, d_in, d_out, dtype)) or {}
    if req == 'auto':
        concrete = entry.get('impl') or \
            ('pallas' if backend() == 'tpu' else 'xla')
    else:
        concrete = req
    interpret = True if concrete == 'pallas_interpret' \
        else backend() != 'tpu'
    if concrete == 'pallas_interpret':
        concrete = 'pallas'
    align = 8 if (concrete == 'pallas' and not interpret) else 1
    bm = tiles.fit_block(d_in, int(entry.get('block_in', DEFAULT_BLOCK)),
                         align)
    bn = tiles.fit_block(d_out, int(entry.get('block_out', DEFAULT_BLOCK)),
                         align)
    choice = Choice(impl=concrete, interpret=interpret,
                    block_in=bm, block_out=bn)
    label = concrete + ('/interpret' if concrete == 'pallas' and interpret
                        else '')
    _choices[op] = f'{label} {bm}x{bn} @ {d_in}x{d_out}'
    return choice


def choices_snapshot() -> dict[str, str]:
    """Latest resolved (impl, tiles) per op — the obs ``kernel_tiles``."""
    return dict(_choices)


# ---------------------------------------------------------------------------
# Op wrappers (the only call sites the rest of the repo should use)


def bilinear(g, a, b, impl: Optional[str] = None):
    c = resolve('bilinear', *g.shape[-2:], g.dtype, impl)
    if c.impl == 'xla':
        return ref.bilinear_ref(g, a, b)
    return _bil.bilinear(g, a, b, block_in=c.block_in, block_out=c.block_out,
                         interpret=c.interpret)


def bilinear_stacked(g, a, b, impl: Optional[str] = None):
    c = resolve('bilinear', *g.shape[-2:], g.dtype, impl)
    if c.impl == 'xla':
        return ref.bilinear_ref(g, a, b)
    return _bil.bilinear_stacked(g, a, b, block_in=c.block_in,
                                 block_out=c.block_out, interpret=c.interpret)


def matvec(g, a, impl: Optional[str] = None):
    c = resolve('matvec', *g.shape[-2:], g.dtype, impl)
    if c.impl == 'xla':
        return ref.matvec_ref(g, a)
    return _mv.matvec(g, a, block_in=c.block_in, block_out=c.block_out,
                      interpret=c.interpret)


def matvec_stacked(g, a, impl: Optional[str] = None):
    c = resolve('matvec', *g.shape[-2:], g.dtype, impl)
    if c.impl == 'xla':
        return ref.matvec_ref(g, a)
    return _mv.matvec_stacked(g, a, block_in=c.block_in,
                              block_out=c.block_out, interpret=c.interpret)


def matvec_cols(g, a, impl: Optional[str] = None):
    c = resolve('matvec_cols', *g.shape[-2:], g.dtype, impl)
    if c.impl == 'xla':
        return ref.matvec_cols_ref(g, a)
    return _mv.matvec_cols(g, a, block_in=c.block_in, block_out=c.block_out,
                           interpret=c.interpret)


def matvec_cols_stacked(g, a, impl: Optional[str] = None):
    c = resolve('matvec_cols', *g.shape[-2:], g.dtype, impl)
    if c.impl == 'xla':
        return ref.matvec_cols_ref(g, a)
    return _mv.matvec_cols_stacked(g, a, block_in=c.block_in,
                                   block_out=c.block_out,
                                   interpret=c.interpret)


def rank1_update(g, a, b, coeff, scale, impl: Optional[str] = None):
    c = resolve('rank1_update', *g.shape[-2:], g.dtype, impl)
    if c.impl == 'xla':
        return ref.rank1_update_ref(g, a, b, coeff, scale)
    return _r1.rank1_update(g, a, b, coeff, scale, block_in=c.block_in,
                            block_out=c.block_out, interpret=c.interpret)


def rank1_update_stacked(g, a, b, coeff, scale, impl: Optional[str] = None):
    c = resolve('rank1_update', *g.shape[-2:], g.dtype, impl)
    if c.impl == 'xla':
        return ref.rank1_update_ref(g, a, b, coeff, scale)
    return _r1.rank1_update_stacked(g, a, b, coeff, scale,
                                    block_in=c.block_in,
                                    block_out=c.block_out,
                                    interpret=c.interpret)


def eva_fused_stacked(g, a, b, gamma: float, m, mu: float,
                      fold_momentum: bool = True,
                      impl: Optional[str] = None):
    """One-launch Eva precondition + epilogue (see ``kernels/fused.py``).

    Returns ``(out, aux)``: ``out`` = μ·m + P (or P when ``fold_momentum``
    is off), f32; ``aux`` (L, 3) per-item partials [⟨out,g⟩, ⟨out,out⟩,
    ⟨g,g⟩] for the KL/graft scalar tails.
    """
    from repro.kernels import fused
    c = resolve('eva_fused', *g.shape[-2:], g.dtype, impl)
    if c.impl == 'xla':
        return ref.eva_fused_ref(g, a, b, gamma, m, mu, fold_momentum)
    return fused.eva_fused_stacked(g, a, b, gamma, m, mu,
                                   fold_momentum=fold_momentum,
                                   block_in=c.block_in,
                                   block_out=c.block_out,
                                   interpret=c.interpret)


def eva_f_fused_stacked(g, a, gamma: float, m, mu: float,
                        fold_momentum: bool = True,
                        impl: Optional[str] = None):
    """One-launch Eva-f precondition + epilogue; same contract as
    :func:`eva_fused_stacked`."""
    from repro.kernels import fused
    c = resolve('eva_f_fused', *g.shape[-2:], g.dtype, impl)
    if c.impl == 'xla':
        return ref.eva_f_fused_ref(g, a, gamma, m, mu, fold_momentum)
    return fused.eva_f_fused_stacked(g, a, gamma, m, mu,
                                     fold_momentum=fold_momentum,
                                     block_in=c.block_in,
                                     block_out=c.block_out,
                                     interpret=c.interpret)
