"""Waste-aware tile clamping shared by the kernels and the dispatch layer.

The kernels pad each operand up to a multiple of the block size and slice
the pad back off; with the historical ``min(block, d)`` clamp a 520-row
operand at the 512 default still paid 504 rows of padded-tile waste
(2 tiles of 512).  ``fit_block`` keeps the tile *count* implied by the
requested block but shrinks the block to the smallest size covering the
dim in that many tiles, so the pad is at most ``tiles - 1`` elements:

    d=520, block=512  ->  2 tiles of 260 (pad 0)   [min() gave 2x512, pad 504]
    d=1000, block=512 ->  2 tiles of 500 (pad 0)
    d<=block          ->  1 tile of d    (pad 0, same as min())

Kept dependency-free (no jax import) so both the kernel modules and
``dispatch`` can use it without an import cycle.
"""
from __future__ import annotations


def fit_block(d: int, block: int, align: int = 1) -> int:
    """Largest-waste-free block <= ``block`` for a dim of size ``d``.

    ``align`` rounds the fitted block up to a hardware multiple (TPU wants
    8-row sublanes); alignment may reintroduce a small pad but never more
    than ``align - 1`` rows per tile.
    """
    if d <= 0:
        raise ValueError(f'fit_block: dim must be positive, got {d}')
    if block <= 0:
        raise ValueError(f'fit_block: block must be positive, got {block}')
    if d <= block:
        b = d
    else:
        tiles = -(-d // block)      # ceil: tile count at the requested block
        b = -(-d // tiles)          # smallest block covering d in that many
    if align > 1 and b % align:
        b = min(-(-b // align) * align, max(block, align))
    return b
