"""Tile-size / impl autotuner feeding the kernel dispatch cache.

For each (op, shape, dtype) the tuner benchmarks a small block_in/block_out
grid of the Pallas kernel plus the pure-XLA ``ref.py`` path and records the
winner in a JSON cache keyed on (backend, op, shape, dtype) — the format
``dispatch.install_cache`` consumes and ``tile_defaults.json`` ships as
warm-start defaults:

    {"version": 1,
     "backend": "cpu",
     "entries": {"cpu/bilinear/float32/512x384":
                 {"impl": "xla", "block_in": 512, "block_out": 384,
                  "us": 12.3}}}

Determinism: given identical measurements the output bytes are identical —
entries are emitted with ``json.dumps(sort_keys=True, indent=2)``, the
candidate list is a fixed-order dedup, and ties break toward (lower time,
'xla' before 'pallas', smaller blocks).  Tests inject a fake ``bench`` to
pin the measurements and assert byte-stable output.

CLI: ``scripts/autotune.py``; programmatic warm-start:
``dispatch.install_cache(tune([...]))``.
"""
from __future__ import annotations

import json
import time
from pathlib import Path
from typing import Callable, Iterable, Optional

import jax
import jax.numpy as jnp

from repro.kernels import bilinear as _bil
from repro.kernels import fused as _fused
from repro.kernels import matvec as _mv
from repro.kernels import rank1_update as _r1
from repro.kernels import ref
from repro.kernels.dispatch import DEFAULT_BLOCK, backend, cache_key
from repro.kernels.tiles import fit_block

OPS = ('bilinear', 'matvec', 'rank1_update')
FUSED_OPS = ('eva_fused', 'eva_f_fused')
DEFAULT_GRID = ((128, 128), (256, 256), (512, 512))
_IMPL_RANK = {'xla': 0, 'pallas': 1}


def default_bench(fn: Callable[[], object], reps: int = 3,
                  warmup: int = 1) -> float:
    """Median wall µs of ``fn()`` (must block on its result)."""
    for _ in range(warmup):
        fn()
    times = []
    for _ in range(reps):
        t0 = time.perf_counter()
        fn()
        times.append((time.perf_counter() - t0) * 1e6)
    times.sort()
    return times[len(times) // 2]


def _operands(op: str, d_in: int, d_out: int, dtype):
    ks = jax.random.split(jax.random.PRNGKey(0), 4)
    g = jax.random.normal(ks[0], (d_in, d_out), jnp.float32).astype(dtype)
    a = jax.random.normal(ks[1], (d_in,), jnp.float32).astype(dtype)
    b = jax.random.normal(ks[2], (d_out,), jnp.float32).astype(dtype)
    m = jnp.zeros((1, d_in, d_out), jnp.float32)
    return g, a, b, m


def _candidate_fn(op: str, impl: str, g, a, b, m, bm: int, bn: int,
                  interpret: bool):
    """A no-arg, result-blocking callable running one op instance."""
    coeff = jnp.float32(0.37)
    scale = jnp.float32(2.5)
    if impl == 'xla':
        table = {
            'bilinear': lambda: ref.bilinear_ref(g, a, b),
            'matvec': lambda: ref.matvec_ref(g, a),
            'rank1_update': lambda: ref.rank1_update_ref(g, a, b, coeff,
                                                         scale),
            'eva_fused': lambda: ref.eva_fused_ref(g[None], a[None], b[None],
                                                   0.03, m, 0.9, True)[0],
            'eva_f_fused': lambda: ref.eva_f_fused_ref(g[None], a[None],
                                                       0.03, m, 0.9, True)[0],
        }
    else:
        kw = dict(block_in=bm, block_out=bn, interpret=interpret)
        table = {
            'bilinear': lambda: _bil.bilinear(g, a, b, **kw),
            'matvec': lambda: _mv.matvec(g, a, **kw),
            'rank1_update': lambda: _r1.rank1_update(g, a, b, coeff, scale,
                                                     **kw),
            'eva_fused': lambda: _fused.eva_fused_stacked(
                g[None], a[None], b[None], 0.03, m, 0.9, **kw)[0],
            'eva_f_fused': lambda: _fused.eva_f_fused_stacked(
                g[None], a[None], 0.03, m, 0.9, **kw)[0],
        }
    fn = table[op]
    jitted = jax.jit(fn)
    return lambda: jax.block_until_ready(jitted())


def _candidates(op: str, d_in: int, d_out: int, grid, impls):
    """Fixed-order (impl, block_in, block_out) list; fitted duplicates
    collapse to the first occurrence so the sweep stays deterministic."""
    seen, out = set(), []
    for impl in impls:
        if impl == 'xla':
            pairs = ((DEFAULT_BLOCK, DEFAULT_BLOCK),)
        else:
            pairs = grid
        for bi, bo in pairs:
            bm, bn = fit_block(d_in, bi), fit_block(d_out, bo)
            key = (impl, bm, bn)
            if key not in seen:
                seen.add(key)
                out.append(key)
    return out


def tune(shapes: Iterable[tuple[int, int]], *, ops=OPS,
         dtypes=('float32',), grid=DEFAULT_GRID, impls=('xla', 'pallas'),
         bench: Optional[Callable[[Callable[[], object]], float]] = None,
         backend_name: Optional[str] = None) -> dict:
    """Benchmark the candidate grid per (op, shape, dtype); return the
    cache dict (see module docstring).  ``bench(fn) -> µs`` is injectable
    (tests pin it for determinism); ``backend_name`` overrides the key
    prefix (the measurements still run on the current backend)."""
    bench = bench or default_bench
    be = backend_name or backend()
    interpret = backend() != 'tpu'
    entries = {}
    for d_in, d_out in shapes:
        for dtype in dtypes:
            dt = jnp.dtype(dtype)
            for op in ops:
                g, a, b, m = _operands(op, d_in, d_out, dt)
                best = None
                for impl, bm, bn in _candidates(op, d_in, d_out, grid,
                                                impls):
                    fn = _candidate_fn(op, impl, g, a, b, m, bm, bn,
                                       interpret)
                    us = float(bench(fn))
                    rank = (us, _IMPL_RANK[impl], bm, bn)
                    if best is None or rank < best[0]:
                        best = (rank, impl, bm, bn, us)
                _, impl, bm, bn, us = best
                entries[cache_key(op, d_in, d_out, dt, be)] = {
                    'impl': impl, 'block_in': bm, 'block_out': bn,
                    'us': round(us, 3)}
    return {'version': 1, 'backend': be, 'entries': entries}


def dumps(cache: dict) -> str:
    """Canonical byte-stable serialization of a tune() result."""
    return json.dumps(cache, sort_keys=True, indent=2) + '\n'


def write(cache: dict, path) -> Path:
    path = Path(path)
    path.parent.mkdir(parents=True, exist_ok=True)
    path.write_text(dumps(cache))
    return path


def merge(base: dict, new: dict) -> dict:
    """New entries win; version/backend from ``new``."""
    entries = dict(base.get('entries', {}))
    entries.update(new.get('entries', {}))
    out = dict(new)
    out['entries'] = entries
    return out
