"""Pallas kernels: fused Eva precondition -> update epilogue, one launch.

The composed bucket hot path costs ~4 gradient-sized HBM round trips after
the stats are ready: ``bilinear`` reads G, ``rank1_update`` reads G and
writes P, the momentum trace reads (m, P) and writes m, and the KL trust
region reads (m, G) again for the inner product.  These kernels do all of
it in ONE pass over G per bucket:

  phase 0  accumulate the reduction (aᵀGb for Eva / aᵀG for Eva-f) into a
           tiny VMEM-resident output, visiting tiles in exactly the same
           order as the standalone ``bilinear``/``matvec`` kernels — the
           reduction is bit-identical to the composed path;
  phase 1  re-stream G: compute the rank-one tile P = s·(G − c·abᵀ)
           (bit-identical to ``rank1_update``), optionally fold the
           heavy-ball momentum ``out = μ·m + P``, write the f32 output
           tile, and accumulate the epilogue partials
           ``aux = [⟨out,G⟩, ⟨out,out⟩, ⟨G,G⟩]`` per stack item.

The trust-region scale ν (Eq. 16) depends on the GLOBAL ⟨u,g⟩ across every
parameter, so it cannot be applied inside a per-bucket launch; the aux
partials make the remaining host-side tail a scalar reduction plus one
cheap elementwise scale.  ``aux``'s tile-major accumulation order differs
from the composed ``tree_vdot`` (which reduces each leaf fully first), so
the folded tail agrees with the composed chain to f32 reduction tolerance
(~1e-6 relative).

Both kernels use a two-phase grid ``(L, 2, ...)``: TPU grid iterations are
sequential per core, so every phase-0 tile of a stack item completes before
its phase-1 tiles read the reduction back.  G is read twice from HBM — the
reduction output is far too small to carry tile partials for a one-read
formulation — so the win over the composed path is the dropped P/m/vdot
round trips, not the G reads.

"Bit-identical" above holds per tile formula; across a whole launch the
in-kernel coeff division (``dot/denom``) can contract differently from the
host-side division of the composed path, so end-to-end agreement with the
composed chain is within 1 f32 ulp of the update scale (γ·|Δ| < 1e-6),
not universally bit-exact — see tests/test_fused.py.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.bilinear import _tile_bilinear
from repro.kernels.matvec import _tile_matvec
from repro.kernels.rank1_update import _rank1_tile
from repro.kernels.tiles import fit_block


def _epilogue_tile(g, p, m, mu, fold, o_ref, aux_ref):
    """Shared phase-1 tail: momentum fold + output write + aux partials."""
    out = mu * m + p if fold else p
    o_ref[0] = out
    aux_ref[0, 0] += jnp.sum(out * g)
    aux_ref[0, 1] += jnp.sum(out * out)
    aux_ref[0, 2] += jnp.sum(g * g)


def _make_eva_fused_kernel(fold: bool):
    def kernel(g_ref, a_ref, b_ref, sc_ref, m_ref, o_ref, dot_ref, aux_ref):
        ph = pl.program_id(1)
        i = pl.program_id(2)
        j = pl.program_id(3)

        @pl.when((ph == 0) & (i == 0) & (j == 0))
        def _init():
            dot_ref[...] = jnp.zeros_like(dot_ref)
            aux_ref[...] = jnp.zeros_like(aux_ref)

        g = g_ref[0].astype(jnp.float32)
        a = a_ref[0].astype(jnp.float32)
        b = b_ref[0].astype(jnp.float32)

        @pl.when(ph == 0)
        def _reduce():
            dot_ref[0, 0] += _tile_bilinear(g, a, b)

        @pl.when(ph == 1)
        def _emit():
            denom = sc_ref[0, 0]
            scale = sc_ref[0, 1]
            mu = sc_ref[0, 2]
            p = _rank1_tile(g, a, b, dot_ref[0, 0] / denom, scale)
            _epilogue_tile(g, p, m_ref[0], mu, fold, o_ref, aux_ref)

    return kernel


def _make_eva_f_fused_kernel(fold: bool):
    def kernel(g_ref, a_ref, sc_ref, m_ref, o_ref, u_ref, aux_ref):
        ph = pl.program_id(1)
        j = pl.program_id(2)
        i = pl.program_id(3)

        # u_ref's block follows j, so each column block zeroes at the start
        # of ITS reduction; aux_ref is one shared block per stack item
        @pl.when((ph == 0) & (i == 0))
        def _init_u():
            u_ref[...] = jnp.zeros_like(u_ref)

        @pl.when((ph == 0) & (j == 0) & (i == 0))
        def _init_aux():
            aux_ref[...] = jnp.zeros_like(aux_ref)

        g = g_ref[0].astype(jnp.float32)
        a = a_ref[0].astype(jnp.float32)

        @pl.when(ph == 0)
        def _reduce():
            u_ref[0] += _tile_matvec(g, a)

        @pl.when(ph == 1)
        def _emit():
            denom = sc_ref[0, 0]
            scale = sc_ref[0, 1]
            mu = sc_ref[0, 2]
            p = _rank1_tile(g, a, u_ref[0], 1.0 / denom, scale)
            _epilogue_tile(g, p, m_ref[0], mu, fold, o_ref, aux_ref)

    return kernel


def _pad_stacked(g, vecs_in, vecs_out, m, bm, bn):
    d_in, d_out = g.shape[1:]
    pad_in = (-d_in) % bm
    pad_out = (-d_out) % bn
    if pad_in or pad_out:
        g = jnp.pad(g, ((0, 0), (0, pad_in), (0, pad_out)))
        m = jnp.pad(m, ((0, 0), (0, pad_in), (0, pad_out)))
        vecs_in = [jnp.pad(v, ((0, 0), (0, pad_in))) for v in vecs_in]
        vecs_out = [jnp.pad(v, ((0, 0), (0, pad_out))) for v in vecs_out]
    return g, vecs_in, vecs_out, m, (d_in, d_out)


@functools.partial(jax.jit, static_argnames=('gamma', 'mu', 'fold_momentum',
                                             'block_in', 'block_out',
                                             'interpret'))
def eva_fused_stacked(g, a, b, gamma: float, m, mu: float,
                      fold_momentum: bool = True,
                      block_in: int = 512, block_out: int = 512,
                      interpret: bool = True):
    """Fused Eva (Eq. 13) + epilogue.  g: (L, d_in, d_out); a: (L, d_in);
    b: (L, d_out); m: (L, d_in, d_out) f32 momentum buffer.

    Returns ``(out, aux)``: out (L, d_in, d_out) f32 = μ·m + P (P only when
    ``fold_momentum=False``); aux (L, 3) f32 = [⟨out,g⟩, ⟨out,out⟩, ⟨g,g⟩].
    """
    L = g.shape[0]
    a32 = a.astype(jnp.float32)
    b32 = b.astype(jnp.float32)
    denom = gamma + jnp.sum(a32 * a32, -1) * jnp.sum(b32 * b32, -1)
    sc = jnp.stack([denom,
                    jnp.full((L,), 1.0 / gamma, jnp.float32),
                    jnp.full((L,), mu, jnp.float32)], axis=-1)
    bm = fit_block(g.shape[1], block_in)
    bn = fit_block(g.shape[2], block_out)
    g, (a32,), (b32,), m, (d_in, d_out) = _pad_stacked(
        g, [a32], [b32], m.astype(jnp.float32), bm, bn)
    mp, np_ = g.shape[1:]
    out, _, aux = pl.pallas_call(
        _make_eva_fused_kernel(fold_momentum),
        grid=(L, 2, mp // bm, np_ // bn),
        in_specs=[
            pl.BlockSpec((1, bm, bn), lambda l, p, i, j: (l, i, j)),
            pl.BlockSpec((1, bm), lambda l, p, i, j: (l, i)),
            pl.BlockSpec((1, bn), lambda l, p, i, j: (l, j)),
            pl.BlockSpec((1, 3), lambda l, p, i, j: (l, 0)),
            pl.BlockSpec((1, bm, bn), lambda l, p, i, j: (l, i, j)),
        ],
        out_specs=[
            pl.BlockSpec((1, bm, bn), lambda l, p, i, j: (l, i, j)),
            pl.BlockSpec((1, 1), lambda l, p, i, j: (l, 0)),
            pl.BlockSpec((1, 3), lambda l, p, i, j: (l, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((L, mp, np_), jnp.float32),
            jax.ShapeDtypeStruct((L, 1), jnp.float32),
            jax.ShapeDtypeStruct((L, 3), jnp.float32),
        ],
        interpret=interpret,
    )(g, a32, b32, sc, m)
    if (mp, np_) != (d_in, d_out):
        out = out[:, :d_in, :d_out]
    return out, aux


@functools.partial(jax.jit, static_argnames=('gamma', 'mu', 'fold_momentum',
                                             'block_in', 'block_out',
                                             'interpret'))
def eva_f_fused_stacked(g, a, gamma: float, m, mu: float,
                        fold_momentum: bool = True,
                        block_in: int = 512, block_out: int = 512,
                        interpret: bool = True):
    """Fused Eva-f (Eq. 21) + epilogue; same contract as
    :func:`eva_fused_stacked` with u = aᵀG accumulated in phase 0."""
    L = g.shape[0]
    a32 = a.astype(jnp.float32)
    denom = gamma + jnp.sum(a32 * a32, -1)
    sc = jnp.stack([denom,
                    jnp.full((L,), 1.0 / gamma, jnp.float32),
                    jnp.full((L,), mu, jnp.float32)], axis=-1)
    bm = fit_block(g.shape[1], block_in)
    bn = fit_block(g.shape[2], block_out)
    g, (a32,), _, m, (d_in, d_out) = _pad_stacked(
        g, [a32], [], m.astype(jnp.float32), bm, bn)
    mp, np_ = g.shape[1:]
    out, _, aux = pl.pallas_call(
        _make_eva_f_fused_kernel(fold_momentum),
        grid=(L, 2, np_ // bn, mp // bm),
        in_specs=[
            pl.BlockSpec((1, bm, bn), lambda l, p, j, i: (l, i, j)),
            pl.BlockSpec((1, bm), lambda l, p, j, i: (l, i)),
            pl.BlockSpec((1, 3), lambda l, p, j, i: (l, 0)),
            pl.BlockSpec((1, bm, bn), lambda l, p, j, i: (l, i, j)),
        ],
        out_specs=[
            pl.BlockSpec((1, bm, bn), lambda l, p, j, i: (l, i, j)),
            pl.BlockSpec((1, bn), lambda l, p, j, i: (l, j)),
            pl.BlockSpec((1, 3), lambda l, p, j, i: (l, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((L, mp, np_), jnp.float32),
            jax.ShapeDtypeStruct((L, np_), jnp.float32),
            jax.ShapeDtypeStruct((L, 3), jnp.float32),
        ],
        interpret=interpret,
    )(g, a32, sc, m)
    if (mp, np_) != (d_in, d_out):
        out = out[:, :d_in, :d_out]
    return out, aux
