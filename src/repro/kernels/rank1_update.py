"""Pallas TPU kernel: fused rank-one update  P = s·(G − c·a bᵀ).

This is the hot half of Eva's Sherman–Morrison step (Eq. 13): a purely
memory-bound pass over the gradient (read G once, write P once, ~3 flops per
element).  The roofline goal is streaming G at HBM bandwidth, so:

  * G is tiled (block_in × block_out) — 128-aligned blocks so the VPU lanes
    (8×128) are full and each tile sits in VMEM (default 512×512 f32 = 1 MiB
    per operand buffer, well under the ~16 MiB/core VMEM budget with double
    buffering);
  * the KV slices a[i-block], b[j-block] are tiny VMEM residents;
  * coeff/scale ride in as a (2,)-vector block broadcast to every tile
    (computed on the host side of the op — see ops.eva_precondition).

Grid iteration order is (d_in/bm, d_out/bn), sequential per TPU core;
the fused multiply-sub runs on the VPU while the next G tile streams in.
``rank1_update_stacked`` folds a leading stack of L problems into the grid
(one launch per parameter bucket); the body is purely elementwise, so
stacked and per-item results agree bit-for-bit.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.tiles import fit_block


def _rank1_tile(g, a, b, coeff, scale):
    return scale * (g - coeff * (a[:, None] * b[None, :]))


def _rank1_kernel(g_ref, a_ref, b_ref, cs_ref, o_ref):
    g = g_ref[...].astype(jnp.float32)
    a = a_ref[...].astype(jnp.float32)
    b = b_ref[...].astype(jnp.float32)
    o_ref[...] = _rank1_tile(g, a, b, cs_ref[0], cs_ref[1]).astype(o_ref.dtype)


def _rank1_stacked_kernel(g_ref, a_ref, b_ref, cs_ref, o_ref):
    g = g_ref[0].astype(jnp.float32)
    a = a_ref[0].astype(jnp.float32)
    b = b_ref[0].astype(jnp.float32)
    o_ref[0] = _rank1_tile(g, a, b, cs_ref[0, 0], cs_ref[0, 1]).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=('block_in', 'block_out', 'interpret'))
def rank1_update(g: jnp.ndarray, a: jnp.ndarray, b: jnp.ndarray,
                 coeff: jnp.ndarray, scale: jnp.ndarray,
                 block_in: int = 512, block_out: int = 512,
                 interpret: bool = True) -> jnp.ndarray:
    """P = scale·(G − coeff·a bᵀ).  g: (d_in, d_out); a: (d_in,); b: (d_out,).

    Shapes not divisible by the block are padded (the pad region computes
    garbage that is sliced off — cheaper than ragged BlockSpecs).
    """
    d_in, d_out = g.shape
    bm, bn = fit_block(d_in, block_in), fit_block(d_out, block_out)
    pad_in = (-d_in) % bm
    pad_out = (-d_out) % bn
    if pad_in or pad_out:
        g = jnp.pad(g, ((0, pad_in), (0, pad_out)))
        a = jnp.pad(a, (0, pad_in))
        b = jnp.pad(b, (0, pad_out))
    m, n = g.shape
    cs = jnp.stack([jnp.asarray(coeff, jnp.float32),
                    jnp.asarray(scale, jnp.float32)])
    out = pl.pallas_call(
        _rank1_kernel,
        grid=(m // bm, n // bn),
        in_specs=[
            pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
            pl.BlockSpec((bm,), lambda i, j: (i,)),
            pl.BlockSpec((bn,), lambda i, j: (j,)),
            pl.BlockSpec((2,), lambda i, j: (0,)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), g.dtype),
        interpret=interpret,
    )(g, a.astype(jnp.float32), b.astype(jnp.float32), cs)
    if pad_in or pad_out:
        out = out[:d_in, :d_out]
    return out


@functools.partial(jax.jit, static_argnames=('block_in', 'block_out', 'interpret'))
def rank1_update_stacked(g: jnp.ndarray, a: jnp.ndarray, b: jnp.ndarray,
                         coeff: jnp.ndarray, scale: jnp.ndarray,
                         block_in: int = 512, block_out: int = 512,
                         interpret: bool = True) -> jnp.ndarray:
    """Stacked P = scale·(G − coeff·a bᵀ); one launch for the whole stack.

    g: (L, d_in, d_out); a: (L, d_in); b: (L, d_out); coeff/scale: (L,).
    """
    L, d_in, d_out = g.shape
    bm, bn = fit_block(d_in, block_in), fit_block(d_out, block_out)
    pad_in = (-d_in) % bm
    pad_out = (-d_out) % bn
    if pad_in or pad_out:
        g = jnp.pad(g, ((0, 0), (0, pad_in), (0, pad_out)))
        a = jnp.pad(a, ((0, 0), (0, pad_in)))
        b = jnp.pad(b, ((0, 0), (0, pad_out)))
    m, n = g.shape[1:]
    cs = jnp.stack([jnp.asarray(coeff, jnp.float32),
                    jnp.asarray(scale, jnp.float32)], axis=-1)   # (L, 2)
    out = pl.pallas_call(
        _rank1_stacked_kernel,
        grid=(L, m // bm, n // bn),
        in_specs=[
            pl.BlockSpec((1, bm, bn), lambda l, i, j: (l, i, j)),
            pl.BlockSpec((1, bm), lambda l, i, j: (l, i)),
            pl.BlockSpec((1, bn), lambda l, i, j: (l, j)),
            pl.BlockSpec((1, 2), lambda l, i, j: (l, 0)),
        ],
        out_specs=pl.BlockSpec((1, bm, bn), lambda l, i, j: (l, i, j)),
        out_shape=jax.ShapeDtypeStruct((L, m, n), g.dtype),
        interpret=interpret,
    )(g, a.astype(jnp.float32), b.astype(jnp.float32), cs)
    if pad_in or pad_out:
        out = out[:, :d_in, :d_out]
    return out
