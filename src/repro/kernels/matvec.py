"""Pallas TPU kernel: tiled vector–matrix product  u = aᵀ G.

Used by Eva-f (Eq. 21: u = āᵀG) and by the bilinear form (Eq. 13's
b̄ᵀGā = u·b̄).  Memory-bound: each G tile is read once; partial products
accumulate in the f32 VMEM output block across the reduction grid axis
(TPU grid iterations are sequential, so the j-major accumulation is safe).

Tiles are 128-aligned for the 8×128 VPU; the (bm × bn) G tile multiplies a
(bm,) a-slice and accumulates into a (bn,) output slice.  The tile product
is an elementwise multiply + axis reduction (not ``a @ g``) so the lowering
— and therefore the accumulation order — is identical inside and outside
grid loops; this is what lets ``matvec_stacked`` (stack folded into the
leading grid axis, one launch per parameter bucket) match per-item calls
bit-for-bit.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.tiles import fit_block


def _tile_matvec(g, a):
    """(bm, bn) tile × (bm,) slice -> (bn,) partial products, f32."""
    return jnp.sum(a[:, None] * g, axis=0)


def _matvec_kernel(g_ref, a_ref, o_ref):
    i = pl.program_id(1)  # reduction index (d_in blocks)

    @pl.when(i == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    g = g_ref[...].astype(jnp.float32)
    a = a_ref[...].astype(jnp.float32)
    o_ref[...] += _tile_matvec(g, a)


def _matvec_stacked_kernel(g_ref, a_ref, o_ref):
    i = pl.program_id(2)  # reduction index (d_in blocks)

    @pl.when(i == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    g = g_ref[0].astype(jnp.float32)
    a = a_ref[0].astype(jnp.float32)
    o_ref[0] += _tile_matvec(g, a)


@functools.partial(jax.jit, static_argnames=('block_in', 'block_out', 'interpret'))
def matvec(g: jnp.ndarray, a: jnp.ndarray, block_in: int = 512,
           block_out: int = 512, interpret: bool = True) -> jnp.ndarray:
    """u = aᵀ G.  g: (d_in, d_out); a: (d_in,) -> (d_out,) f32."""
    d_in, d_out = g.shape
    bm, bn = fit_block(d_in, block_in), fit_block(d_out, block_out)
    pad_in = (-d_in) % bm
    pad_out = (-d_out) % bn
    if pad_in or pad_out:
        g = jnp.pad(g, ((0, pad_in), (0, pad_out)))
        a = jnp.pad(a, (0, pad_in))
    m, n = g.shape
    out = pl.pallas_call(
        _matvec_kernel,
        # out-block-major order: j outer, i inner -> accumulate over i
        grid=(n // bn, m // bm),
        in_specs=[
            pl.BlockSpec((bm, bn), lambda j, i: (i, j)),
            pl.BlockSpec((bm,), lambda j, i: (i,)),
        ],
        out_specs=pl.BlockSpec((bn,), lambda j, i: (j,)),
        out_shape=jax.ShapeDtypeStruct((n,), jnp.float32),
        interpret=interpret,
    )(g, a.astype(jnp.float32))
    return out[:d_out] if pad_out else out


def _matvec_cols_kernel(g_ref, a_ref, o_ref):
    i = pl.program_id(2)  # reduction index (band-row blocks)

    @pl.when(i == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    g = g_ref[...].astype(jnp.float32)
    a = a_ref[0].astype(jnp.float32)
    o_ref[0] += _tile_matvec(g, a)


@functools.partial(jax.jit, static_argnames=('block_in', 'block_out', 'interpret'))
def matvec_cols(g: jnp.ndarray, a: jnp.ndarray, block_in: int = 512,
                block_out: int = 512, interpret: bool = True) -> jnp.ndarray:
    """Column-blocked partial matvec  U_w = A_w G_w  for factor sharding.

    ``g``: (m, n) — one worker's contiguous row band of a symmetric (n, n)
    factor B (m = band rows; symmetry makes the row band the transposed
    column block, so the band partial is the column-block partial).
    ``a``: (R, m) — the matching owned columns of R stacked vectors.
    Returns (R, n) f32 *partials*: full output width, 1/W of the FLOPs;
    summing the partials over all bands (one zero-padded psum) reconstructs
    ``A B`` exactly — zero pad rows contribute zero.

    Same tile product as :func:`matvec` (elementwise multiply + axis-0
    reduction), so per-band partials summed on the host match the unsharded
    kernel bit-for-bit in f32 accumulation order per tile.
    """
    R, m = a.shape
    m_g, n = g.shape
    assert m == m_g, (a.shape, g.shape)
    bm, bn = fit_block(m, block_in), fit_block(n, block_out)
    pad_m = (-m) % bm
    pad_n = (-n) % bn
    if pad_m or pad_n:
        g = jnp.pad(g, ((0, pad_m), (0, pad_n)))
        a = jnp.pad(a, ((0, 0), (0, pad_m)))
    mp, np_ = g.shape
    out = pl.pallas_call(
        _matvec_cols_kernel,
        # vectors ride the leading grid axis; j outer, i inner accumulation
        grid=(R, np_ // bn, mp // bm),
        in_specs=[
            pl.BlockSpec((bm, bn), lambda r, j, i: (i, j)),
            pl.BlockSpec((1, bm), lambda r, j, i: (r, i)),
        ],
        out_specs=pl.BlockSpec((1, bn), lambda r, j, i: (r, j)),
        out_shape=jax.ShapeDtypeStruct((R, np_), jnp.float32),
        interpret=interpret,
    )(g, a.astype(jnp.float32))
    return out[:, :n] if pad_n else out


def _matvec_cols_stacked_kernel(g_ref, a_ref, o_ref):
    i = pl.program_id(3)  # reduction index (band-row blocks)

    @pl.when(i == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    g = g_ref[0].astype(jnp.float32)
    a = a_ref[0, 0].astype(jnp.float32)
    o_ref[0, 0] += _tile_matvec(g, a)


@functools.partial(jax.jit, static_argnames=('block_in', 'block_out', 'interpret'))
def matvec_cols_stacked(g: jnp.ndarray, a: jnp.ndarray, block_in: int = 512,
                        block_out: int = 512,
                        interpret: bool = True) -> jnp.ndarray:
    """Stacked :func:`matvec_cols`: one launch per parameter bucket.

    ``g``: (L, m, n) row bands of L factors; ``a``: (L, R, m) owned columns
    of R vectors per factor -> (L, R, n) f32 partials.  The factor stack
    rides the leading grid axis exactly like :func:`matvec_stacked`."""
    L, R, m = a.shape
    Lg, m_g, n = g.shape
    assert (L, m) == (Lg, m_g), (a.shape, g.shape)
    bm, bn = fit_block(m, block_in), fit_block(n, block_out)
    pad_m = (-m) % bm
    pad_n = (-n) % bn
    if pad_m or pad_n:
        g = jnp.pad(g, ((0, 0), (0, pad_m), (0, pad_n)))
        a = jnp.pad(a, ((0, 0), (0, 0), (0, pad_m)))
    mp, np_ = g.shape[1:]
    out = pl.pallas_call(
        _matvec_cols_stacked_kernel,
        grid=(L, R, np_ // bn, mp // bm),
        in_specs=[
            pl.BlockSpec((1, bm, bn), lambda l, r, j, i: (l, i, j)),
            pl.BlockSpec((1, 1, bm), lambda l, r, j, i: (l, r, i)),
        ],
        out_specs=pl.BlockSpec((1, 1, bn), lambda l, r, j, i: (l, r, j)),
        out_shape=jax.ShapeDtypeStruct((L, R, np_), jnp.float32),
        interpret=interpret,
    )(g, a.astype(jnp.float32))
    return out[:, :, :n] if pad_n else out


@functools.partial(jax.jit, static_argnames=('block_in', 'block_out', 'interpret'))
def matvec_stacked(g: jnp.ndarray, a: jnp.ndarray, block_in: int = 512,
                   block_out: int = 512, interpret: bool = True) -> jnp.ndarray:
    """Stacked u = aᵀ G.  g: (L, d_in, d_out); a: (L, d_in) -> (L, d_out)
    f32.  One launch; the stack rides the leading grid axis."""
    L, d_in, d_out = g.shape
    bm, bn = fit_block(d_in, block_in), fit_block(d_out, block_out)
    pad_in = (-d_in) % bm
    pad_out = (-d_out) % bn
    if pad_in or pad_out:
        g = jnp.pad(g, ((0, 0), (0, pad_in), (0, pad_out)))
        a = jnp.pad(a, ((0, 0), (0, pad_in)))
    m, n = g.shape[1:]
    out = pl.pallas_call(
        _matvec_stacked_kernel,
        grid=(L, n // bn, m // bm),
        in_specs=[
            pl.BlockSpec((1, bm, bn), lambda l, j, i: (l, i, j)),
            pl.BlockSpec((1, bm), lambda l, j, i: (l, i)),
        ],
        out_specs=pl.BlockSpec((1, bn), lambda l, j, i: (l, j)),
        out_shape=jax.ShapeDtypeStruct((L, n), jnp.float32),
        interpret=interpret,
    )(g, a.astype(jnp.float32))
    return out[:, :d_out] if pad_out else out
