"""Pure-jnp oracles for the Pallas kernels (assert_allclose targets).

Layouts match ``repro.core.precondition``: g (d_in, d_out), a (d_in,),
b (d_out,).
"""
from __future__ import annotations

import jax.numpy as jnp


def matvec_ref(g: jnp.ndarray, a: jnp.ndarray) -> jnp.ndarray:
    """u = aᵀ G — contraction over d_in.  (d_in, d_out),(d_in,) -> (d_out,)"""
    return jnp.einsum('io,i->o', g.astype(jnp.float32), a.astype(jnp.float32))


def bilinear_ref(g: jnp.ndarray, a: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    """aᵀ G b (scalar)."""
    return jnp.einsum('io,i,o->', g.astype(jnp.float32),
                      a.astype(jnp.float32), b.astype(jnp.float32))


def rank1_update_ref(g: jnp.ndarray, a: jnp.ndarray, b: jnp.ndarray,
                     coeff, scale) -> jnp.ndarray:
    """P = scale · (G − coeff · a bᵀ)."""
    g32 = g.astype(jnp.float32)
    out = scale * (g32 - coeff * (a.astype(jnp.float32)[:, None] *
                                  b.astype(jnp.float32)[None, :]))
    return out.astype(g.dtype)


def eva_precondition_ref(g, a, b, gamma: float) -> jnp.ndarray:
    """Full fused Eva preconditioning (Eq. 13), the composition target."""
    dot = bilinear_ref(g, a, b)
    a32, b32 = a.astype(jnp.float32), b.astype(jnp.float32)
    denom = gamma + jnp.sum(a32 * a32) * jnp.sum(b32 * b32)
    return rank1_update_ref(g, a, b, dot / denom, 1.0 / gamma)


def eva_f_precondition_ref(g, a, gamma: float) -> jnp.ndarray:
    """Eva-f (Eq. 21): P = (G − a (aᵀG) / (γ+‖a‖²)) / γ."""
    u = matvec_ref(g, a)
    a32 = a.astype(jnp.float32)
    denom = gamma + jnp.sum(a32 * a32)
    g32 = g.astype(jnp.float32)
    return ((g32 - (a32[:, None] * u[None, :]) / denom) / gamma).astype(g.dtype)
