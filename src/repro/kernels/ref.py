"""Pure-jnp oracles for the Pallas kernels — and the XLA dispatch path.

Historically these were only ``assert_allclose`` targets for the kernel
tests; the dispatch layer (``kernels/dispatch.py``) now routes production
calls here when ``impl='xla'`` (the CPU default), so every op accepts both
the single-matrix layout and the stacked/broadcast layout via ellipsis
einsums.  Layouts match ``repro.core.precondition``: g (..., d_in, d_out),
a (..., d_in), b (..., d_out).  Reductions are f32 regardless of input
dtype, like the kernels.
"""
from __future__ import annotations

import jax.numpy as jnp


def matvec_ref(g: jnp.ndarray, a: jnp.ndarray) -> jnp.ndarray:
    """u = aᵀ G — contraction over d_in.  (..., d_in, d_out),(..., d_in)
    -> (..., d_out) f32."""
    return jnp.einsum('...io,...i->...o', g.astype(jnp.float32),
                      a.astype(jnp.float32))


def matvec_cols_ref(g: jnp.ndarray, a: jnp.ndarray) -> jnp.ndarray:
    """Column-blocked partial matvec U_w = A_w G_w (factor sharding).

    g (..., m, n) row band; a (..., R, m) owned columns -> (..., R, n) f32
    partials (see ``kernels/matvec.py::matvec_cols``)."""
    return jnp.einsum('...mn,...rm->...rn', g.astype(jnp.float32),
                      a.astype(jnp.float32))


def bilinear_ref(g: jnp.ndarray, a: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    """aᵀ G b — scalar per leading index.  -> (...) f32."""
    return jnp.einsum('...io,...i,...o->...', g.astype(jnp.float32),
                      a.astype(jnp.float32), b.astype(jnp.float32))


def rank1_update_ref(g: jnp.ndarray, a: jnp.ndarray, b: jnp.ndarray,
                     coeff, scale) -> jnp.ndarray:
    """P = scale · (G − coeff · a bᵀ); coeff/scale scalar or (...,)."""
    g32 = g.astype(jnp.float32)
    coeff = jnp.asarray(coeff, jnp.float32)[..., None, None]
    scale = jnp.asarray(scale, jnp.float32)[..., None, None]
    out = scale * (g32 - coeff * (a.astype(jnp.float32)[..., :, None] *
                                  b.astype(jnp.float32)[..., None, :]))
    return out.astype(g.dtype)


def eva_precondition_ref(g, a, b, gamma: float) -> jnp.ndarray:
    """Full fused Eva preconditioning (Eq. 13), the composition target."""
    dot = bilinear_ref(g, a, b)
    a32, b32 = a.astype(jnp.float32), b.astype(jnp.float32)
    denom = gamma + jnp.sum(a32 * a32, -1) * jnp.sum(b32 * b32, -1)
    return rank1_update_ref(g, a, b, dot / denom,
                            jnp.full_like(denom, 1.0 / gamma))


def eva_f_precondition_ref(g, a, gamma: float) -> jnp.ndarray:
    """Eva-f (Eq. 21): P = (G − a (aᵀG) / (γ+‖a‖²)) / γ."""
    u = matvec_ref(g, a)
    a32 = a.astype(jnp.float32)
    denom = gamma + jnp.sum(a32 * a32, -1)
    g32 = g.astype(jnp.float32)
    outer = a32[..., :, None] * u[..., None, :]
    return ((g32 - outer / denom[..., None, None]) / gamma).astype(g.dtype)


def _fused_epilogue(g32, p, m, mu, fold_momentum):
    out = mu * m.astype(jnp.float32) + p if fold_momentum else p
    aux = jnp.stack([jnp.sum(out * g32, (-2, -1)),
                     jnp.sum(out * out, (-2, -1)),
                     jnp.sum(g32 * g32, (-2, -1))], axis=-1)
    return out, aux


def eva_fused_ref(g, a, b, gamma: float, m, mu: float,
                  fold_momentum: bool = True):
    """XLA twin of ``kernels/fused.py::eva_fused_stacked``.

    Returns ``(out, aux)``: out (..., d_in, d_out) f32 = μ·m + P (or P when
    ``fold_momentum`` is off); aux (..., 3) f32 = [⟨out,g⟩, ⟨out,out⟩,
    ⟨g,g⟩] per leading index.
    """
    g32 = g.astype(jnp.float32)
    a32, b32 = a.astype(jnp.float32), b.astype(jnp.float32)
    dot = bilinear_ref(g, a, b)
    denom = gamma + jnp.sum(a32 * a32, -1) * jnp.sum(b32 * b32, -1)
    coeff = (dot / denom)[..., None, None]
    # multiply by the precomputed reciprocal, matching _rank1_tile's
    # scale operand bit-for-bit (x/gamma rounds differently)
    p = (1.0 / gamma) * (g32 - coeff * (a32[..., :, None] * b32[..., None, :]))
    return _fused_epilogue(g32, p, m, mu, fold_momentum)


def eva_f_fused_ref(g, a, gamma: float, m, mu: float,
                    fold_momentum: bool = True):
    """XLA twin of ``kernels/fused.py::eva_f_fused_stacked``; same contract
    as :func:`eva_fused_ref` with u = aᵀG."""
    g32 = g.astype(jnp.float32)
    a32 = a.astype(jnp.float32)
    u = matvec_ref(g, a)
    coeff = (1.0 / (gamma + jnp.sum(a32 * a32, -1)))[..., None, None]
    p = (1.0 / gamma) * (g32 - coeff * (a32[..., :, None] * u[..., None, :]))
    return _fused_epilogue(g32, p, m, mu, fold_momentum)
