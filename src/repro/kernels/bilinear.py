"""Pallas TPU kernel: bilinear form  d = aᵀ G b  (Eq. 13 numerator).

Single pass over G: each (bm × bn) tile contracts against its a- and
b-slices and accumulates into a (1,1) f32 VMEM scalar across the whole
sequential grid.  Combined with ``rank1_update`` this gives the two-pass
fused Eva step: 2 reads + 1 write of G total (vs ≥4 G-sized transfers for
the unfused jnp composition).

``bilinear_stacked`` folds a leading stack of L independent (G, a, b)
problems into the grid as its leading axis — one kernel launch for a whole
parameter bucket (layers of identical shape, see ``core/bucketing``).  The
per-tile program and the (i, j) iteration order within each stack entry are
identical to the unstacked kernel, so stacked and per-item results agree
bit-for-bit.  The tile contraction is written as an elementwise
multiply + reduction (not ``jnp.dot``): reduction lowering is stable across
grid-loop contexts, where dot_general on CPU may pick different blocked
algorithms inside vs outside a loop and break that bit-equality.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.tiles import fit_block


def _tile_bilinear(g, a, b):
    """Contract one (bm, bn) tile against its a/b slices -> scalar f32."""
    return jnp.sum((a[:, None] * g) * b[None, :])


def _bilinear_kernel(g_ref, a_ref, b_ref, o_ref):
    i = pl.program_id(0)
    j = pl.program_id(1)

    @pl.when((i == 0) & (j == 0))
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    g = g_ref[...].astype(jnp.float32)
    a = a_ref[...].astype(jnp.float32)
    b = b_ref[...].astype(jnp.float32)
    o_ref[0, 0] += _tile_bilinear(g, a, b)


def _bilinear_stacked_kernel(g_ref, a_ref, b_ref, o_ref):
    i = pl.program_id(1)
    j = pl.program_id(2)

    @pl.when((i == 0) & (j == 0))
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    g = g_ref[0].astype(jnp.float32)
    a = a_ref[0].astype(jnp.float32)
    b = b_ref[0].astype(jnp.float32)
    o_ref[0, 0, 0] += _tile_bilinear(g, a, b)


def _pad2(g, a, b, bm, bn):
    d_in, d_out = g.shape[-2:]
    pad_in = (-d_in) % bm
    pad_out = (-d_out) % bn
    if pad_in or pad_out:
        lead = [(0, 0)] * (g.ndim - 2)
        g = jnp.pad(g, lead + [(0, pad_in), (0, pad_out)])
        a = jnp.pad(a, [(0, 0)] * (a.ndim - 1) + [(0, pad_in)])
        b = jnp.pad(b, [(0, 0)] * (b.ndim - 1) + [(0, pad_out)])
    return g, a, b


@functools.partial(jax.jit, static_argnames=('block_in', 'block_out', 'interpret'))
def bilinear(g: jnp.ndarray, a: jnp.ndarray, b: jnp.ndarray,
             block_in: int = 512, block_out: int = 512,
             interpret: bool = True) -> jnp.ndarray:
    """aᵀ G b -> () f32.  g: (d_in, d_out); a: (d_in,); b: (d_out,)."""
    d_in, d_out = g.shape
    bm, bn = fit_block(d_in, block_in), fit_block(d_out, block_out)
    g, a, b = _pad2(g, a, b, bm, bn)
    m, n = g.shape
    out = pl.pallas_call(
        _bilinear_kernel,
        grid=(m // bm, n // bn),
        in_specs=[
            pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
            pl.BlockSpec((bm,), lambda i, j: (i,)),
            pl.BlockSpec((bn,), lambda i, j: (j,)),
        ],
        out_specs=pl.BlockSpec((1, 1), lambda i, j: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((1, 1), jnp.float32),
        interpret=interpret,
    )(g, a.astype(jnp.float32), b.astype(jnp.float32))
    return out[0, 0]


@functools.partial(jax.jit, static_argnames=('block_in', 'block_out', 'interpret'))
def bilinear_stacked(g: jnp.ndarray, a: jnp.ndarray, b: jnp.ndarray,
                     block_in: int = 512, block_out: int = 512,
                     interpret: bool = True) -> jnp.ndarray:
    """Stacked aᵀ G b -> (L,) f32.  g: (L, d_in, d_out); a: (L, d_in);
    b: (L, d_out).  One launch; the stack rides the leading grid axis."""
    L, d_in, d_out = g.shape
    bm, bn = fit_block(d_in, block_in), fit_block(d_out, block_out)
    g, a, b = _pad2(g, a, b, bm, bn)
    m, n = g.shape[1:]
    out = pl.pallas_call(
        _bilinear_stacked_kernel,
        grid=(L, m // bm, n // bn),
        in_specs=[
            pl.BlockSpec((1, bm, bn), lambda l, i, j: (l, i, j)),
            pl.BlockSpec((1, bm), lambda l, i, j: (l, i)),
            pl.BlockSpec((1, bn), lambda l, i, j: (l, j)),
        ],
        out_specs=pl.BlockSpec((1, 1, 1), lambda l, i, j: (l, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((L, 1, 1), jnp.float32),
        interpret=interpret,
    )(g, a.astype(jnp.float32), b.astype(jnp.float32))
    return out[:, 0, 0]
