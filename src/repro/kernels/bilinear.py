"""Pallas TPU kernel: bilinear form  d = aᵀ G b  (Eq. 13 numerator).

Single pass over G: each (bm × bn) tile contracts against its a- and
b-slices and accumulates into a (1,1) f32 VMEM scalar across the whole
sequential grid.  Combined with ``rank1_update`` this gives the two-pass
fused Eva step: 2 reads + 1 write of G total (vs ≥4 G-sized transfers for
the unfused jnp composition).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _bilinear_kernel(g_ref, a_ref, b_ref, o_ref):
    i = pl.program_id(0)
    j = pl.program_id(1)

    @pl.when((i == 0) & (j == 0))
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    g = g_ref[...].astype(jnp.float32)
    a = a_ref[...].astype(jnp.float32)
    b = b_ref[...].astype(jnp.float32)
    o_ref[0, 0] += jnp.dot(a @ g, b)


@functools.partial(jax.jit, static_argnames=('block_in', 'block_out', 'interpret'))
def bilinear(g: jnp.ndarray, a: jnp.ndarray, b: jnp.ndarray,
             block_in: int = 512, block_out: int = 512,
             interpret: bool = True) -> jnp.ndarray:
    """aᵀ G b -> () f32.  g: (d_in, d_out); a: (d_in,); b: (d_out,)."""
    d_in, d_out = g.shape
    bm, bn = min(block_in, d_in), min(block_out, d_out)
    pad_in = (-d_in) % bm
    pad_out = (-d_out) % bn
    if pad_in or pad_out:
        g = jnp.pad(g, ((0, pad_in), (0, pad_out)))
        a = jnp.pad(a, (0, pad_in))
        b = jnp.pad(b, (0, pad_out))
    m, n = g.shape
    out = pl.pallas_call(
        _bilinear_kernel,
        grid=(m // bm, n // bn),
        in_specs=[
            pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
            pl.BlockSpec((bm,), lambda i, j: (i,)),
            pl.BlockSpec((bn,), lambda i, j: (j,)),
        ],
        out_specs=pl.BlockSpec((1, 1), lambda i, j: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((1, 1), jnp.float32),
        interpret=interpret,
    )(g, a.astype(jnp.float32), b.astype(jnp.float32))
    return out[0, 0]
