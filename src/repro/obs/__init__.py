"""Unified telemetry: typed event records, phase spans, run analysis.

``events``  — versioned record schemas + the JSONL ``Recorder`` (owns the
              run-scoped comm-counter context).
``spans``   — host-timed phase spans with ``block_until_ready`` fences,
              the straggler watchdog, profile-mode samplers.
``report``  — breakdown / A-vs-B diff / validation CLI core
              (``scripts/obs_report.py``).
"""
from repro.obs.events import (SCHEMA_VERSION, SCHEMAS, Recorder, SchemaError,
                              infer_event, step_fields, validate_record)
from repro.obs.spans import (SpanTracker, StragglerWatchdog,
                             compiled_fn_costs, device_bytes_in_use,
                             hlo_costs, live_buffer_mb)

__all__ = [
    'SCHEMA_VERSION', 'SCHEMAS', 'Recorder', 'SchemaError', 'infer_event',
    'step_fields', 'validate_record',
    'SpanTracker', 'StragglerWatchdog', 'compiled_fn_costs',
    'device_bytes_in_use', 'hlo_costs', 'live_buffer_mb',
]
