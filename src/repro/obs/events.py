"""Versioned, schema-typed telemetry records + the JSONL ``Recorder``.

One event model for everything the runtime emits — trainer step records,
refresh/ownership/comm-exchange one-offs, straggler flags, phase spans and
profile samples — replacing the hand-rolled dicts that used to be scattered
across ``train/trainer.py``, ``comm/metrics.py`` and the benchmarks.

Design rules:

* Every record is one JSON object per line with an ``event`` type and a
  schema version ``v`` (``SCHEMA_VERSION``).  Everything else is typed by
  ``SCHEMAS[event]``; per-site key families use a trailing ``/*``
  (``pipeline_lag/stats/kfac``).  Unknown top-level keys are validation
  errors — the emitters are all in-repo, so strictness catches typos
  instead of letting them rot in artifacts.
* Records are **bit-compatible supersets** of the pre-obs trainer fields:
  old parsers that read ``step``/``loss``/``step_time_s`` keep working,
  and the loader treats envelope-less step-shaped dicts as legacy ``step``
  records (pre-v1 files stay readable).
* Versioning policy: bump ``SCHEMA_VERSION`` whenever a field changes
  name, unit, or type, or a required field is added — adding an optional
  field is NOT a bump (supersets are the compatibility contract).  Note
  the bump in CHANGES.md (see the conventions block there).
* The scheduler-owned step fields come from the producing modules'
  ``METRIC_FIELDS`` declarations (``schedule/runtime.py``,
  ``schedule/pipeline.py``) so the schema cannot drift from the code that
  emits them.

The ``Recorder`` owns the sink AND the run-scoped comm-counter context
(``repro.comm.metrics.scope``): while a recorder is open, every exchange
site traced belongs to *its* run — this replaces the trainer's old
trace-count-baselining workaround over the process-global table.
"""
from __future__ import annotations

import dataclasses
import json
from pathlib import Path
from typing import Any, Optional

from repro.comm import metrics as comm_metrics
from repro.core import factor_sharded as _fsh
from repro.schedule import pipeline as _pipemod
from repro.schedule import runtime as _schedrt

SCHEMA_VERSION = 1

_NUM = (int, float)
_INT = (int,)
_STR = (str,)
_DICT = (dict,)


@dataclasses.dataclass(frozen=True)
class Field:
    """One schema field: accepted JSON types, requiredness, display unit."""
    types: tuple
    required: bool = False
    unit: str = ''


def _declared(module) -> dict[str, 'Field']:
    """METRIC_FIELDS of a producer module -> schema fields."""
    kinds = {'int': _INT, 'num': _NUM}
    return {name: Field(kinds[kind], unit=unit)
            for name, (kind, unit) in module.METRIC_FIELDS.items()}


SCHEMAS: dict[str, dict[str, Field]] = {
    # one per logged training step (superset of the pre-obs record)
    'step': {
        'step': Field(_INT, required=True, unit='index'),
        'loss': Field(_NUM, required=True),
        'grad_norm': Field(_NUM),
        'step_time_s': Field(_NUM, unit='s'),
        'exchanged_mb_cum': Field(_NUM, unit='MiB'),
        # kernel dispatch telemetry (optional fields: no version bump) —
        # the requested impl and the latest per-op resolved tile choices
        # (kernels.dispatch.choices_snapshot)
        'kernel_impl': Field(_STR, unit="requested impl ('auto'|...)"),
        'kernel_tiles': Field(_DICT, unit='op -> resolved impl+tiles'),
        **_declared(_schedrt),
        **_declared(_pipemod),
        **_declared(_fsh),
    },
    # one per realized curvature refresh (derived from the cumulative
    # counter crossing between steps)
    'refresh': {
        'step': Field(_INT, required=True, unit='index'),
        'refreshes': Field(_INT, required=True, unit='cumulative refreshes'),
        'step_time_s': Field(_NUM, unit='s'),
    },
    # startup one-off: per-bucket refresh-owner map
    'refresh_ownership': {
        'world': Field(_INT, required=True, unit='workers'),
        'owners': Field(_DICT, required=True,
                        unit='bucket -> per-worker slice counts'),
    },
    # elastic resize one-off: a checkpoint written at world_from resumed
    # at world_to (or a live between-steps resize) — emitted by
    # Trainer.fit_elastic after schedule.reshard.reshard_state (optional
    # event type: no version bump)
    'reshard': {
        'world_from': Field(_INT, required=True, unit='workers'),
        'world_to': Field(_INT, required=True, unit='workers'),
        'pipeline': Field(_STR, required=True,
                          unit="in-flight buffers: 'drained'|'kept'|'none'"),
        'source': Field(_STR, required=True,
                        unit="'checkpoint' (restore) | 'live' (between steps)"),
        'step': Field(_INT, unit='index'),
        'slices_total': Field(_INT, unit='owned refresh slices'),
        'slices_moved': Field(_INT, unit='slices with a new owner'),
    },
    # post-trace one-off: per-call-site logical exchange bytes (site dicts
    # are validated by _validate_site; codec extras stay open)
    'comm_exchange': {
        'sites': Field(_DICT, required=True),
    },
    # straggler watchdog flag
    'straggler': {
        'step': Field(_INT, required=True, unit='index'),
        'step_time_s': Field(_NUM, required=True, unit='s'),
        'median_s': Field(_NUM, required=True, unit='s'),
        'factor': Field(_NUM, unit='trigger threshold x median'),
    },
    # one host-timed phase span (block_until_ready-fenced)
    'span': {
        'name': Field(_STR, required=True),
        'ms': Field(_NUM, required=True, unit='ms'),
        'step': Field(_INT, unit='index'),
        'seq': Field(_INT, unit='emission order'),
        'depth': Field(_INT, unit='nesting depth'),
        'parent': Field(_STR + (type(None),)),
    },
    # profile-mode sample: live buffers + one-shot HLO costs per fn
    'profile': {
        'step': Field(_INT, required=True, unit='index'),
        'live_buffer_mb': Field(_NUM, unit='MiB'),
        'device_bytes_in_use': Field(_INT, unit='bytes'),
        'fns': Field(_DICT, unit='fn -> HLO cost/overlap summary'),
    },
    # one BENCH_*.json row (benchmarks/common.write_json)
    'bench': {
        'name': Field(_STR, required=True),
        'us_per_call': Field(_NUM, required=True, unit='us'),
        'derived': Field(_STR),
        'fields': Field(_DICT),
    },
}

_SITE_FIELDS = {
    'bytes_per_call': Field(_INT, required=True, unit='B'),
    'codec': Field(_STR, required=True),
    'mode': Field(_STR, required=True),
    'traces': Field(_INT),
    'world': Field(_INT),
    'pods': Field((list, tuple), unit='(n_pods, pod_size)'),
    'ici_bytes': Field(_INT, unit='B'),
    'dcn_bytes': Field(_INT, unit='B'),
    # sharded-factor apply sites (factor/*) — optional, no version bump
    'solve_iters': Field(_INT, unit='iterations per solve'),
    'factor_shard_bytes': Field(_INT, unit='B of factor band per worker'),
}


class SchemaError(ValueError):
    pass


def _check(value, fld: Field, where: str) -> list[str]:
    # bool is an int subclass in Python; never a valid numeric field here
    if isinstance(value, bool) or not isinstance(value, fld.types):
        return [f'{where}: expected {"/".join(t.__name__ for t in fld.types)}'
                f', got {type(value).__name__} ({value!r})']
    return []


def _validate_site(site: str, rec: Any) -> list[str]:
    where = f'comm_exchange.sites[{site!r}]'
    if not isinstance(rec, dict):
        return [f'{where}: expected object, got {type(rec).__name__}']
    errs = []
    for name, fld in _SITE_FIELDS.items():
        if name in rec:
            errs += _check(rec[name], fld, f'{where}.{name}')
        elif fld.required:
            errs.append(f'{where}: missing required field {name!r}')
    return errs  # codec/topology extras beyond _SITE_FIELDS stay open


def infer_event(rec: dict) -> Optional[str]:
    """Event type of a record; legacy envelope-less step dicts count."""
    ev = rec.get('event')
    if ev is None and 'step' in rec and 'loss' in rec:
        return 'step'
    return ev


def validate_record(rec: Any) -> list[str]:
    """All schema violations of one record ([] = valid)."""
    if not isinstance(rec, dict):
        return [f'record is not an object: {rec!r}']
    ev = infer_event(rec)
    if ev is None:
        return [f'missing event type (keys: {sorted(rec)[:6]})']
    if ev not in SCHEMAS:
        return [f'unknown event type {ev!r} (have {sorted(SCHEMAS)})']
    errs: list[str] = []
    v = rec.get('v')
    if v is not None and v != SCHEMA_VERSION:
        errs.append(f'{ev}: schema version {v} != {SCHEMA_VERSION}')
    schema = SCHEMAS[ev]
    for name, fld in schema.items():
        if fld.required and name not in rec:
            errs.append(f'{ev}: missing required field {name!r}')
    for key, value in rec.items():
        if key in ('event', 'v'):
            continue
        fld = schema.get(key)
        if fld is None and '/' in key:
            fld = schema.get(key.split('/', 1)[0] + '/*')
        if fld is None:
            errs.append(f'{ev}: unknown field {key!r}')
            continue
        errs += _check(value, fld, f'{ev}.{key}')
    if ev == 'comm_exchange' and isinstance(rec.get('sites'), dict):
        for site, srec in rec['sites'].items():
            errs += _validate_site(site, srec)
    return errs


def step_fields(metrics: dict) -> dict:
    """Typed host-side step-record fields from the jitted step's metrics
    dict (the scheduler/pipeline scalars are traced arrays)."""
    out: dict[str, Any] = {}
    if 'refreshes' in metrics:
        out['refreshes'] = int(metrics['refreshes'])
        out['staleness'] = float(metrics['staleness'])
        out['refresh_since'] = int(metrics['refresh_since'])
    for key, value in metrics.items():
        if key.startswith('pipeline_lag'):
            out[key] = int(value)
    if 'factor_solve_iters' in metrics:
        out['factor_solve_iters'] = int(metrics['factor_solve_iters'])
        out['factor_shard_bytes'] = float(metrics['factor_shard_bytes'])
    return out


class Recorder:
    """JSONL sink + run-scoped comm-counter context.

    ``emit`` stamps the envelope (``event``, ``v``), validates against the
    schema (fail-fast — a malformed record is a bug at the emit site, not
    something to discover in the artifact), appends one line, and returns
    the record.  ``path=None`` keeps records in memory only (tests).
    """

    def __init__(self, path: Optional[Any] = None, validate: bool = True,
                 scope_comm: bool = True):
        self._f = Path(path).open('a') if path is not None else None
        self._validate = validate
        self._scope = comm_metrics.push_scope() if scope_comm else None
        self.records: list[dict] = []

    def emit(self, event: str, **fields: Any) -> dict:
        rec = {'event': event, 'v': SCHEMA_VERSION, **fields}
        if self._validate:
            errs = validate_record(rec)
            if errs:
                raise SchemaError('; '.join(errs))
        self.records.append(rec)
        if self._f is not None:
            self._f.write(json.dumps(rec) + '\n')
            self._f.flush()
        return rec

    def comm_sites(self) -> dict:
        """Exchange sites traced while THIS recorder was open (falls back
        to the process-global table when scoping was disabled)."""
        if self._scope is not None:
            return self._scope.snapshot()
        return comm_metrics.snapshot()

    def close(self) -> None:
        if self._scope is not None:
            comm_metrics.pop_scope(self._scope)
            self._scope = None
        if self._f is not None:
            self._f.close()
            self._f = None

    def __enter__(self) -> 'Recorder':
        return self

    def __exit__(self, *exc) -> None:
        self.close()
