"""Run analysis over telemetry artifacts (``scripts/obs_report.py``).

Loads one or more ``metrics.jsonl`` (trainer telemetry) and/or
``BENCH_*.json`` (benchmark rows) files, validates every record against the
versioned schema (``repro.obs.events``), and renders:

* a per-run breakdown — per-phase time (from spans when the run profiled,
  plus the refresh time derived differentially from refresh-firing vs
  cached steps), exchanged bytes per site with the ICI/DCN topology split,
  staleness/pipeline-lag, the refresh-owner map, and HLO profile costs;
* an A-vs-B diff with a regression gate: ``--max-regress PCT`` exits 2
  when any *gated* metric (mean step time, benchmark ``us_per_call`` rows)
  regressed by more than PCT percent — the CI perf-trajectory hook.

Exit codes: 0 ok · 1 schema-validation errors · 2 gated regression.

Phase-attribution notes (honest accounting, also in the README):
  * span times exist only for profiled runs; the first step's spans are
    dropped (compile);
  * ``refresh`` time is the firing-vs-cached step-time differential — it
    runs *inside* the precondition phase, so it is a sub-row, not an
    addend;
  * ``exchange`` is reported in logical bytes (exact, from trace-time
    counters); its wall time on a single host is ~0 (no live mesh axes →
    no collectives) and on a real mesh is visible via the profile record's
    blocking-collective counts.
"""
from __future__ import annotations

import argparse
import json
import statistics
from pathlib import Path
from typing import Any, Optional

from repro.obs import events


# ---------------------------------------------------------------------------
# Loading / validation


def load_records(path: str) -> list[dict]:
    """Records from a ``.jsonl`` telemetry file or a ``BENCH_*.json`` row
    list (rows are wrapped as ``bench`` events).  Unparseable lines become
    ``_parse_error`` records so validation can report them by line."""
    p = Path(path)
    text = p.read_text()
    if text.lstrip().startswith('['):
        rows = json.loads(text)
        return [row if isinstance(row, dict) and 'event' in row
                else {'event': 'bench', **row} if isinstance(row, dict)
                else {'_parse_error': f'non-object bench row {row!r}'}
                for row in rows]
    recs = []
    for lineno, line in enumerate(text.splitlines(), 1):
        if not line.strip():
            continue
        try:
            rec = json.loads(line)
            if not isinstance(rec, dict):
                rec = {'_parse_error': f'line {lineno}: not an object'}
        except json.JSONDecodeError as e:
            rec = {'_parse_error': f'line {lineno}: {e}'}
        recs.append(rec)
    return recs


def validate_records(records: list[dict]) -> list[str]:
    errs = []
    for i, rec in enumerate(records, 1):
        if '_parse_error' in rec:
            errs.append(f'record {i}: {rec["_parse_error"]}')
            continue
        errs += [f'record {i}: {e}' for e in events.validate_record(rec)]
    return errs


def _of(records: list[dict], event: str) -> list[dict]:
    return [r for r in records if events.infer_event(r) == event]


# ---------------------------------------------------------------------------
# Breakdown


def breakdown(records: list[dict]) -> dict:
    """Aggregate one run's records into the summary ``render`` prints."""
    bd: dict[str, Any] = {}
    steps = sorted(_of(records, 'step'), key=lambda r: r['step'])
    bd['n_step_records'] = len(steps)
    warm: list[dict] = []
    if steps:
        bd['step_range'] = (steps[0]['step'], steps[-1]['step'])
        bd['first_loss'] = float(steps[0]['loss'])
        bd['final_loss'] = float(steps[-1]['loss'])
        warm = [r for r in steps
                if r['step'] > steps[0]['step'] and 'step_time_s' in r]
        times = [float(r['step_time_s']) for r in warm]
        if times:
            bd['mean_step_ms'] = statistics.fmean(times) * 1e3
            bd['p50_step_ms'] = statistics.median(times) * 1e3
        stal = [float(r['staleness']) for r in steps if 'staleness' in r]
        if stal:
            bd['staleness'] = {'final': stal[-1], 'max': max(stal)}
        if 'pipeline_lag' in steps[-1]:
            bd['pipeline_lag'] = int(steps[-1]['pipeline_lag'])
        if 'exchanged_mb_cum' in steps[-1]:
            bd['exchanged_mb_cum'] = float(steps[-1]['exchanged_mb_cum'])

    spans = _of(records, 'span')
    if spans:
        first = min(r.get('step', 0) for r in spans)
        warm_spans = [r for r in spans if r.get('step', first) != first]
        warm_spans = warm_spans or spans  # single-step runs: keep something
        per_phase: dict[str, list[float]] = {}
        for r in warm_spans:
            per_phase.setdefault(r['name'], []).append(float(r['ms']))
        bd['phases'] = {
            name: {'count': len(ms), 'mean_ms': statistics.fmean(ms),
                   'total_ms': sum(ms)}
            for name, ms in per_phase.items()}

    # refresh: realized count + the firing-vs-cached step-time differential
    refresh: dict[str, Any] = {}
    refr = _of(records, 'refresh')
    firing_steps = {r['step'] for r in refr}
    if not firing_steps and len(steps) >= 2:
        for prev, cur in zip(steps, steps[1:]):
            if cur.get('refreshes', 0) > prev.get('refreshes', 0):
                firing_steps.add(cur['step'])
    if refr:
        refresh['count'] = len(refr)
    elif steps and 'refreshes' in steps[-1]:
        refresh['count'] = int(steps[-1]['refreshes'])
    if firing_steps and warm:
        fire = [float(r['step_time_s']) for r in warm
                if r['step'] in firing_steps]
        cached = [float(r['step_time_s']) for r in warm
                  if r['step'] not in firing_steps]
        if fire and cached:
            refresh['mean_firing_ms'] = statistics.fmean(fire) * 1e3
            refresh['mean_cached_ms'] = statistics.fmean(cached) * 1e3
            refresh['extra_ms_per_refresh'] = (refresh['mean_firing_ms']
                                               - refresh['mean_cached_ms'])
            refresh['amortized_ms_per_step'] = (
                refresh['extra_ms_per_refresh'] * len(fire) / len(warm))
    if refresh:
        bd['refresh'] = refresh

    comm = _of(records, 'comm_exchange')
    if comm:
        sites = comm[-1]['sites']
        step_b = sum(int(v['bytes_per_call']) for s, v in sites.items()
                     if not s.startswith('refresh/'))
        refresh_b = sum(int(v['bytes_per_call']) for s, v in sites.items()
                        if s.startswith('refresh/'))
        ici = sum(int(v.get('ici_bytes', 0)) for v in sites.values())
        dcn = sum(int(v.get('dcn_bytes', 0)) for v in sites.values())
        bd['exchange'] = {'sites': sites, 'step_bytes': step_b,
                          'refresh_bytes': refresh_b}
        if ici or dcn:
            bd['exchange']['ici_bytes'] = ici
            bd['exchange']['dcn_bytes'] = dcn

    own = _of(records, 'refresh_ownership')
    if own:
        bd['ownership'] = {'world': own[-1]['world'],
                           'owners': own[-1]['owners']}
    stragglers = _of(records, 'straggler')
    if stragglers:
        bd['stragglers'] = len(stragglers)
    prof = _of(records, 'profile')
    if prof:
        # latest memory numbers, but the one-shot HLO costs ('fns') only
        # land in the first profiled step — merge them forward
        bd['profile'] = dict(prof[-1])
        if 'fns' not in bd['profile']:
            for p in prof:
                if 'fns' in p:
                    bd['profile']['fns'] = p['fns']
                    break
    bench = _of(records, 'bench')
    if bench:
        bd['bench'] = {r['name']: r for r in bench if 'name' in r}
    return bd


# ---------------------------------------------------------------------------
# Rendering


def _mib(n_bytes: float) -> str:
    return f'{n_bytes / 2**20:.2f} MiB'


_PHASE_ORDER = ('data', 'grad', 'precondition', 'refresh', 'exchange',
                'apply', 'step')


def render(bd: dict, title: str = '') -> str:
    out = [f'== {title} ==' if title else '== run ==']
    if bd.get('n_step_records'):
        lo, hi = bd['step_range']
        out.append(f"steps: {bd['n_step_records']} records "
                   f"(step {lo}..{hi})   loss {bd['first_loss']:.4f} -> "
                   f"{bd['final_loss']:.4f}")
    if 'mean_step_ms' in bd:
        out.append(f"mean step time: {bd['mean_step_ms']:.2f} ms "
                   f"(p50 {bd['p50_step_ms']:.2f}, first step dropped)")
    line = []
    if 'staleness' in bd:
        line.append(f"staleness final {bd['staleness']['final']:.3g} "
                    f"max {bd['staleness']['max']:.3g}")
    if 'pipeline_lag' in bd:
        line.append(f"pipeline lag {bd['pipeline_lag']}")
    if 'stragglers' in bd:
        line.append(f"stragglers {bd['stragglers']}")
    if line:
        out.append('   '.join(line))

    # unified per-phase table: span-timed phases + the derived refresh and
    # byte-accounted exchange rows
    phases = dict(bd.get('phases', {}))
    refresh = bd.get('refresh', {})
    exch = bd.get('exchange', {})
    if phases or refresh or exch:
        out.append('')
        out.append(f"{'phase':<14} {'ms/step':>10} {'share':>7}   bytes")
        step_ms = (phases.get('step', {}).get('mean_ms')
                   or bd.get('mean_step_ms'))

        def row(name, ms, byt='-', note=''):
            share = (f'{100 * ms / step_ms:.1f}%'
                     if ms is not None and step_ms else '')
            ms_s = f'{ms:.3f}' if ms is not None else '-'
            out.append(f'{name:<14} {ms_s:>10} {share:>7}   {byt}{note}')

        for name in _PHASE_ORDER:
            if name == 'refresh':
                if refresh:
                    ms = refresh.get('amortized_ms_per_step')
                    note = f"  ({refresh.get('count', '?')} realized"
                    if 'extra_ms_per_refresh' in refresh:
                        note += (f", +{refresh['extra_ms_per_refresh']:.3f}"
                                 ' ms each, inside precondition')
                    note += ')'
                    byt = (_mib(exch['refresh_bytes']) + '/refresh'
                           if exch.get('refresh_bytes') else '-')
                    row('refresh', ms, byt, note)
            elif name == 'exchange':
                if exch:
                    byt = _mib(exch['step_bytes']) + '/step'
                    if exch.get('refresh_bytes'):
                        byt += f" + {_mib(exch['refresh_bytes'])}/refresh"
                    row('exchange', None, byt,
                        '  (logical, traced; time inside grad+precondition)')
            elif name in phases:
                row(name, phases[name]['mean_ms'])
        for name in sorted(set(phases) - set(_PHASE_ORDER)):
            row(name, phases[name]['mean_ms'])

    if exch:
        out.append('')
        out.append('exchange sites (logical bytes one worker contributes '
                   'per call):')
        for site, v in sorted(exch['sites'].items()):
            cadence = ('per-refresh' if site.startswith('refresh/')
                       else 'per-step')
            extra = ''
            if v.get('ici_bytes') or v.get('dcn_bytes'):
                extra = (f"  ici {_mib(v.get('ici_bytes', 0))} / "
                         f"dcn {_mib(v.get('dcn_bytes', 0))}")
            out.append(f"  {site:<24} {v['bytes_per_call']:>12} B  "
                       f"{v['codec']:<5} {v['mode']:<12} {cadence}{extra}")
        if 'ici_bytes' in exch:
            out.append(f"  topology split: ICI {_mib(exch['ici_bytes'])} vs "
                       f"DCN {_mib(exch['dcn_bytes'])} per refresh")
        if 'exchanged_mb_cum' in bd:
            out.append(f"  cumulative this run: "
                       f"{bd['exchanged_mb_cum']:.2f} MiB")

    if 'ownership' in bd:
        own = bd['ownership']
        out.append('')
        out.append(f"refresh ownership (world={own['world']}, per-worker "
                   'slice counts):')
        for bucket, counts in sorted(own['owners'].items()):
            out.append(f'  {bucket:<24} {counts}')

    if 'profile' in bd:
        prof = bd['profile']
        out.append('')
        parts = [f"profile @ step {prof.get('step', '?')}:"]
        if 'live_buffer_mb' in prof:
            parts.append(f"live buffers {prof['live_buffer_mb']:.1f} MiB")
        if prof.get('device_bytes_in_use') is not None:
            parts.append(f"device {_mib(prof['device_bytes_in_use'])}")
        out.append(' '.join(parts))
        for fn, c in sorted(prof.get('fns', {}).items()):
            out.append(f"  {fn:<14} {c.get('flops', 0)/1e9:8.3f} GFLOP  "
                       f"traffic {_mib(c.get('traffic_bytes', 0)):>12}  "
                       f"collectives {c.get('collective_count', 0)} "
                       f"({c.get('blocking_collectives', 0)} blocking, "
                       f"dep-dot {c.get('dependent_dot_flop_frac', 0.0)})")

    if 'bench' in bd:
        out.append('')
        out.append(f"bench rows: {len(bd['bench'])}")
        for name, r in sorted(bd['bench'].items()):
            us = r.get('us_per_call', 0.0)
            derived = r.get('derived', '')
            out.append(f'  {name:<40} {us:>10.1f} us  {derived}')
    return '\n'.join(out) + '\n'


# ---------------------------------------------------------------------------
# A-vs-B diff


def _pct(a: float, b: float) -> Optional[float]:
    if not a:
        return None
    return (b - a) / a * 100.0


def diff(bd_a: dict, bd_b: dict, label_a: str = 'A', label_b: str = 'B'
         ) -> tuple[str, Optional[float]]:
    """Comparison table + the worst regression (in %) over *gated*
    metrics: mean step time and benchmark ``us_per_call`` rows.  Positive
    percentages mean B is slower/larger than A."""
    rows: list[tuple[str, float, float, bool]] = []
    if 'mean_step_ms' in bd_a and 'mean_step_ms' in bd_b:
        rows.append(('mean step ms', bd_a['mean_step_ms'],
                     bd_b['mean_step_ms'], True))
    for name in sorted(set(bd_a.get('phases', {})) & set(bd_b.get('phases', {}))):
        rows.append((f'phase {name} ms', bd_a['phases'][name]['mean_ms'],
                     bd_b['phases'][name]['mean_ms'], False))
    if 'final_loss' in bd_a and 'final_loss' in bd_b:
        rows.append(('final loss', bd_a['final_loss'], bd_b['final_loss'],
                     False))
    for key in ('step_bytes', 'refresh_bytes'):
        a = bd_a.get('exchange', {}).get(key)
        b = bd_b.get('exchange', {}).get(key)
        if a is not None and b is not None:
            rows.append((f'exchange {key}', float(a), float(b), False))
    bench_a, bench_b = bd_a.get('bench', {}), bd_b.get('bench', {})
    for name in sorted(set(bench_a) & set(bench_b)):
        ua = float(bench_a[name].get('us_per_call', 0.0))
        ub = float(bench_b[name].get('us_per_call', 0.0))
        if ua > 0 and ub > 0:
            rows.append((f'bench {name} us', ua, ub, True))

    out = [f'== diff: A={label_a} vs B={label_b} ==']
    if not rows:
        out.append('(no comparable metrics)')
        return '\n'.join(out) + '\n', None
    out.append(f"{'metric':<44} {'A':>12} {'B':>12} {'delta':>9}")
    worst: Optional[float] = None
    for name, a, b, gated in rows:
        pct = _pct(a, b)
        pct_s = f'{pct:+.1f}%' if pct is not None else 'n/a'
        tag = '  [gate]' if gated else ''
        out.append(f'{name:<44} {a:>12.3f} {b:>12.3f} {pct_s:>9}{tag}')
        if gated and pct is not None:
            worst = pct if worst is None else max(worst, pct)
    return '\n'.join(out) + '\n', worst


# ---------------------------------------------------------------------------
# CLI


def main(argv: Optional[list[str]] = None) -> int:
    ap = argparse.ArgumentParser(
        prog='obs_report',
        description='Validate / break down / diff telemetry artifacts '
                    '(metrics.jsonl, BENCH_*.json). Exit codes: 0 ok, '
                    '1 validation errors, 2 gated regression.')
    ap.add_argument('files', nargs='+',
                    help='metrics.jsonl and/or BENCH_*.json paths')
    ap.add_argument('--validate', action='store_true',
                    help='schema-validate every record, then exit '
                         '(1 on any error)')
    ap.add_argument('--diff', action='store_true',
                    help='A-vs-B diff of exactly two files')
    ap.add_argument('--max-regress', type=float, default=None, metavar='PCT',
                    help='with two files: exit 2 if any gated metric '
                         '(mean step time, bench us/call) regressed >PCT%%')
    args = ap.parse_args(argv)

    loaded = [(f, load_records(f)) for f in args.files]

    if args.validate:
        n_err = 0
        for f, recs in loaded:
            errs = validate_records(recs)
            if errs:
                print(f'{f}: {len(errs)} schema error(s)')
                for e in errs[:50]:
                    print(f'  {e}')
                n_err += len(errs)
            else:
                print(f'{f}: {len(recs)} records OK '
                      f'(schema v{events.SCHEMA_VERSION})')
        return 1 if n_err else 0

    want_diff = args.diff or args.max_regress is not None
    if want_diff and len(loaded) != 2:
        ap.error('--diff/--max-regress need exactly two files')

    if not args.diff:
        for f, recs in loaded:
            print(render(breakdown(recs), title=f))

    if len(loaded) == 2:
        (fa, ra), (fb, rb) = loaded
        text, worst = diff(breakdown(ra), breakdown(rb), fa, fb)
        print(text)
        if args.max_regress is not None and worst is not None \
                and worst > args.max_regress:
            print(f'REGRESSION: worst gated metric {worst:+.1f}% exceeds '
                  f'--max-regress {args.max_regress:g}%')
            return 2
    return 0
