"""Phase spans, the straggler watchdog, and profile-mode samplers.

Spans are host-timed phase windows (data/grad/precondition/refresh/
exchange/apply/step) around pieces of the jitted step.  JAX dispatch is
async, so a naive ``perf_counter`` pair around a jitted call measures
dispatch, not compute — each span therefore carries an optional *fence*:
the device outputs produced inside the span, passed to
``jax.block_until_ready`` before the clock stops.  This is donate-safe
(blocking reads nothing back; it only waits), but fencing at phase
granularity does serialize phases the scheduler could otherwise overlap —
which is why span timing lives behind the trainer's ``profile`` flag
instead of always-on (README "Observability" has the measured overhead).

Profile mode additionally samples per-step live-buffer bytes
(``jax.live_arrays``), device-memory stats where the backend has them, and
a one-shot HLO cost + blocking-collective summary per compiled fn
(``launch/hlo_analysis``).
"""
from __future__ import annotations

import contextlib
import statistics
import time
from typing import Any, Iterator, Optional

import jax

from repro.obs import events


class SpanHandle:
    """Yielded by ``SpanTracker.span``; ``fence(x)`` registers the device
    values the span must wait on before its clock stops."""

    __slots__ = ('_fence',)

    def __init__(self) -> None:
        self._fence: Any = None

    def fence(self, x: Any) -> Any:
        self._fence = x
        return x


class SpanTracker:
    """Emits one ``span`` record per closed span, with nesting metadata
    (``depth``/``parent``) and a global emission order (``seq``)."""

    def __init__(self, recorder: Optional[events.Recorder] = None,
                 clock=time.perf_counter):
        self.recorder = recorder
        self.records: list[dict] = []
        self._clock = clock
        self._stack: list[str] = []
        self._seq = 0

    @contextlib.contextmanager
    def span(self, name: str, step: Optional[int] = None
             ) -> Iterator[SpanHandle]:
        handle = SpanHandle()
        parent = self._stack[-1] if self._stack else None
        depth = len(self._stack)
        self._stack.append(name)
        t0 = self._clock()
        try:
            yield handle
        finally:
            if handle._fence is not None:
                jax.block_until_ready(handle._fence)
            ms = (self._clock() - t0) * 1e3
            self._stack.pop()
            rec = {'name': name, 'ms': round(ms, 4), 'seq': self._seq,
                   'depth': depth, 'parent': parent}
            if step is not None:
                rec['step'] = int(step)
            self._seq += 1
            self.records.append(rec)
            if self.recorder is not None:
                self.recorder.emit('span', **rec)


class StragglerWatchdog:
    """Median-of-window straggler detection (factored out of the trainer so
    injected timings can drive it in tests).

    ``observe(step, dt)`` returns True — and emits a ``straggler`` record —
    when ``dt`` exceeds ``factor ×`` the median of the last ``window``
    step times (current step included, matching the original trainer
    logic); needs ``min_history`` samples before it can trigger.  On a
    real pod this feeds the controller that evicts/replaces the slow host.
    """

    def __init__(self, factor: float = 3.0,
                 recorder: Optional[events.Recorder] = None,
                 window: int = 64, min_history: int = 8):
        self.factor = factor
        self.recorder = recorder
        self.window = window
        self.min_history = min_history
        self.times: list[float] = []

    def observe(self, step: int, dt: float) -> bool:
        self.times.append(dt)
        if len(self.times) < self.min_history:
            return False
        med = statistics.median(self.times[-self.window:])
        if dt <= self.factor * med:
            return False
        if self.recorder is not None:
            self.recorder.emit('straggler', step=int(step),
                               step_time_s=round(dt, 6),
                               median_s=round(med, 6), factor=self.factor)
        print(f'[obs] STRAGGLER step {step}: {dt*1e3:.0f} ms vs median '
              f'{med*1e3:.0f} ms — flagged for controller', flush=True)
        return True


# ---------------------------------------------------------------------------
# Profile-mode samplers


def live_buffer_mb() -> float:
    """Total bytes of live device arrays in this process, in MiB."""
    try:
        arrays = jax.live_arrays()
    except Exception:
        return -1.0
    return round(sum(getattr(a, 'nbytes', 0) for a in arrays) / 2 ** 20, 3)


def device_bytes_in_use() -> Optional[int]:
    """Allocator bytes-in-use of device 0, where the backend reports it
    (TPU/GPU; the CPU backend returns None)."""
    try:
        stats = jax.local_devices()[0].memory_stats()
    except Exception:
        return None
    if not stats or 'bytes_in_use' not in stats:
        return None
    return int(stats['bytes_in_use'])


def hlo_costs(compiled_text: str) -> dict:
    """One compiled fn's HLO cost + blocking-collective summary — the
    ``fns`` entries of a ``profile`` record (trip-count-aware, reusing
    ``launch/hlo_analysis``)."""
    from repro.launch import hlo_analysis
    costs = hlo_analysis.analyze(compiled_text)
    overlap = hlo_analysis.collective_overlap(compiled_text)
    dep_frac = (overlap.dot_flops_dependent / overlap.dot_flops_total
                if overlap.dot_flops_total else 0.0)
    return {
        'flops': costs.flops,
        'traffic_bytes': costs.traffic_bytes,
        'collective_bytes': costs.collective_bytes,
        'collective_count': overlap.collective_count,
        'blocking_collectives': overlap.blocking_collectives,
        'dependent_dot_flop_frac': round(dep_frac, 4),
    }


def compiled_fn_costs(jitted_fn, *args) -> dict:
    """``hlo_costs`` of a jitted fn lowered at ``args``' shapes."""
    text = jitted_fn.lower(*args).compile().as_text()
    return hlo_costs(text)
