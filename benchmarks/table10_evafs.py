"""Paper Table 10: Eva-f / Eva-s iteration time and memory vs SGD
(transformer section; claim: ≈1.1–1.4× time, ≈1.0× state memory)."""
from __future__ import annotations

import jax

from benchmarks.common import emit, time_fn, tree_bytes
from repro.configs.registry import demo_lm
from repro.core.registry import make_optimizer
from repro.data.synthetic import LMStream
from repro.models import build_model
from repro.models import module as M
from repro.train.step import init_opt_state, make_train_step


def run() -> None:
    cfg = demo_lm('small')
    model = build_model(cfg)
    params = M.init_params(model.param_specs(), jax.random.PRNGKey(0))
    batch = LMStream(vocab=cfg.vocab, seq_len=64, batch=16, seed=0).batch_at(0)
    res = {}
    for name in ('sgd', 'eva_f', 'eva_s'):
        opt, capture = make_optimizer(name, lr=0.01)
        state = init_opt_state(model, opt, capture, params, batch)
        step = jax.jit(make_train_step(model, opt, capture))
        res[name] = (time_fn(step, params, state, batch), tree_bytes(state))
    t0, m0 = res['sgd']
    for name, (t, mem) in res.items():
        emit(f'table10/{name}', t,
             f'rel_time={t / t0:.2f};rel_state_mem={mem / max(m0, 1):.2f}')
