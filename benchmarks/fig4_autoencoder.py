"""Paper Fig. 4: deep-autoencoder optimization with SGD / Adagrad / K-FAC /
Shampoo / Eva (synthetic MNIST-like data offline; relative claim under test:
Eva ≈ K-FAC ≪ SGD in loss-vs-iterations, Shampoo between)."""
from __future__ import annotations

import time

import jax

from benchmarks.common import emit
from repro.core.registry import make_optimizer
from repro.core.transform import Extras
from repro.data.synthetic import AEStream
from repro.models import module as M
from repro.models.simple import ae_loss_fn, autoencoder
from repro.train.step import init_opt_state, make_train_step

STEPS = 40
BATCH = 128
LRS = {'sgd': 0.3, 'adagrad': 0.05, 'kfac': 0.15, 'shampoo': 0.3, 'eva': 0.15,
       'eva_f': 0.15, 'eva_s': 0.3}


def train_one(name: str, steps: int = STEPS) -> tuple[float, float]:
    model = autoencoder(hidden=(256, 64, 16, 64, 256), d_in=784)
    model.loss_fn = ae_loss_fn(model)
    params = M.init_params(model.param_specs(), jax.random.PRNGKey(0))
    data = AEStream(batch=BATCH)
    opt, capture = make_optimizer(name, lr=LRS.get(name, 0.1))
    taps_fn = (lambda p: model.make_taps(BATCH, capture)) \
        if capture.needs_taps else None
    state = init_opt_state(model, opt, capture, params, data.batch_at(0),
                           taps_fn=taps_fn)
    step = jax.jit(make_train_step(model, opt, capture, taps_fn=taps_fn))
    t0 = time.perf_counter()
    loss = None
    for i in range(steps):
        params, state, metrics = step(params, state, data.batch_at(i))
    loss = float(metrics['loss'])
    wall = (time.perf_counter() - t0) / steps
    return loss, wall * 1e6


def run() -> None:
    losses = {}
    for name in ('sgd', 'adagrad', 'kfac', 'shampoo', 'eva'):
        loss, us = train_one(name)
        losses[name] = loss
        emit(f'fig4/ae/{name}', us, f'loss_at_{STEPS}={loss:.4f}')
    # headline relative claims
    emit('fig4/ae/eva_vs_kfac', 0.0,
         f'ratio={losses["eva"] / max(losses["kfac"], 1e-9):.3f}')
    emit('fig4/ae/eva_vs_sgd', 0.0,
         f'ratio={losses["eva"] / max(losses["sgd"], 1e-9):.3f}')
