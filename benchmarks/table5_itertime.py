"""Paper Table 5: per-iteration time and memory relative to SGD.

Two sections:
  * transformer LM (demo config) — SGD / Eva / Eva-f / Eva-s / Shampoo@1 /
    Shampoo@10 / AdamW (K-FAC's full-tap capture targets the MLP section;
    see DESIGN.md §4.1),
  * MLP — adds K-FAC@1 / K-FAC@10 / FOOF (explicit inverses).
Derived: time and optimizer-state memory relative to SGD — the paper's
headline "Eva ≈ 1.14× SGD time, ~1.0× memory; K-FAC/Shampoo ≫".

``--bucketed`` adds a third section isolating the preconditioning stage on
a 24-layer qwen2-0.5b-proportioned transformer: per-LAYER loop (one call
per layer per projection — what a hook-based implementation pays) vs
per-PATH loop (broadcast over the scan stack, the pre-bucketing repo
state) vs the bucketed ``precondition_tree`` (one call per (shape, dtype)
bucket), with the launch counts that explain the gap.

``--refresh-sharding`` isolates the curvature *refresh* stage (K-FAC damped
inverses for the same 24-layer config) under a W=4 host-device data mesh:
every-worker-redundant recomputation (the pre-runtime behavior) vs
worker-sharded ownership with the owned-slice gather exchange (default)
and the legacy full-stack psum — plus the exchanged-bytes-per-refresh
table for psum vs gather × codec (identity/bf16/int8), the ROADMAP
"Refresh-exchange volume" numbers.

``--factor-sharding`` isolates the oversized-factor *apply* stage under the
same W=4 mesh: the legacy cached two-sided contraction vs the
``head_policy`` ladder from ``repro.core.factor_sharded`` — 'exclude'
(identity guard) and 'shard' (matrix-free distributed solve; CG at K-FAC's
power −1, binomial series at Shampoo's −1/4) — with the shard rows'
deviation from the dense reference asserted as a CI bound.
"""
from __future__ import annotations

import os
import sys

if ('--refresh-sharding' in sys.argv     # must precede the first jax import
        or '--factor-sharding' in sys.argv
        or '--pipeline' in sys.argv):
    _flags = os.environ.get('XLA_FLAGS', '')
    if '--xla_force_host_platform_device_count' not in _flags:
        os.environ['XLA_FLAGS'] = (
            _flags + ' --xla_force_host_platform_device_count=4').strip()

import argparse
import math

import jax
import jax.numpy as jnp

from benchmarks.common import emit, time_fn, tree_bytes, write_json
from repro.configs.base import ArchConfig
from repro.configs.registry import demo_lm
from repro.core import bucketing
from repro.core import kv as kvlib
from repro.core import precondition as pre
from repro.core.registry import make_optimizer
from repro.data.synthetic import ClassStream, LMStream
from repro.models import build_model
from repro.models import module as M
from repro.models.simple import MLP, classifier_loss_fn
from repro.train.step import init_opt_state, make_train_step


def _bench(model, params, batch, name, taps_batch=None, **opt_kw):
    opt, capture = make_optimizer(name.split('@')[0], lr=0.01, **opt_kw)
    taps_fn = None
    if capture.needs_taps and hasattr(model, 'make_taps'):
        taps_fn = lambda p: model.make_taps(taps_batch, capture)  # noqa: E731
    state = init_opt_state(model, opt, capture, params, batch, taps_fn=taps_fn)
    step = jax.jit(make_train_step(model, opt, capture, taps_fn=taps_fn))
    t = time_fn(step, params, state, batch)
    return t, tree_bytes(state)


def _bench_config() -> ArchConfig:
    """qwen2-0.5b layer structure (24L, GQA, SwiGLU) at 1/4 width so the
    CPU interpret path finishes in benchmark time; the bucket structure —
    what the comparison measures — is identical to the full model's."""
    return ArchConfig(name='qwen2-0.5b-bench', family='dense', n_layers=24,
                      d_model=224, n_heads=7, n_kv_heads=1, d_ff=1216,
                      vocab=2048)


def run_bucketed(method: str = 'eva') -> None:
    cfg = _bench_config()
    model = build_model(cfg)
    flat_specs = M.flatten_specs(model.param_specs())
    paths = sorted(set(model.precon_paths()) & set(flat_specs))
    key = jax.random.PRNGKey(0)
    grads, aux = {}, {}
    for i, p in enumerate(paths):
        shape = flat_specs[p].shape
        ks = jax.random.split(jax.random.fold_in(key, i), 3)
        grads[p] = jax.random.normal(ks[0], shape, jnp.float32)
        aux[p] = kvlib.LayerStats(
            a_mean=jax.random.normal(ks[1], shape[:-1], jnp.float32),
            b_mean=jax.random.normal(ks[2], shape[:-2] + shape[-1:],
                                     jnp.float32))
    plan = bucketing.build_plan(grads)
    n_layers = sum(
        (flat_specs[p].shape[0] if len(flat_specs[p].shape) == 3 else 1)
        for p in paths)

    def per_layer(g, a):
        out = {}
        for p in paths:
            if g[p].ndim == 3:   # unstack the scan dim: one call per layer
                out[p] = jnp.stack([
                    pre.eva_precondition(g[p][l], a[p].a_mean[l],
                                         a[p].b_mean[l], 0.03)
                    for l in range(g[p].shape[0])])
            else:
                out[p] = pre.eva_precondition(g[p], a[p].a_mean,
                                              a[p].b_mean, 0.03)
        return out

    def per_path(g, a):
        return {p: pre.eva_precondition(g[p], a[p].a_mean, a[p].b_mean, 0.03)
                for p in paths}

    def bucketed(p):
        return lambda g, a: pre.precondition_tree(g, a, method, 0.03, plan=p)

    def launches(p):
        return sum(1 if b.stacked else len(b.paths) for b in p.buckets)

    # pure bucketing (every bucket stacked) vs the tuned plan (default
    # min_bucket_size: N<=2 buckets skip gather/scatter — the ROADMAP
    # "bucket gather cost" item; at this config every bucket is small, so
    # the tuned plan degenerates to per-path, which is the point on CPU)
    plan_pure = bucketing.build_plan(grads, min_bucket_size=1)
    t_layer = time_fn(jax.jit(per_layer), grads, aux)
    t_path = time_fn(jax.jit(per_path), grads, aux)
    t_pure = time_fn(jax.jit(bucketed(plan_pure)), grads, aux)
    t_tuned = time_fn(jax.jit(bucketed(plan)), grads, aux)
    emit(f'table5/precon/{cfg.name}/per_layer', t_layer,
         f'launches={n_layers}')
    emit(f'table5/precon/{cfg.name}/per_path', t_path,
         f'launches={len(paths)}')
    emit(f'table5/precon/{cfg.name}/bucketed', t_pure,
         f'launches={launches(plan_pure)};speedup_vs_per_layer='
         f'{t_layer / max(t_pure, 1e-9):.2f}x;'
         f'speedup_vs_per_path={t_path / max(t_pure, 1e-9):.2f}x')
    emit(f'table5/precon/{cfg.name}/bucketed_tuned', t_tuned,
         f'launches={launches(plan)};min_bucket_size=default;'
         f'speedup_vs_per_layer={t_layer / max(t_tuned, 1e-9):.2f}x;'
         f'speedup_vs_bucketed={t_pure / max(t_tuned, 1e-9):.2f}x')


def run_refresh_sharding() -> None:
    """K-FAC inverse refresh for the 24-layer bench config on a (4,)-'data'
    host mesh: redundant (every worker inverts every bucket item) vs
    worker-sharded (each worker inverts only its owned slices) under both
    exchange modes (owned-slice gather / full-stack psum).  Wall time
    includes the exchange, so the printed speedup is the end-to-end
    refresh win, not just the FLOP ratio; the bytes table quantifies the
    wire volume each mode × codec moves."""
    from jax.sharding import PartitionSpec as P

    from repro.comm import exchange
    from repro.comm.exchange import ExchangeConfig
    from repro.core.precondition import kfac_pi_damping
    from repro.schedule import ownership
    from repro.schedule import runtime as schedrt
    from repro.sharding import compat

    cfg = _bench_config()
    model = build_model(cfg)
    flat_specs = M.flatten_specs(model.param_specs())
    paths = sorted(set(model.precon_paths()) & set(flat_specs))
    key = jax.random.PRNGKey(0)
    grads = {p: jax.random.normal(jax.random.fold_in(key, i),
                                  flat_specs[p].shape, jnp.float32)
             for i, p in enumerate(paths)}
    plan = bucketing.build_plan(grads)

    def psd(k, *shape):
        m = jax.random.normal(k, shape)
        return m @ jnp.swapaxes(m, -1, -2) + 0.1 * jnp.eye(shape[-1])

    stats, old = {}, {}
    for i, b in enumerate(plan.buckets):
        k1, k2 = jax.random.split(jax.random.fold_in(key, 1000 + i))
        lead = (len(b.paths),) + b.shape[:-2]
        d_in, d_out = b.shape[-2], b.shape[-1]
        ao = psd(k1, *lead, d_in, d_in)
        bo = psd(k2, *lead, d_out, d_out)
        stats[b.key] = (ao, bo)
        old[b.key] = (jnp.zeros_like(ao), jnp.zeros_like(bo))

    def one(b, args):
        ao, bo = args
        gamma_r, gamma_q = kfac_pi_damping(ao, bo, 0.03)
        eye_a = jnp.eye(ao.shape[-1], dtype=jnp.float32)
        eye_b = jnp.eye(bo.shape[-1], dtype=jnp.float32)
        return (jnp.linalg.inv(ao + gamma_r[..., None, None] * eye_a),
                jnp.linalg.inv(bo + gamma_q[..., None, None] * eye_b))

    if jax.device_count() < 2:
        raise SystemExit('refresh-sharding cell needs multiple host devices '
                         f'(got {jax.device_count()}; check XLA_FLAGS)')
    mesh = compat.make_mesh((jax.device_count(),), ('data',))

    def refresh(shard, comm=None):
        def body(s, o):
            return schedrt.sharded_refresh(
                plan, jnp.asarray(True), one, s, o,
                cost=ownership.inverse_cost('both'), shard=shard, comm=comm)
        return jax.jit(compat.shard_map(body, mesh=mesh, in_specs=(P(), P()),
                                        out_specs=P(), check=False))

    t_red = time_fn(refresh(False), stats, old)
    t_shard = time_fn(refresh(True), stats, old)           # default: gather
    t_psum = time_fn(refresh(True, comm=ExchangeConfig(exchange='psum')),
                     stats, old)
    world = jax.device_count()
    n_slices = sum(len(b.paths) * ownership.lead_size(b)
                   for b in plan.buckets)
    emit(f'table5/refresh/{cfg.name}/redundant_w{world}', t_red,
         f'slices_per_worker={n_slices}')
    per_worker = {w: 0 for w in range(world)}
    for counts in ownership.describe_ownership(plan, world).values():
        for w, c in enumerate(counts):
            per_worker[w] += c
    emit(f'table5/refresh/{cfg.name}/sharded_w{world}', t_shard,
         f'slices_per_worker={max(per_worker.values())};'
         f'speedup={t_red / max(t_shard, 1e-9):.2f}x')
    emit(f'table5/refresh/{cfg.name}/sharded_psum_w{world}', t_psum,
         f'slices_per_worker={max(per_worker.values())};'
         f'speedup={t_red / max(t_psum, 1e-9):.2f}x')

    # --- exchange bytes per refresh: psum vs gather × codec (the ROADMAP
    # "Refresh-exchange volume" numbers; logical per-worker bytes from the
    # same repro.comm accounting the runtime records at trace time) ---
    owners = ownership.assign_slice_owners(plan,
                                           ownership.inverse_cost('both'),
                                           world)
    inv_stacks = exchange.slice_stack_specs(plan, 'both')
    psum_b = exchange.refresh_exchange_bytes(plan, owners, inv_stacks, world,
                                             mode='psum')
    emit(f'table5/refresh_bytes/{cfg.name}/psum_w{world}', 0.0,
         f'bytes_per_refresh={psum_b}')
    for codec in ('identity', 'bf16', 'int8'):
        g_b = exchange.refresh_exchange_bytes(plan, owners, inv_stacks,
                                              world, codec=codec,
                                              mode='gather')
        emit(f'table5/refresh_bytes/{cfg.name}/gather_{codec}_w{world}', 0.0,
             f'bytes_per_refresh={g_b};'
             f'reduction_vs_psum={psum_b / g_b:.2f}x')


def run_factor_sharding() -> None:
    """Per-step apply of one head-proportioned bucket (in-dim dense, out-dim
    tripping the sub-slice threshold) on a W=4 host-device data mesh: the
    legacy cached two-sided einsum vs ``head_policy='exclude'`` (identity
    guard) vs ``'shard'`` (matrix-free distributed solve — CG at K-FAC's
    power −1, binomial series at Shampoo's −1/4).  Each shard row reports
    its max deviation from the dense reference (the iterative-tolerance
    bound the tests pin) and the static partial-psum bytes the solve pays."""
    from jax.sharding import PartitionSpec as P

    from repro.core import factor_sharded as fsh
    from repro.core.precondition import kfac_pi_damping
    from repro.sharding import compat

    if jax.device_count() < 2:
        raise SystemExit('factor-sharding cell needs multiple host devices '
                         f'(got {jax.device_count()}; check XLA_FLAGS)')
    mesh = compat.make_mesh((jax.device_count(),), ('data',))
    world = jax.device_count()

    key = jax.random.PRNGKey(0)
    d_in, d_out = 48, 384
    flat = {'head/w': jax.random.normal(key, (d_in, d_out), jnp.float32)}
    plan = bucketing.build_plan(flat)
    (bucket,) = plan.buckets

    def psd(k, d):
        m = jax.random.normal(k, (d, d))
        return m @ m.T / d + 0.5 * jnp.eye(d)

    k1, k2 = jax.random.split(jax.random.fold_in(key, 1))
    m_in = psd(k1, d_in)[None]     # bucket batch dim (N=1 path)
    m_out = psd(k2, d_out)[None]
    factors = {bucket.key: (m_in, m_out)}
    gamma = 0.03

    def smap(body):
        return jax.jit(compat.shard_map(body, mesh=mesh, in_specs=(P(),),
                                        out_specs=P(), check=False))

    def sharded(method, power, solver, iters):
        cfg = fsh.FactorShardConfig(head_policy='shard', shard_threshold=256,
                                    solver=solver, solve_iters=iters)
        _, pol = fsh.split_plan(plan, cfg)
        head = fsh.init_head(factors, pol, cfg, plan, method)
        head = fsh.refresh_head(jnp.asarray(True), factors, head, pol, gamma,
                                cfg=cfg, plan=plan, method=method)
        fn = smap(lambda g: fsh.apply_tree(g, plan, pol, head, factors,
                                           power=power, cfg=cfg,
                                           site='factor/bench')['head/w'])
        return fn, fsh.shard_psum_bytes(plan, pol, cfg)

    # --- K-FAC (power −1): cached dense inverses vs exclude vs CG solve ---
    gamma_r, gamma_q = kfac_pi_damping(m_in, m_out, gamma)
    a_inv = jnp.linalg.inv(m_in + gamma_r[..., None, None] * jnp.eye(d_in))
    b_inv = jnp.linalg.inv(m_out + gamma_q[..., None, None] * jnp.eye(d_out))
    ops = {bucket.key: kvlib.LayerStats(a_outer=a_inv, b_outer=b_inv)}
    dense_fn = smap(lambda g: pre.precondition_tree(
        g, ops, 'kfac_cached', gamma, plan=plan)['head/w'])

    ecfg = fsh.FactorShardConfig(head_policy='exclude', shard_threshold=256)
    _, epol = fsh.split_plan(plan, ecfg)
    ehead = fsh.refresh_head(jnp.asarray(True), factors,
                             fsh.init_head(factors, epol, ecfg, plan, 'kfac'),
                             epol, gamma, cfg=ecfg, plan=plan, method='kfac')
    excl_fn = smap(lambda g: fsh.apply_tree(g, plan, epol, ehead, factors,
                                            power=1.0, cfg=ecfg,
                                            site='factor/bench')['head/w'])
    cg_fn, cg_bytes = sharded('kfac', 1.0, 'cg', 32)

    ref = dense_fn(flat)
    t_dense = time_fn(dense_fn, flat)
    t_excl = time_fn(excl_fn, flat)
    t_cg = time_fn(cg_fn, flat)
    cg_dev = float(jnp.max(jnp.abs(cg_fn(flat) - ref)))
    emit(f'table5/factor/kfac/dense_w{world}', t_dense,
         f'd_out={d_out};cached_two_sided=1')
    emit(f'table5/factor/kfac/exclude_w{world}', t_excl,
         f'd_out={d_out};speedup_vs_dense={t_dense / max(t_excl, 1e-9):.2f}x')
    emit(f'table5/factor/kfac/shard_cg_w{world}', t_cg,
         f'd_out={d_out};iters=32;maxdiff_vs_dense={cg_dev:.2e};'
         f'solve_psum_bytes={cg_bytes:.0f}')
    if cg_dev > 1e-4:
        raise SystemExit(f'factor-sharding cell: CG solve deviates '
                         f'{cg_dev:.2e} from the dense inverse (>1e-4)')

    # --- Shampoo (power −1/4): cached eigh roots vs binomial series ---
    p_in = pre._inv_proot_psd(m_in, gamma, 0.25)
    p_out = pre._inv_proot_psd(m_out, gamma, 0.25)
    sops = {bucket.key: kvlib.LayerStats(a_outer=p_in, b_outer=p_out)}
    sdense_fn = smap(lambda g: pre.precondition_tree(
        g, sops, 'shampoo_cached', gamma, plan=plan)['head/w'])
    bin_fn, bin_bytes = sharded('shampoo', 0.25, 'binomial', 200)

    sref = sdense_fn(flat)
    t_sdense = time_fn(sdense_fn, flat)
    t_bin = time_fn(bin_fn, flat)
    bin_dev = float(jnp.max(jnp.abs(bin_fn(flat) - sref)))
    emit(f'table5/factor/shampoo/dense_w{world}', t_sdense,
         f'd_out={d_out};cached_eigh_roots=1')
    emit(f'table5/factor/shampoo/shard_binomial_w{world}', t_bin,
         f'd_out={d_out};iters=200;maxdiff_vs_dense={bin_dev:.2e};'
         f'solve_psum_bytes={bin_bytes:.0f}')
    if bin_dev > 1e-3:
        raise SystemExit(f'factor-sharding cell: binomial −1/4 solve '
                         f'deviates {bin_dev:.2e} from the eigh root (>1e-3)')


def run_pipeline(check_overlap: bool = False) -> None:
    """Sync vs onestep curvature pipeline on a W=4 host-device data mesh.

    Times the full explicit-DP train step (``make_dp_train_step``) for eva
    (stats pmean site) on the demo LM and for K-FAC (codec'd stats reduce +
    owned-slice refresh gather) on the MLP, in both pipeline modes, and
    reports the HLO dependence structure: the fraction of dot FLOPs inside
    the collectives' forward cone.  On CPU the thunk runtime executes
    serially, so wall-clock gains are muted — the dependence collapse
    (sync ≈ 1.0 → onestep ≈ 0.0) is the backend-independent evidence that
    an async-collective backend (TPU/GPU) can overlap the exchange, and is
    what ``--check-overlap`` asserts for CI."""
    from jax.sharding import PartitionSpec as P  # noqa: F401 (mesh check)

    from repro.launch import hlo_analysis
    from repro.schedule.runtime import RefreshRuntime
    from repro.sharding import compat
    from repro.train.compression import make_dp_train_step

    if jax.device_count() < 2:
        raise SystemExit('pipeline cell needs multiple host devices '
                         f'(got {jax.device_count()}; check XLA_FLAGS)')
    mesh = compat.make_mesh((jax.device_count(),), ('data',))
    world = jax.device_count()

    cases = []
    cfg = demo_lm('small')
    lm = build_model(cfg)
    lm_params = M.init_params(lm.param_specs(), jax.random.PRNGKey(0))
    lm_batch = LMStream(vocab=cfg.vocab, seq_len=64, batch=16, seed=0).batch_at(0)
    cases.append(('lm/eva', lm, lm_params, lm_batch, 'eva', {}, None))

    mlp = MLP([64, 256, 256, 256, 10])
    mlp.loss_fn = classifier_loss_fn(mlp)
    mparams = M.init_params(mlp.param_specs(), jax.random.PRNGKey(1))
    mbatch = ClassStream(batch=128, dim=64, classes=10).batch_at(0)
    cases.append(('mlp/kfac', mlp, mparams, mbatch, 'kfac',
                  {'interval': 1}, 128 // world))

    failures = []
    for label, model, params, batch, name, kw, taps_batch in cases:
        opt, capture = make_optimizer(name, lr=0.01, **kw)
        taps_init = taps_step = None
        if capture.needs_taps and hasattr(model, 'make_taps'):
            # init sees the full batch; the step's taps see the per-worker
            # shard inside shard_map (batch split over 'data')
            taps_init = lambda p: model.make_taps(taps_batch * world, capture)  # noqa: B023,E731
            taps_step = lambda p: model.make_taps(taps_batch, capture)  # noqa: B023,E731
        rows = {}
        for mode in ('sync', 'onestep'):
            rt = RefreshRuntime(pipeline=mode)
            state = init_opt_state(model, opt, capture, params, batch,
                                   taps_fn=taps_init, sched=rt)
            step, init_err = make_dp_train_step(model, opt, capture, mesh,
                                                compress=False,
                                                taps_fn=taps_step, sched=rt)
            err = init_err(params)
            t = time_fn(step, params, state, err, batch)
            txt = step.lower(params, state, err, batch).compile().as_text()
            rep = hlo_analysis.collective_overlap(txt)
            rows[mode] = (t, rep)
        t_sync, rep_sync = rows['sync']
        t_one, rep_one = rows['onestep']
        emit(f'table5/pipeline/{label}/sync_w{world}', t_sync,
             f'blocking_collectives={rep_sync.blocking_collectives}'
             f'/{rep_sync.collective_count};'
             f'dep_dot_frac={rep_sync.dependent_fraction:.3f}')
        emit(f'table5/pipeline/{label}/onestep_w{world}', t_one,
             f'blocking_collectives={rep_one.blocking_collectives}'
             f'/{rep_one.collective_count};'
             f'dep_dot_frac={rep_one.dependent_fraction:.3f};'
             f'speedup_vs_sync={t_sync / max(t_one, 1e-9):.2f}x')
        # the gradient all-reduce must stay blocking (it feeds the whole
        # update — that's data parallelism, not this pipeline's concern);
        # the curvature exchanges must LEAVE the blocking set
        if rep_one.blocking_collectives >= rep_sync.blocking_collectives:
            failures.append(
                f'{label}: onestep leaves {rep_one.blocking_collectives} '
                f'collectives blocking dots (sync: '
                f'{rep_sync.blocking_collectives}) — the curvature '
                'exchanges did not leave the compute dependence cone')
    if check_overlap and failures:
        raise SystemExit('overlap check FAILED:\n  ' + '\n  '.join(failures))
    if check_overlap:
        print('# overlap check passed: onestep collectives are outside the '
              'dot dependence cone')


def run_kernels(check_speedup: bool = False) -> None:
    """Kernel dispatch microbench: the pure-XLA ``ref.py`` path vs
    interpret-mode Pallas (the pre-dispatch CPU default) per op × shape,
    through the same ``kernels.dispatch`` wrappers the optimizers call.
    The geomean xla speedup is the number the dispatch layer's
    CPU-``'auto'``-resolves-to-``'xla'`` rule banks every step;
    ``--check-speedup`` gates it at ≥1.5× for CI."""
    from repro.kernels import dispatch

    key = jax.random.PRNGKey(0)
    shapes = [(128, 128), (512, 384), (1000, 513)]
    ops = ('bilinear', 'matvec', 'rank1_update', 'eva_fused')
    speedups = []
    for d_in, d_out in shapes:
        ks = jax.random.split(jax.random.fold_in(key, d_in), 3)
        g = jax.random.normal(ks[0], (d_in, d_out), jnp.float32)
        a = jax.random.normal(ks[1], (d_in,), jnp.float32)
        b = jax.random.normal(ks[2], (d_out,), jnp.float32)
        m = jnp.zeros((1, d_in, d_out), jnp.float32)

        def cases(impl):
            return {
                'bilinear': lambda: dispatch.bilinear(g, a, b, impl=impl),
                'matvec': lambda: dispatch.matvec(g, a, impl=impl),
                'rank1_update': lambda: dispatch.rank1_update(
                    g, a, b, jnp.float32(0.37), jnp.float32(2.5), impl=impl),
                'eva_fused': lambda: dispatch.eva_fused_stacked(
                    g[None], a[None], b[None], 0.03, m, 0.9, impl=impl)[0],
            }

        for op in ops:
            t_xla = time_fn(jax.jit(cases('xla')[op]))
            t_int = time_fn(jax.jit(cases('pallas_interpret')[op]))
            sp = t_int / max(t_xla, 1e-9)
            speedups.append(sp)
            emit(f'table5/kernels/{op}/{d_in}x{d_out}/xla', t_xla,
                 'impl=xla')
            emit(f'table5/kernels/{op}/{d_in}x{d_out}/interpret', t_int,
                 f'impl=pallas_interpret;xla_speedup={sp:.2f}x')
    geo = math.exp(sum(math.log(s) for s in speedups) / len(speedups))
    emit('table5/kernels/summary', 0.0,
         f'xla_speedup_geomean={geo:.2f}x;cells={len(speedups)};'
         f'min_speedup={min(speedups):.2f}x')
    if check_speedup and geo < 1.5:
        raise SystemExit(f'kernel dispatch cell: xla geomean speedup '
                         f'{geo:.2f}x < 1.5x over interpret')
    if check_speedup:
        print(f'# speedup check passed: xla {geo:.2f}x over interpret '
              '(geomean)')


def run() -> None:
    # --- transformer section ---
    cfg = demo_lm('small')
    model = build_model(cfg)
    params = M.init_params(model.param_specs(), jax.random.PRNGKey(0))
    data = LMStream(vocab=cfg.vocab, seq_len=64, batch=16, seed=0)
    batch = data.batch_at(0)
    results = {}
    for name, kw in [('sgd', {}), ('eva', {}), ('eva_f', {}), ('eva_s', {}),
                     ('adamw', {}), ('shampoo@1', {'interval': 1}),
                     ('shampoo@10', {'interval': 10}), ('mfac', {'m': 8})]:
        t, mem = _bench(model, params, batch, name, **kw)
        results[name] = (t, mem)
    t_sgd, m_sgd = results['sgd']
    for name, (t, mem) in results.items():
        emit(f'table5/lm/{name}', t,
             f'rel_time={t / t_sgd:.2f};rel_state_mem={mem / max(m_sgd, 1):.2f}')

    # --- MLP section (K-FAC / FOOF need full taps) ---
    mlp = MLP([64, 256, 256, 256, 10])
    mlp.loss_fn = classifier_loss_fn(mlp)
    mparams = M.init_params(mlp.param_specs(), jax.random.PRNGKey(1))
    mbatch = ClassStream(batch=128, dim=64, classes=10).batch_at(0)
    mres = {}
    for name, kw in [('sgd', {}), ('eva', {}), ('kfac@1', {'interval': 1}),
                     ('kfac@10', {'interval': 10}), ('foof', {}),
                     ('shampoo@1', {'interval': 1})]:
        t, mem = _bench(mlp, mparams, mbatch, name, taps_batch=128, **kw)
        mres[name] = (t, mem)
    t_sgd, m_sgd = mres['sgd']
    for name, (t, mem) in mres.items():
        emit(f'table5/mlp/{name}', t,
             f'rel_time={t / t_sgd:.2f};rel_state_mem={mem / max(m_sgd, 1):.2f}')


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument('--bucketed', action='store_true',
                    help='only the bucketed-vs-per-layer preconditioning '
                         'comparison (24-layer qwen2-0.5b-proportioned)')
    ap.add_argument('--refresh-sharding', action='store_true',
                    help='only the worker-sharded curvature-refresh cell '
                         '(4 host devices, K-FAC inverses)')
    ap.add_argument('--factor-sharding', action='store_true',
                    help='only the matrix-free sharded-factor apply cell '
                         '(4 host devices, dense vs exclude vs shard)')
    ap.add_argument('--pipeline', action='store_true',
                    help='only the sync-vs-onestep curvature pipeline cell '
                         '(4 host devices, eva LM + K-FAC MLP)')
    ap.add_argument('--check-overlap', action='store_true',
                    help='with --pipeline: fail (exit 1) unless the onestep '
                         'collectives are outside the dot dependence cone')
    ap.add_argument('--kernels', action='store_true',
                    help='only the kernel dispatch microbench (xla ref path '
                         'vs interpret-mode Pallas per op/shape)')
    ap.add_argument('--check-speedup', action='store_true',
                    help='with --kernels: fail (exit 1) unless the xla path '
                         'is >=1.5x faster than interpret (geomean)')
    ap.add_argument('--json', default=None, metavar='PATH',
                    help='also write the emitted rows to PATH as JSON '
                         '(CI benchmark artifacts)')
    args = ap.parse_args()
    print('name,us_per_call,derived')
    if args.bucketed:
        run_bucketed()
    elif args.refresh_sharding:
        run_refresh_sharding()
    elif args.factor_sharding:
        run_factor_sharding()
    elif args.pipeline:
        run_pipeline(check_overlap=args.check_overlap)
    elif args.kernels:
        run_kernels(check_speedup=args.check_speedup)
    else:
        run()
    if args.json:
        write_json(args.json)


if __name__ == '__main__':
    main()
