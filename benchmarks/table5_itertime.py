"""Paper Table 5: per-iteration time and memory relative to SGD.

Two sections:
  * transformer LM (demo config) — SGD / Eva / Eva-f / Eva-s / Shampoo@1 /
    Shampoo@10 / AdamW (K-FAC's full-tap capture targets the MLP section;
    see DESIGN.md §4.1),
  * MLP — adds K-FAC@1 / K-FAC@10 / FOOF (explicit inverses).
Derived: time and optimizer-state memory relative to SGD — the paper's
headline "Eva ≈ 1.14× SGD time, ~1.0× memory; K-FAC/Shampoo ≫".
"""
from __future__ import annotations

import jax

from benchmarks.common import emit, time_fn, tree_bytes
from repro.configs.registry import demo_lm
from repro.core.registry import make_optimizer
from repro.data.synthetic import ClassStream, LMStream
from repro.models import build_model
from repro.models import module as M
from repro.models.simple import MLP, classifier_loss_fn
from repro.train.step import init_opt_state, make_train_step


def _bench(model, params, batch, name, taps_batch=None, **opt_kw):
    opt, capture = make_optimizer(name.split('@')[0], lr=0.01, **opt_kw)
    taps_fn = None
    if capture.needs_taps and hasattr(model, 'make_taps'):
        taps_fn = lambda p: model.make_taps(taps_batch, capture)  # noqa: E731
    state = init_opt_state(model, opt, capture, params, batch, taps_fn=taps_fn)
    step = jax.jit(make_train_step(model, opt, capture, taps_fn=taps_fn))
    t = time_fn(step, params, state, batch)
    return t, tree_bytes(state)


def run() -> None:
    # --- transformer section ---
    cfg = demo_lm('small')
    model = build_model(cfg)
    params = M.init_params(model.param_specs(), jax.random.PRNGKey(0))
    data = LMStream(vocab=cfg.vocab, seq_len=64, batch=16, seed=0)
    batch = data.batch_at(0)
    results = {}
    for name, kw in [('sgd', {}), ('eva', {}), ('eva_f', {}), ('eva_s', {}),
                     ('adamw', {}), ('shampoo@1', {'interval': 1}),
                     ('shampoo@10', {'interval': 10}), ('mfac', {'m': 8})]:
        t, mem = _bench(model, params, batch, name, **kw)
        results[name] = (t, mem)
    t_sgd, m_sgd = results['sgd']
    for name, (t, mem) in results.items():
        emit(f'table5/lm/{name}', t,
             f'rel_time={t / t_sgd:.2f};rel_state_mem={mem / max(m_sgd, 1):.2f}')

    # --- MLP section (K-FAC / FOOF need full taps) ---
    mlp = MLP([64, 256, 256, 256, 10])
    mlp.loss_fn = classifier_loss_fn(mlp)
    mparams = M.init_params(mlp.param_specs(), jax.random.PRNGKey(1))
    mbatch = ClassStream(batch=128, dim=64, classes=10).batch_at(0)
    mres = {}
    for name, kw in [('sgd', {}), ('eva', {}), ('kfac@1', {'interval': 1}),
                     ('kfac@10', {'interval': 10}), ('foof', {}),
                     ('shampoo@1', {'interval': 1})]:
        t, mem = _bench(mlp, mparams, mbatch, name, taps_batch=128, **kw)
        mres[name] = (t, mem)
    t_sgd, m_sgd = mres['sgd']
    for name, (t, mem) in mres.items():
        emit(f'table5/mlp/{name}', t,
             f'rel_time={t / t_sgd:.2f};rel_state_mem={mem / max(m_sgd, 1):.2f}')
