"""Paper Table 4/7 analogue: accuracy / CE under a fixed iteration budget.

Two tasks (synthetic stand-ins for Cifar per DESIGN.md §8):
  * MLP classifier on gaussian blobs — accuracy after N steps for
    SGD / Adagrad / AdamW / K-FAC / Eva,
  * demo transformer LM on the bigram stream — CE after N steps for
    SGD / AdamW / Eva / Eva-f / Eva-s (bigram entropy floor printed).
Claim under test: Eva ≥ SGD at equal iterations, Eva ≈ K-FAC.

``--kappa-sweep`` calibrates the ``kl_clip_trace`` trust-region radius κ
(ROADMAP "κ calibration"): CE after a fixed budget on the *base*-scale
demo LM (~10M params — the 'small' config the rest of this file uses is
too shallow to stress the trust region) for κ on a 1e-4..1e-2 log grid
around the 1e-3 default.
"""
from __future__ import annotations

import argparse

import jax
import numpy as np

from benchmarks.common import classifier_accuracy, emit, time_fn, write_json
from repro.configs.registry import demo_lm
from repro.core import kv as kvlib
from repro.core.registry import make_optimizer
from repro.data.synthetic import ClassStream, LMStream
from repro.models import build_model
from repro.models import module as M
from repro.models.simple import MLP, classifier_loss_fn
from repro.train.step import init_opt_state, make_train_step

CLS_STEPS = 60
LM_STEPS = 60
LRS = {'sgd': 0.05, 'adagrad': 0.02, 'adamw': 1e-3, 'kfac': 0.05, 'eva': 0.05,
       'eva_f': 0.05, 'eva_s': 0.05}

KAPPA_GRID = (1e-4, 3e-4, 1e-3, 3e-3, 1e-2)


def run() -> None:
    # --- classifier ---
    stream = ClassStream(batch=128, dim=64, classes=10, spread=1.2)
    accs = {}
    for name in ('sgd', 'adagrad', 'adamw', 'kfac', 'eva'):
        model = MLP([64, 128, 128, 10])
        model.loss_fn = classifier_loss_fn(model)
        params = M.init_params(model.param_specs(), jax.random.PRNGKey(0))
        opt, capture = make_optimizer(name, lr=LRS[name])
        taps_fn = (lambda p: model.make_taps(128, capture)) \
            if capture.needs_taps else None
        state = init_opt_state(model, opt, capture, params, stream.batch_at(0),
                               taps_fn=taps_fn)
        step = jax.jit(make_train_step(model, opt, capture, taps_fn=taps_fn))
        for i in range(CLS_STEPS):
            params, state, m = step(params, state, stream.batch_at(i))
        accs[name] = classifier_accuracy(model, params, stream)
        emit(f'table4/cls/{name}', 0.0, f'acc_at_{CLS_STEPS}={accs[name]:.4f}')

    # --- LM ---
    cfg = demo_lm('small')
    data = LMStream(vocab=cfg.vocab, seq_len=64, batch=16, seed=0)
    emit('table4/lm/bigram_floor', 0.0, f'ce_floor={data.bigram_ce:.4f}')
    for name in ('sgd', 'adamw', 'eva', 'eva_f', 'eva_s'):
        model = build_model(cfg)
        params = M.init_params(model.param_specs(), jax.random.PRNGKey(0))
        opt, capture = make_optimizer(name, lr=LRS[name])
        state = init_opt_state(model, opt, capture, params, data.batch_at(0))
        step = jax.jit(make_train_step(model, opt, capture))
        for i in range(LM_STEPS):
            params, state, m = step(params, state, data.batch_at(i))
        emit(f'table4/lm/{name}', 0.0,
             f'ce_at_{LM_STEPS}={float(m["loss"]):.4f}')


def run_kappa_sweep(methods: list[str], steps: int = 80,
                    scale: str = 'base') -> None:
    """κ calibration for the KL trust region on the larger demo LM.

    Each cell trains ``steps`` iterations and reports the tail-geomean CE
    (last 8 steps — single-step losses near the floor are minibatch noise,
    same convention as the fig6 drift sweep) so κ values separate by
    converged quality rather than by one lucky batch."""
    cfg = demo_lm(scale)
    data = LMStream(vocab=cfg.vocab, seq_len=32, batch=8, seed=1)
    emit(f'table4/kappa/bigram_floor_{scale}', 0.0,
         f'ce_floor={data.bigram_ce:.4f}')
    for name in methods:
        model = build_model(cfg)
        params0 = M.init_params(model.param_specs(), jax.random.PRNGKey(0))
        paths = set(model.precon_paths()) & \
            set(kvlib.flatten_params(params0))
        for kappa in KAPPA_GRID:
            opt, capture = make_optimizer(name, lr=LRS[name], kl_kappa=kappa)
            # K-FAC: full z-shaped taps, lead-dims intact so the stacked
            # b_outer keeps the scan path dim (the old vector-tap fallback
            # collapsed it and the refresh cond branches disagreed)
            taps_fn = (lambda p: kvlib.make_full_taps(
                p, paths, (data.batch, data.seq_len))) \
                if capture.b == 'outer' else None
            state = init_opt_state(model, opt, capture, params0,
                                   data.batch_at(0), taps_fn=taps_fn)
            step = jax.jit(make_train_step(model, opt, capture,
                                           taps_fn=taps_fn))
            p, losses = params0, []
            for i in range(steps):
                p, state, m = step(p, state, data.batch_at(i))
                losses.append(float(m['loss']))
            tail = float(np.exp(np.mean(np.log(np.asarray(losses[-8:])))))
            emit(f'table4/kappa/{scale}/{name}@k{kappa:g}', 0.0,
                 f'tail_ce_at_{steps}={tail:.4f}')


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument('--kappa-sweep', action='store_true',
                    help='kl_clip_trace κ calibration on the base-scale '
                         'demo LM (1e-4..1e-2 log grid around the 1e-3 '
                         'default) instead of the accuracy/CE table')
    ap.add_argument('--scale', default='base',
                    help="demo-LM scale for --kappa-sweep (default 'base')")
    ap.add_argument('--steps', type=int, default=80,
                    help='iteration budget per --kappa-sweep cell')
    ap.add_argument('--methods', default=None,
                    help='comma-separated method filter for --kappa-sweep '
                         '(default: eva; kfac runs too — full taps are '
                         'built automatically for b=outer captures)')
    ap.add_argument('--json', default=None, metavar='PATH',
                    help='also write the emitted rows to PATH as JSON')
    args = ap.parse_args()
    print('name,us_per_call,derived')
    if args.kappa_sweep:
        methods = ([m.strip() for m in args.methods.split(',')]
                   if args.methods else ['eva'])
        run_kappa_sweep(methods, steps=args.steps, scale=args.scale)
    else:
        run()
    if args.json:
        write_json(args.json)


if __name__ == '__main__':
    main()
