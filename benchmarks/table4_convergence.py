"""Paper Table 4/7 analogue: accuracy / CE under a fixed iteration budget.

Two tasks (synthetic stand-ins for Cifar per DESIGN.md §8):
  * MLP classifier on gaussian blobs — accuracy after N steps for
    SGD / Adagrad / AdamW / K-FAC / Eva,
  * demo transformer LM on the bigram stream — CE after N steps for
    SGD / AdamW / Eva / Eva-f / Eva-s (bigram entropy floor printed).
Claim under test: Eva ≥ SGD at equal iterations, Eva ≈ K-FAC.
"""
from __future__ import annotations

import jax

from benchmarks.common import classifier_accuracy, emit, time_fn
from repro.configs.registry import demo_lm
from repro.core.registry import make_optimizer
from repro.data.synthetic import ClassStream, LMStream
from repro.models import build_model
from repro.models import module as M
from repro.models.simple import MLP, classifier_loss_fn
from repro.train.step import init_opt_state, make_train_step

CLS_STEPS = 60
LM_STEPS = 60
LRS = {'sgd': 0.05, 'adagrad': 0.02, 'adamw': 1e-3, 'kfac': 0.05, 'eva': 0.05,
       'eva_f': 0.05, 'eva_s': 0.05}


def run() -> None:
    # --- classifier ---
    stream = ClassStream(batch=128, dim=64, classes=10, spread=1.2)
    accs = {}
    for name in ('sgd', 'adagrad', 'adamw', 'kfac', 'eva'):
        model = MLP([64, 128, 128, 10])
        model.loss_fn = classifier_loss_fn(model)
        params = M.init_params(model.param_specs(), jax.random.PRNGKey(0))
        opt, capture = make_optimizer(name, lr=LRS[name])
        taps_fn = (lambda p: model.make_taps(128, capture)) \
            if capture.needs_taps else None
        state = init_opt_state(model, opt, capture, params, stream.batch_at(0),
                               taps_fn=taps_fn)
        step = jax.jit(make_train_step(model, opt, capture, taps_fn=taps_fn))
        for i in range(CLS_STEPS):
            params, state, m = step(params, state, stream.batch_at(i))
        accs[name] = classifier_accuracy(model, params, stream)
        emit(f'table4/cls/{name}', 0.0, f'acc_at_{CLS_STEPS}={accs[name]:.4f}')

    # --- LM ---
    cfg = demo_lm('small')
    data = LMStream(vocab=cfg.vocab, seq_len=64, batch=16, seed=0)
    emit('table4/lm/bigram_floor', 0.0, f'ce_floor={data.bigram_ce:.4f}')
    for name in ('sgd', 'adamw', 'eva', 'eva_f', 'eva_s'):
        model = build_model(cfg)
        params = M.init_params(model.param_specs(), jax.random.PRNGKey(0))
        opt, capture = make_optimizer(name, lr=LRS[name])
        state = init_opt_state(model, opt, capture, params, data.batch_at(0))
        step = jax.jit(make_train_step(model, opt, capture))
        for i in range(LM_STEPS):
            params, state, m = step(params, state, data.batch_at(i))
        emit(f'table4/lm/{name}', 0.0,
             f'ce_at_{LM_STEPS}={float(m["loss"]):.4f}')
