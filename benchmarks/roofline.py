"""Roofline table (deliverable g): aggregate results/dryrun/*.json.

Per (arch × shape × mesh): the three roofline terms, the dominant
bottleneck, MODEL_FLOPS/HLO_FLOPs, memory/device — plus a one-line
suggestion for moving the dominant term (heuristic from the breakdown).
Writes results/roofline.md and prints CSV rows.

Also emits the §3.3 sublinear-communication tables: per-step curvature
(KV/KF) all-reduce volume vs the gradient all-reduce volume, analytically
from the model's parameter/precon-path specs — Eva's KV vectors are O(d)
per layer against the O(d²) gradients (the paper's claim), K-FAC's factors
are O(d²) (same order as gradients) — plus, since the unified comm layer
(``repro.comm``), the per-call-site exchange bytes under each codec
(f32/bf16/int8) and the refresh-exchange comparison of the legacy
full-stack psum vs the owned-slice all-gather at W=4, all pulled from the
``repro.comm.metrics`` counters the runtime itself records.
"""
from __future__ import annotations

import json
from pathlib import Path

from benchmarks.common import emit

DRYRUN_DIR = Path('results/dryrun')

KVCOMM_ARCHES = ['qwen2-0.5b', 'glm4-9b']
OWNERSHIP_INTERVAL = 10  # refresh interval amortizing the exchange volume
REFRESH_WORLD = 4        # data-parallel world for the refresh-exchange row


def _suggest(rec: dict) -> str:
    dom = rec['dominant']
    coll = rec.get('collective_by_op', {})
    if dom == 'collective_s':
        worst = max(coll, key=coll.get) if coll else '?'
        if worst == 'all-gather':
            return 'reduce FSDP regather: larger model-axis shard or cached gather'
        if worst == 'all-reduce':
            return 'reduce-scatter grads / shrink TP psums (activation resharding)'
        return f'restructure {worst} traffic'
    if dom == 'memory_s':
        return 'cut HBM traffic: fuse/remat less, smaller saved residuals'
    return 'compute-bound: raise MFU via larger tiles / less recompute'


def load_records() -> list[dict]:
    recs = []
    for p in sorted(DRYRUN_DIR.glob('*.json')):
        recs.append(json.loads(p.read_text()))
    return recs


def _arch_comm_trees(arch: str):
    """(plan, grads_tree, kv_tree, kf_tree, inverse_stacks) as
    ShapeDtypeStructs — everything the comm tables need, no arrays."""
    import jax
    import jax.numpy as jnp

    from repro.configs.registry import get_config
    from repro.core import bucketing
    from repro.models import build_model
    from repro.models import module as M

    cfg = get_config(arch)
    model = build_model(cfg)
    specs = M.flatten_specs(model.param_specs())
    precon = sorted(set(model.precon_paths()) & set(specs))
    f32 = jnp.float32
    grads = {p: jax.ShapeDtypeStruct(s.shape, f32) for p, s in specs.items()}
    kv, kf = {}, {}
    for p in precon:
        shape = specs[p].shape
        lead, d_in, d_out = shape[:-2], shape[-2], shape[-1]
        kv[p] = (jax.ShapeDtypeStruct(lead + (d_in,), f32),
                 jax.ShapeDtypeStruct(lead + (d_out,), f32))
        kf[p] = (jax.ShapeDtypeStruct(lead + (d_in, d_in), f32),
                 jax.ShapeDtypeStruct(lead + (d_out, d_out), f32))
    from repro.comm.exchange import slice_stack_specs

    plan = bucketing.build_plan({p: specs[p] for p in precon})
    return plan, grads, kv, kf, slice_stack_specs(plan, 'both')


def kv_comm_rows() -> list[str]:
    """§3.3 exchange-volume tables, per arch: the classic KV-vs-gradient
    comparison, the per-call-site × codec matrix, and the refresh-exchange
    psum-vs-owned-slice row — the codec'd numbers come from the same
    ``repro.comm`` accounting the runtime records at trace time."""
    from repro.comm import exchange as ex
    from repro.comm import get_codec, metrics
    from repro.schedule import ownership

    mb = 1 / 2 ** 20
    codecs = ['f32', 'bf16', 'int8']
    lines = ['',
             '## KV vs gradient all-reduce volume per step (§3.3)',
             '',
             '| arch | grad MB | eva_kv MB | kv/grad | kfac_kf MB | kf/grad '
             f'| refresh_exchange MB (@k={OWNERSHIP_INTERVAL}, owned-slice, '
             f'W={REFRESH_WORLD}) |',
             '|---|---|---|---|---|---|---|']
    site_lines = ['',
                  '## Per-call-site exchange bytes × codec (repro.comm)',
                  '',
                  '| arch | call-site | ' +
                  ' | '.join(f'{c} MB' for c in codecs) + ' |',
                  '|---|---|---|---|---|']
    refresh_lines = ['',
                     f'## Refresh exchange: full-stack psum vs owned-slice '
                     f'all-gather (W={REFRESH_WORLD})',
                     '',
                     '| arch | psum MB | gather f32 MB | reduction | '
                     'gather int8 MB | reduction |',
                     '|---|---|---|---|---|---|']
    for arch in KVCOMM_ARCHES:
        plan, grads, kv, kf, stacks = _arch_comm_trees(arch)
        owners = ownership.assign_slice_owners(
            plan, ownership.inverse_cost('both'), REFRESH_WORLD)
        # record through the comm metrics counters (the same accounting the
        # trainer logs), then read the table back out of the snapshot
        for site, tree in (('grads/dp', grads), ('stats/eva_kv', kv),
                           ('stats/kfac_kf', kf)):
            for c in codecs:
                metrics.record(f'{arch}/{site}/{c}',
                               bytes_per_call=ex.tree_payload_bytes(
                                   tree, get_codec(c)),
                               codec=c, mode='allreduce')
        for mode, c in (('psum', 'f32'), ('gather', 'f32'),
                        ('gather', 'int8')):
            metrics.record(
                f'{arch}/refresh/{mode}/{c}',
                bytes_per_call=ex.refresh_exchange_bytes(
                    plan, owners, stacks, REFRESH_WORLD, codec=c, mode=mode),
                codec=c, mode=mode)
        snap = metrics.snapshot()

        def b_of(site, c='f32', snap=snap, arch=arch):
            return snap[f'{arch}/{site}/{c}']['bytes_per_call']

        grad_b, kv_b, kf_b = (b_of('grads/dp'), b_of('stats/eva_kv'),
                              b_of('stats/kfac_kf'))
        ag_b = b_of('refresh/gather')
        ag_i8 = b_of('refresh/gather', 'int8')
        ps_b = b_of('refresh/psum')
        lines.append(
            f'| {arch} | {grad_b * mb:.1f} | {kv_b * mb:.3f} '
            f'| {kv_b / grad_b:.2e} | {kf_b * mb:.1f} | {kf_b / grad_b:.2f} '
            f'| {ag_b / OWNERSHIP_INTERVAL * mb:.1f} |')
        for site in ('grads/dp', 'stats/eva_kv', 'stats/kfac_kf'):
            site_lines.append(
                f'| {arch} | {site} | ' +
                ' | '.join(f'{b_of(site, c) * mb:.3f}' for c in codecs) +
                ' |')
        refresh_lines.append(
            f'| {arch} | {ps_b * mb:.1f} | {ag_b * mb:.1f} '
            f'| {ps_b / ag_b:.2f}x | {ag_i8 * mb:.1f} '
            f'| {ps_b / ag_i8:.2f}x |')
        emit(f'roofline/kvcomm/{arch}', 0.0,
             f'kv_over_grad={kv_b / grad_b:.2e};kf_over_grad='
             f'{kf_b / grad_b:.2f};grad_mb={grad_b * mb:.1f};'
             f'refresh_mb_per_step={ag_b / OWNERSHIP_INTERVAL * mb:.2f}')
        emit(f'roofline/refresh_exchange/{arch}', 0.0,
             f'psum_mb={ps_b * mb:.1f};gather_mb={ag_b * mb:.1f};'
             f'reduction={ps_b / ag_b:.2f}x;int8_mb={ag_i8 * mb:.1f};'
             f'int8_reduction={ps_b / ag_i8:.2f}x;world={REFRESH_WORLD}')
    return lines + site_lines + refresh_lines


def factor_policy_rows() -> list[str]:
    """Per-factor byte attribution + the head-policy ladder (PR 8).

    Two tables per arch: (a) the top-5 largest Kronecker-factor buckets by
    f32 refresh-exchange share — making visible WHERE the owned-slice
    gather's bytes actually go (glm4-9b: the 151552² vocab-head b_outer is
    ~97% of the volume); (b) the measured refresh-exchange bytes at
    W={REFRESH_WORLD} under ``head_policy`` dense/exclude/shard — the split
    dense plan re-gathered through the same ``refresh_exchange_bytes``
    accounting, plus the per-refresh matrix-free partial-psum bytes the
    'shard' apply pays instead (``factor_sharded.shard_psum_bytes``)."""
    from repro.comm import exchange as ex
    from repro.core import factor_sharded as fsh
    from repro.schedule import ownership

    mb = 1 / 2 ** 20
    cost = ownership.inverse_cost('both')
    attr_lines = ['',
                  '## Per-factor refresh bytes: top-5 buckets (f32, '
                  'owned-slice gather)',
                  '',
                  '| arch | bucket | layers | factor dims | MB | share |',
                  '|---|---|---|---|---|---|']
    pol_lines = ['',
                 f'## Vocab-head factor policy: refresh exchange at '
                 f'W={REFRESH_WORLD} (f32, owned-slice)',
                 '',
                 '| arch | policy | refresh MB | vs dense psum | '
                 'solve psum MB/step (iters=32) |',
                 '|---|---|---|---|---|']
    for arch in KVCOMM_ARCHES:
        plan, _, _, _, _ = _arch_comm_trees(arch)
        # (a) attribution: each bucket's share of the full-plan f32 gather
        per_bucket = []
        for b in plan.buckets:
            n = len(b.paths) * ownership.lead_size(b)
            d_in, d_out = int(b.shape[-2]), int(b.shape[-1])
            per_bucket.append((4.0 * n * (d_in ** 2 + d_out ** 2), b))
        total = sum(x for x, _ in per_bucket) or 1.0
        per_bucket.sort(key=lambda t: -t[0])
        for nbytes, b in per_bucket[:5]:
            d_in, d_out = int(b.shape[-2]), int(b.shape[-1])
            attr_lines.append(
                f'| {arch} | {b.key} | {len(b.paths)} | {d_in}²+{d_out}² '
                f'| {nbytes * mb:.1f} | {nbytes / total:.1%} |')
        # (b) the policy ladder: dense psum baseline vs per-policy gather
        owners = ownership.assign_slice_owners(plan, cost, REFRESH_WORLD)
        stacks = ex.slice_stack_specs(plan, 'both')
        psum_full = ex.refresh_exchange_bytes(
            plan, owners, stacks, REFRESH_WORLD, codec='f32', mode='psum')
        derived = []
        for policy in ('dense', 'exclude', 'shard'):
            cfg = fsh.FactorShardConfig(head_policy=policy)
            dense_plan, head_pol = fsh.split_plan(plan, cfg)
            d_owners = ownership.assign_slice_owners(
                dense_plan, cost, REFRESH_WORLD)
            d_stacks = ex.slice_stack_specs(dense_plan, 'both')
            gather = ex.refresh_exchange_bytes(
                dense_plan, d_owners, d_stacks, REFRESH_WORLD,
                codec='f32', mode='gather')
            solve = fsh.shard_psum_bytes(plan, head_pol, cfg)
            red = psum_full / gather if gather else float('inf')
            red_s = f'{red:.2f}x' if gather else '∞'
            pol_lines.append(
                f'| {arch} | {policy} | {gather * mb:.1f} | {red_s} '
                f'| {solve * mb:.1f} |')
            derived.append(f'{policy}_mb={gather * mb:.1f};'
                           f'{policy}_reduction={red:.2f}')
            if policy == 'shard':
                derived.append(f'shard_solve_mb={solve * mb:.1f}')
        emit(f'roofline/factor_policy/{arch}', 0.0,
             ';'.join(derived) + f';world={REFRESH_WORLD}')
    pol_lines += ['', "'shard' removes the head factors from the refresh "
                  'gather entirely but pays gradient-shaped partial psums '
                  'at every apply — tune solve_iters (or pick exclude) when '
                  'the head dominates per-step volume.']
    return attr_lines + pol_lines


def run() -> None:
    recs = load_records()
    lines = ['| arch | shape | mesh | compute_s | memory_s | collective_s | '
             'dominant | useful_flop_ratio | GiB/dev | note |',
             '|---|---|---|---|---|---|---|---|---|---|']
    for rec in recs:
        tag = f"{rec['arch']}/{rec['shape']}/{rec['mesh']}"
        if 'skipped' in rec:
            lines.append(f"| {rec['arch']} | {rec['shape']} | {rec['mesh']} |"
                         f" — | — | — | skipped | — | — | {rec['skipped'][:60]} |")
            emit(f'roofline/{tag}', 0.0, 'skipped')
            continue
        r = rec['roofline_s']
        mem_gib = rec['memory']['total_bytes'] / 2 ** 30
        lines.append(
            f"| {rec['arch']} | {rec['shape']} | {rec['mesh']} "
            f"| {r['compute_s']:.3f} | {r['memory_s']:.3f} "
            f"| {r['collective_s']:.3f} | {rec['dominant'].replace('_s','')} "
            f"| {rec['useful_flop_ratio']:.2f} | {mem_gib:.1f} "
            f"| {_suggest(rec)} |")
        dom_val = r[rec['dominant']]
        emit(f'roofline/{tag}', dom_val * 1e6,
             f"dominant={rec['dominant']};useful_ratio="
             f"{rec['useful_flop_ratio']:.2f};mem_gib={mem_gib:.1f}")
    lines += kv_comm_rows()
    lines += factor_policy_rows()
    out = Path('results/roofline.md')
    out.parent.mkdir(exist_ok=True)
    out.write_text('\n'.join(lines) + '\n')
    print(f'# wrote {out} ({len(recs)} cells)')


if __name__ == '__main__':
    run()
