"""Roofline table (deliverable g): aggregate results/dryrun/*.json.

Per (arch × shape × mesh): the three roofline terms, the dominant
bottleneck, MODEL_FLOPS/HLO_FLOPs, memory/device — plus a one-line
suggestion for moving the dominant term (heuristic from the breakdown).
Writes results/roofline.md and prints CSV rows.

Also emits the §3.3 sublinear-communication table: per-step curvature
(KV/KF) all-reduce volume vs the gradient all-reduce volume, analytically
from the model's parameter/precon-path specs — Eva's KV vectors are O(d)
per layer against the O(d²) gradients (the paper's claim), K-FAC's factors
are O(d²) (same order as gradients), and the refresh runtime's ownership
exchange adds the cached-inverse volume amortized by the refresh interval.
"""
from __future__ import annotations

import json
from pathlib import Path

from benchmarks.common import emit

DRYRUN_DIR = Path('results/dryrun')

KVCOMM_ARCHES = ['qwen2-0.5b', 'glm4-9b']
OWNERSHIP_INTERVAL = 10  # refresh interval amortizing the exchange volume


def _suggest(rec: dict) -> str:
    dom = rec['dominant']
    coll = rec.get('collective_by_op', {})
    if dom == 'collective_s':
        worst = max(coll, key=coll.get) if coll else '?'
        if worst == 'all-gather':
            return 'reduce FSDP regather: larger model-axis shard or cached gather'
        if worst == 'all-reduce':
            return 'reduce-scatter grads / shrink TP psums (activation resharding)'
        return f'restructure {worst} traffic'
    if dom == 'memory_s':
        return 'cut HBM traffic: fuse/remat less, smaller saved residuals'
    return 'compute-bound: raise MFU via larger tiles / less recompute'


def load_records() -> list[dict]:
    recs = []
    for p in sorted(DRYRUN_DIR.glob('*.json')):
        recs.append(json.loads(p.read_text()))
    return recs


def kv_comm_rows() -> list[str]:
    """§3.3 per-step all-reduce volumes (bytes, f32) for each arch:
    gradients vs Eva KVs vs K-FAC factors vs the ownership exchange."""
    from repro.configs.registry import get_config
    from repro.models import build_model
    from repro.models import module as M

    lines = ['',
             '## KV vs gradient all-reduce volume per step (§3.3)',
             '',
             '| arch | grad MB | eva_kv MB | kv/grad | kfac_kf MB | kf/grad '
             f'| ownership_exchange MB (@k={OWNERSHIP_INTERVAL}) |',
             '|---|---|---|---|---|---|---|']
    for arch in KVCOMM_ARCHES:
        cfg = get_config(arch)
        model = build_model(cfg)
        specs = M.flatten_specs(model.param_specs())
        precon = sorted(set(model.precon_paths()) & set(specs))
        n_params = sum(int(_prod(s.shape)) for s in specs.values())
        grad_b = 4 * n_params
        kv_b = kf_b = 0
        for p in precon:
            shape = specs[p].shape
            lead = _prod(shape[:-2])
            d_in, d_out = shape[-2], shape[-1]
            kv_b += 4 * lead * (d_in + d_out)          # ā, b̄ vectors
            kf_b += 4 * lead * (d_in ** 2 + d_out ** 2)  # AAᵀ, BBᵀ factors
        # the worker-sharded refresh exchanges the cached inverses (same
        # volume as the factors) once per refresh — amortize by the interval
        own_b = kf_b / OWNERSHIP_INTERVAL
        mb = 1 / 2 ** 20
        lines.append(
            f'| {arch} | {grad_b * mb:.1f} | {kv_b * mb:.3f} '
            f'| {kv_b / grad_b:.2e} | {kf_b * mb:.1f} | {kf_b / grad_b:.2f} '
            f'| {own_b * mb:.1f} |')
        emit(f'roofline/kvcomm/{arch}', 0.0,
             f'kv_over_grad={kv_b / grad_b:.2e};kf_over_grad='
             f'{kf_b / grad_b:.2f};grad_mb={grad_b * mb:.1f};'
             f'ownership_mb_per_step={own_b * mb:.2f}')
    return lines


def _prod(xs) -> int:
    out = 1
    for x in xs:
        out *= int(x)
    return out


def run() -> None:
    recs = load_records()
    lines = ['| arch | shape | mesh | compute_s | memory_s | collective_s | '
             'dominant | useful_flop_ratio | GiB/dev | note |',
             '|---|---|---|---|---|---|---|---|---|---|']
    for rec in recs:
        tag = f"{rec['arch']}/{rec['shape']}/{rec['mesh']}"
        if 'skipped' in rec:
            lines.append(f"| {rec['arch']} | {rec['shape']} | {rec['mesh']} |"
                         f" — | — | — | skipped | — | — | {rec['skipped'][:60]} |")
            emit(f'roofline/{tag}', 0.0, 'skipped')
            continue
        r = rec['roofline_s']
        mem_gib = rec['memory']['total_bytes'] / 2 ** 30
        lines.append(
            f"| {rec['arch']} | {rec['shape']} | {rec['mesh']} "
            f"| {r['compute_s']:.3f} | {r['memory_s']:.3f} "
            f"| {r['collective_s']:.3f} | {rec['dominant'].replace('_s','')} "
            f"| {rec['useful_flop_ratio']:.2f} | {mem_gib:.1f} "
            f"| {_suggest(rec)} |")
        dom_val = r[rec['dominant']]
        emit(f'roofline/{tag}', dom_val * 1e6,
             f"dominant={rec['dominant']};useful_ratio="
             f"{rec['useful_flop_ratio']:.2f};mem_gib={mem_gib:.1f}")
    lines += kv_comm_rows()
    out = Path('results/roofline.md')
    out.parent.mkdir(exist_ok=True)
    out.write_text('\n'.join(lines) + '\n')
    print(f'# wrote {out} ({len(recs)} cells)')


if __name__ == '__main__':
    run()
