"""Roofline table (deliverable g): aggregate results/dryrun/*.json.

Per (arch × shape × mesh): the three roofline terms, the dominant
bottleneck, MODEL_FLOPS/HLO_FLOPs, memory/device — plus a one-line
suggestion for moving the dominant term (heuristic from the breakdown).
Writes results/roofline.md and prints CSV rows.
"""
from __future__ import annotations

import json
from pathlib import Path

from benchmarks.common import emit

DRYRUN_DIR = Path('results/dryrun')


def _suggest(rec: dict) -> str:
    dom = rec['dominant']
    coll = rec.get('collective_by_op', {})
    if dom == 'collective_s':
        worst = max(coll, key=coll.get) if coll else '?'
        if worst == 'all-gather':
            return 'reduce FSDP regather: larger model-axis shard or cached gather'
        if worst == 'all-reduce':
            return 'reduce-scatter grads / shrink TP psums (activation resharding)'
        return f'restructure {worst} traffic'
    if dom == 'memory_s':
        return 'cut HBM traffic: fuse/remat less, smaller saved residuals'
    return 'compute-bound: raise MFU via larger tiles / less recompute'


def load_records() -> list[dict]:
    recs = []
    for p in sorted(DRYRUN_DIR.glob('*.json')):
        recs.append(json.loads(p.read_text()))
    return recs


def run() -> None:
    recs = load_records()
    lines = ['| arch | shape | mesh | compute_s | memory_s | collective_s | '
             'dominant | useful_flop_ratio | GiB/dev | note |',
             '|---|---|---|---|---|---|---|---|---|---|']
    for rec in recs:
        tag = f"{rec['arch']}/{rec['shape']}/{rec['mesh']}"
        if 'skipped' in rec:
            lines.append(f"| {rec['arch']} | {rec['shape']} | {rec['mesh']} |"
                         f" — | — | — | skipped | — | — | {rec['skipped'][:60]} |")
            emit(f'roofline/{tag}', 0.0, 'skipped')
            continue
        r = rec['roofline_s']
        mem_gib = rec['memory']['total_bytes'] / 2 ** 30
        lines.append(
            f"| {rec['arch']} | {rec['shape']} | {rec['mesh']} "
            f"| {r['compute_s']:.3f} | {r['memory_s']:.3f} "
            f"| {r['collective_s']:.3f} | {rec['dominant'].replace('_s','')} "
            f"| {rec['useful_flop_ratio']:.2f} | {mem_gib:.1f} "
            f"| {_suggest(rec)} |")
        dom_val = r[rec['dominant']]
        emit(f'roofline/{tag}', dom_val * 1e6,
             f"dominant={rec['dominant']};useful_ratio="
             f"{rec['useful_flop_ratio']:.2f};mem_gib={mem_gib:.1f}")
    out = Path('results/roofline.md')
    out.parent.mkdir(exist_ok=True)
    out.write_text('\n'.join(lines) + '\n')
    print(f'# wrote {out} ({len(recs)} cells)')


if __name__ == '__main__':
    run()
