"""Paper Table 8: training throughput (tokens/s) per optimizer on the demo
transformer LM."""
from __future__ import annotations

import jax

from benchmarks.common import emit, time_fn
from repro.configs.registry import demo_lm
from repro.core.registry import make_optimizer
from repro.data.synthetic import LMStream
from repro.models import build_model
from repro.models import module as M
from repro.train.step import init_opt_state, make_train_step

BATCH, SEQ = 16, 64


def run() -> None:
    cfg = demo_lm('small')
    data = LMStream(vocab=cfg.vocab, seq_len=SEQ, batch=BATCH, seed=0)
    batch = data.batch_at(0)
    for name, kw in [('sgd', {}), ('eva', {}), ('shampoo@10', {'interval': 10}),
                     ('adamw', {})]:
        model = build_model(cfg)
        params = M.init_params(model.param_specs(), jax.random.PRNGKey(0))
        opt, capture = make_optimizer(name.split('@')[0], lr=0.01, **kw)
        state = init_opt_state(model, opt, capture, params, batch)
        step = jax.jit(make_train_step(model, opt, capture))
        us = time_fn(step, params, state, batch)
        tput = BATCH * SEQ / (us / 1e6)
        emit(f'table8/{name}', us, f'tokens_per_s={tput:.0f}')
