"""Shared benchmark helpers: timing, state sizing, CSV rows."""
from __future__ import annotations

import time
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np

ROWS: list[tuple[str, float, str]] = []


def emit(name: str, us_per_call: float, derived: str) -> None:
    ROWS.append((name, us_per_call, derived))
    print(f'{name},{us_per_call:.1f},{derived}')


def time_fn(fn: Callable, *args, reps: int = 5, warmup: int = 2) -> float:
    """Median wall time (µs) of a jitted callable; blocks on outputs."""
    for _ in range(warmup):
        out = fn(*args)
        jax.block_until_ready(out)
    times = []
    for _ in range(reps):
        t0 = time.perf_counter()
        out = fn(*args)
        jax.block_until_ready(out)
        times.append(time.perf_counter() - t0)
    return float(np.median(times) * 1e6)


def tree_bytes(tree) -> int:
    return sum(l.size * l.dtype.itemsize
               for l in jax.tree_util.tree_leaves(tree)
               if hasattr(l, 'size'))


def classifier_accuracy(model, params, stream, steps: int = 5) -> float:
    correct = total = 0
    for i in range(steps):
        b = stream.batch_at(10_000 + i)  # held-out region of the stream
        logits, _ = model.apply(params, b['x'])
        correct += int((jnp.argmax(logits, -1) == b['y']).sum())
        total += b['y'].shape[0]
    return correct / total
