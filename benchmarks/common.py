"""Shared benchmark helpers: timing, state sizing, CSV rows."""
from __future__ import annotations

import json
import time
from pathlib import Path
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np

ROWS: list[tuple[str, float, str]] = []


def emit(name: str, us_per_call: float, derived: str) -> None:
    ROWS.append((name, us_per_call, derived))
    print(f'{name},{us_per_call:.1f},{derived}')


def write_json(path: str) -> None:
    """Dump every row emitted so far to ``path`` as JSON — the BENCH_*.json
    artifacts the CI benchmark-smoke job uploads, so the perf trajectory is
    recorded per commit instead of scrolling away in logs.  The ``derived``
    key=value pairs are split out so downstream tooling can diff them.

    Rows are typed ``bench`` records in the unified telemetry schema
    (``repro.obs.events``) — supersets of the original
    name/us_per_call/derived shape, schema-validated before writing so a
    malformed row fails the benchmark, not the downstream report."""
    from repro.obs import events as obs_events
    rows = []
    for name, us, derived in ROWS:
        rec = {'event': 'bench', 'v': obs_events.SCHEMA_VERSION,
               'name': name, 'us_per_call': us, 'derived': derived}
        kv = {}
        for part in derived.split(';'):
            if '=' in part:
                k, v = part.split('=', 1)
                kv[k] = v
        if kv:
            rec['fields'] = kv
        errs = obs_events.validate_record(rec)
        if errs:
            raise obs_events.SchemaError(f'{name}: ' + '; '.join(errs))
        rows.append(rec)
    Path(path).write_text(json.dumps(rows, indent=2) + '\n')
    print(f'# wrote {path} ({len(rows)} rows)')


def time_fn(fn: Callable, *args, reps: int = 5, warmup: int = 2) -> float:
    """Median wall time (µs) of a jitted callable; blocks on outputs."""
    for _ in range(warmup):
        out = fn(*args)
        jax.block_until_ready(out)
    times = []
    for _ in range(reps):
        t0 = time.perf_counter()
        out = fn(*args)
        jax.block_until_ready(out)
        times.append(time.perf_counter() - t0)
    return float(np.median(times) * 1e6)


def tree_bytes(tree) -> int:
    return sum(l.size * l.dtype.itemsize
               for l in jax.tree_util.tree_leaves(tree)
               if hasattr(l, 'size'))


def classifier_accuracy(model, params, stream, steps: int = 5) -> float:
    correct = total = 0
    for i in range(steps):
        b = stream.batch_at(10_000 + i)  # held-out region of the stream
        logits, _ = model.apply(params, b['x'])
        correct += int((jnp.argmax(logits, -1) == b['y']).sum())
        total += b['y'].shape[0]
    return correct / total
