"""Benchmark harness — one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows.  Roofline rows are included
when results/dryrun has been populated by ``python -m repro.launch.dryrun``.
"""
from __future__ import annotations

import sys
import time
import traceback


def main() -> None:
    import benchmarks.fig4_autoencoder as fig4
    import benchmarks.fig6_interval as fig6
    import benchmarks.fig8_vectorized as fig8
    import benchmarks.table1_complexity as table1
    import benchmarks.table4_convergence as table4
    import benchmarks.table5_itertime as table5
    import benchmarks.table8_throughput as table8
    import benchmarks.table10_evafs as table10
    import benchmarks.roofline as roofline

    modules = [table1, table5, fig4, table4, fig6, fig8, table8, table10,
               roofline]
    print('name,us_per_call,derived')
    failures = []
    for mod in modules:
        t0 = time.time()
        try:
            mod.run()
        except Exception as e:  # noqa: BLE001
            failures.append((mod.__name__, repr(e)))
            traceback.print_exc()
        print(f'# {mod.__name__} done in {time.time() - t0:.1f}s',
              file=sys.stderr)
    if failures:
        raise SystemExit(f'benchmark failures: {failures}')


if __name__ == '__main__':
    main()
