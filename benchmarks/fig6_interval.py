"""Paper Fig. 6, generalized by the refresh runtime: every method × policy.

The original figure studies K-FAC@{1,5,20} — per-step time falls with the
update interval but staleness costs loss, while Eva@1 needs no interval at
all.  With the curvature refresh runtime (``repro.schedule``) the interval
is a *policy*, and every method takes the same knob, so the grid is now
method × {every_k(1), every_k(5), every_k(20), adaptive} with the realized
per-policy refresh count, the staleness proxy, per-step time and final
loss in every cell.

``--drift-sweep`` calibrates the adaptive policy on the demo-LM config
(ROADMAP "Adaptive-policy calibration"): the drift threshold sweeps a
0.01–0.2 log grid against the every_k Pareto points {1, 5, 20}, each cell
emitting the realized refresh count and the tail-geomean loss (single-step
losses near the floor are minibatch noise — see the verify notes).
"""
from __future__ import annotations

import argparse

import jax
import numpy as np

from benchmarks.common import emit, time_fn, write_json
from repro.configs.registry import demo_lm
from repro.core.registry import make_optimizer
from repro.data.synthetic import ClassStream, LMStream
from repro.models import build_model
from repro.models import module as M
from repro.models.simple import MLP, classifier_loss_fn
from repro.schedule import runtime as schedrt
from repro.schedule.policy import adaptive, every_k
from repro.train.step import init_opt_state, make_train_step

STEPS = 40

DRIFT_GRID = np.geomspace(0.01, 0.2, 6)
PARETO_KS = (1, 5, 20)

METHODS = ['eva', 'eva_f', 'eva_s', 'foof', 'kfac', 'shampoo']

POLICIES = [
    ('every1', lambda: every_k(1)),
    ('every5', lambda: every_k(5)),
    ('every20', lambda: every_k(20)),
    ('adaptive', lambda: adaptive(threshold=0.05, max_interval=50)),
]


def run(steps: int = STEPS, methods=None) -> None:
    stream = ClassStream(batch=128, dim=64, classes=10, spread=1.2)

    def train(name, policy):
        model = MLP([64, 256, 256, 10])
        model.loss_fn = classifier_loss_fn(model)
        params = M.init_params(model.param_specs(), jax.random.PRNGKey(0))
        opt, capture = make_optimizer(name, lr=0.05, policy=policy)
        taps_fn = (lambda p: model.make_taps(128, capture)) \
            if capture.needs_taps else None
        state = init_opt_state(model, opt, capture, params, stream.batch_at(0),
                               taps_fn=taps_fn)
        step = jax.jit(make_train_step(model, opt, capture, taps_fn=taps_fn))
        t = time_fn(step, params, state, stream.batch_at(0))
        for i in range(steps):
            params, state, m = step(params, state, stream.batch_at(i))
        sched = schedrt.schedule_metrics(state)
        return (t, float(m['loss']), int(sched['refreshes']),
                float(sched['staleness']))

    for name in (methods or METHODS):
        for plabel, make_policy in POLICIES:
            t, loss, refreshes, staleness = train(name, make_policy())
            emit(f'fig6/{name}@{plabel}', t,
                 f'loss_at_{steps}={loss:.4f};refreshes={refreshes}/{steps};'
                 f'staleness={staleness:.3g}')


def run_drift_sweep(methods: list[str], steps: int = 120) -> None:
    """Adaptive-threshold calibration on the demo-LM config: refresh-count
    vs tail-loss rows for each threshold, next to the every_k Pareto
    points the thresholds must beat.

    Default horizon is 120 steps (3× the policy grid's): at 40 steps the
    drift statistic has barely left its warm-up transient, so every
    threshold below ~0.09 kept refreshing near-every-step and the sweep
    could not separate them; by 120 steps the drift scale settles and the
    low thresholds spread out (see the BENCH_fig6_drift.json rows)."""
    cfg = demo_lm('small')
    model = build_model(cfg)
    params = M.init_params(model.param_specs(), jax.random.PRNGKey(0))
    data = LMStream(vocab=cfg.vocab, seq_len=32, batch=8, seed=1)

    def cell(method, label, policy):
        opt, capture = make_optimizer(method, lr=0.05, policy=policy)
        state = init_opt_state(model, opt, capture, params, data.batch_at(0))
        step = jax.jit(make_train_step(model, opt, capture))
        t = time_fn(step, params, state, data.batch_at(0))
        p, s = params, state
        losses = []
        for i in range(steps):
            p, s, m = step(p, s, data.batch_at(i))
            losses.append(float(m['loss']))
        sched = schedrt.schedule_metrics(s)
        tail = float(np.exp(np.mean(np.log(np.asarray(losses[-8:])))))
        emit(f'fig6/drift/{method}@{label}', t,
             f'tail_loss={tail:.4f};refreshes={int(sched["refreshes"])}'
             f'/{steps};staleness={float(sched["staleness"]):.3g}')

    for method in methods:
        for k in PARETO_KS:
            cell(method, f'every{k}', every_k(k))
        for thr in DRIFT_GRID:
            cell(method, f'thr{thr:.3g}',
                 adaptive(threshold=float(thr), max_interval=50))


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument('--drift-sweep', action='store_true',
                    help='adaptive drift-threshold calibration on the '
                         'demo-LM config (0.01-0.2 log grid vs every_k '
                         'Pareto points)')
    ap.add_argument('--steps', type=int, default=None,
                    help='horizon override; defaults to 40 for the policy '
                         'grid and 120 for --drift-sweep (the drift '
                         'statistic needs ~3x the grid horizon to leave '
                         'its warm-up transient)')
    ap.add_argument('--methods', default=None,
                    help='comma-separated method filter, used by BOTH the '
                         'policy grid (default: all six; CI smoke passes a '
                         'subset) and --drift-sweep (default: eva)')
    ap.add_argument('--json', default=None, metavar='PATH',
                    help='also write the emitted rows to PATH as JSON '
                         '(CI benchmark artifacts)')
    args = ap.parse_args()
    methods = ([m.strip() for m in args.methods.split(',')]
               if args.methods else None)
    print('name,us_per_call,derived')
    if args.drift_sweep:
        run_drift_sweep(methods or ['eva'], steps=args.steps or 120)
    else:
        run(steps=args.steps or STEPS, methods=methods)
    if args.json:
        write_json(args.json)


if __name__ == '__main__':
    main()
