"""Paper Fig. 6: K-FAC second-order update interval study.

K-FAC@{1,5,20} on the MLP task: per-step time falls with the interval but
staleness costs loss; Eva@1 needs no interval at all — the paper's core
systems argument."""
from __future__ import annotations

import jax

from benchmarks.common import emit, time_fn
from repro.core.registry import make_optimizer
from repro.data.synthetic import ClassStream
from repro.models import module as M
from repro.models.simple import MLP, classifier_loss_fn
from repro.train.step import init_opt_state, make_train_step

STEPS = 40


def run() -> None:
    stream = ClassStream(batch=128, dim=64, classes=10, spread=1.2)

    def train(name, **kw):
        model = MLP([64, 256, 256, 10])
        model.loss_fn = classifier_loss_fn(model)
        params = M.init_params(model.param_specs(), jax.random.PRNGKey(0))
        opt, capture = make_optimizer(name, lr=0.05, **kw)
        taps_fn = (lambda p: model.make_taps(128, capture)) \
            if capture.needs_taps else None
        state = init_opt_state(model, opt, capture, params, stream.batch_at(0),
                               taps_fn=taps_fn)
        step = jax.jit(make_train_step(model, opt, capture, taps_fn=taps_fn))
        t = time_fn(step, params, state, stream.batch_at(0))
        for i in range(STEPS):
            params, state, m = step(params, state, stream.batch_at(i))
        return t, float(m['loss'])

    for label, name, kw in [('kfac@1', 'kfac', {'interval': 1}),
                            ('kfac@5', 'kfac', {'interval': 5}),
                            ('kfac@20', 'kfac', {'interval': 20}),
                            ('eva@1', 'eva', {})]:
        t, loss = train(name, **kw)
        emit(f'fig6/{label}', t, f'loss_at_{STEPS}={loss:.4f}')
