"""Paper Fig. 6, generalized by the refresh runtime: every method × policy.

The original figure studies K-FAC@{1,5,20} — per-step time falls with the
update interval but staleness costs loss, while Eva@1 needs no interval at
all.  With the curvature refresh runtime (``repro.schedule``) the interval
is a *policy*, and every method takes the same knob, so the grid is now
method × {every_k(1), every_k(5), every_k(20), adaptive} with the realized
per-policy refresh count, the staleness proxy, per-step time and final
loss in every cell.
"""
from __future__ import annotations

import jax

from benchmarks.common import emit, time_fn
from repro.core.registry import make_optimizer
from repro.data.synthetic import ClassStream
from repro.models import module as M
from repro.models.simple import MLP, classifier_loss_fn
from repro.schedule import runtime as schedrt
from repro.schedule.policy import adaptive, every_k
from repro.train.step import init_opt_state, make_train_step

STEPS = 40

METHODS = ['eva', 'eva_f', 'eva_s', 'foof', 'kfac', 'shampoo']

POLICIES = [
    ('every1', lambda: every_k(1)),
    ('every5', lambda: every_k(5)),
    ('every20', lambda: every_k(20)),
    ('adaptive', lambda: adaptive(threshold=0.05, max_interval=50)),
]


def run() -> None:
    stream = ClassStream(batch=128, dim=64, classes=10, spread=1.2)

    def train(name, policy):
        model = MLP([64, 256, 256, 10])
        model.loss_fn = classifier_loss_fn(model)
        params = M.init_params(model.param_specs(), jax.random.PRNGKey(0))
        opt, capture = make_optimizer(name, lr=0.05, policy=policy)
        taps_fn = (lambda p: model.make_taps(128, capture)) \
            if capture.needs_taps else None
        state = init_opt_state(model, opt, capture, params, stream.batch_at(0),
                               taps_fn=taps_fn)
        step = jax.jit(make_train_step(model, opt, capture, taps_fn=taps_fn))
        t = time_fn(step, params, state, stream.batch_at(0))
        for i in range(STEPS):
            params, state, m = step(params, state, stream.batch_at(i))
        sched = schedrt.schedule_metrics(state)
        return (t, float(m['loss']), int(sched['refreshes']),
                float(sched['staleness']))

    for name in METHODS:
        for plabel, make_policy in POLICIES:
            t, loss, refreshes, staleness = train(name, make_policy())
            emit(f'fig6/{name}@{plabel}', t,
                 f'loss_at_{STEPS}={loss:.4f};refreshes={refreshes}/{STEPS};'
                 f'staleness={staleness:.3g}')


if __name__ == '__main__':
    print('name,us_per_call,derived')
    run()
