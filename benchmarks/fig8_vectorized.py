"""Paper Fig. 8 + §5.6: vectorized algorithms track their originals.

Eva-f vs FOOF and Eva-s vs Shampoo on the autoencoder task: final losses
should be close (derived ratio ≈ 1), at a fraction of the step time."""
from __future__ import annotations

from benchmarks.common import emit
from benchmarks.fig4_autoencoder import train_one


def run() -> None:
    pairs = [('eva_f', 'foof'), ('eva_s', 'shampoo')]
    results = {}
    for name in ('eva_f', 'foof', 'eva_s', 'shampoo'):
        loss, us = train_one(name)
        results[name] = (loss, us)
        emit(f'fig8/ae/{name}', us, f'loss={loss:.4f}')
    for vec, orig in pairs:
        lv, tv = results[vec]
        lo, to = results[orig]
        emit(f'fig8/{vec}_vs_{orig}', 0.0,
             f'loss_ratio={lv / max(lo, 1e-9):.3f};speedup={to / max(tv, 1e-9):.2f}x')
