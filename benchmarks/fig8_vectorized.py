"""Paper Fig. 8 + §5.6: vectorized algorithms track their originals.

Eva-f vs FOOF and Eva-s vs Shampoo on the autoencoder task: final losses
should be close (derived ratio ≈ 1), at a fraction of the step time.

``--bucketed`` adds an end-to-end comparison on a deep *uniform* MLP (the
bucketing engine's best case: 12 same-shape hidden layers collapse into one
bucket): full eva train-step time with the bucketed ``precondition_tree``
engine vs a reference per-path Python-loop preconditioner (the pre-bucketing
repo state), plus the launch counts.
"""
from __future__ import annotations

import argparse

import jax

from benchmarks.common import emit, time_fn, write_json
from benchmarks.fig4_autoencoder import train_one


def run() -> None:
    pairs = [('eva_f', 'foof'), ('eva_s', 'shampoo')]
    results = {}
    for name in ('eva_f', 'foof', 'eva_s', 'shampoo'):
        loss, us = train_one(name)
        results[name] = (loss, us)
        emit(f'fig8/ae/{name}', us, f'loss={loss:.4f}')
    for vec, orig in pairs:
        lv, tv = results[vec]
        lo, to = results[orig]
        emit(f'fig8/{vec}_vs_{orig}', 0.0,
             f'loss_ratio={lv / max(lo, 1e-9):.3f};speedup={to / max(tv, 1e-9):.2f}x')


def run_bucketed() -> None:
    from repro.core import bucketing
    from repro.core import kv as kvlib
    from repro.core import precondition as pre
    from repro.core.clipping import kl_clip_trace
    from repro.core.eva import eva_preconditioner, _extract
    from repro.core.transform import (GradientTransformation, chain,
                                      scale_by_schedule)
    from repro.data.synthetic import ClassStream
    from repro.models import module as M
    from repro.models.simple import MLP, classifier_loss_fn
    from repro.train.step import init_opt_state, make_train_step

    def per_path_eva_preconditioner(gamma=0.03, kv_decay=0.95):
        """The pre-bucketing per-path dict loop, kept as the baseline."""
        from typing import NamedTuple

        fields = ('a_mean', 'b_mean')

        class PerPathState(NamedTuple):
            running: kvlib.RunningStats

        def init(params, extras=None):
            from repro.core.eva import _zeros_like_spec
            return PerPathState(running=kvlib.init_running(
                _zeros_like_spec(_extract(extras.stats, fields))))

        def update(updates, state, params=None, extras=None):
            fresh = _extract(extras.stats, fields)
            stats, running = kvlib.update_running(state.running, fresh, kv_decay)
            flat = kvlib.flatten_params(updates)
            for path, st in stats.items():
                flat[path] = pre.eva_precondition(
                    flat[path], st.a_mean, st.b_mean, gamma)
            return kvlib.unflatten_params(flat), PerPathState(running=running)

        return GradientTransformation(init, update)

    dims = [64] + [256] * 12 + [10]
    capture = kvlib.EVA_CAPTURE
    stream = ClassStream(batch=128, dim=64, classes=10)
    batch = stream.batch_at(0)
    times = {}
    for mode in ('per_path', 'bucketed'):
        model = MLP(dims)
        model.loss_fn = classifier_loss_fn(model)
        params = M.init_params(model.param_specs(), jax.random.PRNGKey(0))
        precon = (eva_preconditioner() if mode == 'bucketed'
                  else per_path_eva_preconditioner())
        opt = chain(precon, kl_clip_trace(1e-3, 0.03, 0.9),
                    scale_by_schedule(lambda _: 0.03))
        taps_fn = lambda p: model.make_taps(128, capture)  # noqa: E731
        state = init_opt_state(model, opt, capture, params, batch,
                               taps_fn=taps_fn)
        step = jax.jit(make_train_step(model, opt, capture, taps_fn=taps_fn))
        times[mode] = time_fn(step, params, state, batch)
    flat = kvlib.flatten_params(M.abstract_params(MLP(dims).param_specs()))
    weights = {p: s for p, s in flat.items() if p.endswith('/w')}
    n_buckets = len(bucketing.build_plan(weights).buckets)
    emit('fig8/bucketed/mlp13/per_path', times['per_path'],
         f'launches={len(weights)}')
    emit('fig8/bucketed/mlp13/bucketed', times['bucketed'],
         f'launches={n_buckets};step_speedup='
         f'{times["per_path"] / max(times["bucketed"], 1e-9):.2f}x')


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument('--bucketed', action='store_true',
                    help='bucketed-engine vs per-path-loop step time on a '
                         'deep uniform MLP')
    ap.add_argument('--json', default=None, metavar='PATH',
                    help='also write the emitted rows to PATH as JSON '
                         '(CI benchmark artifacts)')
    args = ap.parse_args()
    print('name,us_per_call,derived')
    if args.bucketed:
        run_bucketed()
    else:
        run()
    if args.json:
        write_json(args.json)


if __name__ == '__main__':
    main()
