"""Paper Table 1: time & memory complexity of the second-order update.

Measured on an L-layer MLP with hidden width d swept — optimizer *state*
bytes (the second-order memory) and preconditioning wall time.  The paper's
claims, in measurable form:
  Eva    state ~ O(2dL)   (sublinear in params)   time ~ O(d²L)
  K-FAC  state ~ O(2d²L)                          time ~ O(2d³L)
  FOOF   state ~ O(d²L);  Shampoo ~ O(2d²L);  SGD-momentum ~ O(params).
Derived column: state-bytes growth exponent w.r.t. d (≈1 for Eva, ≈2 for
KFs) — the asymptotic separation Table 1 asserts.
"""
from __future__ import annotations

import math

import jax
import numpy as np

from benchmarks.common import emit, time_fn, tree_bytes
from repro.core.registry import make_optimizer
from repro.core.transform import Extras
from repro.data.synthetic import ClassStream
from repro.models import module as M
from repro.models.simple import MLP, classifier_loss_fn
from repro.train.step import compute_grads_and_stats, init_opt_state

WIDTHS = (64, 128, 256)
LAYERS = 4
OPTS = ('sgd', 'adamw', 'eva', 'eva_f', 'eva_s', 'kfac', 'foof', 'shampoo', 'mfac')


def _setup(d: int):
    model = MLP([32, *([d] * LAYERS), 10])
    model.loss_fn = classifier_loss_fn(model)
    params = M.init_params(model.param_specs(), jax.random.PRNGKey(0))
    stream = ClassStream(batch=64, dim=32, classes=10, seed=0)
    return model, params, stream.batch_at(0)


def run() -> None:
    # SGD state (momentum, O(params)) is common to every optimizer here;
    # Table 1 is about the SECOND-ORDER state, so report the excess over SGD.
    sgd_bytes = {}
    for d in WIDTHS:
        model, params, batch = _setup(d)
        opt, capture = make_optimizer('sgd', lr=0.01)
        sgd_bytes[d] = tree_bytes(init_opt_state(model, opt, capture,
                                                 params, batch))

    for name in OPTS:
        extra_bytes, times = [], []
        for d in WIDTHS:
            model, params, batch = _setup(d)
            kw = {'m': 8} if name == 'mfac' else {}
            opt, capture = make_optimizer(name, lr=0.01, **kw)
            taps_fn = (lambda p, _m=model, _c=capture:
                       _m.make_taps(64, _c)) if capture.needs_taps else None
            st = init_opt_state(model, opt, capture, params, batch,
                                taps_fn=taps_fn)
            extra_bytes.append(max(tree_bytes(st) - sgd_bytes[d], 1))

            @jax.jit
            def step(p, s, b):
                loss, grads, stats = compute_grads_and_stats(
                    model, p, b, capture,
                    taps_fn(p) if taps_fn else None)
                u, s2 = opt.update(grads, s, params=p,
                                   extras=Extras(stats=stats, loss=loss))
                return u, s2

            times.append(time_fn(step, params, st, batch))
        # growth exponent of the second-order state in d:
        # Eva KVs ~ d^1, K-FAC/FOOF/Shampoo KFs ~ d^2, first-order ~ 0
        expo = (math.log(extra_bytes[-1] / extra_bytes[0])
                / math.log(WIDTHS[-1] / WIDTHS[0]))
        emit(f'table1/{name}/d{WIDTHS[-1]}', times[-1],
             f'second_order_state_bytes={extra_bytes[-1]};growth_exp={expo:.2f}')
