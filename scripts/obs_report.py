#!/usr/bin/env python
"""Telemetry run-analysis CLI — thin wrapper over ``repro.obs.report``.

    PYTHONPATH=src python scripts/obs_report.py runs/a/metrics.jsonl
    PYTHONPATH=src python scripts/obs_report.py --validate BENCH_*.json
    PYTHONPATH=src python scripts/obs_report.py --diff a.jsonl b.jsonl \\
        --max-regress 25

Exit codes: 0 ok · 1 schema-validation errors · 2 gated perf regression.
"""
import sys

from repro.obs import report

if __name__ == '__main__':
    sys.exit(report.main(sys.argv[1:]))
