#!/usr/bin/env bash
# Tier-1 verify — the exact command from ROADMAP.md.
# Run from the repo root; any extra args are passed through to pytest.
set -euo pipefail
cd "$(dirname "$0")/.."
export PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH}
# Everything here runs on CPU (pallas under interpret=True); without the
# pin, a host that has libtpu installed but no TPU hangs forever in
# accelerator discovery at the first jax import.  Caller override wins.
export JAX_PLATFORMS=${JAX_PLATFORMS:-cpu}
# pytest keeps only the LAST -m, so our 'not multihost' deselect would
# silently swallow (or be swallowed by) a caller-passed -m; withdraw ours
# when the caller brings their own marker expression
DESELECT=(-m "not multihost")
for a in "$@"; do [[ "$a" == "-m" ]] && DESELECT=(); done
if [[ "$(uname -s)" == "Linux" ]]; then
  # the multihost cells run (and are gated) separately below, so the main
  # run skips them rather than paying the slow subprocess compiles twice
  python -m pytest -x -q --durations=20 ${DESELECT[@]+"${DESELECT[@]}"} "$@"
else
  # no gated re-run on this platform — keep the multihost tests in the
  # main run instead of silently dropping them
  python -m pytest -x -q --durations=20 "$@"
fi

# The multi-device subprocess tests (forced 4 host devices; marked
# `multihost`) are the only coverage of the worker-sharded refresh exchange
# and the comm-layer collectives, so a Linux runner must not let them skip
# silently — a skip here usually means the subprocess environment lost
# PYTHONPATH or the XLA host-device flag stopped working.  The file list is
# explicit so hypothesis-module collection skips elsewhere can't mask a
# skipped multihost cell; add new multihost test files here too.
# The gate only runs for the FULL suite (no caller args): a developer
# narrowing the run with paths/-k/-m is doing a quick loop and must not
# pay (or be failed by) the ~15-min multihost subprocess cells.
MULTIHOST_FILES="tests/test_schedule.py tests/test_comm_exchange.py tests/test_pipeline.py tests/test_factor_sharded.py tests/test_elastic.py"
if [[ "$(uname -s)" == "Linux" && $# -eq 0 ]]; then
  # tee keeps the full output (tracebacks, subprocess stderr) in the CI log;
  # `|| true` so a failing pytest reaches the diagnostic below instead of
  # aborting inside the assignment under set -e/pipefail
  # shellcheck disable=SC2086
  python -m pytest -q --durations=20 -m multihost ${MULTIHOST_FILES} 2>&1 \
    | tee /tmp/tier1-multihost.log || true
  summary=$(tail -1 /tmp/tier1-multihost.log)
  echo "multihost cell: ${summary}"
  if [[ "${summary}" != *passed* || "${summary}" == *skipped* \
        || "${summary}" == *failed* || "${summary}" == *error* ]]; then
    echo "error: multi-device subprocess tests did not all run+pass" >&2
    echo "       (got: ${summary})" >&2
    exit 1
  fi
fi
