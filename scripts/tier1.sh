#!/usr/bin/env bash
# Tier-1 verify — the exact command from ROADMAP.md.
# Run from the repo root; any extra args are passed through to pytest.
set -euo pipefail
cd "$(dirname "$0")/.."
PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} python -m pytest -x -q "$@"
