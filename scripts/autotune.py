#!/usr/bin/env python
"""Kernel tile/impl autotuner CLI — thin wrapper over
``repro.kernels.autotune``.

    PYTHONPATH=src python scripts/autotune.py --shapes 512x384,1000x513 \\
        --out runs/tile_cache.json
    PYTHONPATH=src python scripts/autotune.py --shapes 64x48 \\
        --ops bilinear,matvec --update-defaults

Benchmarks each (op, shape, dtype) across the pure-XLA path and a small
Pallas block grid, writes the deterministic winner cache (the format
``dispatch.install_cache`` / ``--kernel-impl auto`` consume), and with
``--update-defaults`` merges it into the shipped
``src/repro/kernels/tile_defaults.json`` warm-start file.
"""
import argparse
import json
import sys
from pathlib import Path


def parse_shapes(text):
    shapes = []
    for tok in text.split(','):
        d_in, d_out = tok.lower().split('x')
        shapes.append((int(d_in), int(d_out)))
    return shapes


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__.split('\n')[0])
    ap.add_argument('--shapes', required=True,
                    help='comma list of d_inxd_out, e.g. 512x384,1000x513')
    ap.add_argument('--ops', default=None,
                    help='comma list from bilinear,matvec,rank1_update,'
                         'eva_fused,eva_f_fused (default: the three '
                         'primitives)')
    ap.add_argument('--dtypes', default='float32',
                    help='comma list of dtypes (default float32)')
    ap.add_argument('--reps', type=int, default=3)
    ap.add_argument('--out', default=None,
                    help='write the cache JSON here')
    ap.add_argument('--update-defaults', action='store_true',
                    help='merge winners into the shipped tile_defaults.json')
    args = ap.parse_args(argv)

    from repro.kernels import autotune, dispatch

    cache = autotune.tune(
        parse_shapes(args.shapes),
        ops=tuple(args.ops.split(',')) if args.ops else autotune.OPS,
        dtypes=tuple(args.dtypes.split(',')),
        bench=lambda fn: autotune.default_bench(fn, reps=args.reps))
    sys.stdout.write(autotune.dumps(cache))
    if args.out:
        autotune.write(cache, args.out)
        print(f'wrote {args.out}', file=sys.stderr)
    if args.update_defaults:
        path = dispatch._DEFAULTS_FILE
        base = json.loads(path.read_text()) if path.exists() else {}
        autotune.write(autotune.merge(base, cache), path)
        print(f'updated {path}', file=sys.stderr)
    return 0


if __name__ == '__main__':
    sys.path.insert(0, str(Path(__file__).resolve().parent.parent / 'src'))
    sys.exit(main())
