"""repro.sharding.compat: the version-tolerant mesh shim must work under
BOTH jax API generations — the real installed one, and the other generation
simulated via monkeypatching (so a single CI matrix cell covers both
code paths)."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.sharding import compat
from repro.sharding.constraints import (constrain, data_axes_in_scope,
                                        pmean_stats, shard_activations)

HAS_NEW_API = getattr(jax.sharding, 'AxisType', None) is not None \
    and hasattr(jax.sharding, 'get_abstract_mesh')


def test_make_mesh_installed_api():
    mesh = compat.make_mesh((1, 1), ('data', 'model'))
    assert tuple(mesh.axis_names) == ('data', 'model')
    assert compat.axes_all_auto(mesh)


def test_current_mesh_none_outside_context():
    assert compat.current_mesh() is None


def test_current_mesh_inside_context():
    mesh = compat.make_mesh((1,), ('data',))
    with compat.set_mesh(mesh):
        m = compat.current_mesh()
        assert m is not None
        assert 'data' in m.shape


def test_constrain_noop_outside_mesh():
    x = jnp.ones((4, 4))
    np.testing.assert_array_equal(np.asarray(constrain(x, 'data')), np.asarray(x))
    np.testing.assert_array_equal(np.asarray(shard_activations(x)), np.asarray(x))


def test_constrain_under_mesh_context():
    mesh = compat.make_mesh((1, 1), ('data', 'model'))
    x = jnp.ones((2, 4, 8))
    with compat.set_mesh(mesh):
        y = shard_activations(x)
    np.testing.assert_array_equal(np.asarray(y), np.asarray(x))


# ---------------------------------------------------------------------------
# Simulate the OTHER jax generation via monkeypatching


def test_current_mesh_new_api_path(monkeypatch):
    """Exercise the get_abstract_mesh branch even on old jax."""
    mesh = compat.make_mesh((1,), ('data',))
    monkeypatch.setattr(jax.sharding, 'get_abstract_mesh', lambda: mesh,
                        raising=False)
    m = compat.current_mesh()
    assert m is mesh


def test_current_mesh_new_api_empty(monkeypatch):
    class _Empty:
        empty = True
    monkeypatch.setattr(jax.sharding, 'get_abstract_mesh', lambda: _Empty(),
                        raising=False)
    assert compat.current_mesh() is None


def test_old_api_path(monkeypatch):
    """Force the 0.4.x fallback branch even on new jax."""
    if HAS_NEW_API:
        monkeypatch.delattr(jax.sharding, 'get_abstract_mesh', raising=False)
    assert compat.current_mesh() is None  # no mesh context active
    mesh = compat.make_mesh((1,), ('data',))
    with mesh:  # 0.4.x context mechanism: Mesh is a context manager
        m = compat.current_mesh()
        assert m is not None and 'data' in m.shape


def test_axes_all_auto_without_axis_types():
    class _NoTypes:
        pass
    assert compat.axes_all_auto(_NoTypes())


def test_make_mesh_passes_axis_types_on_new_api(monkeypatch):
    """When AxisType exists, make_mesh must request all-Auto axes."""
    sentinel = object()
    seen = {}

    def fake_make_mesh(shapes, names, **kw):
        seen.update(kw)
        return 'mesh'

    monkeypatch.setattr(compat, 'AXIS_TYPE_AUTO', sentinel)
    monkeypatch.setattr(jax, 'make_mesh', fake_make_mesh)
    assert compat.make_mesh((2,), ('data',)) == 'mesh'
    assert seen['axis_types'] == (sentinel,)


def test_bound_axis_names_and_pmean_stats():
    assert compat.bound_axis_names() == ()
    assert data_axes_in_scope() == ()
    # pmean_stats is the identity outside any shard_map scope
    tree = {'b': jnp.arange(3.0)}
    out = pmean_stats(tree)
    np.testing.assert_array_equal(np.asarray(out['b']), np.asarray(tree['b']))
    assert pmean_stats(None) is None


def test_pmean_stats_inside_shard_map():
    mesh = compat.make_mesh((1,), ('data',))
    from jax.sharding import PartitionSpec as P

    def body(x):
        assert data_axes_in_scope() == ('data',)
        return pmean_stats({'s': x})['s']

    f = compat.shard_map(body, mesh=mesh, in_specs=(P(),), out_specs=P(),
                         check=False)
    out = jax.jit(f)(jnp.arange(4.0))
    np.testing.assert_allclose(np.asarray(out), np.arange(4.0))
