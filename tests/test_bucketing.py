"""Bucketed ``precondition_tree`` must be BIT-IDENTICAL (atol=0) to the
per-layer loop over the ``precondition`` formulas, for every method, on
mixed-shape trees, scan-stacked leading dims, and the Pallas interpret path.

This is the contract that lets the optimizers batch same-shape layers into
one launch without changing a single ulp of the training trajectory.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import bucketing
from repro.core import kv as kvlib
from repro.core import precondition as pre

GAMMA = 0.03

# mixed shapes: two 3-path buckets, one singleton, one scan-stacked bucket
SHAPES = {
    'blk0/mlp/w': (16, 8),
    'blk1/mlp/w': (16, 8),
    'blk2/mlp/w': (16, 8),
    'head/w': (16, 4),
    'stack/attn/w': (3, 12, 8),   # lax.scan-stacked layers
    'stack/mlp/w': (3, 12, 8),
    'odd/w': (7, 5),              # non-128-aligned (pallas padding path)
}


def _psd(key, *shape):
    m = jax.random.normal(key, shape)
    return m @ jnp.swapaxes(m, -1, -2) + 0.1 * jnp.eye(shape[-1])


def _make_tree(seed=0):
    key = jax.random.PRNGKey(seed)
    grads, aux = {}, {}
    for i, (path, shape) in enumerate(SHAPES.items()):
        k = jax.random.fold_in(key, i)
        ks = jax.random.split(k, 5)
        lead, d_in, d_out = shape[:-2], shape[-2], shape[-1]
        grads[path] = jax.random.normal(ks[0], shape)
        aux[path] = kvlib.LayerStats(
            a_mean=jax.random.normal(ks[1], lead + (d_in,)),
            b_mean=jax.random.normal(ks[2], lead + (d_out,)),
            a_outer=_psd(ks[3], *lead, d_in, d_in),
            b_outer=_psd(ks[4], *lead, d_out, d_out))
    return grads, aux


PER_LAYER = {
    'eva': lambda g, st, use_pallas: pre.eva_precondition(
        g, st.a_mean, st.b_mean, GAMMA, use_pallas=use_pallas),
    'eva_f': lambda g, st, use_pallas: pre.eva_f_precondition(
        g, st.a_mean, GAMMA, use_pallas=use_pallas),
    'eva_s': lambda g, st, use_pallas: pre.eva_s_precondition(
        g, st.a_mean, st.b_mean, GAMMA, use_pallas=use_pallas),
    'foof': lambda g, st, use_pallas: pre.foof_precondition(
        g, st.a_outer, GAMMA),
    'kfac': lambda g, st, use_pallas: pre.kfac_precondition(
        g, st.a_outer, st.b_outer, GAMMA),
    'shampoo': lambda g, st, use_pallas: pre.shampoo_precondition(
        g, st.a_outer, st.b_outer, GAMMA),
}

ALL_METHODS = sorted(PER_LAYER)


def _assert_bit_identical(out, ref):
    for path in ref:
        a, b = np.asarray(out[path]), np.asarray(ref[path])
        assert a.dtype == b.dtype, path
        np.testing.assert_array_equal(a, b, err_msg=path)  # atol=0


@pytest.mark.parametrize('method', ALL_METHODS)
def test_bucketed_matches_per_layer_loop(method):
    grads, aux = _make_tree()
    ref = {p: PER_LAYER[method](grads[p], aux[p], False) for p in grads}
    out = pre.precondition_tree(grads, aux, method, GAMMA)
    _assert_bit_identical(out, ref)


@pytest.mark.parametrize('method', ['eva', 'eva_f', 'eva_s'])
def test_bucketed_matches_per_layer_loop_pallas(method):
    """use_pallas=True (interpret on CPU): the grid-folded stacked kernels
    must match per-path kernel calls bit-for-bit."""
    grads, aux = _make_tree(seed=1)
    ref = {p: PER_LAYER[method](grads[p], aux[p], True) for p in grads}
    out = pre.precondition_tree(grads, aux, method, GAMMA, use_pallas=True)
    _assert_bit_identical(out, ref)


@pytest.mark.parametrize('method', ['eva', 'kfac'])
def test_cached_operator_path(method):
    """The *_cached application (what the interval-cached optimizers run)
    equals the per-path einsum loop."""
    grads, aux = _make_tree(seed=2)
    ops = {p: kvlib.LayerStats(a_outer=aux[p].a_outer, b_outer=aux[p].b_outer)
           for p in grads}
    out = pre.precondition_tree(grads, ops, 'kfac_cached', GAMMA)
    ref = {p: pre.apply_two_sided(grads[p], aux[p].a_outer, aux[p].b_outer)
           for p in grads}
    _assert_bit_identical(out, ref)


def test_non_preconditioned_paths_pass_through():
    grads, aux = _make_tree()
    grads['bias/b'] = jnp.arange(4.0)
    out = pre.precondition_tree(grads, aux, 'eva', GAMMA)
    np.testing.assert_array_equal(np.asarray(out['bias/b']), np.arange(4.0))


def test_dtype_segregation():
    """Same shape, different dtype -> different buckets; dtypes preserved."""
    key = jax.random.PRNGKey(3)
    grads = {
        'a/w': jax.random.normal(key, (8, 4), jnp.float32),
        'b/w': jax.random.normal(key, (8, 4)).astype(jnp.bfloat16),
    }
    aux = {p: kvlib.LayerStats(a_mean=jnp.ones((8,)), b_mean=jnp.ones((4,)))
           for p in grads}
    plan = bucketing.build_plan(grads)
    assert len(plan.buckets) == 2
    out = pre.precondition_tree(grads, aux, 'eva', GAMMA, plan=plan)
    assert out['a/w'].dtype == jnp.float32
    assert out['b/w'].dtype == jnp.bfloat16
    ref = {p: PER_LAYER['eva'](grads[p], aux[p], False) for p in grads}
    _assert_bit_identical(out, ref)


def test_plan_determinism_and_layout():
    grads, _ = _make_tree()
    plan = bucketing.build_plan(grads)
    plan2 = bucketing.build_plan(dict(reversed(list(grads.items()))))
    assert plan == plan2  # insertion order must not matter
    assert plan is plan2  # memoized on the shape signature
    # the three (16, 8) paths share one bucket, sorted
    by_key = {b.key: b for b in plan.buckets}
    b = by_key[bucketing.bucket_key((16, 8), jnp.float32)]
    assert b.paths == ('blk0/mlp/w', 'blk1/mlp/w', 'blk2/mlp/w')


def test_gather_scatter_roundtrip():
    grads, _ = _make_tree()
    plan = bucketing.build_plan(grads)
    back = bucketing.scatter(plan, bucketing.gather(plan, grads))
    _assert_bit_identical(back, grads)


def test_bucketed_aux_equals_flat_aux():
    """State-resident (pre-gathered) aux must give the same result as flat
    per-path aux — this is the optimizer fast path."""
    grads, aux = _make_tree(seed=4)
    plan = bucketing.build_plan(grads)
    aux_b = bucketing.gather_tree(plan, aux)
    out_flat = pre.precondition_tree(grads, aux, 'eva', GAMMA, plan=plan)
    out_bucketed = pre.precondition_tree(grads, aux_b, 'eva', GAMMA, plan=plan)
    _assert_bit_identical(out_bucketed, out_flat)


def test_min_bucket_size_marks_small_buckets_unstacked():
    """Default threshold: N<=2 buckets skip the stack/unstack copies (the
    table5 CPU numbers — ROADMAP 'bucket gather cost'); grouping, keys and
    state layout are unchanged."""
    grads, _ = _make_tree()
    plan = bucketing.build_plan(grads)
    by_key = {b.key: b for b in plan.buckets}
    assert by_key[bucketing.bucket_key((16, 8), jnp.float32)].stacked  # N=3
    assert not by_key[bucketing.bucket_key((16, 4), jnp.float32)].stacked  # N=1
    assert not by_key[bucketing.bucket_key((3, 12, 8), jnp.float32)].stacked  # N=2
    # explicit threshold overrides
    all_stacked = bucketing.build_plan(grads, min_bucket_size=1)
    assert all(b.stacked for b in all_stacked.buckets)
    none_stacked = bucketing.build_plan(grads, min_bucket_size=99)
    assert not any(b.stacked for b in none_stacked.buckets)
    # same grouping either way
    assert [b.paths for b in plan.buckets] == \
        [b.paths for b in all_stacked.buckets]


@pytest.mark.parametrize('method', ['eva', 'eva_f', 'eva_s', 'eva_cached',
                                    'kfac_cached'])
@pytest.mark.parametrize('min_size', [1, 2, 99])
def test_min_bucket_size_output_bit_identical(method, min_size):
    """For every path the OPTIMIZERS actually run (rank-one broadcast +
    cached-operator application), the threshold is invisible: any
    min_bucket_size gives bit-identical outputs to the per-layer loop."""
    grads, aux = _make_tree(seed=7)
    plan = bucketing.build_plan(grads, min_bucket_size=min_size)
    if method.endswith('_cached'):
        ops = {p: kvlib.LayerStats(a_outer=aux[p].a_outer,
                                   b_outer=aux[p].b_outer) for p in grads}
        out = pre.precondition_tree(grads, ops, 'kfac_cached', GAMMA,
                                    plan=plan)
        ref = {p: pre.apply_two_sided(grads[p], aux[p].a_outer,
                                      aux[p].b_outer) for p in grads}
    else:
        out = pre.precondition_tree(grads, aux, method, GAMMA, plan=plan)
        ref = {p: PER_LAYER[method](grads[p], aux[p], False) for p in grads}
    _assert_bit_identical(out, ref)


@pytest.mark.parametrize('method', ['foof', 'kfac', 'shampoo'])
@pytest.mark.parametrize('min_size', [1, 99])
def test_min_bucket_size_lapack_methods_allclose(min_size, method):
    """The direct solve/eigh methods flip between a compiled ``lax.map``
    body (stacked) and eager per-path calls (unstacked), which — like
    jit-vs-eager (see test_under_jit) — may differ in the last ulp; they
    must still agree to float tolerance at every threshold.  (The
    optimizers themselves only use the *_cached application, which is
    exact — see test_min_bucket_size_output_bit_identical.)"""
    grads, aux = _make_tree(seed=7)
    ref = {p: PER_LAYER[method](grads[p], aux[p], False) for p in grads}
    plan = bucketing.build_plan(grads, min_bucket_size=min_size)
    out = pre.precondition_tree(grads, aux, method, GAMMA, plan=plan)
    for p in ref:
        np.testing.assert_allclose(np.asarray(out[p]), np.asarray(ref[p]),
                                   rtol=1e-5, atol=1e-6, err_msg=p)


def test_min_bucket_size_with_bucketed_state_aux():
    """Optimizer state stays bucket-stacked for ALL buckets; the small-
    bucket path must slice it per item and still match."""
    grads, aux = _make_tree(seed=8)
    plan = bucketing.build_plan(grads, min_bucket_size=99)  # all unstacked
    aux_b = bucketing.gather_tree(plan, aux)  # state layout: always stacked
    out = pre.precondition_tree(grads, aux_b, 'eva', GAMMA, plan=plan)
    ref = {p: PER_LAYER['eva'](grads[p], aux[p], False) for p in grads}
    _assert_bit_identical(out, ref)


def test_under_jit():
    """The whole engine must trace cleanly (plans are static metadata)."""
    grads, aux = _make_tree(seed=5)

    @jax.jit
    def run(g, a):
        return pre.precondition_tree(g, a, 'eva', GAMMA)

    out = run(grads, aux)
    eager = pre.precondition_tree(grads, aux, 'eva', GAMMA)
    for p in eager:
        # jit fuses differently than eager -> last-ulp differences only
        np.testing.assert_allclose(np.asarray(out[p]), np.asarray(eager[p]),
                                   rtol=1e-5, atol=1e-6)
