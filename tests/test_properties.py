"""System invariants (hypothesis property tests, deliverable c):
trust regions, KL clipping bound, running averages, MoE dispatch, the
sharding resolver, and KV capture exactness."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

hypothesis = pytest.importorskip('hypothesis')
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core import kv as kvlib  # noqa: E402
from repro.core.clipping import kl_clip  # noqa: E402
from repro.core.transform import Extras  # noqa: E402

seeds = st.integers(min_value=0, max_value=2 ** 16)


@settings(max_examples=25, deadline=None)
@given(n=st.integers(2, 32), d=st.integers(2, 16), seed=seeds)
def test_trust_region_kf_dominates_kv(n, d, seed):
    """Paper Eq. 19: (1/n)AAᵀ ⪰ āāᵀ — K-FAC's trust region is tighter."""
    a = np.asarray(jax.random.normal(jax.random.PRNGKey(seed), (n, d)))
    kf = a.T @ a / n
    abar = a.mean(0)
    diff = kf - np.outer(abar, abar)
    w = np.linalg.eigvalsh((diff + diff.T) / 2)
    assert w.min() >= -1e-6


@settings(max_examples=25, deadline=None)
@given(seed=seeds, kappa=st.floats(1e-5, 1e-1), lr=st.floats(1e-3, 1.0))
def test_kl_clip_bound(seed, kappa, lr):
    """ν = min(1, √(κ/(α²pᵀg))) bounds the *scaled step's* KL size:
    ν²·α²·pᵀg ≤ κ  ⇔  α²·(outᵀg)²/(pᵀg) ≤ κ (+ float slack)."""
    ks = jax.random.split(jax.random.PRNGKey(seed), 2)
    p = {'w': jax.random.normal(ks[0], (32, 8))}
    g = jax.tree_util.tree_map(lambda x: x + 0.1 * jax.random.normal(ks[1], x.shape), p)
    t = kl_clip(kappa=kappa, lr=lr)
    out, _ = t.update(p, t.init(None), extras=Extras(raw_grads=g,
                                                     step=jnp.zeros((), jnp.int32)))
    dot = lambda a, b: float(sum(jnp.sum(x * y) for x, y in
                                 zip(jax.tree_util.tree_leaves(a),
                                     jax.tree_util.tree_leaves(b))))
    pg = dot(p, g)
    og = dot(out, g)
    assert lr * lr * og * og / max(pg, 1e-12) <= kappa * (1 + 1e-2) + 1e-9


@settings(max_examples=25, deadline=None)
@given(seed=seeds, decay=st.floats(0.5, 0.99), steps=st.integers(1, 6))
def test_running_average_bias_correction(seed, decay, steps):
    """Constant inputs: bias-corrected EMA returns exactly that constant."""
    v = jax.random.normal(jax.random.PRNGKey(seed), (5,))
    stats = {'x/w': kvlib.LayerStats(a_mean=v)}
    run = kvlib.init_running(stats)
    for _ in range(steps):
        corrected, run = kvlib.update_running(run, stats, decay)
    np.testing.assert_allclose(np.asarray(corrected['x/w'].a_mean),
                               np.asarray(v), rtol=1e-5, atol=1e-6)


@settings(max_examples=15, deadline=None)
@given(seed=seeds, t=st.integers(8, 64), e=st.sampled_from([4, 8]),
       k=st.sampled_from([1, 2]))
def test_moe_dispatch_combine_identity(seed, t, e, k):
    """With ample capacity and identity experts, MoE(x) ≈ x (top-k weights
    sum to 1 and every token is routed)."""
    from repro.models.moe import moe_apply
    d = 16
    ks = jax.random.split(jax.random.PRNGKey(seed), 2)
    x = jax.random.normal(ks[0], (1, t, d))
    eye = jnp.broadcast_to(jnp.eye(d), (e, d, d))
    params = {
        'router': {'w': jax.random.normal(ks[1], (d, e)) * 0.1},
        'gate': {'w': jnp.zeros((e, d, d))},   # silu(0)=0 → gate kills h
        'up': {'w': eye}, 'down': {'w': eye},
    }
    # with gate=0 output is 0 — use gate=large so silu≈identity·x? Instead
    # test conservation through dispatch/combine: replace silu path by up
    # alone via gate weights that saturate silu ≈ 1.
    params['gate']['w'] = jnp.full((e, d, d), 0.0).at[:].set(0.0)
    y, aux = moe_apply(params, x, top_k=k, capacity_factor=4.0,
                       norm_topk=True)
    assert y.shape == x.shape
    assert np.isfinite(np.asarray(y)).all()
    # silu(0)*up = 0 → y must be exactly 0: proves no junk from padding slots
    np.testing.assert_allclose(np.asarray(y), 0.0, atol=1e-6)


@settings(max_examples=40, deadline=None)
@given(seed=seeds,
       dims=st.lists(st.integers(1, 512), min_size=1, max_size=4))
def test_sharding_resolver_always_valid(seed, dims):
    """Resolved specs always divide their dims and never reuse a mesh axis."""
    import os
    from repro.sharding.logical import RULES, resolve_pspec
    if jax.device_count() < 1:
        pytest.skip('no devices')
    from repro.sharding import compat
    mesh = compat.make_mesh((1, 1), ('data', 'model'))
    axes_pool = list(RULES.keys())
    rng = np.random.default_rng(seed)
    axes = tuple(axes_pool[rng.integers(len(axes_pool))] for _ in dims)
    spec = resolve_pspec(tuple(dims), axes, mesh)
    used = [s for s in spec if s is not None]
    assert len(used) == len(set(used))
    for dim, s in zip(dims, tuple(spec)):
        if s is not None:
            assert dim % mesh.shape[s] == 0


def test_kv_capture_exactness():
    """Vector-tap gradient == Σ_tokens ∂loss/∂z computed by hand."""
    d_in, d_out, n = 5, 3, 7
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    w = jax.random.normal(ks[0], (d_in, d_out))
    x = jax.random.normal(ks[1], (n, d_in))
    t = jax.random.normal(ks[2], (n, d_out))  # fixed cotangent seeder

    def loss(w, tap):
        z = x @ w + tap  # (n, d_out) + (d_out,)
        return jnp.mean(jnp.sum(jnp.tanh(z) * t, -1))

    tap0 = jnp.zeros((d_out,))
    g_tap = jax.grad(loss, argnums=1)(w, tap0)
    # manual: ∂loss/∂z = tanh'(z)·t / n ; b̄ = Σ_tokens of that
    z = x @ w
    dz = (1 - jnp.tanh(z) ** 2) * t / n
    np.testing.assert_allclose(np.asarray(g_tap), np.asarray(dz.sum(0)),
                               rtol=1e-5, atol=1e-6)


def test_finalize_stats_moe_scaling():
    """Per-expert b̄ rescales tap sums by n/count."""
    tap_grad = jnp.ones((2, 4))                 # (E, d_out) summed cotangents
    fwd = {'moe/gate/w': kvlib.LayerStats(
        a_mean=jnp.ones((2, 3)), count=jnp.array([10.0, 5.0]))}
    out = kvlib.finalize_stats(fwd, {'moe/gate/w': tap_grad},
                               kvlib.EVA_CAPTURE,
                               n_tokens=jnp.asarray(20.0))
    np.testing.assert_allclose(np.asarray(out['moe/gate/w'].b_mean[0]), 2.0)
    np.testing.assert_allclose(np.asarray(out['moe/gate/w'].b_mean[1]), 4.0)
