"""Integration: trainer resume bit-exactness, preemption checkpoint,
compressed-DP parity, flash-vs-naive model equivalence."""
import os
import signal

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.sharding import compat

from repro.configs.registry import demo_lm
from repro.core.registry import make_optimizer
from repro.data.synthetic import LMStream
from repro.models import build_model
from repro.models import module as M
from repro.train import checkpoint as ckpt
from repro.train.compression import make_dp_train_step
from repro.train.step import init_opt_state, make_train_step
from repro.train.trainer import Trainer, TrainerConfig


def _setup():
    cfg = demo_lm('small')
    model = build_model(cfg)
    params = M.init_params(model.param_specs(), jax.random.PRNGKey(0))
    data = LMStream(vocab=cfg.vocab, seq_len=32, batch=8, seed=1)
    return cfg, model, params, data


def test_resume_bit_exact(tmp_path):
    cfg, model, params, data = _setup()
    opt, capture = make_optimizer('eva', lr=0.05)

    # uninterrupted 10 steps
    tc = TrainerConfig(total_steps=10, log_every=100, ckpt_every=0,
                       out_dir=str(tmp_path / 'a'))
    p_full, _, h_full = Trainer(model, opt, capture, tc).fit(params, data,
                                                             resume=False)

    # 5 steps + checkpoint, then resume for 5 more
    tc1 = TrainerConfig(total_steps=5, log_every=100, ckpt_every=5,
                        out_dir=str(tmp_path / 'b'))
    Trainer(model, opt, capture, tc1).fit(params, data, resume=False)
    tc2 = TrainerConfig(total_steps=10, log_every=100, ckpt_every=5,
                        out_dir=str(tmp_path / 'b'))
    p_res, _, h_res = Trainer(model, opt, capture, tc2).fit(params, data)

    np.testing.assert_allclose(np.asarray(h_res[-1]), np.asarray(h_full[-1]),
                               rtol=1e-5, atol=1e-6)
    jax.tree_util.tree_map(
        lambda a, b: np.testing.assert_allclose(
            np.asarray(a), np.asarray(b), rtol=1e-4, atol=1e-5),
        p_full, p_res)


def test_preemption_checkpoints_and_exits(tmp_path):
    cfg, model, params, data = _setup()
    opt, capture = make_optimizer('sgd', lr=0.05)
    tc = TrainerConfig(total_steps=1000, log_every=10_000, ckpt_every=0,
                       out_dir=str(tmp_path))
    tr = Trainer(model, opt, capture, tc)
    orig = tr.step_fn
    count = {'n': 0}

    def wrapped(*a):
        count['n'] += 1
        if count['n'] == 4:
            tr._preempted = True  # simulate SIGTERM delivery
        return orig(*a)

    tr.step_fn = wrapped
    tr.fit(params, data, resume=False)
    assert count['n'] == 4  # stopped promptly
    assert ckpt.latest_step(tmp_path / 'ckpt') == 4  # saved before exit


def test_compressed_dp_matches_uncompressed_closely():
    cfg, model, params, data = _setup()
    opt, capture = make_optimizer('eva', lr=0.05)
    mesh = compat.make_mesh((1,), ('data',))
    losses = {}
    for compress in (False, True):
        step_fn, init_err = make_dp_train_step(model, opt, capture, mesh,
                                               compress=compress)
        st = init_opt_state(model, opt, capture, params, data.batch_at(0))
        err = init_err(params)
        p = params
        for i in range(8):
            p, st, err, m = step_fn(p, st, err, data.batch_at(i))
        losses[compress] = float(m['loss'])
    assert abs(losses[True] - losses[False]) / losses[False] < 0.05


def test_flash_config_matches_naive_loss():
    cfg, model, params, data = _setup()
    batch = data.batch_at(0)
    l1 = model.loss_fn(params, None, batch, None)[0]
    cfg2 = cfg.replace(attn_impl='flash', q_chunk=16, k_chunk=16)
    model2 = build_model(cfg2)
    l2 = model2.loss_fn(params, None, batch, None)[0]
    np.testing.assert_allclose(float(l1), float(l2), rtol=1e-4)
