"""Exchange layer (repro.comm.exchange): owned-slice refresh equivalence,
traffic accounting, and config plumbing.

Contracts proven here:
  * the owned-slice gather refresh exchange (``exchange='gather'``, the
    default) is BIT-exact (atol=0) against the legacy full-stack
    zero-padded psum for ALL SIX optimizers on a 4-device host mesh (f32
    codec), state included — and within 1e-2 relative under the int8
    codec;
  * raw gather reconstruction is atol=0 for the identity codec and for
    bf16-of-bf16-representable state;
  * ``topology='pod'`` (pod-local ownership, intra-pod ICI slice gather +
    one cross-pod zero-padded bucket psum) is atol=0 vs psum on a (2,2)
    ('pod','data') mesh, and the assignment keeps every bucket inside one
    pod with balanced intra-pod counts;
  * the int8 gradient all-reduce under shard_map matches the historical
    ``quantize_allreduce`` semantics and reports zero saturation;
  * at W=4 the owned-slice exchange moves ≥2× fewer logical bytes than the
    full-stack psum on the qwen2-0.5b bucket structure (the acceptance
    number ``benchmarks/roofline.py`` records);
  * the static gather maps cover every stack row exactly once and pad to
    the max per-worker count;
  * ``Extras.comm`` threads the config end to end.
"""
import json
import subprocess
import sys
import textwrap
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.comm import exchange, metrics
from repro.comm.codec import F32, INT8_EF
from repro.core import bucketing
from repro.core.transform import Extras
from repro.schedule import ownership


# ---------------------------------------------------------------------------
# Static gather maps


def test_gather_maps_cover_and_pad():
    owner = (0, 1, 2, 3, 0, 0)            # worker 0 owns 3 items
    send, src, m = exchange._gather_maps(owner, 4)
    assert m == 3 and send.shape == (4, 3) and src.shape == (6,)
    # every worker's row lists its owned items (padded by repetition)
    assert set(send[0]) == {0, 4, 5}
    assert set(send[1]) == {1} and set(send[2]) == {2} and set(send[3]) == {3}
    # src recovers each item from its owner's slot, all distinct
    flat = np.full(4 * m, -1, np.int64)
    for w in range(4):
        for j, i in enumerate(send[w]):
            if flat[w * m + j] == -1:
                flat[w * m + j] = i
    recovered = flat[src]
    np.testing.assert_array_equal(recovered, np.arange(6))


def test_gather_maps_idle_worker():
    send, src, m = exchange._gather_maps((0, 0), 4)   # workers 1-3 idle
    assert m == 2
    np.testing.assert_array_equal(src, [0, 1])
    assert (send[1:] == 0).all()          # idle workers send padding


def test_pod_slice_owners_stay_pod_local():
    """topology='pod': every bucket's slices are owned inside ONE pod, the
    intra-pod counts are balanced, and the map is deterministic."""
    flat = {f'b{i}/w': jnp.zeros((8, 4)) for i in range(5)}
    flat['stack/w'] = jnp.zeros((6, 8, 4))
    plan = bucketing.build_plan(flat)
    cost = ownership.inverse_cost('both')
    own = ownership.assign_pod_slice_owners(plan, cost, (2, 2))
    used_pods = set()
    for b in plan.buckets:
        o = own[b.key]
        assert o.shape == (len(b.paths) * ownership.lead_size(b),)
        pods = {int(w) // 2 for w in o}
        assert len(pods) == 1, (b.key, o)          # pod-local
        used_pods |= pods
        counts = np.bincount(np.asarray(o) % 2, minlength=2)
        assert counts.max() - counts.min() <= 1    # intra-pod balance
    assert used_pods == {0, 1}                     # buckets LPT over pods
    again = ownership.assign_pod_slice_owners(plan, cost, (2, 2))
    for k in own:
        np.testing.assert_array_equal(own[k], again[k])


# ---------------------------------------------------------------------------
# Config plumbing


def test_exchange_config_defaults_and_validation():
    cfg = exchange.ExchangeConfig()
    assert cfg.exchange == 'gather' and cfg.grads == 'int8'
    assert cfg.stats == 'f32' and cfg.codec == 'f32'
    with pytest.raises(ValueError):
        exchange.ExchangeConfig(exchange='broadcast')


def test_from_extras():
    assert exchange.from_extras(None) == exchange.ExchangeConfig()
    assert exchange.from_extras(Extras()) == exchange.ExchangeConfig()
    cfg = exchange.ExchangeConfig(codec='int8', exchange='psum')
    assert exchange.from_extras(Extras(comm=cfg)) is cfg


def test_pmean_stats_codec_noop_outside_mesh():
    from repro.sharding.constraints import pmean_stats
    tree = {'s': jnp.ones((3, 3))}
    for codec in (None, 'f32', 'bf16', 'int8'):
        out = pmean_stats(tree, codec=codec)
        np.testing.assert_array_equal(np.asarray(out['s']),
                                      np.asarray(tree['s']))
    assert pmean_stats(None, codec='int8') is None


# ---------------------------------------------------------------------------
# Traffic accounting: the W=4 acceptance number on the real bucket structure


def _qwen_inverse_stacks():
    """The slice-granular cached-inverse stacks of qwen2-0.5b, shapes only
    — (N·lead, d, d) per side, mirroring what ``sharded_refresh``
    exchanges."""
    from repro.configs.registry import get_config
    from repro.models import build_model
    from repro.models import module as M

    cfg = get_config('qwen2-0.5b')
    model = build_model(cfg)
    specs = M.flatten_specs(model.param_specs())
    precon = {p: specs[p] for p in sorted(set(model.precon_paths()) & set(specs))}
    plan = bucketing.build_plan(precon)
    return plan, exchange.slice_stack_specs(plan, 'both')


def test_owned_slice_bytes_at_w4_at_least_2x_smaller():
    plan, stacks = _qwen_inverse_stacks()
    world = 4
    owners = ownership.assign_slice_owners(plan,
                                           ownership.inverse_cost('both'),
                                           world)
    psum_b = exchange.refresh_exchange_bytes(plan, owners, stacks, world,
                                             mode='psum')
    ag_b = exchange.refresh_exchange_bytes(plan, owners, stacks, world,
                                           codec='f32', mode='gather')
    assert psum_b > 0 and ag_b > 0
    ratio = psum_b / ag_b
    assert ratio >= 2.0, (psum_b, ag_b, ratio)
    # int8 refresh wire shrinks it ~4x further
    ag_i8 = exchange.refresh_exchange_bytes(plan, owners, stacks, world,
                                            codec='int8', mode='gather')
    assert psum_b / ag_i8 >= 2.0 * 3.5


def test_owned_slice_bytes_padding_counted():
    """3 equal items over 2 workers: M=2, so the all-gather still moves
    2/3 of the stack per worker (padding is not free) — the accounting
    must say so rather than the idealized 1/W."""
    plan = bucketing.build_plan({f'l{i}/w': jnp.zeros((4, 4)) for i in range(3)})
    owners = {plan.buckets[0].key: np.array([0, 1, 0])}
    stacks = {plan.buckets[0].key: jax.ShapeDtypeStruct((3, 4, 4), jnp.float32)}
    ag = exchange.refresh_exchange_bytes(plan, owners, stacks, 2,
                                         codec='f32', mode='gather')
    assert ag == 2 * 4 * 4 * 4            # M=2 rows of 4x4 f32
    ps = exchange.refresh_exchange_bytes(plan, owners, stacks, 2, mode='psum')
    assert ps == 3 * 4 * 4 * 4


# ---------------------------------------------------------------------------
# 4-device equivalence: psum vs owned-slice all-gather for all six methods
# (subprocess: the forced 4-device flag must not leak into this process)

_EQUIV_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    import json
    import jax, jax.numpy as jnp, numpy as np
    from jax.sharding import PartitionSpec as P
    from repro.comm import metrics
    from repro.comm.exchange import (ExchangeConfig, allgather_owned_slices,
                                     allreduce_mean_tree)
    from repro.core import bucketing
    from repro.schedule import ownership
    from repro.core import kv as kvlib
    from repro.core.eva import eva_preconditioner
    from repro.core.eva_f import eva_f_preconditioner
    from repro.core.eva_s import eva_s_preconditioner
    from repro.core.foof import foof_preconditioner
    from repro.core.kfac import kfac_preconditioner
    from repro.core.shampoo import shampoo_preconditioner
    from repro.core.transform import Extras
    from repro.schedule.policy import every_k
    from repro.schedule.runtime import RefreshRuntime
    from repro.sharding import compat

    SHAPES = {'blk0/w': (8, 4), 'blk1/w': (8, 4), 'blk2/w': (8, 4),
              'head/w': (8, 3), 'stack/w': (2, 6, 4)}

    def psd(key, *shape):
        m = jax.random.normal(key, shape)
        return m @ jnp.swapaxes(m, -1, -2) + 0.1 * jnp.eye(shape[-1])

    def grads(seed):
        key = jax.random.PRNGKey(seed)
        return {p: jax.random.normal(jax.random.fold_in(key, i), s)
                for i, (p, s) in enumerate(SHAPES.items())}

    def stats(seed):
        key = jax.random.PRNGKey(1000 + seed)
        out = {}
        for i, (p, s) in enumerate(SHAPES.items()):
            ks = jax.random.split(jax.random.fold_in(key, i), 4)
            lead, d_in, d_out = s[:-2], s[-2], s[-1]
            out[p] = kvlib.LayerStats(
                a_mean=jax.random.normal(ks[0], lead + (d_in,)),
                b_mean=jax.random.normal(ks[1], lead + (d_out,)),
                a_outer=psd(ks[2], *lead, d_in, d_in),
                b_outer=psd(ks[3], *lead, d_out, d_out))
        return out

    MAKERS = {
        'eva': lambda: eva_preconditioner(0.03, 0.9, policy=every_k(2)),
        'eva_f': lambda: eva_f_preconditioner(0.03, 0.9, policy=every_k(2)),
        'eva_s': lambda: eva_s_preconditioner(0.03, 0.9, policy=every_k(2)),
        'foof': lambda: foof_preconditioner(0.03, 0.9, policy=every_k(2)),
        'kfac': lambda: kfac_preconditioner(0.03, 0.9, policy=every_k(2)),
        'shampoo': lambda: shampoo_preconditioner(1e-4, policy=every_k(2)),
    }
    NEEDS_STATS = {'eva', 'eva_f', 'foof', 'kfac'}
    STEPS = 3
    mesh = compat.make_mesh((4,), ('data',))
    params = kvlib.unflatten_params(grads(0))

    def run(method, comm):
        opt = MAKERS[method]()
        rt = RefreshRuntime(shard_refresh=True)
        ex = lambda t: (Extras(stats=stats(t), sched=rt, comm=comm)
                        if method in NEEDS_STATS
                        else Extras(sched=rt, comm=comm))
        state = opt.init(params, ex(0))

        def body(g, s, st):
            e = (Extras(stats=st, sched=rt, comm=comm)
                 if method in NEEDS_STATS else Extras(sched=rt, comm=comm))
            return opt.update(g, s, extras=e)

        in_specs = (P(), P(), P()) if method in NEEDS_STATS else (P(), P())
        step = jax.jit(compat.shard_map(
            (body if method in NEEDS_STATS
             else (lambda g, s: body(g, s, None))),
            mesh=mesh, in_specs=in_specs, out_specs=(P(), P()), check=False))
        outs = []
        for t in range(STEPS):
            args = (grads(t), state, stats(t)) if method in NEEDS_STATS \
                else (grads(t), state)
            out, state = step(*args)
            outs.append(out)
        return outs, state

    def maxdiff(a, b):
        return max(float(np.max(np.abs(
            np.asarray(x).astype(np.float64) -
            np.asarray(y).astype(np.float64))))
            for x, y in zip(jax.tree_util.tree_leaves(a),
                            jax.tree_util.tree_leaves(b)))

    def maxabs(a):
        return max(float(np.max(np.abs(np.asarray(x))))
                   for x in jax.tree_util.tree_leaves(a))

    rec = {'devices': jax.device_count(), 'methods': {}}
    for method in sorted(MAKERS):
        o_ps, s_ps = run(method, ExchangeConfig(exchange='psum'))
        o_ag, s_ag = run(method, ExchangeConfig(exchange='gather'))
        o_i8, s_i8 = run(method, ExchangeConfig(exchange='gather',
                                                codec='int8'))
        rec['methods'][method] = {
            'ag_vs_psum_out': maxdiff(o_ag, o_ps),
            'ag_vs_psum_state': maxdiff(s_ag, s_ps),
            'int8_vs_psum_rel': maxdiff(o_i8, o_ps) / max(maxabs(o_ps), 1e-12),
        }

    # int8 gradient all-reduce under shard_map: mean within half a step of
    # exact, saturation identically zero
    g = grads(7)
    err0 = jax.tree_util.tree_map(lambda x: jnp.zeros(x.shape, jnp.float32), g)

    def reduce_body(gs, es):
        return allreduce_mean_tree(gs, es, codec='int8', axes=('data',),
                                   site='grads/test')

    red = jax.jit(compat.shard_map(
        reduce_body, mesh=mesh, in_specs=(P(), P()),
        out_specs=(P(), P(), P()), check=False))
    mean, new_err, info = red(g, err0)
    exact = jax.tree_util.tree_map(lambda x: x.astype(jnp.float32), g)
    rec['grad_int8_err'] = maxdiff(mean, exact)
    rec['grad_int8_scale'] = max(
        float(jnp.max(jnp.abs(x))) / 127.0
        for x in jax.tree_util.tree_leaves(g))
    rec['saturation'] = float(info['saturation'])

    # --- raw owned-slice gather: identity and bf16-of-bf16 are atol=0 ---
    flatg = {f'l{i}/w': jax.random.normal(jax.random.PRNGKey(i), (4, 4))
             for i in range(6)}
    plan2 = bucketing.build_plan(flatg)
    key2 = plan2.buckets[0].key
    stack = jnp.stack([flatg[p] for p in plan2.buckets[0].paths])
    owners2 = ownership.assign_slice_owners(plan2,
                                            ownership.inverse_cost('both'), 4)

    def gather_of(codec):
        def body(s):
            w, r = ownership.world_and_rank(('data',))
            out = allgather_owned_slices(plan2, owners2, w, r, {key2: s},
                                         codec=codec, axes=('data',))
            return out[key2]
        return jax.jit(compat.shard_map(body, mesh=mesh, in_specs=(P(),),
                                        out_specs=P(), check=False))

    # every worker holds the full true stack; non-owned rows are never read,
    # so the reconstruction must equal the input exactly
    rec['gather_identity_err'] = maxdiff(gather_of('identity')(stack), stack)
    stack_bf = stack.astype(jnp.bfloat16).astype(jnp.float32)
    rec['gather_bf16_of_bf16_err'] = maxdiff(gather_of('bf16')(stack_bf),
                                             stack_bf)

    # --- topology='pod' on a (2,2) ('pod','data') mesh: the two-stage
    # (ICI slice gather + DCN bucket psum) exchange ≡ full-stack psum ---
    mesh22 = compat.make_mesh((2, 2), ('pod', 'data'))

    def run22(method, comm):
        opt = MAKERS[method]()
        rt = RefreshRuntime(shard_refresh=True)
        state = opt.init(params, Extras(stats=stats(0), sched=rt, comm=comm))

        def body(g, s, st):
            return opt.update(g, s, extras=Extras(stats=st, sched=rt,
                                                  comm=comm))

        step = jax.jit(compat.shard_map(
            body, mesh=mesh22, in_specs=(P(), P(), P()),
            out_specs=(P(), P()), check=False))
        outs = []
        for t in range(STEPS):
            out, state = step(grads(t), state, stats(t))
            outs.append(out)
        return outs, state

    o22_ps, s22_ps = run22('kfac', ExchangeConfig(exchange='psum'))
    o22_pod, s22_pod = run22('kfac', ExchangeConfig(exchange='gather',
                                                    topology='pod'))
    rec['pod_vs_psum_out'] = maxdiff(o22_pod, o22_ps)
    rec['pod_vs_psum_state'] = maxdiff(s22_pod, s22_ps)

    rec['sites'] = {k: {kk: vv for kk, vv in v.items() if kk != 'traces'}
                    for k, v in metrics.snapshot().items()}
    print(json.dumps(rec))
""")


@pytest.mark.multihost
def test_owned_slice_exchange_matches_psum_all_methods():
    out = subprocess.run(
        [sys.executable, '-c', _EQUIV_SCRIPT],
        capture_output=True, text=True, timeout=1800,
        # JAX_PLATFORMS pinned: the scrubbed env must not fall through to
        # accelerator discovery (libtpu-on-a-TPU-less-host hangs forever)
        env={'PYTHONPATH': 'src', 'PATH': '/usr/bin:/bin', 'HOME': '/root',
             'JAX_PLATFORMS': 'cpu'},
        cwd=Path(__file__).resolve().parent.parent)
    assert out.returncode == 0, out.stderr[-3000:]
    rec = json.loads(out.stdout.strip().splitlines()[-1])
    assert rec['devices'] == 4
    for method, r in rec['methods'].items():
        # owned-slice all-gather ≡ full-stack psum, bit-exact, state included
        assert r['ag_vs_psum_out'] == 0.0, (method, r)
        assert r['ag_vs_psum_state'] == 0.0, (method, r)
        # int8 refresh wire: within 1e-2 relative of the exact exchange
        assert r['int8_vs_psum_rel'] <= 1e-2, (method, r)
    # replicated inputs: the int8+EF mean must sit within half a
    # quantization step of the exact value, with zero saturation
    assert rec['grad_int8_err'] <= 0.5 * rec['grad_int8_scale'] + 1e-7
    assert rec['saturation'] == 0.0
    # raw gather reconstruction: the identity codec and bf16-of-bf16-
    # representable values round-trip the stack bit-exactly (the ISSUE's
    # atol=0 contract for the default exchange='gather')
    assert rec['gather_identity_err'] == 0.0
    assert rec['gather_bf16_of_bf16_err'] == 0.0
    # topology='pod' two-stage exchange (ICI slice gather + one DCN
    # zero-padded bucket psum) is exact too
    assert rec['pod_vs_psum_out'] == 0.0
    assert rec['pod_vs_psum_state'] == 0.0
    # the byte counters saw the refresh call-sites with the gather mode.
    # Exactly the three inverse-caching methods exchange — for the eva
    # family the refresh is a snapshot select with NO exchange, so their
    # psum≡allgather rows above are no-op coverage, not proof; this
    # assertion is what keeps the "all six" claim honest (a future
    # eva-family cached path would show up here and demand real proof).
    sites = rec['sites']
    assert sites['grads/test']['codec'] == 'int8'
    refresh_sites = {s for s in sites if s.startswith('refresh/')}
    assert refresh_sites == {'refresh/kfac', 'refresh/foof',
                             'refresh/shampoo'}, refresh_sites
    assert all(sites[s]['mode'] in ('gather', 'gather-pod')
               for s in refresh_sites)
    # the last-traced kfac cell ran pod topology: the record carries the
    # ICI/DCN byte split of the two-stage exchange
    kf = sites['refresh/kfac']
    assert kf['mode'] == 'gather-pod' and kf['pods'] == [2, 2]
    assert kf['ici_bytes'] > 0 and kf['dcn_bytes'] > 0
    assert kf['bytes_per_call'] == kf['ici_bytes'] + kf['dcn_bytes']
