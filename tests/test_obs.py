"""Unified telemetry layer (``repro.obs``): schema validation of every
record type, the run-scoped Recorder, span nesting, the straggler watchdog,
the golden-file report/diff contract, and the phased-step parity the
profile mode rests on."""
import json
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.comm import metrics as comm_metrics
from repro.core import kv as kvlib
from repro.core.registry import make_optimizer
from repro.data.synthetic import ClassStream
from repro.models import module as M
from repro.models.simple import MLP, classifier_loss_fn
from repro.obs import events, report, spans
from repro.train.step import (init_opt_state, make_phased_step,
                              make_train_step)

DATA = Path(__file__).parent / 'data'
FIX_A = str(DATA / 'obs_fixture_a.jsonl')
FIX_B = str(DATA / 'obs_fixture_b.jsonl')


# ---------------------------------------------------------------------------
# Schema: one valid + one corrupted example per record type


VALID = {
    'step': {'step': 3, 'loss': 1.5, 'grad_norm': 0.2, 'step_time_s': 0.01,
             'refreshes': 2, 'refresh_since': 1, 'staleness': 1.0,
             'pipeline_lag': 1, 'pipeline_lag/stats': 1,
             'exchanged_mb_cum': 4.5},
    'refresh': {'step': 4, 'refreshes': 2, 'step_time_s': 0.02},
    'refresh_ownership': {'world': 4, 'owners': {'float32_4x8x8': [1, 1, 1, 1]}},
    'reshard': {'world_from': 4, 'world_to': 2, 'pipeline': 'drained',
                'source': 'checkpoint', 'step': 7, 'slices_total': 5,
                'slices_moved': 3},
    'comm_exchange': {'sites': {'stats/eva': {
        'traces': 1, 'bytes_per_call': 1024, 'codec': 'f32',
        'mode': 'psum'}}},
    'straggler': {'step': 9, 'step_time_s': 0.9, 'median_s': 0.01,
                  'factor': 3.0},
    'span': {'name': 'grad', 'ms': 12.5, 'step': 2, 'seq': 0, 'depth': 1,
             'parent': 'step'},
    'profile': {'step': 0, 'live_buffer_mb': 8.0, 'device_bytes_in_use': 123,
                'fns': {'grad': {'flops': 1}}},
    'bench': {'name': 'table5/x', 'us_per_call': 10.0, 'derived': 'a=1',
              'fields': {'a': '1'}},
}


@pytest.mark.parametrize('event', sorted(events.SCHEMAS))
def test_schema_accepts_valid_record(event):
    rec = {'event': event, 'v': events.SCHEMA_VERSION, **VALID[event]}
    assert events.validate_record(rec) == []


@pytest.mark.parametrize('event', sorted(events.SCHEMAS))
def test_schema_rejects_missing_required(event):
    required = [k for k, f in events.SCHEMAS[event].items() if f.required]
    assert required, event
    rec = {'event': event, **VALID[event]}
    del rec[required[0]]
    errs = events.validate_record(rec)
    assert any(required[0] in e for e in errs), errs


@pytest.mark.parametrize('event', sorted(events.SCHEMAS))
def test_schema_rejects_unknown_field_and_bad_type(event):
    rec = {'event': event, **VALID[event], 'not_a_field': 1}
    assert any('not_a_field' in e for e in events.validate_record(rec))
    required = [k for k, f in events.SCHEMAS[event].items() if f.required]
    bad = {'event': event, **VALID[event], required[0]: object}
    # an un-JSON-able junk value never matches any accepted type set
    bad[required[0]] = [[]] if event != 'comm_exchange' else 'oops'
    assert events.validate_record(bad), event


def test_schema_version_and_bool_rules():
    rec = {'event': 'refresh', 'v': events.SCHEMA_VERSION + 1,
           'step': 1, 'refreshes': 1}
    assert any('schema version' in e for e in events.validate_record(rec))
    # bool is an int subclass in Python but never a valid numeric field
    rec = {'event': 'refresh', 'step': True, 'refreshes': 1}
    assert events.validate_record(rec)


def test_legacy_envelope_less_step_records_validate():
    # pre-obs trainer lines had no 'event'/'v' — still valid step records
    legacy = {'step': 5, 'loss': 2.0, 'grad_norm': 0.1, 'step_time_s': 0.02}
    assert events.infer_event(legacy) == 'step'
    assert events.validate_record(legacy) == []


def test_site_validation_catches_corruption():
    rec = {'event': 'comm_exchange',
           'sites': {'stats/eva': {'bytes_per_call': 'lots',
                                   'codec': 'f32'}}}
    errs = events.validate_record(rec)
    assert any('bytes_per_call' in e for e in errs)      # wrong type
    assert any("missing required field 'mode'" in e for e in errs)
    # the pod gather extras are typed: pods is the (n_pods, pod_size) pair
    ok = {'event': 'comm_exchange',
          'sites': {'refresh/kfac': {'bytes_per_call': 8, 'codec': 'f32',
                                     'mode': 'gather-pod', 'pods': [2, 2],
                                     'ici_bytes': 6, 'dcn_bytes': 2}}}
    assert events.validate_record(ok) == []


# ---------------------------------------------------------------------------
# Recorder


def test_recorder_writes_validates_and_scopes(tmp_path):
    path = tmp_path / 'metrics.jsonl'
    with events.Recorder(path) as rec:
        comm_metrics.record('stats/test_obs', bytes_per_call=64,
                            codec='f32', mode='local')
        rec.emit('refresh', step=1, refreshes=1)
        with pytest.raises(events.SchemaError):
            rec.emit('refresh', step=1)                  # missing required
        with pytest.raises(events.SchemaError):
            rec.emit('no_such_event', x=1)
        # the recorder's comm scope saw the site traced while it was open
        assert rec.comm_sites()['stats/test_obs']['bytes_per_call'] == 64
    lines = [json.loads(l) for l in path.read_text().splitlines()]
    assert lines == [{'event': 'refresh', 'v': events.SCHEMA_VERSION,
                      'step': 1, 'refreshes': 1}]
    # a recorder opened after the trace does NOT see the old site...
    with events.Recorder(None) as rec2:
        assert 'stats/test_obs' not in rec2.comm_sites()
    # ...but the process-global table still has it (roofline contract)
    assert comm_metrics.snapshot()['stats/test_obs']['traces'] == 1


# ---------------------------------------------------------------------------
# Spans + watchdog


def test_span_nesting_order_and_fence():
    clock = iter(range(100))
    tracker = spans.SpanTracker(clock=lambda: float(next(clock)))
    fenced = []
    with tracker.span('step', step=2) as outer:
        with tracker.span('grad', step=2) as sp:
            fenced.append(sp.fence(jnp.ones((2, 2))))
        with tracker.span('apply', step=2):
            pass
        outer.fence(fenced[0] * 2)
    names = [r['name'] for r in tracker.records]
    assert names == ['grad', 'apply', 'step']            # closed-in order
    by = {r['name']: r for r in tracker.records}
    assert by['grad']['depth'] == 1 and by['grad']['parent'] == 'step'
    assert by['step']['depth'] == 0 and by['step']['parent'] is None
    assert [r['seq'] for r in tracker.records] == [0, 1, 2]
    assert all(r['step'] == 2 for r in tracker.records)
    assert all(events.validate_record({'event': 'span', **r}) == []
               for r in tracker.records)


def test_straggler_watchdog_flags_injected_slow_step():
    rec = events.Recorder(None)
    dog = spans.StragglerWatchdog(factor=3.0, recorder=rec, min_history=8)
    for i in range(7):
        assert not dog.observe(i, 0.010)     # below min_history: never fires
    assert not dog.observe(7, 0.012)
    assert dog.observe(8, 0.100)             # 10x the median
    flag = rec.records[-1]
    assert flag['event'] == 'straggler' and flag['step'] == 8
    assert flag['step_time_s'] == pytest.approx(0.1)
    assert events.validate_record(flag) == []
    assert not dog.observe(9, 0.011)


# ---------------------------------------------------------------------------
# Golden-file report contract (checked-in fixtures; B is A +15% slower)


def test_breakdown_golden_numbers():
    bd = report.breakdown(report.load_records(FIX_A))
    assert bd['n_step_records'] == 6 and bd['step_range'] == (0, 10)
    # warm mean drops the (compile) first step: [18,24,18,24,16] -> 20.0
    assert bd['mean_step_ms'] == pytest.approx(20.0)
    # spans: the step-0 (compile) spans are dropped from phase means
    assert bd['phases']['grad']['mean_ms'] == pytest.approx(12.0)
    assert bd['phases']['step']['mean_ms'] == pytest.approx(18.0)
    # refresh differential: firing [24,24] vs cached [18,18,16]
    r = bd['refresh']
    assert r['count'] == 2
    assert r['extra_ms_per_refresh'] == pytest.approx(24.0 - 52 / 3)
    assert r['amortized_ms_per_step'] == pytest.approx(
        r['extra_ms_per_refresh'] * 2 / 5)
    # exchange split: per-step vs per-refresh sites, ICI/DCN byte split
    ex = bd['exchange']
    assert ex['step_bytes'] == 1048576 and ex['refresh_bytes'] == 2097152
    assert ex['ici_bytes'] == 1572864 and ex['dcn_bytes'] == 524288
    assert bd['ownership']['world'] == 4
    # HLO costs merge forward from the step-0 one-shot profile record
    assert bd['profile']['step'] == 10
    assert bd['profile']['fns']['grad']['flops'] == 1000000


def test_render_contains_breakdown_sections():
    text = report.render(report.breakdown(report.load_records(FIX_A)), 'A')
    assert 'mean step time: 20.00 ms' in text
    assert 'stats/kfac' in text and 'refresh/kfac' in text
    assert 'ici 1.50 MiB / dcn 0.50 MiB' in text
    assert 'refresh ownership (world=4' in text
    assert 'grad' in text and 'GFLOP' in text


def test_diff_gates_on_mean_step_time():
    bd_a = report.breakdown(report.load_records(FIX_A))
    bd_b = report.breakdown(report.load_records(FIX_B))
    text, worst = report.diff(bd_a, bd_b)
    assert worst == pytest.approx(15.0)
    assert '[gate]' in text and '+15.0%' in text


def test_cli_exit_codes(capsys):
    assert report.main([FIX_A, FIX_B, '--validate']) == 0
    assert report.main([FIX_A, FIX_B, '--diff', '--max-regress', '20']) == 0
    assert report.main([FIX_A, FIX_B, '--diff', '--max-regress', '10']) == 2
    capsys.readouterr()


def test_cli_validate_catches_corruption(tmp_path, capsys):
    bad = tmp_path / 'metrics.jsonl'
    bad.write_text('{"event": "step", "loss": 1.0}\n'     # missing step
                   'not json at all\n'
                   '{"event": "wat", "x": 1}\n')
    assert report.main([str(bad), '--validate']) == 1
    out = capsys.readouterr().out
    assert '3 schema error' in out


def test_bench_rows_load_and_gate(tmp_path, capsys):
    def bench(path, us):
        rows = [{'event': 'bench', 'v': events.SCHEMA_VERSION,
                 'name': 'cell/x', 'us_per_call': us, 'derived': 'n=1'}]
        Path(path).write_text(json.dumps(rows))
    a, b = tmp_path / 'a.json', tmp_path / 'b.json'
    bench(a, 100.0)
    bench(b, 140.0)
    assert report.main([str(a), str(b), '--validate']) == 0
    assert report.main([str(a), str(b), '--diff', '--max-regress', '50']) == 0
    assert report.main([str(a), str(b), '--diff', '--max-regress', '25']) == 2
    capsys.readouterr()


# ---------------------------------------------------------------------------
# Phased step ≡ fused step (what profile mode runs)


def test_phased_step_matches_fused():
    stream = ClassStream(batch=32, dim=8, classes=4, spread=1.5, seed=0)
    model = MLP([8, 16, 4])
    model.loss_fn = classifier_loss_fn(model)
    params0 = M.init_params(model.param_specs(), jax.random.PRNGKey(0))
    opt, capture = make_optimizer('eva', lr=0.05)
    taps_fn = (lambda p: model.make_taps(32, capture)) \
        if capture.needs_taps else None

    fused = jax.jit(make_train_step(model, opt, capture, taps_fn=taps_fn))
    grad_fn, update_fn, apply_fn = (jax.jit(f) for f in make_phased_step(
        model, opt, capture, taps_fn=taps_fn))

    state_f = init_opt_state(model, opt, capture, params0, stream.batch_at(0),
                             taps_fn=taps_fn)
    state_p = jax.tree_util.tree_map(lambda x: x, state_f)
    p_f, p_p = params0, params0
    for i in range(3):
        batch = stream.batch_at(i)
        p_f, state_f, m_f = fused(p_f, state_f, batch)
        loss, grads, stats = grad_fn(p_p, batch)
        updates, state_p, m_p = update_fn(grads, stats, loss, state_p, p_p)
        p_p = apply_fn(p_p, updates)
        assert float(m_f['loss']) == pytest.approx(float(m_p['loss']),
                                                   rel=1e-6)
    for a, b in zip(jax.tree_util.tree_leaves(p_f),
                    jax.tree_util.tree_leaves(p_p)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-6, atol=1e-7)


# ---------------------------------------------------------------------------
# Trainer profile mode end-to-end (tiny MLP, CPU-fast)


def test_trainer_profile_mode_emits_valid_telemetry(tmp_path):
    from repro.train import Trainer, TrainerConfig
    stream = ClassStream(batch=16, dim=8, classes=4, spread=1.5, seed=0)
    model = MLP([8, 16, 4])
    model.loss_fn = classifier_loss_fn(model)
    params = M.init_params(model.param_specs(), jax.random.PRNGKey(0))
    opt, capture = make_optimizer('eva', lr=0.05)
    taps_fn = (lambda p: model.make_taps(16, capture)) \
        if capture.needs_taps else None
    cfg = TrainerConfig(total_steps=3, log_every=1, ckpt_every=0,
                        out_dir=str(tmp_path / 'run'), profile=True)
    tr = Trainer(model, opt, capture, cfg, taps_fn=taps_fn)
    tr.fit(params, stream)

    recs = report.load_records(str(tmp_path / 'run' / 'metrics.jsonl'))
    assert report.validate_records(recs) == []
    by_event = {}
    for r in recs:
        by_event.setdefault(events.infer_event(r), []).append(r)
    assert len(by_event['step']) == 3
    assert {'data', 'grad', 'precondition', 'apply', 'step'} <= {
        s['name'] for s in by_event['span']}
    assert by_event['profile'], 'profile mode must emit profile records'
    # eva exchanges its KV stats every step — the site must be attributed
    assert any('stats/eva' in r['sites'] for r in by_event['comm_exchange'])
    # the step record is a superset of the legacy fields
    step0 = by_event['step'][0]
    assert {'step', 'loss', 'grad_norm', 'step_time_s'} <= set(step0)


# ---------------------------------------------------------------------------
# K-FAC scan-stacked capture regression (the bug this PR fixed: the vector-
# tap fallback collapsed scan lead dims into the token axis, so the stacked
# b_outer lost the path dim and the refresh cond branches disagreed)


def test_kfac_full_taps_keep_scan_lead_dims():
    from repro.configs.registry import demo_lm
    from repro.models import build_model
    from repro.train.step import compute_grads_and_stats
    cfg = demo_lm('small')
    model = build_model(cfg)
    params = M.init_params(model.param_specs(), jax.random.PRNGKey(0))
    _, capture = make_optimizer('kfac', lr=0.05)
    paths = set(model.precon_paths()) & set(kvlib.flatten_params(params))
    batch_shape = (2, 8)
    taps_fn = lambda p: kvlib.make_full_taps(p, paths, batch_shape)
    from repro.data.synthetic import LMStream
    batch = LMStream(vocab=cfg.vocab, seq_len=8, batch=2,
                     seed=0).batch_at(0)

    def stats_of(p):
        return compute_grads_and_stats(model, p, batch, capture,
                                       taps_fn(p))[2]

    shapes = jax.eval_shape(stats_of, params)
    flat = kvlib.flatten_params(params)
    for path, st in shapes.items():
        lead = flat[path].shape[:-2]
        d_out = flat[path].shape[-1]
        # b_outer must keep the scan path dims in front, matching a_outer
        assert st.b_outer.shape == lead + (d_out, d_out), path
        assert st.a_outer.shape[:-2] == lead, path


def test_kfac_scan_stacked_step_runs():
    from repro.configs.registry import demo_lm
    from repro.models import build_model
    cfg = demo_lm('small')
    model = build_model(cfg)
    params = M.init_params(model.param_specs(), jax.random.PRNGKey(0))
    opt, capture = make_optimizer('kfac', lr=0.05)
    paths = set(model.precon_paths()) & set(kvlib.flatten_params(params))
    taps_fn = lambda p: kvlib.make_full_taps(p, paths, (2, 8))
    from repro.data.synthetic import LMStream
    batch = LMStream(vocab=cfg.vocab, seq_len=8, batch=2,
                     seed=0).batch_at(0)
    state = init_opt_state(model, opt, capture, params, batch,
                           taps_fn=taps_fn)
    step = jax.jit(make_train_step(model, opt, capture, taps_fn=taps_fn))
    for _ in range(2):
        params, state, m = step(params, state, batch)
    assert np.isfinite(float(m['loss']))
