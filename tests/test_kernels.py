"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps (deliverable c)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import precondition as pre
from repro.kernels import ops, ref
from repro.kernels.bilinear import bilinear
from repro.kernels.matvec import matvec
from repro.kernels.rank1_update import rank1_update

SHAPES = [(8, 8), (64, 48), (128, 128), (200, 136), (512, 384), (1000, 513)]
DTYPES = [jnp.float32, jnp.bfloat16]


def _mk(shape, dtype, key=0):
    ks = jax.random.split(jax.random.PRNGKey(key), 3)
    g = jax.random.normal(ks[0], shape, jnp.float32).astype(dtype)
    a = jax.random.normal(ks[1], (shape[0],), jnp.float32).astype(dtype)
    b = jax.random.normal(ks[2], (shape[1],), jnp.float32).astype(dtype)
    return g, a, b


@pytest.mark.parametrize('shape', SHAPES)
@pytest.mark.parametrize('dtype', DTYPES)
def test_rank1_update(shape, dtype):
    g, a, b = _mk(shape, dtype)
    out = rank1_update(g, a, b, jnp.float32(0.37), jnp.float32(2.5),
                       block_in=128, block_out=128)
    want = ref.rank1_update_ref(g, a, b, 0.37, 2.5)
    tol = 1e-5 if dtype == jnp.float32 else 3e-2
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32), atol=tol, rtol=tol)


@pytest.mark.parametrize('shape', SHAPES)
@pytest.mark.parametrize('dtype', DTYPES)
def test_matvec(shape, dtype):
    g, a, _ = _mk(shape, dtype)
    out = matvec(g, a, block_in=128, block_out=128)
    want = ref.matvec_ref(g, a)
    tol = 1e-4 if dtype == jnp.float32 else 2e-1
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               atol=tol * shape[0] ** 0.5, rtol=tol)


@pytest.mark.parametrize('shape', SHAPES)
@pytest.mark.parametrize('dtype', DTYPES)
def test_bilinear(shape, dtype):
    g, a, b = _mk(shape, dtype)
    out = bilinear(g, a, b, block_in=128, block_out=128)
    want = ref.bilinear_ref(g, a, b)
    tol = 1e-4 if dtype == jnp.float32 else 2e-1
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               atol=tol * (shape[0] * shape[1]) ** 0.5, rtol=tol)


@pytest.mark.parametrize('shape', [(64, 48), (256, 200)])
def test_fused_eva_matches_core_math(shape):
    """ops.eva_precondition (pallas) == precondition.eva_precondition (jnp)."""
    g, a, b = _mk(shape, jnp.float32)
    out = ops.eva_precondition(g, a, b, gamma=0.03)
    want = pre.eva_precondition(g, a, b, gamma=0.03)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               atol=1e-4, rtol=1e-4)


@pytest.mark.parametrize('shape', [(64, 48), (256, 200)])
def test_fused_eva_f_matches_core_math(shape):
    g, a, _ = _mk(shape, jnp.float32)
    out = ops.eva_f_precondition(g, a, gamma=0.03)
    want = pre.eva_f_precondition(g, a, gamma=0.03)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               atol=1e-4, rtol=1e-4)


def test_stacked_vmap():
    """Leading layer/expert stack dims fold through vmap."""
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    g = jax.random.normal(ks[0], (3, 2, 64, 48))
    a = jax.random.normal(ks[1], (3, 2, 64))
    b = jax.random.normal(ks[2], (3, 2, 48))
    out = ops.eva_precondition(g, a, b, gamma=0.1)
    want = pre.eva_precondition(g, a, b, gamma=0.1)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               atol=1e-4, rtol=1e-4)


def test_optimizer_use_pallas_flag():
    """eva(use_pallas=True) == eva(use_pallas=False) end-to-end."""
    from repro.core import kv as kvlib
    from repro.core.eva import eva
    from repro.core.transform import Extras

    params = {'lin': {'w': jax.random.normal(jax.random.PRNGKey(0), (32, 16))}}
    grads = {'lin': {'w': jax.random.normal(jax.random.PRNGKey(1), (32, 16))}}
    stats = {'lin/w': kvlib.LayerStats(
        a_mean=jax.random.normal(jax.random.PRNGKey(2), (32,)),
        b_mean=jax.random.normal(jax.random.PRNGKey(3), (16,)))}
    outs = []
    for flag in (False, True):
        opt = eva(lr=0.1, use_pallas=flag)
        state = opt.init(params, Extras(stats=stats))
        upd, _ = opt.update(grads, state, params=params, extras=Extras(stats=stats))
        outs.append(upd['lin']['w'])
    np.testing.assert_allclose(np.asarray(outs[0]), np.asarray(outs[1]),
                               atol=1e-5, rtol=1e-5)
