"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps (deliverable c)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import precondition as pre
from repro.kernels import ops, ref
from repro.kernels.bilinear import bilinear
from repro.kernels.matvec import matvec
from repro.kernels.rank1_update import rank1_update

SHAPES = [(8, 8), (64, 48), (128, 128), (200, 136), (512, 384), (1000, 513)]
DTYPES = [jnp.float32, jnp.bfloat16]


def _mk(shape, dtype, key=0):
    ks = jax.random.split(jax.random.PRNGKey(key), 3)
    g = jax.random.normal(ks[0], shape, jnp.float32).astype(dtype)
    a = jax.random.normal(ks[1], (shape[0],), jnp.float32).astype(dtype)
    b = jax.random.normal(ks[2], (shape[1],), jnp.float32).astype(dtype)
    return g, a, b


@pytest.mark.parametrize('shape', SHAPES)
@pytest.mark.parametrize('dtype', DTYPES)
def test_rank1_update(shape, dtype):
    g, a, b = _mk(shape, dtype)
    out = rank1_update(g, a, b, jnp.float32(0.37), jnp.float32(2.5),
                       block_in=128, block_out=128)
    want = ref.rank1_update_ref(g, a, b, 0.37, 2.5)
    tol = 1e-5 if dtype == jnp.float32 else 3e-2
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32), atol=tol, rtol=tol)


@pytest.mark.parametrize('shape', SHAPES)
@pytest.mark.parametrize('dtype', DTYPES)
def test_matvec(shape, dtype):
    g, a, _ = _mk(shape, dtype)
    out = matvec(g, a, block_in=128, block_out=128)
    want = ref.matvec_ref(g, a)
    tol = 1e-4 if dtype == jnp.float32 else 2e-1
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               atol=tol * shape[0] ** 0.5, rtol=tol)


@pytest.mark.parametrize('shape', SHAPES)
@pytest.mark.parametrize('dtype', DTYPES)
def test_bilinear(shape, dtype):
    g, a, b = _mk(shape, dtype)
    out = bilinear(g, a, b, block_in=128, block_out=128)
    want = ref.bilinear_ref(g, a, b)
    tol = 1e-4 if dtype == jnp.float32 else 2e-1
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               atol=tol * (shape[0] * shape[1]) ** 0.5, rtol=tol)


@pytest.mark.parametrize('shape', [(64, 48), (256, 200)])
def test_fused_eva_matches_core_math(shape):
    """ops.eva_precondition (pallas) == precondition.eva_precondition (jnp)."""
    g, a, b = _mk(shape, jnp.float32)
    out = ops.eva_precondition(g, a, b, gamma=0.03)
    want = pre.eva_precondition(g, a, b, gamma=0.03)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               atol=1e-4, rtol=1e-4)


@pytest.mark.parametrize('shape', [(64, 48), (256, 200)])
def test_fused_eva_f_matches_core_math(shape):
    g, a, _ = _mk(shape, jnp.float32)
    out = ops.eva_f_precondition(g, a, gamma=0.03)
    want = pre.eva_f_precondition(g, a, gamma=0.03)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               atol=1e-4, rtol=1e-4)


def test_stacked_vmap():
    """Leading layer/expert stack dims fold through vmap."""
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    g = jax.random.normal(ks[0], (3, 2, 64, 48))
    a = jax.random.normal(ks[1], (3, 2, 64))
    b = jax.random.normal(ks[2], (3, 2, 48))
    out = ops.eva_precondition(g, a, b, gamma=0.1)
    want = pre.eva_precondition(g, a, b, gamma=0.1)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               atol=1e-4, rtol=1e-4)


@pytest.mark.parametrize('shape', [(64, 48), (200, 136), (512, 384)])
@pytest.mark.parametrize('world', [1, 2, 4])
def test_matvec_cols_partials_sum_to_matmul(shape, world):
    """Band partials over W row bands sum to the full A @ G (zero-pad rows
    of the last band contribute zero) — the factor-sharding invariant."""
    from repro.kernels.matvec import matvec_cols

    m, n = shape
    ks = jax.random.split(jax.random.PRNGKey(3), 2)
    g = jax.random.normal(ks[0], (m, n), jnp.float32)
    a = jax.random.normal(ks[1], (5, m), jnp.float32)
    blk = -(-m // world)
    gp = jnp.pad(g, ((0, world * blk - m), (0, 0)))
    ap = jnp.pad(a, ((0, 0), (0, world * blk - m)))
    total = sum(matvec_cols(gp[w * blk:(w + 1) * blk],
                            ap[:, w * blk:(w + 1) * blk],
                            block_in=128, block_out=128)
                for w in range(world))
    want = a @ g
    np.testing.assert_allclose(np.asarray(total), np.asarray(want),
                               atol=1e-4 * m ** 0.5, rtol=1e-4)


def test_matvec_cols_stacked_matches_per_item():
    """The bucket-stacked variant equals per-factor matvec_cols calls."""
    from repro.kernels.matvec import matvec_cols, matvec_cols_stacked

    ks = jax.random.split(jax.random.PRNGKey(4), 2)
    g = jax.random.normal(ks[0], (3, 100, 136), jnp.float32)
    a = jax.random.normal(ks[1], (3, 4, 100), jnp.float32)
    out = matvec_cols_stacked(g, a, block_in=64, block_out=64)
    for l in range(3):
        one = matvec_cols(g[l], a[l], block_in=64, block_out=64)
        np.testing.assert_array_equal(np.asarray(out[l]), np.asarray(one))


# ---------------------------------------------------------------------------
# tile-boundary edge cases: non-divisible dims + stack depths.  The hard
# guarantee is stacked ≡ per-item (identical tile programs, bit-exact);
# agreement vs the einsum refs is tight-tolerance — XLA contracts the
# broadcast formulas with different FMA/reduction order, so bit-identity
# vs ref.py does not hold even for single-tile launches.

ODD_SHAPES = [(7, 5), (65, 33), (129, 127)]
BLOCKS = [32, 512]  # multi-tile with padding remainder / single padded tile


def _mk_stacked(L, shape, key=7):
    ks = jax.random.split(jax.random.PRNGKey(key), 3)
    g = jax.random.normal(ks[0], (L,) + shape, jnp.float32)
    a = jax.random.normal(ks[1], (L, shape[0]), jnp.float32)
    b = jax.random.normal(ks[2], (L, shape[1]), jnp.float32)
    return g, a, b


@pytest.mark.parametrize('shape', ODD_SHAPES)
@pytest.mark.parametrize('block', BLOCKS)
def test_tile_boundary_vs_ref(shape, block):
    g, a, b = _mk(shape, jnp.float32, key=11)
    np.testing.assert_allclose(
        np.asarray(bilinear(g, a, b, block_in=block, block_out=block)),
        np.asarray(ref.bilinear_ref(g, a, b)),
        atol=1e-4 * (shape[0] * shape[1]) ** 0.5, rtol=1e-5)
    np.testing.assert_allclose(
        np.asarray(matvec(g, a, block_in=block, block_out=block)),
        np.asarray(ref.matvec_ref(g, a)),
        atol=1e-4 * shape[0] ** 0.5, rtol=1e-5)
    np.testing.assert_allclose(
        np.asarray(rank1_update(g, a, b, jnp.float32(0.37), jnp.float32(2.5),
                                block_in=block, block_out=block)),
        np.asarray(ref.rank1_update_ref(g, a, b, 0.37, 2.5)),
        atol=1e-5, rtol=1e-5)


@pytest.mark.parametrize('shape', ODD_SHAPES)
@pytest.mark.parametrize('block', BLOCKS)
@pytest.mark.parametrize('L', [1, 3])
def test_tile_boundary_stacked_bit_identical_to_per_item(shape, block, L):
    from repro.kernels.bilinear import bilinear_stacked
    from repro.kernels.matvec import matvec_stacked
    from repro.kernels.rank1_update import rank1_update_stacked

    g, a, b = _mk_stacked(L, shape)
    coeff = jnp.linspace(0.1, 0.9, L)
    scale = jnp.linspace(1.5, 2.5, L)
    dot_s = bilinear_stacked(g, a, b, block_in=block, block_out=block)
    mv_s = matvec_stacked(g, a, block_in=block, block_out=block)
    r1_s = rank1_update_stacked(g, a, b, coeff, scale,
                                block_in=block, block_out=block)
    for l in range(L):
        np.testing.assert_array_equal(
            np.asarray(dot_s[l]),
            np.asarray(bilinear(g[l], a[l], b[l],
                                block_in=block, block_out=block)))
        np.testing.assert_array_equal(
            np.asarray(mv_s[l]),
            np.asarray(matvec(g[l], a[l],
                              block_in=block, block_out=block)))
        np.testing.assert_array_equal(
            np.asarray(r1_s[l]),
            np.asarray(rank1_update(g[l], a[l], b[l], coeff[l], scale[l],
                                    block_in=block, block_out=block)))


@pytest.mark.parametrize('shape', ODD_SHAPES)
def test_tile_boundary_block_size_invariance(shape):
    """Padding remainder tiles must not leak into the result: the same op
    at block 32 vs one padded tile agrees to f32 reduction order."""
    g, a, b = _mk(shape, jnp.float32, key=13)
    np.testing.assert_allclose(
        np.asarray(bilinear(g, a, b, block_in=32, block_out=32)),
        np.asarray(bilinear(g, a, b, block_in=512, block_out=512)),
        atol=1e-4 * (shape[0] * shape[1]) ** 0.5, rtol=1e-5)
    np.testing.assert_allclose(
        np.asarray(matvec(g, a, block_in=32, block_out=32)),
        np.asarray(matvec(g, a, block_in=512, block_out=512)),
        atol=1e-4 * shape[0] ** 0.5, rtol=1e-5)
    # rank1 is elementwise: tile layout cannot change any element
    np.testing.assert_array_equal(
        np.asarray(rank1_update(g, a, b, jnp.float32(0.37), jnp.float32(2.5),
                                block_in=32, block_out=32)),
        np.asarray(rank1_update(g, a, b, jnp.float32(0.37), jnp.float32(2.5),
                                block_in=512, block_out=512)))


def test_optimizer_use_pallas_flag():
    """eva(use_pallas=True) == eva(use_pallas=False) end-to-end."""
    from repro.core import kv as kvlib
    from repro.core.eva import eva
    from repro.core.transform import Extras

    params = {'lin': {'w': jax.random.normal(jax.random.PRNGKey(0), (32, 16))}}
    grads = {'lin': {'w': jax.random.normal(jax.random.PRNGKey(1), (32, 16))}}
    stats = {'lin/w': kvlib.LayerStats(
        a_mean=jax.random.normal(jax.random.PRNGKey(2), (32,)),
        b_mean=jax.random.normal(jax.random.PRNGKey(3), (16,)))}
    outs = []
    for flag in (False, True):
        opt = eva(lr=0.1, use_pallas=flag)
        state = opt.init(params, Extras(stats=stats))
        upd, _ = opt.update(grads, state, params=params, extras=Extras(stats=stats))
        outs.append(upd['lin']['w'])
    np.testing.assert_allclose(np.asarray(outs[0]), np.asarray(outs[1]),
                               atol=1e-5, rtol=1e-5)
