"""Per-architecture smoke tests (deliverable f).

Each assigned arch is instantiated at its REDUCED config (same family —
fewer layers/width/experts, tiny vocab) and runs one forward + one Eva train
step on CPU, asserting output shapes and finiteness.  The FULL configs are
exercised via the dry-run only (ShapeDtypeStruct, no allocation).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_reduced
from repro.core.registry import make_optimizer
from repro.models import build_model
from repro.models import module as M
from repro.train.step import init_opt_state, make_train_step


def tiny_batch(cfg, b=2, s=16, key=0):
    ks = jax.random.split(jax.random.PRNGKey(key), 3)
    out = {}
    if cfg.family == 'encdec':
        dec = s // cfg.dec_ratio
        out['embeds'] = jax.random.normal(ks[0], (b, s, cfg.d_model),
                                          dtype=cfg.cdtype)
        out['tokens'] = jax.random.randint(ks[1], (b, dec), 0, cfg.vocab)
        out['labels'] = jax.random.randint(ks[2], (b, dec), 0, cfg.vocab)
    elif cfg.input_is_embeds:
        out['embeds'] = jax.random.normal(ks[0], (b, s, cfg.d_model),
                                          dtype=cfg.cdtype)
        out['labels'] = jax.random.randint(ks[2], (b, s), 0, cfg.vocab)
    else:
        out['tokens'] = jax.random.randint(ks[1], (b, s), 0, cfg.vocab)
        out['labels'] = jax.random.randint(ks[2], (b, s), 0, cfg.vocab)
    return out


@pytest.mark.parametrize('arch_id', ARCH_IDS)
def test_smoke_forward_and_train_step(arch_id):
    cfg = get_reduced(arch_id)
    model = build_model(cfg)
    params = M.init_params(model.param_specs(), jax.random.PRNGKey(0))
    batch = tiny_batch(cfg)

    opt, capture = make_optimizer('eva', lr=0.05)
    opt_state = init_opt_state(model, opt, capture, params, batch)
    step = jax.jit(make_train_step(model, opt, capture))

    new_params, new_state, metrics = step(params, opt_state, batch)
    loss0 = float(metrics['loss'])
    assert np.isfinite(loss0), f'{arch_id}: non-finite initial loss'

    # shapes preserved, params actually changed, still finite
    jax.tree_util.tree_map(lambda a, b: (_ for _ in ()).throw(
        AssertionError('shape change')) if a.shape != b.shape else None,
        params, new_params)
    for _ in range(2):
        new_params, new_state, metrics = step(new_params, new_state, batch)
    assert np.isfinite(float(metrics['loss'])), f'{arch_id}: diverged'
    for leaf in jax.tree_util.tree_leaves(new_params):
        assert np.isfinite(np.asarray(leaf, dtype=np.float32)).all(), \
            f'{arch_id}: non-finite params'


@pytest.mark.parametrize('arch_id', ARCH_IDS)
def test_smoke_prefill_decode(arch_id):
    cfg = get_reduced(arch_id)
    model = build_model(cfg)
    params = M.init_params(model.param_specs(), jax.random.PRNGKey(0))
    batch = tiny_batch(cfg)
    batch.pop('labels', None)

    logits, cache = jax.jit(model.prefill_fn)(params, batch)
    assert logits.shape == (2, cfg.vocab)
    assert np.isfinite(np.asarray(logits, dtype=np.float32)).all()

    toks = jnp.argmax(logits, -1).astype(jnp.int32)
    plen = batch['tokens'].shape[1] if 'tokens' in batch else batch['embeds'].shape[1]
    logits2, cache2 = jax.jit(model.decode_fn)(
        params, cache, toks, jnp.asarray(plen, jnp.int32))
    assert logits2.shape == (2, cfg.vocab)
    assert np.isfinite(np.asarray(logits2, dtype=np.float32)).all()
