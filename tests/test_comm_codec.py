"""Codec layer (repro.comm.codec): round-trip exactness, error-feedback
convergence, saturation accounting, and the byte bookkeeping.

Contracts proven here:
  * ``f32`` round-trips any value exactly; ``bf16`` round-trips exactly
    where the value is bf16-representable;
  * the int8 codec's carried error-feedback residual keeps the *cumulative*
    compressed-mean trajectory within a quantization-step tolerance of the
    exact mean over many steps (EF-SGD's telescoping-error property) — this
    is what makes compressed-gradient training converge;
  * the saturation counter is 0 by construction under the true max scale
    and counts correctly under an understated scale;
  * ``quantize_allreduce`` (the public compression API) still matches a
    from-scratch reference of the historical op sequence bit-for-bit.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.comm import exchange, get_codec, metrics
from repro.comm.codec import BF16, F32, INT8_EF, SCALE_FLOOR


# ---------------------------------------------------------------------------
# Round-trips


def test_f32_roundtrip_exact():
    x = jnp.asarray(np.random.RandomState(0).randn(7, 5).astype(np.float32))
    p, s, sat = F32.encode(x, jnp.max(jnp.abs(x)))
    np.testing.assert_array_equal(np.asarray(F32.decode(p, s)), np.asarray(x))
    assert float(sat) == 0.0


def test_bf16_roundtrip_exact_where_representable():
    raw = jnp.asarray(np.random.RandomState(1).randn(64).astype(np.float32))
    x = raw.astype(jnp.bfloat16).astype(jnp.float32)   # representable values
    p, s, sat = BF16.encode(x, jnp.max(jnp.abs(x)))
    assert p.dtype == jnp.bfloat16
    np.testing.assert_array_equal(np.asarray(BF16.decode(p, s)), np.asarray(x))
    # and a non-representable value moves by at most one bf16 ulp
    y = jnp.float32(1.0 + 2 ** -10)
    d = abs(float(BF16.decode(*BF16.encode(y, jnp.abs(y))[:2])) - float(y))
    assert d <= 2 ** -8


def test_int8_quantization_error_bounded_by_half_step():
    rng = np.random.RandomState(2)
    x = jnp.asarray(rng.randn(16, 16).astype(np.float32) * 3.0)
    amax = jnp.max(jnp.abs(x))
    q, scale, sat = INT8_EF.encode(x, amax)
    assert q.dtype == jnp.int8 and float(sat) == 0.0
    err = np.abs(np.asarray(INT8_EF.decode(q, scale)) - np.asarray(x))
    assert err.max() <= float(scale) * 0.5 + 1e-7


def test_int8_zero_tensor_scale_floor():
    x = jnp.zeros((4, 4), jnp.float32)
    q, scale, sat = INT8_EF.encode(x, jnp.max(jnp.abs(x)))
    assert float(scale) == float(np.float32(SCALE_FLOOR))
    assert float(sat) == 0.0
    np.testing.assert_array_equal(np.asarray(INT8_EF.decode(q, scale)), 0.0)


def test_int8_saturation_counts_understated_scale():
    """Saturation is impossible under the true max (the clamp only raises
    the scale) but must be *counted* when a caller understates it."""
    x = jnp.asarray([10.0, -10.0, 1.0, 0.5], jnp.float32)
    _, _, sat_true = INT8_EF.encode(x, jnp.max(jnp.abs(x)))
    assert float(sat_true) == 0.0
    _, _, sat_lo = INT8_EF.encode(x, jnp.asarray(1.0))   # pretend max is 1
    assert float(sat_lo) == 2.0                          # the two ±10s


def test_get_codec_registry():
    assert get_codec(None) is F32
    assert get_codec('bf16') is BF16
    assert get_codec(INT8_EF) is INT8_EF
    assert get_codec('int8').error_feedback
    with pytest.raises(KeyError):
        get_codec('fp4')


def test_init_err_only_for_error_feedback():
    tree = {'w': jnp.ones((3, 2), jnp.bfloat16)}
    assert F32.init_err(tree) is None
    e = INT8_EF.init_err(tree)
    assert e['w'].dtype == jnp.float32 and e['w'].shape == (3, 2)


# ---------------------------------------------------------------------------
# Byte accounting


def test_tree_payload_bytes():
    tree = {'a': jnp.zeros((10, 10)), 'b': jnp.zeros((5,))}
    assert exchange.tree_payload_bytes(tree, F32) == 4 * 105
    assert exchange.tree_payload_bytes(tree, BF16) == 2 * 105
    # int8: 1 byte/elem + one f32 scale per leaf
    assert exchange.tree_payload_bytes(tree, INT8_EF) == 105 + 2 * 4


def test_metrics_record_snapshot_reset():
    metrics.reset()
    metrics.record('x', bytes_per_call=128, codec='int8', mode='allreduce')
    metrics.record('x', bytes_per_call=128, codec='int8', mode='allreduce')
    snap = metrics.snapshot()
    assert snap['x']['traces'] == 2 and snap['x']['bytes_per_call'] == 128
    snap['x']['traces'] = 0                    # copies, not views
    assert metrics.snapshot()['x']['traces'] == 2
    metrics.reset()
    assert metrics.snapshot() == {}


# ---------------------------------------------------------------------------
# quantize_allreduce stays the historical op sequence (W=1 collective-free
# reference; the multi-worker form is proven in test_comm_exchange.py)


def test_quantize_allreduce_leaf_matches_reference():
    rng = np.random.RandomState(3)
    g = jnp.asarray(rng.randn(9, 6).astype(np.float32))
    err = jnp.asarray(rng.randn(9, 6).astype(np.float32) * 0.01)
    # the historical inline math, axis-free (W=1):
    x = g + err
    scale = jnp.maximum(jnp.max(jnp.abs(x)) / 127.0, 1e-12)
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    ref_mean = q.astype(jnp.float32) * scale
    ref_err = x - ref_mean
    mean, new_err, sat = exchange.allreduce_mean_leaf(
        g, err, codec='int8', axes=())
    np.testing.assert_array_equal(np.asarray(mean), np.asarray(ref_mean))
    np.testing.assert_array_equal(np.asarray(new_err), np.asarray(ref_err))
    assert float(sat) == 0.0


# ---------------------------------------------------------------------------
# Error-feedback property (hypothesis where available — CI installs it; the
# deterministic tests above must run regardless, so no module-level skip)

try:
    from hypothesis import given, settings, strategies as st
    _HYP = True
except ImportError:                                    # pragma: no cover
    _HYP = False

    def given(**kw):                                   # noqa: D103
        def deco(fn):
            def _skipped(*a, **k):
                pytest.skip('hypothesis not installed')
            _skipped.__name__ = fn.__name__
            return _skipped
        return deco

    def settings(**kw):                                # noqa: D103
        return lambda fn: fn

    class st:                                          # noqa: D101
        integers = staticmethod(lambda *a, **k: None)
        floats = staticmethod(lambda *a, **k: None)


def _ef_trajectory_check(seed, w, steps, d, scale_mag):
    """EF telescoping: over T steps the sum of compressed means differs from
    the sum of exact means only by the final residual mean — bounded by half
    a quantization step, NOT growing with T."""
    rng = np.random.RandomState(seed)
    errs = [jnp.zeros((d,), jnp.float32) for _ in range(w)]
    cum_comp = np.zeros(d, np.float64)
    cum_exact = np.zeros(d, np.float64)
    max_scale = 0.0
    for _ in range(steps):
        xs = [jnp.asarray((rng.randn(d) * scale_mag).astype(np.float32))
              for _ in range(w)]
        # shared global scale = pmax of per-worker maxima (what the live
        # collective computes), then per-worker encode + exact int32 sum
        amax = jnp.max(jnp.stack([jnp.max(jnp.abs(x + e))
                                  for x, e in zip(xs, errs)]))
        total = jnp.zeros((d,), jnp.int32)
        scale = None
        for i in range(w):
            x = xs[i] + errs[i]
            q, scale, sat = INT8_EF.encode(x, amax)
            assert float(sat) == 0.0
            errs[i] = x - q.astype(jnp.float32) * scale
            total = total + q.astype(jnp.int32)
        comp_mean = np.asarray(total, np.float64) * float(scale) / w
        exact_mean = np.mean([np.asarray(x, np.float64) for x in xs], axis=0)
        cum_comp += comp_mean
        cum_exact += exact_mean
        max_scale = max(max_scale, float(scale))
    resid = np.mean([np.asarray(e, np.float64) for e in errs], axis=0)
    # exact identity: cum_comp == cum_exact - resid (up to f32 roundoff)
    np.testing.assert_allclose(cum_comp, cum_exact - resid,
                               rtol=1e-4, atol=max_scale * 1e-3 + 1e-6)
    # and the drift is bounded by half a step, independent of T
    assert np.max(np.abs(cum_comp - cum_exact)) <= 0.5 * max_scale + 1e-6


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 2 ** 16), w=st.integers(1, 4),
       steps=st.integers(5, 40), d=st.integers(1, 32),
       scale_mag=st.floats(0.01, 100.0))
def test_int8_ef_cumulative_mean_tracks_exact(seed, w, steps, d, scale_mag):
    _ef_trajectory_check(seed, w, steps, d, scale_mag)


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 2 ** 16), d=st.integers(1, 64))
def test_f32_bf16_roundtrip_property(seed, d):
    rng = np.random.RandomState(seed)
    x = jnp.asarray(rng.randn(d).astype(np.float32))
    m, e, sat = exchange.allreduce_mean_leaf(x, None, codec='f32', axes=())
    np.testing.assert_array_equal(np.asarray(m), np.asarray(x))
    xb = x.astype(jnp.bfloat16).astype(jnp.float32)
    m, e, sat = exchange.allreduce_mean_leaf(xb, None, codec='bf16', axes=())
    np.testing.assert_array_equal(np.asarray(m), np.asarray(xb))


# Deterministic anchor points for the EF property, so the contract is
# exercised even where hypothesis is absent (this container).
@pytest.mark.parametrize('seed,w,steps,d,scale_mag', [
    (0, 4, 40, 32, 100.0),
    (7, 3, 25, 8, 0.01),
    (42, 1, 5, 1, 1.0),
])
def test_int8_ef_trajectory_anchor(seed, w, steps, d, scale_mag):
    _ef_trajectory_check(seed, w, steps, d, scale_mag)
