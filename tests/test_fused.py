"""Fused precondition→update epilogue (kernels/fused.py + the optimizer
``fused=True`` paths): the fused single-launch chain must reproduce the
composed bilinear → rank1_update → clip/momentum chain.

Tolerance contract (see the fused.py module docstring): with
``fold_momentum=False`` the fused output is BIT-exact vs the composed
standalone kernels (identical tile visit order + identical tile formulas);
the momentum-folded output and the aux partials differ from the composed
chain only by f32 reduction/FMA order, within 1e-6.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import kv as kvlib
from repro.core.eva import eva
from repro.core.eva_f import eva_f
from repro.core.eva_s import eva_s
from repro.core.foof import foof
from repro.core.kfac import kfac
from repro.core.shampoo import shampoo
from repro.core.transform import Extras
from repro.kernels import fused, ref
from repro.kernels.bilinear import bilinear_stacked
from repro.kernels.rank1_update import rank1_update_stacked

GAMMA = 0.03
MU = 0.9
SHAPES = [(3, 64, 48), (2, 129, 127), (1, 200, 136)]


def _mk_stacked(shape, key=0):
    L, d_in, d_out = shape
    ks = jax.random.split(jax.random.PRNGKey(key), 4)
    g = jax.random.normal(ks[0], shape, jnp.float32)
    a = jax.random.normal(ks[1], (L, d_in), jnp.float32)
    b = jax.random.normal(ks[2], (L, d_out), jnp.float32)
    m = jax.random.normal(ks[3], shape, jnp.float32)
    return g, a, b, m


def _composed_eva_p(g, a, b, block=128):
    """The composed standalone-kernel chain the fused launch replaces."""
    dot = bilinear_stacked(g, a, b, block_in=block, block_out=block)
    denom = GAMMA + jnp.sum(a * a, -1) * jnp.sum(b * b, -1)
    return rank1_update_stacked(g, a, b, dot / denom,
                                jnp.full_like(denom, 1.0 / GAMMA),
                                block_in=block, block_out=block)


# ---------------------------------------------------------------------------
# kernel level


@pytest.mark.parametrize('shape', SHAPES)
def test_eva_fused_foldoff_matches_composed(shape):
    """Tile order matches the standalone kernels, so the only deviation
    left is how XLA contracts the in-kernel coeff division vs the
    host-side one — observed ≤1 f32 ulp at the update's O(1/γ) scale
    (3.8e-6 abs at |P|≈32).  γ·diff stays under 1e-6."""
    g, a, b, m = _mk_stacked(shape)
    out, _ = fused.eva_fused_stacked(g, a, b, GAMMA, m, MU,
                                     fold_momentum=False,
                                     block_in=128, block_out=128)
    comp = _composed_eva_p(g, a, b)
    np.testing.assert_allclose(GAMMA * np.asarray(out),
                               GAMMA * np.asarray(comp),
                               atol=1e-6, rtol=1e-6)


@pytest.mark.parametrize('shape', SHAPES)
def test_eva_fused_foldon_matches_jnp_tail(shape):
    g, a, b, m = _mk_stacked(shape)
    out, aux = fused.eva_fused_stacked(g, a, b, GAMMA, m, MU,
                                       fold_momentum=True,
                                       block_in=128, block_out=128)
    want = MU * m + _composed_eva_p(g, a, b)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               atol=1e-6, rtol=1e-6)
    want_aux = jnp.stack([jnp.sum(want * g, (-2, -1)),
                          jnp.sum(want * want, (-2, -1)),
                          jnp.sum(g * g, (-2, -1))], axis=-1)
    np.testing.assert_allclose(np.asarray(aux), np.asarray(want_aux),
                               rtol=2e-5, atol=1e-4)


@pytest.mark.parametrize('shape', SHAPES)
@pytest.mark.parametrize('fold', [False, True])
def test_eva_fused_matches_ref_twin(shape, fold):
    g, a, b, m = _mk_stacked(shape)
    out, aux = fused.eva_fused_stacked(g, a, b, GAMMA, m, MU,
                                       fold_momentum=fold,
                                       block_in=128, block_out=128)
    r_out, r_aux = ref.eva_fused_ref(g, a, b, GAMMA, m, MU,
                                     fold_momentum=fold)
    np.testing.assert_allclose(np.asarray(out), np.asarray(r_out),
                               atol=1e-5, rtol=1e-5)
    np.testing.assert_allclose(np.asarray(aux), np.asarray(r_aux),
                               rtol=2e-5, atol=1e-4)


@pytest.mark.parametrize('shape', SHAPES)
@pytest.mark.parametrize('fold', [False, True])
def test_eva_f_fused_matches_ref_twin(shape, fold):
    g, a, _, m = _mk_stacked(shape)
    out, aux = fused.eva_f_fused_stacked(g, a, GAMMA, m, MU,
                                         fold_momentum=fold,
                                         block_in=128, block_out=128)
    r_out, r_aux = ref.eva_f_fused_ref(g, a, GAMMA, m, MU,
                                       fold_momentum=fold)
    np.testing.assert_allclose(np.asarray(out), np.asarray(r_out),
                               atol=1e-5, rtol=1e-5)
    np.testing.assert_allclose(np.asarray(aux), np.asarray(r_aux),
                               rtol=2e-5, atol=1e-4)


@pytest.mark.parametrize('fn', ['eva', 'eva_f'])
def test_fused_single_vs_multi_tile_agree(fn):
    """Tile count must not change the result beyond f32 reduction order."""
    g, a, b, m = _mk_stacked((2, 129, 127))
    if fn == 'eva':
        one = fused.eva_fused_stacked(g, a, b, GAMMA, m, MU,
                                      block_in=512, block_out=512)
        many = fused.eva_fused_stacked(g, a, b, GAMMA, m, MU,
                                       block_in=32, block_out=32)
    else:
        one = fused.eva_f_fused_stacked(g, a, GAMMA, m, MU,
                                        block_in=512, block_out=512)
        many = fused.eva_f_fused_stacked(g, a, GAMMA, m, MU,
                                         block_in=32, block_out=32)
    np.testing.assert_allclose(np.asarray(one[0]), np.asarray(many[0]),
                               atol=1e-5, rtol=1e-5)
    np.testing.assert_allclose(np.asarray(one[1]), np.asarray(many[1]),
                               rtol=2e-5, atol=1e-4)


# ---------------------------------------------------------------------------
# optimizer level: fused=True ≡ fused=False for all six optimizers


def _params():
    ks = jax.random.split(jax.random.PRNGKey(0), 4)
    return {'l1': {'w': jax.random.normal(ks[0], (32, 16)),
                   'b': jax.random.normal(ks[1], (16,))},
            'l2': {'w': jax.random.normal(ks[2], (32, 16))},
            'l3': {'w': jax.random.normal(ks[3], (16, 8))}}


def _grads(step):
    ks = jax.random.split(jax.random.PRNGKey(100 + step), 4)
    return {'l1': {'w': jax.random.normal(ks[0], (32, 16)),
                   'b': jax.random.normal(ks[1], (16,))},
            'l2': {'w': jax.random.normal(ks[2], (32, 16))},
            'l3': {'w': jax.random.normal(ks[3], (16, 8))}}


def _stats(kind, step):
    """Per-layer curvature stats of the shape each optimizer family
    captures (kv.LayerStats): rank-1 vectors for eva/eva_f, PSD outer
    products for the solve-based families."""
    ks = jax.random.split(jax.random.PRNGKey(200 + step), 12)

    def ls(i, din, dout):
        if kind == 'eva':
            return kvlib.LayerStats(
                a_mean=jax.random.normal(ks[i], (din,)),
                b_mean=jax.random.normal(ks[i + 1], (dout,)))
        if kind == 'eva_f':
            return kvlib.LayerStats(a_mean=jax.random.normal(ks[i], (din,)))
        if kind == 'foof':
            a = jax.random.normal(ks[i], (din, din))
            return kvlib.LayerStats(a_outer=a @ a.T / din)
        a = jax.random.normal(ks[i], (din, din))
        b = jax.random.normal(ks[i + 1], (dout, dout))
        return kvlib.LayerStats(a_outer=a @ a.T / din, b_outer=b @ b.T / dout)

    return {'l1/w': ls(0, 32, 16), 'l2/w': ls(3, 32, 16),
            'l3/w': ls(6, 16, 8)}


def _run(factory, kind, steps=4, **kw):
    params = _params()
    opt = factory(lr=0.1, **kw)
    state = opt.init(params, Extras(stats=_stats(kind, 0)))
    outs = []
    for t in range(steps):
        upd, state = opt.update(_grads(t), state, params=params,
                                extras=Extras(stats=_stats(kind, t)))
        outs.append(upd)
    return outs


@pytest.mark.parametrize('name,factory', [
    ('eva', eva), ('eva_f', eva_f), ('eva_s', eva_s),
    ('kfac', kfac), ('foof', foof), ('shampoo', shampoo)])
def test_optimizer_fused_matches_composed(name, factory):
    base = _run(factory, name, fused=False)
    fusd = _run(factory, name, fused=True)
    for t, (u0, u1) in enumerate(zip(base, fusd)):
        for x, y in zip(jax.tree_util.tree_leaves(u0),
                        jax.tree_util.tree_leaves(u1)):
            np.testing.assert_allclose(
                np.asarray(x, np.float32), np.asarray(y, np.float32),
                atol=1e-6, rtol=1e-6, err_msg=f'{name} step {t}')
