"""Checkpoint roundtrip/async/GC/elastic-reshard + data determinism tests."""
import threading

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.sharding import compat

from repro.data.memmap_loader import MemmapLM, write_tokens
from repro.data.pipeline import Prefetcher
from repro.data.synthetic import AEStream, ClassStream, LMStream
from repro.train import checkpoint as ckpt


def _tree():
    return {'a': {'w': jnp.arange(12.0).reshape(3, 4)},
            'opt': (jnp.zeros(()), {'m': jnp.ones((5,)) * 2})}


def test_roundtrip(tmp_path):
    t = _tree()
    ckpt.save(tmp_path, 3, t, {'next_step': 3})
    template = jax.tree_util.tree_map(jnp.zeros_like, t)
    restored, meta = ckpt.restore(tmp_path, 3, template)
    assert meta['next_step'] == 3
    jax.tree_util.tree_map(
        lambda a, b: np.testing.assert_allclose(np.asarray(a), np.asarray(b)),
        t, restored)


def test_async_and_gc(tmp_path):
    c = ckpt.AsyncCheckpointer(tmp_path, keep=2)
    for s in (1, 2, 3, 4):
        c.save(s, _tree(), {'next_step': s})
    c.wait()
    assert ckpt.available_steps(tmp_path) == [3, 4]
    assert ckpt.latest_step(tmp_path) == 4


def test_atomicity_incomplete_ignored(tmp_path):
    ckpt.save(tmp_path, 1, _tree())
    # simulate a crashed save: directory without the commit marker
    (tmp_path / 'step_00000009').mkdir()
    assert ckpt.latest_step(tmp_path) == 1


def test_elastic_reshard_restore(tmp_path):
    """Restore onto an explicit sharding (single-device 'mesh')."""
    t = _tree()
    ckpt.save(tmp_path, 1, t)
    mesh = compat.make_mesh((1,), ('data',))
    sh = jax.sharding.NamedSharding(mesh, jax.sharding.PartitionSpec())
    restored, _ = ckpt.restore(tmp_path, 1,
                               jax.tree_util.tree_map(jnp.zeros_like, t),
                               shardings=sh)
    assert restored['a']['w'].sharding.is_equivalent_to(sh, 2)


def test_restore_missing_leaf_raises(tmp_path):
    """A template leaf absent from the manifest is a structural mismatch
    (different optimizer / pipeline mode), not silently zero-filled."""
    ckpt.save(tmp_path, 1, {'a': jnp.zeros(3)})
    with pytest.raises(KeyError, match='missing leaf'):
        ckpt.restore(tmp_path, 1, {'a': jnp.zeros(3), 'b': jnp.zeros(2)})


def test_restore_shape_mismatch_names_the_leaf(tmp_path):
    ckpt.save(tmp_path, 1, {'a': {'w': jnp.zeros((3, 4))}})
    with pytest.raises(ValueError, match=r"\['a'\]\['w'\]"):
        ckpt.restore(tmp_path, 1, {'a': {'w': jnp.zeros((4, 3))}})


def test_restore_missing_step_raises(tmp_path):
    ckpt.save(tmp_path, 1, {'a': jnp.zeros(3)})
    with pytest.raises(FileNotFoundError):
        ckpt.restore(tmp_path, 99, {'a': jnp.zeros(3)})


def test_gc_keep_zero_disables_gc(tmp_path):
    """keep <= 0 means 'never delete' — NOT 'delete everything' (the
    steps[:-0] == [] footgun is guarded explicitly)."""
    for s in (1, 2, 3):
        ckpt.save(tmp_path, s, {'a': jnp.zeros(2)})
    ckpt.gc_old(tmp_path, keep=0)
    assert ckpt.available_steps(tmp_path) == [1, 2, 3]
    ckpt.gc_old(tmp_path, keep=-1)
    assert ckpt.available_steps(tmp_path) == [1, 2, 3]


def test_gc_keep_larger_than_available(tmp_path):
    for s in (1, 2):
        ckpt.save(tmp_path, s, {'a': jnp.zeros(2)})
    ckpt.gc_old(tmp_path, keep=5)
    assert ckpt.available_steps(tmp_path) == [1, 2]


def test_gc_missing_dir_is_noop(tmp_path):
    ckpt.gc_old(tmp_path / 'never_created', keep=2)  # must not raise
    assert ckpt.available_steps(tmp_path / 'never_created') == []


def test_gc_skips_incomplete_dirs(tmp_path):
    """GC counts only committed checkpoints; a crashed save's tmp/partial
    dir neither counts toward keep-K nor gets deleted by gc_old."""
    for s in (1, 2, 3):
        ckpt.save(tmp_path, s, {'a': jnp.zeros(2)})
    (tmp_path / 'step_00000009').mkdir()  # no .complete marker
    ckpt.gc_old(tmp_path, keep=1)
    assert ckpt.available_steps(tmp_path) == [3]
    assert (tmp_path / 'step_00000009').exists()


def test_lm_stream_seekable_deterministic():
    s = LMStream(vocab=64, seq_len=16, batch=4, seed=3)
    b1 = s.batch_at(7)
    b2 = s.batch_at(7)
    np.testing.assert_array_equal(np.asarray(b1['tokens']),
                                  np.asarray(b2['tokens']))
    # labels are next-token shifted views of the same sample
    np.testing.assert_array_equal(np.asarray(b1['tokens'][:, 1:]),
                                  np.asarray(b1['labels'][:, :-1]))
    assert s.bigram_ce < s.uniform_ce  # structure present


def test_memmap_rank_disjoint(tmp_path):
    toks = np.arange(10_000) % 251
    write_tokens(tmp_path / 'corpus', toks)
    world = 4
    seen = []
    for r in range(world):
        ds = MemmapLM(str(tmp_path / 'corpus'), seq_len=32, batch=2,
                      rank=r, world=world, seed=0)
        b = ds.batch_at(0)
        seen.append(np.asarray(b['tokens']))
    flat = np.concatenate([s.reshape(-1) for s in seen])
    # same step across ranks covers disjoint windows (first tokens differ)
    firsts = [s[:, 0] for s in seen]
    assert len({tuple(f.tolist()) for f in firsts}) == world
    # deterministic
    ds0 = MemmapLM(str(tmp_path / 'corpus'), seq_len=32, batch=2,
                   rank=0, world=world, seed=0)
    np.testing.assert_array_equal(np.asarray(ds0.batch_at(0)['tokens']),
                                  seen[0])


def test_prefetcher_matches_stream_and_seeks():
    s = ClassStream(batch=4, dim=8, classes=3, seed=1)
    p = Prefetcher(s, depth=2)
    try:
        for i in range(3):
            got = p.batch_at(i)
            want = s.batch_at(i)
            np.testing.assert_allclose(np.asarray(got['x']),
                                       np.asarray(want['x']))
        got = p.batch_at(10)  # seek
        np.testing.assert_allclose(np.asarray(got['x']),
                                   np.asarray(s.batch_at(10)['x']))
    finally:
        p.close()


def test_ae_stream_range():
    b = AEStream(batch=3).batch_at(0)
    x = np.asarray(b['x'])
    assert x.min() >= 0.0 and x.max() <= 1.0 and x.shape == (3, 784)


# ---------------------------------------------------------------------------
# Refresh-runtime state must checkpoint: resume at step s is bit-exact with
# an uninterrupted run, including a mid-interval phase (cached inverses +
# counters) and adaptive-policy state (drift snapshot).


def _sched_train(name, steps, tmp_path=None, save_at=None, sched=None,
                 **opt_kw):
    import jax.numpy as jnp

    from repro.core.registry import make_optimizer
    from repro.models import module as M
    from repro.models.simple import MLP, classifier_loss_fn
    from repro.train.step import init_opt_state, make_train_step

    stream = ClassStream(batch=32, dim=8, classes=3, seed=0)
    model = MLP([8, 16, 3])
    model.loss_fn = classifier_loss_fn(model)
    params = M.init_params(model.param_specs(), jax.random.PRNGKey(0))
    opt, capture = make_optimizer(name, lr=0.05, **opt_kw)
    taps_fn = (lambda p: model.make_taps(32, capture)) \
        if capture.needs_taps else None
    state = init_opt_state(model, opt, capture, params, stream.batch_at(0),
                           taps_fn=taps_fn, sched=sched)
    step = jax.jit(make_train_step(model, opt, capture, taps_fn=taps_fn,
                                   sched=sched))
    for i in range(steps):
        if save_at is not None and i == save_at:
            ckpt.save(tmp_path, i, {'params': params, 'opt_state': state},
                      {'next_step': i})
            template = jax.tree_util.tree_map(
                jnp.zeros_like, {'params': params, 'opt_state': state})
            restored, meta = ckpt.restore(tmp_path, i, template)
            params, state = restored['params'], restored['opt_state']
            assert meta['next_step'] == i
        params, state, _ = step(params, state, stream.batch_at(i))
    return params, state


def _assert_bit_equal(a, b):
    for x, y in zip(jax.tree_util.tree_leaves(a), jax.tree_util.tree_leaves(b)):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


@pytest.mark.parametrize('name,kw,save_at', [
    # save at step 4 = mid-interval for k=3 (last refresh at 3, cached
    # inverses + since-counter must survive the roundtrip)
    ('kfac', {'interval': 3}, 4),
    ('shampoo', {'interval': 2}, 3),
    # adaptive policy: the drift snapshot is part of the checkpoint
    ('eva', {}, 4),
])
def test_refresh_state_resume_bit_exact(tmp_path, name, kw, save_at):
    from repro.schedule.policy import adaptive

    if name == 'eva':
        kw = dict(kw, policy=adaptive(threshold=0.05))
    steps = 7
    p_ref, s_ref = _sched_train(name, steps, **kw)
    p_res, s_res = _sched_train(name, steps, tmp_path=tmp_path,
                                save_at=save_at, **kw)
    _assert_bit_equal(p_ref, p_res)
    _assert_bit_equal(s_ref, s_res)


@pytest.mark.parametrize('name,kw,save_at', [
    # onestep pipeline: the checkpoint lands at a step boundary with a
    # buffer IN FLIGHT (the stats exchanged at step save_at-1 not yet
    # applied, a mid-interval inverse age) — PipelineState must roundtrip
    ('kfac', {'interval': 3}, 4),
    ('eva', {}, 4),
])
def test_pipeline_state_resume_bit_exact(tmp_path, name, kw, save_at):
    from repro.schedule.runtime import RefreshRuntime

    rt = RefreshRuntime(pipeline='onestep')
    steps = 7
    p_ref, s_ref = _sched_train(name, steps, sched=rt, **kw)
    p_res, s_res = _sched_train(name, steps, tmp_path=tmp_path,
                                save_at=save_at, sched=rt, **kw)
    _assert_bit_equal(p_ref, p_res)
    _assert_bit_equal(s_ref, s_res)
