"""Mini dry-run in a subprocess (the 512-device flag must not leak into
this test process): lower+compile a reduced arch on a (2,2,2) mesh and
check the JSON record schema + HLO analyzer outputs."""
import json
import subprocess
import sys
import textwrap
from pathlib import Path

import pytest

SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import json, sys
    import jax
    from repro.configs import get_reduced, SHAPE_BY_NAME
    from repro.configs.base import ShapeCell
    from repro.launch import hlo_analysis
    from repro.launch.dryrun import build_cell
    from repro.models import build_model

    from repro.sharding import compat
    mesh = compat.make_mesh((2, 2, 2), ('pod', 'data', 'model'))
    cfg = get_reduced(sys.argv[1])
    shape = ShapeCell('mini_train', seq_len=16, global_batch=8, kind=sys.argv[2])
    fn, args, shardings, donate, tokens, kind = build_cell(cfg, shape, mesh, [])
    with compat.set_mesh(mesh):
        lowered = jax.jit(fn, in_shardings=shardings,
                          donate_argnums=donate).lower(*args)
        compiled = lowered.compile()
    costs = hlo_analysis.analyze(compiled.as_text())
    mem = compiled.memory_analysis()
    print(json.dumps({
        'flops': costs.flops, 'traffic': costs.traffic_bytes,
        'collective': costs.collective_bytes,
        'temp': mem.temp_size_in_bytes,
        'cost_flops': float(compat.cost_analysis(compiled).get('flops', 0)),
    }))
""")


def _run(arch: str, kind: str) -> dict:
    out = subprocess.run(
        [sys.executable, '-c', SCRIPT, arch, kind],
        capture_output=True, text=True, timeout=600,
        # JAX_PLATFORMS pinned: the scrubbed env must not fall through to
        # accelerator discovery (libtpu-on-a-TPU-less-host hangs forever)
        env={'PYTHONPATH': 'src', 'PATH': '/usr/bin:/bin',
             'HOME': '/root', 'JAX_PLATFORMS': 'cpu'},
        cwd=Path(__file__).resolve().parent.parent)
    assert out.returncode == 0, out.stderr[-3000:]
    return json.loads(out.stdout.strip().splitlines()[-1])


@pytest.mark.parametrize('arch,kind', [
    ('qwen2-0.5b', 'train'),
    ('qwen3-moe-30b-a3b', 'train'),
    ('mamba2-780m', 'decode'),
    ('jamba-v0.1-52b', 'train'),
])
def test_mini_multipod_compiles(arch, kind):
    rec = _run(arch, kind)
    assert rec['flops'] > 0
    assert rec['traffic'] > 0
    if kind == 'train':
        assert rec['collective'] > 0  # gradient reduction must exist
    # trip-count correction: corrected flops >= raw cost_analysis flops
    assert rec['flops'] >= 0.5 * rec['cost_flops']


def test_main_process_has_one_device():
    """The 512-device flag must never leak outside dryrun.py."""
    import jax
    assert jax.device_count() == 1
