"""Curvature refresh runtime (repro.schedule).

Contracts proven here:
  * with ``every_k(1)`` (and with ``every_k(k)`` for the interval methods)
    the scheduled optimizers are BIT-IDENTICAL (atol=0) to the legacy
    per-optimizer behavior — the references below replicate the exact
    pre-runtime update structure (``count % interval`` under ``lax.cond``,
    always-fresh KV snapshots for the eva family);
  * single-host refresh ≡ W-worker ownership-sharded refresh under
    shard_map (subprocess with 4 host devices) to float tolerance — the
    exchange itself is bit-exact (see tests/test_comm_exchange.py), the
    slice-granular compute batches LAPACK differently (last-ulp);
  * policy semantics: every_k counts, warmup_then_k, adaptive drift
    triggering;
  * ownership assignment is deterministic, covers every item, and balances
    weighted cost;
  * the train-level default policy threads through ``Extras.sched``.
"""
import json
import subprocess
import sys
import textwrap
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import bucketing
from repro.core import kv as kvlib
from repro.core import precondition as pre
from repro.core.eva import (_extract, _stats_plan, _zeros_like_spec,
                            eva_preconditioner)
from repro.core.eva_f import eva_f_preconditioner
from repro.core.eva_s import eva_s_preconditioner
from repro.core.foof import foof_preconditioner
from repro.core.kfac import _damped_inv, kfac_preconditioner
from repro.core.shampoo import shampoo_preconditioner
from repro.core.transform import Extras
from repro.schedule import ownership, runtime as schedrt
from repro.schedule.policy import (SchedState, adaptive, every_k, named_policy,
                                   warmup_then_k)
from repro.sharding.constraints import pmean_stats

GAMMA = 0.03

SHAPES = {
    'blk0/w': (8, 4),
    'blk1/w': (8, 4),
    'blk2/w': (8, 4),
    'head/w': (8, 3),          # singleton bucket (broadcast path)
    'stack/w': (2, 6, 4),      # scan-stacked leading dim
}


def _psd(key, *shape):
    m = jax.random.normal(key, shape)
    return m @ jnp.swapaxes(m, -1, -2) + 0.1 * jnp.eye(shape[-1])


def _grads(seed):
    key = jax.random.PRNGKey(seed)
    return {p: jax.random.normal(jax.random.fold_in(key, i), s)
            for i, (p, s) in enumerate(SHAPES.items())}


def _capture_stats(seed):
    """Per-path LayerStats as the forward/backward capture would emit."""
    key = jax.random.PRNGKey(1000 + seed)
    out = {}
    for i, (p, s) in enumerate(SHAPES.items()):
        ks = jax.random.split(jax.random.fold_in(key, i), 4)
        lead, d_in, d_out = s[:-2], s[-2], s[-1]
        out[p] = kvlib.LayerStats(
            a_mean=jax.random.normal(ks[0], lead + (d_in,)),
            b_mean=jax.random.normal(ks[1], lead + (d_out,)),
            a_outer=_psd(ks[2], *lead, d_in, d_in),
            b_outer=_psd(ks[3], *lead, d_out, d_out))
    return out


def _params():
    return kvlib.unflatten_params(_grads(0))


def _assert_trees_equal(a, b, msg=''):
    la, lb = jax.tree_util.tree_leaves(a), jax.tree_util.tree_leaves(b)
    assert len(la) == len(lb), msg
    for x, y in zip(la, lb):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y),
                                      err_msg=msg)


# ---------------------------------------------------------------------------
# Legacy references: the exact pre-runtime update structure


def _legacy_kfac_run(steps, interval, kf_decay=0.9):
    """The pre-runtime K-FAC preconditioner: count % interval under cond,
    recompute via one fused lax.map per bucket."""
    fields = ('a_outer', 'b_outer')
    params = _params()
    flat = kvlib.flatten_params(params)
    stats0 = _capture_stats(0)
    plan = _stats_plan(flat, stats0, None)
    zeros = bucketing.gather_tree(plan, _zeros_like_spec(_extract(stats0, fields)))
    run = kvlib.init_running(zeros)
    a_inv = {k: jnp.zeros_like(st.a_outer) for k, st in run.stats.items()}
    b_inv = {k: jnp.zeros_like(st.b_outer) for k, st in run.stats.items()}
    count = jnp.zeros((), jnp.int32)
    outs = []
    for t in range(steps):
        g = _grads(t)
        fresh = pmean_stats(bucketing.gather_tree(
            plan, _extract(_capture_stats(t), fields)))
        stats, run = kvlib.update_running(run, fresh, kf_decay)

        def one(ao, bo):
            gamma_r, gamma_q = pre.kfac_pi_damping(ao, bo, GAMMA)
            return _damped_inv(ao, gamma_r), _damped_inv(bo, gamma_q)

        def recompute(_):
            ai, bi = {}, {}
            for k, st in stats.items():
                ai[k], bi[k] = pre.map_bucket(one, st.a_outer, st.b_outer)
            return ai, bi

        refresh = (count % interval) == 0
        a_inv, b_inv = jax.lax.cond(refresh, recompute,
                                    lambda _: (a_inv, b_inv), operand=None)
        ops = {k: kvlib.LayerStats(a_outer=a_inv[k], b_outer=b_inv[k])
               for k in a_inv}
        outs.append(pre.precondition_tree(g, ops, 'kfac_cached', GAMMA,
                                          plan=plan))
        count = count + 1
    return outs


def _legacy_foof_run(steps, interval, kf_decay=0.9):
    fields = ('a_outer',)
    params = _params()
    flat = kvlib.flatten_params(params)
    stats0 = _capture_stats(0)
    plan = _stats_plan(flat, stats0, None)
    zeros = bucketing.gather_tree(plan, _zeros_like_spec(_extract(stats0, fields)))
    run = kvlib.init_running(zeros)
    a_inv = {k: jnp.zeros_like(st.a_outer) for k, st in run.stats.items()}
    count = jnp.zeros((), jnp.int32)
    outs = []
    for t in range(steps):
        g = _grads(t)
        fresh = pmean_stats(bucketing.gather_tree(
            plan, _extract(_capture_stats(t), fields)))
        stats, run = kvlib.update_running(run, fresh, kf_decay)

        def recompute(_):
            return {k: pre.map_bucket(lambda m: _damped_inv(m, GAMMA),
                                      st.a_outer)
                    for k, st in stats.items()}

        refresh = (count % interval) == 0
        a_inv = jax.lax.cond(refresh, recompute, lambda _: a_inv, operand=None)
        ops = {k: kvlib.LayerStats(a_outer=a_inv[k]) for k in a_inv}
        outs.append(pre.precondition_tree(g, ops, 'foof_cached', GAMMA,
                                          plan=plan))
        count = count + 1
    return outs


def _legacy_shampoo_run(steps, interval, eps_init=1e-6):
    params = _params()
    flat = kvlib.flatten_params(params)
    plan = bucketing.build_plan(flat)
    m_in, m_out = {}, {}
    for b in plan.buckets:
        lead = (len(b.paths),) + b.shape[:-2]
        d_in, d_out = b.shape[-2], b.shape[-1]
        m_in[b.key] = eps_init * jnp.broadcast_to(
            jnp.eye(d_in, dtype=jnp.float32), lead + (d_in, d_in))
        m_out[b.key] = eps_init * jnp.broadcast_to(
            jnp.eye(d_out, dtype=jnp.float32), lead + (d_out, d_out))
    p_in = jax.tree_util.tree_map(jnp.zeros_like, m_in)
    p_out = jax.tree_util.tree_map(jnp.zeros_like, m_out)
    count = jnp.zeros((), jnp.int32)
    outs = []
    for t in range(steps):
        g = _grads(t)
        g_b = bucketing.gather(plan, g)
        for b in plan.buckets:
            gg = g_b[b.key].astype(jnp.float32)
            m_in[b.key] = m_in[b.key] + jnp.einsum('...io,...jo->...ij', gg, gg)
            m_out[b.key] = m_out[b.key] + jnp.einsum('...io,...ij->...oj', gg, gg)

        def recompute(_):
            return ({k: pre.map_bucket(
                        lambda m: pre._inv_proot_psd(m, 1e-4, 0.25), m_in[k])
                     for k in m_in},
                    {k: pre.map_bucket(
                        lambda m: pre._inv_proot_psd(m, 1e-4, 0.25), m_out[k])
                     for k in m_out})

        refresh = (count % interval) == 0
        p_in, p_out = jax.lax.cond(refresh, recompute,
                                   lambda _: (p_in, p_out), operand=None)
        ops = {k: kvlib.LayerStats(a_outer=p_in[k], b_outer=p_out[k])
               for k in p_in}
        outs.append(pre.precondition_tree(g, ops, 'shampoo_cached', 1e-4,
                                          plan=plan))
        count = count + 1
    return outs


def _legacy_eva_family_run(method, steps, kv_decay=0.9):
    """Pre-runtime eva/eva_f: always-fresh bias-corrected KV snapshot."""
    fields = {'eva': ('a_mean', 'b_mean'), 'eva_f': ('a_mean',)}[method]
    params = _params()
    flat = kvlib.flatten_params(params)
    stats0 = _capture_stats(0)
    plan = _stats_plan(flat, stats0, None)
    run = kvlib.init_running(bucketing.gather_tree(
        plan, _zeros_like_spec(_extract(stats0, fields))))
    outs = []
    for t in range(steps):
        g = _grads(t)
        fresh = pmean_stats(bucketing.gather_tree(
            plan, _extract(_capture_stats(t), fields)))
        stats, run = kvlib.update_running(run, fresh, kv_decay)
        outs.append(pre.precondition_tree(g, stats, method, GAMMA, plan=plan))
    return outs


def _legacy_eva_s_run(steps, kv_decay=0.9):
    params = _params()
    flat = kvlib.flatten_params(params)
    plan = bucketing.build_plan(flat)
    zeros = {
        b.key: kvlib.LayerStats(
            a_mean=jnp.zeros((len(b.paths),) + b.shape[:-1], jnp.float32),
            b_mean=jnp.zeros((len(b.paths),) + b.shape[:-2] + b.shape[-1:],
                             jnp.float32))
        for b in plan.buckets}
    run = kvlib.init_running(zeros)
    outs = []
    for t in range(steps):
        g = _grads(t)
        g_b = bucketing.gather(plan, g)
        fresh = {}
        for b in plan.buckets:
            vi, vo = pre.grad_kvs(g_b[b.key])
            fresh[b.key] = kvlib.LayerStats(a_mean=vi, b_mean=vo)
        stats, run = kvlib.update_running(run, fresh, kv_decay)
        outs.append(pre.precondition_tree(g, stats, 'eva_s', GAMMA, plan=plan))
    return outs


# ---------------------------------------------------------------------------
# Scheduled runs


def _scheduled_run(method, steps, sched=None, **kw):
    maker = {
        'eva': lambda: eva_preconditioner(GAMMA, 0.9, **kw),
        'eva_f': lambda: eva_f_preconditioner(GAMMA, 0.9, **kw),
        'eva_s': lambda: eva_s_preconditioner(GAMMA, 0.9, **kw),
        'foof': lambda: foof_preconditioner(GAMMA, 0.9, **kw),
        'kfac': lambda: kfac_preconditioner(GAMMA, 0.9, **kw),
        'shampoo': lambda: shampoo_preconditioner(1e-4, **kw),
    }[method]
    opt = maker()
    params = _params()
    needs_stats = method in ('eva', 'eva_f', 'foof', 'kfac')
    extras0 = Extras(stats=_capture_stats(0) if needs_stats else None,
                     sched=sched)
    state = opt.init(params, extras0)
    outs = []
    for t in range(steps):
        ex = Extras(stats=_capture_stats(t) if needs_stats else None,
                    sched=sched)
        out, state = opt.update(_grads(t), state, extras=ex)
        outs.append(kvlib.flatten_params(out))
    return outs, state


STEPS = 6

LEGACY = {
    'eva': lambda k: _legacy_eva_family_run('eva', STEPS),
    'eva_f': lambda k: _legacy_eva_family_run('eva_f', STEPS),
    'eva_s': lambda k: _legacy_eva_s_run(STEPS),
    'foof': lambda k: _legacy_foof_run(STEPS, k),
    'kfac': lambda k: _legacy_kfac_run(STEPS, k),
    'shampoo': lambda k: _legacy_shampoo_run(STEPS, k),
}

ALL_METHODS = sorted(LEGACY)
INTERVAL_METHODS = ['foof', 'kfac', 'shampoo']


@pytest.mark.parametrize('method', ALL_METHODS)
def test_every_1_bit_identical_to_legacy(method):
    """every_k(1) == the historical always-fresh/interval=1 behavior,
    atol=0, for all six methods."""
    ref = LEGACY[method](1)
    outs, _ = _scheduled_run(method, STEPS, policy=every_k(1))
    for t in range(STEPS):
        _assert_trees_equal(outs[t], ref[t], msg=f'{method} step {t}')


@pytest.mark.parametrize('method', ALL_METHODS)
def test_pipeline_sync_bit_identical_to_legacy(method):
    """An explicit ``RefreshRuntime(pipeline='sync')`` is the staged
    issue/collect composition of every exchange — proven atol=0 against the
    pre-pipeline legacy references, state included (``pipe=None`` adds no
    leaves, so the state trees match the default-runtime run exactly)."""
    ref = LEGACY[method](1)
    sync = schedrt.RefreshRuntime(pipeline='sync')
    outs, state = _scheduled_run(method, STEPS, policy=every_k(1), sched=sync)
    for t in range(STEPS):
        _assert_trees_equal(outs[t], ref[t], msg=f'{method} step {t}')
    _, state_default = _scheduled_run(method, STEPS, policy=every_k(1))
    _assert_trees_equal(state, state_default, msg=f'{method} state')
    assert (jax.tree_util.tree_structure(state)
            == jax.tree_util.tree_structure(state_default))


@pytest.mark.parametrize('method', INTERVAL_METHODS)
def test_every_k_bit_identical_to_legacy_interval(method):
    """every_k(3) == the historical ``count % 3`` branch, atol=0 —
    mid-interval cached-inverse steps included."""
    ref = LEGACY[method](3)
    outs, _ = _scheduled_run(method, STEPS, policy=every_k(3))
    for t in range(STEPS):
        _assert_trees_equal(outs[t], ref[t], msg=f'{method} step {t}')


@pytest.mark.parametrize('method', INTERVAL_METHODS)
def test_interval_kwarg_equals_policy(method):
    """The legacy ``interval=`` kwarg is exactly ``every_k(interval)``."""
    a, sa = _scheduled_run(method, STEPS, interval=3)
    b, sb = _scheduled_run(method, STEPS, policy=every_k(3))
    for t in range(STEPS):
        _assert_trees_equal(a[t], b[t], msg=f'{method} step {t}')
    _assert_trees_equal(sa, sb, msg=f'{method} state')


# ---------------------------------------------------------------------------
# Policy semantics


def _sched_of(state) -> SchedState:
    sts = schedrt.sched_states(state)
    assert len(sts) == 1
    return sts[0]


def test_every_k_refresh_count():
    _, state = _scheduled_run('kfac', STEPS, policy=every_k(3))
    s = _sched_of(state)
    assert int(s.count) == STEPS
    assert int(s.n_refresh) == 2          # steps 0 and 3
    assert int(s.since) == STEPS - 1 - 3  # last refresh at step 3


def test_warmup_then_k():
    _, state = _scheduled_run('kfac', STEPS, policy=warmup_then_k(3, 10))
    s = _sched_of(state)
    # steps 0,1,2 warm up; step 3 fires ((3-3) % 10 == 0); 4,5 do not
    assert int(s.n_refresh) == 4


def test_adaptive_triggers_on_drift():
    """An unreachable threshold refreshes only at the forced step 0; a
    ~zero threshold refreshes every step (the stats stream moves every
    step) and must then equal every_k(1) bit-exactly."""
    _, state = _scheduled_run('kfac', STEPS, policy=adaptive(threshold=1e6))
    s = _sched_of(state)
    assert int(s.n_refresh) == 1          # only the forced step-0 refresh
    eager, state = _scheduled_run('kfac', STEPS,
                                  policy=adaptive(threshold=1e-9))
    s = _sched_of(state)
    assert int(s.n_refresh) == STEPS      # drift always exceeds ~0
    # and an eager adaptive run equals every-step refresh bit-exactly
    ref, _ = _scheduled_run('kfac', STEPS, policy=every_k(1))
    for t in range(STEPS):
        _assert_trees_equal(eager[t], ref[t], msg=f'step {t}')


def test_adaptive_max_interval_bound():
    _, state = _scheduled_run('kfac', STEPS,
                              policy=adaptive(threshold=1e6, max_interval=2))
    s = _sched_of(state)
    assert int(s.n_refresh) == 3          # steps 0, 2, 4 (since >= 1 forces)


def test_named_policy_registry():
    assert named_policy('every_k', k=4).name == 'every_k(4)'
    assert named_policy('adaptive', threshold=0.1).wants_snapshot
    with pytest.raises(KeyError):
        named_policy('nope')


def test_extras_sched_default_policy():
    """A train-level default policy (Extras.sched) applies to optimizers
    built without an explicit policy/interval."""
    rt = schedrt.RefreshRuntime(policy=every_k(3))
    opt = kfac_preconditioner(GAMMA, 0.9)
    params = _params()
    state = opt.init(params, Extras(stats=_capture_stats(0), sched=rt))
    for t in range(STEPS):
        _, state = opt.update(_grads(t), state,
                              extras=Extras(stats=_capture_stats(t), sched=rt))
    assert int(_sched_of(state).n_refresh) == 2
    # an explicitly-tuned local interval beats the train-level default
    opt = kfac_preconditioner(GAMMA, 0.9, interval=2)
    state = opt.init(params, Extras(stats=_capture_stats(0), sched=rt))
    for t in range(STEPS):
        _, state = opt.update(_grads(t), state,
                              extras=Extras(stats=_capture_stats(t), sched=rt))
    assert int(_sched_of(state).n_refresh) == 3


def test_schedule_metrics():
    _, state = _scheduled_run('foof', STEPS, policy=every_k(2))
    m = schedrt.schedule_metrics(state)
    assert int(m['refreshes']) == 3
    assert schedrt.schedule_metrics({'no': 'sched'}) == {}


# ---------------------------------------------------------------------------
# Ownership


def test_ownership_assignment_covers_and_balances():
    plan = bucketing.build_plan(_grads(0))
    cost = ownership.inverse_cost('both')
    owners = ownership.assign_owners(plan, cost, world=3)
    per_worker = np.zeros(3)
    for b in plan.buckets:
        assert owners[b.key].shape == (len(b.paths),)
        assert set(owners[b.key].tolist()) <= {0, 1, 2}
        for i, w in enumerate(owners[b.key]):
            per_worker[w] += cost(b)
    assert (per_worker > 0).all()          # nobody idle at this item count
    # deterministic (and cached) across calls
    again = ownership.assign_owners(plan, cost, world=3)
    for k in owners:
        np.testing.assert_array_equal(owners[k], again[k])
    # W=1: everything owned by rank 0
    solo = ownership.assign_owners(plan, cost, world=1)
    for k in solo:
        assert (solo[k] == 0).all()


def test_inverse_cost_model():
    plan = bucketing.build_plan(_grads(0))
    by_key = {b.key: b for b in plan.buckets}
    b84 = by_key[bucketing.bucket_key((8, 4), jnp.float32)]
    assert ownership.inverse_cost('both')(b84) == 8 ** 3 + 4 ** 3
    assert ownership.inverse_cost('left')(b84) == 8 ** 3
    bstack = by_key[bucketing.bucket_key((2, 6, 4), jnp.float32)]
    assert ownership.inverse_cost('both')(bstack) == 2 * (6 ** 3 + 4 ** 3)
    with pytest.raises(ValueError):
        ownership.inverse_cost('up')


def test_world_and_rank_single_host():
    world, rank = ownership.world_and_rank()
    assert world == 1 and rank is None


# ---------------------------------------------------------------------------
# Single-host ≡ W-worker ownership under shard_map (subprocess: the forced
# 4-device flag must not leak into this test process)

_SHARD_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    import json
    import jax, jax.numpy as jnp, numpy as np
    from jax.sharding import PartitionSpec as P
    from repro.core import kv as kvlib
    from repro.core.kfac import kfac_preconditioner
    from repro.core.transform import Extras
    from repro.schedule.policy import every_k
    from repro.sharding import compat

    SHAPES = {'blk0/w': (8, 4), 'blk1/w': (8, 4), 'blk2/w': (8, 4),
              'head/w': (8, 3), 'stack/w': (2, 6, 4)}

    def psd(key, *shape):
        m = jax.random.normal(key, shape)
        return m @ jnp.swapaxes(m, -1, -2) + 0.1 * jnp.eye(shape[-1])

    def grads(seed):
        key = jax.random.PRNGKey(seed)
        return {p: jax.random.normal(jax.random.fold_in(key, i), s)
                for i, (p, s) in enumerate(SHAPES.items())}

    def stats(seed):
        key = jax.random.PRNGKey(1000 + seed)
        out = {}
        for i, (p, s) in enumerate(SHAPES.items()):
            ks = jax.random.split(jax.random.fold_in(key, i), 2)
            lead, d_in, d_out = s[:-2], s[-2], s[-1]
            out[p] = kvlib.LayerStats(
                a_outer=psd(ks[0], *lead, d_in, d_in),
                b_outer=psd(ks[1], *lead, d_out, d_out))
        return out

    STEPS = 4
    opt = kfac_preconditioner(0.03, 0.9, policy=every_k(2))
    params = kvlib.unflatten_params(grads(0))
    from repro.schedule.runtime import RefreshRuntime

    def run_single():
        state = opt.init(params, Extras(stats=stats(0)))
        outs = []
        for t in range(STEPS):
            out, state = opt.update(grads(t), state,
                                    extras=Extras(stats=stats(t)))
            outs.append(out)
        return outs, state

    def run_meshed(shard):
        rt = RefreshRuntime(shard_refresh=shard)
        mesh = compat.make_mesh((4,), ('data',))
        state = opt.init(params, Extras(stats=stats(0), sched=rt))

        def body(g, s, st):
            return opt.update(g, s, extras=Extras(stats=st, sched=rt))

        step = jax.jit(compat.shard_map(
            body, mesh=mesh, in_specs=(P(), P(), P()), out_specs=(P(), P()),
            check=False))
        outs = []
        for t in range(STEPS):
            out, state = step(grads(t), state, stats(t))
            outs.append(out)
        return outs, state

    def maxdiff(a, b):
        return max(float(np.max(np.abs(np.asarray(x).astype(np.float64)
                                       - np.asarray(y).astype(np.float64))))
                   for x, y in zip(jax.tree_util.tree_leaves(a),
                                   jax.tree_util.tree_leaves(b)))

    (o1, s1) = run_single()
    (o2, s2) = run_meshed(shard=True)      # ownership-sharded refresh
    (o3, s3) = run_meshed(shard=False)     # every worker recomputes all
    print(json.dumps({
        'devices': jax.device_count(),
        # ownership mechanism alone: sharded vs redundant on the SAME mesh
        'shard_vs_redundant_out': maxdiff(o2, o3),
        'shard_vs_redundant_state': maxdiff(s2, s3),
        # cross-world: only the pmean of replicated stats may round
        'shard_vs_single_out': maxdiff(o2, o1),
        'shard_vs_single_state': maxdiff(s2, s1),
    }))
""")


@pytest.mark.multihost
def test_sharded_refresh_matches_single_host():
    out = subprocess.run(
        [sys.executable, '-c', _SHARD_SCRIPT],
        capture_output=True, text=True, timeout=600,
        # JAX_PLATFORMS pinned: the scrubbed env must not fall through to
        # accelerator discovery (libtpu-on-a-TPU-less-host hangs forever)
        env={'PYTHONPATH': 'src', 'PATH': '/usr/bin:/bin', 'HOME': '/root',
             'JAX_PLATFORMS': 'cpu'},
        cwd=Path(__file__).resolve().parent.parent)
    assert out.returncode == 0, out.stderr[-3000:]
    rec = json.loads(out.stdout.strip().splitlines()[-1])
    assert rec['devices'] == 4
    # W-worker sharded refresh vs W-worker redundant refresh on the same
    # mesh: the EXCHANGE is bit-exact (owned-slice copies / x+0 psums —
    # tests/test_comm_exchange.py proves allgather ≡ psum atol=0 for all
    # six methods), but since the comm layer the sharded path owns stack
    # slices at (row × lead-dim) granularity, so its LAPACK inverses run
    # per (d, d) slice where the redundant worker batches (lead, d, d) —
    # batched-vs-single getrf moves the last float ulp (~1e-6,
    # data-dependent; see the lax.map note in test_bucketing).
    assert rec['shard_vs_redundant_out'] < 1e-4
    assert rec['shard_vs_redundant_state'] < 1e-4
    # Against a single host: additionally the pre-existing pmean_stats
    # reduction of replicated statistics (a psum of four equal f32 values
    # can round in the last ulp); the trajectory must still agree to float
    # tolerance.
    assert rec['shard_vs_single_out'] < 1e-4
    assert rec['shard_vs_single_state'] < 1e-4
