"""Elastic training: checkpoint resharding across world sizes + the
preemption chaos tests.

Three layers:

* unit tests for ``schedule/reshard.py`` (metadata contract, ownership
  delta, pipeline drain rule) and ``launch.mesh.make_data_mesh`` — single
  device, fast;
* a single-device anchor: ``fit_elastic`` at W=1 resumes bit-exactly and
  matches ``fit`` bit-exactly (size-1 collectives are exact);
* ``@pytest.mark.multihost`` subprocess chaos tests (forced 4 host
  devices): a W=4 run SIGTERM-killed mid-run, resumed at W=2, killed
  again, re-expanded to W=4 — the stitched loss trajectory must match the
  uninterrupted W=4 run within ``TRAJ_TOL`` for eva AND kfac, with every
  telemetry record (including the ``reshard`` events) schema-valid.

Tolerance: across a resize only the float reduction order of the batch
mean / stats psum changes (pmean of W shard-means = global mean exactly in
real arithmetic).  Measured drift on the seed run: eva 0.0 (bit-exact),
kfac ≤ 6e-8; ``TRAJ_TOL = 5e-6`` documents the contract with margin
(docs/CHECKPOINT_FORMAT.md).  The ``pipeline='onestep'`` drain rule is
*semantic*, not numerical — one cold pipeline step after a resize — so it
is asserted structurally, not by trajectory equality.
"""
import json
import subprocess
import sys
import textwrap
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import bucketing
from repro.core.registry import make_optimizer
from repro.data.synthetic import ClassStream
from repro.launch.mesh import make_data_mesh
from repro.models import module as M
from repro.models.simple import MLP, classifier_loss_fn
from repro.obs import events as obs_events
from repro.schedule import pipeline as pipemod
from repro.schedule import reshard
from repro.train.step import taps_caller
from repro.train.trainer import Trainer, TrainerConfig

# documented cross-resize trajectory tolerance (sync pipeline, f32 wire)
TRAJ_TOL = 5e-6

REPO = Path(__file__).resolve().parent.parent


# ---------------------------------------------------------------------------
# reshard.py units


def _plan():
    leaves = {'blk0/w': jnp.zeros((8, 4)), 'blk1/w': jnp.zeros((8, 4)),
              'head/w': jnp.zeros((8, 3)), 'stack/w': jnp.zeros((2, 6, 4))}
    return bucketing.build_plan(leaves)


def test_plan_fingerprint_stable_and_distinct():
    p = _plan()
    assert reshard.plan_fingerprint(p) == reshard.plan_fingerprint(_plan())
    other = bucketing.build_plan({'blk0/w': jnp.zeros((8, 5))})
    assert reshard.plan_fingerprint(p) != reshard.plan_fingerprint(other)
    assert reshard.plan_fingerprint(None) == ''


def test_metadata_roundtrip_and_mismatches():
    p = _plan()
    meta = reshard.elastic_metadata(4, plan=p, pipeline='onestep')
    assert meta == {'world': 4, 'pipeline': 'onestep',
                    'plan': reshard.plan_fingerprint(p)}
    assert reshard.check_metadata(meta, plan=p, pipeline='onestep') == 4
    # pre-elastic checkpoint (no block): accepted, world unknown
    assert reshard.check_metadata(None, plan=p) == 0
    assert reshard.check_metadata({}, plan=p) == 0
    with pytest.raises(reshard.ReshardError, match='bucket plan'):
        reshard.check_metadata(meta, plan=None, pipeline='onestep')
    with pytest.raises(reshard.ReshardError, match='pipeline mode'):
        reshard.check_metadata(meta, plan=p, pipeline='sync')


def test_ownership_delta():
    p = _plan()
    same = reshard.ownership_delta(p, 4, 4)
    # total = sum of rows x lead over buckets: 2*1 + 1*1 + 1*2 = 5 slices
    assert same['slices_total'] == 5 and same['slices_moved'] == 0
    d = reshard.ownership_delta(p, 1, 4)
    assert d['slices_total'] == 5
    assert 0 < d['slices_moved'] <= 5  # W=1 owns all at rank 0; W=4 spreads
    assert reshard.ownership_delta(None, 4, 2) == {}


def _pipe_state():
    buf = {'s': jnp.full((3,), 7.0), 't': jnp.full((2, 2), -1.0)}
    return {'ema': jnp.ones((4,)),
            'pipe': {'stats': pipemod.PipelineState(
                         inflight=buf, age=jnp.asarray(3, jnp.int32)),
                     'refresh': pipemod.PipelineState(
                         inflight=None, age=jnp.asarray(2, jnp.int32))}}


def test_reshard_state_drain_keep_and_passthrough():
    st = _pipe_state()
    # resize + drain: buffers zeroed, ages reset — the documented cold start
    out, body = reshard.reshard_state(st, world_from=4, world_to=2)
    assert body['pipeline'] == 'drained'
    assert float(out['pipe']['stats'].age) == 0
    assert float(out['pipe']['refresh'].age) == 0
    assert out['pipe']['refresh'].inflight is None
    np.testing.assert_array_equal(out['pipe']['stats'].inflight['s'],
                                  np.zeros(3))
    np.testing.assert_array_equal(out['ema'], st['ema'])  # untouched
    # resize + keep: values pass through
    out, body = reshard.reshard_state(st, world_from=4, world_to=2,
                                      pipeline_rule='keep')
    assert body['pipeline'] == 'kept'
    np.testing.assert_array_equal(out['pipe']['stats'].inflight['s'],
                                  np.full(3, 7.0))
    # no resize: bit-exact passthrough (the non-elastic resume contract)
    out, body = reshard.reshard_state(st, world_from=4, world_to=4)
    assert body['pipeline'] == 'kept'
    assert float(out['pipe']['stats'].age) == 3
    # no pipeline in the state at all
    out, body = reshard.reshard_state({'ema': jnp.ones(2)},
                                      world_from=4, world_to=2)
    assert body['pipeline'] == 'none'
    with pytest.raises(ValueError, match='pipeline_rule'):
        reshard.reshard_state(st, world_from=4, world_to=2,
                              pipeline_rule='zero')


def test_reshard_event_body_is_schema_valid():
    st = _pipe_state()
    _, body = reshard.reshard_state(st, world_from=4, world_to=2,
                                    plan=_plan(), step=17, source='live')
    rec = obs_events.Recorder(None).emit('reshard', **body)  # fail-fast
    assert rec['world_from'] == 4 and rec['world_to'] == 2
    assert rec['step'] == 17 and rec['slices_total'] == 5
    assert obs_events.validate_record(rec) == []


def test_make_data_mesh_bounds():
    mesh = make_data_mesh(1)
    assert mesh.axis_names == ('data',) and mesh.devices.size == 1
    n = jax.device_count()
    assert make_data_mesh().devices.size == n
    with pytest.raises(ValueError, match='world'):
        make_data_mesh(n + 1)
    with pytest.raises(ValueError, match='world'):
        make_data_mesh(0)


def test_taps_caller_arities():
    one = taps_caller(lambda p: ('one', p))
    two = taps_caller(lambda p, b: ('two', p, b))
    none = taps_caller(None)
    assert one('P', 'B') == ('one', 'P')
    assert two('P', 'B') == ('two', 'P', 'B')
    assert none('P', 'B') is None


# ---------------------------------------------------------------------------
# Single-device anchor: W=1 elastic == fit, and elastic resume is bit-exact


def _build(name, out_dir, steps, ckpt_every=0):
    model = MLP([8, 16, 3])
    model.loss_fn = classifier_loss_fn(model)
    params = M.init_params(model.param_specs(), jax.random.PRNGKey(0))
    opt, capture = make_optimizer(name, lr=0.05)
    taps_fn = ((lambda p, b: model.make_taps(b['x'].shape[0], capture))
               if capture.needs_taps else None)
    cfg = TrainerConfig(total_steps=steps, log_every=4,
                        ckpt_every=ckpt_every, out_dir=str(out_dir))
    return Trainer(model, opt, capture, cfg, taps_fn=taps_fn), params


def test_fit_elastic_w1_matches_fit_bit_exact(tmp_path):
    data = ClassStream(batch=32, dim=8, classes=3, seed=0)
    tr, params = _build('eva', tmp_path / 'fit', steps=8)
    _, _, h_fit = tr.fit(params, data)
    tr2, params2 = _build('eva', tmp_path / 'el', steps=8)
    _, _, h_el = tr2.fit_elastic(params2, data, world=1)
    assert [l for _, l in h_el] == h_fit  # atol=0


def test_fit_elastic_resume_same_world_bit_exact(tmp_path):
    data = ClassStream(batch=32, dim=8, classes=3, seed=0)
    tr, params = _build('eva', tmp_path / 'full', steps=10)
    _, _, h_full = tr.fit_elastic(params, data, world=1)
    # interrupted: 6 steps, checkpoint, then a fresh trainer resumes to 10
    tr1, params1 = _build('eva', tmp_path / 'resumed', steps=6, ckpt_every=6)
    _, _, h_a = tr1.fit_elastic(params1, data, world=1)
    tr2, params2 = _build('eva', tmp_path / 'resumed', steps=10, ckpt_every=6)
    _, _, h_b = tr2.fit_elastic(params2, data, world=1)
    assert [s for s, _ in h_b] == list(range(6, 10))
    assert h_a + h_b == h_full  # atol=0: restore→reshard(W unchanged)→go


def test_batch_divisibility_check():
    batch = ClassStream(batch=30, dim=8, classes=3, seed=0).batch_at(0)
    reshard.check_batch_divisible(batch, 2)  # 30 % 2 == 0
    with pytest.raises(reshard.ReshardError, match='batch % W'):
        reshard.check_batch_divisible(batch, 4)  # 30 % 4 != 0


# ---------------------------------------------------------------------------
# Chaos tests (subprocess; forced 4 host devices)

# One trainer run in a scrubbed subprocess.  argv:
#   world steps kill_at out_dir opt pipeline
# kill_at >= 0: SIGTERM ourselves when the trainer requests that step's
# batch — the real preemption path (signal → synchronous checkpoint →
# clean exit), made deterministic.  Prints {'hist': [[step, loss], ...]}.
_RUN_SCRIPT = textwrap.dedent("""
    import os
    os.environ['XLA_FLAGS'] = '--xla_force_host_platform_device_count=4'
    import json, signal, sys
    import jax
    from repro.core.registry import make_optimizer
    from repro.data.synthetic import ClassStream
    from repro.models import module as M
    from repro.models.simple import MLP, classifier_loss_fn
    from repro.schedule.runtime import RefreshRuntime
    from repro.train.trainer import Trainer, TrainerConfig

    world, steps, kill_at = (int(a) for a in sys.argv[1:4])
    out_dir, opt_name, pipeline = sys.argv[4:7]

    class ChaosStream:
        # preemption chaos: deliver SIGTERM when the trainer asks for the
        # kill step's batch; that step still runs, then the trainer's own
        # handler checkpoints synchronously and exits the loop
        def __init__(self, inner, kill_at):
            self.inner, self.kill_at = inner, kill_at
        def batch_at(self, step):
            if step == self.kill_at:
                os.kill(os.getpid(), signal.SIGTERM)
            return self.inner.batch_at(step)

    model = MLP([8, 16, 3])
    model.loss_fn = classifier_loss_fn(model)
    params = M.init_params(model.param_specs(), jax.random.PRNGKey(0))
    opt, capture = make_optimizer(opt_name, lr=0.05)
    taps_fn = ((lambda p, b: model.make_taps(b['x'].shape[0], capture))
               if capture.needs_taps else None)
    cfg = TrainerConfig(total_steps=steps, log_every=1, ckpt_every=10 ** 6,
                        out_dir=out_dir)
    tr = Trainer(model, opt, capture, cfg, taps_fn=taps_fn,
                 sched=RefreshRuntime(pipeline=pipeline))
    data = ChaosStream(ClassStream(batch=32, dim=8, classes=3, seed=0),
                       kill_at if kill_at >= 0 else None)
    _, _, hist = tr.fit_elastic(params, data, world=world)
    print(json.dumps({'devices': jax.device_count(),
                      'hist': [[s, float(l)] for s, l in hist]}))
""")

# Live resize inside ONE process: W=4 constant vs world_fn 4 -> 2 -> 4
# (restore-free re-jit path).  argv: opt pipeline out_dir
_RESIZE_SCRIPT = textwrap.dedent("""
    import os
    os.environ['XLA_FLAGS'] = '--xla_force_host_platform_device_count=4'
    import json, sys
    import jax
    from repro.core.registry import make_optimizer
    from repro.data.synthetic import ClassStream
    from repro.models import module as M
    from repro.models.simple import MLP, classifier_loss_fn
    from repro.schedule.runtime import RefreshRuntime
    from repro.train.trainer import Trainer, TrainerConfig

    opt_name, pipeline, out_dir = sys.argv[1:4]

    def run(tag, world_fn):
        model = MLP([8, 16, 3])
        model.loss_fn = classifier_loss_fn(model)
        params = M.init_params(model.param_specs(), jax.random.PRNGKey(0))
        opt, capture = make_optimizer(opt_name, lr=0.05)
        taps_fn = ((lambda p, b: model.make_taps(b['x'].shape[0], capture))
                   if capture.needs_taps else None)
        cfg = TrainerConfig(total_steps=16, log_every=4,
                            out_dir=f'{out_dir}/{tag}')
        tr = Trainer(model, opt, capture, cfg, taps_fn=taps_fn,
                     sched=RefreshRuntime(pipeline=pipeline))
        data = ClassStream(batch=32, dim=8, classes=3, seed=0)
        _, _, hist = tr.fit_elastic(params, data, world=4,
                                    world_fn=world_fn)
        return [l for _, l in hist]

    base = run('base', None)
    resized = run('resized', lambda s: 2 if 6 <= s < 11 else 4)
    print(json.dumps({'devices': jax.device_count(),
                      'maxdiff': max(abs(a - b)
                                     for a, b in zip(base, resized))}))
""")


def _run_sub(script, *args):
    out = subprocess.run(
        [sys.executable, '-c', script, *map(str, args)],
        capture_output=True, text=True, timeout=600,
        # JAX_PLATFORMS pinned: the scrubbed env must not fall through to
        # accelerator discovery (libtpu-on-a-TPU-less-host hangs forever)
        env={'PYTHONPATH': 'src', 'PATH': '/usr/bin:/bin', 'HOME': '/root',
             'JAX_PLATFORMS': 'cpu'},
        cwd=REPO)
    assert out.returncode == 0, (out.stdout[-2000:], out.stderr[-4000:])
    return json.loads(out.stdout.strip().splitlines()[-1])


def _records(out_dir: Path) -> list[dict]:
    lines = (out_dir / 'metrics.jsonl').read_text().splitlines()
    return [json.loads(l) for l in lines if l.strip()]


@pytest.mark.multihost
@pytest.mark.parametrize('opt_name', ['eva', 'kfac'])
def test_chaos_kill_reshard_matches_uninterrupted(opt_name, tmp_path):
    """W=4 → SIGTERM at step 8 → resume at W=2 → SIGTERM at step 16 →
    re-expand to W=4: the stitched trajectory matches the uninterrupted
    W=4 run within TRAJ_TOL, and every record (incl. the two `reshard`
    events) is schema-valid."""
    steps = 24
    base = _run_sub(_RUN_SCRIPT, 4, steps, -1, tmp_path / 'base',
                    opt_name, 'sync')
    assert base['devices'] == 4
    chaos_dir = tmp_path / 'chaos'
    h1 = _run_sub(_RUN_SCRIPT, 4, steps, 8, chaos_dir, opt_name, 'sync')
    h2 = _run_sub(_RUN_SCRIPT, 2, steps, 16, chaos_dir, opt_name, 'sync')
    h3 = _run_sub(_RUN_SCRIPT, 4, steps, -1, chaos_dir, opt_name, 'sync')
    stitched = h1['hist'] + h2['hist'] + h3['hist']
    assert [s for s, _ in stitched] == list(range(steps))  # no gap, no rerun
    diffs = [abs(a - b) for (_, a), (_, b) in zip(base['hist'], stitched)]
    assert max(diffs) < TRAJ_TOL, f'trajectory drift {max(diffs)}'

    # telemetry across the resizes: schema-valid, resize pairs recorded
    recs = _records(chaos_dir)
    for rec in recs:
        assert obs_events.validate_record(rec) == [], rec
    resizes = [(r['world_from'], r['world_to'], r['source'])
               for r in recs if r.get('event') == 'reshard']
    assert resizes == [(4, 2, 'checkpoint'), (2, 4, 'checkpoint')]
    owns = [r['world'] for r in recs if r.get('event') == 'refresh_ownership']
    if owns:  # eva-family preconditions too → ownership re-emitted per phase
        assert owns == [4, 2, 4]

    # CI artifacts: the two trajectories, uploaded by the elastic workflow
    # cell (gitignored locally)
    for tag, hist in (('base', base['hist']), ('chaos', stitched)):
        (REPO / f'ELASTIC_{opt_name}_{tag}.json').write_text(json.dumps(
            {'opt': opt_name, 'tol': TRAJ_TOL, 'hist': hist}))


@pytest.mark.multihost
@pytest.mark.parametrize('opt_name', ['eva', 'kfac'])
def test_live_resize_matches_uninterrupted(opt_name, tmp_path):
    """world_fn resize 4 → 2 → 4 between steps (no restart, re-jit only)
    stays within TRAJ_TOL of the constant-W=4 run."""
    rec = _run_sub(_RESIZE_SCRIPT, opt_name, 'sync', tmp_path)
    assert rec['devices'] == 4
    assert rec['maxdiff'] < TRAJ_TOL, rec
    resizes = [(r['world_from'], r['world_to'])
               for r in _records(tmp_path / 'resized')
               if r.get('event') == 'reshard']
    assert resizes == [(4, 2), (2, 4)]


@pytest.mark.multihost
def test_live_resize_onestep_drains_pipeline(tmp_path):
    """Under pipeline='onestep' a resize drains the in-flight buffers: the
    reshard events must say so, and the trajectory stays close (one cold
    pipeline step is a semantic, documented divergence — loose bound)."""
    rec = _run_sub(_RESIZE_SCRIPT, 'kfac', 'onestep', tmp_path)
    assert rec['maxdiff'] < 0.1  # drain != bit-exact, but same basin
    drains = [r['pipeline'] for r in _records(tmp_path / 'resized')
              if r.get('event') == 'reshard']
    assert drains == ['drained', 'drained']
