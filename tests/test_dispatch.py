"""Kernel dispatch layer: impl resolution, runtime flips, tile fitting,
autotune cache install + determinism (PR: backend-aware dispatch)."""
import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import autotune, dispatch, ref
from repro.kernels.tiles import fit_block


@pytest.fixture(autouse=True)
def _clean_dispatch_state():
    prev = dispatch.default_impl()
    yield
    dispatch.set_default_impl(prev)
    dispatch.reset_cache()


def _mk(shape, key=0):
    ks = jax.random.split(jax.random.PRNGKey(key), 3)
    g = jax.random.normal(ks[0], shape, jnp.float32)
    a = jax.random.normal(ks[1], (shape[0],), jnp.float32)
    b = jax.random.normal(ks[2], (shape[1],), jnp.float32)
    return g, a, b


# ---------------------------------------------------------------------------
# tiles.fit_block (satellite: waste-aware clamp)


def test_fit_block_small_dim_is_dim():
    assert fit_block(48, 512) == 48
    assert fit_block(512, 512) == 512


def test_fit_block_balances_tiles():
    # 520 @ 512: min() clamp would pad to 1024 (49% waste); fit_block keeps
    # the 2 tiles but shrinks them to 260 (zero pad)
    assert fit_block(520, 512) == 260
    assert fit_block(1000, 512) == 500
    assert fit_block(513, 512) == 257


def test_fit_block_alignment_rounds_up():
    b = fit_block(1000, 512, align=8)
    assert b % 8 == 0 and b >= 500
    # align never exceeds max(block, align)
    assert fit_block(7, 4, align=8) <= 8


def test_fit_block_rejects_nonpositive():
    with pytest.raises(ValueError):
        fit_block(0, 512)
    with pytest.raises(ValueError):
        fit_block(64, 0)


# ---------------------------------------------------------------------------
# resolution rules


def test_resolve_auto_cpu_is_xla():
    # shape absent from the shipped cache -> pure backend rule (cpu: xla)
    c = dispatch.resolve('bilinear', 96, 80, jnp.float32, 'auto')
    if dispatch.backend() == 'cpu':
        assert c.impl == 'xla'


def test_resolve_auto_reads_shipped_cache():
    # 64x48 ships with a measured pallas winner in tile_defaults.json
    key = dispatch.cache_key('bilinear', 64, 48, jnp.float32)
    entry = dispatch._cache().get(key)
    if entry is not None:
        c = dispatch.resolve('bilinear', 64, 48, jnp.float32, 'auto')
        assert c.impl == entry['impl']


def test_resolve_explicit_pallas_interprets_off_tpu():
    c = dispatch.resolve('bilinear', 64, 48, jnp.float32, 'pallas')
    assert c.impl == 'pallas'
    assert c.interpret == (dispatch.backend() != 'tpu')
    ci = dispatch.resolve('bilinear', 64, 48, jnp.float32, 'pallas_interpret')
    assert ci.impl == 'pallas' and ci.interpret


def test_resolve_unknown_impl_raises():
    with pytest.raises(ValueError):
        dispatch.resolve('bilinear', 64, 48, jnp.float32, 'cuda')


def test_runtime_default_flip_no_reload():
    """set_default_impl / impl_override replace the old import-time
    ops.INTERPRET constant — flipping needs no module reload."""
    dispatch.set_default_impl('xla')
    assert dispatch.resolve('matvec', 64, 48, jnp.float32).impl == 'xla'
    with dispatch.impl_override('pallas_interpret'):
        c = dispatch.resolve('matvec', 64, 48, jnp.float32)
        assert c.impl == 'pallas' and c.interpret
    assert dispatch.resolve('matvec', 64, 48, jnp.float32).impl == 'xla'


def test_choices_snapshot_records_resolution():
    dispatch.resolve('bilinear', 200, 136, jnp.float32, 'pallas_interpret')
    snap = dispatch.choices_snapshot()
    assert 'bilinear' in snap and '@ 200x136' in snap['bilinear']


def test_impl_from_extras_config_wins():
    from repro.core.transform import Extras

    cfg = dispatch.KernelConfig(impl='xla')
    assert dispatch.impl_from_extras(Extras(kernel=cfg), 'pallas') == 'xla'
    # a present config wins even at 'auto' (engages the dispatch cache)
    auto = dispatch.KernelConfig(impl='auto')
    assert dispatch.impl_from_extras(Extras(kernel=auto), None) == 'auto'
    # no config -> caller default (None keeps the inline-jnp path)
    assert dispatch.impl_from_extras(Extras(), 'pallas') == 'pallas'
    assert dispatch.impl_from_extras(None, None) is None


# ---------------------------------------------------------------------------
# cache install / winner routing


def test_install_cache_routes_auto(tmp_path):
    key = dispatch.cache_key('bilinear', 64, 48, jnp.float32)
    cache = {'version': 1, 'entries': {
        key: {'impl': 'pallas', 'block_in': 32, 'block_out': 16, 'us': 1.0}}}
    path = tmp_path / 'cache.json'
    path.write_text(json.dumps(cache))
    assert dispatch.install_cache(str(path)) >= 1
    c = dispatch.resolve('bilinear', 64, 48, jnp.float32, 'auto')
    assert c.impl == 'pallas'
    assert (c.block_in, c.block_out) == (32, 16)
    # other shapes keep the backend rule
    assert dispatch.resolve('bilinear', 65, 48, jnp.float32, 'auto').impl \
        in ('xla', 'pallas')
    dispatch.reset_cache()
    # after reset, shipped defaults govern again (entry gone unless shipped)
    c2 = dispatch.resolve('bilinear', 64, 48, jnp.float32, 'auto')
    assert (c2.block_in, c2.block_out) != (32, 16) or c2.impl != 'pallas'


def test_shipped_defaults_exist_and_validate():
    """The warm-start file ships with the repo and parses into entries of
    the documented shape."""
    assert dispatch._DEFAULTS_FILE.exists()
    data = json.loads(dispatch._DEFAULTS_FILE.read_text())
    assert data['version'] == 1 and data['entries']
    for key, e in data['entries'].items():
        assert set(e) >= {'impl', 'block_in', 'block_out'}, key
        assert e['impl'] in ('xla', 'pallas')


# ---------------------------------------------------------------------------
# op wrappers: xla path is ref.py bit-for-bit; pallas path agrees tightly


@pytest.mark.parametrize('shape', [(64, 48), (200, 136)])
def test_xla_path_is_ref_bit_exact(shape):
    g, a, b = _mk(shape)
    np.testing.assert_array_equal(
        np.asarray(dispatch.bilinear(g, a, b, impl='xla')),
        np.asarray(ref.bilinear_ref(g, a, b)))
    np.testing.assert_array_equal(
        np.asarray(dispatch.matvec(g, a, impl='xla')),
        np.asarray(ref.matvec_ref(g, a)))
    np.testing.assert_array_equal(
        np.asarray(dispatch.rank1_update(g, a, b, 0.37, 2.5, impl='xla')),
        np.asarray(ref.rank1_update_ref(g, a, b, 0.37, 2.5)))


@pytest.mark.parametrize('shape', [(64, 48), (200, 136)])
def test_xla_vs_interpret_agree(shape):
    g, a, b = _mk(shape)
    for op, args in [('bilinear', (g, a, b)), ('matvec', (g, a)),
                     ('rank1_update', (g, a, b, jnp.float32(0.37),
                                       jnp.float32(2.5)))]:
        fn = getattr(dispatch, op)
        x = fn(*args, impl='xla')
        p = fn(*args, impl='pallas_interpret')
        np.testing.assert_allclose(np.asarray(x), np.asarray(p),
                                   atol=1e-5, rtol=1e-5)


# ---------------------------------------------------------------------------
# autotuner: deterministic output given pinned measurements


def _fake_bench():
    calls = {'n': 0}

    def bench(fn):
        del fn
        calls['n'] += 1
        return float(calls['n'])
    return bench


def test_autotune_deterministic_bytes():
    """Same shapes + same (injected) measurements -> identical JSON bytes;
    the CI determinism contract for the persisted cache."""
    shapes = [(64, 48), (200, 136)]
    s1 = autotune.dumps(autotune.tune(shapes, bench=_fake_bench()))
    s2 = autotune.dumps(autotune.tune(shapes, bench=_fake_bench()))
    assert s1 == s2
    data = json.loads(s1)
    assert data['version'] == 1
    assert len(data['entries']) == len(shapes) * len(autotune.OPS)


def test_autotune_first_candidate_wins_fixed_order():
    """The injected bench returns strictly increasing times, so the first
    candidate (xla, fixed candidate order) must win everywhere."""
    cache = autotune.tune([(64, 48)], bench=_fake_bench())
    for e in cache['entries'].values():
        assert e['impl'] == 'xla'


def test_autotune_winner_installs_and_resolves(tmp_path):
    def pallas_wins(fn):
        del fn
        # called in candidate order: xla first -> make it slow
        pallas_wins.n = getattr(pallas_wins, 'n', 0) + 1
        return 1e6 if pallas_wins.n % 7 == 1 else float(pallas_wins.n)

    cache = autotune.tune([(64, 48)], ops=('bilinear',), bench=pallas_wins)
    (entry,) = cache['entries'].values()
    assert entry['impl'] == 'pallas'
    path = autotune.write(cache, tmp_path / 'win.json')
    dispatch.install_cache(path)
    c = dispatch.resolve('bilinear', 64, 48, jnp.float32, 'auto')
    assert c.impl == 'pallas'
    assert (c.block_in, c.block_out) == (entry['block_in'],
                                         entry['block_out'])


def test_autotune_merge_new_wins():
    base = {'version': 1, 'entries': {'k1': {'impl': 'xla'},
                                      'k2': {'impl': 'xla'}}}
    new = {'version': 1, 'backend': 'cpu',
           'entries': {'k2': {'impl': 'pallas'}}}
    merged = autotune.merge(base, new)
    assert merged['entries']['k1']['impl'] == 'xla'
    assert merged['entries']['k2']['impl'] == 'pallas'
