"""Eq. 13/21/23 correctness: Sherman–Morrison forms == explicit inverses
(hypothesis sweeps over shapes/values — deliverable c, property tests)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

hypothesis = pytest.importorskip('hypothesis')
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core import precondition as pre  # noqa: E402


@pytest.fixture(autouse=True, scope='module')
def _x64():
    """f64 precision for the explicit-inverse comparisons, scoped to this
    module only (a global flip would poison int dtypes in later tests)."""
    old = jax.config.jax_enable_x64
    jax.config.update('jax_enable_x64', True)
    yield
    jax.config.update('jax_enable_x64', old)

dims = st.integers(min_value=2, max_value=12)
gammas = st.floats(min_value=1e-3, max_value=10.0)
seeds = st.integers(min_value=0, max_value=2 ** 16)


def _rand(seed, *shape):
    return jax.random.normal(jax.random.PRNGKey(seed), shape, jnp.float64)


@settings(max_examples=30, deadline=None)
@given(d_in=dims, d_out=dims, gamma=gammas, seed=seeds)
def test_eva_sherman_morrison_vs_explicit(d_in, d_out, gamma, seed):
    g = _rand(seed, d_in, d_out)
    a = _rand(seed + 1, d_in)
    b = _rand(seed + 2, d_out)
    got = pre.eva_precondition(g, a, b, gamma)
    want = pre.eva_explicit(g, a, b, gamma)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=1e-8, rtol=1e-6)


@settings(max_examples=30, deadline=None)
@given(d_in=dims, d_out=dims, gamma=gammas, seed=seeds)
def test_eva_f_vs_explicit(d_in, d_out, gamma, seed):
    g = _rand(seed, d_in, d_out)
    a = _rand(seed + 1, d_in)
    got = pre.eva_f_precondition(g, a, gamma)
    m = np.outer(a, a) + gamma * np.eye(d_in)
    want = np.linalg.solve(m, np.asarray(g))
    np.testing.assert_allclose(np.asarray(got), want, atol=1e-8, rtol=1e-6)


@settings(max_examples=30, deadline=None)
@given(d_in=dims, d_out=dims, gamma=gammas, seed=seeds)
def test_eva_s_vs_explicit(d_in, d_out, gamma, seed):
    g = _rand(seed, d_in, d_out)
    vi, vo = pre.grad_kvs(g)
    got = pre.eva_s_precondition(g, vi, vo, gamma)
    want = pre.eva_explicit(g, vi, vo, gamma)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=1e-8, rtol=1e-6)


@settings(max_examples=20, deadline=None)
@given(d_in=dims, d_out=dims, gamma=gammas, seed=seeds)
def test_foof_solve(d_in, d_out, gamma, seed):
    g = _rand(seed, d_in, d_out)
    a = _rand(seed + 1, d_in)
    ao = jnp.outer(a, a) + 0.1 * jnp.eye(d_in)
    got = pre.foof_precondition(g, ao, gamma)
    want = np.linalg.solve(np.asarray(ao) + gamma * np.eye(d_in), np.asarray(g))
    np.testing.assert_allclose(np.asarray(got), want, atol=1e-8, rtol=1e-6)


@settings(max_examples=20, deadline=None)
@given(d=dims, gamma=gammas, seed=seeds)
def test_shampoo_inverse_root(d, gamma, seed):
    """(M+γI)^{-1/4} really is the inverse 4th root."""
    x = _rand(seed, d, d)
    m = x @ x.T
    r = pre._inv_proot_psd(m, gamma, 0.25)
    m4 = np.linalg.matrix_power(np.asarray(r, np.float64), 4)
    want = np.linalg.inv(np.asarray(m) + gamma * np.eye(d))
    np.testing.assert_allclose(m4, want, atol=1e-6, rtol=1e-4)


@settings(max_examples=20, deadline=None)
@given(d_in=dims, d_out=dims, seed=seeds)
def test_eva_gamma_limit_is_sgd(d_in, d_out, seed):
    """γ→∞: γ·P → G (preconditioning washes out to the SGD direction)."""
    g = _rand(seed, d_in, d_out)
    a = _rand(seed + 1, d_in)
    b = _rand(seed + 2, d_out)
    gamma = 1e8
    p = pre.eva_precondition(g, a, b, gamma)
    np.testing.assert_allclose(np.asarray(p) * gamma, np.asarray(g),
                               atol=1e-4, rtol=1e-4)


@settings(max_examples=20, deadline=None)
@given(d_in=dims, d_out=dims, gamma=gammas, seed=seeds)
def test_eva_preserves_descent(d_in, d_out, gamma, seed):
    """pᵀg ≥ 0: (C+γI)^{-1} is PD so preconditioning keeps descent."""
    g = _rand(seed, d_in, d_out)
    a = _rand(seed + 1, d_in)
    b = _rand(seed + 2, d_out)
    p = pre.eva_precondition(g, a, b, gamma)
    assert float(jnp.sum(p * g)) >= -1e-9
