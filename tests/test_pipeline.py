"""Double-buffered curvature pipeline (repro.schedule.pipeline).

Contracts proven here:
  * ``PipelineState`` slot semantics: zeros cold start at age 0, swap on
    ``stage``, refresh-gated age on ``tick``;
  * ``pipeline='onestep'`` EXACT semantics (atol=0, single host): the
    stats-only optimizers (eva, eva_f) equal a sync run fed the
    one-step-shifted stats stream ``[0, s_0, s_1, …]``; the interval
    methods (kfac, foof, shampoo) equal hand-rolled double-buffered
    references (precondition with the PREVIOUS caches, store this step's
    refresh); eva_s has no exchange so onestep ≡ sync trivially;
  * init/update pipeline-mode agreement is statically enforced
    (``resolve_pipe`` raises on mismatch);
  * observability: ``pipe_entries`` / ``pipeline_metrics`` report realized
    per-site staleness;
  * under a live 4-device mesh (subprocess) the onestep trajectory matches
    the single-host onestep trajectory to float tolerance, with the same
    exchange/LAPACK caveats as the sync sharded-refresh test.
"""
import json
import subprocess
import sys
import textwrap
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import bucketing
from repro.core import kv as kvlib
from repro.core import precondition as pre
from repro.core.eva import (_extract, _stats_plan, _zeros_like_spec,
                            eva_preconditioner)
from repro.core.eva_f import eva_f_preconditioner
from repro.core.eva_s import eva_s_preconditioner
from repro.core.foof import foof_preconditioner
from repro.core.kfac import _damped_inv, kfac_preconditioner
from repro.core.shampoo import shampoo_preconditioner
from repro.core.transform import Extras
from repro.schedule import pipeline as pipemod, runtime as schedrt
from repro.schedule.policy import adaptive, every_k

GAMMA = 0.03
STEPS = 6

SHAPES = {
    'blk0/w': (8, 4),
    'blk1/w': (8, 4),
    'blk2/w': (8, 4),
    'head/w': (8, 3),          # singleton bucket (broadcast path)
    'stack/w': (2, 6, 4),      # scan-stacked leading dim
}


def _psd(key, *shape):
    m = jax.random.normal(key, shape)
    return m @ jnp.swapaxes(m, -1, -2) + 0.1 * jnp.eye(shape[-1])


def _grads(seed):
    key = jax.random.PRNGKey(seed)
    return {p: jax.random.normal(jax.random.fold_in(key, i), s)
            for i, (p, s) in enumerate(SHAPES.items())}


def _capture_stats(seed):
    key = jax.random.PRNGKey(1000 + seed)
    out = {}
    for i, (p, s) in enumerate(SHAPES.items()):
        ks = jax.random.split(jax.random.fold_in(key, i), 4)
        lead, d_in, d_out = s[:-2], s[-2], s[-1]
        out[p] = kvlib.LayerStats(
            a_mean=jax.random.normal(ks[0], lead + (d_in,)),
            b_mean=jax.random.normal(ks[1], lead + (d_out,)),
            a_outer=_psd(ks[2], *lead, d_in, d_in),
            b_outer=_psd(ks[3], *lead, d_out, d_out))
    return out


def _zero_stats():
    return jax.tree_util.tree_map(jnp.zeros_like, _capture_stats(0))


def _params():
    return kvlib.unflatten_params(_grads(0))


def _assert_trees_equal(a, b, msg=''):
    la, lb = jax.tree_util.tree_leaves(a), jax.tree_util.tree_leaves(b)
    assert len(la) == len(lb), msg
    for x, y in zip(la, lb):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y),
                                      err_msg=msg)


_MAKERS = {
    'eva': lambda **kw: eva_preconditioner(GAMMA, 0.9, **kw),
    'eva_f': lambda **kw: eva_f_preconditioner(GAMMA, 0.9, **kw),
    'eva_s': lambda **kw: eva_s_preconditioner(GAMMA, 0.9, **kw),
    'foof': lambda **kw: foof_preconditioner(GAMMA, 0.9, **kw),
    'kfac': lambda **kw: kfac_preconditioner(GAMMA, 0.9, **kw),
    'shampoo': lambda **kw: shampoo_preconditioner(1e-4, **kw),
}
_NEEDS_STATS = ('eva', 'eva_f', 'foof', 'kfac')


def _run(method, steps, sched=None, stats_fn=_capture_stats, **kw):
    """Scheduled run with an explicit RefreshRuntime and stats stream."""
    opt = _MAKERS[method](**kw)
    params = _params()
    needs = method in _NEEDS_STATS
    state = opt.init(params, Extras(stats=stats_fn(0) if needs else None,
                                    sched=sched))
    outs = []
    for t in range(steps):
        ex = Extras(stats=stats_fn(t) if needs else None, sched=sched)
        out, state = opt.update(_grads(t), state, extras=ex)
        outs.append(kvlib.flatten_params(out))
    return outs, state


_ONESTEP = schedrt.RefreshRuntime(pipeline='onestep')


# ---------------------------------------------------------------------------
# PipelineState slot semantics


def test_pipeline_state_slots():
    tmpl = {'a': jnp.ones((2, 3))}
    p = pipemod.init_state(tmpl)
    _assert_trees_equal(p.inflight, {'a': jnp.zeros((2, 3))})
    assert int(p.age) == 0

    applied, p1 = pipemod.stage(p, {'a': jnp.full((2, 3), 5.0)})
    _assert_trees_equal(applied, {'a': jnp.zeros((2, 3))})  # cold zeros out
    _assert_trees_equal(p1.inflight, {'a': jnp.full((2, 3), 5.0)})
    assert int(p1.age) == 1
    applied, p2 = pipemod.stage(p1, {'a': jnp.full((2, 3), 7.0)})
    _assert_trees_equal(applied, {'a': jnp.full((2, 3), 5.0)})

    # refresh-site slot: buffer lives elsewhere, only the age is carried
    r = pipemod.init_state()
    assert r.inflight is None and int(r.age) == 0
    r = pipemod.tick(r, jnp.asarray(True))
    assert int(r.age) == 1
    r = pipemod.tick(r, jnp.asarray(False))
    r = pipemod.tick(r, jnp.asarray(False))
    assert int(r.age) == 3
    r = pipemod.tick(r, jnp.asarray(True))
    assert int(r.age) == 1


def test_staged_pmean_sync_is_identity_composition():
    tree = {'x': jnp.arange(6.0).reshape(2, 3)}
    fresh, pipe = pipemod.staged_pmean(tree, None)
    assert pipe is None
    _assert_trees_equal(fresh, tree)          # W=1, raw passthrough


def test_resolve_pipe_mode_mismatch_raises():
    """init and update must agree on the pipeline mode — a checkpoint from
    one mode fed to a step of the other is a config bug, caught statically."""
    with pytest.raises(ValueError, match='onestep'):
        _, state = _run('kfac', 1, sched=None)  # sync state (pipe=None)
        opt = _MAKERS['kfac']()
        opt.update(_grads(0), state,
                   extras=Extras(stats=_capture_stats(0), sched=_ONESTEP))
    with pytest.raises(ValueError, match='sync'):
        opt = _MAKERS['kfac']()
        state = opt.init(_params(), Extras(stats=_capture_stats(0),
                                           sched=_ONESTEP))
        opt.update(_grads(0), state,
                   extras=Extras(stats=_capture_stats(0), sched=None))


# ---------------------------------------------------------------------------
# Exact onestep semantics, single host (atol=0)


@pytest.mark.parametrize('method', ['eva', 'eva_f'])
@pytest.mark.parametrize('policy', [every_k(1), adaptive(threshold=0.05)])
def test_onestep_equals_shifted_stream(method, policy):
    """For the stats-only optimizers the one-step-stale pipeline IS the sync
    optimizer fed yesterday's statistics: onestep on [s_0, s_1, …] equals
    sync on [0, s_0, …, s_{n-2}] bit-exactly (the EMA count advances
    identically, only the consumed stream shifts)."""
    onestep, _ = _run(method, STEPS, sched=_ONESTEP, policy=policy)

    def shifted(t):
        return _zero_stats() if t == 0 else _capture_stats(t - 1)

    sync, _ = _run(method, STEPS, sched=None, stats_fn=shifted, policy=policy)
    for t in range(STEPS):
        _assert_trees_equal(onestep[t], sync[t], msg=f'{method} step {t}')


def test_onestep_eva_s_is_noop():
    """eva_s performs no curvature collective → onestep ≡ sync exactly."""
    a, sa = _run('eva_s', STEPS, sched=_ONESTEP)
    b, sb = _run('eva_s', STEPS, sched=None)
    for t in range(STEPS):
        _assert_trees_equal(a[t], b[t], msg=f'step {t}')
    _assert_trees_equal(sa, sb)


def _ref_kfac_onestep(steps, interval, kf_decay=0.9):
    """Hand-rolled double-buffered K-FAC: the EMA consumes LAST step's
    reduced factors (zeros at t=0) and preconditioning uses LAST step's
    inverses; this step's gated recompute lands in state only."""
    fields = ('a_outer', 'b_outer')
    flat = kvlib.flatten_params(_params())
    stats0 = _capture_stats(0)
    plan = _stats_plan(flat, stats0, None)
    zeros = bucketing.gather_tree(plan, _zeros_like_spec(_extract(stats0, fields)))
    run = kvlib.init_running(zeros)
    a_inv = {k: jnp.zeros_like(st.a_outer) for k, st in run.stats.items()}
    b_inv = {k: jnp.zeros_like(st.b_outer) for k, st in run.stats.items()}
    prev_fresh = zeros
    outs = []
    for t in range(steps):
        applied, prev_fresh = prev_fresh, bucketing.gather_tree(
            plan, _extract(_capture_stats(t), fields))
        stats, run = kvlib.update_running(run, applied, kf_decay)

        def one(ao, bo):
            gamma_r, gamma_q = pre.kfac_pi_damping(ao, bo, GAMMA)
            return _damped_inv(ao, gamma_r), _damped_inv(bo, gamma_q)

        def recompute(_):
            ai, bi = {}, {}
            for k, st in stats.items():
                ai[k], bi[k] = pre.map_bucket(one, st.a_outer, st.b_outer)
            return ai, bi

        used_a, used_b = a_inv, b_inv
        a_inv, b_inv = jax.lax.cond(t % interval == 0, recompute,
                                    lambda _: (a_inv, b_inv), operand=None)
        ops = {k: kvlib.LayerStats(a_outer=used_a[k], b_outer=used_b[k])
               for k in used_a}
        outs.append(pre.precondition_tree(_grads(t), ops, 'kfac_cached',
                                          GAMMA, plan=plan))
    return outs


def _ref_foof_onestep(steps, interval, kf_decay=0.9):
    fields = ('a_outer',)
    flat = kvlib.flatten_params(_params())
    stats0 = _capture_stats(0)
    plan = _stats_plan(flat, stats0, None)
    zeros = bucketing.gather_tree(plan, _zeros_like_spec(_extract(stats0, fields)))
    run = kvlib.init_running(zeros)
    a_inv = {k: jnp.zeros_like(st.a_outer) for k, st in run.stats.items()}
    prev_fresh = zeros
    outs = []
    for t in range(steps):
        applied, prev_fresh = prev_fresh, bucketing.gather_tree(
            plan, _extract(_capture_stats(t), fields))
        stats, run = kvlib.update_running(run, applied, kf_decay)

        def recompute(_):
            return {k: pre.map_bucket(lambda m: _damped_inv(m, GAMMA),
                                      st.a_outer)
                    for k, st in stats.items()}

        used = a_inv
        a_inv = jax.lax.cond(t % interval == 0, recompute, lambda _: a_inv,
                             operand=None)
        ops = {k: kvlib.LayerStats(a_outer=used[k]) for k in used}
        outs.append(pre.precondition_tree(_grads(t), ops, 'foof_cached',
                                          GAMMA, plan=plan))
    return outs


def _ref_shampoo_onestep(steps, interval, eps_init=1e-6):
    """Shampoo's accumulators are local (no stats exchange); only the root
    refresh is pipelined — apply last step's roots, store this step's."""
    flat = kvlib.flatten_params(_params())
    plan = bucketing.build_plan(flat)
    m_in, m_out = {}, {}
    for b in plan.buckets:
        lead = (len(b.paths),) + b.shape[:-2]
        d_in, d_out = b.shape[-2], b.shape[-1]
        m_in[b.key] = eps_init * jnp.broadcast_to(
            jnp.eye(d_in, dtype=jnp.float32), lead + (d_in, d_in))
        m_out[b.key] = eps_init * jnp.broadcast_to(
            jnp.eye(d_out, dtype=jnp.float32), lead + (d_out, d_out))
    p_in = jax.tree_util.tree_map(jnp.zeros_like, m_in)
    p_out = jax.tree_util.tree_map(jnp.zeros_like, m_out)
    outs = []
    for t in range(steps):
        g = _grads(t)
        g_b = bucketing.gather(plan, g)
        for b in plan.buckets:
            gg = g_b[b.key].astype(jnp.float32)
            m_in[b.key] = m_in[b.key] + jnp.einsum('...io,...jo->...ij', gg, gg)
            m_out[b.key] = m_out[b.key] + jnp.einsum('...io,...ij->...oj', gg, gg)

        def recompute(_):
            return ({k: pre.map_bucket(
                        lambda m: pre._inv_proot_psd(m, 1e-4, 0.25), m_in[k])
                     for k in m_in},
                    {k: pre.map_bucket(
                        lambda m: pre._inv_proot_psd(m, 1e-4, 0.25), m_out[k])
                     for k in m_out})

        used_in, used_out = p_in, p_out
        p_in, p_out = jax.lax.cond(t % interval == 0, recompute,
                                   lambda _: (p_in, p_out), operand=None)
        ops = {k: kvlib.LayerStats(a_outer=used_in[k], b_outer=used_out[k])
               for k in used_in}
        outs.append(pre.precondition_tree(g, ops, 'shampoo_cached', 1e-4,
                                          plan=plan))
    return outs


_ONESTEP_REFS = {
    'kfac': _ref_kfac_onestep,
    'foof': _ref_foof_onestep,
    'shampoo': _ref_shampoo_onestep,
}


@pytest.mark.parametrize('method', sorted(_ONESTEP_REFS))
@pytest.mark.parametrize('interval', [1, 3])
def test_onestep_equals_double_buffered_reference(method, interval):
    ref = _ONESTEP_REFS[method](STEPS, interval)
    outs, _ = _run(method, STEPS, sched=_ONESTEP, policy=every_k(interval))
    for t in range(STEPS):
        _assert_trees_equal(
            kvlib.flatten_params(ref[t]), outs[t],
            msg=f'{method} interval={interval} step {t}')


# ---------------------------------------------------------------------------
# Observability


def test_pipe_entries_and_metrics():
    _, state = _run('kfac', STEPS, sched=_ONESTEP, policy=every_k(2))
    entries = pipemod.pipe_entries(state)
    assert sorted(k for k, _ in entries) == ['refresh', 'stats']
    by_key = dict(entries)
    assert int(by_key['stats'].age) == 1       # re-exchanged every step
    # refreshes fired at steps 0, 2, 4 → after step 5 the in-flight
    # inverses were computed at step 4: age 2
    assert int(by_key['refresh'].age) == 2
    m = pipemod.pipeline_metrics(state)
    assert int(m['pipeline_lag']) == 2
    assert int(m['pipeline_lag/stats']) == 1
    assert int(m['pipeline_lag/refresh']) == 2

    # sync state: no pipeline, no metrics
    _, state = _run('kfac', 1, sched=None)
    assert pipemod.pipe_entries(state) == []
    assert pipemod.pipeline_metrics(state) == {}


def test_sync_state_structure_has_no_pipe_leaves():
    """pipe=None must contribute zero leaves — sync checkpoints stay
    loadable across the refactor."""
    _, state = _run('foof', 2, sched=schedrt.RefreshRuntime(pipeline='sync'))
    _, legacy = _run('foof', 2, sched=None)
    assert (jax.tree_util.tree_structure(state)
            == jax.tree_util.tree_structure(legacy))


# ---------------------------------------------------------------------------
# HLO overlap checker (launch.hlo_analysis.collective_overlap)

_HLO_DIRECT = textwrap.dedent("""
    HloModule m

    ENTRY %main (p0: f32[4,4], p1: f32[4,4]) -> (f32[4,4], f32[4,4]) {
      %p0 = f32[4,4]{1,0} parameter(0)
      %p1 = f32[4,4]{1,0} parameter(1)
      %ar = f32[4,4]{1,0} all-reduce(%p0), replica_groups=[1,4]
      %dep = f32[4,4]{1,0} dot(%ar, %p1), lhs_contracting_dims={1}, rhs_contracting_dims={0}
      %indep = f32[4,4]{1,0} dot(%p0, %p1), lhs_contracting_dims={1}, rhs_contracting_dims={0}
      ROOT %out = (f32[4,4], f32[4,4]) tuple(%dep, %indep)
    }
""")

_HLO_FUSION = textwrap.dedent("""
    HloModule m

    %fused (fp0: f32[4,4], fp1: f32[4,4]) -> f32[4,4] {
      %fp0 = f32[4,4]{1,0} parameter(0)
      %fp1 = f32[4,4]{1,0} parameter(1)
      ROOT %d = f32[4,4]{1,0} dot(%fp0, %fp1), lhs_contracting_dims={1}, rhs_contracting_dims={0}
    }

    ENTRY %main (p0: f32[4,4], p1: f32[4,4]) -> f32[4,4] {
      %p0 = f32[4,4]{1,0} parameter(0)
      %p1 = f32[4,4]{1,0} parameter(1)
      %ags = f32[4,4]{1,0} all-gather-start(%p0), replica_groups=[1,4]
      %agd = f32[4,4]{1,0} all-gather-done(%ags)
      ROOT %f = f32[4,4]{1,0} fusion(%agd, %p1), kind=kLoop, calls=%fused
    }
""")

_HLO_WHILE_CARRY = textwrap.dedent("""
    HloModule m

    %cond (cp: (s32[], f32[4,4])) -> pred[] {
      %cp = (s32[], f32[4,4]) parameter(0)
      %i = s32[] get-tuple-element(%cp), index=0
      %n = s32[] constant(3)
      ROOT %lt = pred[] compare(%i, %n), direction=LT
    }

    %body (bp: (s32[], f32[4,4])) -> (s32[], f32[4,4]) {
      %bp = (s32[], f32[4,4]) parameter(0)
      %i = s32[] get-tuple-element(%bp), index=0
      %x = f32[4,4]{1,0} get-tuple-element(%bp), index=1
      %one = s32[] constant(1)
      %ip = s32[] add(%i, %one)
      %ar = f32[4,4]{1,0} all-reduce(%x), replica_groups=[1,4]
      ROOT %t = (s32[], f32[4,4]) tuple(%ip, %ar)
    }

    ENTRY %main (p0: f32[4,4], p1: f32[4,4]) -> f32[4,4] {
      %p0 = f32[4,4]{1,0} parameter(0)
      %p1 = f32[4,4]{1,0} parameter(1)
      %init = (s32[], f32[4,4]) tuple-hack(%p0)
      %w = (s32[], f32[4,4]) while(%init), condition=%cond, body=%body
      %wx = f32[4,4]{1,0} get-tuple-element(%w), index=1
      ROOT %d = f32[4,4]{1,0} dot(%wx, %p1), lhs_contracting_dims={1}, rhs_contracting_dims={0}
    }
""").replace('tuple-hack', 'tuple')


def test_overlap_checker_direct_dependence():
    from repro.launch import hlo_analysis
    rep = hlo_analysis.collective_overlap(_HLO_DIRECT)
    assert rep.collective_count == 1
    assert rep.blocking_collectives == 1
    assert rep.total_dots == 2
    assert rep.dependent_dots == 1
    # both dots are 2*16*4 = 128 flops; exactly half the flops must wait
    assert rep.dependent_fraction == pytest.approx(0.5)
    assert rep.dot_flops_independent == pytest.approx(rep.dot_flops_dependent)


def test_overlap_checker_through_fusion_and_async_pair():
    from repro.launch import hlo_analysis
    rep = hlo_analysis.collective_overlap(_HLO_FUSION)
    # -start and -done both count as collective sources; the dot INSIDE the
    # fusion computation is reached through the caller-operand→parameter edge
    assert rep.collective_count == 2
    assert rep.blocking_collectives == 2
    assert rep.total_dots == 1
    assert rep.dependent_dots == 1
    assert rep.dependent_fraction == 1.0


def test_overlap_checker_while_loop_carry():
    from repro.launch import hlo_analysis
    rep = hlo_analysis.collective_overlap(_HLO_WHILE_CARRY)
    # the all-reduce inside the while body reaches the downstream dot via
    # body-root → while-op → consumer
    assert rep.collective_count == 1
    assert rep.blocking_collectives == 1
    assert rep.dependent_dots == 1 and rep.total_dots == 1


def test_overlap_checker_no_collectives():
    from repro.launch import hlo_analysis
    rep = hlo_analysis.collective_overlap(
        _HLO_DIRECT.replace('all-reduce(%p0), replica_groups=[1,4]',
                            'negate(%p0)'))
    assert rep.collective_count == 0
    assert rep.dependent_fraction == 0.0
    assert rep.total_dots == 2


def test_overlap_checker_nonblocking_collective():
    """A collective whose output feeds only a state-like output (no dot in
    its cone) must not count as blocking — the onestep signature."""
    from repro.launch import hlo_analysis
    hlo = _HLO_DIRECT.replace('dot(%ar, %p1)', 'dot(%p0, %p1)')
    rep = hlo_analysis.collective_overlap(hlo)
    assert rep.collective_count == 1
    assert rep.blocking_collectives == 0
    assert rep.dependent_dots == 0


# ---------------------------------------------------------------------------
# 4-device mesh (subprocess: the forced device-count flag must not leak)

_MESH_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    import json
    import jax, jax.numpy as jnp, numpy as np
    from jax.sharding import PartitionSpec as P
    from repro.core import kv as kvlib
    from repro.core.kfac import kfac_preconditioner
    from repro.core.transform import Extras
    from repro.schedule import pipeline as pipemod
    from repro.schedule.policy import every_k
    from repro.schedule.runtime import RefreshRuntime
    from repro.sharding import compat

    SHAPES = {'blk0/w': (8, 4), 'blk1/w': (8, 4), 'blk2/w': (8, 4),
              'head/w': (8, 3), 'stack/w': (2, 6, 4)}

    def psd(key, *shape):
        m = jax.random.normal(key, shape)
        return m @ jnp.swapaxes(m, -1, -2) + 0.1 * jnp.eye(shape[-1])

    def grads(seed):
        key = jax.random.PRNGKey(seed)
        return {p: jax.random.normal(jax.random.fold_in(key, i), s)
                for i, (p, s) in enumerate(SHAPES.items())}

    def stats(seed):
        key = jax.random.PRNGKey(1000 + seed)
        out = {}
        for i, (p, s) in enumerate(SHAPES.items()):
            ks = jax.random.split(jax.random.fold_in(key, i), 2)
            lead, d_in, d_out = s[:-2], s[-2], s[-1]
            out[p] = kvlib.LayerStats(
                a_outer=psd(ks[0], *lead, d_in, d_in),
                b_outer=psd(ks[1], *lead, d_out, d_out))
        return out

    STEPS = 5
    opt = kfac_preconditioner(0.03, 0.9, policy=every_k(2))
    params = kvlib.unflatten_params(grads(0))

    def run(rt, meshed):
        state = opt.init(params, Extras(stats=stats(0), sched=rt))
        if meshed:
            mesh = compat.make_mesh((4,), ('data',))

            def body(g, s, st):
                return opt.update(g, s, extras=Extras(stats=st, sched=rt))

            step = jax.jit(compat.shard_map(
                body, mesh=mesh, in_specs=(P(), P(), P()),
                out_specs=(P(), P()), check=False))
        else:
            def step(g, s, st):
                return opt.update(g, s, extras=Extras(stats=st, sched=rt))
        outs = []
        for t in range(STEPS):
            out, state = step(grads(t), state, stats(t))
            outs.append(out)
        return outs, state

    def maxdiff(a, b):
        return max(float(np.max(np.abs(np.asarray(x).astype(np.float64)
                                       - np.asarray(y).astype(np.float64))))
                   for x, y in zip(jax.tree_util.tree_leaves(a),
                                   jax.tree_util.tree_leaves(b)))

    one_rt = lambda shard: RefreshRuntime(pipeline='onestep',
                                          shard_refresh=shard)
    o_single, s_single = run(one_rt(False), meshed=False)
    o_mesh, s_mesh = run(one_rt(True), meshed=True)
    lag = {k: int(v) for k, v in pipemod.pipeline_metrics(s_mesh).items()}

    # structural overlap: dependent dot-FLOP fraction per pipeline mode
    from repro.launch import hlo_analysis
    frac = {}
    for mode in ('sync', 'onestep'):
        rt = RefreshRuntime(pipeline=mode, shard_refresh=True)
        st = opt.init(params, Extras(stats=stats(0), sched=rt))
        mesh = compat.make_mesh((4,), ('data',))

        def body(g, s, stt):
            return opt.update(g, s, extras=Extras(stats=stt, sched=rt))

        step = jax.jit(compat.shard_map(
            body, mesh=mesh, in_specs=(P(), P(), P()),
            out_specs=(P(), P()), check=False))
        txt = step.lower(grads(0), st, stats(0)).compile().as_text()
        frac[mode] = hlo_analysis.collective_overlap(txt).dependent_fraction

    print(json.dumps({
        'devices': jax.device_count(),
        'mesh_vs_single_out': maxdiff(o_mesh, o_single),
        'mesh_vs_single_state': maxdiff(
            [l for l in jax.tree_util.tree_leaves(s_mesh)],
            [l for l in jax.tree_util.tree_leaves(s_single)]),
        'lag': lag,
        'dep_frac': frac,
    }))
""")


@pytest.mark.multihost
def test_onestep_sharded_matches_single_host():
    out = subprocess.run(
        [sys.executable, '-c', _MESH_SCRIPT],
        capture_output=True, text=True, timeout=600,
        # JAX_PLATFORMS pinned: the scrubbed env must not fall through to
        # accelerator discovery (libtpu-on-a-TPU-less-host hangs forever)
        env={'PYTHONPATH': 'src', 'PATH': '/usr/bin:/bin', 'HOME': '/root',
             'JAX_PLATFORMS': 'cpu'},
        cwd=Path(__file__).resolve().parent.parent)
    assert out.returncode == 0, out.stderr[-3000:]
    rec = json.loads(out.stdout.strip().splitlines()[-1])
    assert rec['devices'] == 4
    # same tolerance rationale as the sync sharded-refresh test: the
    # exchange is bit-exact, slice-granular LAPACK batching moves the last
    # float ulp, replicated-stats psum rounding likewise
    assert rec['mesh_vs_single_out'] < 1e-4
    assert rec['mesh_vs_single_state'] < 1e-4
    # refreshes fired at steps 0, 2, 4; after step 4 the in-flight
    # inverses are 1 step old, the stats buffer always 1
    assert rec['lag'] == {'pipeline_lag': 1, 'pipeline_lag/refresh': 1,
                          'pipeline_lag/stats': 1}
    # the point of the pipeline: in sync mode the preconditioning dots sit
    # in the collectives' dependence cone; in onestep they all leave it
    assert rec['dep_frac']['sync'] > 0.5
    assert rec['dep_frac']['onestep'] == 0.0
