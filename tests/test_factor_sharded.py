"""Sharded vocab-head factors (repro.core.factor_sharded): policy routing,
matrix-free solve accuracy, and state compatibility.

Contracts proven here:
  * ``head_policy='dense'`` — and an untripped threshold under any policy —
    reproduce the legacy K-FAC/Shampoo outputs AND state bit-exactly
    (atol=0): the split returns the original plan object and ``head=None``
    keeps the state pytree structure unchanged;
  * ``'exclude'`` changes only the tripped head path (identity on the
    oversized side), non-head buckets stay bit-exact;
  * ``'shard'`` matches the dense damped inverse within the iterative
    tolerance (CG at power −1; binomial series at Shampoo's −1/4), with
    the non-head buckets again bit-exact;
  * on a real 4-device host mesh (subprocess) the distributed partial-psum
    solve agrees with the legacy dense run to the same tolerance, and the
    ``factor/*`` call-site is recorded with the partial-psum mode;
  * checkpoint save/restore mid-run with sharded head state resumes
    bit-exactly (frozen dampings + cached dense-side operators roundtrip);
  * the sub-slice ownership helpers partition factor rows exactly once;
  * ``step_metrics`` surfaces the declared ``repro.obs`` fields.
"""
import json
import subprocess
import sys
import textwrap
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import bucketing
from repro.core import factor_sharded as fsh
from repro.core import kv as kvlib
from repro.core.factor_sharded import FactorShardConfig
from repro.core.transform import Extras
from repro.schedule import ownership
from repro.train import checkpoint as ckpt


# ---------------------------------------------------------------------------
# Config + plan split


def test_config_validation():
    assert FactorShardConfig().head_policy == 'dense'
    with pytest.raises(ValueError):
        FactorShardConfig(head_policy='drop')
    with pytest.raises(ValueError):
        FactorShardConfig(solver='chebyshev')
    assert fsh.from_extras(None) == FactorShardConfig()
    assert fsh.from_extras(Extras()) == FactorShardConfig()
    cfg = FactorShardConfig(head_policy='shard', shard_threshold=128)
    assert fsh.from_extras(Extras(factor=cfg)) is cfg
    kw = fsh.from_extras(Extras(factor={'head_policy': 'exclude'}))
    assert kw.head_policy == 'exclude'


def _toy_plan():
    return bucketing.build_plan({'blk/w': jnp.zeros((8, 6)),
                                 'head/w': jnp.zeros((8, 40))})


def test_split_plan_identity_when_nothing_trips():
    plan = _toy_plan()
    for cfg in (FactorShardConfig(),                       # policy dense
                FactorShardConfig(head_policy='shard',     # threshold high
                                  shard_threshold=64)):
        dense, pol = fsh.split_plan(plan, cfg)
        assert dense is plan and pol == {}


def test_split_plan_trips_per_side():
    plan = _toy_plan()
    dense, pol = fsh.split_plan(
        plan, FactorShardConfig(head_policy='shard', shard_threshold=32))
    dense_keys = {b.key for b in dense.buckets}
    head_keys = set(pol)
    assert len(head_keys) == 1 and not (dense_keys & head_keys)
    (policies,) = pol.values()
    assert policies == ('dense', 'shard')   # only the 40-dim out side trips


def test_subslice_ownership_partitions_rows():
    assert ownership.factor_block(40, 4) == 10
    assert ownership.factor_block(41, 4) == 11
    np.testing.assert_array_equal(ownership.assign_subslice_owners(40, 4),
                                  np.arange(4))
    plan = _toy_plan()
    desc = ownership.describe_subslices(plan, 4, 32)
    # only the tripped side appears; band sizes cover the dim exactly once
    (key,) = [k for k in desc if k.endswith('/out')]
    assert sum(desc[key]) == 40 and max(desc[key]) == 10
    assert not any(k.endswith('/in') for k in desc)


# ---------------------------------------------------------------------------
# Optimizer-level equivalence (single device; the solve's psum is a no-op)


def _paths():
    return {'blk/w': (8, 6), 'head/w': (8, 40)}


def _tree(seed):
    rng = np.random.default_rng(seed)
    return {p: jnp.asarray(rng.normal(size=s), jnp.float32)
            for p, s in _paths().items()}


def _stats(seed=10):
    rng = np.random.default_rng(seed)

    def psd(d):
        m = rng.normal(size=(d, d))
        return jnp.asarray(m @ m.T / d + 0.5 * np.eye(d), jnp.float32)

    return {p: kvlib.LayerStats(a_outer=psd(s[0]), b_outer=psd(s[1]))
            for p, s in _paths().items()}


def _run(opt_factory, factor, steps=3):
    params, grads, stats = _tree(0), _tree(1), _stats()
    opt = opt_factory()
    ex = Extras(stats=stats, factor=factor)
    state = opt.init(params, ex)
    out = None
    for _ in range(steps):
        out, state = opt.update(grads, state, params=params, extras=ex)
    return kvlib.flatten_params(out), state


def _md(a, b, p):
    return float(jnp.max(jnp.abs(np.asarray(a[p], np.float64)
                                 - np.asarray(b[p], np.float64))))


def _kf():
    import importlib
    mod = importlib.import_module('repro.core.kfac')
    return mod.kfac_preconditioner(gamma=0.5, interval=1)


def _sp():
    import importlib
    mod = importlib.import_module('repro.core.shampoo')
    return mod.shampoo_preconditioner(gamma=0.5, interval=1)


@pytest.mark.parametrize('factory', [_kf, _sp], ids=['kfac', 'shampoo'])
def test_dense_policy_is_legacy_bit_exact(factory):
    legacy_out, legacy_st = _run(factory, None)
    dense_out, dense_st = _run(
        factory, FactorShardConfig(head_policy='dense', shard_threshold=32))
    for p in legacy_out:
        assert _md(legacy_out, dense_out, p) == 0.0, p
    # state structure AND values identical (head=None keeps the pytree)
    la, da = (jax.tree_util.tree_leaves(s) for s in (legacy_st, dense_st))
    assert len(la) == len(da)
    for x, y in zip(la, da):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


def test_exclude_touches_only_head_path():
    legacy, _ = _run(_kf, None)
    excl, _ = _run(_kf, FactorShardConfig(head_policy='exclude',
                                          shard_threshold=32))
    assert _md(excl, legacy, 'blk/w') == 0.0
    assert _md(excl, legacy, 'head/w') > 0.0   # the guard changes the head


def test_shard_cg_matches_dense_within_tolerance():
    legacy, st = _run(_kf, None)
    shard, st_shard = _run(_kf, FactorShardConfig(
        head_policy='shard', shard_threshold=32, solver='cg',
        solve_iters=60))
    assert _md(shard, legacy, 'blk/w') == 0.0  # dense bucket untouched
    assert _md(shard, legacy, 'head/w') < 1e-5
    # the sharded state carries the declared obs fields
    m = fsh.step_metrics(st_shard)
    assert set(m) == set(fsh.METRIC_FIELDS)
    assert float(m['factor_solve_iters']) == 60
    assert float(m['factor_shard_bytes']) > 0
    assert fsh.step_metrics(st) == {}          # legacy state: no fields


def test_shard_binomial_matches_shampoo_root():
    legacy, _ = _run(_sp, None)
    shard, _ = _run(_sp, FactorShardConfig(
        head_policy='shard', shard_threshold=32, solver='binomial',
        solve_iters=600))
    assert _md(shard, legacy, 'blk/w') == 0.0
    assert _md(shard, legacy, 'head/w') < 1e-4


def test_obs_declares_factor_fields():
    from repro.obs import events
    assert 'factor_solve_iters' in events.SCHEMAS['step']
    assert 'factor_shard_bytes' in events.SCHEMAS['step']
    assert 'solve_iters' in events._SITE_FIELDS
    assert 'factor_shard_bytes' in events._SITE_FIELDS


# ---------------------------------------------------------------------------
# Checkpoint resume: sharded head state (frozen dampings + cached dense-side
# operators) must roundtrip bit-exactly, including mid-interval


def _factor_train(steps, tmp_path=None, save_at=None, factor=None):
    from repro.core.registry import make_optimizer
    from repro.data.synthetic import ClassStream
    from repro.models import module as M
    from repro.models.simple import MLP, classifier_loss_fn
    from repro.train.step import init_opt_state, make_train_step

    stream = ClassStream(batch=32, dim=8, classes=3, seed=0)
    model = MLP([8, 32, 16, 3])
    model.loss_fn = classifier_loss_fn(model)
    params = M.init_params(model.param_specs(), jax.random.PRNGKey(0))
    opt, capture = make_optimizer('kfac', lr=0.05, interval=3)
    taps_fn = lambda p: model.make_taps(32, capture)  # noqa: E731
    state = init_opt_state(model, opt, capture, params, stream.batch_at(0),
                           taps_fn=taps_fn, factor=factor)
    step = jax.jit(make_train_step(model, opt, capture, taps_fn=taps_fn,
                                   factor=factor))
    for i in range(steps):
        if save_at is not None and i == save_at:
            ckpt.save(tmp_path, i, {'params': params, 'opt_state': state},
                      {'next_step': i})
            template = jax.tree_util.tree_map(
                jnp.zeros_like, {'params': params, 'opt_state': state})
            restored, meta = ckpt.restore(tmp_path, i, template)
            params, state = restored['params'], restored['opt_state']
            assert meta['next_step'] == i
        params, state, _ = step(params, state, stream.batch_at(i))
    return params, state


def test_sharded_head_state_resume_bit_exact(tmp_path):
    # threshold 32: the (8,32) layer trips its out side, (32,16) its in
    # side, (16,3) stays dense — head + dense buckets in one state; save at
    # step 4 = mid-interval for k=3 (frozen dampings must survive)
    factor = FactorShardConfig(head_policy='shard', shard_threshold=32,
                               solver='cg', solve_iters=20)
    heads = fsh.head_states(_factor_train(1, factor=factor)[1])
    assert heads and len(heads[0].buckets) == 2   # the premise above holds
    p_ref, s_ref = _factor_train(7, factor=factor)
    p_res, s_res = _factor_train(7, tmp_path=tmp_path, save_at=4,
                                 factor=factor)
    for x, y in zip(jax.tree_util.tree_leaves((p_ref, s_ref)),
                    jax.tree_util.tree_leaves((p_res, s_res))):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


# ---------------------------------------------------------------------------
# 4-device proof (subprocess: the forced device flag must not leak): the
# distributed partial-psum solve ≡ the legacy dense run within iterative
# tolerance, dense buckets bit-exact, and the factor site is recorded

_SHARD_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    import json
    import numpy as np
    import jax, jax.numpy as jnp
    from jax.sharding import PartitionSpec as P
    import importlib
    kfac_mod = importlib.import_module('repro.core.kfac')
    sh_mod = importlib.import_module('repro.core.shampoo')
    from repro.comm import metrics
    from repro.core import kv as kvlib
    from repro.core.factor_sharded import FactorShardConfig
    from repro.core.transform import Extras
    from repro.schedule.runtime import RefreshRuntime
    from repro.sharding import compat

    PATHS = {'blk/w': (8, 6), 'head/w': (8, 40)}
    rng = np.random.default_rng(0)
    params = {p: jnp.asarray(rng.normal(size=s), jnp.float32)
              for p, s in PATHS.items()}
    grads = {p: jnp.asarray(rng.normal(size=s), jnp.float32)
             for p, s in PATHS.items()}

    def psd(d):
        m = rng.normal(size=(d, d))
        return jnp.asarray(m @ m.T / d + 0.5 * np.eye(d), jnp.float32)

    stats = {p: kvlib.LayerStats(a_outer=psd(s[0]), b_outer=psd(s[1]))
             for p, s in PATHS.items()}
    mesh = compat.make_mesh((4,), ('data',))
    rt = RefreshRuntime(shard_refresh=True)

    def run(opt_factory, factor, steps=3):
        opt = opt_factory()
        state = opt.init(params, Extras(stats=stats, factor=factor,
                                        sched=rt))

        def body(g, s, st):
            return opt.update(g, s, extras=Extras(stats=st, factor=factor,
                                                  sched=rt))

        step = jax.jit(compat.shard_map(body, mesh=mesh,
                                        in_specs=(P(), P(), P()),
                                        out_specs=(P(), P()), check=False))
        out = None
        for _ in range(steps):
            out, state = step(grads, state, stats)
        return kvlib.flatten_params(out), state

    def md(a, b, p):
        return float(jnp.max(jnp.abs(
            np.asarray(a[p], np.float64) - np.asarray(b[p], np.float64))))

    kf = lambda: kfac_mod.kfac_preconditioner(gamma=0.5, interval=1)
    sp = lambda: sh_mod.shampoo_preconditioner(gamma=0.5, interval=1)
    cg = FactorShardConfig(head_policy='shard', shard_threshold=32,
                           solver='cg', solve_iters=60)
    bino = FactorShardConfig(head_policy='shard', shard_threshold=32,
                             solver='binomial', solve_iters=600)

    rec = {'devices': jax.device_count()}
    legacy, _ = run(kf, None)
    dense, _ = run(kf, FactorShardConfig(head_policy='dense',
                                         shard_threshold=32))
    shard, st = run(kf, cg)
    rec['kfac_dense_blk'] = md(dense, legacy, 'blk/w')
    rec['kfac_dense_head'] = md(dense, legacy, 'head/w')
    rec['kfac_shard_blk'] = md(shard, legacy, 'blk/w')
    rec['kfac_shard_head'] = md(shard, legacy, 'head/w')

    sp_legacy, _ = run(sp, None)
    sp_shard, _ = run(sp, bino)
    rec['shampoo_shard_blk'] = md(sp_shard, sp_legacy, 'blk/w')
    rec['shampoo_shard_head'] = md(sp_shard, sp_legacy, 'head/w')

    sites = metrics.snapshot()
    rec['sites'] = {k: {'mode': v['mode'],
                        'bytes_per_call': v['bytes_per_call']}
                    for k, v in sites.items() if k.startswith('factor/')}
    print(json.dumps(rec))
""")


@pytest.mark.multihost
def test_shard_solve_matches_dense_on_4_devices():
    out = subprocess.run(
        [sys.executable, '-c', _SHARD_SCRIPT],
        capture_output=True, text=True, timeout=1800,
        # JAX_PLATFORMS pinned: the scrubbed env must not fall through to
        # accelerator discovery (libtpu-on-a-TPU-less-host hangs forever)
        env={'PYTHONPATH': 'src', 'PATH': '/usr/bin:/bin', 'HOME': '/root',
             'JAX_PLATFORMS': 'cpu'},
        cwd=Path(__file__).resolve().parent.parent)
    assert out.returncode == 0, out.stderr[-3000:]
    rec = json.loads(out.stdout.strip().splitlines()[-1])
    assert rec['devices'] == 4
    # dense policy ≡ legacy, bit-exact, even on the live mesh
    assert rec['kfac_dense_blk'] == 0.0 and rec['kfac_dense_head'] == 0.0
    # sharded solve: dense buckets bit-exact, head within CG tolerance
    assert rec['kfac_shard_blk'] == 0.0
    assert rec['kfac_shard_head'] < 1e-4, rec
    assert rec['shampoo_shard_blk'] == 0.0
    assert rec['shampoo_shard_head'] < 1e-3, rec
    # the distributed solve recorded its partial-psum call-sites
    modes = {k: v['mode'] for k, v in rec['sites'].items()}
    assert modes.get('factor/kfac') == 'psum-partial', modes
    assert modes.get('factor/shampoo') == 'psum-partial', modes
    assert all(v['bytes_per_call'] > 0 for v in rec['sites'].values())
