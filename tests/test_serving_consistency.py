"""Serving-path correctness: prefill(t[:n-1]) + decode(t[n-1]) must produce
the same next-token logits as prefill over the full prompt — across the
attention (RoPE/cache), SSM (recurrent-state) and hybrid paths."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_reduced
from repro.models import build_model
from repro.models import module as M

ARCHS = ['qwen2-0.5b', 'mamba2-780m', 'jamba-v0.1-52b', 'qwen3-moe-30b-a3b']


def _grow_cache(model, cache, batch, total):
    grown = model.init_cache(batch, total)
    return jax.tree_util.tree_map(
        lambda full, part: jax.lax.dynamic_update_slice(
            full, part.astype(full.dtype), (0,) * full.ndim), grown, cache)


@pytest.mark.parametrize('arch', ARCHS)
def test_decode_matches_prefill(arch):
    # ample expert capacity: capacity-drops differ between batched prefill
    # and single-token decode by design (documented MoE semantics), which
    # would otherwise make this exactness test a routing-skew lottery.
    cfg = get_reduced(arch).replace(capacity_factor=8.0)
    model = build_model(cfg)
    params = M.init_params(model.param_specs(), jax.random.PRNGKey(0))
    n, b = 16, 2
    toks = jax.random.randint(jax.random.PRNGKey(1), (b, n), 0, cfg.vocab)

    # reference: one prefill over the full prompt
    ref_logits, _ = jax.jit(model.prefill_fn)(params, {'tokens': toks})

    # prefill n-1, grow the cache, decode the last token
    logits0, cache = jax.jit(model.prefill_fn)(
        params, {'tokens': toks[:, :n - 1]})
    if cfg.family != 'ssm':  # attention caches are length-bound; SSM is O(1)
        cache = _grow_cache(model, cache, b, n)
    got_logits, _ = jax.jit(model.decode_fn)(
        params, cache, toks[:, n - 1], jnp.asarray(n - 1, jnp.int32))

    ref = np.asarray(ref_logits, np.float32)
    got = np.asarray(got_logits, np.float32)
    # compare top-1 and values (float tolerance; fp paths differ slightly)
    np.testing.assert_allclose(got, ref, rtol=2e-2, atol=2e-2)
    assert (got.argmax(-1) == ref.argmax(-1)).all()


@pytest.mark.parametrize('arch', ['qwen2-0.5b', 'mamba2-780m'])
def test_multi_step_decode_stable(arch):
    """8 greedy decode steps stay finite and deterministic."""
    cfg = get_reduced(arch)
    model = build_model(cfg)
    params = M.init_params(model.param_specs(), jax.random.PRNGKey(0))
    b, plen, gen = 2, 8, 8
    toks = jax.random.randint(jax.random.PRNGKey(1), (b, plen), 0, cfg.vocab)
    logits, cache = jax.jit(model.prefill_fn)(params, {'tokens': toks})
    if cfg.family != 'ssm':
        cache = _grow_cache(model, cache, b, plen + gen)
    decode = jax.jit(model.decode_fn)
    outs = []
    tok = jnp.argmax(logits, -1).astype(jnp.int32)
    for i in range(gen):
        logits, cache = decode(params, cache, tok,
                               jnp.asarray(plen + i, jnp.int32))
        assert np.isfinite(np.asarray(logits, np.float32)).all()
        tok = jnp.argmax(logits, -1).astype(jnp.int32)
        outs.append(np.asarray(tok))
    # deterministic across a re-run
    logits2, cache2 = jax.jit(model.prefill_fn)(params, {'tokens': toks})
    if cfg.family != 'ssm':
        cache2 = _grow_cache(model, cache2, b, plen + gen)
    tok2 = jnp.argmax(logits2, -1).astype(jnp.int32)
    for i in range(gen):
        logits2, cache2 = decode(params, cache2, tok2,
                                 jnp.asarray(plen + i, jnp.int32))
        tok2 = jnp.argmax(logits2, -1).astype(jnp.int32)
        np.testing.assert_array_equal(np.asarray(tok2), outs[i])
