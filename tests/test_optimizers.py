"""Every registry optimizer trains the AE/classifier; ablations behave
as the paper reports (momentum / KL-clip / KVs matter — Table 9)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import kv as kvlib
from repro.core.eva import eva
from repro.core.registry import make_optimizer, optimizer_names
from repro.data.synthetic import ClassStream
from repro.models import module as M
from repro.models.simple import MLP, classifier_loss_fn
from repro.train.step import init_opt_state, make_train_step

STREAM = ClassStream(batch=64, dim=16, classes=4, spread=1.5, seed=0)


def _train(opt, capture, steps=25, model=None, taps_batch=64, seed=0):
    model = model or MLP([16, 32, 32, 4])
    model.loss_fn = classifier_loss_fn(model)
    params = M.init_params(model.param_specs(), jax.random.PRNGKey(seed))
    taps_fn = (lambda p: model.make_taps(taps_batch, capture)) \
        if capture.needs_taps else None
    state = init_opt_state(model, opt, capture, params, STREAM.batch_at(0),
                           taps_fn=taps_fn)
    step = jax.jit(make_train_step(model, opt, capture, taps_fn=taps_fn))
    losses = []
    for i in range(steps):
        params, state, m = step(params, state, STREAM.batch_at(i))
        losses.append(float(m['loss']))
    return losses[0], losses[-1]


def _train_tail_gm(opt, capture, steps, tail=8, **kw):
    """Geometric mean of the last ``tail`` minibatch losses — near the loss
    floor single-step losses are minibatch noise spanning decades, so
    endpoint comparisons between optimizers are a parity lottery."""
    model = MLP([16, 32, 32, 4])
    model.loss_fn = classifier_loss_fn(model)
    params = M.init_params(model.param_specs(), jax.random.PRNGKey(0))
    taps_fn = (lambda p: model.make_taps(64, capture)) \
        if capture.needs_taps else None
    state = init_opt_state(model, opt, capture, params, STREAM.batch_at(0),
                           taps_fn=taps_fn)
    step = jax.jit(make_train_step(model, opt, capture, taps_fn=taps_fn))
    losses = []
    for i in range(steps):
        params, state, m = step(params, state, STREAM.batch_at(i))
        losses.append(float(m['loss']))
    t = np.asarray(losses[-tail:]) + 1e-8
    return float(np.exp(np.mean(np.log(t))))


@pytest.mark.parametrize('name', optimizer_names())
def test_optimizer_reduces_loss(name):
    kw = {'m': 8} if name == 'mfac' else {}
    lr = {'adamw': 1e-3, 'adagrad': 0.02, 'mfac': 0.01}.get(name, 0.03)
    opt, capture = make_optimizer(name, lr=lr, **kw)
    first, last = _train(opt, capture)
    assert np.isfinite(last), name
    assert last < first, f'{name}: {first} -> {last}'


def test_ablation_kl_clip_matters():
    """Without KL clip a hot LR diverges or regresses; with it, trains."""
    hot = 2.0
    _, with_clip = _train(*(eva(lr=hot, kl_kappa=1e-3), kvlib.EVA_CAPTURE))
    _, without = _train(*(eva(lr=hot, kl_kappa=None), kvlib.EVA_CAPTURE))
    assert with_clip < 1.4  # still trains
    assert (not np.isfinite(without)) or without > with_clip


def test_ablation_momentum_matters():
    _, with_m = _train(*(eva(lr=0.03, momentum=0.9), kvlib.EVA_CAPTURE))
    _, without = _train(*(eva(lr=0.03, momentum=0.0), kvlib.EVA_CAPTURE))
    assert with_m <= without + 1e-3


def test_eva_tracks_kfac():
    """Paper's core claim at micro-scale: Eva ≈ K-FAC ≤ SGD at equal steps.

    Compared on tail geometric means: the seed version compared single
    final-step losses, which near the floor are minibatch noise spanning
    decades (and under the pre-fix momentum limit cycle the result depended
    on which phase of the oscillation step N landed on)."""
    o1, c1 = make_optimizer('eva', lr=0.05)
    o2, c2 = make_optimizer('kfac', lr=0.05)
    o3, c3 = make_optimizer('sgd', lr=0.05)
    l_eva = _train_tail_gm(o1, c1, steps=60)
    l_kfac = _train_tail_gm(o2, c2, steps=60)
    l_sgd = _train_tail_gm(o3, c3, steps=60)
    assert l_eva <= l_sgd * 1.05
    # "Eva ≈ K-FAC": same convergence regime (within ~a decade), both far
    # below the ~1.8 initial loss
    assert abs(np.log10(l_eva) - np.log10(l_kfac)) < 1.5
    assert l_eva < 0.05 and l_kfac < 0.05


def test_interval_staleness_tradeoff():
    """A stale K-FAC preconditioner must not break convergence: both
    interval=1 and interval=20 drive this task to (near) the loss floor.
    (At this micro-scale both variants fully converge, so comparing the
    residual losses is numerical noise — the Fig. 6 time/quality trade-off
    is measured at a fixed budget in benchmarks/fig6_interval.py.)"""
    o1, c = make_optimizer('kfac', lr=0.05, interval=1)
    o2, _ = make_optimizer('kfac', lr=0.05, interval=20)
    _, fresh = _train(o1, c, steps=30)
    _, stale = _train(o2, c, steps=30)
    assert np.isfinite(fresh) and fresh < 0.5
    assert np.isfinite(stale) and stale < 0.5
