"""Batched serving demo: prefill + greedy decode loop over the KV cache.

    PYTHONPATH=src python examples/serve_lm.py [--tokens 32] [--batch 4]
"""
import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs.registry import demo_lm
from repro.data import LMStream
from repro.models import build_model
from repro.models import module as M


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument('--tokens', type=int, default=32)
    ap.add_argument('--batch', type=int, default=4)
    ap.add_argument('--prompt-len', type=int, default=32)
    args = ap.parse_args()

    cfg = demo_lm('small')
    model = build_model(cfg)
    params = M.init_params(model.param_specs(), jax.random.PRNGKey(0))
    prompts = LMStream(vocab=cfg.vocab, seq_len=args.prompt_len,
                       batch=args.batch, seed=7).batch_at(0)['tokens']

    # serving caches must outlive the prompt: preallocate to prompt+gen
    total = args.prompt_len + args.tokens
    prefill = jax.jit(model.prefill_fn)
    decode = jax.jit(model.decode_fn, donate_argnums=(1,))

    t0 = time.time()
    logits, cache = prefill(params, {'tokens': prompts})
    # grow the cache to the full serving length
    grown = model.init_cache(args.batch, total)
    cache = jax.tree_util.tree_map(
        lambda full, part: jax.lax.dynamic_update_slice(
            full, part.astype(full.dtype), (0,) * full.ndim), grown, cache)
    tok = jnp.argmax(logits, -1).astype(jnp.int32)
    out = [tok]
    for i in range(args.tokens - 1):
        logits, cache = decode(params, cache, tok,
                               jnp.asarray(args.prompt_len + i, jnp.int32))
        tok = jnp.argmax(logits, -1).astype(jnp.int32)
        out.append(tok)
    jax.block_until_ready(tok)
    dt = time.time() - t0
    gen = jnp.stack(out, axis=1)
    print(f'generated {args.batch}×{args.tokens} tokens in {dt:.2f}s '
          f'({args.batch * args.tokens / dt:.1f} tok/s)')
    for b in range(args.batch):
        print(f'  seq {b}: {list(map(int, gen[b][:16]))} ...')


if __name__ == '__main__':
    main()
