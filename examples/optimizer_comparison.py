"""Optimizer shoot-out (paper Tables 4/7 in miniature): every optimizer in
the registry on the same LM task, equal iteration budget.

    PYTHONPATH=src python examples/optimizer_comparison.py [--steps 80]
"""
import argparse
import time

import jax

from repro.configs.registry import demo_lm
from repro.core import make_optimizer, optimizer_names
from repro.data import LMStream
from repro.models import build_model
from repro.models import module as M
from repro.train import init_opt_state, make_train_step

LRS = {'sgd': 0.05, 'adagrad': 0.02, 'adamw': 1e-3, 'eva': 0.05,
       'eva_f': 0.05, 'eva_s': 0.05, 'shampoo': 0.05, 'mfac': 0.05}
SKIP = {'kfac', 'foof'}  # full-tap capture targets the MLP/AE models


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument('--steps', type=int, default=80)
    args = ap.parse_args()
    cfg = demo_lm('small')
    data = LMStream(vocab=cfg.vocab, seq_len=64, batch=16, seed=0)
    print(f'bigram CE floor: {data.bigram_ce:.4f}\n')
    print(f'{"optimizer":10s} {"final CE":>9s} {"ms/step":>8s}')
    for name in optimizer_names():
        if name in SKIP:
            continue
        model = build_model(cfg)
        params = M.init_params(model.param_specs(), jax.random.PRNGKey(0))
        kw = {'m': 8} if name == 'mfac' else {}
        opt, capture = make_optimizer(name, lr=LRS.get(name, 0.05), **kw)
        state = init_opt_state(model, opt, capture, params, data.batch_at(0))
        step = jax.jit(make_train_step(model, opt, capture))
        params, state, m = step(params, state, data.batch_at(0))  # compile
        t0 = time.time()
        for i in range(1, args.steps):
            params, state, m = step(params, state, data.batch_at(i))
        dt = (time.time() - t0) / (args.steps - 1) * 1e3
        print(f'{name:10s} {float(m["loss"]):9.4f} {dt:8.1f}')


if __name__ == '__main__':
    main()
