"""Quickstart: train a small transformer LM with Eva in ~30 lines.

    PYTHONPATH=src python examples/quickstart.py
"""
import jax

from repro.configs.registry import demo_lm
from repro.core import make_optimizer
from repro.data import LMStream
from repro.models import build_model
from repro.models import module as M
from repro.train import init_opt_state, make_train_step

cfg = demo_lm('small')
model = build_model(cfg)
params = M.init_params(model.param_specs(), jax.random.PRNGKey(0))
data = LMStream(vocab=cfg.vocab, seq_len=64, batch=16, seed=0)

# the paper's optimizer: rank-one KV curvature + Sherman-Morrison (Eq. 13)
opt, capture = make_optimizer('eva', lr=0.05, gamma=0.03, kl_kappa=1e-3)

opt_state = init_opt_state(model, opt, capture, params, data.batch_at(0))
step = jax.jit(make_train_step(model, opt, capture))

print(f'params: {M.count_params(model.param_specs()):,}   '
      f'bigram CE floor: {data.bigram_ce:.3f}')
for i in range(100):
    params, opt_state, metrics = step(params, opt_state, data.batch_at(i))
    if i % 10 == 0:
        print(f'step {i:3d}  loss {float(metrics["loss"]):.4f}')
print('done — Eva trains like SGD-with-momentum, at second-order quality.')
