"""Paper §5.1 / Fig. 4: the 8-layer deep autoencoder benchmark.

Runs SGD / Adagrad / K-FAC / Shampoo / Eva on a synthetic MNIST-like stream
and prints the loss trajectory — the claim under test is the *relative*
ordering (Eva ≈ K-FAC, both well ahead of SGD at equal iterations).

    PYTHONPATH=src python examples/autoencoder_eva.py [--steps 60]
"""
import argparse

import jax

from repro.core import make_optimizer
from repro.data import AEStream
from repro.models import module as M
from repro.models.simple import ae_loss_fn, autoencoder
from repro.train import init_opt_state, make_train_step

LRS = {'sgd': 0.3, 'adagrad': 0.05, 'kfac': 0.15, 'shampoo': 0.3, 'eva': 0.15}


def train(name: str, steps: int, batch: int = 128) -> list[float]:
    model = autoencoder(hidden=(256, 64, 16, 64, 256), d_in=784)
    model.loss_fn = ae_loss_fn(model)
    params = M.init_params(model.param_specs(), jax.random.PRNGKey(0))
    data = AEStream(batch=batch)
    opt, capture = make_optimizer(name, lr=LRS[name])
    taps_fn = (lambda p: model.make_taps(batch, capture)) \
        if capture.needs_taps else None
    state = init_opt_state(model, opt, capture, params, data.batch_at(0),
                           taps_fn=taps_fn)
    step = jax.jit(make_train_step(model, opt, capture, taps_fn=taps_fn))
    losses = []
    for i in range(steps):
        params, state, m = step(params, state, data.batch_at(i))
        losses.append(float(m['loss']))
    return losses


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument('--steps', type=int, default=60)
    args = ap.parse_args()
    curves = {}
    for name in ('sgd', 'adagrad', 'kfac', 'shampoo', 'eva'):
        curves[name] = train(name, args.steps)
        c = curves[name]
        print(f'{name:8s} loss: {c[0]:.4f} -> {c[-1]:.4f}')
    print('\nstep ' + '  '.join(f'{n:>8s}' for n in curves))
    for i in range(0, args.steps, max(args.steps // 10, 1)):
        print(f'{i:4d} ' + '  '.join(f'{curves[n][i]:8.4f}' for n in curves))
    eva, kfac, sgd = (curves[n][-1] for n in ('eva', 'kfac', 'sgd'))
    print(f'\nEva/K-FAC final-loss ratio: {eva/kfac:.3f} (≈1 expected); '
          f'Eva/SGD: {eva/sgd:.3f} (<1 expected)')


if __name__ == '__main__':
    main()
