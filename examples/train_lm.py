"""End-to-end training driver: full trainer stack on a decoder-only LM.

Uses the production substrate: seekable data, async checkpointing + resume,
preemption handling, straggler watchdog, optional int8 gradient compression
(explicit-DP engine), any optimizer from the registry.

    PYTHONPATH=src python examples/train_lm.py                     # quick demo
    PYTHONPATH=src python examples/train_lm.py --scale 100m \\
        --steps 300 --opt eva                                      # the ~100M driver
    PYTHONPATH=src python examples/train_lm.py --opt sgd --compare eva
"""
import argparse
import time

import jax

from repro.configs.registry import demo_lm
from repro.core import make_optimizer
from repro.data import LMStream
from repro.models import build_model
from repro.models import module as M
from repro.train import Trainer, TrainerConfig


def run_one(opt_name: str, args) -> list[float]:
    cfg = demo_lm(args.scale)
    model = build_model(cfg)
    params = M.init_params(model.param_specs(), jax.random.PRNGKey(args.seed))
    n = M.count_params(model.param_specs())
    data = LMStream(vocab=cfg.vocab, seq_len=args.seq_len, batch=args.batch,
                    seed=args.seed)
    opt, capture = make_optimizer(opt_name, lr=args.lr)
    tc = TrainerConfig(total_steps=args.steps, log_every=args.log_every,
                       ckpt_every=args.ckpt_every,
                       out_dir=f'{args.out_dir}/{cfg.name}-{opt_name}')
    print(f'== {cfg.name} ({n/1e6:.1f}M params) × {opt_name} '
          f'(bigram CE floor {data.bigram_ce:.3f}) ==')
    t0 = time.time()
    _, _, history = Trainer(model, opt, capture, tc).fit(params, data)
    print(f'   {len(history)} steps in {time.time()-t0:.1f}s; '
          f'loss {history[0]:.4f} -> {history[-1]:.4f}')
    return history


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument('--scale', default='small', choices=['small', 'base', '100m'])
    ap.add_argument('--opt', default='eva')
    ap.add_argument('--compare', default=None,
                    help='also train with this optimizer and report both')
    ap.add_argument('--steps', type=int, default=200)
    ap.add_argument('--batch', type=int, default=16)
    ap.add_argument('--seq-len', type=int, default=64)
    ap.add_argument('--lr', type=float, default=0.05)
    ap.add_argument('--log-every', type=int, default=20)
    ap.add_argument('--ckpt-every', type=int, default=50)
    ap.add_argument('--out-dir', default='runs')
    ap.add_argument('--seed', type=int, default=0)
    args = ap.parse_args()

    h1 = run_one(args.opt, args)
    if args.compare:
        h2 = run_one(args.compare, args)
        n = min(len(h1), len(h2))
        print(f'\nfinal loss: {args.opt}={h1[n-1]:.4f} '
              f'{args.compare}={h2[n-1]:.4f}')


if __name__ == '__main__':
    main()
